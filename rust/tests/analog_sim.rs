//! Graph-generic analog crossbar simulator (`analog::CrossbarSim`):
//! σ = 0 bit-identity against the integer engine across the stage
//! grammars (every KWS dilation-schedule prefix, a dense-weight
//! variant, resnet8 residual blocks, a pooled DarkNet-style fuzz
//! graph), at digital pool sizes 1/2/4; the silent fast path's
//! allocation-freeness; and the `evaluate_noisy` sample-index clamp.

use std::sync::Arc;

use fqconv::analog::{CrossbarSim, NoiseConfig};
use fqconv::data::Dataset;
use fqconv::infer::graph::{synthetic_graph, DarkArch, Scratch, SeqArch, SynthArch};
use fqconv::util::Rng;

/// Every logit of the always-analog σ = 0 walk must equal the integer
/// engine's bit for bit, at every digital thread budget (the analog
/// walk is single-threaded; the engine must agree regardless of how
/// its own work is split).
fn assert_sigma0_identity(arch: &SynthArch, nw: f32, samples: usize) {
    let graph = Arc::new(synthetic_graph(arch, nw, 7.0, 11).unwrap());
    let mut sim = CrossbarSim::new(Arc::clone(&graph));
    let mut s_analog = Scratch::for_graph(&graph);
    let mut s_eng = Scratch::for_graph(&graph);
    let mut analog = vec![0f32; graph.classes()];
    let mut eng = vec![0f32; graph.classes()];
    let mut rng = Rng::new(0xA11A_106 ^ nw.to_bits() as u64);
    let mut x = vec![0f32; graph.in_numel()];
    for i in 0..samples {
        rng.fill_gaussian(&mut x, 0.8);
        sim.forward_analog_into(&x, NoiseConfig::default(), &mut rng, &mut s_analog, &mut analog);
        for threads in [1usize, 2, 4] {
            sim.graph().forward_into(&x, &mut s_eng, &mut eng, threads);
            assert_eq!(
                analog, eng,
                "σ=0 analog walk diverged from engine: arch={} nw={nw} sample={i} threads={threads}",
                arch.name()
            );
        }
    }
}

#[test]
fn sigma0_identity_every_kws_dilation_prefix() {
    // every prefix of the paper's [1, 1, 2, 4, 8, 8, 8] schedule — the
    // receptive field (and thus t_out per layer) changes each step, so
    // an indexing slip in the analog taps cannot hide in the full net
    let schedule = [1usize, 1, 2, 4, 8, 8, 8];
    for p in 1..=schedule.len() {
        let arch = SynthArch::Seq(SeqArch {
            name: "kws-prefix",
            n_in: 39,
            frames: 80,
            embed_dim: 32,
            classes: 12,
            convs: schedule[..p].iter().map(|&d| (32, 3, d)).collect(),
        });
        assert_sigma0_identity(&arch, 1.0, 2);
    }
}

#[test]
fn sigma0_identity_dense_weights() {
    // nw = 7 takes the dense (W4) weight path: the conductance
    // extraction reads a different WeightKind layout than ternary
    assert_sigma0_identity(&SynthArch::kws(), 7.0, 3);
}

#[test]
fn sigma0_identity_resnet8_residual_blocks() {
    // residual skip-adds (identity and 1x1 strided projections) through
    // the AddLut grids, on the smallest CIFAR ResNet
    assert_sigma0_identity(&SynthArch::resnet("r8", 1), 1.0, 2);
}

#[test]
fn sigma0_identity_pooled_fuzz_graph() {
    // a small DarkNet-style pooled grammar (3x3 widen / 1x1 squeeze
    // groups split by 2x2/2 max pools) — direct literal, sized for
    // debug-mode tests; full-size darknet19 runs in the release-mode
    // table7_noise bench
    let arch = SynthArch::Dark(DarkArch {
        name: "dark-fuzz",
        in_ch: 3,
        h: 16,
        w: 16,
        classes: 7,
        groups: vec![(8, 1, true), (12, 3, true), (16, 1, false)],
    });
    assert_sigma0_identity(&arch, 1.0, 2);
}

#[test]
fn silent_fast_path_is_allocation_free() {
    let graph = Arc::new(synthetic_graph(&SynthArch::kws(), 1.0, 7.0, 3).unwrap());
    let mut sim = CrossbarSim::new(Arc::clone(&graph));
    let mut s = Scratch::for_graph(&graph);
    let mut logits = vec![0f32; graph.classes()];
    let mut rng = Rng::new(5);
    let mut x = vec![0f32; graph.in_numel()];
    rng.fill_gaussian(&mut x, 0.8);
    // warm-up: the plan sizes the buffers on construction, but let one
    // forward settle any lazy growth before pinning
    sim.forward_noisy_into(&x, NoiseConfig::default(), &mut rng, &mut s, &mut logits);
    let caps = s.capacities();
    for _ in 0..5 {
        sim.forward_noisy_into(&x, NoiseConfig::default(), &mut rng, &mut s, &mut logits);
    }
    assert_eq!(
        s.capacities(),
        caps,
        "σ=0 fast path must reuse the caller's scratch, not allocate per call"
    );
}

/// A tiny deterministic dataset over the KWS input geometry.
struct Toy {
    shape: Vec<usize>,
    classes: usize,
}

impl Dataset for Toy {
    fn input_shape(&self) -> Vec<usize> {
        self.shape.clone()
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn sample(&self, id: u64, _aug: Option<&mut Rng>) -> (Vec<f32>, i32) {
        let numel: usize = self.shape.iter().product();
        let mut x = vec![0f32; numel];
        Rng::new(id).fill_gaussian(&mut x, 0.8);
        (x, (id % self.classes as u64) as i32)
    }
}

#[test]
fn evaluate_noisy_clamps_to_val_size() {
    // n past the held-out set must evaluate the same 512 samples, not
    // wrap the index and double-count early ids (which inflated the
    // reported accuracy); at σ = 0 the result is deterministic, so the
    // clamped call and the in-bounds call must agree exactly
    let arch = SynthArch::Seq(SeqArch {
        name: "toy",
        n_in: 4,
        frames: 10,
        embed_dim: 8,
        classes: 3,
        convs: vec![(8, 3, 1)],
    });
    let graph = Arc::new(synthetic_graph(&arch, 1.0, 7.0, 21).unwrap());
    let mut sim = CrossbarSim::new(graph);
    let ds = Toy { shape: vec![4, 10], classes: 3 };
    let silent = NoiseConfig::default();
    let exact = sim.evaluate_noisy(&ds, fqconv::data::VAL_SIZE as usize, silent, 1, 9);
    let clamped = sim.evaluate_noisy(&ds, 600, silent, 1, 9);
    assert_eq!(exact, clamped, "n > VAL_SIZE must clamp, not wrap and double-count");
}
