//! Model-checked concurrency protocols (`--features model-check`).
//!
//! Run with:
//!   cargo test -p fqconv --features model-check --test model_check
//!
//! Three load-bearing protocols are checked (see CONCURRENCY.md for the
//! invariant catalogue):
//!
//! 1. **Pool fork-join epoch handshake** — checked against the *real*
//!    `exec::Pool`: no lost wakeup (every part runs exactly once), no
//!    stale-epoch execution across consecutive forks, and the
//!    panic-guard join (a panicking part propagates to the caller after
//!    every participant finished, and the pool survives).
//! 2. **Registry replica generations** — distilled model of the
//!    register/evict vs. in-flight-batch protocol from
//!    `serve::worker_loop`: a batch is only ever served by a replica of
//!    its own generation, a stale resolution never overwrites the
//!    current generation's cached replica, and an evict prunes exactly
//!    once.
//! 3. **Quarantine/bounce hand-back** — distilled model: a poisoned
//!    model quarantines its replica and fails its batches *typed*; it
//!    never retires the shared worker, which keeps serving healthy
//!    models.
//! 4. **Admission/shed handshake** — distilled model of the
//!    `submit_with` reservation protocol: the queue never exceeds its
//!    admission bound, and every request gets exactly one terminal
//!    reply — served by the consumer, or shed right at submit.
//! 5. **Streaming session lifecycle** — distilled model of the serve
//!    session table (`open_session`/`feed`/idle sweep vs. the worker's
//!    checkout/put-back): an idle eviction racing an in-flight feed
//!    yields exactly one terminal outcome per feed — served or typed
//!    `UnknownSession`, never a hang or a double reply — and a stale
//!    handle never aliases a recycled slot.
//!
//! The registry/quarantine protocols are modeled in distilled form
//! (same decision structure, minus backends/mpsc/wall-clock — none of
//! which the deterministic scheduler can control); the real threaded
//! registry is exercised by the tier-1 stress test in
//! rust/tests/serving.rs. The seeded-mutation suite at the bottom
//! hand-breaks each protocol in ≥6 distinct ways and proves the checker
//! catches every one; the replay test pins that a recorded failing
//! schedule reproduces its failure deterministically.

#![cfg(feature = "model-check")]

use std::collections::VecDeque;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use fqconv::check::sync::{spawn_named, Condvar, Mutex, RwLock};
use fqconv::check::{check_with, replay, Config, FailureKind};
use fqconv::exec::Pool;

fn cfg(preemptions: usize, max_execs: usize, random_execs: usize) -> Config {
    Config { preemptions, max_execs, random_execs, seed: 0x5eed_cafe }
}

// ===========================================================================
// 1. Pool fork-join epoch handshake (real exec::Pool under the model)
// ===========================================================================

/// The headline exhaustiveness claim: at 2 workers (fork width 3), the
/// bounded-preemption DFS over the full pool lifecycle — spawn, one
/// 3-part fork, shutdown, join — terminates, and no schedule loses a
/// wakeup (every part runs exactly once) or deadlocks.
#[test]
fn pool_forkjoin_two_workers_exhaustive() {
    let report = check_with(cfg(1, 150_000, 0), || {
        let pool = Pool::new(2);
        let hits: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        pool.run(3, &|part| {
            hits[part].fetch_add(1, Ordering::SeqCst);
        });
        for (p, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "part {p} did not run exactly once");
        }
    });
    assert!(report.failure.is_none(), "pool fork-join failed: {:#?}", report.failure);
    assert!(
        report.complete,
        "preemption-bound-1 DFS did not terminate within the cap ({} execs)",
        report.execs
    );
}

/// Same protocol at preemption bound 2 (capped DFS + seeded random
/// fallback): deeper coverage of preempted schedules.
#[test]
fn pool_forkjoin_two_workers_preemptive() {
    let report = check_with(cfg(2, 15_000, 5_000), || {
        let pool = Pool::new(2);
        let hits: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        pool.run(3, &|part| {
            hits[part].fetch_add(1, Ordering::SeqCst);
        });
        for (p, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "part {p} did not run exactly once");
        }
    });
    assert!(report.failure.is_none(), "pool fork-join failed: {:#?}", report.failure);
}

/// No stale-epoch execution: two consecutive forks on one pool must
/// each run their *own* closure exactly once per part — a worker that
/// re-runs a stale job (or misses the epoch bump) breaks the counts.
#[test]
fn pool_consecutive_forks_no_stale_epoch() {
    let report = check_with(cfg(1, 30_000, 5_000), || {
        let pool = Pool::new(2);
        for round in 1usize..=2 {
            let hits: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
            pool.run(3, &|part| {
                hits[part].fetch_add(round, Ordering::SeqCst);
            });
            for (p, h) in hits.iter().enumerate() {
                assert_eq!(
                    h.load(Ordering::SeqCst),
                    round,
                    "round {round}: part {p} ran a stale or duplicated job"
                );
            }
        }
    });
    assert!(report.failure.is_none(), "stale-epoch check failed: {:#?}", report.failure);
}

/// Panic-guard join: a panicking part (caller part 0, then a worker
/// part) propagates to the forking caller only after every participant
/// finished, and the pool survives and serves the next fork.
#[test]
fn pool_panic_guard_join() {
    let report = check_with(cfg(1, 30_000, 5_000), || {
        let pool = Pool::new(1);
        // caller part panics
        let r = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(2, &|part| {
                if part == 0 {
                    panic!("injected caller-part panic");
                }
            });
        }));
        assert!(r.is_err(), "caller-part panic must propagate");
        // worker part panics
        let r = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(2, &|part| {
                if part == 1 {
                    panic!("injected worker-part panic");
                }
            });
        }));
        assert!(r.is_err(), "worker-part panic must propagate to the caller");
        // the pool still works after both failed forks
        let hits: Vec<AtomicUsize> = (0..2).map(|_| AtomicUsize::new(0)).collect();
        pool.run(2, &|part| {
            hits[part].fetch_add(1, Ordering::SeqCst);
        });
        for (p, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "post-panic fork lost part {p}");
        }
    });
    assert!(report.failure.is_none(), "panic-guard join failed: {:#?}", report.failure);
}

// ===========================================================================
// 2. Registry replica generations (distilled serve::worker_loop model)
// ===========================================================================

/// Hand-breakable switches for the distilled protocols. `None` is the
/// faithful distillation; every other variant removes one load-bearing
/// line of the real code and must be caught by the checker.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Mutation {
    None,
    // -- mini-pool fork-join --
    /// the last finishing worker does not notify the done condvar
    DroppedNotify,
    /// job fields are published *after* the epoch bump + notify instead
    /// of atomically with them (reordered epoch store)
    ReorderedEpochStore,
    /// the forking thread checks completion with `if` instead of `while`
    IfInsteadOfWhile,
    /// the fork wakes workers with notify_one instead of notify_all
    NotifyOneNotAll,
    /// a worker decrements `remaining` before publishing its result
    DecrementBeforeRun,
    // -- registry generations --
    /// a worker uses any cached replica for the model id without
    /// comparing its generation to the batch's (missing generation check)
    NoFreshGenerationCheck,
    /// a stale resolution caches its replica even though the live
    /// generation moved on (overwrites the current-generation entry)
    NoLiveGenerationCheck,
    /// evict forgets to bump the eviction epoch (prune never fires)
    NoEvictBump,
    // -- quarantine --
    /// the worker retires itself when a model trips the quarantine
    /// threshold instead of quarantining just that replica
    RetireOnPoison,
    // -- admission / shed --
    /// submit never checks the bound — the queue grows without limit
    UnboundedQueue,
    /// an over-bound submit drops the shed reply on the floor instead
    /// of answering the request at submit
    ShedReplyDropped,
    // -- streaming sessions --
    /// the idle sweeper evicts a session even while its feed is in
    /// flight (missing `!busy` guard), dropping the queued backlog
    EvictIgnoresBusy,
    /// session lookups skip the slot-generation compare, so a stale
    /// handle aliases a recycled slot
    NoSessionGenerationCheck,
}

/// Distilled register/evict vs. in-flight-batch replica-generation
/// protocol (mirrors serve::worker_loop's resolve path, minus the
/// eviction-epoch prune, which registry_prune_model checks separately).
///
/// Threads: an admin evicts + re-registers the one model id (generation
/// 1 -> 2) and then submits a generation-2 batch; a worker drains the
/// batch queue, re-queueing the first generation-1 batch once (the
/// requeue path is how a stale batch can land *behind* a current one).
///
/// Invariants asserted inside the model:
/// - a batch of generation g is only ever served by a replica of
///   generation g;
/// - after all traffic, the cached replica (if any) is the live
///   generation — a stale resolution never overwrote it.
fn registry_generation_model(m: Mutation) {
    let live: Arc<RwLock<Option<u64>>> = Arc::new(RwLock::new(Some(1)));
    // queue of batch generations; None = shutdown sentinel
    let queue: Arc<(Mutex<VecDeque<Option<u64>>>, Condvar)> =
        Arc::new((Mutex::new(VecDeque::new()), Condvar::new()));
    queue.0.lock().unwrap().push_back(Some(1));

    let admin = {
        let live = Arc::clone(&live);
        let queue = Arc::clone(&queue);
        spawn_named("admin", move || {
            // evict + re-register under the models write lock, then
            // submit a current-generation batch
            *live.write().unwrap() = Some(2);
            queue.0.lock().unwrap().push_back(Some(2));
            queue.1.notify_all();
        })
    };

    let worker = {
        let live = Arc::clone(&live);
        let queue = Arc::clone(&queue);
        spawn_named("worker", move || {
            let mut cache: Option<u64> = None;
            let mut requeued = false;
            loop {
                let g = {
                    let mut q = queue.0.lock().unwrap();
                    loop {
                        if let Some(cmd) = q.pop_front() {
                            break cmd;
                        }
                        q = queue.1.wait(q).unwrap();
                    }
                };
                let Some(g) = g else { break };
                if g == 1 && !requeued {
                    // model the real requeue path (failed attempt /
                    // bounce): the stale batch goes to the back, behind
                    // any current-generation traffic
                    requeued = true;
                    queue.0.lock().unwrap().push_back(Some(1));
                    queue.1.notify_all();
                    continue;
                }
                // resolve the replica (serve::worker_loop lines: fresh
                // check -> live_generation read -> cache or one-shot)
                let fresh = if m == Mutation::NoFreshGenerationCheck {
                    cache.is_some()
                } else {
                    cache == Some(g)
                };
                let replica_gen = if fresh {
                    cache.expect("fresh implies cached")
                } else {
                    let live_generation = *live.read().unwrap();
                    // the factory belongs to the batch's entry, so the
                    // constructed replica is of the batch's generation
                    let replica = g;
                    if m == Mutation::NoLiveGenerationCheck || live_generation == Some(g) {
                        cache = Some(replica);
                    }
                    replica
                };
                assert_eq!(
                    replica_gen, g,
                    "batch of generation {g} served by a generation-{replica_gen} replica"
                );
            }
            cache
        })
    };

    admin.join().expect("admin");
    // all traffic has been submitted; tell the worker to finish
    queue.0.lock().unwrap().push_back(None);
    queue.1.notify_all();
    let cache = worker.join().expect("worker");
    let live_now = *live.read().unwrap();
    if let Some(g) = cache {
        assert_eq!(
            Some(g),
            live_now,
            "a stale resolution overwrote the current-generation cache entry"
        );
    }
}

/// The eviction-epoch prune: exactly one prune per evict (mirrors the
/// `evictions != seen_evictions` compare in serve::worker_loop).
fn registry_prune_model(m: Mutation) {
    let live: Arc<RwLock<Option<u64>>> = Arc::new(RwLock::new(Some(1)));
    let evictions: Arc<Mutex<u64>> = Arc::new(Mutex::new(0));
    let queue: Arc<(Mutex<VecDeque<Option<u64>>>, Condvar)> =
        Arc::new((Mutex::new(VecDeque::new()), Condvar::new()));
    queue.0.lock().unwrap().push_back(Some(1));

    let admin = {
        let live = Arc::clone(&live);
        let evictions = Arc::clone(&evictions);
        let queue = Arc::clone(&queue);
        spawn_named("admin", move || {
            *live.write().unwrap() = None; // evict
            if m != Mutation::NoEvictBump {
                *evictions.lock().unwrap() += 1;
            }
            *live.write().unwrap() = Some(2); // re-register
            queue.0.lock().unwrap().push_back(Some(2));
            queue.1.notify_all();
        })
    };

    let worker = {
        let live = Arc::clone(&live);
        let evictions = Arc::clone(&evictions);
        let queue = Arc::clone(&queue);
        spawn_named("worker", move || {
            let mut cache: Option<u64> = None;
            let mut seen_evictions = 0u64;
            let mut prunes = 0u32;
            loop {
                let g = {
                    let mut q = queue.0.lock().unwrap();
                    loop {
                        if let Some(cmd) = q.pop_front() {
                            break cmd;
                        }
                        q = queue.1.wait(q).unwrap();
                    }
                };
                // eviction-epoch prune, once per bump
                let ev = *evictions.lock().unwrap();
                if ev != seen_evictions {
                    seen_evictions = ev;
                    prunes += 1;
                    let l = *live.read().unwrap();
                    if cache.is_some() && cache != l {
                        cache = None;
                    }
                }
                let Some(g) = g else { break };
                let live_generation = *live.read().unwrap();
                if live_generation == Some(g) {
                    cache = Some(g);
                }
            }
            (cache, prunes)
        })
    };

    admin.join().expect("admin");
    queue.0.lock().unwrap().push_back(None);
    queue.1.notify_all();
    let (cache, prunes) = worker.join().expect("worker");
    assert_eq!(prunes, 1, "one evict must prune exactly once (got {prunes})");
    let live_now = *live.read().unwrap();
    if let Some(g) = cache {
        assert_eq!(Some(g), live_now, "stale replica survived the eviction prune");
    }
}

/// The satellite "model-scheduler stress" of concurrent register /
/// evict / submit on one model id: the faithful generation model under
/// a deeper preemption budget plus random schedules.
#[test]
fn registry_register_evict_submit_model_stress() {
    let report = check_with(cfg(2, 20_000, 10_000), || {
        registry_generation_model(Mutation::None)
    });
    assert!(report.failure.is_none(), "generation protocol failed: {:#?}", report.failure);
    let report = check_with(cfg(2, 20_000, 10_000), || registry_prune_model(Mutation::None));
    assert!(report.failure.is_none(), "prune protocol failed: {:#?}", report.failure);
}

// ===========================================================================
// 3. Quarantine / bounce hand-back (distilled)
// ===========================================================================

const MODEL_A: u8 = 0; // poisoned: every infer errors
const MODEL_B: u8 = 1; // healthy

/// Distilled quarantine protocol: model A's backend always errors; two
/// consecutive errors quarantine the worker's A-replica; quarantined
/// batches bounce (re-queue) under a bounce budget and then fail typed.
/// The worker itself must survive and still serve model B.
fn quarantine_model(m: Mutation) {
    const MAX_ERRS: u32 = 2;
    const MAX_ATTEMPTS: u32 = 2;
    const MAX_BOUNCES: u32 = 2;
    struct Batch {
        model: u8,
        attempts: u32,
        bounces: u32,
    }
    struct Outcome {
        served_b: u32,
        failed_a: u32,
        retired_early: bool,
    }
    let queue: Arc<(Mutex<VecDeque<Batch>>, Condvar)> =
        Arc::new((Mutex::new(VecDeque::new()), Condvar::new()));
    {
        let mut q = queue.0.lock().unwrap();
        q.push_back(Batch { model: MODEL_A, attempts: 0, bounces: 0 });
        q.push_back(Batch { model: MODEL_A, attempts: 0, bounces: 0 });
        q.push_back(Batch { model: MODEL_B, attempts: 0, bounces: 0 });
    }
    // 3 batches to resolve (serve or typed failure)
    let worker = {
        let queue = Arc::clone(&queue);
        spawn_named("worker", move || {
            let mut errs: u32 = 0;
            let mut quarantined = false;
            let mut out = Outcome { served_b: 0, failed_a: 0, retired_early: false };
            let mut resolved = 0u32;
            while resolved < 3 {
                let mut qb = {
                    let mut q = queue.0.lock().unwrap();
                    loop {
                        if let Some(b) = q.pop_front() {
                            break b;
                        }
                        q = queue.1.wait(q).unwrap();
                    }
                };
                if qb.model == MODEL_A && quarantined {
                    // hand-back: re-queue FIRST so other replicas could
                    // pick the batch up during this worker's back-off
                    qb.bounces += 1;
                    if qb.bounces >= MAX_BOUNCES {
                        out.failed_a += 1; // typed failure
                        resolved += 1;
                    } else {
                        queue.0.lock().unwrap().push_back(qb);
                        queue.1.notify_all();
                    }
                    continue;
                }
                if qb.model == MODEL_A {
                    // poisoned backend: infer errors
                    errs += 1;
                    qb.attempts += 1;
                    if qb.attempts >= MAX_ATTEMPTS {
                        out.failed_a += 1;
                        resolved += 1;
                    } else {
                        queue.0.lock().unwrap().push_back(qb);
                        queue.1.notify_all();
                    }
                    if errs >= MAX_ERRS {
                        if m == Mutation::RetireOnPoison {
                            // the hand-broken variant takes the whole
                            // worker down with the poisoned model
                            out.retired_early = true;
                            return out;
                        }
                        quarantined = true;
                        errs = 0;
                    }
                } else {
                    // healthy backend: serve, which also resets nothing
                    // for A (budgets are per-model)
                    out.served_b += 1;
                    resolved += 1;
                }
            }
            out
        })
    };
    let out = worker.join().expect("worker");
    assert!(!out.retired_early, "a poisoned model retired the shared worker");
    assert_eq!(out.served_b, 1, "the healthy model was not served");
    assert_eq!(out.failed_a, 2, "poisoned batches must fail typed, not vanish");
}

#[test]
fn quarantine_never_retires_shared_worker() {
    let report = check_with(cfg(2, 20_000, 5_000), || quarantine_model(Mutation::None));
    assert!(report.failure.is_none(), "quarantine protocol failed: {:#?}", report.failure);
}

// ===========================================================================
// 4. Admission / shed handshake (distilled submit_with reservation model)
// ===========================================================================

/// Queue half of the distilled admission protocol.
struct AdmissionState {
    q: VecDeque<usize>,
    depth_max: usize,
    closed: bool,
}

/// Distilled admission-control protocol from `serve::submit_with`: a
/// producer submits N requests through a depth-BOUND queue; a submit
/// that finds the queue full must answer the request *right there*
/// with a terminal shed reply (`ServeError::Overloaded` in the real
/// registry). A consumer serves whatever was admitted.
///
/// Invariants asserted inside the model:
/// - the queue never holds more than BOUND requests;
/// - every request receives exactly one terminal reply — served or
///   shed; none is silently dropped, none is answered twice.
fn admission_model(m: Mutation) {
    const N: usize = 4;
    const BOUND: usize = 1;
    let shared = Arc::new((
        Mutex::new(AdmissionState { q: VecDeque::new(), depth_max: 0, closed: false }),
        Condvar::new(),
    ));
    let replies: Arc<Vec<AtomicUsize>> =
        Arc::new((0..N).map(|_| AtomicUsize::new(0)).collect());

    let consumer = {
        let shared = Arc::clone(&shared);
        let replies = Arc::clone(&replies);
        spawn_named("admission-consumer", move || loop {
            let i = {
                let mut st = shared.0.lock().unwrap();
                loop {
                    if let Some(i) = st.q.pop_front() {
                        break i;
                    }
                    if st.closed {
                        return;
                    }
                    st = shared.1.wait(st).unwrap();
                }
            };
            // serve: the request's one terminal reply
            replies[i].fetch_add(1, Ordering::SeqCst);
        })
    };

    // producer: submit N requests through the reservation check
    for i in 0..N {
        let mut st = shared.0.lock().unwrap();
        let over = st.q.len() >= BOUND;
        if over && m != Mutation::UnboundedQueue {
            drop(st);
            // shed: the request's one terminal reply, at submit — the
            // load-bearing line the ShedReplyDropped mutation removes
            if m != Mutation::ShedReplyDropped {
                replies[i].fetch_add(1, Ordering::SeqCst);
            }
            continue;
        }
        st.q.push_back(i);
        st.depth_max = st.depth_max.max(st.q.len());
        drop(st);
        shared.1.notify_all();
    }
    {
        let mut st = shared.0.lock().unwrap();
        st.closed = true;
    }
    shared.1.notify_all();
    consumer.join().expect("admission consumer");

    let st = shared.0.lock().unwrap();
    assert!(
        st.depth_max <= BOUND,
        "queue depth {} exceeded the admission bound {BOUND}",
        st.depth_max
    );
    for (i, r) in replies.iter().enumerate() {
        assert_eq!(r.load(Ordering::SeqCst), 1, "request {i}: not exactly one terminal reply");
    }
}

/// The faithful admission protocol passes — every request is answered
/// exactly once and the bound holds under every explored interleaving.
#[test]
fn admission_faithful_passes() {
    let report = check_with(cfg(2, 20_000, 5_000), || admission_model(Mutation::None));
    assert!(report.failure.is_none(), "admission protocol failed: {:#?}", report.failure);
}

// ===========================================================================
// 5. Streaming session lifecycle (distilled serve session-table model)
// ===========================================================================

/// One slab slot of the distilled session table (mirrors
/// `serve::SessionSlot`): generation-tagged occupancy, the in-flight
/// `busy` flag, the parked state (tagged with the generation it belongs
/// to), and the backlog of feeds queued behind the in-flight one.
struct SessSlot {
    occupied: bool,
    generation: u64,
    busy: bool,
    /// Some(gen) while the session state is parked in the slot; None
    /// while a worker has it checked out (or after release)
    state: Option<u64>,
    /// (feed index, handle generation) queued while `busy`
    backlog: Vec<(usize, u64)>,
}

/// The `get_live` validation from the real session table: slot occupied
/// and the handle's generation current. `NoSessionGenerationCheck`
/// removes the load-bearing compare.
fn sess_live(s: &SessSlot, sid: u64, m: Mutation) -> bool {
    s.occupied && (m == Mutation::NoSessionGenerationCheck || s.generation == sid)
}

/// Distilled session open/feed/evict lifecycle from the serve session
/// layer (`ModelRegistry::{open_session, feed}` + `sweep_idle_sessions`
/// vs. `serve_stream_feed`'s checkout/put-back): a client feeds two
/// frames on its generation-1 handle, an idle sweeper races the feeds
/// (a legitimate evict immediately recycles the slot under generation
/// 2 — slab reuse), and a worker drains the feed queue, draining the
/// backlog under its checkout before putting the state back.
///
/// Invariants asserted inside the model:
/// - every feed gets exactly one terminal reply — served, or typed
///   `UnknownSession` — never zero (hang), never two;
/// - a feed is only ever served against the state of its own session
///   generation (a stale handle never aliases a recycled slot).
fn session_model(m: Mutation) {
    const FEEDS: usize = 2;
    const SID: u64 = 1; // the client's handle: slot generation 1
    let table: Arc<Mutex<SessSlot>> = Arc::new(Mutex::new(SessSlot {
        occupied: true,
        generation: SID,
        busy: false,
        state: Some(SID),
        backlog: Vec::new(),
    }));
    // feed queue: Some((feed index, handle generation)); None = shutdown
    let queue: Arc<(Mutex<VecDeque<Option<(usize, u64)>>>, Condvar)> =
        Arc::new((Mutex::new(VecDeque::new()), Condvar::new()));
    let replies: Arc<Vec<AtomicUsize>> =
        Arc::new((0..FEEDS).map(|_| AtomicUsize::new(0)).collect());

    let sweeper = {
        let table = Arc::clone(&table);
        spawn_named("session-sweeper", move || {
            let mut t = table.lock().unwrap();
            // the `!busy` guard is the load-bearing line the
            // EvictIgnoresBusy mutation removes
            if t.occupied && (m == Mutation::EvictIgnoresBusy || !t.busy) {
                t.occupied = false;
                t.busy = false;
                t.state = None;
                t.backlog.clear(); // the hand-broken variant drops queued feeds
                // slab reuse: a fresh open recycles the freed slot
                // under the next generation
                t.occupied = true;
                t.generation = SID + 1;
                t.state = Some(SID + 1);
            }
        })
    };

    let worker = {
        let table = Arc::clone(&table);
        let queue = Arc::clone(&queue);
        let replies = Arc::clone(&replies);
        spawn_named("session-worker", move || loop {
            let job = {
                let mut q = queue.0.lock().unwrap();
                loop {
                    if let Some(job) = q.pop_front() {
                        break job;
                    }
                    q = queue.1.wait(q).unwrap();
                }
            };
            let Some((i, sid)) = job else { return };
            // checkout
            let state = {
                let mut t = table.lock().unwrap();
                if sess_live(&t, sid, m) {
                    t.state.take()
                } else {
                    None
                }
            };
            let Some(state_gen) = state else {
                // typed UnknownSession: the feed's one terminal reply
                replies[i].fetch_add(1, Ordering::SeqCst);
                continue;
            };
            let mut reqs = vec![(i, sid)];
            loop {
                for &(j, sj) in &reqs {
                    assert_eq!(
                        state_gen, sj,
                        "feed {j} of session generation {sj} served with \
                         generation-{state_gen} state"
                    );
                    replies[j].fetch_add(1, Ordering::SeqCst); // served
                }
                reqs.clear();
                let mut t = table.lock().unwrap();
                if !sess_live(&t, sid, m) {
                    break; // evicted while checked out: the state is dropped
                }
                if t.backlog.is_empty() {
                    t.state = Some(state_gen); // put back
                    t.busy = false;
                    break;
                }
                // keep draining feeds that queued up behind the checkout
                reqs.append(&mut t.backlog);
            }
        })
    };

    // client: two feeds on the (possibly stale) handle
    for i in 0..FEEDS {
        let mut t = table.lock().unwrap();
        if !sess_live(&t, SID, m) {
            drop(t);
            // typed UnknownSession right at feed: the terminal reply
            replies[i].fetch_add(1, Ordering::SeqCst);
            continue;
        }
        if t.busy {
            t.backlog.push((i, SID));
        } else {
            t.busy = true;
            drop(t);
            queue.0.lock().unwrap().push_back(Some((i, SID)));
            queue.1.notify_all();
        }
    }

    sweeper.join().expect("sweeper");
    queue.0.lock().unwrap().push_back(None);
    queue.1.notify_all();
    worker.join().expect("session worker");
    for (i, r) in replies.iter().enumerate() {
        assert_eq!(
            r.load(Ordering::SeqCst),
            1,
            "feed {i}: not exactly one terminal reply (served or UnknownSession)"
        );
    }
}

/// The faithful session lifecycle passes: idle eviction racing an
/// in-flight feed always resolves to exactly one terminal outcome.
#[test]
fn session_lifecycle_feed_evict_model() {
    let report = check_with(cfg(2, 20_000, 10_000), || session_model(Mutation::None));
    assert!(report.failure.is_none(), "session protocol failed: {:#?}", report.failure);
}

// ===========================================================================
// Mini-pool: a parameterized distillation of the exec::Pool fork-join
// handshake, used by the seeded-mutation suite (the real Pool cannot be
// hand-broken at runtime).
// ===========================================================================

struct MiniState {
    epoch: u64,
    /// parts of the published fork (None between forks / pre-publish)
    job: Option<usize>,
    remaining: usize,
    done: [bool; 3],
    shutdown: bool,
}

fn mini_pool(m: Mutation) {
    let shared = Arc::new((
        Mutex::new(MiniState {
            epoch: 0,
            job: None,
            remaining: 0,
            done: [false; 3],
            shutdown: false,
        }),
        Condvar::new(), // work_cv
        Condvar::new(), // done_cv
    ));
    const PARTS: usize = 3;
    let workers: Vec<_> = (0..2usize)
        .map(|wi| {
            let shared = Arc::clone(&shared);
            spawn_named(&format!("mini-worker-{wi}"), move || {
                let mut seen = 0u64;
                loop {
                    let parts = {
                        let mut st = shared.0.lock().unwrap();
                        loop {
                            if st.shutdown {
                                return;
                            }
                            if st.epoch != seen {
                                seen = st.epoch;
                                break st.job.expect("fresh epoch published without a job");
                            }
                            st = shared.1.wait(st).unwrap();
                        }
                    };
                    let part = wi + 1;
                    if part >= parts {
                        continue;
                    }
                    if m == Mutation::DecrementBeforeRun {
                        // hand-broken: signal completion before doing
                        // the work
                        {
                            let mut st = shared.0.lock().unwrap();
                            st.remaining -= 1;
                        }
                        shared.2.notify_all();
                        let mut st = shared.0.lock().unwrap();
                        assert!(!st.done[part], "part {part} ran twice");
                        st.done[part] = true;
                        continue;
                    }
                    {
                        let mut st = shared.0.lock().unwrap();
                        assert!(!st.done[part], "part {part} ran twice");
                        st.done[part] = true;
                        st.remaining -= 1;
                    }
                    // per-part completion signal; the join re-checks
                    // `remaining` under `while` (the load-bearing line
                    // the IfInsteadOfWhile mutation removes)
                    if m != Mutation::DroppedNotify {
                        shared.2.notify_all();
                    }
                }
            })
        })
        .collect();

    // publish the fork
    if m == Mutation::ReorderedEpochStore {
        // hand-broken: epoch bump + notify escape the critical section
        // that publishes the job fields
        {
            let mut st = shared.0.lock().unwrap();
            st.epoch += 1;
            st.remaining = PARTS - 1;
        }
        shared.1.notify_all();
        {
            let mut st = shared.0.lock().unwrap();
            st.job = Some(PARTS);
        }
    } else {
        {
            let mut st = shared.0.lock().unwrap();
            st.epoch += 1;
            st.job = Some(PARTS);
            st.remaining = PARTS - 1;
        }
        if m == Mutation::NotifyOneNotAll {
            shared.1.notify_one();
        } else {
            shared.1.notify_all();
        }
    }
    // caller runs part 0
    {
        let mut st = shared.0.lock().unwrap();
        st.done[0] = true;
    }
    // join: wait for the workers' parts
    {
        let mut st = shared.0.lock().unwrap();
        if m == Mutation::IfInsteadOfWhile {
            if st.remaining > 0 {
                st = shared.2.wait(st).unwrap();
            }
        } else {
            while st.remaining > 0 {
                st = shared.2.wait(st).unwrap();
            }
        }
        for (p, d) in st.done.iter().enumerate() {
            assert!(*d, "fork joined with part {p} not finished");
        }
        st.job = None;
        st.shutdown = true;
    }
    shared.1.notify_all();
    for w in workers {
        w.join().expect("mini worker");
    }
}

/// The faithful mini-pool passes exhaustively — pinning that the
/// mutation failures below come from the seeded breakage, not from the
/// distillation itself.
#[test]
fn mini_pool_faithful_passes() {
    let report = check_with(cfg(2, 40_000, 5_000), || mini_pool(Mutation::None));
    assert!(report.failure.is_none(), "faithful mini-pool failed: {:#?}", report.failure);
}

// ===========================================================================
// Seeded-mutation suite: every hand-broken variant must be caught.
// ===========================================================================

fn assert_caught(name: &str, m: Mutation, f: impl Fn() + Send + Sync + 'static) -> Vec<usize> {
    let report = check_with(cfg(2, 20_000, 10_000), f);
    let failure = report
        .failure
        .unwrap_or_else(|| panic!("mutation {name} ({m:?}) was NOT caught by the checker"));
    assert!(!failure.schedule.is_empty(), "failing schedule missing for {name}");
    assert!(!failure.trace.is_empty(), "failing trace missing for {name}");
    failure.schedule
}

#[test]
fn mutation_dropped_notify_caught() {
    assert_caught("dropped-notify", Mutation::DroppedNotify, || {
        mini_pool(Mutation::DroppedNotify)
    });
}

#[test]
fn mutation_reordered_epoch_store_caught() {
    assert_caught("reordered-epoch-store", Mutation::ReorderedEpochStore, || {
        mini_pool(Mutation::ReorderedEpochStore)
    });
}

#[test]
fn mutation_if_instead_of_while_caught() {
    assert_caught("if-instead-of-while", Mutation::IfInsteadOfWhile, || {
        mini_pool(Mutation::IfInsteadOfWhile)
    });
}

#[test]
fn mutation_notify_one_not_all_caught() {
    assert_caught("notify-one-not-all", Mutation::NotifyOneNotAll, || {
        mini_pool(Mutation::NotifyOneNotAll)
    });
}

#[test]
fn mutation_decrement_before_run_caught() {
    assert_caught("decrement-before-run", Mutation::DecrementBeforeRun, || {
        mini_pool(Mutation::DecrementBeforeRun)
    });
}

#[test]
fn mutation_missing_generation_check_caught() {
    assert_caught("missing-generation-check", Mutation::NoFreshGenerationCheck, || {
        registry_generation_model(Mutation::NoFreshGenerationCheck)
    });
}

#[test]
fn mutation_stale_cache_overwrite_caught() {
    assert_caught("stale-cache-overwrite", Mutation::NoLiveGenerationCheck, || {
        registry_generation_model(Mutation::NoLiveGenerationCheck)
    });
}

#[test]
fn mutation_no_evict_bump_caught() {
    assert_caught("no-evict-bump", Mutation::NoEvictBump, || {
        registry_prune_model(Mutation::NoEvictBump)
    });
}

#[test]
fn mutation_retire_on_poison_caught() {
    assert_caught("retire-on-poison", Mutation::RetireOnPoison, || {
        quarantine_model(Mutation::RetireOnPoison)
    });
}

#[test]
fn mutation_unbounded_queue_caught() {
    assert_caught("unbounded-queue", Mutation::UnboundedQueue, || {
        admission_model(Mutation::UnboundedQueue)
    });
}

#[test]
fn mutation_shed_reply_dropped_caught() {
    assert_caught("shed-reply-dropped", Mutation::ShedReplyDropped, || {
        admission_model(Mutation::ShedReplyDropped)
    });
}

#[test]
fn mutation_evict_ignores_busy_caught() {
    assert_caught("evict-ignores-busy", Mutation::EvictIgnoresBusy, || {
        session_model(Mutation::EvictIgnoresBusy)
    });
}

#[test]
fn mutation_no_session_generation_check_caught() {
    assert_caught("no-session-generation-check", Mutation::NoSessionGenerationCheck, || {
        session_model(Mutation::NoSessionGenerationCheck)
    });
}

// ===========================================================================
// Replay: a recorded failing schedule reproduces its failure.
// ===========================================================================

#[test]
fn failing_schedule_replays_deterministically() {
    let schedule =
        assert_caught("dropped-notify", Mutation::DroppedNotify, || {
            mini_pool(Mutation::DroppedNotify)
        });
    let report = replay(|| mini_pool(Mutation::DroppedNotify), &schedule);
    let failure = report.failure.expect("replayed schedule must reproduce the failure");
    assert_eq!(
        failure.kind,
        FailureKind::Deadlock,
        "dropped notify must replay as the lost-wakeup deadlock: {failure:#?}"
    );
}

#[test]
fn session_mutation_replays_deterministically() {
    let schedule = assert_caught("evict-ignores-busy", Mutation::EvictIgnoresBusy, || {
        session_model(Mutation::EvictIgnoresBusy)
    });
    let report = replay(|| session_model(Mutation::EvictIgnoresBusy), &schedule);
    assert!(
        report.failure.is_some(),
        "replayed session schedule must reproduce its dropped-backlog failure"
    );
}
