//! Coordinator integration: a miniature gradual-quantization pipeline
//! runs end-to-end through real PJRT train steps, checkpoints persist
//! and reload, distillation plumbs teacher logits, and the QAT->FQ
//! hand-off produces a trainable FQ network.

use fqconv::coordinator::{
    checkpoint, Pipeline, Schedule, Stage, TeacherPolicy, Trainer, Variant,
};
use fqconv::data::{self, Dataset};
use fqconv::runtime::hp;
use fqconv::util::Rng;

mod common;
use common::setup;

#[test]
fn training_reduces_loss() {
    let Some((manifest, engine)) = setup() else { return };
    let mut t = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
    let info = manifest.model("kws").unwrap();
    t.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let mut rng = Rng::new(3);
    let mut hpv = hp::defaults();
    hpv[hp::LR] = 0.01; // fp stage
    let mut first = None;
    let mut last = 0.0;
    for step in 0..20 {
        let batch = ds.train_batch(info.batch, &mut rng);
        hpv[hp::SEED] = step as f32;
        let stats = t.step(&batch, None, &hpv).unwrap();
        assert!(stats.loss.is_finite(), "loss must stay finite");
        if first.is_none() {
            first = Some(stats.loss);
        }
        last = stats.loss;
    }
    assert!(
        last < first.unwrap() * 0.9,
        "20 steps should reduce loss materially: {} -> {last}",
        first.unwrap()
    );
}

#[test]
fn mini_pipeline_with_fq_stage() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("kws").unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let mut pipe = Pipeline::new(&engine, &manifest, ds.as_ref());
    pipe.eval_batches = 2;
    let tmp = std::env::temp_dir().join("fqconv_test_ckpts");
    pipe.ckpt_dir = Some(tmp.clone());
    let sched = Schedule::new(
        "kws",
        vec![
            Stage::new("FP", 0, 0).steps(10).lr(0.01),
            Stage::new("Q24", 2, 4).from("FP").taught_by("FP").steps(10).lr(0.005),
            Stage::new("FQ24", 2, 4).from("Q24").taught_by("FP").fq().steps(5).lr(0.0005),
        ],
        TeacherPolicy::Declared,
    )
    .unwrap();
    let report = pipe.run(&sched).unwrap();
    assert_eq!(report.stages.len(), 3);
    assert!(report.stages.iter().all(|s| s.val_acc.is_finite()));
    assert!(report.stage("FQ24").unwrap().fq);
    // distillation actually resolved a teacher for stage 2
    assert_eq!(report.stage("Q24").unwrap().teacher.as_deref(), Some("FP"));
    // checkpoints persisted per stage and reload cleanly
    for stage in ["FP", "Q24", "FQ24"] {
        let path = tmp.join(format!("kws_{stage}.ckpt"));
        assert!(path.exists(), "missing checkpoint {}", path.display());
        let ck = checkpoint::read(&path).unwrap();
        assert!(ck.len() > 10);
    }
    // FQ checkpoint loads into the FQ graph
    let fq_ck = checkpoint::read(&tmp.join("kws_FQ24.ckpt")).unwrap();
    let fq_graph = info.fq.clone().unwrap();
    let ps = fqconv::coordinator::ParamSet::from_checkpoint(&fq_graph, &fq_ck).unwrap();
    assert_eq!(ps.specs.len(), fq_graph.trainable.len() + fq_graph.state.len());
}

#[test]
fn teacher_promotion_policy_picks_best() {
    // PromoteBest must select the highest-accuracy completed stage; we
    // check the plumbing by observing the recorded teacher names.
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("kws").unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let mut pipe = Pipeline::new(&engine, &manifest, ds.as_ref());
    pipe.eval_batches = 2;
    let sched = Schedule::new(
        "kws",
        vec![
            Stage::new("FP", 0, 0).steps(12).lr(0.01),
            Stage::new("Q88", 8, 8).from("FP").taught_by("FP").steps(6).lr(0.005),
            Stage::new("Q44", 4, 4).from("Q88").taught_by("Q88").steps(6).lr(0.005),
        ],
        TeacherPolicy::PromoteBest,
    )
    .unwrap();
    let report = pipe.run(&sched).unwrap();
    // Q44's teacher must be whichever of FP/Q88 evaluated best
    let fp = report.stage("FP").unwrap().val_acc;
    let q88 = report.stage("Q88").unwrap().val_acc;
    let expect = if q88 > fp { "Q88" } else { "FP" };
    assert_eq!(report.stage("Q44").unwrap().teacher.as_deref(), Some(expect));
}

#[test]
fn distillation_changes_training() {
    // same seed, with vs without teacher: parameter trajectories differ
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("kws").unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let init = checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap();

    let run = |distill: bool| -> f32 {
        let mut t = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
        t.load_params(&init).unwrap();
        let mut teacher = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
        teacher.load_params(&init).unwrap();
        let mut rng = Rng::new(5);
        let mut hpv = hp::defaults();
        hpv[hp::LR] = 0.01;
        hpv[hp::DISTILL_WEIGHT] = if distill { 0.8 } else { 0.0 };
        let mut loss = 0.0;
        for step in 0..5 {
            let batch = ds.train_batch(info.batch, &mut rng);
            let tl = teacher.forward(&batch.x, &hp::defaults()).unwrap();
            hpv[hp::SEED] = step as f32;
            loss = t.step(&batch, Some(&tl), &hpv).unwrap().loss;
        }
        loss
    };
    let with = run(true);
    let without = run(false);
    assert!((with - without).abs() > 1e-6, "distillation weight must matter");
}
