//! Fig. 3 as a test: replacing BN+ReLU with the learned quantized ReLU
//! is numerically exact when BN reduces to identity (gamma=1, beta=0,
//! running mean=0, var=1), and the general transform preserves the
//! network's decisions well enough to serve as the FQ fine-tune init.

use fqconv::coordinator::{checkpoint, fq_transform, Trainer, Variant};
use fqconv::data::{self, Dataset};
use fqconv::metrics;
use fqconv::runtime::hp;
use fqconv::util::Rng;

mod common;
use common::setup;

#[test]
fn identity_bn_transform_is_exact() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("kws").unwrap();
    let mut qat = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
    qat.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();

    // force identity BN everywhere (init already has mean=0/var=1/beta=0/
    // gamma=1, but assert it to keep the test honest)
    for (spec, v) in qat.params.specs.iter().zip(&qat.params.values) {
        if spec.name.contains(".bn.gamma") || spec.name.contains(".bn.var") {
            assert!(v.data().iter().all(|&x| (x - 1.0).abs() < 1e-6), "{}", spec.name);
        }
        if spec.name.contains(".bn.beta") || spec.name.contains(".bn.mean") {
            assert!(v.data().iter().all(|&x| x.abs() < 1e-6), "{}", spec.name);
        }
    }

    let fq_graph = info.fq.clone().unwrap();
    let fq = fq_transform::qat_to_fq(info, &fq_graph, &qat.params).unwrap();

    // weights unchanged under identity BN (up to the 1/sqrt(1+eps)
    // factor, ~5e-6 relative); scales wired per §3.4
    for i in 0..7 {
        let wq = qat.params.get(&format!("conv{i}.w")).unwrap();
        let wf = fq.get(&format!("conv{i}.w")).unwrap();
        for (a, b) in wq.data().iter().zip(wf.data()) {
            assert!((a - b).abs() <= a.abs() * 2e-5 + 1e-7, "conv{i}: {a} vs {b}");
        }
        let so = fq.scalar(&format!("conv{i}.so")).unwrap();
        let sa_qat = qat.params.scalar(&format!("conv{i}.sa")).unwrap();
        assert!((so - sa_qat).abs() < 1e-6, "so must inherit the QAT act scale");
    }
    // first FQ layer's input grid = the embedding quantizer
    let sa0 = fq.scalar("conv0.sa").unwrap();
    let emb = qat.params.scalar("embed.sa").unwrap();
    assert!((sa0 - emb).abs() < 1e-6);
}

#[test]
fn transform_preserves_decisions_after_brief_training() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("kws").unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let mut qat = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
    qat.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();
    let mut rng = Rng::new(21);
    // FP warmup first — direct-to-ternary from random init collapses,
    // which is exactly the paper's no-GQ observation (Table 1)
    let mut hpv = hp::defaults();
    hpv[hp::LR] = 0.01;
    for step in 0..50 {
        let batch = ds.train_batch(info.batch, &mut rng);
        hpv[hp::SEED] = step as f32;
        qat.step(&batch, None, &hpv).unwrap();
    }
    hpv[hp::NW] = 7.0; // 4-bit weights: trains reliably at this budget
    hpv[hp::NA] = 7.0;
    hpv[hp::LR] = 0.005;
    for step in 0..50 {
        let batch = ds.train_batch(info.batch, &mut rng);
        hpv[hp::SEED] = 100.0 + step as f32;
        qat.step(&batch, None, &hpv).unwrap();
    }
    let mut eval_hp = hpv;
    eval_hp[hp::LR] = 0.0;
    let qat_acc = qat.evaluate(ds.as_ref(), &eval_hp, 4).unwrap();

    // hand off to FQ (no fine-tuning yet) and evaluate through fq_fwd
    let fq_graph = info.fq.clone().unwrap();
    let fq_params = fq_transform::qat_to_fq(info, &fq_graph, &qat.params).unwrap();
    let mut fq = Trainer::new(&engine, &manifest, "kws", Variant::Fq).unwrap();
    fq.set_params(fq_params);
    let fq_acc = fq.evaluate(ds.as_ref(), &eval_hp, 4).unwrap();

    // The paper *requires* retraining after BN removal ("we have found it
    // necessary to first train the network ... then retrain"): dropping the
    // per-channel shift is lossy. Before fine-tuning the transform must
    // still carry real signal (well above the 1/12 chance level); the
    // companion test `fine_tune_recovers_accuracy` covers the recovery.
    assert!(qat_acc > 0.5, "QAT net failed to train: {qat_acc:.3}");
    assert!(
        fq_acc > 0.25,
        "FQ init lost the network: qat={qat_acc:.3} fq={fq_acc:.3} (chance=0.083)"
    );
}

#[test]
fn fine_tune_recovers_accuracy() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("kws").unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let mut qat = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
    qat.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();
    let mut rng = Rng::new(22);
    let mut hpv = hp::defaults();
    hpv[hp::LR] = 0.01;
    for step in 0..30 {
        let batch = ds.train_batch(info.batch, &mut rng);
        hpv[hp::SEED] = step as f32;
        qat.step(&batch, None, &hpv).unwrap();
    }
    hpv[hp::NW] = 7.0;
    hpv[hp::NA] = 7.0;
    hpv[hp::LR] = 0.005;
    for step in 0..30 {
        let batch = ds.train_batch(info.batch, &mut rng);
        hpv[hp::SEED] = 50.0 + step as f32;
        qat.step(&batch, None, &hpv).unwrap();
    }
    let fq_graph = info.fq.clone().unwrap();
    let fq_params = fq_transform::qat_to_fq(info, &fq_graph, &qat.params).unwrap();
    let mut fq = Trainer::new(&engine, &manifest, "kws", Variant::Fq).unwrap();
    fq.set_params(fq_params);
    let mut eval_hp = hpv;
    eval_hp[hp::LR] = 0.0;
    let before = fq.evaluate(ds.as_ref(), &eval_hp, 4).unwrap();
    let mut ft_hp = hpv;
    ft_hp[hp::LR] = 5e-4;
    for step in 0..25 {
        let batch = ds.train_batch(info.batch, &mut rng);
        ft_hp[hp::SEED] = 1000.0 + step as f32;
        fq.step(&batch, None, &ft_hp).unwrap();
    }
    let after = fq.evaluate(ds.as_ref(), &eval_hp, 4).unwrap();
    assert!(
        after >= before - 0.02,
        "fine-tuning should not destroy the FQ network: {before:.3} -> {after:.3}"
    );
    let _ = metrics::accuracy; // (module referenced for doc-link stability)
}
