//! Whole-stack integration: the compact version of the e2e example —
//! GQ pipeline on a ResNet, baselines, accounting, schedule rendering.

use fqconv::config::Budget;
use fqconv::coordinator::{Pipeline, Schedule, Stage, TeacherPolicy};
use fqconv::data;
use fqconv::exp;

mod common;
use common::setup;

#[test]
fn resnet_mini_ladder_runs() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("resnet8s").unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let mut pipe = Pipeline::new(&engine, &manifest, ds.as_ref());
    pipe.eval_batches = 2;
    let sched = Schedule::new(
        "resnet8s",
        vec![
            Stage::new("FP0", 0, 0).steps(12).lr(0.02),
            Stage::new("Q44", 4, 4).from("FP0").taught_by("FP0").steps(8).lr(0.01),
        ],
        TeacherPolicy::Declared,
    )
    .unwrap();
    let report = pipe.run(&sched).unwrap();
    assert_eq!(report.stages.len(), 2);
    for s in &report.stages {
        assert!(s.val_acc.is_finite() && s.val_acc >= 0.0 && s.val_acc <= 1.0);
        assert!(s.final_loss.is_finite());
    }
}

#[test]
fn baseline_flavors_train() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("resnet8s").unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    for flavor in ["dorefa", "pact"] {
        let mut pipe = Pipeline::new(&engine, &manifest, ds.as_ref());
        pipe.eval_batches = 2;
        pipe.flavor = if flavor == "dorefa" { "dorefa" } else { "pact" };
        let sched = Schedule::new(
            "resnet8s",
            vec![
                Stage::new("FP0", 0, 0).steps(6).lr(0.02),
                Stage::new("Q33", 3, 3).from("FP0").taught_by("FP0").steps(6).lr(0.01),
            ],
            TeacherPolicy::Declared,
        )
        .unwrap();
        let report = pipe.run(&sched).unwrap();
        assert!(
            report.stages.iter().all(|s| s.final_loss.is_finite()),
            "{flavor} produced non-finite loss"
        );
    }
}

#[test]
fn darknet_trains_one_stage() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("darknet_tiny").unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let mut pipe = Pipeline::new(&engine, &manifest, ds.as_ref());
    pipe.eval_batches = 2;
    pipe.topk = 5;
    let sched = Schedule::new(
        "darknet_tiny",
        vec![Stage::new("FP0", 0, 0).steps(8).lr(0.02)],
        TeacherPolicy::Declared,
    )
    .unwrap();
    let report = pipe.run(&sched).unwrap();
    let s = &report.stages[0];
    assert!(s.val_topk >= s.val_acc, "top-5 must be >= top-1");
}

#[test]
fn table5_accounting_matches_paper_scale() {
    let Some((manifest, _)) = setup() else { return };
    let info = manifest.model("kws").unwrap();
    // the paper reports ~50K params and ~3.5M MACs for the KWS net
    assert!(
        (30_000..80_000).contains(&info.qat.param_count),
        "param count {} off paper scale",
        info.qat.param_count
    );
    assert!(
        (2_000_000..5_000_000).contains(&(info.macs_per_sample as usize)),
        "MACs {} off paper scale",
        info.macs_per_sample
    );
    let rows_lit = fqconv::models::table5_literature_rows();
    let ours = fqconv::models::table5_our_rows(info, 0.95, 0.94);
    // our model must be the smallest by size and fewest mults, as in Table 5
    let min_lit_size = rows_lit.iter().map(|r| r.size_bytes).fold(f64::MAX, f64::min);
    assert!(ours.iter().all(|r| r.size_bytes < min_lit_size));
    let min_lit_mults = rows_lit.iter().map(|r| r.mults).fold(f64::MAX, f64::min);
    assert!(ours.iter().all(|r| r.mults < min_lit_mults));
}

#[test]
fn figure_renderers_produce_output() {
    let Some((manifest, _)) = setup() else { return };
    for model in ["kws", "resnet32", "darknet_tiny"] {
        let info = manifest.model(model).unwrap();
        let a = fqconv::models::render_architecture(info, false);
        assert!(a.len() > 100, "{model} arch render too small");
        assert!(a.contains("params"));
    }
    let plan = exp::fig1_plan("kws", 600);
    assert!(plan.contains("FQ24") && plan.contains("chain:"));
    let plan6 = exp::fig1_plan("resnet14s", 100);
    assert!(plan6.contains("FQ25"));
}

#[test]
fn budgets_scale_sanely() {
    let q = Budget::quick();
    let f = Budget::full();
    assert!(f.steps_per_stage > q.steps_per_stage);
    assert!(f.noise_reps >= q.noise_reps);
}
