//! Shared integration-test bootstrap (`mod common;` in each test file —
//! a directory module so cargo does not treat it as its own test target).

// each test binary includes this module and uses a subset of it
#![allow(dead_code)]

use fqconv::infer::graph::{global_avg_pool_into, QuantStage};
use fqconv::infer::QuantGraph;
use fqconv::quant::QParams;
use fqconv::runtime::{Engine, Manifest};

/// `None` (=> the caller's test skips) when the artifacts or the PJRT
/// runtime are unavailable — e.g. offline builds against the vendored
/// xla stub.
pub fn setup() -> Option<(Manifest, Engine)> {
    let dir = fqconv::artifacts_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping (no artifacts — run `make artifacts`): {e}");
            return None;
        }
    };
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping (PJRT unavailable): {e}");
            return None;
        }
    };
    Some((manifest, engine))
}

/// Stage-by-stage reference walk of a 2-D graph with every conv run
/// through its im2col + GEMM + threshold-search oracle
/// (`QuantConv2d::forward_im2col`) — the independent implementation the
/// direct engine must match bit-for-bit (rust/tests/graph.rs,
/// rust/tests/graph_fuzz.rs).
///
/// The walk tracks the live quantizer grid so `MaxPool2d` stages can be
/// oracled through the *float* path — dequantize every code in the
/// window, take the float max, requantize onto the same grid — which
/// independently proves the engine's LUT-free integer max is
/// order-exact on every graph it runs.
pub fn forward_reference_2d(g: &QuantGraph, x: &[f32]) -> Vec<f32> {
    let shape = g.in_shape();
    assert_eq!(shape.len(), 3, "reference walk is for image graphs");
    let (mut h, mut w) = (shape[1], shape[2]);
    let mut codes: Vec<i8> = Vec::new();
    let (mut cols, mut acc, mut out) = (Vec::new(), Vec::new(), Vec::new());
    let mut pooled = Vec::new();
    let mut logits = vec![0f32; g.classes()];
    // the grid the live codes are currently binned on
    let mut grid: Option<QParams> = None;
    for stage in g.stages() {
        match stage {
            QuantStage::QuantStem2d(st) => {
                st.forward_into(x, &mut codes);
                grid = Some(st.out_q);
            }
            QuantStage::FqConv2dStack(stack) => {
                for l in &stack.layers {
                    l.forward_im2col(&codes, h, w, &mut cols, &mut acc, &mut out);
                    let (h2, w2) = l.out_hw(h, w);
                    h = h2;
                    w = w2;
                    std::mem::swap(&mut codes, &mut out);
                    grid = Some(l.out_grid());
                }
            }
            QuantStage::Residual(r) => {
                let skip: Vec<i8> = match &r.down {
                    Some(d) => {
                        let mut s = Vec::new();
                        d.forward_im2col(&codes, h, w, &mut cols, &mut acc, &mut s);
                        s
                    }
                    None => codes.clone(),
                };
                for l in &r.body {
                    l.forward_im2col(&codes, h, w, &mut cols, &mut acc, &mut out);
                    let (h2, w2) = l.out_hw(h, w);
                    h = h2;
                    w = w2;
                    std::mem::swap(&mut codes, &mut out);
                }
                assert_eq!(codes.len(), skip.len(), "join geometry");
                for (c, &sk) in codes.iter_mut().zip(&skip) {
                    *c = r.add.apply(*c, sk);
                }
                grid = Some(r.add.out);
            }
            QuantStage::MaxPool2d(p) => {
                let q = grid.expect("pool before any code-producing stage");
                let (h2, w2) = p.out_hw(h, w);
                let channels = codes.len() / (h * w);
                out.clear();
                out.resize(channels * h2 * w2, 0);
                for c in 0..channels {
                    for oh in 0..h2 {
                        for ow in 0..w2 {
                            let mut best = f32::NEG_INFINITY;
                            for ih in oh * p.stride..oh * p.stride + p.ksize {
                                for iw in ow * p.stride..ow * p.stride + p.ksize {
                                    let code = codes[(c * h + ih) * w + iw];
                                    best = best.max(q.dequantize(code as i32));
                                }
                            }
                            out[(c * h2 + oh) * w2 + ow] = q.int_code(best) as i8;
                        }
                    }
                }
                h = h2;
                w = w2;
                std::mem::swap(&mut codes, &mut out);
            }
            QuantStage::GlobalAvgPool(gap) => {
                pooled.clear();
                pooled.resize(gap.channels, 0.0);
                global_avg_pool_into(&codes, gap.channels, h * w, &gap.dq, &mut pooled);
            }
            QuantStage::DenseHead(hd) => hd.forward_into(&pooled, &mut logits),
            _ => panic!("unexpected 1-D stage in an image graph"),
        }
    }
    logits
}
