//! Shared integration-test bootstrap (`mod common;` in each test file —
//! a directory module so cargo does not treat it as its own test target).

use fqconv::runtime::{Engine, Manifest};

/// `None` (=> the caller's test skips) when the artifacts or the PJRT
/// runtime are unavailable — e.g. offline builds against the vendored
/// xla stub.
pub fn setup() -> Option<(Manifest, Engine)> {
    let dir = fqconv::artifacts_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping (no artifacts — run `make artifacts`): {e}");
            return None;
        }
    };
    let engine = match Engine::cpu() {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping (PJRT unavailable): {e}");
            return None;
        }
    };
    Some((manifest, engine))
}
