//! Runtime integration: load real artifacts through PJRT, execute, and
//! check shapes/determinism of the results. Requires `make artifacts`.

use fqconv::coordinator::checkpoint;
use fqconv::runtime::{hp, lit_f32, lit_to_vec_f32, Engine, Manifest};

mod common;
use common::setup;

fn forward_logits(manifest: &Manifest, engine: &Engine, model: &str, nw: f32, na: f32) -> Vec<f32> {
    let info = manifest.model(model).unwrap();
    let exe = engine.load(&info.artifact_path(&manifest.dir, "fwd").unwrap()).unwrap();
    let ck = checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap();
    let mut inputs = Vec::new();
    for spec in info.qat.all_specs() {
        let t = ck.get(&spec.name).unwrap_or_else(|| panic!("init missing {}", spec.name));
        inputs.push(lit_f32(&spec.shape, t.data()));
    }
    let b = info.batch;
    let numel: usize = info.input_shape.iter().product();
    let x: Vec<f32> = (0..b * numel).map(|i| ((i % 97) as f32 - 48.0) / 48.0).collect();
    let mut shape = vec![b];
    shape.extend(&info.input_shape);
    inputs.push(lit_f32(&shape, &x));
    let mut hpv = hp::defaults();
    hpv[hp::NW] = nw;
    hpv[hp::NA] = na;
    inputs.push(lit_f32(&[hp::LEN], &hpv));
    let outs = exe.run(&inputs).unwrap();
    lit_to_vec_f32(&outs[0]).unwrap()
}

#[test]
fn manifest_has_all_models_and_artifacts() {
    let Some((manifest, _)) = setup() else { return };
    for name in ["kws", "resnet20", "resnet8s", "resnet32", "resnet14s", "darknet_tiny"] {
        let info = manifest.model(name).unwrap();
        assert!(info.artifacts.contains_key("train"), "{name} missing train");
        assert!(info.artifacts.contains_key("fwd"), "{name} missing fwd");
        assert!(!info.qat.trainable.is_empty());
        assert!(info.macs_per_sample > 0);
        assert!(manifest.dir.join(&info.init_ckpt).exists(), "{name} init ckpt");
    }
    // FQ graphs where the paper defines them
    assert!(manifest.model("kws").unwrap().fq.is_some());
    assert!(manifest.model("resnet32").unwrap().fq.is_some());
    assert!(manifest.model("resnet20").unwrap().fq.is_none());
    // table-2 baselines
    let r8 = manifest.model("resnet8s").unwrap();
    assert!(r8.artifacts.contains_key("train_dorefa"));
    assert!(r8.artifacts.contains_key("train_pact"));
}

#[test]
fn kws_forward_executes_and_is_deterministic() {
    let Some((manifest, engine)) = setup() else { return };
    let a = forward_logits(&manifest, &engine, "kws", 1.0, 7.0);
    let b = forward_logits(&manifest, &engine, "kws", 1.0, 7.0);
    assert_eq!(a.len(), 32 * 12);
    assert!(a.iter().all(|v| v.is_finite()));
    assert_eq!(a, b, "same inputs must give identical logits");
}

#[test]
fn bitwidth_is_a_runtime_input() {
    // one artifact, different hp -> different numerics (fp vs ternary)
    let Some((manifest, engine)) = setup() else { return };
    let fp = forward_logits(&manifest, &engine, "resnet8s", 0.0, 0.0);
    let tern = forward_logits(&manifest, &engine, "resnet8s", 1.0, 7.0);
    assert_eq!(fp.len(), tern.len());
    let diff: f32 = fp.iter().zip(&tern).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1e-3, "quantized forward should differ from fp forward");
}

#[test]
fn fq_forward_artifact_runs() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("kws").unwrap();
    let exe = engine.load(&info.artifact_path(&manifest.dir, "fq_fwd").unwrap()).unwrap();
    let fq = info.fq.as_ref().unwrap();
    let mut inputs = Vec::new();
    for spec in fq.all_specs() {
        // zeros are fine: we only check execution + shape here
        inputs.push(lit_f32(&spec.shape, &vec![0.01; spec.numel()]));
    }
    let b = info.batch;
    let numel: usize = info.input_shape.iter().product();
    let mut shape = vec![b];
    shape.extend(&info.input_shape);
    inputs.push(lit_f32(&shape, &vec![0.1; b * numel]));
    let mut hpv = hp::defaults();
    hpv[hp::NW] = 1.0;
    hpv[hp::NA] = 7.0;
    inputs.push(lit_f32(&[hp::LEN], &hpv));
    let outs = exe.run(&inputs).unwrap();
    let logits = lit_to_vec_f32(&outs[0]).unwrap();
    assert_eq!(logits.len(), b * info.num_classes);
    assert!(logits.iter().all(|v| v.is_finite()));
}
