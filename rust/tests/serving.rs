//! Serving-layer behaviour: batching policy honored, all requests
//! answered, latency recorded, graceful shutdown, multi-worker fan-out.
//! Uses a synthetic backend (no XLA / no trained network needed).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use fqconv::serve::{ready, Backend, BatchPolicy, Server};
use fqconv::tensor::TensorF;

/// Deterministic toy backend: class = argmax-like hash of first feature.
struct ToyBackend {
    classes: usize,
    calls: Arc<AtomicUsize>,
    max_seen_batch: Arc<AtomicUsize>,
    delay_us: u64,
}

impl Backend for ToyBackend {
    fn infer(&mut self, x: &TensorF) -> anyhow::Result<TensorF> {
        let b = x.shape()[0];
        self.calls.fetch_add(1, Ordering::SeqCst);
        self.max_seen_batch.fetch_max(b, Ordering::SeqCst);
        if self.delay_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.delay_us));
        }
        let per = x.shape()[1];
        let mut out = vec![0f32; b * self.classes];
        for i in 0..b {
            let c = (x.data()[i * per].abs() as usize) % self.classes;
            out[i * self.classes + c] = 1.0;
        }
        Ok(TensorF::from_vec(&[b, self.classes], out))
    }

    fn sample_shape(&self) -> Vec<usize> {
        vec![4]
    }
}

fn toy_server(
    workers: usize,
    policy: BatchPolicy,
    delay_us: u64,
) -> (Server, Arc<AtomicUsize>, Arc<AtomicUsize>) {
    let calls = Arc::new(AtomicUsize::new(0));
    let maxb = Arc::new(AtomicUsize::new(0));
    let factories = (0..workers)
        .map(|_| {
            ready(ToyBackend {
                classes: 5,
                calls: Arc::clone(&calls),
                max_seen_batch: Arc::clone(&maxb),
                delay_us,
            })
        })
        .collect();
    (Server::start_with(factories, 4, policy), calls, maxb)
}

#[test]
fn all_requests_answered_correctly() {
    let (server, _, _) = toy_server(2, BatchPolicy::new(8, 500), 0);
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..100u64 {
        let f = vec![i as f32, 0.0, 0.0, 0.0];
        expected.push((i as usize) % 5);
        rxs.push(server.submit(f));
    }
    for (rx, want) in rxs.into_iter().zip(expected) {
        let resp = rx.recv().expect("response");
        assert_eq!(resp.class, want);
        assert_eq!(resp.logits.len(), 5);
        assert!(resp.latency_us >= 0.0);
        assert!(resp.batch_size >= 1);
    }
    let stats = server.stats();
    assert_eq!(stats.served, 100);
    assert!(stats.batches <= 100);
    server.shutdown();
}

#[test]
fn batches_respect_max_batch() {
    let (server, _, maxb) = toy_server(1, BatchPolicy::new(4, 50_000), 100);
    let rxs: Vec<_> = (0..32).map(|i| server.submit(vec![i as f32, 0.0, 0.0, 0.0])).collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    assert!(maxb.load(Ordering::SeqCst) <= 4, "batch exceeded policy");
    server.shutdown();
}

#[test]
fn timer_flushes_partial_batches() {
    // a single request must not wait forever for a full batch
    let (server, _, _) = toy_server(1, BatchPolicy::new(64, 1_000), 0);
    let t = std::time::Instant::now();
    let resp = server.infer(vec![1.0, 0.0, 0.0, 0.0]);
    assert_eq!(resp.batch_size, 1);
    assert!(
        t.elapsed() < std::time::Duration::from_millis(500),
        "partial batch stuck: {:?}",
        t.elapsed()
    );
    server.shutdown();
}

#[test]
fn multiple_workers_share_load() {
    let (server, calls, _) = toy_server(3, BatchPolicy::new(1, 100), 200);
    let rxs: Vec<_> = (0..30).map(|i| server.submit(vec![i as f32, 0.0, 0.0, 0.0])).collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    // with batch=1, every request is its own backend call
    assert_eq!(calls.load(Ordering::SeqCst), 30);
    let stats = server.stats();
    assert!((stats.mean_batch - 1.0).abs() < 1e-9, "mean_batch={}", stats.mean_batch);
    server.shutdown();
}

/// Backend that always errors — models a poisoned replica.
struct FailingBackend;

impl Backend for FailingBackend {
    fn infer(&mut self, _x: &TensorF) -> anyhow::Result<TensorF> {
        Err(anyhow::anyhow!("injected backend failure"))
    }

    fn sample_shape(&self) -> Vec<usize> {
        vec![4]
    }
}

#[test]
fn failing_worker_cannot_lose_or_block_requests() {
    let calls = Arc::new(AtomicUsize::new(0));
    let maxb = Arc::new(AtomicUsize::new(0));
    // one poisoned replica + two healthy (slow) ones: failed batches are
    // re-queued (bounded attempts, back of the line) so the shared queue
    // must deliver every request, and the poisoned worker retires after
    // MAX_WORKER_ERRORS failures instead of taking the pool down
    let factories = vec![
        ready(FailingBackend),
        ready(ToyBackend {
            classes: 5,
            calls: Arc::clone(&calls),
            max_seen_batch: Arc::clone(&maxb),
            delay_us: 1_000,
        }),
        ready(ToyBackend {
            classes: 5,
            calls: Arc::clone(&calls),
            max_seen_batch: Arc::clone(&maxb),
            delay_us: 1_000,
        }),
    ];
    let server = Server::start_with(factories, 4, BatchPolicy::new(4, 200));
    let n = 60u64;
    let rxs: Vec<_> =
        (0..n).map(|i| server.submit(vec![i as f32, 0.0, 0.0, 0.0])).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap_or_else(|_| panic!("request {i} lost to the dead worker"));
        assert_eq!(resp.class, i % 5);
    }
    let stats = server.stats();
    assert_eq!(stats.served, n, "every request must be served");
    // per-worker stats: a worker that exhausted its error budget has
    // retired (how many batches the poisoned worker happened to pull
    // before that is scheduling-dependent); error-free workers stay up
    for w in &stats.workers {
        if w.errors >= fqconv::serve::MAX_WORKER_ERRORS {
            assert!(!w.alive, "worker {} exhausted its error budget but is alive", w.worker);
        }
        if w.errors == 0 {
            assert!(w.alive, "healthy worker {} retired: {:?}", w.worker, stats.workers);
        }
    }
    assert!(
        stats.workers.iter().filter(|w| w.alive).count() >= 2,
        "healthy workers must stay alive: {:?}",
        stats.workers
    );
    assert_eq!(
        stats.workers.iter().map(|w| w.served).sum::<u64>(),
        n,
        "per-worker served counters must add up to the total"
    );
    server.shutdown();
}

#[test]
fn stats_percentiles_sane() {
    let (server, _, _) = toy_server(2, BatchPolicy::default(), 300);
    let rxs: Vec<_> = (0..50).map(|i| server.submit(vec![i as f32, 0.0, 0.0, 0.0])).collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let stats = server.stats();
    assert!(stats.p50_us > 0.0);
    assert!(stats.p99_us >= stats.p50_us);
    server.shutdown();
}
