//! Serving-layer behaviour: batching policy honored, all requests
//! answered, latency recorded, graceful shutdown, multi-worker fan-out,
//! multi-model registry, priorities and deadlines.
//! Uses a synthetic backend (no XLA / no trained network needed).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use fqconv::infer::graph::{synthetic_graph, Scratch, SynthArch};
use fqconv::infer::FqKwsNet;
use fqconv::serve::{
    ready, ready_indexed, AdmissionPolicy, Backend, BatchPolicy, GraphBackend, ModelId,
    ModelRegistry, ModelSpec, NativeBackend, Priority, ServeError, Server,
};
use fqconv::util::Rng;

/// Deterministic toy backend: class = argmax-like hash of first feature.
struct ToyBackend {
    classes: usize,
    calls: Arc<AtomicUsize>,
    max_seen_batch: Arc<AtomicUsize>,
    delay_us: u64,
    shape: Vec<usize>,
}

impl ToyBackend {
    fn new(
        classes: usize,
        calls: &Arc<AtomicUsize>,
        max_seen_batch: &Arc<AtomicUsize>,
        delay_us: u64,
    ) -> Self {
        ToyBackend {
            classes,
            calls: Arc::clone(calls),
            max_seen_batch: Arc::clone(max_seen_batch),
            delay_us,
            shape: vec![4],
        }
    }
}

impl Backend for ToyBackend {
    fn infer_into(&mut self, x: &[f32], batch: usize, out: &mut [f32]) -> anyhow::Result<()> {
        self.calls.fetch_add(1, Ordering::SeqCst);
        self.max_seen_batch.fetch_max(batch, Ordering::SeqCst);
        if self.delay_us > 0 {
            std::thread::sleep(std::time::Duration::from_micros(self.delay_us));
        }
        let per = x.len() / batch.max(1);
        out.fill(0.0);
        for i in 0..batch {
            let c = (x[i * per].abs() as usize) % self.classes;
            out[i * self.classes + c] = 1.0;
        }
        Ok(())
    }

    fn sample_shape(&self) -> &[usize] {
        &self.shape
    }

    fn out_dim(&self) -> usize {
        self.classes
    }
}

fn toy_server(
    workers: usize,
    policy: BatchPolicy,
    delay_us: u64,
) -> (Server, Arc<AtomicUsize>, Arc<AtomicUsize>) {
    let calls = Arc::new(AtomicUsize::new(0));
    let maxb = Arc::new(AtomicUsize::new(0));
    let (c, m) = (Arc::clone(&calls), Arc::clone(&maxb));
    let factory = ready(move || ToyBackend::new(5, &c, &m, delay_us));
    (Server::start(factory, workers, 4, policy), calls, maxb)
}

#[test]
fn all_requests_answered_correctly() {
    let (server, _, _) = toy_server(2, BatchPolicy::new(8, 500), 0);
    let mut expected = Vec::new();
    let mut rxs = Vec::new();
    for i in 0..100u64 {
        let f = vec![i as f32, 0.0, 0.0, 0.0];
        expected.push((i as usize) % 5);
        rxs.push(server.submit(f));
    }
    for (rx, want) in rxs.into_iter().zip(expected) {
        let resp = rx.recv().expect("response").expect("serving ok");
        assert_eq!(resp.class, want);
        assert_eq!(resp.logits.len(), 5);
        assert_eq!(resp.model.as_str(), "default");
        assert_eq!(resp.priority, Priority::Interactive);
        assert!(resp.latency_us >= 0.0);
        assert!(resp.batch_size >= 1);
    }
    let stats = server.stats();
    assert_eq!(stats.served, 100);
    assert!(stats.batches <= 100);
    assert_eq!(stats.expired, 0);
    assert_eq!(stats.dropped, 0);
    server.shutdown();
}

#[test]
fn batches_respect_max_batch() {
    let (server, _, maxb) = toy_server(1, BatchPolicy::new(4, 50_000), 100);
    let rxs: Vec<_> = (0..32).map(|i| server.submit(vec![i as f32, 0.0, 0.0, 0.0])).collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    assert!(maxb.load(Ordering::SeqCst) <= 4, "batch exceeded policy");
    server.shutdown();
}

#[test]
fn timer_flushes_partial_batches() {
    // a single request must not wait forever for a full batch
    let (server, _, _) = toy_server(1, BatchPolicy::new(64, 1_000), 0);
    let t = std::time::Instant::now();
    let resp = server.infer(vec![1.0, 0.0, 0.0, 0.0]);
    assert_eq!(resp.batch_size, 1);
    assert!(
        t.elapsed() < std::time::Duration::from_millis(500),
        "partial batch stuck: {:?}",
        t.elapsed()
    );
    server.shutdown();
}

#[test]
fn multiple_workers_share_load() {
    let (server, calls, _) = toy_server(3, BatchPolicy::new(1, 100), 200);
    let rxs: Vec<_> = (0..30).map(|i| server.submit(vec![i as f32, 0.0, 0.0, 0.0])).collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    // with batch=1, every request is its own backend call
    assert_eq!(calls.load(Ordering::SeqCst), 30);
    let stats = server.stats();
    assert!((stats.mean_batch - 1.0).abs() < 1e-9, "mean_batch={}", stats.mean_batch);
    server.shutdown();
}

/// Backend that always errors — models a poisoned replica.
struct FailingBackend {
    shape: Vec<usize>,
}

impl Backend for FailingBackend {
    fn infer_into(&mut self, _x: &[f32], _batch: usize, _out: &mut [f32]) -> anyhow::Result<()> {
        Err(anyhow::anyhow!("injected backend failure"))
    }

    fn sample_shape(&self) -> &[usize] {
        &self.shape
    }

    fn out_dim(&self) -> usize {
        5
    }
}

#[test]
fn failing_worker_cannot_lose_or_block_requests() {
    let calls = Arc::new(AtomicUsize::new(0));
    let maxb = Arc::new(AtomicUsize::new(0));
    // one poisoned replica + two healthy (slow) ones: failed batches are
    // re-queued (bounded attempts, back of the lane) so the shared queue
    // must deliver every request, and the poisoned worker quarantines
    // its replica after MAX_WORKER_ERRORS consecutive failures while
    // staying alive for other models
    let (c, m) = (Arc::clone(&calls), Arc::clone(&maxb));
    let factory = ready_indexed(move |wi| {
        if wi == 0 {
            Box::new(FailingBackend { shape: vec![4] })
        } else {
            Box::new(ToyBackend::new(5, &c, &m, 1_000))
        }
    });
    let server = Server::start(factory, 3, 4, BatchPolicy::new(4, 200));
    let n = 60u64;
    let rxs: Vec<_> = (0..n).map(|i| server.submit(vec![i as f32, 0.0, 0.0, 0.0])).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx
            .recv()
            .unwrap_or_else(|_| panic!("request {i} lost to the dead worker"))
            .unwrap_or_else(|e| panic!("request {i} failed: {e}"));
        assert_eq!(resp.class, i % 5);
    }
    let stats = server.stats();
    assert_eq!(stats.served, n, "every request must be served");
    // quarantine is per (worker, model): every worker stays alive —
    // including the one with the poisoned replica — and the healthy
    // ones absorb the load
    for w in &stats.workers {
        assert!(w.alive, "worker {} must stay alive under quarantine: {:?}", w.worker, stats);
    }
    assert!(
        stats.workers.iter().any(|w| w.errors >= fqconv::serve::MAX_WORKER_ERRORS),
        "the poisoned replica must have burned its error budget: {:?}",
        stats.workers
    );
    assert_eq!(
        stats.workers.iter().map(|w| w.served).sum::<u64>(),
        n,
        "per-worker served counters must add up to the total"
    );
    server.shutdown();
}

#[test]
fn poisoned_model_cannot_take_down_healthy_models() {
    // regression: worker error budgets are per *model*, so a model whose
    // backend always fails must not retire the shared workers — traffic
    // to the healthy model keeps flowing, and the failing model's
    // requests get typed BackendFailed replies
    let registry = ModelRegistry::start(2);
    let calls = Arc::new(AtomicUsize::new(0));
    let maxb = Arc::new(AtomicUsize::new(0));
    let (c, m) = (Arc::clone(&calls), Arc::clone(&maxb));
    registry
        .register(
            "healthy",
            ModelSpec::new(
                ready(move || ToyBackend::new(5, &c, &m, 0)),
                4,
                BatchPolicy::new(2, 100),
            ),
        )
        .unwrap();
    registry
        .register(
            "poisoned",
            ModelSpec::new(
                ready(|| FailingBackend { shape: vec![4] }),
                4,
                BatchPolicy::new(2, 100),
            ),
        )
        .unwrap();
    let (healthy, poisoned) = (ModelId::new("healthy"), ModelId::new("poisoned"));
    // interleave traffic so both models cross every worker
    for round in 0..8u64 {
        let bad: Vec<_> = (0..4u64)
            .map(|i| registry.submit(&poisoned, vec![i as f32, 0.0, 0.0, 0.0]).unwrap())
            .collect();
        for rx in bad {
            let err = rx.recv().expect("typed reply, not a disconnect").unwrap_err();
            assert!(
                matches!(err, ServeError::BackendFailed { .. }),
                "round {round}: expected BackendFailed, got {err}"
            );
        }
        for i in 0..4u64 {
            let resp = registry
                .infer(&healthy, vec![i as f32, 0.0, 0.0, 0.0])
                .unwrap_or_else(|e| panic!("round {round}: healthy model failed: {e}"));
            assert_eq!(resp.class, (i as usize) % 5);
        }
    }
    let stats = registry.stats();
    for w in &stats.workers {
        assert!(w.alive, "worker {} retired because of one bad model: {:?}", w.worker, stats);
    }
    let healthy_stats = stats.models.iter().find(|m| m.id == healthy).unwrap();
    assert_eq!(healthy_stats.served, 32);
    assert_eq!(healthy_stats.dropped, 0);
    let poisoned_stats = stats.models.iter().find(|m| m.id == poisoned).unwrap();
    assert_eq!(poisoned_stats.served, 0);
    assert_eq!(poisoned_stats.dropped, 32, "every poisoned request gets a typed failure");
    registry.shutdown();
}

#[test]
fn stats_percentiles_sane() {
    let (server, _, _) = toy_server(2, BatchPolicy::default(), 300);
    let rxs: Vec<_> = (0..50).map(|i| server.submit(vec![i as f32, 0.0, 0.0, 0.0])).collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let stats = server.stats();
    assert!(stats.p50_us > 0.0);
    assert!(stats.p99_us >= stats.p50_us);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Priorities + deadlines (threaded; ordering properties live in
// rust/tests/properties.rs over batcher::simulate_prio)
// ---------------------------------------------------------------------------

#[test]
fn per_priority_stats_are_recorded() {
    let (server, _, _) = toy_server(2, BatchPolicy::new(4, 300), 50);
    let mut rxs = Vec::new();
    for i in 0..40u64 {
        let f = vec![i as f32, 0.0, 0.0, 0.0];
        let prio = if i % 4 == 0 { Priority::Batch } else { Priority::Interactive };
        rxs.push((prio, server.submit_with(f, prio, None)));
    }
    for (prio, rx) in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.priority, prio, "reply must carry the request's class");
    }
    let stats = server.stats();
    let inter = &stats.priorities[Priority::Interactive.index()];
    let batch = &stats.priorities[Priority::Batch.index()];
    assert_eq!(inter.served, 30);
    assert_eq!(batch.served, 10);
    assert!(inter.p50_us > 0.0 && batch.p50_us > 0.0);
    assert_eq!(stats.served, 40);
    server.shutdown();
}

#[test]
fn expired_deadline_gets_a_typed_reply() {
    // one worker, busy with a slow no-deadline request; the queued
    // deadlined request must be answered DeadlineExceeded, not served
    let (server, _, _) = toy_server(1, BatchPolicy::new(1, 50), 30_000);
    let first = server.submit(vec![1.0, 0.0, 0.0, 0.0]);
    // give the worker a moment to pick up the first batch
    std::thread::sleep(Duration::from_millis(5));
    let doomed = server.submit_with(
        vec![2.0, 0.0, 0.0, 0.0],
        Priority::Interactive,
        Some(Duration::from_micros(1)),
    );
    let err = doomed.recv().expect("typed reply, not a disconnect").unwrap_err();
    match err {
        ServeError::DeadlineExceeded { model, waited_us } => {
            assert_eq!(model.as_str(), "default");
            assert!(waited_us > 0);
        }
        other => panic!("expected DeadlineExceeded, got {other}"),
    }
    first.recv().unwrap().unwrap();
    let stats = server.stats();
    assert_eq!(stats.expired, 1);
    assert_eq!(stats.served, 1, "the expired request must not be served");
    server.shutdown();
}

#[test]
fn generous_deadline_is_honored() {
    let (server, _, _) = toy_server(2, BatchPolicy::new(4, 200), 0);
    let rxs: Vec<_> = (0..20)
        .map(|i| {
            server.submit_with(
                vec![i as f32, 0.0, 0.0, 0.0],
                Priority::Interactive,
                Some(Duration::from_secs(30)),
            )
        })
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap().expect("generous deadline must ride");
        assert_eq!(resp.class, i % 5);
    }
    assert_eq!(server.stats().expired, 0);
    server.shutdown();
}

// ---------------------------------------------------------------------------
// Multi-model registry
// ---------------------------------------------------------------------------

#[test]
fn registry_serves_two_models_concurrently() {
    let registry = ModelRegistry::start(2);
    let calls = Arc::new(AtomicUsize::new(0));
    let maxb = Arc::new(AtomicUsize::new(0));
    let (c5, m5) = (Arc::clone(&calls), Arc::clone(&maxb));
    registry
        .register(
            "toy5",
            ModelSpec::new(
                ready(move || ToyBackend::new(5, &c5, &m5, 100)),
                4,
                BatchPolicy::new(4, 200),
            ),
        )
        .expect("register toy5");
    let (c3, m3) = (Arc::clone(&calls), Arc::clone(&maxb));
    registry
        .register(
            "toy3",
            ModelSpec::new(
                ready(move || {
                    let mut t = ToyBackend::new(3, &c3, &m3, 100);
                    t.shape = vec![2];
                    t
                }),
                2,
                BatchPolicy::new(2, 200),
            ),
        )
        .expect("register toy3");
    // duplicate registration is refused
    assert!(registry
        .register(
            "toy3",
            ModelSpec::new(
                ready(|| FailingBackend { shape: vec![2] }),
                2,
                BatchPolicy::new(1, 100),
            ),
        )
        .is_err());

    let (id5, id3) = (ModelId::new("toy5"), ModelId::new("toy3"));
    let n = 40u64;
    std::thread::scope(|s| {
        let (r5, r3) = (&registry, &registry);
        let (id5, id3) = (&id5, &id3);
        s.spawn(move || {
            let rxs: Vec<_> = (0..n)
                .map(|i| r5.submit(id5, vec![i as f32, 0.0, 0.0, 0.0]).expect("registered"))
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let resp = rx.recv().unwrap().unwrap();
                assert_eq!(resp.model.as_str(), "toy5");
                assert_eq!(resp.logits.len(), 5);
                assert_eq!(resp.class, i % 5);
            }
        });
        s.spawn(move || {
            let rxs: Vec<_> = (0..n)
                .map(|i| r3.submit(id3, vec![i as f32, 0.0]).expect("registered"))
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let resp = rx.recv().unwrap().unwrap();
                assert_eq!(resp.model.as_str(), "toy3");
                assert_eq!(resp.logits.len(), 3);
                assert_eq!(resp.class, i % 3);
            }
        });
    });

    let stats = registry.stats();
    assert_eq!(stats.served, 2 * n);
    assert_eq!(stats.models.len(), 2);
    // models are sorted by id: toy3 then toy5
    assert_eq!(stats.models[0].id.as_str(), "toy3");
    assert_eq!(stats.models[1].id.as_str(), "toy5");
    for m in &stats.models {
        assert_eq!(m.served, n, "model {} served {} of {n}", m.id, m.served);
        assert!(m.batches >= 1);
        assert!(m.mean_batch >= 1.0);
        assert_eq!(m.expired, 0);
        assert_eq!(m.dropped, 0);
        assert!(m.p50_us > 0.0);
    }
    // per-worker served must cover both models' traffic
    assert_eq!(stats.workers.iter().map(|w| w.served).sum::<u64>(), 2 * n);
    registry.shutdown();
}

#[test]
fn registry_serves_resnet32_alongside_a_kws_model() {
    // the acceptance pin for the 2-D subsystem: the synthetic ResNet-32
    // graph serves from the registry next to a KWS model on the same
    // shared worker pool, and every served logit row is bit-identical
    // to the engine's direct forward of the same sample
    let kws = Arc::new(FqKwsNet::synthetic(1.0, 7.0, 7).expect("kws net"));
    let resnet =
        Arc::new(synthetic_graph(&SynthArch::resnet32(), 1.0, 7.0, 7).expect("resnet32"));
    let registry = ModelRegistry::start(2);
    registry
        .register(
            "kws",
            ModelSpec::new(
                NativeBackend::factory(&kws, &[39, 80]),
                39 * 80,
                BatchPolicy::new(4, 300),
            ),
        )
        .expect("register kws");
    registry
        .register(
            "resnet32",
            ModelSpec::new(
                GraphBackend::factory(&resnet),
                resnet.in_numel(),
                BatchPolicy::new(2, 300),
            ),
        )
        .expect("register resnet32");

    // deterministic inputs + expected logits from the direct engine
    let mut rng = Rng::new(15);
    let (n_res, n_kws) = (4usize, 12usize);
    let res_x: Vec<Vec<f32>> = (0..n_res)
        .map(|_| {
            let mut v = vec![0f32; resnet.in_numel()];
            rng.fill_gaussian(&mut v, 0.5);
            v
        })
        .collect();
    let kws_x: Vec<Vec<f32>> = (0..n_kws)
        .map(|_| {
            let mut v = vec![0f32; 39 * 80];
            rng.fill_gaussian(&mut v, 1.0);
            v
        })
        .collect();
    let mut rs = Scratch::for_graph(&resnet);
    let res_want: Vec<Vec<f32>> = res_x.iter().map(|x| resnet.forward(x, &mut rs)).collect();
    let mut ks = Scratch::for_graph(kws.graph());
    let kws_want: Vec<Vec<f32>> = kws_x.iter().map(|x| kws.forward(x, &mut ks)).collect();

    let (rid, kid) = (ModelId::new("resnet32"), ModelId::new("kws"));
    std::thread::scope(|s| {
        let (reg_a, reg_b) = (&registry, &registry);
        let (rid, kid) = (&rid, &kid);
        let (res_x, res_want) = (&res_x, &res_want);
        let (kws_x, kws_want) = (&kws_x, &kws_want);
        s.spawn(move || {
            let rxs: Vec<_> = res_x
                .iter()
                .map(|x| reg_a.submit(rid, x.clone()).expect("registered"))
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let resp = rx.recv().unwrap().unwrap();
                assert_eq!(resp.model.as_str(), "resnet32");
                assert_eq!(resp.logits, res_want[i], "resnet sample {i} diverged");
            }
        });
        s.spawn(move || {
            let rxs: Vec<_> = kws_x
                .iter()
                .map(|x| reg_b.submit(kid, x.clone()).expect("registered"))
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let resp = rx.recv().unwrap().unwrap();
                assert_eq!(resp.model.as_str(), "kws");
                assert_eq!(resp.logits, kws_want[i], "kws sample {i} diverged");
            }
        });
    });

    let stats = registry.stats();
    assert_eq!(stats.served, (n_res + n_kws) as u64);
    let rm = stats.models.iter().find(|m| m.id == rid).unwrap();
    assert_eq!(rm.served, n_res as u64);
    assert_eq!(rm.dropped, 0);
    let km = stats.models.iter().find(|m| m.id == kid).unwrap();
    assert_eq!(km.served, n_kws as u64);
    registry.shutdown();
}

#[test]
fn registry_serves_batched_2d_models_bit_identically_at_1_2_4_workers() {
    // the batched-2-D acceptance pin: resnet32 AND darknet19 registered
    // in one registry, mixed batch>1 traffic, and every served logit
    // row bit-identical to the offline forward_into of the same sample
    // — at 1, 2 and 4 workers (exercises the new sample-parallel
    // GraphBackend batch path at several pool shapes)
    let resnet =
        Arc::new(synthetic_graph(&SynthArch::resnet32(), 1.0, 7.0, 7).expect("resnet32"));
    let dark =
        Arc::new(synthetic_graph(&SynthArch::darknet19(), 1.0, 7.0, 7).expect("darknet19"));
    let mut rng = Rng::new(77);
    let (n_res, n_dark) = (3usize, 2usize);
    let res_x: Vec<Vec<f32>> = (0..n_res)
        .map(|_| {
            let mut v = vec![0f32; resnet.in_numel()];
            rng.fill_gaussian(&mut v, 0.5);
            v
        })
        .collect();
    let dark_x: Vec<Vec<f32>> = (0..n_dark)
        .map(|_| {
            let mut v = vec![0f32; dark.in_numel()];
            rng.fill_gaussian(&mut v, 0.5);
            v
        })
        .collect();
    let mut rs = Scratch::for_graph(&resnet);
    let res_want: Vec<Vec<f32>> = res_x.iter().map(|x| resnet.forward(x, &mut rs)).collect();
    let mut ds = Scratch::for_graph(&dark);
    let dark_want: Vec<Vec<f32>> = dark_x.iter().map(|x| dark.forward(x, &mut ds)).collect();

    let (rid, did) = (ModelId::new("resnet32"), ModelId::new("darknet19"));
    for workers in [1usize, 2, 4] {
        let registry = ModelRegistry::start(workers);
        // max_batch == the traffic size with a generous wait: every
        // model's requests close into one batch > 1 by count
        registry
            .register(
                rid.as_str(),
                ModelSpec::new(
                    GraphBackend::factory_sharded(&resnet, workers),
                    resnet.in_numel(),
                    BatchPolicy::new(n_res, 500_000),
                ),
            )
            .expect("register resnet32");
        registry
            .register(
                did.as_str(),
                ModelSpec::new(
                    GraphBackend::factory_sharded(&dark, workers),
                    dark.in_numel(),
                    BatchPolicy::new(n_dark, 500_000),
                ),
            )
            .expect("register darknet19");
        let rrx: Vec<_> =
            res_x.iter().map(|x| registry.submit(&rid, x.clone()).expect("registered")).collect();
        let drx: Vec<_> =
            dark_x.iter().map(|x| registry.submit(&did, x.clone()).expect("registered")).collect();
        let mut max_batch = 0usize;
        for (i, rx) in rrx.into_iter().enumerate() {
            let resp = rx.recv().expect("reply").expect("served");
            assert_eq!(resp.logits, res_want[i], "workers={workers} resnet sample {i} diverged");
            max_batch = max_batch.max(resp.batch_size);
        }
        for (i, rx) in drx.into_iter().enumerate() {
            let resp = rx.recv().expect("reply").expect("served");
            assert_eq!(resp.logits, dark_want[i], "workers={workers} darknet sample {i} diverged");
            max_batch = max_batch.max(resp.batch_size);
        }
        assert!(
            max_batch >= 2,
            "workers={workers}: traffic never formed a batch > 1 — the batched path \
             went unexercised"
        );
        let stats = registry.stats();
        assert_eq!(stats.served, (n_res + n_dark) as u64);
        registry.shutdown();
    }
}

#[test]
fn graph_backend_batch_output_bit_identical_across_intra_budgets() {
    // regression for the batch>1 thread-budget drop: GraphBackend used
    // to run every batched sample with threads=1 regardless of
    // intra_threads; now the budget fans out across samples — and the
    // output must stay bit-identical at every budget
    let g = Arc::new(synthetic_graph(&SynthArch::resnet("resnet8", 1), 1.0, 7.0, 11).expect("r8"));
    let b = 5usize;
    let mut rng = Rng::new(21);
    let mut flat = vec![0f32; b * g.in_numel()];
    rng.fill_gaussian(&mut flat, 0.5);
    // reference: the offline sequential walk
    let mut s = Scratch::for_graph(&g);
    let mut want = vec![0f32; b * g.classes()];
    g.forward_rows(&flat, &mut s, &mut want);
    for intra in [1usize, 2, 3, 8] {
        let mut backend = GraphBackend::with_intra_threads(Arc::clone(&g), intra);
        let mut out = vec![0f32; b * g.classes()];
        backend.infer_into(&flat, b, &mut out).expect("infer");
        assert_eq!(out, want, "intra={intra}: batched backend diverged");
    }
}

#[test]
fn evicted_model_rejects_new_submits_but_other_models_survive() {
    let registry = ModelRegistry::start(1);
    let calls = Arc::new(AtomicUsize::new(0));
    let maxb = Arc::new(AtomicUsize::new(0));
    let (c, m) = (Arc::clone(&calls), Arc::clone(&maxb));
    registry
        .register(
            "a",
            ModelSpec::new(
                ready(move || ToyBackend::new(5, &c, &m, 0)),
                4,
                BatchPolicy::new(2, 100),
            ),
        )
        .unwrap();
    let (c, m) = (Arc::clone(&calls), Arc::clone(&maxb));
    registry
        .register(
            "b",
            ModelSpec::new(
                ready(move || ToyBackend::new(5, &c, &m, 0)),
                4,
                BatchPolicy::new(2, 100),
            ),
        )
        .unwrap();
    let (ida, idb) = (ModelId::new("a"), ModelId::new("b"));
    assert_eq!(registry.model_ids(), vec![ida.clone(), idb.clone()]);
    registry.infer(&ida, vec![1.0, 0.0, 0.0, 0.0]).expect("a serves");

    assert!(registry.evict(&ida), "evicting a registered model");
    assert!(!registry.evict(&ida), "double evict reports absence");
    match registry.submit(&ida, vec![1.0, 0.0, 0.0, 0.0]) {
        Err(ServeError::UnknownModel(id)) => assert_eq!(id.as_str(), "a"),
        other => panic!("expected UnknownModel, got {:?}", other.map(|_| ())),
    }
    // the surviving model keeps serving through the same workers
    for i in 0..10u64 {
        let resp = registry.infer(&idb, vec![i as f32, 0.0, 0.0, 0.0]).expect("b serves");
        assert_eq!(resp.class, (i as usize) % 5);
    }
    assert_eq!(registry.model_ids(), vec![idb.clone()]);
    let stats = registry.stats();
    assert_eq!(stats.models.len(), 1);
    assert_eq!(stats.models[0].id, idb);
    registry.shutdown();
}

#[test]
fn concurrent_register_evict_submit_same_model_id() {
    // registry churn stress: one thread register/evicts the same ModelId
    // in a tight loop while two submitter threads hammer it and a fourth
    // drives steady traffic to a neighbor model. Invariants: a submit
    // either gets a typed UnknownModel at the evicted window or is
    // accepted — and every accepted request is served correctly (stale
    // generations ride on one-shot replicas, they are never dropped); the
    // neighbor model never misses; no worker retires.
    let registry = ModelRegistry::start(2);
    let calls = Arc::new(AtomicUsize::new(0));
    let maxb = Arc::new(AtomicUsize::new(0));
    let (c, m) = (Arc::clone(&calls), Arc::clone(&maxb));
    registry
        .register(
            "stable",
            ModelSpec::new(
                ready(move || ToyBackend::new(5, &c, &m, 0)),
                4,
                BatchPolicy::new(2, 100),
            ),
        )
        .unwrap();
    let churn_id = ModelId::new("churn");
    let stable_id = ModelId::new("stable");
    std::thread::scope(|s| {
        let reg = &registry;
        let churn = &churn_id;
        let stable = &stable_id;
        s.spawn(move || {
            for _round in 0..30 {
                let (c, m) = (Arc::new(AtomicUsize::new(0)), Arc::new(AtomicUsize::new(0)));
                reg.register(
                    "churn",
                    ModelSpec::new(
                        ready(move || ToyBackend::new(5, &c, &m, 0)),
                        4,
                        BatchPolicy::new(2, 100),
                    ),
                )
                .expect("churn id was evicted last round");
                // let some traffic land on this generation
                std::thread::sleep(Duration::from_micros(300));
                assert!(reg.evict(churn), "evicting the generation just registered");
            }
        });
        for t in 0..2u64 {
            s.spawn(move || {
                for i in 0..150u64 {
                    match reg.submit(churn, vec![i as f32, 0.0, 0.0, 0.0]) {
                        Ok(rx) => {
                            // accepted: must be answered, and with the
                            // right class — evicted generations are
                            // served via one-shot replicas, not dropped
                            let resp = rx
                                .recv()
                                .unwrap_or_else(|_| {
                                    panic!("submitter {t}: request {i} lost to churn")
                                })
                                .unwrap_or_else(|e| {
                                    panic!("submitter {t}: request {i} failed typed: {e}")
                                });
                            assert_eq!(resp.class, (i as usize) % 5);
                        }
                        // racing the evicted window is the expected miss
                        Err(ServeError::UnknownModel(id)) => assert_eq!(id.as_str(), "churn"),
                        Err(e) => panic!("submitter {t}: unexpected submit error: {e}"),
                    }
                }
            });
        }
        s.spawn(move || {
            for i in 0..100u64 {
                let resp = reg
                    .infer(stable, vec![i as f32, 0.0, 0.0, 0.0])
                    .unwrap_or_else(|e| panic!("stable model missed under churn: {e}"));
                assert_eq!(resp.class, (i as usize) % 5);
            }
        });
    });
    // the storm ends on an evict; a fresh generation must register and
    // serve, and no worker may have retired along the way
    assert_eq!(registry.model_ids(), vec![stable_id.clone()]);
    let (c, m) = (Arc::new(AtomicUsize::new(0)), Arc::new(AtomicUsize::new(0)));
    registry
        .register(
            "churn",
            ModelSpec::new(
                ready(move || ToyBackend::new(5, &c, &m, 0)),
                4,
                BatchPolicy::new(2, 100),
            ),
        )
        .expect("fresh register after the churn storm");
    let resp = registry.infer(&churn_id, vec![3.0, 0.0, 0.0, 0.0]).expect("fresh generation serves");
    assert_eq!(resp.class, 3);
    for w in &registry.stats().workers {
        assert!(w.alive, "worker {} retired during registry churn", w.worker);
    }
    registry.shutdown();
}

// ---------------------------------------------------------------------------
// Overload robustness: admission control, DWFQ fairness, replica budgets,
// and the chaos fault-injection harness
// ---------------------------------------------------------------------------

#[test]
fn admission_bound_sheds_typed_overloaded_at_submit() {
    // one slow worker, a pending bound of 2, and a 10-deep instant
    // burst: the overflow must come back as a typed Overloaded *from
    // submit*, every admitted request must still be served, and the
    // reservation counter must drain back to zero with the replies
    let registry = ModelRegistry::start(1);
    let calls = Arc::new(AtomicUsize::new(0));
    let maxb = Arc::new(AtomicUsize::new(0));
    let (c, m) = (Arc::clone(&calls), Arc::clone(&maxb));
    registry
        .register(
            "bounded",
            ModelSpec::new(
                ready(move || ToyBackend::new(5, &c, &m, 20_000)),
                4,
                BatchPolicy::new(1, 100),
            )
            .with_admission(AdmissionPolicy::bounded(2)),
        )
        .unwrap();
    let id = ModelId::new("bounded");
    let mut rxs = Vec::new();
    let mut shed = 0u64;
    for i in 0..10u64 {
        match registry.submit(&id, vec![i as f32, 0.0, 0.0, 0.0]) {
            Ok(rx) => rxs.push(rx),
            Err(ServeError::Overloaded { model, pending }) => {
                assert_eq!(model.as_str(), "bounded");
                assert!(pending >= 2, "shed below the bound: pending={pending}");
                shed += 1;
            }
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    assert!(shed >= 1, "a 10-deep instant burst over a bound of 2 must shed");
    let served = rxs.len() as u64;
    for rx in rxs {
        rx.recv().expect("admitted request must reach a terminal reply").expect("served");
    }
    let stats = registry.stats();
    let ms = &stats.models[0];
    assert_eq!(ms.served, served);
    assert_eq!(ms.shed, shed);
    assert_eq!(ms.served + ms.shed, 10);
    assert_eq!(ms.pending, 0, "reservations must drain with the terminal replies");
    registry.shutdown();
}

#[test]
fn infeasible_deadline_is_shed_at_submit_once_cost_is_known() {
    // once one served batch has trained the per-sample service-time
    // EWMA (~50ms here), a 2ms-deadline request arriving behind a
    // queued no-deadline request is a guaranteed deadline miss — the
    // admission layer must shed it at submit instead of queueing it
    let registry = ModelRegistry::start(1);
    let calls = Arc::new(AtomicUsize::new(0));
    let maxb = Arc::new(AtomicUsize::new(0));
    let (c, m) = (Arc::clone(&calls), Arc::clone(&maxb));
    registry
        .register(
            "slow",
            ModelSpec::new(
                ready(move || ToyBackend::new(5, &c, &m, 50_000)),
                4,
                BatchPolicy::new(1, 100),
            )
            .with_admission(AdmissionPolicy::bounded(16)),
        )
        .unwrap();
    let id = ModelId::new("slow");
    let resp = registry.infer(&id, vec![1.0, 0.0, 0.0, 0.0]).expect("first request serves");
    assert_eq!(resp.class, 1);
    // occupy the worker; no deadline, so feasibility never sheds it
    let blocker = registry
        .submit_with(&id, vec![2.0, 0.0, 0.0, 0.0], Priority::Interactive, None)
        .expect("no-deadline requests pass feasibility");
    let doomed = registry.submit_with(
        &id,
        vec![3.0, 0.0, 0.0, 0.0],
        Priority::Interactive,
        Some(Duration::from_millis(2)),
    );
    match doomed {
        Err(ServeError::Overloaded { model, .. }) => assert_eq!(model.as_str(), "slow"),
        Ok(_) => panic!("an infeasible deadline must be shed at submit"),
        Err(e) => panic!("unexpected submit error: {e}"),
    }
    blocker.recv().expect("reply").expect("served");
    let stats = registry.stats();
    assert_eq!(stats.models[0].shed, 1);
    assert_eq!(stats.models[0].served, 2);
    registry.shutdown();
}

#[test]
fn replica_budget_pins_a_model_to_a_subset_of_the_pool() {
    // dropping a model's replica budget to 1 on a 2-worker pool must
    // route all of its (healthy, never-bounced) batches through worker
    // 0 — worker 1 serves nothing — while every request is still
    // answered correctly
    let registry = ModelRegistry::start(2);
    let calls = Arc::new(AtomicUsize::new(0));
    let maxb = Arc::new(AtomicUsize::new(0));
    let (c, m) = (Arc::clone(&calls), Arc::clone(&maxb));
    registry
        .register(
            "pinned",
            ModelSpec::new(
                ready(move || ToyBackend::new(5, &c, &m, 1_000)),
                4,
                BatchPolicy::new(1, 100),
            ),
        )
        .unwrap();
    let id = ModelId::new("pinned");
    assert!(registry.set_replica_budget(&id, 1), "budget applies to a registered model");
    assert!(!registry.set_replica_budget(&ModelId::new("ghost"), 1), "unknown id reports false");
    let rxs: Vec<_> = (0..20u64)
        .map(|i| registry.submit(&id, vec![i as f32, 0.0, 0.0, 0.0]).expect("registered"))
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("reply").expect("served");
        assert_eq!(resp.class, i % 5);
    }
    let stats = registry.stats();
    assert_eq!(stats.models[0].replica_budget, 1);
    assert_eq!(stats.workers[0].served, 20, "the budgeted worker serves everything");
    assert_eq!(stats.workers[1].served, 0, "budget 1 must exclude worker 1");
    registry.shutdown();
}

/// Backend that logs its model tag into a shared slot array on every
/// call (cursor + slot stores, no locks), so tests can assert the
/// cross-model dispatch order of a single worker.
struct OrderBackend {
    tag: usize,
    order: Arc<Vec<AtomicUsize>>,
    cursor: Arc<AtomicUsize>,
    delay_us: u64,
    shape: Vec<usize>,
}

impl Backend for OrderBackend {
    fn infer_into(&mut self, _x: &[f32], _batch: usize, out: &mut [f32]) -> anyhow::Result<()> {
        let k = self.cursor.fetch_add(1, Ordering::SeqCst);
        if k < self.order.len() {
            self.order[k].store(self.tag, Ordering::SeqCst);
        }
        if self.delay_us > 0 {
            std::thread::sleep(Duration::from_micros(self.delay_us));
        }
        out.fill(0.0);
        Ok(())
    }

    fn sample_shape(&self) -> &[usize] {
        &self.shape
    }

    fn out_dim(&self) -> usize {
        2
    }
}

#[test]
fn dwfq_keeps_a_cheap_model_live_behind_an_expensive_flood() {
    // one worker, two models on the same (Batch) lane: a 1000x-cost
    // model floods 8 requests first, then a cheap model submits 8. With
    // FIFO the cheap model would wait out the whole flood; deficit-
    // weighted fair queueing must instead serve every cheap batch
    // before the flood's second batch (the first is already in flight)
    const EXPENSIVE: usize = 1;
    const CHEAP: usize = 2;
    let registry = ModelRegistry::start(1);
    let order: Arc<Vec<AtomicUsize>> = Arc::new((0..32).map(|_| AtomicUsize::new(0)).collect());
    let cursor = Arc::new(AtomicUsize::new(0));
    for (name, tag, cost, delay_us) in
        [("expensive", EXPENSIVE, 1_000_000u64, 10_000u64), ("cheap", CHEAP, 1_000, 1_000)]
    {
        let (order, cursor) = (Arc::clone(&order), Arc::clone(&cursor));
        registry
            .register(
                name,
                ModelSpec::new(
                    ready(move || OrderBackend {
                        tag,
                        order: Arc::clone(&order),
                        cursor: Arc::clone(&cursor),
                        delay_us,
                        shape: vec![4],
                    }),
                    4,
                    BatchPolicy::new(1, 100),
                )
                .with_cost(cost),
            )
            .unwrap();
    }
    let (eid, cid) = (ModelId::new("expensive"), ModelId::new("cheap"));
    let mut rxs = Vec::new();
    // the first expensive request occupies the worker (10ms) while the
    // rest of the contest lands on the queue
    for i in 0..9u64 {
        rxs.push(
            registry
                .submit_with(&eid, vec![i as f32, 0.0, 0.0, 0.0], Priority::Batch, None)
                .expect("registered"),
        );
    }
    for i in 0..8u64 {
        rxs.push(
            registry
                .submit_with(&cid, vec![i as f32, 0.0, 0.0, 0.0], Priority::Batch, None)
                .expect("registered"),
        );
    }
    for rx in rxs {
        rx.recv().expect("reply").expect("served");
    }
    let n = cursor.load(Ordering::SeqCst).min(order.len());
    let seq: Vec<usize> = (0..n).map(|k| order[k].load(Ordering::SeqCst)).collect();
    assert_eq!(seq.len(), 17, "max_batch=1 means one call per request");
    let last_cheap = seq.iter().rposition(|&t| t == CHEAP).expect("cheap model served");
    let flood_ahead = seq[..last_cheap].iter().filter(|&&t| t == EXPENSIVE).count();
    assert!(
        flood_ahead <= 1,
        "expensive flood starved the cheap model under DWFQ: dispatch order {seq:?}"
    );
    registry.shutdown();
}

#[test]
fn chaos_faults_degrade_gracefully_and_keep_healthy_models_exact() {
    // the overload-robustness acceptance pin: a two-model registry
    // (kws + darknet19) where the darknet19 backend is wrapped in the
    // chaos harness — seeded transient failures, injected stalls, and
    // (at >=2 workers) one worker panicking outright on its first
    // chaos call. Invariants at 1, 2 and 4 workers: every accepted
    // request reaches exactly one terminal reply (no disconnects, no
    // hangs), the chaos model only ever fails *typed*, and the healthy
    // model's logits stay bit-identical to the offline forward
    use fqconv::serve::chaos::{chaos_factory, ChaosConfig};
    let kws = Arc::new(FqKwsNet::synthetic(1.0, 7.0, 7).expect("kws net"));
    let dark =
        Arc::new(synthetic_graph(&SynthArch::darknet19(), 1.0, 7.0, 7).expect("darknet19"));
    let mut rng = Rng::new(31);
    let (n_kws, n_dark) = (12usize, 6usize);
    let kws_x: Vec<Vec<f32>> = (0..n_kws)
        .map(|_| {
            let mut v = vec![0f32; 39 * 80];
            rng.fill_gaussian(&mut v, 1.0);
            v
        })
        .collect();
    let dark_x: Vec<Vec<f32>> = (0..n_dark)
        .map(|_| {
            let mut v = vec![0f32; dark.in_numel()];
            rng.fill_gaussian(&mut v, 0.5);
            v
        })
        .collect();
    let mut ks = Scratch::for_graph(kws.graph());
    let kws_want: Vec<Vec<f32>> = kws_x.iter().map(|x| kws.forward(x, &mut ks)).collect();

    let (kid, did) = (ModelId::new("kws"), ModelId::new("darknet19"));
    for workers in [1usize, 2, 4] {
        let registry = ModelRegistry::start(workers);
        registry
            .register(
                "kws",
                ModelSpec::new(
                    NativeBackend::factory(&kws, &[39, 80]),
                    39 * 80,
                    BatchPolicy::new(4, 300),
                )
                .with_cost(kws.cost_per_sample()),
            )
            .expect("register kws");
        let mut cfg = ChaosConfig::new(0xC4A05 + workers as u64)
            .with_failures(250)
            .with_stalls(250, Duration::from_millis(2));
        if workers >= 2 {
            // kill one worker outright; the survivors absorb the load
            cfg = cfg.with_panic_on(workers - 1);
        }
        registry
            .register(
                "darknet19",
                ModelSpec::new(
                    chaos_factory(GraphBackend::factory_sharded(&dark, workers), cfg),
                    dark.in_numel(),
                    BatchPolicy::new(2, 200),
                )
                .with_cost(dark.cost_per_sample()),
            )
            .expect("register darknet19");
        // chaos traffic first so the doomed worker meets it early
        let drx: Vec<_> = dark_x
            .iter()
            .map(|x| {
                registry
                    .submit_with(&did, x.clone(), Priority::Batch, None)
                    .expect("registered")
            })
            .collect();
        let krx: Vec<_> =
            kws_x.iter().map(|x| registry.submit(&kid, x.clone()).expect("registered")).collect();
        for (i, rx) in krx.into_iter().enumerate() {
            let resp = rx
                .recv()
                .expect("healthy-model reply lost to chaos next door")
                .expect("healthy model must keep serving");
            assert_eq!(
                resp.logits, kws_want[i],
                "workers={workers}: kws sample {i} corrupted by chaos next door"
            );
        }
        let (mut dark_served, mut dark_failed) = (0usize, 0usize);
        for rx in drx {
            let reply = rx.recv().unwrap_or_else(|_| {
                panic!("workers={workers}: accepted chaos-model request silently dropped")
            });
            match reply {
                Ok(resp) => {
                    assert_eq!(resp.model.as_str(), "darknet19");
                    dark_served += 1;
                }
                Err(ServeError::BackendFailed { .. }) => dark_failed += 1,
                Err(e) => panic!("workers={workers}: unexpected typed error: {e}"),
            }
        }
        assert_eq!(
            dark_served + dark_failed,
            n_dark,
            "workers={workers}: every accepted request needs a terminal reply"
        );
        let stats = registry.stats();
        let km = stats.models.iter().find(|m| m.id == kid).unwrap();
        assert_eq!(km.served, n_kws as u64);
        assert_eq!(km.pending, 0, "workers={workers}: kws reservations must drain");
        let dm = stats.models.iter().find(|m| m.id == did).unwrap();
        assert_eq!(dm.pending, 0, "workers={workers}: chaos-model reservations must drain");
        registry.shutdown();
    }
}

/// A small synthetic sequence graph for the noisy-ensemble tests:
/// analog Monte-Carlo walks are f64 code-space, so debug-mode tests
/// keep the net small (the full-size architectures run in the
/// release-mode `table7_noise` bench).
fn small_noise_graph() -> Arc<fqconv::infer::QuantGraph> {
    use fqconv::infer::graph::SeqArch;
    let arch = SynthArch::Seq(SeqArch {
        name: "noise-small",
        n_in: 8,
        frames: 40,
        embed_dim: 16,
        classes: 6,
        convs: vec![(16, 3, 1), (16, 3, 2), (16, 3, 4)],
    });
    Arc::new(synthetic_graph(&arch, 1.0, 7.0, 13).expect("synthetic graph"))
}

#[test]
fn noisy_backend_two_run_determinism() {
    // an ensemble reply must be a pure function of (features, spec):
    // per-sample noise streams are derived from the spec seed + the
    // sample's feature bits + the replica index, so batching layout and
    // worker placement cannot change the answer. Two registries with
    // different worker counts / batch policies must agree bit for bit.
    use fqconv::analog::NoiseConfig;
    use fqconv::serve::{NoiseSpec, Vote};
    let graph = small_noise_graph();
    let nspec = NoiseSpec {
        graph: Arc::clone(&graph),
        noise: NoiseConfig { sigma_w: 10.0, sigma_a: 10.0, sigma_mac: 50.0 },
        replicas: 4,
        vote: Vote::MeanLogit,
        seed: 0xD1CE,
    };
    let mut rng = Rng::new(77);
    let xs: Vec<Vec<f32>> = (0..5)
        .map(|_| {
            let mut v = vec![0f32; graph.in_numel()];
            rng.fill_gaussian(&mut v, 0.8);
            v
        })
        .collect();
    let run = |workers: usize, max_batch: usize| -> Vec<Vec<f32>> {
        let registry = ModelRegistry::start(workers);
        registry
            .register(
                "noisy",
                ModelSpec::new(
                    GraphBackend::factory_sharded(&graph, workers),
                    graph.in_numel(),
                    BatchPolicy::new(max_batch, 400),
                )
                .with_cost(graph.cost_per_sample())
                .with_noise(nspec.clone()),
            )
            .expect("register noisy");
        let id = ModelId::new("noisy");
        let rxs: Vec<_> =
            xs.iter().map(|x| registry.submit(&id, x.clone()).expect("registered")).collect();
        let out: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().expect("reply").expect("served").logits)
            .collect();
        registry.shutdown();
        out
    };
    let a = run(1, 1);
    let b = run(2, 4);
    assert_eq!(a, b, "ensemble replies must not depend on workers or batch layout");
}

#[test]
fn noisy_ensemble_size_surfaces_in_stats_and_cost() {
    use fqconv::analog::NoiseConfig;
    use fqconv::serve::{NoiseSpec, Vote};
    let graph = small_noise_graph();
    let nspec = NoiseSpec {
        graph: Arc::clone(&graph),
        noise: NoiseConfig { sigma_w: 5.0, sigma_a: 5.0, sigma_mac: 25.0 },
        replicas: 8,
        vote: Vote::Majority,
        seed: 3,
    };
    let base_cost = graph.cost_per_sample();
    let spec =
        ModelSpec::new(GraphBackend::factory(&graph), graph.in_numel(), BatchPolicy::new(2, 200))
            .with_cost(base_cost)
            .with_noise(nspec);
    assert_eq!(spec.ensemble, 8);
    assert_eq!(spec.cost_per_sample, base_cost * 8, "DWFQ must charge N x the base weight");
    let registry = ModelRegistry::start(1);
    registry.register("noisy", spec).expect("register noisy");
    registry
        .register(
            "plain",
            ModelSpec::new(
                GraphBackend::factory(&graph),
                graph.in_numel(),
                BatchPolicy::new(2, 200),
            ),
        )
        .expect("register plain");
    // one served request so the majority-vote output shape is exercised
    let id = ModelId::new("noisy");
    let mut x = vec![0f32; graph.in_numel()];
    Rng::new(4).fill_gaussian(&mut x, 0.8);
    let resp = registry
        .submit(&id, x)
        .expect("registered")
        .recv()
        .expect("reply")
        .expect("served");
    let votes: f32 = resp.logits.iter().sum();
    assert_eq!(votes, 8.0, "majority logits are vote counts over 8 replicas");
    let stats = registry.stats();
    let noisy = stats.models.iter().find(|m| m.id.as_str() == "noisy").unwrap();
    let plain = stats.models.iter().find(|m| m.id.as_str() == "plain").unwrap();
    assert_eq!(noisy.ensemble, 8, "ensemble size must surface in per-model stats");
    assert_eq!(plain.ensemble, 1, "plain models report a degenerate ensemble of 1");
    registry.shutdown();
}

#[test]
fn noisy_ensemble_exactly_one_terminal_reply_under_chaos() {
    // the acceptance pin: an N=8 Monte-Carlo ensemble behind the chaos
    // harness — chaos wraps *outside* the noisy factory (ModelSpec
    // exposes the composed factory), so injected faults hit the
    // ensemble path itself. Every accepted request must reach exactly
    // one terminal reply: served or typed BackendFailed, never a hang
    // or a disconnect.
    use fqconv::analog::NoiseConfig;
    use fqconv::serve::chaos::{chaos_factory, ChaosConfig};
    use fqconv::serve::{NoiseSpec, Vote};
    let graph = small_noise_graph();
    let nspec = NoiseSpec {
        graph: Arc::clone(&graph),
        noise: NoiseConfig { sigma_w: 10.0, sigma_a: 10.0, sigma_mac: 50.0 },
        replicas: 8,
        vote: Vote::MeanLogit,
        seed: 0xE5EB,
    };
    let mut rng = Rng::new(99);
    let n = 8usize;
    let xs: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0f32; graph.in_numel()];
            rng.fill_gaussian(&mut v, 0.8);
            v
        })
        .collect();
    for workers in [1usize, 2] {
        let mut spec = ModelSpec::new(
            GraphBackend::factory_sharded(&graph, workers),
            graph.in_numel(),
            BatchPolicy::new(2, 200),
        )
        .with_cost(graph.cost_per_sample())
        .with_noise(nspec.clone());
        let cfg = ChaosConfig::new(0xBAD5EED + workers as u64)
            .with_failures(300)
            .with_stalls(300, Duration::from_millis(1));
        spec.factory = chaos_factory(Arc::clone(&spec.factory), cfg);
        let registry = ModelRegistry::start(workers);
        registry.register("noisy", spec).expect("register noisy");
        let id = ModelId::new("noisy");
        let rxs: Vec<_> = xs
            .iter()
            .map(|x| {
                registry.submit_with(&id, x.clone(), Priority::Batch, None).expect("registered")
            })
            .collect();
        let (mut served, mut failed) = (0usize, 0usize);
        for rx in rxs {
            let reply = rx.recv().unwrap_or_else(|_| {
                panic!("workers={workers}: accepted ensemble request silently dropped")
            });
            match reply {
                Ok(resp) => {
                    assert_eq!(resp.logits.len(), graph.classes());
                    served += 1;
                }
                Err(ServeError::BackendFailed { .. }) => failed += 1,
                Err(e) => panic!("workers={workers}: unexpected typed error: {e}"),
            }
        }
        assert_eq!(
            served + failed,
            n,
            "workers={workers}: every accepted ensemble request needs one terminal reply"
        );
        let stats = registry.stats();
        let m = stats.models.iter().find(|m| m.id.as_str() == "noisy").unwrap();
        assert_eq!(m.pending, 0, "workers={workers}: reservations must drain");
        assert_eq!(m.ensemble, 8);
        registry.shutdown();
    }
}
