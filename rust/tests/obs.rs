//! Observability layer: a seeded chaos run is fully reconstructable
//! from its traces, per-stage timing names every stage, a fake clock
//! makes traces deterministic, exposition covers every subsystem, and
//! stats stay consistent (and panic-free) under registry churn.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use fqconv::infer::graph::{synthetic_graph, Scratch, SynthArch};
use fqconv::obs::{EventKind, FakeClock, ObsConfig, TraceEvent};
use fqconv::serve::chaos::{chaos_factory, ChaosConfig};
use fqconv::serve::{
    ready, AdmissionPolicy, Backend, BatchPolicy, GraphBackend, ModelId, ModelRegistry,
    ModelSpec, Priority, ServeError, Server,
};
use fqconv::util::Rng;

/// Deterministic echo backend: logit 0 carries the first feature.
struct EchoBackend {
    shape: Vec<usize>,
}

impl Backend for EchoBackend {
    fn infer_into(&mut self, x: &[f32], batch: usize, out: &mut [f32]) -> anyhow::Result<()> {
        let per = x.len() / batch.max(1);
        out.fill(0.0);
        for i in 0..batch {
            out[i * 2] = x[i * per];
        }
        Ok(())
    }

    fn sample_shape(&self) -> &[usize] {
        &self.shape
    }

    fn out_dim(&self) -> usize {
        2
    }
}

fn echo_factory() -> fqconv::serve::BackendFactory {
    ready(|| EchoBackend { shape: vec![4] })
}

/// Group a post-quiescence event log by trace id (0 = not request-tied).
fn by_trace(events: &[TraceEvent]) -> HashMap<u64, Vec<TraceEvent>> {
    let mut m: HashMap<u64, Vec<TraceEvent>> = HashMap::new();
    for e in events {
        if e.trace != 0 {
            m.entry(e.trace).or_default().push(*e);
        }
    }
    m
}

#[test]
fn chaos_run_is_fully_reconstructable_from_traces() {
    // the acceptance pin: a seeded ChaosBackend run (transient failures,
    // stalls, and at >=2 workers one worker panicking outright) leaves a
    // trace log from which every accepted request's path can be
    // reconstructed — exactly one Submit, only legal intermediate hops,
    // and exactly one terminal reply that matches what the client saw
    let arch = SynthArch::darknet19();
    let dark = Arc::new(synthetic_graph(&arch, 1.0, 7.0, 7).expect("darknet19"));
    let mut rng = Rng::new(41);
    let n = 12usize;
    let xs: Vec<Vec<f32>> = (0..n)
        .map(|_| {
            let mut v = vec![0f32; dark.in_numel()];
            rng.fill_gaussian(&mut v, 0.5);
            v
        })
        .collect();
    for workers in [1usize, 2, 4] {
        let cfg = ObsConfig::default().with_trace_capacity(16_384);
        let registry = ModelRegistry::start_with_obs(workers, cfg);
        let mut chaos = ChaosConfig::new(0x0B5 + workers as u64)
            .with_failures(250)
            .with_stalls(250, Duration::from_millis(2));
        if workers >= 2 {
            chaos = chaos.with_panic_on(workers - 1);
        }
        registry
            .register(
                "darknet19",
                ModelSpec::new(
                    chaos_factory(GraphBackend::factory_sharded(&dark, workers), chaos),
                    dark.in_numel(),
                    BatchPolicy::new(2, 200),
                )
                .with_cost(dark.cost_per_sample())
                .with_observed_graph(&dark),
            )
            .expect("register darknet19");
        let did = ModelId::new("darknet19");
        let mut rxs = Vec::new();
        for x in &xs {
            let rx = registry.submit_with(&did, x.clone(), Priority::Batch, None);
            rxs.push(rx.expect("registered"));
        }
        let (mut served, mut failed) = (0u64, 0u64);
        for rx in rxs {
            match rx.recv().expect("accepted requests reach a terminal reply") {
                Ok(_) => served += 1,
                Err(ServeError::BackendFailed { .. }) => failed += 1,
                Err(e) => panic!("workers={workers}: unexpected typed error: {e}"),
            }
        }
        let (recorded, dropped) = registry.trace_counts();
        assert!(recorded > 0, "workers={workers}: the run must have traced");
        assert_eq!(dropped, 0, "workers={workers}: a sized ring must retain every event");
        let events = registry.shutdown_with_traces();
        let traces = by_trace(&events);
        assert_eq!(traces.len(), n, "workers={workers}: one trace per accepted request");
        let (mut t_served, mut t_failed) = (0u64, 0u64);
        for (id, t) in &traces {
            let submits = t.iter().filter(|e| e.kind == EventKind::Submit).count();
            assert_eq!(submits, 1, "trace {id}: exactly one submit: {t:?}");
            let terminals: Vec<_> = t.iter().filter(|e| e.kind.is_terminal()).collect();
            assert_eq!(terminals.len(), 1, "trace {id}: exactly one terminal: {t:?}");
            assert!(
                !t.iter().any(|e| e.kind == EventKind::Shed),
                "trace {id}: unbounded admission cannot shed: {t:?}"
            );
            for e in t {
                let legal = e.kind.is_terminal()
                    || matches!(
                        e.kind,
                        EventKind::Submit
                            | EventKind::Enqueue
                            | EventKind::Dispatch
                            | EventKind::Requeue
                    );
                assert!(legal, "trace {id}: illegal hop for a batch request: {e:?}");
            }
            match terminals[0].kind {
                EventKind::Served => {
                    t_served += 1;
                    assert!(
                        t.iter().any(|e| e.kind == EventKind::Dispatch),
                        "trace {id}: served without a dispatch: {t:?}"
                    );
                }
                EventKind::Failed => t_failed += 1,
                k => panic!("trace {id}: batch requests cannot end in {k:?}"),
            }
        }
        assert_eq!(
            (t_served, t_failed),
            (served, failed),
            "workers={workers}: trace terminals must match the client-observed replies"
        );
    }
}

#[test]
fn stage_timing_names_every_stage_of_resnet32_and_darknet19() {
    for arch in [SynthArch::resnet32(), SynthArch::darknet19()] {
        let g = synthetic_graph(&arch, 1.0, 7.0, 7).expect("synthetic graph");
        assert!(g.stage_times().iter().all(|st| st.calls == 0), "fresh graph has run nothing");
        assert!(g.measured_us_per_sample().is_none(), "no samples measured yet");
        let mut s = Scratch::for_graph(&g);
        let mut rng = Rng::new(9);
        let mut x = vec![0f32; g.in_numel()];
        rng.fill_gaussian(&mut x, 0.5);
        let _ = g.forward(&x, &mut s);
        let _ = g.forward(&x, &mut s);
        let times = g.stage_times();
        assert_eq!(times.len(), g.stages().len(), "every stage appears in the snapshot");
        for (i, st) in times.iter().enumerate() {
            assert_eq!(st.index, i);
            assert_eq!(st.kind, g.stages()[i].kind(), "snapshot names the stage");
            assert!(!st.kind.is_empty());
            assert_eq!(st.calls, 2, "stage {i} ({}) runs once per forward", st.kind);
        }
        let kinds: Vec<&str> = times.iter().map(|st| st.kind).collect();
        assert!(
            kinds.contains(&"GlobalAvgPool") && kinds.contains(&"DenseHead"),
            "structural stages missing from {kinds:?}"
        );
        let us = g.measured_us_per_sample().expect("two samples measured");
        assert!(us >= 1, "measured cost is clamped to at least 1us/sample");
    }
}

#[test]
fn fake_clock_makes_traces_deterministic() {
    let run = || {
        let clock = Arc::new(FakeClock::new(7_000));
        let cfg = ObsConfig::default().with_clock(clock.clone());
        let registry = ModelRegistry::start_with_obs(1, cfg);
        let spec = ModelSpec::new(echo_factory(), 4, BatchPolicy::new(1, 100));
        registry.register("echo", spec).expect("register echo");
        let id = ModelId::new("echo");
        for i in 0..5u64 {
            // each blocking infer completes while the fake time is
            // frozen, so its whole path shares one deterministic stamp
            clock.advance(1_000);
            registry.infer(&id, vec![i as f32, 0.0, 0.0, 0.0]).expect("served");
        }
        registry
            .shutdown_with_traces()
            .into_iter()
            .filter(|e| e.trace != 0)
            .map(|e| (e.trace, e.t_ns, e.kind))
            .collect::<Vec<_>>()
    };
    let (a, b) = (run(), run());
    assert!(!a.is_empty());
    assert_eq!(a, b, "identical workloads on a fake clock must trace identically");
    for &(trace, t_ns, _) in &a {
        assert!(t_ns >= 8_000 && t_ns % 1_000 == 0, "trace {trace}: stamp {t_ns} off the grid");
    }
}

#[test]
fn exposition_covers_counters_stages_queues_and_traces() {
    let g = Arc::new(synthetic_graph(&SynthArch::kws(), 1.0, 7.0, 7).expect("kws graph"));
    let spec = ModelSpec::new(
        GraphBackend::factory_sharded(&g, 2),
        g.in_numel(),
        BatchPolicy::new(4, 200),
    )
    .with_cost(g.cost_per_sample())
    .with_observed_graph(&g);
    let server = Server::start_spec_obs(spec, 2, ObsConfig::default());
    let mut rng = Rng::new(3);
    let rxs: Vec<_> = (0..8)
        .map(|_| {
            let mut x = vec![0f32; g.in_numel()];
            rng.fill_gaussian(&mut x, 1.0);
            server.submit(x)
        })
        .collect();
    for rx in rxs {
        rx.recv().expect("reply").expect("served");
    }
    let text = server.metrics_text();
    for needle in [
        "# TYPE fqconv_served_total counter",
        "fqconv_served_total{model=\"default\"} 8",
        "fqconv_shed_total{reason=\"overload\"} 0",
        "fqconv_latency_count{model=\"default\"} 8",
        "fqconv_stage_us_total{model=\"default\",index=\"0\"",
        "fqconv_stage_calls_total{model=\"default\"",
        "fqconv_measured_us_per_sample{model=\"default\"}",
        "fqconv_replica_budget{model=\"default\"}",
        "fqconv_workers_alive 2",
        "fqconv_trace_events_total",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in exposition:\n{text}");
    }
    // every stage of the observed graph is named in the exposition
    for st in g.stage_times() {
        let line = format!(
            "fqconv_stage_calls_total{{model=\"default\",index=\"{}\",stage=\"{}\"}}",
            st.index, st.kind
        );
        assert!(text.contains(&line), "stage missing from exposition: {line}\n{text}");
        assert!(st.calls >= 8, "stage {} must have timed the served samples", st.index);
    }
    let json = server.metrics_json();
    assert!(json.contains("\"fqconv_served_total\""), "{json}");
    assert!(json.contains("\"counter\"") && json.contains("\"histogram\""), "{json}");
    server.shutdown();
}

#[test]
fn stats_stay_consistent_and_panic_free_under_churn() {
    // concurrent register/evict churn + bounded submits + metrics
    // scrapes: nothing may panic, and the post-quiescence accounting
    // for the stable model must balance exactly
    let cfg = ObsConfig::default().with_trace_capacity(1 << 15);
    let registry = ModelRegistry::start_with_obs(2, cfg);
    let spec = ModelSpec::new(echo_factory(), 4, BatchPolicy::new(2, 100))
        .with_admission(AdmissionPolicy::bounded(8));
    registry.register("stable", spec).expect("register stable");
    let stable = ModelId::new("stable");
    let churn = ModelId::new("churn");
    let accepted = Arc::new(AtomicU64::new(0));
    let shed = Arc::new(AtomicU64::new(0));
    std::thread::scope(|s| {
        let reg = &registry;
        let (stable, churn) = (&stable, &churn);
        s.spawn(move || {
            for _round in 0..20 {
                let spec = ModelSpec::new(echo_factory(), 4, BatchPolicy::new(2, 100));
                reg.register("churn", spec).expect("churn id was evicted last round");
                std::thread::sleep(Duration::from_micros(200));
                assert!(reg.evict(churn), "evicting the generation just registered");
            }
        });
        for _t in 0..2 {
            let (acc, sh) = (Arc::clone(&accepted), Arc::clone(&shed));
            s.spawn(move || {
                for i in 0..150u64 {
                    match reg.submit(stable, vec![i as f32, 0.0, 0.0, 0.0]) {
                        Ok(rx) => {
                            acc.fetch_add(1, Ordering::SeqCst);
                            rx.recv().expect("terminal reply").expect("echo never fails");
                        }
                        Err(ServeError::Overloaded { .. }) => {
                            sh.fetch_add(1, Ordering::SeqCst);
                        }
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                    // churn-model traffic rides along; any terminal
                    // outcome (served / typed miss) is acceptable
                    match reg.submit(churn, vec![i as f32, 0.0, 0.0, 0.0]) {
                        Ok(rx) => {
                            let _ = rx.recv().expect("accepted churn requests are answered");
                        }
                        Err(ServeError::UnknownModel(_)) => {}
                        Err(ServeError::Overloaded { .. }) => {}
                        Err(e) => panic!("unexpected churn submit error: {e}"),
                    }
                }
            });
        }
        s.spawn(move || {
            for _ in 0..50 {
                let text = reg.metrics_text();
                assert!(text.contains("fqconv_served_total"), "scrape lost the registry");
                let _ = reg.metrics_json();
                let _ = reg.trace_snapshot();
                let _ = reg.stats();
                std::thread::sleep(Duration::from_micros(100));
            }
        });
    });
    // post-quiescence: client-side accounting matches the exposition
    let text = registry.metrics_text();
    let acc = accepted.load(Ordering::SeqCst);
    let served_line = format!("fqconv_served_total{{model=\"stable\"}} {acc}");
    assert!(text.contains(&served_line), "missing {served_line:?} in:\n{text}");
    let shed_line =
        format!("fqconv_model_shed_total{{model=\"stable\"}} {}", shed.load(Ordering::SeqCst));
    assert!(text.contains(&shed_line), "missing {shed_line:?} in:\n{text}");
    for lane in 0..2 {
        let drained = format!("fqconv_pending{{model=\"stable\",lane=\"{lane}\"}} 0");
        assert!(text.contains(&drained), "missing {drained:?} in:\n{text}");
    }
    registry.shutdown();
}
