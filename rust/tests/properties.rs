//! Randomized property tests over the crate's core invariants
//! (custom helper in util::proptest — no proptest crate offline).

use fqconv::quant::{learned_quantize, n_levels, AddLut, QParams, RequantLut};
use fqconv::serve::batcher::{
    simulate, simulate_prio, simulate_prio_bounded, BatchPolicy, Priority, SimOutcome, SimRequest,
};
use fqconv::util::proptest::check;
use fqconv::util::Rng;

#[test]
fn quantizer_idempotent() {
    check(
        "quantizer-idempotent",
        200,
        |g, _| {
            let es = g.f32_in(0.05, 5.0);
            let nb = *g.choice(&[2u32, 3, 4, 5, 8]);
            let b = *g.choice(&[-1.0f32, 0.0]);
            let x = g.f32_in(-10.0, 10.0);
            (x, es, nb, b)
        },
        |&(x, es, nb, b)| {
            let n = n_levels(nb) as f32;
            let q1 = learned_quantize(x, es, n, b);
            let q2 = learned_quantize(q1, es, n, b);
            if (q1 - q2).abs() < 1e-5 {
                Ok(())
            } else {
                Err(format!("Q(Q(x)) != Q(x): {q1} vs {q2}"))
            }
        },
    );
}

#[test]
fn quantizer_monotone_and_bounded() {
    check(
        "quantizer-monotone-bounded",
        100,
        |g, _| {
            let es = g.f32_in(0.05, 5.0);
            let nb = *g.choice(&[2u32, 3, 4, 8]);
            let b = *g.choice(&[-1.0f32, 0.0]);
            let xs = g.vec_gaussian(50, 3.0);
            (xs, es, nb, b)
        },
        |(xs, es, nb, b)| {
            let n = n_levels(*nb) as f32;
            let mut sorted = xs.clone();
            sorted.sort_by(|a, c| a.total_cmp(c));
            let qs: Vec<f32> =
                sorted.iter().map(|&x| learned_quantize(x, *es, n, *b)).collect();
            for w in qs.windows(2) {
                if w[1] < w[0] - 1e-6 {
                    return Err(format!("not monotone: {} then {}", w[0], w[1]));
                }
            }
            for &q in &qs {
                if q < *b * *es - 1e-5 || q > *es + 1e-5 {
                    return Err(format!("out of range: {q} (es={es}, b={b})"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn quantizer_error_bounded_by_half_lsb_inside() {
    check(
        "quantizer-half-lsb",
        150,
        |g, _| {
            let es = g.f32_in(0.1, 3.0);
            let nb = *g.choice(&[3u32, 4, 5, 8]);
            // x strictly inside the clip range
            let x = g.f32_in(-0.99, 0.99);
            (x, es, nb)
        },
        |&(x, es, nb)| {
            let q = QParams::new(es, n_levels(nb) as f32, -1.0);
            let err = (q.quantize(x * es) - x * es).abs();
            if err <= q.lsb() / 2.0 + 1e-5 {
                Ok(())
            } else {
                Err(format!("err {err} > lsb/2 {}", q.lsb() / 2.0))
            }
        },
    );
}

#[test]
fn lut_agrees_with_float_reference_everywhere() {
    check(
        "lut-exact",
        40,
        |g, size| {
            let f = g.f32_in(0.0005, 0.05);
            let es = g.f32_in(0.2, 3.0);
            let nb = *g.choice(&[2u32, 3, 4, 5]);
            let b = *g.choice(&[-1.0f32, 0.0]);
            let range = g.sized_usize(size, 3000) as i64 + 50;
            (f, es, nb, b, range)
        },
        |&(f, es, nb, b, range)| {
            let out = QParams::new(es, n_levels(nb) as f32, b);
            let lut = RequantLut::build(f, out, -range, range);
            // probe every accumulator value in range
            for acc in -range..=range {
                let want = RequantLut::reference_code(acc, f, &out);
                let got = lut.apply(acc);
                if got != want {
                    return Err(format!("acc={acc}: lut={got} ref={want}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dense_requant_table_matches_reference_exactly() {
    // random (f, QParams, acc range): the branchless direct-index table
    // must reproduce the float reference for EVERY in-range accumulator
    // (including ties-to-even edges — the sweep probes each value), and
    // must agree with the threshold-search fallback everywhere.
    check(
        "requant-dense-table",
        40,
        |g, size| {
            let f = g.f32_in(0.0005, 0.05);
            let es = g.f32_in(0.2, 3.0);
            let nb = *g.choice(&[2u32, 3, 4, 5, 8]);
            let b = *g.choice(&[-1.0f32, 0.0]);
            let range = g.sized_usize(size, 4000) as i64 + 50;
            (f, es, nb, b, range)
        },
        |&(f, es, nb, b, range)| {
            let out = QParams::new(es, n_levels(nb) as f32, b);
            let lut = RequantLut::build(f, out, -range, range);
            if !lut.is_dense() {
                return Err(format!("range {range} small enough but no dense table"));
            }
            for acc in -range..=range {
                let want = RequantLut::reference_code(acc, f, &out);
                let got = lut.apply(acc);
                if got != want {
                    return Err(format!("acc={acc}: dense={got} ref={want}"));
                }
                let search = lut.apply_search(acc);
                if search != got {
                    return Err(format!("acc={acc}: dense={got} thresholds={search}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dense_composed_table_matches_double_rounding_exactly() {
    check(
        "requant-dense-composed",
        25,
        |g, size| {
            let f = g.f32_in(0.001, 0.05);
            let es1 = g.f32_in(0.3, 2.0);
            let es2 = g.f32_in(0.3, 2.0);
            let n = n_levels(*g.choice(&[3u32, 4])) as f32;
            let range = g.sized_usize(size, 2500) as i64 + 50;
            (f, es1, es2, n, range)
        },
        |&(f, es1, es2, n, range)| {
            let mid = QParams::new(es1, n, 0.0);
            let next = QParams::new(es2, n, 0.0);
            let lut = RequantLut::build_composed(f, mid, next, -range, range);
            if !lut.is_dense() {
                return Err("expected dense table".into());
            }
            for acc in -range..=range {
                let want = RequantLut::reference_code_composed(acc, f, &mid, &next);
                if lut.apply(acc) != want {
                    return Err(format!("acc={acc}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn composed_lut_matches_double_rounding() {
    check(
        "lut-composed",
        25,
        |g, size| {
            let f = g.f32_in(0.001, 0.05);
            let es1 = g.f32_in(0.3, 2.0);
            let es2 = g.f32_in(0.3, 2.0);
            let n = n_levels(*g.choice(&[3u32, 4])) as f32;
            let range = g.sized_usize(size, 2000) as i64 + 50;
            (f, es1, es2, n, range)
        },
        |&(f, es1, es2, n, range)| {
            let mid = QParams::new(es1, n, 0.0);
            let next = QParams::new(es2, n, 0.0);
            let lut = RequantLut::build_composed(f, mid, next, -range, range);
            for acc in (-range..=range).step_by(7) {
                let want = RequantLut::reference_code_composed(acc, f, &mid, &next);
                if lut.apply(acc) != want {
                    return Err(format!("acc={acc}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn batcher_never_starves() {
    check(
        "batcher-no-starvation",
        60,
        |g, size| {
            let max_batch = 1 + g.rng.below(16);
            let max_wait = 100 + g.rng.below(5000) as u64;
            let n = g.sized_usize(size, 200);
            let mut t = 0u64;
            let arrivals: Vec<u64> = (0..n)
                .map(|_| {
                    t += g.rng.below(800) as u64;
                    t
                })
                .collect();
            let service = 50 + g.rng.below(500) as u64;
            (BatchPolicy::new(max_batch, max_wait), arrivals, service)
        },
        |(policy, arrivals, service)| {
            let res = simulate(*policy, arrivals, *service);
            // worst admissible wait: own deadline + the backlog of every
            // earlier batch's service time (single worker)
            let n_batches = res.iter().map(|&(s, _)| s).collect::<std::collections::BTreeSet<_>>().len();
            let worst = policy.max_wait_us + *service * n_batches as u64;
            for (k, &(start, size)) in res.iter().enumerate() {
                if size == 0 {
                    return Err(format!("request {k} never dispatched"));
                }
                if size > policy.max_batch {
                    return Err(format!("batch size {size} > max {}", policy.max_batch));
                }
                if start.saturating_sub(arrivals[k]) > worst {
                    return Err(format!(
                        "request {k} waited {} > {worst}",
                        start - arrivals[k]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// Random mixed-priority workload generator shared by the batcher
/// properties: sorted arrivals, random class, random optional deadline.
fn gen_mixed_requests(
    g: &mut fqconv::util::proptest::Gen,
    size: f64,
    with_deadlines: bool,
) -> (BatchPolicy, Vec<SimRequest>, u64) {
    let max_batch = 1 + g.rng.below(8);
    let max_wait = 100 + g.rng.below(3000) as u64;
    let n = 2 + g.sized_usize(size, 120);
    let mut t = 0u64;
    let reqs: Vec<SimRequest> = (0..n)
        .map(|_| {
            t += g.rng.below(600) as u64;
            let priority =
                if g.rng.below(2) == 0 { Priority::Interactive } else { Priority::Batch };
            let deadline_us = if with_deadlines && g.rng.below(3) == 0 {
                Some(t + g.rng.below(4000) as u64)
            } else {
                None
            };
            SimRequest { arrival_us: t, priority, deadline_us }
        })
        .collect();
    let service = 50 + g.rng.below(800) as u64;
    (BatchPolicy::new(max_batch, max_wait), reqs, service)
}

#[test]
fn batcher_priority_ordering_invariant() {
    // queue invariant: an Interactive batch never waits behind a
    // Batch-priority batch it was already closed before. For every
    // Batch-priority dispatch at start S, no Interactive request whose
    // batch closed at or before S may start after S.
    check(
        "batcher-priority-ordering",
        60,
        |g, size| gen_mixed_requests(g, size, false),
        |(policy, reqs, service)| {
            let out = simulate_prio(*policy, reqs, *service);
            let closed = |o: &SimOutcome| match *o {
                SimOutcome::Dispatched { closed_us, .. } => closed_us,
                SimOutcome::Expired { .. } => unreachable!("no deadlines here"),
                SimOutcome::Shed { .. } => unreachable!("no admission bound here"),
            };
            for (j, oj) in out.iter().enumerate() {
                if reqs[j].priority != Priority::Batch {
                    continue;
                }
                let sj = oj.start_us().unwrap();
                for (i, oi) in out.iter().enumerate() {
                    if reqs[i].priority != Priority::Interactive {
                        continue;
                    }
                    let si = oi.start_us().unwrap();
                    if closed(oi) <= sj && si > sj {
                        return Err(format!(
                            "interactive req {i} (closed {}, start {si}) waited behind \
                             batch-priority req {j} (start {sj})",
                            closed(oi)
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn batcher_deadline_rejection_invariant() {
    // every request is answered exactly once: dispatched no later than
    // its deadline, or expired — and expiry only happens when the batch
    // start really lay beyond the deadline. No silent losses either way.
    check(
        "batcher-deadline-rejection",
        60,
        |g, size| gen_mixed_requests(g, size, true),
        |(policy, reqs, service)| {
            let out = simulate_prio(*policy, reqs, *service);
            if out.len() != reqs.len() {
                return Err("outcome count mismatch".into());
            }
            for (k, o) in out.iter().enumerate() {
                match *o {
                    SimOutcome::Dispatched { start_us, batch, closed_us } => {
                        if batch == 0 || batch > policy.max_batch {
                            return Err(format!("req {k}: bad batch size {batch}"));
                        }
                        if start_us < reqs[k].arrival_us || closed_us < reqs[k].arrival_us {
                            return Err(format!("req {k}: dispatched before it arrived"));
                        }
                        if let Some(d) = reqs[k].deadline_us {
                            if start_us > d {
                                return Err(format!(
                                    "req {k}: started at {start_us} past its deadline {d}"
                                ));
                            }
                        }
                    }
                    SimOutcome::Expired { at_us } => {
                        let d = reqs[k]
                            .deadline_us
                            .ok_or_else(|| format!("req {k}: expired without a deadline"))?;
                        if at_us <= d {
                            return Err(format!(
                                "req {k}: expired at {at_us} although deadline {d} had not \
                                 passed"
                            ));
                        }
                    }
                    SimOutcome::Shed { .. } => {
                        return Err(format!("req {k}: shed without an admission bound"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn batcher_early_expiry_is_prompt() {
    // with zero service time a batch starts the instant it closes, so a
    // deadlined request either rides (its deadline reached the close)
    // or was doomed *while forming* — and early expiry must answer it
    // exactly at its deadline wake, max(d + 1, arrival), never holding
    // it until dispatch
    check(
        "batcher-early-expiry",
        60,
        |g, size| gen_mixed_requests(g, size, true),
        |(policy, reqs, _service)| {
            let out = simulate_prio(*policy, reqs, 0);
            for (k, o) in out.iter().enumerate() {
                match *o {
                    SimOutcome::Expired { at_us } => {
                        let d = reqs[k]
                            .deadline_us
                            .ok_or_else(|| format!("req {k}: expired without a deadline"))?;
                        let want = (d + 1).max(reqs[k].arrival_us);
                        if at_us != want {
                            return Err(format!(
                                "req {k}: expired at {at_us}, early expiry demands {want} \
                                 (deadline {d}, arrival {})",
                                reqs[k].arrival_us
                            ));
                        }
                    }
                    SimOutcome::Dispatched { start_us, .. } => {
                        if let Some(d) = reqs[k].deadline_us {
                            if start_us > d {
                                return Err(format!("req {k}: rode past its deadline"));
                            }
                        }
                    }
                    SimOutcome::Shed { .. } => {
                        return Err(format!("req {k}: shed without an admission bound"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn batcher_bounded_admission_invariant() {
    // admission-control invariants (mirrors the registry's reservation
    // protocol): with a per-lane bound of B, no lane ever holds more
    // than B pending admitted requests — a request holds its slot from
    // arrival to its terminal reply (service end or expiry) — and every
    // shed is answered at its own arrival instant (submit time), never
    // deferred to a deadline
    check(
        "batcher-bounded-admission",
        60,
        |g, size| {
            let (policy, reqs, service) = gen_mixed_requests(g, size, true);
            let bound = 1 + g.rng.below(4);
            (policy, reqs, service, bound)
        },
        |(policy, reqs, service, bound)| {
            let out = simulate_prio_bounded(*policy, Some(*bound), reqs, *service);
            if out.len() != reqs.len() {
                return Err("outcome count mismatch".into());
            }
            let depart: Vec<u64> = out
                .iter()
                .map(|o| match *o {
                    SimOutcome::Dispatched { start_us, .. } => start_us + *service,
                    SimOutcome::Expired { at_us } | SimOutcome::Shed { at_us } => at_us,
                })
                .collect();
            for (k, o) in out.iter().enumerate() {
                if let SimOutcome::Shed { at_us } = *o {
                    if at_us != reqs[k].arrival_us {
                        return Err(format!(
                            "req {k}: shed at {at_us}, not at its arrival {}",
                            reqs[k].arrival_us
                        ));
                    }
                    continue;
                }
                // admitted: its lane may not already be at the bound
                let lane = reqs[k].priority.index();
                let held = (0..k)
                    .filter(|&j| {
                        !matches!(out[j], SimOutcome::Shed { .. })
                            && reqs[j].priority.index() == lane
                            && depart[j] > reqs[k].arrival_us
                    })
                    .count();
                if held >= *bound {
                    return Err(format!(
                        "req {k}: admitted into a lane already holding {held} >= bound {bound}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn residual_add_lut_matches_float_reference_on_random_grids() {
    // the integer residual join (AddLut over the body/shortcut/output
    // grids) must reproduce the float path — dequantize both addends,
    // add, re-quantize onto the consumer grid — exactly, for every
    // representable code pair, across random scale/level combinations
    check(
        "residual-addlut-scale-matching",
        60,
        |g, _| {
            let ea = g.f32_in(0.2, 3.0);
            let eb = g.f32_in(0.2, 3.0);
            let eo = g.f32_in(0.2, 3.0);
            let na = n_levels(*g.choice(&[2u32, 3, 4, 5])) as f32;
            let nb = n_levels(*g.choice(&[2u32, 3, 4, 5])) as f32;
            let no = n_levels(*g.choice(&[3u32, 4, 5])) as f32;
            let ba = *g.choice(&[-1.0f32, 0.0]);
            let bb = *g.choice(&[-1.0f32, 0.0]);
            (ea, eb, eo, na, nb, no, ba, bb)
        },
        |&(ea, eb, eo, na, nb, no, ba, bb)| {
            let a = QParams::new(ea, na, ba);
            let b = QParams::new(eb, nb, bb);
            let out = QParams::new(eo, no, 0.0);
            let lut = AddLut::build(a, b, out);
            let (a_min, a_max) = a.code_range();
            let (b_min, b_max) = b.code_range();
            if lut.len() != ((a_max - a_min + 1) * (b_max - b_min + 1)) as usize {
                return Err(format!("table covers {} pairs", lut.len()));
            }
            for ca in a_min..=a_max {
                for cb in b_min..=b_max {
                    let got = lut.apply(ca as i8, cb as i8) as i32;
                    let want = AddLut::reference_code(ca, cb, &a, &b, &out);
                    if got != want {
                        return Err(format!("pair ({ca},{cb}): lut={got} float={want}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn checkpoint_roundtrip_random() {
    use fqconv::coordinator::checkpoint::{parse, write, Checkpoint};
    use fqconv::tensor::TensorF;
    check(
        "checkpoint-roundtrip",
        30,
        |g, size| {
            let n_tensors = g.sized_usize(size, 12);
            let mut tensors = Vec::new();
            for i in 0..n_tensors {
                let ndim = g.rng.below(4);
                let shape: Vec<usize> = (0..ndim).map(|_| 1 + g.rng.below(6)).collect();
                let numel: usize = shape.iter().product();
                tensors.push((format!("t{i}.w"), TensorF::from_vec(&shape, g.vec_gaussian(numel, 2.0))));
            }
            tensors
        },
        |tensors| {
            let ck = Checkpoint::new(tensors.clone());
            let path = std::env::temp_dir().join(format!(
                "fqconv_prop_{}.ckpt",
                std::process::id()
            ));
            write(&path, &ck).map_err(|e| e.to_string())?;
            let bytes = std::fs::read(&path).map_err(|e| e.to_string())?;
            let ck2 = parse(&bytes).map_err(|e| e.to_string())?;
            if ck2.len() != ck.len() {
                return Err("tensor count changed".into());
            }
            for (name, t) in tensors {
                let t2 = ck2.get(name).ok_or_else(|| format!("lost {name}"))?;
                if t2.shape() != t.shape() || t2.data() != t.data() {
                    return Err(format!("tensor {name} corrupted"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn rust_quantizer_matches_paper_levels() {
    // spot invariant: code count = 2n+1 for signed, n+1 for relu
    check(
        "code-count",
        50,
        |g, _| (*g.choice(&[2u32, 3, 4, 5, 8]), *g.choice(&[-1.0f32, 0.0])),
        |&(nb, b)| {
            let n = n_levels(nb) as f32;
            let q = QParams::new(1.0, n, b);
            let mut seen = std::collections::BTreeSet::new();
            let mut x = -2.0f32;
            while x <= 2.0 {
                seen.insert(q.int_code(x));
                x += 0.001;
            }
            let expect = if b < 0.0 { 2 * n as usize + 1 } else { n as usize + 1 };
            if seen.len() == expect {
                Ok(())
            } else {
                Err(format!("nb={nb} b={b}: {} codes, expected {expect}", seen.len()))
            }
        },
    );
}

#[test]
fn rng_streams_independent() {
    check(
        "rng-fork-independence",
        20,
        |g, _| g.rng.next_u64(),
        |&seed| {
            let mut base = Rng::new(seed);
            let mut a = base.fork(1);
            let mut b = base.fork(2);
            let xs: Vec<u64> = (0..32).map(|_| a.next_u64()).collect();
            let ys: Vec<u64> = (0..32).map(|_| b.next_u64()).collect();
            let same = xs.iter().zip(&ys).filter(|(x, y)| x == y).count();
            if same < 4 {
                Ok(())
            } else {
                Err(format!("{same} collisions between forked streams"))
            }
        },
    );
}
