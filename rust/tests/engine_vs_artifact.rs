//! The CORE deployment-correctness signal: the native integer engine
//! must agree with the XLA deployment artifact (`kws_fq_fwd`, Pallas
//! fused kernel) on the same parameters and inputs.
//!
//! Tiny float-associativity differences in the FP embedding can flip a
//! code at a bin boundary, so agreement is asserted as: logits close
//! (atol) and argmax identical on (nearly) all samples.

use fqconv::coordinator::{checkpoint, fq_transform, Trainer, Variant};
use fqconv::data::{self, Dataset as _};
use fqconv::infer::FqKwsNet;
use fqconv::runtime::{hp, lit_f32, lit_to_vec_f32};
use fqconv::tensor::TensorF;
use fqconv::util::Rng;

mod common;
use common::setup;

#[test]
fn integer_engine_matches_xla_artifact() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("kws").unwrap();

    // get realistic FQ parameters: briefly train QAT, then transform
    let mut t = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
    t.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let mut rng = Rng::new(9);
    let mut hpv = hp::defaults();
    hpv[hp::LR] = 0.005;
    hpv[hp::NW] = 1.0;
    hpv[hp::NA] = 7.0;
    for step in 0..12 {
        let batch = ds.train_batch(info.batch, &mut rng);
        hpv[hp::SEED] = step as f32;
        t.step(&batch, None, &hpv).unwrap();
    }
    let fq_graph = info.fq.clone().unwrap();
    let fq_params = fq_transform::qat_to_fq(info, &fq_graph, &t.params).unwrap();

    // native integer engine
    let net = FqKwsNet::from_params(&fq_params, 1.0, 7.0, info.input_shape[1]).unwrap();

    // XLA deployment artifact on the same params
    let exe = engine.load(&info.artifact_path(&manifest.dir, "fq_fwd").unwrap()).unwrap();
    let batch = ds.val_batch(0, info.batch);
    let mut inputs = Vec::new();
    for (spec, v) in fq_params.specs.iter().zip(&fq_params.values) {
        inputs.push(lit_f32(&spec.shape, v.data()));
    }
    inputs.push(lit_f32(batch.x.shape(), batch.x.data()));
    let mut fhp = hp::defaults();
    fhp[hp::NW] = 1.0;
    fhp[hp::NA] = 7.0;
    inputs.push(lit_f32(&[hp::LEN], &fhp));
    let outs = exe.run(&inputs).unwrap();
    let xla_logits =
        TensorF::from_vec(&[info.batch, info.num_classes], lit_to_vec_f32(&outs[0]).unwrap());

    let native_logits = net.forward_batch(&batch.x);

    // max logit deviation + argmax agreement
    let mut max_dev = 0f32;
    for (a, b) in xla_logits.data().iter().zip(native_logits.data()) {
        max_dev = max_dev.max((a - b).abs());
    }
    let agree = xla_logits
        .argmax_rows()
        .iter()
        .zip(native_logits.argmax_rows())
        .filter(|(&a, b)| a == *b)
        .count();
    assert!(
        max_dev < 0.05,
        "native vs XLA logits deviate too much: {max_dev} (codes drifting?)"
    );
    assert!(
        agree >= info.batch - 1,
        "argmax disagreement on {} of {} samples",
        info.batch - agree,
        info.batch
    );
}

#[test]
fn ternary_layers_use_addonly_path() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("kws").unwrap();
    let mut t = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
    t.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();
    let fq_graph = info.fq.clone().unwrap();
    let fq_params = fq_transform::qat_to_fq(info, &fq_graph, &t.params).unwrap();
    // nw=1 (ternary) -> every conv layer takes the TernaryMatrix path
    let net = FqKwsNet::from_params(&fq_params, 1.0, 7.0, info.input_shape[1]).unwrap();
    assert!(net.layers().iter().all(|l| l.is_ternary()));
    // nw=7 (4-bit) -> dense path
    let net4 = FqKwsNet::from_params(&fq_params, 7.0, 7.0, info.input_shape[1]).unwrap();
    assert!(net4.layers().iter().all(|l| !l.is_ternary()));
}

#[test]
fn analog_sim_with_zero_noise_matches_engine() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("kws").unwrap();
    let mut t = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
    t.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();
    let fq_graph = info.fq.clone().unwrap();
    let fq_params = fq_transform::qat_to_fq(info, &fq_graph, &t.params).unwrap();

    let mut xbar =
        fqconv::analog::CrossbarSim::from_kws_params(&fq_params, 1.0, 7.0, info.input_shape[1])
            .unwrap();
    let g = std::sync::Arc::clone(xbar.graph());
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let mut rng = Rng::new(1);
    let mut s = fqconv::infer::pipeline::Scratch::default();
    let mut s2 = fqconv::infer::pipeline::Scratch::default();
    let mut clean = vec![0f32; g.classes()];
    let mut eng = vec![0f32; g.classes()];
    for id in 0..8u64 {
        let (x, _) = ds.sample(id, None);
        // the always-analog walk (not the silent fast path), so the f64
        // code-space path itself is what must reduce to the engine
        xbar.forward_analog_into(
            &x,
            fqconv::analog::NoiseConfig::default(),
            &mut rng,
            &mut s,
            &mut clean,
        );
        g.forward_into(&x, &mut s2, &mut eng, 1);
        assert_eq!(clean, eng, "zero-noise analog walk must be bit-identical to the engine");
    }
}

#[test]
fn noise_degrades_monotonically_on_average() {
    let Some((manifest, engine)) = setup() else { return };
    let info = manifest.model("kws").unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    // brief training so accuracy is meaningfully above chance
    let mut t = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
    t.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();
    let mut rng = Rng::new(2);
    let mut hpv = hp::defaults();
    hpv[hp::LR] = 0.01;
    hpv[hp::NW] = 1.0;
    hpv[hp::NA] = 7.0;
    for step in 0..30 {
        let batch = ds.train_batch(info.batch, &mut rng);
        hpv[hp::SEED] = step as f32;
        t.step(&batch, None, &hpv).unwrap();
    }
    let fq_graph = info.fq.clone().unwrap();
    let fq_params = fq_transform::qat_to_fq(info, &fq_graph, &t.params).unwrap();
    let mut xbar =
        fqconv::analog::CrossbarSim::from_kws_params(&fq_params, 1.0, 7.0, info.input_shape[1])
            .unwrap();
    let acc_low = xbar.evaluate_noisy(
        ds.as_ref(),
        48,
        fqconv::analog::NoiseConfig { sigma_w: 1.0, sigma_a: 1.0, sigma_mac: 5.0 },
        2,
        7,
    );
    let acc_high = xbar.evaluate_noisy(
        ds.as_ref(),
        48,
        fqconv::analog::NoiseConfig { sigma_w: 60.0, sigma_a: 60.0, sigma_mac: 300.0 },
        2,
        7,
    );
    assert!(
        acc_high <= acc_low + 0.05,
        "extreme noise should not beat low noise: low={acc_low} high={acc_high}"
    );
}
