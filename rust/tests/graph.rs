//! Contract of the composable QuantGraph engine: a graph assembled by
//! hand from KWS stages is bit-identical to the `FqKwsNet` facade at
//! every pool size, a second (deeper/wider) 1-D architecture runs on
//! the same API, and the 2-D stage lists — the residual ResNet-32 and
//! the pooled DarkNet-19 — are bit-identical to a stage-by-stage
//! im2col-oracle walk at every pool size. Runs fully offline on
//! synthetic parameters.

mod common;

use fqconv::data::{self, Dataset as _};
use fqconv::infer::graph::{synthetic_graph, Scratch, SynthArch};
use fqconv::infer::pipeline::{kws_stages, synthetic_params};
use fqconv::infer::{FqKwsNet, QuantGraph};
use fqconv::util::Rng;

use common::forward_reference_2d;

#[test]
fn graph_bit_identical_to_fqkwsnet_at_pool_sizes_1_2_4_8() {
    // same trained-parameter set builds the facade AND a hand-assembled
    // graph; outputs must agree bit-for-bit at every pool size, for both
    // ternary (W2) and dense (W4) weight kinds
    let params = synthetic_params(42).expect("synthetic params");
    for nw in [1.0f32, 7.0] {
        let net = FqKwsNet::from_params(&params, nw, 7.0, 80).expect("facade");
        let graph =
            QuantGraph::new(kws_stages(&params, nw, 7.0).expect("stages"), 80).expect("graph");
        assert_eq!(graph.classes(), net.classes);
        assert_eq!(graph.out_frames(), net.out_frames());
        assert_eq!(graph.macs_per_sample(), net.macs_per_sample());

        let ds = data::for_model("kws", &[39, 80], 12);
        let batch = ds.val_batch(0, 13); // odd size: uneven partitions
        let per = batch.x.data().len() / 13;

        // graph reference: sequential single-sample walk
        let mut s = Scratch::for_graph(&graph);
        let mut want = Vec::new();
        for i in 0..13 {
            want.extend(graph.forward(&batch.x.data()[i * per..(i + 1) * per], &mut s));
        }
        // facade at several pool sizes vs the graph reference
        for threads in [1usize, 2, 4, 8] {
            let got = net.forward_batch_with(&batch.x, threads);
            assert_eq!(
                got.data(),
                &want[..],
                "nw={nw} pool={threads}: facade diverged from the hand-built graph"
            );
        }
        // and the graph's own intra-layer threading is bit-identical
        for threads in [2usize, 4, 8] {
            let mut logits = vec![0f32; graph.classes()];
            graph.forward_into(&batch.x.data()[..per], &mut s, &mut logits, threads);
            assert_eq!(logits[..], want[..graph.classes()], "graph intra-op threads={threads}");
        }
    }
}

#[test]
fn second_architecture_runs_on_the_same_api() {
    // the deeper/wider net with a different dilation schedule exercises
    // the same stage types, buffer planner and kernels
    let kws = synthetic_graph(&SynthArch::kws(), 1.0, 7.0, 7).expect("kws graph");
    let deep = synthetic_graph(&SynthArch::deep_wide(), 1.0, 7.0, 7).expect("deep-wide graph");
    assert_eq!(deep.classes(), kws.classes());
    assert!(deep.frames() > kws.frames());
    assert!(
        deep.macs_per_sample() > kws.macs_per_sample(),
        "deep-wide must be heavier: {} vs {}",
        deep.macs_per_sample(),
        kws.macs_per_sample()
    );
    assert_eq!(deep.first_stack().len(), 10);
    // dilation schedule reaches 16 (vs 8 for KWS)
    assert_eq!(deep.conv_layers().map(|l| l.dilation).max(), Some(16));

    let mut rng = Rng::new(3);
    let mut x = vec![0f32; deep.in_numel()];
    rng.fill_gaussian(&mut x, 1.0);
    let mut s = Scratch::for_graph(&deep);
    let want = deep.forward(&x, &mut s);
    assert_eq!(want.len(), 12);
    assert!(want.iter().all(|v| v.is_finite()));
    assert!(want.iter().any(|&v| v != 0.0), "logits all zero — dead forward");
    for threads in [2usize, 4, 8] {
        let mut logits = vec![0f32; deep.classes()];
        deep.forward_into(&x, &mut s, &mut logits, threads);
        assert_eq!(logits, want, "deep-wide threads={threads}");
    }
}

#[test]
fn dense_weights_run_the_second_architecture_too() {
    let deep = synthetic_graph(&SynthArch::deep_wide(), 7.0, 7.0, 9).expect("dense deep-wide");
    assert!(deep.conv_layers().all(|l| !l.is_ternary()));
    let mut rng = Rng::new(4);
    let mut x = vec![0f32; deep.in_numel()];
    rng.fill_gaussian(&mut x, 1.0);
    let mut s = Scratch::for_graph(&deep);
    let a = deep.forward(&x, &mut s);
    let b = deep.forward(&x, &mut s);
    assert_eq!(a, b, "scratch reuse must not change outputs");
}

#[test]
fn scratch_plan_covers_the_high_water_marks() {
    // the buffer plan computed at graph build time must cover the real
    // per-forward high-water marks: a pre-planned Scratch never grows —
    // for the 1-D nets AND the 2-D residual grammar (skip buffer)
    for arch in [SynthArch::kws(), SynthArch::deep_wide(), SynthArch::resnet("resnet8", 1)] {
        let g = synthetic_graph(&arch, 1.0, 7.0, 5).expect("graph");
        let mut s = Scratch::for_graph(&g);
        let planned = s.capacities();
        let mut rng = Rng::new(8);
        let mut x = vec![0f32; g.in_numel()];
        rng.fill_gaussian(&mut x, 1.0);
        let mut logits = vec![0f32; g.classes()];
        g.forward_into(&x, &mut s, &mut logits, 1);
        g.forward_into(&x, &mut s, &mut logits, 4);
        assert_eq!(
            s.capacities(),
            planned,
            "{}: forward outgrew the planned scratch (allocation on the hot path)",
            arch.name()
        );
    }
}

// ---------------------------------------------------------------------------
// 2-D graphs (ResNet-32, DarkNet-19) vs the shared im2col-oracle walk
// (common::forward_reference_2d)
// ---------------------------------------------------------------------------

#[test]
fn resnet32_bit_identical_to_im2col_oracle_at_pool_sizes_1_2_4_8() {
    // the acceptance pin: the full Table-6 network runs end-to-end
    // through forward_into, matches the stage-by-stage im2col oracle
    // bit-for-bit, at every pool size, with zero steady-state
    // allocations (the planned scratch never grows)
    let g = synthetic_graph(&SynthArch::resnet32(), 1.0, 7.0, 21).expect("resnet32");
    assert_eq!(g.in_shape(), &[3, 32, 32]);
    assert_eq!(g.classes(), 10);
    let mut rng = Rng::new(6);
    let mut x = vec![0f32; g.in_numel()];
    rng.fill_gaussian(&mut x, 0.5);
    let want = forward_reference_2d(&g, &x);
    assert!(want.iter().all(|v| v.is_finite()));
    assert!(want.iter().any(|&v| v != 0.0), "logits all zero — dead forward");

    let mut s = Scratch::for_graph(&g);
    let planned = s.capacities();
    for threads in [1usize, 2, 4, 8] {
        let mut logits = vec![0f32; g.classes()];
        g.forward_into(&x, &mut s, &mut logits, threads);
        assert_eq!(logits, want, "pool={threads}: direct engine diverged from the oracle");
    }
    assert_eq!(
        s.capacities(),
        planned,
        "resnet32 forward outgrew the planned scratch (allocation on the hot path)"
    );
}

#[test]
fn darknet19_bit_identical_to_im2col_oracle_at_pool_sizes_1_2_4_8() {
    // the Table-3 acceptance pin: the full DarkNet-19 stage list (conv
    // groups + 2x2/2 max pools) runs end-to-end through forward_into,
    // matches the stage-by-stage oracle walk (im2col convs + float-path
    // max pooling) bit-for-bit at every pool size, with zero
    // steady-state allocations
    let g = synthetic_graph(&SynthArch::darknet19(), 1.0, 7.0, 23).expect("darknet19");
    assert_eq!(g.in_shape(), &[3, 64, 64]);
    assert_eq!(g.classes(), 100);
    // 64 -> 2 through the five 2x2 stride-2 pools
    assert_eq!(g.out_frames(), 4);
    let mut rng = Rng::new(12);
    let mut x = vec![0f32; g.in_numel()];
    rng.fill_gaussian(&mut x, 0.5);
    let want = forward_reference_2d(&g, &x);
    assert!(want.iter().all(|v| v.is_finite()));
    assert!(want.iter().any(|&v| v != 0.0), "logits all zero — dead forward");

    let mut s = Scratch::for_graph(&g);
    let planned = s.capacities();
    for threads in [1usize, 2, 4, 8] {
        let mut logits = vec![0f32; g.classes()];
        g.forward_into(&x, &mut s, &mut logits, threads);
        assert_eq!(logits, want, "pool={threads}: direct engine diverged from the oracle");
    }
    assert_eq!(
        s.capacities(),
        planned,
        "darknet19 forward outgrew the planned scratch (allocation on the hot path)"
    );
}

#[test]
fn small_resnet_matches_oracle_for_both_weight_kinds() {
    // the shallow ResNet-8 exercises every stage type (stem stack,
    // identity block, strided projection blocks) at a fraction of the
    // cost — swept for ternary AND dense weights
    for nw in [1.0f32, 7.0] {
        let g = synthetic_graph(&SynthArch::resnet("resnet8", 1), nw, 7.0, 17).expect("graph");
        let mut rng = Rng::new(9);
        let mut x = vec![0f32; g.in_numel()];
        rng.fill_gaussian(&mut x, 0.5);
        let want = forward_reference_2d(&g, &x);
        let mut s = Scratch::for_graph(&g);
        for threads in [1usize, 3, 8] {
            let mut logits = vec![0f32; g.classes()];
            g.forward_into(&x, &mut s, &mut logits, threads);
            assert_eq!(logits, want, "nw={nw} pool={threads}");
        }
    }
}
