//! Streaming subsystem acceptance: incremental per-frame inference is
//! bit-identical to the offline whole-window forward — across every KWS
//! dilation schedule prefix, the edge shapes, and through the serving
//! registry at 1/2/4 workers — the overlap-save MFCC front end matches
//! offline framing, steady-state feeds never grow state or scratch, and
//! the session layer's typed lifecycle errors (UnknownSession on
//! close/evict/stale handles, Overloaded over `max_sessions`) hold.

use std::sync::Arc;
use std::time::Duration;

use fqconv::data::dsp::{Mfcc, MfccConfig};
use fqconv::infer::graph::{synthetic_graph, QuantGraph, Scratch, SeqArch, SynthArch};
use fqconv::serve::{BatchPolicy, GraphBackend, ModelSpec, ServeError, Server, StreamSpec};
use fqconv::stream::{Streamer, StreamingMfcc};
use fqconv::util::Rng;

fn seq_graph(
    name: &'static str,
    convs: Vec<(usize, usize, usize)>,
    frames: usize,
    seed: u64,
) -> Arc<QuantGraph> {
    let arch = SeqArch { name, n_in: 5, frames, embed_dim: 8, classes: 4, convs };
    Arc::new(synthetic_graph(&SynthArch::Seq(arch), 1.0, 7.0, seed).expect(name))
}

fn gaussian_clip(g: &QuantGraph, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut clip = vec![0f32; g.in_numel()];
    rng.fill_gaussian(&mut clip, 1.0);
    clip
}

fn offline(g: &QuantGraph, clip: &[f32]) -> Vec<f32> {
    let mut s = Scratch::for_graph(g);
    g.forward(clip, &mut s)
}

/// Feed the clip column by column through a fresh session, asserting the
/// warm-up readiness boundary on the way, and return the final logits.
fn streamed(g: &Arc<QuantGraph>, clip: &[f32]) -> Vec<f32> {
    let streamer = Streamer::new(Arc::clone(g)).expect("1-D graph");
    let frames = g.in_numel() / g.n_in();
    let warmup = streamer.plan().warmup_frames();
    let mut st = streamer.open();
    let mut scr = streamer.scratch();
    let mut frame = vec![0f32; g.n_in()];
    let mut logits = vec![0f32; g.classes()];
    for t in 0..frames {
        for (k, f) in frame.iter_mut().enumerate() {
            *f = clip[k * frames + t];
        }
        streamer.feed(&mut st, &frame, &mut scr);
        let ready = t + 1 >= warmup;
        assert_eq!(st.ready(), ready, "readiness at frame {t} (warmup {warmup})");
        assert_eq!(streamer.logits_into(&st, &mut scr, &mut logits), ready);
    }
    assert_eq!(st.frames_in(), frames);
    logits
}

#[test]
fn every_kws_dilation_schedule_prefix_streams_bit_identically() {
    // the paper's KWS schedule, layer by layer: each prefix is its own
    // network (own warm-up, own ring cascade) and must match offline
    const SCHED: [usize; 7] = [1, 1, 2, 4, 8, 8, 8];
    for p in 1..=SCHED.len() {
        let convs: Vec<_> = SCHED[..p].iter().map(|&d| (8, 3, d)).collect();
        let warmup = 1 + SCHED[..p].iter().map(|d| 2 * d).sum::<usize>();
        let g = seq_graph("kws-prefix", convs, warmup + 3, 11);
        let streamer = Streamer::new(Arc::clone(&g)).unwrap();
        assert_eq!(streamer.plan().warmup_frames(), warmup, "prefix {p} warm-up");
        let clip = gaussian_clip(&g, 100 + p as u64);
        assert_eq!(streamed(&g, &clip), offline(&g, &clip), "prefix {p} diverged");
    }
    // and the full-size paper net: 39 MFCC x 80 frames, 32 wide, 12 classes
    let g = Arc::new(synthetic_graph(&SynthArch::kws(), 1.0, 7.0, 7).expect("kws"));
    let clip = gaussian_clip(&g, 200);
    assert_eq!(streamed(&g, &clip), offline(&g, &clip), "full kws diverged");
}

#[test]
fn edge_shapes_stream_bit_identically() {
    // ksize=1 (span-1 ring), a mixed stack with a pointwise middle
    // layer, dilation gap wider than the surviving t_out, and a stack
    // whose output is a single column
    let cases: [(&'static str, Vec<(usize, usize, usize)>, usize); 4] = [
        ("k1", vec![(6, 1, 1)], 4),
        ("k1-mid", vec![(6, 3, 2), (6, 1, 1), (5, 3, 1)], 10),
        ("wide-gap", vec![(6, 3, 8)], 19), // span 17: t_out=2 < dilation 8
        ("t-out-1", vec![(6, 3, 4)], 9),   // t_out exactly 1
    ];
    for (name, convs, frames) in cases {
        let g = seq_graph(name, convs, frames, 9);
        let clip = gaussian_clip(&g, 300);
        assert_eq!(streamed(&g, &clip), offline(&g, &clip), "{name} diverged");
    }
}

#[test]
fn every_truncated_window_matches_an_offline_rebuild() {
    // after n frames the session's logits must equal the offline forward
    // over exactly the first n columns. The synthetic weights depend
    // only on dims + seed — not on `frames` — so a graph rebuilt with
    // frames=n carries identical parameters.
    let full = 20usize;
    let convs = vec![(6, 3, 1), (7, 3, 2)];
    let mk = |frames: usize| {
        let arch = SeqArch {
            name: "trunc",
            n_in: 5,
            frames,
            embed_dim: 8,
            classes: 4,
            convs: convs.clone(),
        };
        Arc::new(synthetic_graph(&SynthArch::Seq(arch), 1.0, 7.0, 5).expect("trunc"))
    };
    let g = mk(full);
    let clip = gaussian_clip(&g, 400);
    let streamer = Streamer::new(Arc::clone(&g)).unwrap();
    let warmup = streamer.plan().warmup_frames();
    assert_eq!(warmup, 7); // 1 + 2*1 + 2*2
    let mut st = streamer.open();
    let mut scr = streamer.scratch();
    let mut frame = vec![0f32; g.n_in()];
    let mut logits = vec![0f32; g.classes()];
    for t in 0..full {
        for (k, f) in frame.iter_mut().enumerate() {
            *f = clip[k * full + t];
        }
        streamer.feed(&mut st, &frame, &mut scr);
        let n = t + 1;
        if !streamer.logits_into(&st, &mut scr, &mut logits) {
            assert!(n < warmup, "no logits after warm-up");
            continue;
        }
        let gn = mk(n);
        let mut xn = vec![0f32; g.n_in() * n];
        for k in 0..g.n_in() {
            xn[k * n..(k + 1) * n].copy_from_slice(&clip[k * full..k * full + n]);
        }
        assert_eq!(logits, offline(&gn, &xn), "window n={n} diverged");
    }
}

#[test]
fn steady_state_feeds_do_not_grow_state_or_scratch() {
    let g = Arc::new(synthetic_graph(&SynthArch::kws(), 1.0, 7.0, 7).expect("kws"));
    let streamer = Streamer::new(Arc::clone(&g)).unwrap();
    let plan_bytes = streamer.plan().bytes_per_session();
    let mut st = streamer.open();
    let mut scr = streamer.scratch();
    assert_eq!(st.resident_bytes(), plan_bytes, "fresh state off plan");
    let mut rng = Rng::new(8);
    let mut frame = vec![0f32; streamer.frame_dim()];
    let mut logits = vec![0f32; streamer.classes()];
    rng.fill_gaussian(&mut frame, 1.0);
    streamer.feed(&mut st, &frame, &mut scr);
    let caps = scr.capacities();
    for i in 0..200 {
        rng.fill_gaussian(&mut frame, 1.0);
        streamer.feed(&mut st, &frame, &mut scr);
        streamer.logits_into(&st, &mut scr, &mut logits);
        assert_eq!(scr.capacities(), caps, "scratch grew at feed {i}");
        assert_eq!(st.resident_bytes(), plan_bytes, "session state grew at feed {i}");
    }
}

#[test]
fn streaming_mfcc_is_bit_identical_at_any_chunking() {
    let mfcc = Mfcc::new(MfccConfig::default());
    let mut scr = mfcc.scratch();
    // 13 extra samples: less than a hop past the last frame boundary,
    // so the tail must emit nothing
    let mut signal = vec![0f32; mfcc.samples_for_frames(17) + 13];
    let mut rng = Rng::new(6);
    rng.fill_gaussian(&mut signal, 1.0);
    let off = mfcc.compute(&signal); // (n_mfcc, frames) row-major
    let n_frames = mfcc.frames_for(signal.len());
    assert_eq!(n_frames, 17);
    for chunk in [1usize, 7, 160, signal.len()] {
        let mut s = StreamingMfcc::new(&mfcc);
        let mut t = 0usize;
        for c in signal.chunks(chunk) {
            s.push(&mfcc, &mut scr, c, |f| {
                for (k, &v) in f.iter().enumerate() {
                    assert_eq!(v, off[k * n_frames + t], "chunk={chunk} frame {t} coeff {k}");
                }
                t += 1;
            });
        }
        assert_eq!(t, n_frames, "chunk={chunk} emitted the wrong frame count");
        assert_eq!(s.frames_emitted(), n_frames);
    }
}

#[test]
fn registry_sessions_bit_identical_at_1_2_4_workers() {
    // concurrent sessions fed through the shared worker pool: warm-up
    // frames reply with empty logits, every later reply carries running
    // logits, and the final reply equals the offline whole-window
    // forward — while the same pool keeps serving offline submits
    let graph = Arc::new(synthetic_graph(&SynthArch::kws(), 1.0, 7.0, 7).expect("kws"));
    let (n_in, frames) = (graph.n_in(), graph.in_numel() / graph.n_in());
    let n_sessions = 3usize;
    let clips: Vec<Vec<f32>> =
        (0..n_sessions).map(|i| gaussian_clip(&graph, 500 + i as u64)).collect();
    let mut s = Scratch::for_graph(&graph);
    let want: Vec<Vec<f32>> = clips.iter().map(|x| graph.forward(x, &mut s)).collect();
    let warmup = Streamer::new(Arc::clone(&graph)).unwrap().plan().warmup_frames();
    for workers in [1usize, 2, 4] {
        let spec = ModelSpec::new(
            GraphBackend::factory_sharded(&graph, workers),
            graph.in_numel(),
            BatchPolicy::default(),
        )
        .with_cost(graph.cost_per_sample())
        .with_streaming(StreamSpec {
            graph: Arc::clone(&graph),
            max_sessions: 8,
            idle_timeout: Duration::from_secs(30),
        });
        let server = Server::start_spec(spec, workers);
        let sids: Vec<_> = (0..n_sessions)
            .map(|_| server.open_session().expect("under the session bound"))
            .collect();
        assert_eq!(server.registry().stats().models[0].sessions, n_sessions as u64);
        let mut last: Vec<Vec<f32>> = vec![Vec::new(); n_sessions];
        for t in 0..frames {
            let rxs: Vec<_> = sids
                .iter()
                .enumerate()
                .map(|(i, &sid)| {
                    let frame: Vec<f32> = (0..n_in).map(|k| clips[i][k * frames + t]).collect();
                    server.feed(sid, frame).expect("open session accepts feeds")
                })
                .collect();
            for (i, rx) in rxs.into_iter().enumerate() {
                let resp = rx.recv().expect("feed reply").expect("served");
                assert_eq!(resp.batch_size, 1, "a feed is its own unit of work");
                if t + 1 < warmup {
                    assert!(
                        resp.logits.is_empty(),
                        "workers={workers}: warm-up frame {t} emitted logits"
                    );
                } else {
                    assert_eq!(resp.logits.len(), graph.classes());
                    last[i] = resp.logits;
                }
            }
        }
        for (i, l) in last.iter().enumerate() {
            assert_eq!(l, &want[i], "workers={workers} session {i} diverged from offline");
        }
        let resp = server.infer(clips[0].clone());
        assert_eq!(resp.logits, want[0], "workers={workers}: batch path diverged");
        for &sid in &sids {
            server.close_session(sid).expect("closing an open session");
        }
        assert_eq!(server.registry().stats().models[0].sessions, 0);
        server.shutdown();
    }
}

#[test]
fn session_lifecycle_typed_errors() {
    let graph = seq_graph("life", vec![(6, 3, 1)], 6, 4);
    let spec = ModelSpec::new(
        GraphBackend::factory(&graph),
        graph.in_numel(),
        BatchPolicy::default(),
    )
    .with_streaming(StreamSpec {
        graph: Arc::clone(&graph),
        max_sessions: 2,
        idle_timeout: Duration::from_secs(30),
    });
    let server = Server::start_spec(spec, 1);
    let s1 = server.open_session().expect("first session");
    let s2 = server.open_session().expect("second session");
    match server.open_session() {
        Err(ServeError::Overloaded { pending, .. }) => assert_eq!(pending, 2),
        other => panic!("expected Overloaded over max_sessions, got {:?}", other.map(|_| ())),
    }
    server.close_session(s1).expect("closing an open session");
    match server.feed(s1, vec![0.5; graph.n_in()]) {
        Err(ServeError::UnknownSession { .. }) => {}
        other => panic!("expected UnknownSession after close, got {:?}", other.map(|_| ())),
    }
    match server.close_session(s1) {
        Err(ServeError::UnknownSession { .. }) => {}
        other => panic!("double close must be typed dead, got {other:?}"),
    }
    // the freed slot is recycled under a fresh generation — the stale
    // handle must stay typed dead, not alias the new session
    let s3 = server.open_session().expect("slot freed by close");
    match server.feed(s1, vec![0.5; graph.n_in()]) {
        Err(ServeError::UnknownSession { .. }) => {}
        other => panic!("stale handle aliased a recycled slot: {:?}", other.map(|_| ())),
    }
    for sid in [s2, s3] {
        let rx = server.feed(sid, vec![0.5; graph.n_in()]).expect("live session");
        rx.recv().expect("reply").expect("served");
        server.close_session(sid).expect("closing a live session");
    }
    server.shutdown();
}

#[test]
fn idle_sessions_are_swept() {
    let graph = seq_graph("idle", vec![(6, 3, 1)], 6, 4);
    let spec = ModelSpec::new(
        GraphBackend::factory(&graph),
        graph.in_numel(),
        BatchPolicy::default(),
    )
    .with_streaming(StreamSpec {
        graph: Arc::clone(&graph),
        max_sessions: 4,
        idle_timeout: Duration::from_millis(40),
    });
    let server = Server::start_spec(spec, 1);
    let sid = server.open_session().expect("session");
    let rx = server.feed(sid, vec![0.5; graph.n_in()]).expect("live session");
    rx.recv().expect("reply").expect("served");
    // the batcher sweeps idle sessions on its tick; wait it out
    let t = std::time::Instant::now();
    while server.registry().stats().models[0].sessions != 0 {
        assert!(t.elapsed() < Duration::from_secs(5), "idle session never evicted");
        std::thread::sleep(Duration::from_millis(20));
    }
    match server.feed(sid, vec![0.5; graph.n_in()]) {
        Err(ServeError::UnknownSession { .. }) => {}
        other => panic!("expected UnknownSession after eviction, got {:?}", other.map(|_| ())),
    }
    server.shutdown();
}
