//! Randomized graph-equivalence fuzz harness for the 2-D stage grammar.
//!
//! A seeded generator assembles *valid* random stage lists — random
//! depth, channels, strides, kernel/pad shapes, pool placements, a
//! per-conv W2/W4 weight-kind mix and a per-conv fused/unfused requant
//! mix (fused convs re-bin through the composed LUT straight onto the
//! consumer grid, as every real network here does) — over the full
//! grammar
//! `QuantStem2d (FqConv2dStack | Residual | MaxPool2d)+ GlobalAvgPool
//! DenseHead`, then pins for every spec that
//!
//! * the direct engine forward is bit-identical to the independent
//!   oracle walk (im2col + GEMM + threshold-search convs, float-path
//!   max pooling) at pool sizes 1/2/4, and
//! * `Scratch::capacities` is unchanged after those three forwards —
//!   the build-time buffer plan really covers the high-water marks
//!   (no allocation on the hot path).
//!
//! A companion rejection sweep builds one known-valid spec and mutates
//! one field at a time, asserting every mutation is refused with a
//! *typed* construction error — never a panic.
//!
//! Deterministic: one fixed seed drives the whole sweep.

mod common;

use fqconv::infer::graph::{
    DenseHead, FqConv2dStack, GlobalAvgPool, MaxPool2d, QuantGraph, QuantStage, QuantStem2d,
    Residual, Scratch,
};
use fqconv::infer::QuantConv2d;
use fqconv::quant::{AddLut, QParams};
use fqconv::util::Rng;

use common::forward_reference_2d;

/// Activation level count (4-bit) for every generated grid.
const NA: f32 = 7.0;

/// A random post-ReLU (b = 0) activation grid.
fn relu_grid(rng: &mut Rng) -> QParams {
    QParams::new(rng.range(0.6, 1.4), NA, 0.0)
}

/// A random conv layer, randomly ternary (W2) or dense (W4) AND
/// randomly fused (re-bins straight onto a consumer grid through the
/// composed LUT — the configuration every real network in the repo
/// uses) or unfused (emits on its own mid grid) — the full mix the
/// grammar must carry. The chaining grid is always `out_grid()`, so
/// the generator stays valid either way.
fn rand_conv(
    rng: &mut Rng,
    c_in: usize,
    c_out: usize,
    ksize: usize,
    stride: usize,
    pad: usize,
    qa: QParams,
) -> QuantConv2d {
    let mut w = vec![0f32; c_out * c_in * ksize * ksize];
    rng.fill_gaussian(&mut w, 0.5);
    let nw = if rng.chance(0.5) { 1.0 } else { 7.0 };
    let qw = QParams::new(rng.range(0.3, 1.0), nw, -1.0);
    let mid = relu_grid(rng);
    let next = if rng.chance(0.5) { Some(relu_grid(rng)) } else { None };
    QuantConv2d::new(&w, c_out, c_in, ksize, stride, pad, qa, qw, mid, next)
}

/// Geometry threaded through the generator: what the *next* stage sees.
struct Cursor {
    ch: usize,
    h: usize,
    w: usize,
    grid: QParams,
}

/// Append a random conv stack (1-2 layers) to `stages`.
fn push_stack(rng: &mut Rng, stages: &mut Vec<QuantStage>, cur: &mut Cursor) {
    let mut layers = Vec::new();
    for _ in 0..1 + rng.below(2) {
        let c_out = 1 + rng.below(6);
        let ksize = if cur.h.min(cur.w) >= 3 && rng.chance(0.6) { 3 } else { 1 };
        let pad = if ksize == 3 && rng.chance(0.7) { 1 } else { 0 };
        let stride = if cur.h.min(cur.w) >= 4 && rng.chance(0.3) { 2 } else { 1 };
        let l = rand_conv(rng, cur.ch, c_out, ksize, stride, pad, cur.grid);
        cur.grid = l.out_grid();
        let (h2, w2) = l.out_hw(cur.h, cur.w);
        cur.h = h2;
        cur.w = w2;
        cur.ch = c_out;
        layers.push(l);
    }
    stages.push(QuantStage::FqConv2dStack(FqConv2dStack { layers }));
}

/// Append a random residual block (two 3x3 body convs, optional strided
/// / widening 1x1 shortcut projection, fresh join grid).
fn push_residual(rng: &mut Rng, stages: &mut Vec<QuantStage>, cur: &mut Cursor) {
    let c2 = 1 + rng.below(6);
    let stride = if cur.h.min(cur.w) >= 4 && rng.chance(0.4) { 2 } else { 1 };
    let b1 = rand_conv(rng, cur.ch, c2, 3, stride, 1, cur.grid);
    let (h2, w2) = b1.out_hw(cur.h, cur.w);
    let b2 = rand_conv(rng, c2, c2, 3, 1, 1, b1.out_grid());
    let body_grid = b2.out_grid();
    let (down, skip_grid) = if stride != 1 || c2 != cur.ch {
        let d = rand_conv(rng, cur.ch, c2, 1, stride, 0, cur.grid);
        let g = d.out_grid();
        (Some(d), g)
    } else {
        (None, cur.grid)
    };
    let out_grid = relu_grid(rng);
    let add = AddLut::build(body_grid, skip_grid, out_grid);
    stages.push(QuantStage::Residual(Residual { body: vec![b1, b2], down, add }));
    cur.ch = c2;
    cur.h = h2;
    cur.w = w2;
    cur.grid = out_grid;
}

/// Append a random max pool (window <= extent; stride may exceed the
/// window — subsampling gaps are part of the grammar).
fn push_pool(rng: &mut Rng, stages: &mut Vec<QuantStage>, cur: &mut Cursor) {
    let kmax = cur.h.min(cur.w).min(3);
    let k = 1 + rng.below(kmax);
    let s = 1 + rng.below(3);
    let p = MaxPool2d { ksize: k, stride: s };
    let (h2, w2) = p.out_hw(cur.h, cur.w);
    stages.push(QuantStage::MaxPool2d(p));
    cur.h = h2;
    cur.w = w2;
}

/// Generate one valid random spec; returns (stages, h, w).
fn random_spec(rng: &mut Rng) -> (Vec<QuantStage>, usize, usize) {
    let c_in = 1 + rng.below(3);
    let h = 6 + rng.below(6);
    let w = 6 + rng.below(6);
    let classes = 2 + rng.below(3);
    let stem_q = QParams::new(rng.range(0.6, 1.4), NA, -1.0);
    let mut stages = vec![QuantStage::QuantStem2d(QuantStem2d { c_in, out_q: stem_q })];
    let mut cur = Cursor { ch: c_in, h, w, grid: stem_q };
    let mut n_convs = 0usize;
    for _ in 0..2 + rng.below(3) {
        match rng.below(3) {
            0 => {
                push_stack(rng, &mut stages, &mut cur);
                n_convs += 1;
            }
            1 => {
                push_residual(rng, &mut stages, &mut cur);
                n_convs += 1;
            }
            _ => push_pool(rng, &mut stages, &mut cur),
        }
    }
    if n_convs == 0 {
        // the grammar requires at least one conv-bearing stage
        push_stack(rng, &mut stages, &mut cur);
    }
    stages.push(QuantStage::GlobalAvgPool(GlobalAvgPool { channels: cur.ch, dq: cur.grid }));
    let mut hw = vec![0f32; cur.ch * classes];
    rng.fill_gaussian(&mut hw, 0.5);
    stages.push(QuantStage::DenseHead(DenseHead {
        w: hw,
        b: vec![0.0; classes],
        d_in: cur.ch,
        d_out: classes,
    }));
    (stages, h, w)
}

#[test]
fn fuzz_random_2d_graphs_match_the_im2col_oracle() {
    let mut rng = Rng::new(0xF0_22D_5EED);
    let mut built = 0usize;
    let mut pooled_specs = 0usize;
    for spec_i in 0..60 {
        let (stages, h, w) = random_spec(&mut rng);
        let has_pool = stages.iter().any(|s| matches!(s, QuantStage::MaxPool2d(_)));
        pooled_specs += usize::from(has_pool);
        let g = QuantGraph::new_2d(stages, h, w)
            .unwrap_or_else(|e| panic!("spec {spec_i}: generator produced an invalid graph: {e}"));
        let mut x = vec![0f32; g.in_numel()];
        rng.fill_gaussian(&mut x, 0.5);
        let want = forward_reference_2d(&g, &x);
        assert!(want.iter().all(|v| v.is_finite()), "spec {spec_i}: non-finite logits");

        let mut s = Scratch::for_graph(&g);
        let planned = s.capacities();
        for threads in [1usize, 2, 4] {
            let mut logits = vec![0f32; g.classes()];
            g.forward_into(&x, &mut s, &mut logits, threads);
            assert_eq!(
                logits,
                want,
                "spec {spec_i} pool={threads}: direct engine diverged from the oracle"
            );
        }
        assert_eq!(
            s.capacities(),
            planned,
            "spec {spec_i}: three forwards outgrew the planned scratch"
        );
        built += 1;
    }
    assert!(built >= 50, "fuzz sweep must cover >= 50 specs, got {built}");
    assert!(pooled_specs >= 10, "sweep barely exercised pooling: {pooled_specs} specs");
}

// ---------------------------------------------------------------------------
// Rejection sweep: one mutated field per spec => one typed error
// ---------------------------------------------------------------------------

/// Every single-field mutation the sweep applies to the valid base spec.
#[derive(Clone, Copy, Debug)]
enum Mutation {
    None,
    DropStem,
    StemZeroChannels,
    ConvChannelMismatch,
    EmptyStack,
    PoolWiderThanExtent,
    PoolZeroKsize,
    PoolZeroStride,
    MissingProjection,
    AddLutBodyGridMismatch,
    AddLutSkipGridMismatch,
    GapChannelMismatch,
    GapGridMismatch,
    HeadDinMismatch,
    HeadWeightNumel,
    MissingTail,
    TrailingStage,
    NoConvStages,
}

/// Build the base spec (stem → 2-conv stack → 2x2/2 pool → strided
/// residual → GAP → head on 8x8 inputs), with `m` mutating exactly one
/// field. `Mutation::None` must validate; everything else must fail
/// with a typed error.
fn build_spec(m: Mutation) -> Vec<QuantStage> {
    use Mutation as M;
    let mut rng = Rng::new(99);
    let stem_q = QParams::new(1.0, NA, -1.0);
    let stem_ch = if matches!(m, M::StemZeroChannels) { 0 } else { 2 };
    let mut stages = vec![QuantStage::QuantStem2d(QuantStem2d { c_in: stem_ch, out_q: stem_q })];
    if matches!(m, M::DropStem) {
        stages.clear();
    }

    // conv stack: 2 -> 4 -> 4 channels on the 8x8 extent
    let c1 = rand_conv(&mut rng, 2, 4, 3, 1, 1, stem_q);
    let c1_grid = c1.out_grid();
    let c2_in = if matches!(m, M::ConvChannelMismatch) { 5 } else { 4 };
    let c2 = rand_conv(&mut rng, c2_in, 4, 3, 1, 1, c1_grid);
    let stack_grid = c2.out_grid();
    let layers = if matches!(m, M::EmptyStack) { Vec::new() } else { vec![c1, c2] };
    if !matches!(m, M::NoConvStages) {
        stages.push(QuantStage::FqConv2dStack(FqConv2dStack { layers }));
    }

    // pool: 8x8 -> 4x4
    let pool = match m {
        M::PoolWiderThanExtent => MaxPool2d { ksize: 9, stride: 1 },
        M::PoolZeroKsize => MaxPool2d { ksize: 0, stride: 2 },
        M::PoolZeroStride => MaxPool2d { ksize: 2, stride: 0 },
        _ => MaxPool2d { ksize: 2, stride: 2 },
    };
    stages.push(QuantStage::MaxPool2d(pool));

    // strided, widening residual: 4ch 4x4 -> 6ch 2x2 (projection required)
    let b1 = rand_conv(&mut rng, 4, 6, 3, 2, 1, stack_grid);
    let b2 = rand_conv(&mut rng, 6, 6, 3, 1, 1, b1.out_grid());
    let body_grid = b2.out_grid();
    let down = rand_conv(&mut rng, 4, 6, 1, 2, 0, stack_grid);
    let skip_grid = down.out_grid();
    let join_grid = QParams::new(0.9, NA, 0.0);
    let wrong = QParams::new(0.123, NA, 0.0);
    let add = match m {
        M::AddLutBodyGridMismatch => AddLut::build(wrong, skip_grid, join_grid),
        M::AddLutSkipGridMismatch => AddLut::build(body_grid, wrong, join_grid),
        _ => AddLut::build(body_grid, skip_grid, join_grid),
    };
    let down = if matches!(m, M::MissingProjection) { None } else { Some(down) };
    if !matches!(m, M::NoConvStages) {
        stages.push(QuantStage::Residual(Residual { body: vec![b1, b2], down, add }));
    }

    // tail: GAP over 6 channels on the join grid, head to 3 classes
    if matches!(m, M::MissingTail) {
        return stages;
    }
    let (gap_ch, gap_grid) = match m {
        M::GapChannelMismatch => (7, join_grid),
        M::GapGridMismatch => (6, wrong),
        // without conv stages the live grid is still the stem's
        M::NoConvStages => (2, stem_q),
        _ => (6, join_grid),
    };
    stages.push(QuantStage::GlobalAvgPool(GlobalAvgPool { channels: gap_ch, dq: gap_grid }));
    let d_in = if matches!(m, M::HeadDinMismatch) { 5 } else { gap_ch };
    let numel = if matches!(m, M::HeadWeightNumel) { d_in * 3 + 1 } else { d_in * 3 };
    stages.push(QuantStage::DenseHead(DenseHead {
        w: vec![0.1; numel],
        b: vec![0.0; 3],
        d_in,
        d_out: 3,
    }));
    if matches!(m, M::TrailingStage) {
        stages.push(QuantStage::MaxPool2d(MaxPool2d { ksize: 1, stride: 1 }));
    }
    stages
}

#[test]
fn mutated_specs_fail_with_typed_errors_not_panics() {
    // the unmutated base spec is valid...
    let g = QuantGraph::new_2d(build_spec(Mutation::None), 8, 8).expect("base spec");
    assert_eq!(g.classes(), 3);
    // ...and every single-field mutation is refused with a typed error
    // (an Err from the constructor — the sweep itself proves no panic)
    for m in [
        Mutation::DropStem,
        Mutation::StemZeroChannels,
        Mutation::ConvChannelMismatch,
        Mutation::EmptyStack,
        Mutation::PoolWiderThanExtent,
        Mutation::PoolZeroKsize,
        Mutation::PoolZeroStride,
        Mutation::MissingProjection,
        Mutation::AddLutBodyGridMismatch,
        Mutation::AddLutSkipGridMismatch,
        Mutation::GapChannelMismatch,
        Mutation::GapGridMismatch,
        Mutation::HeadDinMismatch,
        Mutation::HeadWeightNumel,
        Mutation::MissingTail,
        Mutation::TrailingStage,
        Mutation::NoConvStages,
    ] {
        let err = QuantGraph::new_2d(build_spec(m), 8, 8);
        assert!(err.is_err(), "{m:?}: mutated spec must be rejected");
        // errors are descriptive (they name a stage or a constraint)
        let msg = err.unwrap_err().to_string();
        assert!(!msg.is_empty(), "{m:?}: empty error message");
    }
}
