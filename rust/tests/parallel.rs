//! Determinism contract of the data-parallel execution layer: the engine
//! and the serve path must produce **bit-identical** outputs at every
//! pool size. Runs fully offline on a synthetic network — no artifacts
//! or XLA needed.

use std::sync::Arc;

use fqconv::data::{self, Dataset as _};
use fqconv::exec;
use fqconv::infer::pipeline::{global_avg_pool, Scratch};
use fqconv::infer::FqKwsNet;
use fqconv::quant::QParams;
use fqconv::serve::{BatchPolicy, NativeBackend, Server};
use fqconv::tensor::TensorF;

fn synthetic_batch(net_frames: usize, b: usize) -> TensorF {
    // real KWS MFCC features so the embedding sees realistic dynamics
    let ds = data::for_model("kws", &[39, net_frames], 12);
    let batch = ds.val_batch(0, b);
    batch.x
}

#[test]
fn forward_batch_bit_identical_at_pool_sizes_1_2_n() {
    for nw in [1.0f32, 7.0] {
        let net = FqKwsNet::synthetic(nw, 7.0, 42).expect("synthetic net");
        let x = synthetic_batch(net.frames, 13); // odd size: uneven partitions
        // sequential reference via the single-sample path
        let mut s = Scratch::default();
        let mut want = Vec::new();
        for i in 0..13 {
            let per = x.data().len() / 13;
            want.extend(net.forward(&x.data()[i * per..(i + 1) * per], &mut s));
        }
        for threads in [1usize, 2, 3, 8, 32] {
            let got = net.forward_batch_with(&x, threads);
            assert_eq!(
                got.data(),
                &want[..],
                "nw={nw} threads={threads}: parallel batch diverged from sequential"
            );
        }
    }
}

#[test]
fn intra_layer_gemm_threads_do_not_change_single_sample() {
    let net = FqKwsNet::synthetic(1.0, 7.0, 7).expect("synthetic net");
    let x = synthetic_batch(net.frames, 1);
    let mut s = Scratch::default();
    let want = net.forward(x.data(), &mut s);
    for threads in [2usize, 4, 16] {
        let got = net.forward_with(x.data(), &mut s, threads);
        assert_eq!(got, want, "intra-op threads={threads} changed the logits");
    }
}

#[test]
fn serve_path_bit_identical_at_every_worker_count() {
    let net = Arc::new(FqKwsNet::synthetic(1.0, 7.0, 99).expect("synthetic net"));
    let shape = vec![39usize, net.frames];
    let numel: usize = shape.iter().product();
    let ds = data::for_model("kws", &shape, 12);
    let feats: Vec<Vec<f32>> = (0..24).map(|i| ds.sample(i as u64, None).0).collect();

    let mut reference: Option<Vec<Vec<f32>>> = None;
    for workers in [1usize, 2, 4] {
        let factory = NativeBackend::factory(&net, &shape);
        let server = Server::start(factory, workers, numel, BatchPolicy::new(4, 500));
        let rxs: Vec<_> = feats.iter().map(|f| server.submit(f.clone())).collect();
        let logits: Vec<Vec<f32>> = rxs
            .into_iter()
            .map(|rx| rx.recv().expect("response").expect("serving ok").logits)
            .collect();
        server.shutdown();
        if let Some(want) = &reference {
            assert_eq!(&logits, want, "{workers}-worker serve path diverged");
        } else {
            reference = Some(logits);
        }
    }
}

#[test]
fn pool_and_scoped_fork_join_agree_on_the_net() {
    // the persistent pool replaced scoped spawning behind par_rows_mut;
    // both fork-join substrates must produce identical logits
    let net = FqKwsNet::synthetic(1.0, 7.0, 21).expect("synthetic net");
    let b = 9usize;
    let x = synthetic_batch(net.frames, b);
    let want = net.forward_batch_with(&x, 4); // persistent pool
    let per = x.data().len() / b;
    let mut out = vec![0f32; b * net.classes];
    exec::par_rows_mut_scoped(&mut out, b, net.classes, 4, |rows, window| {
        let mut s = Scratch::default();
        net.forward_rows(&x.data()[rows.start * per..rows.end * per], &mut s, window);
    });
    assert_eq!(want.data(), &out[..], "pool vs scoped fork-join diverged");
}

#[test]
fn concurrent_batch_calls_share_the_global_pool() {
    // several OS threads hammer forward_batch_with at once: the global
    // pool serializes forks internally and every caller still gets the
    // bit-exact sequential answer
    let net = Arc::new(FqKwsNet::synthetic(1.0, 7.0, 5).expect("synthetic net"));
    let x = synthetic_batch(net.frames, 8);
    let want = net.forward_batch_with(&x, 1);
    std::thread::scope(|sc| {
        for _ in 0..4 {
            let net = Arc::clone(&net);
            let (x, want) = (&x, &want);
            sc.spawn(move || {
                for threads in [2usize, 4, 8] {
                    let got = net.forward_batch_with(x, threads);
                    assert_eq!(got.data(), want.data(), "threads={threads}");
                }
            });
        }
    });
}

#[test]
fn global_avg_pool_survives_huge_time_axis() {
    // t_cur large enough that a sum of max-magnitude i8 codes overflows
    // i32 (127 * 20e6 ≈ 2.54e9 > 2^31): the old `sum as i32` truncated
    let (filters, t_cur) = (2usize, 20_000_000usize);
    let mut codes = vec![127i8; filters * t_cur];
    // second filter sums to a small negative in-range value
    for (i, v) in codes[t_cur..].iter_mut().enumerate() {
        *v = if i % 2 == 0 { -1 } else { 0 };
    }
    let dq = QParams::new(1.0, 7.0, 0.0);
    let pooled = global_avg_pool(&codes, filters, t_cur, &dq);
    let want0 = (127.0f64 / 7.0) as f32; // mean code 127 exactly
    assert!(
        (pooled[0] - want0).abs() < 1e-4,
        "wide sum truncated: got {} want {want0}",
        pooled[0]
    );
    assert!(pooled[0] > 0.0, "i32 wrap would flip the sign");
    let want1 = dq.dequantize_i64(-(t_cur as i64) / 2) / t_cur as f32;
    assert!((pooled[1] - want1).abs() < 1e-6);
}

#[test]
fn pooled_throughput_smoke() {
    // not a perf assert (CI machines vary) — just pins that the pooled
    // path computes the same argmaxes as sequential on a larger batch
    let net = FqKwsNet::synthetic(1.0, 7.0, 3).expect("synthetic net");
    let x = synthetic_batch(net.frames, 32);
    let seq = net.forward_batch_with(&x, 1);
    let par = net.forward_batch_with(&x, fqconv::exec::default_threads());
    assert_eq!(seq.argmax_rows(), par.argmax_rows());
    assert_eq!(seq.data(), par.data());
}
