//! Shared bench-target bootstrap (included via #[path] in each bench).

use fqconv::config::Budget;
use fqconv::exp::Ctx;
use fqconv::runtime::{Engine, Manifest};

/// Budget for table regenerators: FQCONV_BENCH_BUDGET=smoke|quick|full
/// (default quick — the fast, shape-preserving version of each table).
pub fn bench_budget() -> Budget {
    match std::env::var("FQCONV_BENCH_BUDGET").as_deref() {
        Ok("smoke") => Budget::smoke(),
        Ok("full") => Budget::full(),
        _ => Budget::quick(),
    }
}

pub fn setup() -> (Manifest, Engine) {
    let dir = fqconv::artifacts_dir();
    let manifest = Manifest::load(&dir).expect("manifest — run `make artifacts`");
    let engine = Engine::cpu().expect("PJRT engine");
    (manifest, engine)
}

pub fn ctx<'a>(engine: &'a Engine, manifest: &'a Manifest) -> Ctx<'a> {
    Ctx::new(engine, manifest, bench_budget())
}
