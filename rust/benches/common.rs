//! Shared bench-target bootstrap (included via #[path] in each bench).

use fqconv::config::Budget;
use fqconv::exp::Ctx;
use fqconv::runtime::{Engine, Manifest};

/// Budget for table regenerators: FQCONV_BENCH_BUDGET=smoke|quick|full
/// (default quick — the fast, shape-preserving version of each table).
#[allow(dead_code)]
pub fn bench_budget() -> Budget {
    match std::env::var("FQCONV_BENCH_BUDGET").as_deref() {
        Ok("smoke") => Budget::smoke(),
        Ok("full") => Budget::full(),
        _ => Budget::quick(),
    }
}

/// `None` when the artifacts or the PJRT runtime are unavailable (e.g.
/// offline builds against the vendored xla stub).
#[allow(dead_code)]
pub fn try_setup() -> Option<(Manifest, Engine)> {
    let dir = fqconv::artifacts_dir();
    let manifest = Manifest::load(&dir).ok()?;
    let engine = Engine::cpu().ok()?;
    Some((manifest, engine))
}

/// Like [`try_setup`] but exits the bench cleanly when unavailable —
/// artifact-driven table regenerators cannot run without the runtime.
#[allow(dead_code)]
pub fn setup() -> (Manifest, Engine) {
    match try_setup() {
        Some(pair) => pair,
        None => {
            eprintln!("bench skipped: artifacts / PJRT runtime unavailable (run `make artifacts`)");
            std::process::exit(0);
        }
    }
}

#[allow(dead_code)]
pub fn ctx<'a>(engine: &'a Engine, manifest: &'a Manifest) -> Ctx<'a> {
    Ctx::new(engine, manifest, bench_budget())
}
