//! Table 7 regenerator: noise on weights/activations/MACs for the
//! ternary networks, with and without noise-aware training. KWS column
//! runs on the analog crossbar simulator; the CIFAR column through the
//! noisy FQ forward artifact. Expected shape: σ<=5% harmless, large σ
//! degrades, noise training recovers most of the gap.
#[path = "common.rs"]
mod common;

fn main() {
    let (manifest, engine) = common::setup();
    let ctx = common::ctx(&engine, &manifest);
    fqconv::bench::banner("Table 7 — noise resilience (ternary networks)");
    fqconv::exp::table7_kws(&ctx, false).expect("table7 kws");
    fqconv::exp::table7_cifar(&ctx, "resnet14s", false).expect("table7 cifar");
}
