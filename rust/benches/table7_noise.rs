//! Table 7 — noise-resilience ladder on synthetic networks, fully
//! offline (no artifacts, no XLA): for each of the paper's three
//! architectures (KWS temporal-conv, ResNet-32, DarkNet-19) the analog
//! crossbar simulator walks the *full-size* graph in f64 code-space,
//! pins σ = 0 bit-identity against the integer engine (the release-mode
//! half of the acceptance criterion; debug-mode tests cover the small
//! variants), then sweeps the five §4.4 noise points measuring
//! *clean-agreement*: the fraction of (sample, rep) draws whose noisy
//! argmax matches the σ = 0 argmax. Expected shape: σ <= 5% is
//! essentially harmless, large σ degrades — the ladder must be weakly
//! monotone between its first and last rungs (deterministic: every draw
//! is seeded).
//!
//! The artifact-trained KWS/CIFAR regeneration (with noise-aware
//! fine-tuning) lives in `fqconv::exp::table7_kws` / `table7_cifar`.
//!
//! `FQCONV_BENCH_SMOKE=1` shrinks samples/reps (the CI bench-smoke job
//! greps the `table7 arch=` lines for all three architectures).

use std::sync::Arc;

use fqconv::analog::{argmax, CrossbarSim, NoiseConfig};
use fqconv::bench::banner;
use fqconv::infer::graph::{synthetic_graph, Scratch, SynthArch};
use fqconv::util::Rng;

fn smoke() -> bool {
    std::env::var("FQCONV_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// One architecture's ladder: σ = 0 identity pin + the five-point sweep.
fn ladder(arch: &SynthArch, samples: usize, reps: usize) {
    let graph = Arc::new(synthetic_graph(arch, 1.0, 7.0, 7).expect("synthetic graph"));
    let mut sim = CrossbarSim::new(Arc::clone(&graph));
    let mut s = Scratch::for_graph(&graph);
    let mut s_eng = Scratch::for_graph(&graph);
    let mut logits = vec![0f32; graph.classes()];
    let mut eng = vec![0f32; graph.classes()];

    // deterministic synthetic inputs
    let mut rng = Rng::new(0x7AB1E7 ^ samples as u64);
    let xs: Vec<Vec<f32>> = (0..samples)
        .map(|_| {
            let mut x = vec![0f32; graph.in_numel()];
            rng.fill_gaussian(&mut x, 0.8);
            x
        })
        .collect();

    // σ = 0: the always-analog walk must be bit-identical to the
    // integer engine on the full-size graph, at more than one digital
    // thread budget
    let mut clean_class = Vec::with_capacity(samples);
    for x in &xs {
        sim.forward_analog_into(x, NoiseConfig::default(), &mut rng, &mut s, &mut logits);
        for threads in [1usize, 2] {
            graph.forward_into(x, &mut s_eng, &mut eng, threads);
            assert_eq!(
                logits,
                eng,
                "σ=0 analog walk diverged from the integer engine on {}",
                arch.name()
            );
        }
        clean_class.push(argmax(&logits));
    }

    // the five-point ladder: clean-agreement per noise point
    let mut agreements = Vec::new();
    for noise in NoiseConfig::table7_points() {
        let mut agree = 0usize;
        let mut total = 0usize;
        for rep in 0..reps {
            let mut nrng = Rng::new(17 ^ (rep as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            for (x, &want) in xs.iter().zip(clean_class.iter()) {
                sim.forward_noisy_into(x, noise, &mut nrng, &mut s, &mut logits);
                total += 1;
                if argmax(&logits) == want {
                    agree += 1;
                }
            }
        }
        let frac = agree as f64 / total as f64;
        println!(
            "table7 arch={} noise=\"{}\" clean_agreement={frac:.3}",
            arch.name(),
            noise.label()
        );
        agreements.push(frac);
    }
    assert!(
        agreements[agreements.len() - 1] <= agreements[0],
        "{}: the σ ladder must degrade weakly monotonically (first {} -> last {})",
        arch.name(),
        agreements[0],
        agreements[agreements.len() - 1],
    );
}

fn main() {
    banner("Table 7 — noise resilience on synthetic ladders (analog crossbar sim)");
    let archs = [SynthArch::kws(), SynthArch::resnet32(), SynthArch::darknet19()];
    for arch in &archs {
        let (samples, reps) = if smoke() {
            (2, 1)
        } else {
            match arch {
                SynthArch::Seq(_) => (16, 3),
                SynthArch::Img(_) => (6, 2),
                SynthArch::Dark(_) => (3, 1),
            }
        };
        ladder(arch, samples, reps);
    }
}
