//! Perf: the MFCC front end (FFT -> mel -> DCT -> deltas) and the
//! synthetic audio generator — the serving path's preprocessing cost.
#[path = "common.rs"]
mod common;

use fqconv::bench::{banner, bench};
use fqconv::data::dsp::{Mfcc, MfccConfig};
use fqconv::data::kws::{KwsConfig, KwsDataset};
use fqconv::data::Dataset;
use fqconv::util::Rng;

fn main() {
    banner("perf_dsp — MFCC front end");
    let mfcc = Mfcc::new(MfccConfig::default());
    let n = mfcc.samples_for_frames(80);
    let mut rng = Rng::new(2);
    let mut sig = vec![0f32; n];
    rng.fill_gaussian(&mut sig, 0.3);

    let s = bench("MFCC 13-coeff (80 frames)", 5, 200, || {
        std::hint::black_box(mfcc.compute(&sig));
    });
    println!("{}", s.report());
    let s = bench("MFCC+deltas 39-dim (80 frames)", 5, 200, || {
        std::hint::black_box(mfcc.compute_with_deltas(&sig));
    });
    println!("{}", s.report());
    println!("    = {:.0} clips/s/core", 1.0 / s.median_s);

    let ds = KwsDataset::new(KwsConfig::default());
    let s = bench("full sample gen (waveform+aug+MFCC)", 5, 100, || {
        std::hint::black_box(ds.sample(12345, Some(&mut rng)));
    });
    println!("{}", s.report());
}
