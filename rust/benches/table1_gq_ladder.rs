//! Table 1 regenerator: gradual quantization of a CIFAR-10-like ResNet,
//! GQ vs no-GQ. Expected shape: accuracies track FP down to ~3 bits and
//! the no-GQ column collapses at ternary (the paper's 79.9-point gap).
#[path = "common.rs"]
mod common;

fn main() {
    let (manifest, engine) = common::setup();
    let ctx = common::ctx(&engine, &manifest);
    fqconv::bench::banner("Table 1 — GQ ladder (resnet8s, synthetic CIFAR-10-like)");
    fqconv::exp::table1(&ctx, "resnet8s").expect("table1");
}
