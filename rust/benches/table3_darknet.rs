//! Table 3 regenerator: the DarkNet ladder on the ImageNet-64 stand-in.
//! Expected shape: top-1/top-5 flat down the ladder until a moderate
//! ternary drop (paper: 2.4/1.3 points).
#[path = "common.rs"]
mod common;

fn main() {
    let (manifest, engine) = common::setup();
    let ctx = common::ctx(&engine, &manifest);
    fqconv::bench::banner("Table 3 — DarkNet-tiny ladder (synthetic ImageNet-64-like)");
    fqconv::exp::table3(&ctx).expect("table3");
}
