//! Perf: PJRT runtime — train-step latency, forward latency, host-copy
//! overhead (literal build + fetch vs pure execute). Feeds EXPERIMENTS.md
//! §Perf (L3 target: non-XLA driver overhead < 10% of step time).
#[path = "common.rs"]
mod common;

use fqconv::bench::{banner, bench};
use fqconv::coordinator::{checkpoint, Trainer, Variant};
use fqconv::data::{self, Dataset as _};
use fqconv::runtime::{hp, lit_f32, lit_to_vec_f32};
use fqconv::util::Rng;

fn main() {
    banner("perf_runtime — PJRT execute + host-copy overhead");
    let (manifest, engine) = common::setup();
    for model in ["kws", "resnet8s"] {
        let info = manifest.model(model).unwrap();
        let mut t = Trainer::new(&engine, &manifest, model, Variant::Qat("")).unwrap();
        t.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();
        let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
        let mut rng = Rng::new(3);
        let batch = ds.train_batch(info.batch, &mut rng);
        let mut hpv = hp::defaults();
        hpv[hp::LR] = 0.005;
        hpv[hp::NW] = 1.0;
        hpv[hp::NA] = 7.0;
        let s = bench(&format!("{model} train step (full, incl. literals)"), 3, 20, || {
            std::hint::black_box(t.step(&batch, None, &hpv).unwrap());
        });
        println!("{}", s.report());
        println!(
            "    = {:.1} samples/s (batch {})",
            info.batch as f64 / s.median_s,
            info.batch
        );
        let s = bench(&format!("{model} eval forward (batch)"), 3, 30, || {
            std::hint::black_box(t.forward(&batch.x, &hpv).unwrap());
        });
        println!("{}", s.report());
        // literal-building overhead alone (the host-copy part of a step)
        let numel: usize = info.input_shape.iter().product();
        let data = vec![0.5f32; info.batch * numel];
        let mut shape = vec![info.batch];
        shape.extend(&info.input_shape);
        let s = bench(&format!("{model} literal build+read roundtrip"), 5, 100, || {
            let l = lit_f32(&shape, &data);
            std::hint::black_box(lit_to_vec_f32(&l).unwrap());
        });
        println!("{}", s.report());
    }
}
