//! Perf: serving layer — throughput/latency across batching policies and
//! worker counts under open-loop load, over the shared two-lane work
//! queue, plus a mixed-priority paced section. Feeds EXPERIMENTS.md
//! §Perf (target: p99 < 5 ms at the default policy on the KWS net).
//! Falls back to a synthetic network offline.
//!
//! Emits a machine-readable `BENCH_serve.json` at the repository root
//! (req/s, p50/p99 latency, mean batch size per configuration,
//! per-priority p50/p99 from the mixed-priority run, and the `batch_2d`
//! section — GraphBackend sample-parallel batched image serving vs the
//! sequential per-sample walk, for ResNet-32 and DarkNet-19 — plus the
//! `saturation` section: interactive KWS p50/p99 and the flood's shed
//! rate while a darknet19 batch lane is 10x oversubscribed behind a
//! bounded admission queue — plus the `streaming` section: a
//! 10k-concurrent-session sweep over the stateful stream path reporting
//! sessions held, frames/s, per-session resident bytes from the state
//! plan, and closed-loop p99 feed latency — plus the `obs_overhead`
//! section: the same unpaced workload with the observability layer on
//! vs off, pinning tracing+metrics cost to within 2% of metrics-off
//! throughput — plus the `noise` section: the synthetic KWS graph
//! served plain vs as an N=8 Monte-Carlo crossbar ensemble
//! (`ModelSpec::with_noise`), reporting the ensemble throughput cost)
//! so the serving-perf trajectory is tracked across PRs.
//! `FQCONV_BENCH_SMOKE=1` shrinks the load to one short iteration.
#[path = "common.rs"]
mod common;

use std::sync::Arc;

use fqconv::analog::NoiseConfig;
use fqconv::bench::{banner, bench};
use fqconv::coordinator::{checkpoint, fq_transform, Trainer, Variant};
use fqconv::data::{self, Dataset as _};
use fqconv::exec;
use fqconv::infer::graph::{synthetic_graph, Scratch, SynthArch};
use fqconv::infer::FqKwsNet;
use fqconv::obs::ObsConfig;
use fqconv::serve::{
    AdmissionPolicy, Backend as _, BatchPolicy, GraphBackend, ModelId, ModelRegistry, ModelSpec,
    NativeBackend, NoiseSpec, Priority, ServeError, Server, StreamSpec, Vote,
};
use fqconv::util::json::{num, obj, s, Json};
use fqconv::util::{Rng, Timer};

fn smoke() -> bool {
    std::env::var("FQCONV_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn main() {
    banner("perf_serve — registry + dynamic batcher (two-lane shared queue)");
    // trained FQ parameters when the runtime is present, synthetic net
    // otherwise (identical serving mechanics either way)
    let net = match common::try_setup() {
        Some((manifest, engine)) => {
            let info = manifest.model("kws").unwrap();
            let mut t = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
            t.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap())
                .unwrap();
            let fq_graph = info.fq.clone().unwrap();
            let params = fq_transform::qat_to_fq(info, &fq_graph, &t.params).unwrap();
            Arc::new(FqKwsNet::from_params(&params, 1.0, 7.0, info.input_shape[1]).unwrap())
        }
        None => {
            println!("(artifacts unavailable — serving the synthetic KWS net)");
            Arc::new(FqKwsNet::synthetic(1.0, 7.0, 7).expect("synthetic net"))
        }
    };
    let shape = vec![39usize, net.frames];
    let ds = data::for_model("kws", &shape, net.classes);
    let numel: usize = shape.iter().product();
    // pre-generate request features (exclude datagen from the measurement)
    let n_requests = if smoke() { 96 } else { 512 };
    let mut rng = Rng::new(1);
    let feats: Vec<Vec<f32>> =
        (0..n_requests).map(|i| ds.sample(i as u64 % 512, Some(&mut rng)).0).collect();

    // NOTE: the sweep below is an *unpaced* open loop — it measures
    // saturation throughput; latency there is queueing-dominated. The
    // paced run afterwards measures service latency at ~60% utilization,
    // which is what the p99 target applies to.
    println!(
        "{:<34} {:>9} {:>9} {:>9} {:>9}  {}",
        "config", "req/s", "p50(us)", "p99(us)", "meanB", "per-worker batches"
    );
    let mut sweep_json = Vec::new();
    for workers in [1usize, 2, 4] {
        for (mb, wait) in [(1usize, 1u64), (16, 2000), (32, 4000)] {
            let policy = BatchPolicy::new(mb, wait);
            // worker-count-aware intra-layer budget: replicas split the
            // machine instead of contending on the pool's fork lock
            let factory = NativeBackend::factory_sharded(&net, &shape, workers);
            let server = Server::start(factory, workers, numel, policy);
            let timer = Timer::start();
            let rxs: Vec<_> = feats.iter().map(|f| server.submit(f.clone())).collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
            let dt = timer.elapsed_s();
            let stats = server.stats();
            let per_worker: Vec<u64> = stats.workers.iter().map(|w| w.batches).collect();
            let rps = feats.len() as f64 / dt;
            println!(
                "{:<34} {:>9.0} {:>9.0} {:>9.0} {:>9.1}  {:?}",
                format!("w={workers} max_batch={mb} wait={wait}us"),
                rps,
                stats.p50_us,
                stats.p99_us,
                stats.mean_batch,
                per_worker
            );
            sweep_json.push(obj(vec![
                ("workers", num(workers as f64)),
                ("max_batch", num(mb as f64)),
                ("max_wait_us", num(wait as f64)),
                ("req_per_sec", num(rps)),
                ("p50_us", num(stats.p50_us)),
                ("p99_us", num(stats.p99_us)),
                ("mean_batch", num(stats.mean_batch)),
            ]));
            server.shutdown();
        }
    }

    // paced run: ~1000 req/s offered vs saturation capacity
    let server =
        Server::start(NativeBackend::factory(&net, &shape), 1, numel, BatchPolicy::new(8, 1000));
    let mut rxs = Vec::new();
    for f in feats.iter() {
        rxs.push(server.submit(f.clone()));
        std::thread::sleep(std::time::Duration::from_micros(1000));
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let stats = server.stats();
    println!(
        "paced 1000 req/s (60% util):        p50 {:.0}us  p99 {:.0}us  meanB {:.1}",
        stats.p50_us, stats.p99_us, stats.mean_batch
    );
    server.shutdown();

    // mixed-priority paced run: 3:1 Interactive:Batch — the per-priority
    // p50/p99 split is the headline observability for priority classes
    let server =
        Server::start(NativeBackend::factory(&net, &shape), 2, numel, BatchPolicy::new(8, 1000));
    let mut rxs = Vec::new();
    for (i, f) in feats.iter().enumerate() {
        let prio = if i % 4 == 3 { Priority::Batch } else { Priority::Interactive };
        rxs.push(server.submit_with(f.clone(), prio, None));
        std::thread::sleep(std::time::Duration::from_micros(800));
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let mixed = server.stats();
    let pi = &mixed.priorities[Priority::Interactive.index()];
    let pb = &mixed.priorities[Priority::Batch.index()];
    println!(
        "mixed-priority paced:  interactive p50 {:.0}us p99 {:.0}us ({} served) | \
         batch p50 {:.0}us p99 {:.0}us ({} served)",
        pi.p50_us, pi.p99_us, pi.served, pb.p50_us, pb.p99_us, pb.served
    );
    server.shutdown();

    // batched 2-D serving: the GraphBackend batch path (sample-parallel
    // forward_batch_into across the intra-layer budget) against the
    // sequential per-sample walk it replaced — the acceptance number is
    // batched samples/sec >= the sequential-walk baseline
    println!("\n--- batched 2-D serving (GraphBackend, sample-parallel vs sequential walk) ---");
    let threads = exec::default_threads();
    let mut batch2d_json = Vec::new();
    for arch in [SynthArch::resnet32(), SynthArch::darknet19()] {
        let tag = arch.name();
        let graph = Arc::new(synthetic_graph(&arch, 1.0, 7.0, 7).expect("2-D graph"));
        let b = if smoke() { 4usize } else { 16 };
        let iters = if smoke() { 2 } else { 5 };
        let mut rng = Rng::new(5);
        let mut flat = vec![0f32; b * graph.in_numel()];
        rng.fill_gaussian(&mut flat, 0.5);
        let mut out_seq = vec![0f32; b * graph.classes()];
        let mut out_par = vec![0f32; b * graph.classes()];
        // intra budget 1 == the old sequential per-sample walk
        let mut seq = GraphBackend::with_intra_threads(Arc::clone(&graph), 1);
        let mut par = GraphBackend::with_intra_threads(Arc::clone(&graph), threads);
        let st_seq = bench(&format!("{tag} batch({b}) sequential walk"), 1, iters, || {
            seq.infer_into(&flat, b, &mut out_seq).expect("sequential infer");
            std::hint::black_box(&out_seq);
        });
        let st_par = bench(&format!("{tag} batch({b}) sample-parallel x{threads}"), 1, iters, || {
            par.infer_into(&flat, b, &mut out_par).expect("batched infer");
            std::hint::black_box(&out_par);
        });
        assert_eq!(out_par, out_seq, "{tag}: batched path diverged from the sequential walk");
        let speedup = st_seq.median_s / st_par.median_s.max(1e-12);
        println!(
            "{tag} batch {b}: {:.0} -> {:.0} samples/s  ({speedup:.2}x, {threads} threads)",
            b as f64 / st_seq.median_s,
            b as f64 / st_par.median_s
        );
        batch2d_json.push(obj(vec![
            ("model", s(tag)),
            ("batch", num(b as f64)),
            ("threads", num(threads as f64)),
            ("seq_samples_per_sec", num(b as f64 / st_seq.median_s)),
            ("batched_samples_per_sec", num(b as f64 / st_par.median_s)),
            ("speedup_vs_sequential_walk", num(speedup)),
        ]));
    }

    // overload saturation: interactive KWS next to a 10x-oversubscribed
    // darknet19 batch flood behind a bounded admission queue and a
    // replica budget of 1 — the robustness headline is the interactive
    // p99 ratio vs the unloaded baseline plus the flood's shed rate
    println!("\n--- saturation: interactive KWS vs 10x-oversubscribed darknet19 flood ---");
    let dark = Arc::new(synthetic_graph(&SynthArch::darknet19(), 1.0, 7.0, 7).expect("darknet19"));
    let mut dark_in = vec![0f32; dark.in_numel()];
    Rng::new(9).fill_gaussian(&mut dark_in, 0.5);
    // best-of-3 single-sample service time sets the flood pace
    let mut scratch = Scratch::for_graph(&dark);
    let mut t_dark = f64::MAX;
    for _ in 0..3 {
        let t = Timer::start();
        std::hint::black_box(dark.forward(&dark_in, &mut scratch));
        t_dark = t_dark.min(t.elapsed_s());
    }
    let sat_workers = 2usize;
    let overload = 10.0f64;
    let n_inter = if smoke() { 60usize } else { 200 };
    let n_flood = if smoke() { 40usize } else { 300 };
    let kws_spec = || {
        ModelSpec::new(
            NativeBackend::factory_sharded(&net, &shape, sat_workers),
            numel,
            BatchPolicy::new(8, 1000),
        )
        .with_cost(net.cost_per_sample())
    };
    let kid = ModelId::new("kws");
    // unloaded baseline: the same paced interactive traffic, no flood
    let registry = ModelRegistry::start(sat_workers);
    registry.register("kws", kws_spec()).expect("register kws");
    let mut rxs = Vec::new();
    for f in feats.iter().take(n_inter) {
        rxs.push(registry.submit_with(&kid, f.clone(), Priority::Interactive, None).expect("kws"));
        std::thread::sleep(std::time::Duration::from_micros(800));
    }
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let base = registry.stats();
    let base_p99 = base.models[0].priorities[Priority::Interactive.index()].p99_us;
    registry.shutdown();

    // saturated run: identical interactive traffic + the flood. The
    // flood model rides the Batch lane behind a pending bound of 8 and
    // a replica budget of 1, so one worker grinds the flood while the
    // rest of the pool keeps interactive headroom.
    let registry = ModelRegistry::start(sat_workers);
    registry.register("kws", kws_spec()).expect("register kws");
    registry
        .register(
            "darknet19",
            ModelSpec::new(
                GraphBackend::factory_sharded(&dark, sat_workers),
                dark.in_numel(),
                BatchPolicy::new(2, 2000),
            )
            .with_cost(dark.cost_per_sample())
            .with_admission(AdmissionPolicy::bounded(8)),
        )
        .expect("register darknet19");
    let did = ModelId::new("darknet19");
    registry.set_replica_budget(&did, 1);
    // inter-arrival for `overload`x the pool's single-sample capacity
    let flood_gap_us = (t_dark * 1e6 / (sat_workers as f64 * overload)).max(1.0) as u64;
    std::thread::scope(|scope| {
        let (reg, kid, did) = (&registry, &kid, &did);
        let (feats, dark_in) = (&feats, &dark_in);
        scope.spawn(move || {
            let mut rxs = Vec::new();
            for _ in 0..n_flood {
                match reg.submit_with(did, dark_in.clone(), Priority::Batch, None) {
                    Ok(rx) => rxs.push(rx),
                    // over the bound: the typed shed *is* the measurement
                    Err(ServeError::Overloaded { .. }) => {}
                    Err(e) => panic!("flood submit failed: {e}"),
                }
                std::thread::sleep(std::time::Duration::from_micros(flood_gap_us));
            }
            for rx in rxs {
                rx.recv().expect("flood reply").expect("flood served");
            }
        });
        let mut rxs = Vec::new();
        for f in feats.iter().take(n_inter) {
            rxs.push(reg.submit_with(kid, f.clone(), Priority::Interactive, None).expect("kws"));
            std::thread::sleep(std::time::Duration::from_micros(800));
        }
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
    });
    let sat = registry.stats();
    let km = sat.models.iter().find(|m| m.id == kid).expect("kws stats");
    let kp = &km.priorities[Priority::Interactive.index()];
    let dm = sat.models.iter().find(|m| m.id == did).expect("darknet19 stats");
    let shed_rate = dm.shed as f64 / n_flood as f64;
    let p99_ratio = kp.p99_us / base_p99.max(1.0);
    println!(
        "interactive p99 {:.0}us (unloaded {base_p99:.0}us, {p99_ratio:.2}x) | flood: {n_flood} \
         offered, {} shed ({:.0}% shed rate)",
        kp.p99_us,
        dm.shed,
        shed_rate * 100.0
    );
    registry.shutdown();

    // streaming sessions: the stateful per-stream path. Hold a large
    // population of concurrent sessions (the ROADMAP shape: tens of
    // thousands of always-on streams per process), push frames through
    // the shared worker pool in waves for throughput, then measure
    // closed-loop per-feed service latency one round trip at a time.
    // Resident memory is exactly the state plan's bytes_per_session —
    // pinned by tests to not grow across feeds — so sessions * that
    // figure is the RSS proxy reported here.
    println!("\n--- streaming: concurrent stateful sessions (incremental dilated-conv) ---");
    let sgraph = Arc::new(synthetic_graph(&SynthArch::kws(), 1.0, 7.0, 7).expect("kws graph"));
    let stream_workers = if smoke() { 2usize } else { 4 };
    let n_sessions = if smoke() { 64usize } else { 10_000 };
    let waves = if smoke() { 2usize } else { 4 };
    let spec = ModelSpec::new(
        GraphBackend::factory_sharded(&sgraph, stream_workers),
        sgraph.in_numel(),
        BatchPolicy::default(),
    )
    .with_cost(sgraph.cost_per_sample())
    .with_streaming(StreamSpec {
        graph: Arc::clone(&sgraph),
        max_sessions: n_sessions,
        idle_timeout: std::time::Duration::from_secs(120),
    });
    let server = Server::start_spec(spec, stream_workers);
    let sinfo = server.registry().stream_info(server.model_id()).expect("streaming model");
    let t_open = Timer::start();
    let sessions: Vec<_> =
        (0..n_sessions).map(|_| server.open_session().expect("under bound")).collect();
    let sessions_per_sec = n_sessions as f64 / t_open.elapsed_s().max(1e-9);
    // one frame per wave, cloned per feed — contents don't affect cost
    let mut frame = vec![0f32; sinfo.frame_dim];
    Rng::new(11).fill_gaussian(&mut frame, 1.0);
    let t_feed = Timer::start();
    let mut replies = Vec::with_capacity(n_sessions);
    for _ in 0..waves {
        replies.clear();
        for &sid in &sessions {
            replies.push(server.feed(sid, frame.clone()).expect("open session"));
        }
        for rx in &replies {
            rx.recv().expect("feed reply").expect("feed served");
        }
    }
    let frames_per_sec = (n_sessions * waves) as f64 / t_feed.elapsed_s().max(1e-9);
    // closed-loop service latency: one in-flight feed at a time
    let mut lat_us: Vec<f64> = Vec::with_capacity(n_sessions);
    for &sid in &sessions {
        let t = Timer::start();
        let rx = server.feed(sid, frame.clone()).expect("open session");
        rx.recv().expect("feed reply").expect("feed served");
        lat_us.push(t.elapsed_s() * 1e6);
    }
    lat_us.sort_by(|a, b| a.total_cmp(b));
    let pct = |p: f64| lat_us[((lat_us.len() - 1) as f64 * p) as usize];
    let (feed_p50, feed_p99) = (pct(0.50), pct(0.99));
    println!(
        "{n_sessions} sessions (opened at {sessions_per_sec:.0}/s, {stream_workers} workers): \
         {frames_per_sec:.0} frames/s | feed p50 {feed_p50:.0}us p99 {feed_p99:.0}us | \
         {} bytes/session ({} KiB resident)",
        sinfo.bytes_per_session,
        sinfo.bytes_per_session * n_sessions / 1024
    );
    for &sid in &sessions {
        server.close_session(sid).expect("open session");
    }
    server.shutdown();

    // observability overhead: the identical unpaced workload with the
    // obs layer on (tracing + metrics, the default) vs off — the
    // acceptance bound is metrics-on throughput within 2% of metrics-off
    println!("\n--- observability overhead (metrics+tracing on vs off) ---");
    let obs_workers = 2usize;
    let mut obs_rps = [0f64; 2];
    for (k, (label, cfg)) in
        [("on", ObsConfig::default()), ("off", ObsConfig::disabled())].into_iter().enumerate()
    {
        let spec = ModelSpec::new(
            NativeBackend::factory_sharded(&net, &shape, obs_workers),
            numel,
            BatchPolicy::new(16, 2000),
        )
        .with_cost(net.cost_per_sample());
        let server = Server::start_spec_obs(spec, obs_workers, cfg);
        // short warm-up so replica construction is off the clock
        for f in feats.iter().take(8) {
            server.submit(f.clone()).recv().unwrap().unwrap();
        }
        let timer = Timer::start();
        let rxs: Vec<_> = feats.iter().map(|f| server.submit(f.clone())).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        obs_rps[k] = feats.len() as f64 / timer.elapsed_s();
        println!("obs {label:<3}: {:.0} req/s", obs_rps[k]);
        server.shutdown();
    }
    let obs_overhead_pct = (obs_rps[1] - obs_rps[0]) / obs_rps[1].max(1e-9) * 100.0;
    println!("observability overhead: {obs_overhead_pct:.2}% of metrics-off throughput");

    // noisy Monte-Carlo ensemble serving: the same synthetic KWS graph
    // served plain (replicas = 1 delegates to the wrapped backend) and
    // as an N=8 crossbar ensemble, measuring the throughput cost of N
    // independent f64 noise walks per request
    println!("\n--- noise: Monte-Carlo ensemble serving (N-replica crossbar sim) ---");
    let noise_workers = 2usize;
    let noise_replicas = 8usize;
    let ngraph = Arc::new(synthetic_graph(&SynthArch::kws(), 1.0, 7.0, 7).expect("synthetic kws"));
    let n_noise = if smoke() { 16usize } else { 96 };
    let mut noise_rng = Rng::new(0x4015E);
    let noise_feats: Vec<Vec<f32>> = (0..n_noise)
        .map(|_| {
            let mut v = vec![0f32; ngraph.in_numel()];
            noise_rng.fill_gaussian(&mut v, 0.8);
            v
        })
        .collect();
    let mut noise_rps = [0f64; 2];
    let mut ensemble_in_stats = 0usize;
    for (k, replicas) in [1usize, noise_replicas].into_iter().enumerate() {
        let spec = ModelSpec::new(
            GraphBackend::factory_sharded(&ngraph, noise_workers),
            ngraph.in_numel(),
            BatchPolicy::new(8, 1000),
        )
        .with_cost(ngraph.cost_per_sample())
        .with_noise(NoiseSpec {
            graph: Arc::clone(&ngraph),
            noise: NoiseConfig { sigma_w: 10.0, sigma_a: 10.0, sigma_mac: 50.0 },
            replicas,
            vote: Vote::MeanLogit,
            seed: 42,
        });
        let server = Server::start_spec(spec, noise_workers);
        for f in noise_feats.iter().take(4) {
            server.submit(f.clone()).recv().unwrap().unwrap();
        }
        let timer = Timer::start();
        let rxs: Vec<_> = noise_feats.iter().map(|f| server.submit(f.clone())).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        noise_rps[k] = noise_feats.len() as f64 / timer.elapsed_s();
        if replicas > 1 {
            ensemble_in_stats = server.registry().stats().models[0].ensemble;
        }
        println!("replicas {replicas}: {:.0} req/s", noise_rps[k]);
        server.shutdown();
    }
    let noise_cost_x = noise_rps[0] / noise_rps[1].max(1e-9);
    println!(
        "ensemble N={noise_replicas} costs {noise_cost_x:.1}x baseline throughput \
         (ensemble size in stats: {ensemble_in_stats})"
    );

    let prio_json = |p: &fqconv::serve::PriorityStats| {
        obj(vec![
            ("served", num(p.served as f64)),
            ("p50_us", num(p.p50_us)),
            ("p99_us", num(p.p99_us)),
        ])
    };
    let out = obj(vec![
        ("bench", s("perf_serve")),
        ("smoke", Json::Bool(smoke())),
        ("requests", num(n_requests as f64)),
        ("sweep", Json::Arr(sweep_json)),
        (
            "paced_1000rps",
            obj(vec![
                ("p50_us", num(stats.p50_us)),
                ("p99_us", num(stats.p99_us)),
                ("mean_batch", num(stats.mean_batch)),
            ]),
        ),
        (
            "per_priority",
            obj(vec![
                ("interactive", prio_json(pi)),
                ("batch", prio_json(pb)),
                ("expired", num(mixed.expired as f64)),
            ]),
        ),
        ("batch_2d", Json::Arr(batch2d_json)),
        (
            "saturation",
            obj(vec![
                ("workers", num(sat_workers as f64)),
                ("overload_factor", num(overload)),
                ("kws_unloaded_p99_us", num(base_p99)),
                ("kws_p50_us", num(kp.p50_us)),
                ("kws_p99_us", num(kp.p99_us)),
                ("p99_ratio_vs_unloaded", num(p99_ratio)),
                ("dark_offered", num(n_flood as f64)),
                ("dark_shed", num(dm.shed as f64)),
                ("shed_rate", num(shed_rate)),
            ]),
        ),
        (
            "streaming",
            obj(vec![
                ("sessions", num(n_sessions as f64)),
                ("workers", num(stream_workers as f64)),
                ("waves", num(waves as f64)),
                ("sessions_per_sec", num(sessions_per_sec)),
                ("frames_per_sec", num(frames_per_sec)),
                ("bytes_per_session", num(sinfo.bytes_per_session as f64)),
                ("feed_p50_us", num(feed_p50)),
                ("feed_p99_us", num(feed_p99)),
            ]),
        ),
        (
            "obs_overhead",
            obj(vec![
                ("workers", num(obs_workers as f64)),
                ("on_req_per_sec", num(obs_rps[0])),
                ("off_req_per_sec", num(obs_rps[1])),
                ("overhead_pct", num(obs_overhead_pct)),
            ]),
        ),
        (
            "noise",
            obj(vec![
                ("workers", num(noise_workers as f64)),
                ("replicas", num(noise_replicas as f64)),
                ("requests", num(n_noise as f64)),
                ("baseline_req_per_sec", num(noise_rps[0])),
                ("ensemble_req_per_sec", num(noise_rps[1])),
                ("throughput_cost_x", num(noise_cost_x)),
                ("ensemble_in_stats", num(ensemble_in_stats as f64)),
            ]),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    match std::fs::write(path, out.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}
