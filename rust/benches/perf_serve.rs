//! Perf: serving layer — throughput/latency across batching policies and
//! worker counts under open-loop load, over the shared work queue. Feeds
//! EXPERIMENTS.md §Perf (target: p99 < 5 ms at the default policy on the
//! KWS net). Falls back to a synthetic network offline.
#[path = "common.rs"]
mod common;

use fqconv::bench::banner;
use fqconv::coordinator::{checkpoint, fq_transform, Trainer, Variant};
use fqconv::data::{self, Dataset as _};
use fqconv::infer::FqKwsNet;
use fqconv::serve::{ready, BatchPolicy, NativeBackend, Server};
use fqconv::util::{Rng, Timer};

fn main() {
    banner("perf_serve — router + dynamic batcher (shared work queue)");
    // trained FQ parameters when the runtime is present, synthetic net
    // otherwise (identical serving mechanics either way)
    let net = match common::try_setup() {
        Some((manifest, engine)) => {
            let info = manifest.model("kws").unwrap();
            let mut t = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
            t.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap())
                .unwrap();
            let fq_graph = info.fq.clone().unwrap();
            let params = fq_transform::qat_to_fq(info, &fq_graph, &t.params).unwrap();
            std::sync::Arc::new(
                FqKwsNet::from_params(&params, 1.0, 7.0, info.input_shape[1]).unwrap(),
            )
        }
        None => {
            println!("(artifacts unavailable — serving the synthetic KWS net)");
            std::sync::Arc::new(FqKwsNet::synthetic(1.0, 7.0, 7).expect("synthetic net"))
        }
    };
    let shape = vec![39usize, net.frames];
    let ds = data::for_model("kws", &shape, net.classes);
    let numel: usize = shape.iter().product();
    // pre-generate request features (exclude datagen from the measurement)
    let mut rng = Rng::new(1);
    let feats: Vec<Vec<f32>> =
        (0..512).map(|i| ds.sample(i as u64 % 512, Some(&mut rng)).0).collect();

    // NOTE: the sweep below is an *unpaced* open loop — it measures
    // saturation throughput; latency there is queueing-dominated. The
    // paced run afterwards measures service latency at ~60% utilization,
    // which is what the p99 target applies to.
    println!(
        "{:<34} {:>9} {:>9} {:>9} {:>9}  {}",
        "config", "req/s", "p50(us)", "p99(us)", "meanB", "per-worker batches"
    );
    for workers in [1usize, 2, 4] {
        for (mb, wait) in [(1usize, 1u64), (16, 2000), (32, 4000)] {
            let factories = (0..workers)
                .map(|_| ready(NativeBackend::new(net.clone(), shape.clone())))
                .collect();
            let server = Server::start_with(factories, numel, BatchPolicy::new(mb, wait));
            let timer = Timer::start();
            let rxs: Vec<_> = feats.iter().map(|f| server.submit(f.clone())).collect();
            for rx in rxs {
                rx.recv().unwrap();
            }
            let dt = timer.elapsed_s();
            let stats = server.stats();
            let per_worker: Vec<u64> = stats.workers.iter().map(|w| w.batches).collect();
            println!(
                "{:<34} {:>9.0} {:>9.0} {:>9.0} {:>9.1}  {:?}",
                format!("w={workers} max_batch={mb} wait={wait}us"),
                feats.len() as f64 / dt,
                stats.p50_us,
                stats.p99_us,
                stats.mean_batch,
                per_worker
            );
            server.shutdown();
        }
    }

    // paced run: ~1000 req/s offered vs saturation capacity
    let factories = (0..1)
        .map(|_| ready(NativeBackend::new(net.clone(), shape.clone())))
        .collect();
    let server = Server::start_with(factories, numel, BatchPolicy::new(8, 1000));
    let mut rxs = Vec::new();
    for f in feats.iter() {
        rxs.push(server.submit(f.clone()));
        std::thread::sleep(std::time::Duration::from_micros(1000));
    }
    for rx in rxs {
        rx.recv().unwrap();
    }
    let stats = server.stats();
    println!(
        "paced 1000 req/s (60% util):        p50 {:.0}us  p99 {:.0}us  meanB {:.1}",
        stats.p50_us, stats.p99_us, stats.mean_batch
    );
    server.shutdown();
}
