//! Table 2 regenerator: our learned quantizer vs DoReFa vs PACT at
//! W2/A2 and W3/A3 under the identical training harness. Expected shape:
//! ours has the smallest degradation vs its own FP baseline.
#[path = "common.rs"]
mod common;

fn main() {
    let (manifest, engine) = common::setup();
    let ctx = common::ctx(&engine, &manifest);
    fqconv::bench::banner("Table 2 — quantizer comparison (resnet8s)");
    fqconv::exp::table2(&ctx, "resnet8s").expect("table2");
}
