//! Table 4 regenerator: the KWS gradual-quantization sequence including
//! the FQ24 BN-free fine-tune. Expected shape: quantized stages stay
//! within ~1 point of FP; FQ24 within ~1 point of Q24.
#[path = "common.rs"]
mod common;

fn main() {
    let (manifest, engine) = common::setup();
    let ctx = common::ctx(&engine, &manifest);
    fqconv::bench::banner("Table 4 — KWS GQ sequence (synthetic speech commands)");
    fqconv::exp::table4(&ctx).expect("table4");
}
