//! Perf: integer inference engine — i8 GEMM vs ternary add-only path
//! (sequential vs row-block-parallel), full-network single-sample and
//! batch throughput (sequential vs thread pool). Feeds EXPERIMENTS.md
//! §Perf (L3 targets: ternary path faster than dense i8; >= 1 GMAC/s/core;
//! pooled batch throughput >= 2x sequential on a multi-core host).
//!
//! The network sections run on a deterministic synthetic KWS net, so
//! this bench works offline; when the trained artifacts + PJRT runtime
//! are present a section on the real FQ parameters is appended.
#[path = "common.rs"]
mod common;

use fqconv::bench::{banner, bench, bench_for, BenchStats};
use fqconv::coordinator::{checkpoint, fq_transform, Trainer, Variant};
use fqconv::data::{self, Dataset};
use fqconv::exec;
use fqconv::infer::gemm::{gemm_i8, gemm_i8_mt, transpose, TernaryMatrix};
use fqconv::infer::pipeline::Scratch;
use fqconv::infer::FqKwsNet;
use fqconv::util::Rng;

fn report(s: &BenchStats, items: f64, unit: &str) {
    println!("{}   {:>10.2} {unit}", s.report(), s.throughput(items) / 1e9);
}

fn gemm_section(threads: usize) {
    let mut rng = Rng::new(7);
    // GEMM shapes modeled on the KWS layers: (T_out, C*F) x (C*F, 45),
    // plus a larger patch matrix where row-block parallelism pays off
    for &(m, k, n) in &[(78usize, 300usize, 45usize), (64, 135, 45), (1024, 512, 64)] {
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(15) as i32 - 7) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| (rng.below(3) as i32 - 1) as i8).collect();
        let bt = transpose(k, n, &b);
        let tern = TernaryMatrix::from_dense(k, n, &b);
        let mut c = vec![0i32; m * n];
        let macs = (m * k * n) as f64;
        let s = bench(&format!("dense i8 GEMM {m}x{k}x{n}"), 3, 30, || {
            gemm_i8(m, k, n, &a, &bt, &mut c);
            std::hint::black_box(&c);
        });
        report(&s, macs, "GMAC/s");
        let s = bench(&format!("dense i8 GEMM {m}x{k}x{n} (mt x{threads})"), 3, 30, || {
            gemm_i8_mt(m, k, n, &a, &bt, &mut c, threads);
            std::hint::black_box(&c);
        });
        report(&s, macs, "GMAC/s");
        let s = bench(
            &format!("ternary GEMM {m}x{k}x{n} (sparsity {:.0}%)", tern.sparsity * 100.0),
            3,
            30,
            || {
                tern.gemm(m, &a, &mut c);
                std::hint::black_box(&c);
            },
        );
        report(&s, macs, "GMAC/s");
        let s = bench(&format!("ternary GEMM {m}x{k}x{n} (mt x{threads})"), 3, 30, || {
            tern.gemm_mt(m, &a, &mut c, threads);
            std::hint::black_box(&c);
        });
        report(&s, macs, "GMAC/s");
    }
}

fn net_section(net: &FqKwsNet, tag: &str, threads: usize) {
    let ds = data::for_model("kws", &[39, net.frames], net.classes);
    let (x, _) = ds.sample(0, None);
    let macs = net.macs_per_sample() as f64;
    let mut scratch = Scratch::default();
    let s = bench(&format!("{tag} forward (1 sample)"), 5, 50, || {
        std::hint::black_box(net.forward(&x, &mut scratch));
    });
    report(&s, macs, "GMAC/s");
    println!(
        "    = {:.0} samples/s/core ({:.2}M int-MACs/sample)",
        1.0 / s.median_s,
        macs / 1e6
    );

    // batch throughput: sequential loop vs the data-parallel pool —
    // the headline number for the "2x over the sequential seed" target
    let batch = ds.val_batch(0, 64);
    let seq = bench_for(&format!("{tag} forward_batch(64) seq"), 0.5, 40, || {
        std::hint::black_box(net.forward_batch_with(&batch.x, 1));
    });
    println!("{}", seq.report());
    let par = bench_for(&format!("{tag} forward_batch(64) pool x{threads}"), 0.5, 40, || {
        std::hint::black_box(net.forward_batch_with(&batch.x, threads));
    });
    println!("{}", par.report());
    let speedup = seq.median_s / par.median_s.max(1e-12);
    println!(
        "    batch throughput: {:.0} -> {:.0} samples/s  ({speedup:.2}x speedup, {threads} threads)",
        64.0 / seq.median_s,
        64.0 / par.median_s
    );
}

fn main() {
    banner("perf_infer — integer engine hot paths");
    let threads = exec::default_threads();
    println!("(pool size {threads}; override with FQCONV_THREADS)\n");
    gemm_section(threads);

    // full network forward on a synthetic net — always available
    for (nw, label) in [(1.0f32, "ternary (W2)"), (7.0, "dense (W4)")] {
        let net = FqKwsNet::synthetic(nw, 7.0, 7).expect("synthetic net");
        net_section(&net, &format!("synthetic KWS {label}"), threads);
    }

    // trained-artifact section (skipped offline)
    let Some((manifest, engine)) = common::try_setup() else {
        println!("\n(trained-artifact section skipped: artifacts / PJRT unavailable)");
        return;
    };
    let info = manifest.model("kws").unwrap();
    let mut t = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
    t.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();
    let fq_graph = info.fq.clone().unwrap();
    let params = fq_transform::qat_to_fq(info, &fq_graph, &t.params).unwrap();
    for (nw, label) in [(1.0f32, "ternary (W2)"), (7.0, "dense (W4)")] {
        let net = FqKwsNet::from_params(&params, nw, 7.0, info.input_shape[1]).unwrap();
        net_section(&net, &format!("KWS net {label}"), threads);
    }
}
