//! Perf: integer inference engine — i8 GEMM vs ternary add-only path,
//! full-network throughput, LUT re-binning cost. Feeds EXPERIMENTS.md
//! §Perf (L3 targets: ternary path faster than dense i8; >= 1 GMAC/s/core).
#[path = "common.rs"]
mod common;

use fqconv::bench::{banner, bench, BenchStats};
use fqconv::coordinator::{checkpoint, fq_transform, Trainer, Variant};
use fqconv::data::{self, Dataset};
use fqconv::infer::gemm::{gemm_i8, transpose, TernaryMatrix};
use fqconv::infer::pipeline::Scratch;
use fqconv::infer::FqKwsNet;
use fqconv::util::Rng;

fn report(s: &BenchStats, items: f64, unit: &str) {
    println!("{}   {:>10.2} {unit}", s.report(), s.throughput(items) / 1e9);
}

fn main() {
    banner("perf_infer — integer engine hot paths");
    let mut rng = Rng::new(7);
    // GEMM shapes modeled on the KWS layers: (T_out, C*F) x (C*F, 45)
    for &(m, k, n) in &[(78usize, 300usize, 45usize), (64, 135, 45), (256, 512, 64)] {
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(15) as i32 - 7) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| (rng.below(3) as i32 - 1) as i8).collect();
        let bt = transpose(k, n, &b);
        let tern = TernaryMatrix::from_dense(k, n, &b);
        let mut c = vec![0i32; m * n];
        let macs = (m * k * n) as f64;
        let s = bench(&format!("dense i8 GEMM {m}x{k}x{n}"), 3, 30, || {
            gemm_i8(m, k, n, &a, &bt, &mut c);
            std::hint::black_box(&c);
        });
        report(&s, macs, "GMAC/s");
        let s = bench(&format!("ternary GEMM {m}x{k}x{n} (sparsity {:.0}%)", tern.sparsity * 100.0), 3, 30, || {
            tern.gemm(m, &a, &mut c);
            std::hint::black_box(&c);
        });
        report(&s, macs, "GMAC/s");
    }

    // full network forward
    let (manifest, engine) = common::setup();
    let info = manifest.model("kws").unwrap();
    let mut t = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
    t.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();
    let fq_graph = info.fq.clone().unwrap();
    let params = fq_transform::qat_to_fq(info, &fq_graph, &t.params).unwrap();
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let (x, _) = ds.sample(0, None);
    for (nw, label) in [(1.0f32, "ternary (W2)"), (7.0, "dense (W4)")] {
        let net = FqKwsNet::from_params(&params, nw, 7.0, info.input_shape[1]).unwrap();
        let macs = net.macs_per_sample() as f64;
        let mut scratch = Scratch::default();
        let s = bench(&format!("KWS net forward, {label}"), 5, 50, || {
            std::hint::black_box(net.forward(&x, &mut scratch));
        });
        report(&s, macs, "GMAC/s");
        println!(
            "    = {:.0} samples/s/core ({:.2}M int-MACs/sample)",
            1.0 / s.median_s,
            macs / 1e6
        );
    }
}
