//! Perf: integer inference engine — packed-microkernel i8 GEMM vs the
//! ternary add-only path (sequential vs row-block-parallel), full-network
//! single-sample and batch throughput (sequential vs persistent pool vs
//! the old scoped-spawn fork-join). Feeds EXPERIMENTS.md §Perf.
//!
//! Besides the human-readable table, the run emits a machine-readable
//! `BENCH_infer.json` at the repository root (samples/sec, ns/sample,
//! MACs/s, speedups vs sequential) so the perf trajectory is tracked
//! across PRs.
//!
//! The network sections run on a deterministic synthetic KWS net, so
//! this bench works offline; when the trained artifacts + PJRT runtime
//! are present a section on the real FQ parameters is appended.
//! `FQCONV_BENCH_SMOKE=1` shrinks every section to one short iteration
//! (the CI bench-smoke job).
#[path = "common.rs"]
mod common;

use fqconv::bench::{banner, bench, bench_for, BenchStats};
use fqconv::coordinator::{checkpoint, fq_transform, Trainer, Variant};
use fqconv::data::{self, Dataset};
use fqconv::exec;
use fqconv::infer::gemm::{gemm_i8, gemm_i8_mt, gemm_packed, transpose, PackedB, TernaryMatrix};
use fqconv::infer::graph::{synthetic_graph, SynthArch};
use fqconv::infer::pipeline::Scratch;
use fqconv::infer::{FqKwsNet, QuantConv2d};
use fqconv::quant::QParams;
use fqconv::tensor::TensorF;
use fqconv::util::json::{num, obj, s, Json};
use fqconv::util::Rng;

fn smoke() -> bool {
    std::env::var("FQCONV_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

fn report(st: &BenchStats, items: f64, unit: &str) {
    println!("{}   {:>10.2} {unit}", st.report(), st.throughput(items) / 1e9);
}

fn gemm_section(threads: usize, iters: usize) -> Json {
    let mut rng = Rng::new(7);
    let mut records = Vec::new();
    // GEMM shapes modeled on the KWS layers: (T_out, C*F) x (C*F, 45),
    // plus a larger patch matrix where row-block parallelism pays off
    for &(m, k, n) in &[(78usize, 300usize, 45usize), (64, 135, 45), (1024, 512, 64)] {
        let a: Vec<i8> = (0..m * k).map(|_| (rng.below(15) as i32 - 7) as i8).collect();
        let b: Vec<i8> = (0..k * n).map(|_| (rng.below(3) as i32 - 1) as i8).collect();
        let bt = transpose(k, n, &b);
        let pb = PackedB::from_bt(k, n, &bt);
        let tern = TernaryMatrix::from_dense(k, n, &b);
        let mut c = vec![0i32; m * n];
        let macs = (m * k * n) as f64;
        let st = bench(&format!("dense i8 GEMM {m}x{k}x{n} (pack/call)"), 2, iters, || {
            gemm_i8(m, k, n, &a, &bt, &mut c);
            std::hint::black_box(&c);
        });
        report(&st, macs, "GMAC/s");
        let dense_packed = bench(&format!("dense i8 GEMM {m}x{k}x{n} (pre-packed)"), 2, iters, || {
            gemm_packed(m, k, &a, &pb, &mut c);
            std::hint::black_box(&c);
        });
        report(&dense_packed, macs, "GMAC/s");
        let dense_mt = bench(&format!("dense i8 GEMM {m}x{k}x{n} (mt x{threads})"), 2, iters, || {
            gemm_i8_mt(m, k, n, &a, &bt, &mut c, threads);
            std::hint::black_box(&c);
        });
        report(&dense_mt, macs, "GMAC/s");
        let tern_seq = bench(
            &format!("ternary GEMM {m}x{k}x{n} (sparsity {:.0}%)", tern.sparsity * 100.0),
            2,
            iters,
            || {
                tern.gemm(m, &a, &mut c);
                std::hint::black_box(&c);
            },
        );
        report(&tern_seq, macs, "GMAC/s");
        let tern_mt = bench(&format!("ternary GEMM {m}x{k}x{n} (mt x{threads})"), 2, iters, || {
            tern.gemm_mt(m, &a, &mut c, threads);
            std::hint::black_box(&c);
        });
        report(&tern_mt, macs, "GMAC/s");
        records.push(obj(vec![
            ("m", num(m as f64)),
            ("k", num(k as f64)),
            ("n", num(n as f64)),
            ("dense_packed_gmacs", num(dense_packed.throughput(macs) / 1e9)),
            ("dense_mt_gmacs", num(dense_mt.throughput(macs) / 1e9)),
            ("ternary_gmacs", num(tern_seq.throughput(macs) / 1e9)),
            ("ternary_mt_gmacs", num(tern_mt.throughput(macs) / 1e9)),
        ]));
    }
    Json::Arr(records)
}

fn net_section(net: &FqKwsNet, tag: &str, threads: usize, iters: usize) -> Json {
    let ds = data::for_model("kws", &[39, net.frames], net.classes);
    let (x, _) = ds.sample(0, None);
    let macs = net.macs_per_sample() as f64;
    let mut scratch = Scratch::default();
    let st = bench(&format!("{tag} forward (1 sample)"), 3, iters, || {
        std::hint::black_box(net.forward(&x, &mut scratch));
    });
    report(&st, macs, "GMAC/s");
    println!(
        "    = {:.0} samples/s/core ({:.2}M int-MACs/sample)",
        1.0 / st.median_s,
        macs / 1e6
    );

    // batch throughput: sequential loop vs the persistent pool — the
    // headline number for the "2x over the sequential seed" target
    let time_budget = if smoke() { 0.05 } else { 0.5 };
    let batch = ds.val_batch(0, 64);
    let seq = bench_for(&format!("{tag} forward_batch(64) seq"), time_budget, 40, || {
        std::hint::black_box(net.forward_batch_with(&batch.x, 1));
    });
    println!("{}", seq.report());
    let par = bench_for(&format!("{tag} forward_batch(64) pool x{threads}"), time_budget, 40, || {
        std::hint::black_box(net.forward_batch_with(&batch.x, threads));
    });
    println!("{}", par.report());
    let speedup = seq.median_s / par.median_s.max(1e-12);
    println!(
        "    batch throughput: {:.0} -> {:.0} samples/s  ({speedup:.2}x, {threads} threads)",
        64.0 / seq.median_s,
        64.0 / par.median_s
    );
    obj(vec![
        ("tag", s(tag)),
        ("macs_per_sample", num(macs)),
        ("samples_per_sec_1t", num(1.0 / st.median_s)),
        ("ns_per_sample_1t", num(st.median_s * 1e9)),
        ("macs_per_sec_1t", num(macs / st.median_s)),
        ("batch64_seq_samples_per_sec", num(64.0 / seq.median_s)),
        ("batch64_pool_samples_per_sec", num(64.0 / par.median_s)),
        ("batch64_speedup_vs_sequential", num(speedup)),
        ("pool_threads", num(threads as f64)),
    ])
}

/// `forward_batch_with` semantics over the *old* scoped-spawn fork-join
/// (one thread spawn per window per batch) — the baseline the
/// persistent pool is measured against at small batch sizes.
fn forward_batch_scoped(net: &FqKwsNet, x: &TensorF, threads: usize) -> TensorF {
    let b = x.shape()[0];
    let per: usize = x.data().len() / b;
    let classes = net.classes;
    let mut out = vec![0f32; b * classes];
    if b == 1 || threads <= 1 {
        let mut s = Scratch::default();
        net.forward_rows(x.data(), &mut s, &mut out);
    } else {
        exec::par_rows_mut_scoped(&mut out, b, classes, threads, |rows, window| {
            let mut s = Scratch::default();
            net.forward_rows(&x.data()[rows.start * per..rows.end * per], &mut s, window);
        });
    }
    TensorF::from_vec(&[b, classes], out)
}

fn small_batch_section(net: &FqKwsNet, threads: usize) -> Json {
    println!("\n--- small-batch fork-join: persistent pool vs scoped spawn ---");
    let ds = data::for_model("kws", &[39, net.frames], net.classes);
    let time_budget = if smoke() { 0.03 } else { 0.3 };
    let mut records = Vec::new();
    for b in [1usize, 2, 4, 8] {
        let batch = ds.val_batch(0, b);
        let scoped_name = format!("batch({b}) scoped-spawn x{threads}");
        let scoped = bench_for(&scoped_name, time_budget, 400, || {
            std::hint::black_box(forward_batch_scoped(net, &batch.x, threads));
        });
        let pool_name = format!("batch({b}) persistent pool x{threads}");
        let pool = bench_for(&pool_name, time_budget, 400, || {
            std::hint::black_box(net.forward_batch_with(&batch.x, threads));
        });
        let ratio = scoped.median_s / pool.median_s.max(1e-12);
        println!(
            "batch {b}: scoped {:>10.0} samples/s | pool {:>10.0} samples/s | pool is {ratio:.2}x",
            b as f64 / scoped.median_s,
            b as f64 / pool.median_s
        );
        records.push(obj(vec![
            ("batch", num(b as f64)),
            ("scoped_samples_per_sec", num(b as f64 / scoped.median_s)),
            ("pool_samples_per_sec", num(b as f64 / pool.median_s)),
            ("pool_vs_scoped", num(ratio)),
        ]));
    }
    Json::Arr(records)
}

/// Second architecture on the graph API: the deeper/wider synthetic net
/// (10 layers, 48 channels, dilations to 16) — pins that the composable
/// engine carries non-KWS stacks at full kernel speed.
fn graph_arch_section(threads: usize, iters: usize) -> Json {
    println!("\n--- second architecture (QuantGraph deep-wide) ---");
    let g = synthetic_graph(&SynthArch::deep_wide(), 1.0, 7.0, 7).expect("deep-wide graph");
    let mut rng = Rng::new(2);
    let mut x = vec![0f32; g.in_numel()];
    rng.fill_gaussian(&mut x, 1.0);
    let macs = g.macs_per_sample() as f64;
    let mut scratch = fqconv::infer::graph::Scratch::for_graph(&g);
    let seq = bench("deep-wide forward (1 sample, 1 thread)", 3, iters, || {
        std::hint::black_box(g.forward(&x, &mut scratch));
    });
    report(&seq, macs, "GMAC/s");
    let mut logits = vec![0f32; g.classes()];
    let par = bench(&format!("deep-wide forward (1 sample, x{threads})"), 3, iters, || {
        g.forward_into(&x, &mut scratch, &mut logits, threads);
        std::hint::black_box(&logits);
    });
    report(&par, macs, "GMAC/s");
    obj(vec![
        ("arch", s("deep-wide")),
        ("macs_per_sample", num(macs)),
        ("samples_per_sec_1t", num(1.0 / seq.median_s)),
        ("samples_per_sec_mt", num(1.0 / par.median_s)),
        ("intra_layer_speedup", num(seq.median_s / par.median_s.max(1e-12))),
    ])
}

/// 2-D conv layer kernels: direct (im2col-free, fused requant) vs the
/// im2col + GEMM oracle, ternary vs dense, at a ResNet-32 group-2 shape.
fn conv2d_section(threads: usize, iters: usize) -> Json {
    println!("\n--- 2-D conv layer (32ch 3x3 @ 16x16, direct vs im2col) ---");
    let mut rng = Rng::new(9);
    let (c_in, c_out, h, w) = (32usize, 32usize, 16usize, 16usize);
    let wts: Vec<f32> = (0..c_out * c_in * 9).map(|_| rng.gaussian_f32(0.0, 0.5)).collect();
    let qa = QParams::new(1.0, 7.0, 0.0);
    let mid = QParams::new(1.0, 7.0, 0.0);
    let next = Some(QParams::new(1.0, 7.0, 0.0));
    let x: Vec<i8> = (0..c_in * h * w).map(|_| rng.below(8) as i8).collect();
    let mut records = Vec::new();
    for (nw, label) in [(1.0f32, "ternary"), (7.0, "dense")] {
        let qw = QParams::new(1.0, nw, -1.0);
        let layer = QuantConv2d::new(&wts, c_out, c_in, 3, 1, 1, qa, qw, mid, next);
        let (h_out, w_out) = layer.out_hw(h, w);
        let macs = layer.macs(h_out, w_out) as f64;
        let (mut acc, mut out) = (Vec::new(), Vec::new());
        let direct = bench(&format!("conv2d {label} direct"), 2, iters, || {
            layer.forward(&x, h, w, &mut acc, &mut out);
            std::hint::black_box(&out);
        });
        report(&direct, macs, "GMAC/s");
        let direct_mt = bench(&format!("conv2d {label} direct (x{threads})"), 2, iters, || {
            layer.forward_mt(&x, h, w, &mut acc, &mut out, threads);
            std::hint::black_box(&out);
        });
        report(&direct_mt, macs, "GMAC/s");
        let mut cols = Vec::new();
        let im2col = bench(&format!("conv2d {label} im2col oracle"), 2, iters, || {
            layer.forward_im2col(&x, h, w, &mut cols, &mut acc, &mut out);
            std::hint::black_box(&out);
        });
        report(&im2col, macs, "GMAC/s");
        records.push(obj(vec![
            ("kind", s(label)),
            ("macs", num(macs)),
            ("direct_gmacs", num(direct.throughput(macs) / 1e9)),
            ("direct_mt_gmacs", num(direct_mt.throughput(macs) / 1e9)),
            ("im2col_gmacs", num(im2col.throughput(macs) / 1e9)),
            ("direct_vs_im2col", num(im2col.median_s / direct.median_s.max(1e-12))),
        ]));
    }
    Json::Arr(records)
}

/// One of the paper's 2-D networks end to end on the graph engine:
/// single-sample sequential vs intra-layer parallel (ternary weights).
fn img_net_section(arch: &SynthArch, title: &str, threads: usize, iters: usize) -> Json {
    let tag = arch.name();
    println!("\n--- {title} ---");
    let g = synthetic_graph(arch, 1.0, 7.0, 13).unwrap_or_else(|e| panic!("{tag} graph: {e}"));
    let mut rng = Rng::new(3);
    let mut x = vec![0f32; g.in_numel()];
    rng.fill_gaussian(&mut x, 0.5);
    let macs = g.macs_per_sample() as f64;
    let mut scratch = fqconv::infer::graph::Scratch::for_graph(&g);
    let seq = bench(&format!("{tag} forward (1 sample, 1 thread)"), 2, iters, || {
        std::hint::black_box(g.forward(&x, &mut scratch));
    });
    report(&seq, macs, "GMAC/s");
    let mut logits = vec![0f32; g.classes()];
    let par = bench(&format!("{tag} forward (1 sample, x{threads})"), 2, iters, || {
        g.forward_into(&x, &mut scratch, &mut logits, threads);
        std::hint::black_box(&logits);
    });
    report(&par, macs, "GMAC/s");
    println!(
        "    = {:.0} samples/s/core ({:.1}M int-MACs/sample)",
        1.0 / seq.median_s,
        macs / 1e6
    );
    obj(vec![
        ("arch", s(tag)),
        ("macs_per_sample", num(macs)),
        ("samples_per_sec_1t", num(1.0 / seq.median_s)),
        ("samples_per_sec_mt", num(1.0 / par.median_s)),
        ("intra_layer_speedup", num(seq.median_s / par.median_s.max(1e-12))),
    ])
}

fn main() {
    banner("perf_infer — integer engine hot paths");
    let threads = exec::default_threads();
    let iters = if smoke() { 5 } else { 30 };
    println!("(pool size {threads}; override with FQCONV_THREADS)\n");
    let gemm_json = gemm_section(threads, iters);
    let conv2d_json = conv2d_section(threads, iters);

    // full network forward on a synthetic net — always available
    let mut nets_json = Vec::new();
    let mut small_batch_json = Json::Arr(Vec::new());
    for (nw, label) in [(1.0f32, "ternary (W2)"), (7.0, "dense (W4)")] {
        let net = FqKwsNet::synthetic(nw, 7.0, 7).expect("synthetic net");
        nets_json.push(net_section(&net, &format!("synthetic KWS {label}"), threads, iters));
        if nw == 1.0 {
            small_batch_json = small_batch_section(&net, threads);
        }
    }
    let graph_json = graph_arch_section(threads, iters);
    let img_iters = if smoke() { 2 } else { 10 };
    let resnet_json = img_net_section(
        &SynthArch::resnet32(),
        "ResNet-32 (2-D residual QuantGraph)",
        threads,
        img_iters,
    );
    let darknet_json = img_net_section(
        &SynthArch::darknet19(),
        "DarkNet-19 (pooled 2-D QuantGraph)",
        threads,
        img_iters,
    );

    let out = obj(vec![
        ("bench", s("perf_infer")),
        ("threads", num(threads as f64)),
        ("smoke", Json::Bool(smoke())),
        ("gemm", gemm_json),
        ("conv2d", conv2d_json),
        ("nets", Json::Arr(nets_json)),
        ("small_batch_pool_vs_scoped", small_batch_json),
        ("graph_arch", graph_json),
        ("resnet32", resnet_json),
        ("darknet19", darknet_json),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_infer.json");
    match std::fs::write(path, out.to_string() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    // trained-artifact section (skipped offline)
    let Some((manifest, engine)) = common::try_setup() else {
        println!("(trained-artifact section skipped: artifacts / PJRT unavailable)");
        return;
    };
    let info = manifest.model("kws").unwrap();
    let mut t = Trainer::new(&engine, &manifest, "kws", Variant::Qat("")).unwrap();
    t.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt)).unwrap()).unwrap();
    let fq_graph = info.fq.clone().unwrap();
    let params = fq_transform::qat_to_fq(info, &fq_graph, &t.params).unwrap();
    for (nw, label) in [(1.0f32, "ternary (W2)"), (7.0, "dense (W4)")] {
        let net = FqKwsNet::from_params(&params, nw, 7.0, info.input_shape[1]).unwrap();
        net_section(&net, &format!("KWS net {label}"), threads, iters);
    }
}
