//! Table 5 regenerator: KWS model comparison. Literature rows are quoted
//! from the paper; our rows use manifest accounting + accuracies measured
//! by a quick ladder run. Expected shape: our models are 10-100x smaller
//! in size and mults at competitive accuracy.
#[path = "common.rs"]
mod common;

fn main() {
    let (manifest, engine) = common::setup();
    let ctx = common::ctx(&engine, &manifest);
    fqconv::bench::banner("Table 5 — KWS model comparison");
    let report = fqconv::exp::table4(&ctx).expect("ladder for accuracies");
    let q35 = report.stage("Q35").map(|s| s.val_acc).unwrap_or(0.0);
    let fq24 = report.stage("FQ24").map(|s| s.val_acc).unwrap_or(0.0);
    fqconv::exp::table5(&ctx, q35, fq24).expect("table5");
}
