//! Table 6 regenerator: CIFAR-100-like ladder on the slim ResNet stand-in
//! (resnet14s; run `fqconv exp table6 --model resnet32 --budget full` for
//! the full-size version). Expected shape: graceful degradation down the
//! ladder; FQ25 ~= Q25.
#[path = "common.rs"]
mod common;

fn main() {
    let (manifest, engine) = common::setup();
    let ctx = common::ctx(&engine, &manifest);
    fqconv::bench::banner("Table 6 — ResNet ladder (synthetic CIFAR-100-like)");
    fqconv::exp::table6(&ctx, "resnet14s").expect("table6");
}
