//! fqconv — leader entrypoint + CLI.
//!
//! Subcommands:
//!   arch <model> [--fq]                         Fig. 2/4 architecture printer
//!   plan --model <m> [--steps N]                Fig. 1 GQ schedule renderer
//!   exp <table1..table7|all> [--budget B] ...   regenerate a paper table
//!   train --model <m> [--steps N] [--verbose]   run the model's GQ ladder
//!   serve [--requests N] [--workers W]          serving demo + latency/shed stats
//!   stream [--sessions N] [--frames F]          concurrent streaming-session demo
//!   stats [--format prometheus|json]            observability demo: run a short
//!                                               workload, print the metrics registry
//!   selftest                                    quick wiring check
//!
//! Budgets: --budget smoke|quick|full (default quick for exp, full for train).

use anyhow::{bail, Context, Result};

use fqconv::config::Budget;
use fqconv::coordinator::{checkpoint, ParamSet, Pipeline, Schedule};
use fqconv::data;
use fqconv::exp::{self, Ctx};
use fqconv::infer::FqKwsNet;
use fqconv::runtime::{Engine, Manifest};
use fqconv::serve::{AdmissionPolicy, BatchPolicy, ModelSpec, NativeBackend, Priority, Server};
use fqconv::util::cli::Args;
use fqconv::util::{Rng, Timer};

const USAGE: &str = "usage: fqconv <arch|plan|exp|train|serve|stream|stats|selftest> [options]
  arch <model> [--fq]
  plan --model <model> [--steps N]
  exp <table1|table2|table3|table4|table5|table6|table7|all> [--budget smoke|quick|full] [--model M] [--verbose]
  train --model <model> [--steps N] [--ckpt-dir DIR] [--verbose]
  serve [--requests N] [--workers W] [--max-batch B] [--max-wait-us U] [--deadline-us D] [--max-pending P]
  stream [--sessions N] [--frames F] [--workers W] [--max-sessions M]
  stats [--requests N] [--workers W] [--format prometheus|json] [--trace]
  selftest";

fn main() -> Result<()> {
    let args = Args::parse();
    match args.command.as_str() {
        "arch" => cmd_arch(&args),
        "plan" => cmd_plan(&args),
        "exp" => cmd_exp(&args),
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "stream" => cmd_stream(&args),
        "stats" => cmd_stats(&args),
        "selftest" => cmd_selftest(),
        _ => {
            eprintln!("{USAGE}");
            bail!("unknown command {:?}", args.command);
        }
    }
}

fn load_manifest() -> Result<Manifest> {
    let dir = fqconv::artifacts_dir();
    Manifest::load(&dir).with_context(|| {
        format!("loading manifest from {} (run `make artifacts` first?)", dir.display())
    })
}

fn budget_from(args: &Args, default: Budget) -> Budget {
    match args.str_or("budget", "").as_str() {
        "smoke" => Budget::smoke(),
        "quick" => Budget::quick(),
        "full" => Budget::full(),
        "" => default,
        other => {
            eprintln!("unknown budget {other:?}, using quick");
            Budget::quick()
        }
    }
}

fn cmd_arch(args: &Args) -> Result<()> {
    let model = args.positional.first().map(|s| s.as_str()).unwrap_or("kws");
    let manifest = load_manifest()?;
    let info = manifest.model(model)?;
    println!("{}", fqconv::models::render_architecture(info, args.has("fq")));
    if args.has("fq") {
        println!("{}", exp::fig3_note());
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let model = args.str_or("model", "kws");
    let steps = args.usize_or("steps", 600);
    println!("{}", exp::fig1_plan(&model, steps));
    Ok(())
}

fn cmd_exp(args: &Args) -> Result<()> {
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let manifest = load_manifest()?;
    let engine = Engine::cpu()?;
    let budget = budget_from(args, Budget::quick());
    let mut ctx = Ctx::new(&engine, &manifest, budget);
    ctx.verbose = args.has("verbose");
    ctx.seed = args.u64_or("seed", 17);
    let t = Timer::start();
    match which {
        "table1" => {
            exp::table1(&ctx, &args.str_or("model", "resnet8s"))?;
        }
        "table2" => {
            exp::table2(&ctx, &args.str_or("model", "resnet8s"))?;
        }
        "table3" => {
            exp::table3(&ctx)?;
        }
        "table4" => {
            exp::table4(&ctx)?;
        }
        "table5" => {
            // measure accuracies through the KWS ladder, then print
            let report = exp::table4(&ctx)?;
            let q35 = report.stage("Q35").map(|s| s.val_acc).unwrap_or(0.0);
            let fq24 = report.stage("FQ24").map(|s| s.val_acc).unwrap_or(0.0);
            exp::table5(&ctx, q35, fq24)?;
        }
        "table6" => {
            exp::table6(&ctx, &args.str_or("model", "resnet14s"))?;
        }
        "table7" => {
            exp::table7_kws(&ctx, false)?;
            exp::table7_cifar(&ctx, &args.str_or("model", "resnet14s"), false)?;
        }
        "all" => {
            exp::table1(&ctx, "resnet8s")?;
            exp::table2(&ctx, "resnet8s")?;
            exp::table3(&ctx)?;
            let report = exp::table4(&ctx)?;
            let q35 = report.stage("Q35").map(|s| s.val_acc).unwrap_or(0.0);
            let fq24 = report.stage("FQ24").map(|s| s.val_acc).unwrap_or(0.0);
            exp::table5(&ctx, q35, fq24)?;
            exp::table6(&ctx, "resnet14s")?;
            exp::table7_kws(&ctx, false)?;
            exp::table7_cifar(&ctx, "resnet14s", false)?;
        }
        other => bail!("unknown experiment {other:?}"),
    }
    eprintln!("[exp {which}] total {:.1}s", t.elapsed_s());
    Ok(())
}

fn cmd_train(args: &Args) -> Result<()> {
    let model = args.str_or("model", "kws");
    let manifest = load_manifest()?;
    let engine = Engine::cpu()?;
    let info = manifest.model(&model)?;
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let mut pipe = Pipeline::new(&engine, &manifest, ds.as_ref());
    pipe.verbose = args.has("verbose");
    pipe.seed = args.u64_or("seed", 17);
    let default_ckpts = manifest.dir.join("ckpts");
    pipe.ckpt_dir =
        Some(args.str_or("ckpt-dir", default_ckpts.to_str().unwrap_or("ckpts")).into());
    let steps = args.usize_or("steps", Budget::full().steps_per_stage);
    let sched = match info.kind.as_str() {
        "kws" => Schedule::table4_kws(steps, 0.01),
        "darknet" => Schedule::table3_darknet(steps, 0.02),
        _ if info.fq.is_some() => Schedule::table6(&model, steps, 0.002),
        _ => Schedule::table1(&model, steps, 0.02),
    };
    println!("{}", sched.render());
    let report = pipe.run(&sched)?;
    println!("{}", report.render_table());
    Ok(())
}

/// Deployment network for `fqconv serve`: trained FQ checkpoint, else
/// the BN-folded init (needs PJRT to briefly build QAT params), else an
/// error — `cmd_serve` falls back to the synthetic net on any failure.
fn artifact_serve_net() -> Result<FqKwsNet> {
    let manifest = load_manifest()?;
    let info = manifest.model("kws")?;
    let frames = info.input_shape[1];
    let fq_graph = info.fq.clone().context("kws fq graph")?;
    let ckpt = manifest.dir.join("ckpts/kws_FQ24.ckpt");
    let params = if ckpt.exists() {
        ParamSet::from_checkpoint(&fq_graph, &checkpoint::read(&ckpt)?)?
    } else {
        eprintln!(
            "note: no trained checkpoint at {}; serving untrained weights",
            ckpt.display()
        );
        let engine = Engine::cpu()?;
        let mut src = fqconv::coordinator::Trainer::new(
            &engine,
            &manifest,
            "kws",
            fqconv::coordinator::Variant::Qat(""),
        )?;
        src.load_params(&checkpoint::read(&manifest.dir.join(&info.init_ckpt))?)?;
        fqconv::coordinator::fq_transform::qat_to_fq(info, &fq_graph, &src.params)?
    };
    FqKwsNet::from_params(&params, 1.0, 7.0, frames)
}

fn cmd_serve(args: &Args) -> Result<()> {
    // deploy parameters: trained FQ checkpoint > BN-folded init >
    // synthetic network (no artifacts / PJRT needed for the last)
    let net = match artifact_serve_net() {
        Ok(net) => std::sync::Arc::new(net),
        Err(e) => {
            eprintln!("note: {e:#}");
            eprintln!("note: serving the synthetic KWS network instead");
            std::sync::Arc::new(FqKwsNet::synthetic(1.0, 7.0, 7)?)
        }
    };
    let input_shape = vec![39usize, net.frames];
    let workers = args.usize_or("workers", 2);
    let policy =
        BatchPolicy::new(args.usize_or("max-batch", 16), args.u64_or("max-wait-us", 2000));
    // 0 = no deadline; otherwise every 4th (Batch-priority) request gets
    // none and the Interactive ones carry this budget
    let deadline_us = args.u64_or("deadline-us", 0);
    let deadline = (deadline_us > 0).then(|| std::time::Duration::from_micros(deadline_us));
    // 0 = unbounded; otherwise admission control sheds submits over the
    // per-lane pending bound with a typed Overloaded reply
    let max_pending = args.usize_or("max-pending", 0);
    let admission = if max_pending == 0 {
        AdmissionPolicy::unbounded()
    } else {
        AdmissionPolicy::bounded(max_pending)
    };
    let sample_numel: usize = input_shape.iter().product();
    // split the intra-layer thread budget across the serve workers so
    // their batch-of-one forks don't contend on the global pool lock
    let factory = NativeBackend::factory_sharded(&net, &input_shape, workers);
    let spec = ModelSpec::new(factory, sample_numel, policy)
        .with_cost(net.cost_per_sample())
        .with_admission(admission);
    let server = Server::start_spec(spec, workers);

    let ds = data::for_model("kws", &input_shape, net.classes);
    let n = args.usize_or("requests", 256);
    let mut rng = Rng::new(7);
    let t = Timer::start();
    let mut correct = 0usize;
    let mut expired = 0usize;
    let mut shed = 0usize;
    let mut pending = Vec::new();
    let mut labels = Vec::new();
    for i in 0..n {
        let (x, y) = ds.sample(i as u64 % data::VAL_SIZE, Some(&mut rng));
        labels.push(y);
        // mixed workload: every 4th request is bulk (Batch class, no
        // deadline), the rest are Interactive with the optional budget
        let rx = if i % 4 == 3 {
            server.submit_with(x, Priority::Batch, None)
        } else {
            server.submit_with(x, Priority::Interactive, deadline)
        };
        pending.push(rx);
    }
    for (rx, y) in pending.into_iter().zip(labels) {
        match rx.recv().expect("reply channel") {
            Ok(resp) => {
                if resp.class as i32 == y {
                    correct += 1;
                }
            }
            Err(fqconv::serve::ServeError::DeadlineExceeded { .. }) => expired += 1,
            Err(fqconv::serve::ServeError::Overloaded { .. }) => shed += 1,
            Err(e) => anyhow::bail!("serving failed: {e}"),
        }
    }
    let dt = t.elapsed_s();
    let stats = server.stats();
    let answered = n - expired - shed;
    println!("served {answered}/{n} requests in {dt:.3}s = {:.0} req/s", answered as f64 / dt);
    println!(
        "accuracy {:.2}%  mean batch {:.1}  expired {expired}  shed {shed}",
        correct as f64 / answered.max(1) as f64 * 100.0,
        stats.mean_batch
    );
    println!("latency: {}", stats.latency_summary);
    for p in Priority::ALL {
        let ps = &stats.priorities[p.index()];
        println!(
            "priority {:<11} served={} p50={:.0}us p99={:.0}us",
            p.label(),
            ps.served,
            ps.p50_us,
            ps.p99_us
        );
    }
    for w in &stats.workers {
        println!(
            "worker {}: batches={} served={} errors={} alive={}",
            w.worker, w.batches, w.served, w.errors, w.alive
        );
    }
    server.shutdown();
    Ok(())
}

/// Streaming-session demo: open N synthetic KWS sessions, feed each F
/// paced frames through the shared worker pool, and report open rate,
/// feed throughput, and the state plan's per-session memory bound.
fn cmd_stream(args: &Args) -> Result<()> {
    use fqconv::infer::graph::{synthetic_graph, SynthArch};
    use fqconv::serve::{GraphBackend, StreamSpec};

    let sessions = args.usize_or("sessions", 64);
    let frames = args.usize_or("frames", 50);
    let workers = args.usize_or("workers", 2);
    let max_sessions = args.usize_or("max-sessions", sessions);
    let graph =
        std::sync::Arc::new(synthetic_graph(&SynthArch::kws(), 1.0, 7.0, 7)?);
    let spec = ModelSpec::new(
        GraphBackend::factory_sharded(&graph, workers),
        graph.in_numel(),
        BatchPolicy::default(),
    )
    .with_cost(graph.cost_per_sample())
    .with_streaming(StreamSpec {
        graph: std::sync::Arc::clone(&graph),
        max_sessions,
        idle_timeout: std::time::Duration::from_secs(30),
    });
    let server = Server::start_spec(spec, workers);
    let info = server.registry().stream_info(server.model_id()).expect("streaming model");
    println!(
        "state plan: {} bytes/session, warm-up {} frames, frame dim {}",
        info.bytes_per_session, info.warmup_frames, info.frame_dim
    );

    let t_open = Timer::start();
    let handles: Vec<_> = (0..sessions)
        .map(|_| server.open_session().expect("under the session bound"))
        .collect();
    let open_s = t_open.elapsed_s();

    // paced feeds: one wave per frame index across every session, reply
    // drained per wave (a live deployment would pace by the MFCC hop)
    let mut rng = Rng::new(11);
    let t_feed = Timer::start();
    let mut replies = Vec::with_capacity(sessions);
    for _ in 0..frames {
        replies.clear();
        for &sid in &handles {
            let frame: Vec<f32> =
                (0..info.frame_dim).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            replies.push(server.feed(sid, frame).expect("session is open"));
        }
        for rx in &replies {
            rx.recv().expect("reply channel").expect("feed served");
        }
    }
    let feed_s = t_feed.elapsed_s();
    let total_frames = sessions * frames;

    let stats = server.stats();
    println!(
        "opened {sessions} sessions in {open_s:.3}s = {:.0} sessions/s",
        sessions as f64 / open_s.max(1e-9)
    );
    println!(
        "fed {total_frames} frames in {feed_s:.3}s = {:.0} frames/s \
         ({sessions} concurrent sessions x {frames} frames)",
        total_frames as f64 / feed_s.max(1e-9)
    );
    println!(
        "resident session state: {} KiB total ({} bytes x {sessions} sessions)",
        info.bytes_per_session * sessions / 1024,
        info.bytes_per_session
    );
    println!("feed latency: {}", stats.latency_summary);
    for &sid in &handles {
        server.close_session(sid).expect("session is open");
    }
    server.shutdown();
    Ok(())
}

/// Observability demo: serve a short synthetic workload with tracing
/// and per-stage timing on, then print the metrics registry in
/// Prometheus text (default) or JSON form. `--trace` additionally
/// dumps the exact post-shutdown trace-event log.
fn cmd_stats(args: &Args) -> Result<()> {
    use fqconv::infer::graph::{synthetic_graph, SynthArch};
    use fqconv::obs::ObsConfig;
    use fqconv::serve::GraphBackend;

    let workers = args.usize_or("workers", 2);
    let n = args.usize_or("requests", 64);
    let format = args.str_or("format", "prometheus");
    let graph = std::sync::Arc::new(synthetic_graph(&SynthArch::kws(), 1.0, 7.0, 7)?);
    let spec = ModelSpec::new(
        GraphBackend::factory_sharded(&graph, workers),
        graph.in_numel(),
        BatchPolicy::new(args.usize_or("max-batch", 8), args.u64_or("max-wait-us", 500)),
    )
    .with_cost(graph.cost_per_sample())
    .with_observed_graph(&graph);
    let server = Server::start_spec_obs(spec, workers, ObsConfig::default());

    let mut rng = Rng::new(13);
    let numel = graph.in_numel();
    let pending: Vec<_> = (0..n)
        .map(|i| {
            let x: Vec<f32> = (0..numel).map(|_| rng.gaussian_f32(0.0, 1.0)).collect();
            let prio = if i % 4 == 3 { Priority::Batch } else { Priority::Interactive };
            server.submit_with(x, prio, None)
        })
        .collect();
    for rx in pending {
        if let Err(e) = rx.recv().context("reply channel")? {
            bail!("serving failed: {e}");
        }
    }
    match format.as_str() {
        "prometheus" => print!("{}", server.metrics_text()),
        "json" => println!("{}", server.metrics_json()),
        other => bail!("unknown stats format {other:?} (use prometheus|json)"),
    }
    if args.has("trace") {
        for e in server.shutdown_with_traces() {
            let kind = e.kind.as_str();
            println!("trace {} t={}ns {kind} a={} b={}", e.trace, e.t_ns, e.a, e.b);
        }
    } else {
        server.shutdown();
    }
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    let manifest = load_manifest()?;
    println!("manifest: {} models", manifest.models.len());
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform());
    let info = manifest.model("kws")?;
    let exe = engine.load(&info.artifact_path(&manifest.dir, "fwd")?)?;
    println!("compiled {}", exe.name());
    let ds = data::for_model(&info.kind, &info.input_shape, info.num_classes);
    let b = ds.val_batch(0, 4);
    println!("dataset ok: batch {:?}", b.x.shape());
    println!("selftest OK");
    Ok(())
}
