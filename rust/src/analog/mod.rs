//! Analog compute-in-memory crossbar simulator (Table 7).
//!
//! Models the paper's analog accelerator target: weights stored as
//! conductances in a crossbar array (noisy memory cells), activations
//! driven by DACs (noisy), analog Kirchhoff accumulation (effectively
//! infinite precision — "comes at no additional cost"), and ADC
//! re-binning into the next layer's quantized input grid (noisy ADC).
//!
//! Noise model exactly as §4.4: zero-mean Gaussian with σ expressed in
//! **percent of one LSB** of the corresponding quantizer —
//!   * σ_w   on weight codes (memory-cell noise; 1 LSB = 1 code step),
//!   * σ_a   on activation codes (DAC noise),
//!   * σ_MAC on the analog sum, in % of the *output* quantizer's LSB
//!     (ADC input-referred noise).
//!
//! The simulator reuses the integer KWS pipeline's structure but computes
//! in f64 code-space so the Gaussian perturbations are exact, then bins
//! through the same two-step (Q_out -> next-input) mapping as the
//! deployed kernel. With all σ = 0 it reduces to the integer engine.

use anyhow::Result;

use crate::coordinator::ParamSet;
use crate::infer::pipeline::{FqKwsNet, Scratch};
use crate::quant::learned_quantize;
use crate::util::Rng;

/// Table-7 noise configuration (percent of LSB).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NoiseConfig {
    pub sigma_w: f32,
    pub sigma_a: f32,
    pub sigma_mac: f32,
}

impl NoiseConfig {
    pub fn silent(&self) -> bool {
        self.sigma_w == 0.0 && self.sigma_a == 0.0 && self.sigma_mac == 0.0
    }

    /// The paper's five Table-7 operating points.
    pub fn table7_points() -> Vec<NoiseConfig> {
        [
            (1.0, 1.0, 5.0),
            (5.0, 5.0, 25.0),
            (10.0, 10.0, 50.0),
            (20.0, 20.0, 100.0),
            (30.0, 30.0, 150.0),
        ]
        .iter()
        .map(|&(w, a, m)| NoiseConfig { sigma_w: w, sigma_a: a, sigma_mac: m })
        .collect()
    }

    pub fn label(&self) -> String {
        format!("sw={}% sa={}% smac={}%", self.sigma_w, self.sigma_a, self.sigma_mac)
    }
}

/// Crossbar-array simulation of the KWS FQ network.
pub struct CrossbarKws {
    net: FqKwsNet,
    /// float weight codes per layer (conductance programming targets),
    /// layout (kdim, c_out)
    wcodes: Vec<Vec<f32>>,
}

impl CrossbarKws {
    pub fn new(params: &ParamSet, nw: f32, na: f32, frames: usize) -> Result<Self> {
        let net = FqKwsNet::from_params(params, nw, na, frames)?;
        let mut wcodes = Vec::new();
        for (i, l) in net.layers().iter().enumerate() {
            let w = params.get(&format!("conv{i}.w")).unwrap();
            let kdim = l.c_in * l.ksize;
            let mut codes = vec![0f32; kdim * l.c_out];
            for ko in 0..l.c_out {
                for ci in 0..l.c_in {
                    for f in 0..l.ksize {
                        codes[(ci * l.ksize + f) * l.c_out + ko] =
                            l.qw.int_code(w.data()[(ko * l.c_in + ci) * l.ksize + f]) as f32;
                    }
                }
            }
            wcodes.push(codes);
        }
        Ok(CrossbarKws { net, wcodes })
    }

    pub fn net(&self) -> &FqKwsNet {
        &self.net
    }

    /// One noisy inference of a single sample.
    pub fn forward_noisy(&self, x: &[f32], noise: NoiseConfig, rng: &mut Rng) -> Vec<f32> {
        if noise.silent() {
            let mut s = Scratch::default();
            return self.net.forward(x, &mut s);
        }
        let net = &self.net;
        let t_in = net.frames;
        // --- digital front end: embedding + input quantization -----------
        let (dim, n_mfcc, ew, scale, shift, es) = net.embed_view();
        let qa0 = net.layers()[0].qa;
        let mut codes = vec![0f64; dim * t_in];
        for k in 0..dim {
            for t in 0..t_in {
                let mut acc = 0f32;
                for c in 0..n_mfcc {
                    acc += ew[k * n_mfcc + c] * x[c * t_in + t];
                }
                let bn = acc * scale[k] + shift[k];
                let q = learned_quantize(bn, es, net.na, -1.0);
                codes[k * t_in + t] = qa0.int_code(q) as f64;
            }
        }
        // --- analog crossbar layers ---------------------------------------
        let mut t_cur = t_in;
        for (li, l) in net.layers().iter().enumerate() {
            let t_out = l.t_out(t_cur);
            // DAC noise on activation codes
            let acts: Vec<f64> = codes
                .iter()
                .map(|&c| c + rng.gaussian() * (noise.sigma_a as f64 / 100.0))
                .collect();
            // memory-cell noise on conductances (per inference draw)
            let wnoisy: Vec<f64> = self.wcodes[li]
                .iter()
                .map(|&c| c as f64 + rng.gaussian() * (noise.sigma_w as f64 / 100.0))
                .collect();
            let fpre = (l.qa.es as f64 / l.qa.n as f64) * (l.qw.es as f64 / l.qw.n as f64);
            let (mid_q, next_q) = net.layer_grids(li);
            let mac_lsb = mid_q.es as f64 / mid_q.n as f64;
            let mut next_codes = vec![0f64; l.c_out * t_out];
            for t in 0..t_out {
                for ko in 0..l.c_out {
                    // Kirchhoff accumulation: full analog precision
                    let mut acc = 0f64;
                    for ci in 0..l.c_in {
                        for f in 0..l.ksize {
                            acc += acts[ci * t_cur + t + f * l.dilation]
                                * wnoisy[(ci * l.ksize + f) * l.c_out + ko];
                        }
                    }
                    let mut y = acc * fpre;
                    // ADC input-referred noise
                    y += rng.gaussian() * (noise.sigma_mac as f64 / 100.0) * mac_lsb;
                    // ADC binning: same two-step as the digital kernel
                    let q1 = learned_quantize(y as f32, mid_q.es, mid_q.n, mid_q.b);
                    let code = match next_q {
                        Some(nq) => nq.int_code(q1),
                        None => mid_q.int_code(q1),
                    };
                    next_codes[ko * t_out + t] = code as f64;
                }
            }
            codes = next_codes;
            t_cur = t_out;
        }
        // --- digital back end: GAP + head ----------------------------------
        let last = net.layers().last().unwrap();
        let dq = last.lut.out;
        let mut pooled = vec![0f32; net.filters];
        for (k, p) in pooled.iter_mut().enumerate() {
            let sum: f64 = (0..t_cur).map(|t| codes[k * t_cur + t]).sum();
            *p = dq.dequantize(sum.round() as i32) / t_cur as f32;
        }
        net.head_logits(&pooled)
    }

    /// Accuracy over `n` validation samples at a noise point, averaged
    /// over `reps` independent noise draws (paper: 10 test repetitions).
    pub fn evaluate_noisy(
        &self,
        ds: &dyn crate::data::Dataset,
        n: usize,
        noise: NoiseConfig,
        reps: usize,
        seed: u64,
    ) -> f64 {
        let mut total_acc = 0.0;
        for rep in 0..reps {
            let mut rng = Rng::new(seed ^ (rep as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut correct = 0usize;
            for i in 0..n {
                let (x, y) = ds.sample(i as u64 % crate::data::VAL_SIZE, None);
                let logits = self.forward_noisy(&x, noise, &mut rng);
                let pred = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i as i32)
                    .unwrap();
                if pred == y {
                    correct += 1;
                }
            }
            total_acc += correct as f64 / n as f64;
        }
        total_acc / reps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table7_points_match_paper() {
        let pts = NoiseConfig::table7_points();
        assert_eq!(pts.len(), 5);
        assert_eq!(pts[0], NoiseConfig { sigma_w: 1.0, sigma_a: 1.0, sigma_mac: 5.0 });
        assert_eq!(pts[4], NoiseConfig { sigma_w: 30.0, sigma_a: 30.0, sigma_mac: 150.0 });
        // MAC sigma = 5x the w/a sigma at every point
        for p in &pts {
            assert!((p.sigma_mac - 5.0 * p.sigma_w).abs() < 1e-6);
        }
    }

    #[test]
    fn silent_detection() {
        assert!(NoiseConfig::default().silent());
        assert!(!NoiseConfig { sigma_w: 1.0, ..Default::default() }.silent());
    }
}
