//! Analog compute-in-memory crossbar simulator (Table 7) — graph-generic.
//!
//! Models the paper's analog accelerator target: weights stored as
//! conductances in a crossbar array (noisy memory cells), activations
//! driven by DACs (noisy), analog Kirchhoff accumulation (effectively
//! infinite precision — "comes at no additional cost"), and ADC
//! re-binning into the next layer's quantized input grid (noisy ADC).
//!
//! Noise model exactly as §4.4: zero-mean Gaussian with σ expressed in
//! **percent of one LSB** of the corresponding quantizer —
//!   * σ_w   on weight codes (memory-cell noise; 1 LSB = 1 code step),
//!   * σ_a   on activation codes (DAC noise),
//!   * σ_MAC on the analog sum, in % of the *output* quantizer's LSB
//!     (ADC input-referred noise).
//!
//! [`CrossbarSim`] walks any [`QuantGraph`] the integer engine can run
//! — the 1-D KWS stacks, the 2-D residual/pooled grammars (ResNet-32,
//! DarkNet-19, fuzzed graphs) — in f64 code-space, mirroring
//! [`QuantGraph::forward_into`] stage for stage: the FP embedding /
//! input stem and the dense head stay digital (they are digital on the
//! paper's target too), convolutions accumulate perturbed codes in f64,
//! and the ADC bins each analog sum through the **same f32 prefactor
//! the digital requant LUT was built from** ([`RequantLut::f`]), so
//! with every σ = 0 the walk is bit-identical to the integer engine.
//! Residual joins apply the exact tabulated [`AddLut`] on the post-ADC
//! integer codes; max pools are order-exact on codes; the GAP sums
//! post-ADC codes in i64 through [`QParams::dequantize_i64`] — the same
//! wide path the digital engine uses, so an arbitrarily long time axis
//! cannot silently truncate.
//!
//! [`RequantLut::f`]: crate::quant::RequantLut
//! [`AddLut`]: crate::quant::AddLut
//! [`QParams::dequantize_i64`]: crate::quant::QParams

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::ParamSet;
use crate::infer::conv::WeightKind;
use crate::infer::graph::{QuantGraph, QuantStage, Scratch};
use crate::infer::pipeline::kws_stages;
use crate::infer::{QuantConv1d, QuantConv2d};
use crate::quant::QParams;
use crate::util::Rng;

/// Table-7 noise configuration (percent of LSB).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NoiseConfig {
    pub sigma_w: f32,
    pub sigma_a: f32,
    pub sigma_mac: f32,
}

impl NoiseConfig {
    pub fn silent(&self) -> bool {
        self.sigma_w == 0.0 && self.sigma_a == 0.0 && self.sigma_mac == 0.0
    }

    /// The paper's five Table-7 operating points.
    pub fn table7_points() -> Vec<NoiseConfig> {
        [
            (1.0, 1.0, 5.0),
            (5.0, 5.0, 25.0),
            (10.0, 10.0, 50.0),
            (20.0, 20.0, 100.0),
            (30.0, 30.0, 150.0),
        ]
        .iter()
        .map(|&(w, a, m)| NoiseConfig { sigma_w: w, sigma_a: a, sigma_mac: m })
        .collect()
    }

    pub fn label(&self) -> String {
        format!("sw={}% sa={}% smac={}%", self.sigma_w, self.sigma_a, self.sigma_mac)
    }
}

/// Crossbar-array simulation of any fully-quantized [`QuantGraph`].
///
/// Construction extracts every conv layer's integer weight codes (the
/// conductance programming targets) in walk order — a residual block's
/// shortcut projection before its body, matching the forward — so the
/// per-inference noise draws perturb exactly what the hardware stores.
/// The simulator owns reusable f64 code buffers; after the first call
/// the analog walk performs no steady-state allocation, and the σ = 0
/// fast path of [`CrossbarSim::forward_noisy_into`] delegates to the
/// integer engine over the caller's [`Scratch`] without allocating at
/// all (pinned by `Scratch::capacities` in rust/tests/analog_sim.rs).
pub struct CrossbarSim {
    graph: Arc<QuantGraph>,
    /// f32 weight codes per conv layer in walk order, tap-major
    /// `(taps, c_out)` — the same layout the kernels consume
    wcodes: Vec<Vec<f32>>,
    /// ping-pong f64 code buffers for the analog walk
    buf_a: Vec<f64>,
    buf_b: Vec<f64>,
    /// residual shortcut codes, held while the block body ping-pongs
    buf_skip: Vec<f64>,
    /// DAC-perturbed activation codes of the current layer
    buf_acts: Vec<f64>,
    /// cell-perturbed weight codes of the current layer
    buf_w: Vec<f64>,
}

impl CrossbarSim {
    /// Simulator over a shared graph (any architecture the engine runs).
    pub fn new(graph: Arc<QuantGraph>) -> Self {
        let mut wcodes = Vec::new();
        for stage in graph.stages() {
            match stage {
                QuantStage::FqConvStack(st) => {
                    for l in &st.layers {
                        wcodes.push(weight_codes(&l.weights, l.c_in * l.ksize, l.c_out));
                    }
                }
                QuantStage::FqConv2dStack(st) => {
                    for l in &st.layers {
                        wcodes.push(weight_codes(&l.weights, l.c_in * l.ksize * l.ksize, l.c_out));
                    }
                }
                QuantStage::Residual(r) => {
                    // shortcut projection first: the walk stashes the
                    // skip before running the body
                    if let Some(d) = &r.down {
                        wcodes.push(weight_codes(&d.weights, d.c_in * d.ksize * d.ksize, d.c_out));
                    }
                    for l in &r.body {
                        wcodes.push(weight_codes(&l.weights, l.c_in * l.ksize * l.ksize, l.c_out));
                    }
                }
                _ => {}
            }
        }
        CrossbarSim {
            graph,
            wcodes,
            buf_a: Vec::new(),
            buf_b: Vec::new(),
            buf_skip: Vec::new(),
            buf_acts: Vec::new(),
            buf_w: Vec::new(),
        }
    }

    /// Convenience constructor for the trained KWS pipeline: builds the
    /// quantized graph from a FQ [`ParamSet`] (same stage list as
    /// [`crate::infer::FqKwsNet::from_params`]) and wraps it.
    pub fn from_kws_params(params: &ParamSet, nw: f32, na: f32, frames: usize) -> Result<Self> {
        let graph = QuantGraph::new(kws_stages(params, nw, na)?, frames)?;
        Ok(CrossbarSim::new(Arc::new(graph)))
    }

    /// The simulated graph (also the σ = 0 digital reference).
    pub fn graph(&self) -> &Arc<QuantGraph> {
        &self.graph
    }

    /// One noisy inference of a single sample into the caller's logit
    /// slice. A silent config takes the integer engine's allocation-free
    /// forward over `s`; any σ > 0 takes the analog walk
    /// ([`CrossbarSim::forward_analog_into`]).
    pub fn forward_noisy_into(
        &mut self,
        x: &[f32],
        noise: NoiseConfig,
        rng: &mut Rng,
        s: &mut Scratch,
        logits: &mut [f32],
    ) {
        if noise.silent() {
            // σ = 0 fast path: the digital engine over the caller's
            // reusable scratch — no per-call allocation (the old code
            // built a fresh Scratch::default() per call, a hot-loop
            // allocation under Monte-Carlo reps)
            self.graph.forward_into(x, s, logits, 1);
        } else {
            self.forward_analog_into(x, noise, rng, s, logits);
        }
    }

    /// Allocating convenience wrapper over
    /// [`CrossbarSim::forward_noisy_into`].
    pub fn forward_noisy(
        &mut self,
        x: &[f32],
        noise: NoiseConfig,
        rng: &mut Rng,
        s: &mut Scratch,
    ) -> Vec<f32> {
        let mut logits = vec![0f32; self.graph.classes()];
        self.forward_noisy_into(x, noise, rng, s, &mut logits);
        logits
    }

    /// The f64 code-space walk, unconditionally — even at σ = 0, where
    /// it must be bit-identical to [`QuantGraph::forward_into`] (the
    /// bit-identity tests call this directly so the analog path itself
    /// is exercised, not the silent shortcut). `s` supplies the i8/f32
    /// staging for the digital front end, pooled features and head.
    pub fn forward_analog_into(
        &mut self,
        x: &[f32],
        noise: NoiseConfig,
        rng: &mut Rng,
        s: &mut Scratch,
        logits: &mut [f32],
    ) {
        let g = Arc::clone(&self.graph);
        debug_assert_eq!(x.len(), g.in_numel(), "feature buffer size");
        assert_eq!(logits.len(), g.classes(), "logit buffer size");
        let is_2d = g.in_shape().len() == 3;
        let mut t_cur = g.frames();
        let (mut h_cur, mut w_cur) =
            if is_2d { (g.in_shape()[1], g.in_shape()[2]) } else { (0, 0) };
        // move the reusable buffers out so the walk can borrow
        // `self.wcodes` immutably alongside them
        let mut a = std::mem::take(&mut self.buf_a);
        let mut b = std::mem::take(&mut self.buf_b);
        let mut skip = std::mem::take(&mut self.buf_skip);
        let mut acts = std::mem::take(&mut self.buf_acts);
        let mut wn = std::mem::take(&mut self.buf_w);
        let mut wi = 0usize;
        let mut cur_in_a = true;
        for stage in g.stages() {
            match stage {
                QuantStage::FpEmbed(e) => {
                    // digital-exact front end (digital on the paper's
                    // target too), widened to f64 codes
                    e.forward_into(x, t_cur, &mut s.a, &mut s.fa);
                    widen(&s.a, &mut a);
                    cur_in_a = true;
                }
                QuantStage::QuantStem2d(st) => {
                    st.forward_into(x, &mut s.a);
                    widen(&s.a, &mut a);
                    cur_in_a = true;
                }
                QuantStage::FqConvStack(stack) => {
                    for l in &stack.layers {
                        let (input, output) =
                            if cur_in_a { (&a, &mut b) } else { (&b, &mut a) };
                        analog_conv1d(
                            l,
                            &self.wcodes[wi],
                            input,
                            t_cur,
                            noise,
                            rng,
                            &mut acts,
                            &mut wn,
                            output,
                        );
                        wi += 1;
                        t_cur = l.t_out(t_cur);
                        cur_in_a = !cur_in_a;
                    }
                }
                QuantStage::FqConv2dStack(stack) => {
                    for l in &stack.layers {
                        let (input, output) =
                            if cur_in_a { (&a, &mut b) } else { (&b, &mut a) };
                        analog_conv2d(
                            l,
                            &self.wcodes[wi],
                            input,
                            h_cur,
                            w_cur,
                            noise,
                            rng,
                            &mut acts,
                            &mut wn,
                            output,
                        );
                        wi += 1;
                        let (h2, w2) = l.out_hw(h_cur, w_cur);
                        h_cur = h2;
                        w_cur = w2;
                        cur_in_a = !cur_in_a;
                    }
                }
                QuantStage::Residual(r) => {
                    // stash the shortcut (identity copy or noisy analog
                    // projection) before the body ping-pongs
                    {
                        let input = if cur_in_a { &a } else { &b };
                        if let Some(d) = &r.down {
                            analog_conv2d(
                                d,
                                &self.wcodes[wi],
                                input,
                                h_cur,
                                w_cur,
                                noise,
                                rng,
                                &mut acts,
                                &mut wn,
                                &mut skip,
                            );
                            wi += 1;
                        } else {
                            skip.clear();
                            skip.extend_from_slice(input);
                        }
                    }
                    for l in &r.body {
                        let (input, output) =
                            if cur_in_a { (&a, &mut b) } else { (&b, &mut a) };
                        analog_conv2d(
                            l,
                            &self.wcodes[wi],
                            input,
                            h_cur,
                            w_cur,
                            noise,
                            rng,
                            &mut acts,
                            &mut wn,
                            output,
                        );
                        wi += 1;
                        let (h2, w2) = l.out_hw(h_cur, w_cur);
                        h_cur = h2;
                        w_cur = w2;
                        cur_in_a = !cur_in_a;
                    }
                    // exact integer skip-add on the post-ADC codes (both
                    // operands are integer-valued i8-range by
                    // construction: int_code clamps to the grid)
                    let cur = if cur_in_a { &mut a } else { &mut b };
                    debug_assert_eq!(cur.len(), skip.len(), "residual join geometry");
                    for (o, &sk) in cur.iter_mut().zip(skip.iter()) {
                        *o = r.add.apply(*o as i8, sk as i8) as f64;
                    }
                }
                QuantStage::MaxPool2d(p) => {
                    let (input, output) = if cur_in_a { (&a, &mut b) } else { (&b, &mut a) };
                    debug_assert_eq!(input.len() % (h_cur * w_cur), 0, "live code geometry");
                    let channels = input.len() / (h_cur * w_cur);
                    analog_max_pool(p, input, channels, h_cur, w_cur, output);
                    let (h2, w2) = p.out_hw(h_cur, w_cur);
                    h_cur = h2;
                    w_cur = w2;
                    cur_in_a = !cur_in_a;
                }
                QuantStage::GlobalAvgPool(gp) => {
                    let codes = if cur_in_a { &a } else { &b };
                    let t = if is_2d { h_cur * w_cur } else { t_cur };
                    s.pooled.clear();
                    s.pooled.resize(gp.channels, 0.0);
                    analog_gap(codes, gp.channels, t, &gp.dq, &mut s.pooled);
                }
                QuantStage::DenseHead(h) => h.forward_into(&s.pooled, logits),
            }
        }
        self.buf_a = a;
        self.buf_b = b;
        self.buf_skip = skip;
        self.buf_acts = acts;
        self.buf_w = wn;
    }

    /// Accuracy over `n` validation samples at a noise point, averaged
    /// over `reps` independent noise draws (paper: 10 test repetitions).
    /// `n` is clamped to [`crate::data::VAL_SIZE`]: the held-out set has
    /// exactly that many ids, and the old modulo wrap silently
    /// double-counted early samples, inflating the reported accuracy.
    pub fn evaluate_noisy(
        &mut self,
        ds: &dyn crate::data::Dataset,
        n: usize,
        noise: NoiseConfig,
        reps: usize,
        seed: u64,
    ) -> f64 {
        let n = n.clamp(1, crate::data::VAL_SIZE as usize);
        let mut s = Scratch::for_graph(&self.graph);
        let mut logits = vec![0f32; self.graph.classes()];
        let mut total_acc = 0.0;
        for rep in 0..reps {
            let mut rng = Rng::new(seed ^ (rep as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut correct = 0usize;
            for i in 0..n {
                let (x, y) = ds.sample(i as u64, None);
                self.forward_noisy_into(&x, noise, &mut rng, &mut s, &mut logits);
                if argmax(&logits) as i32 == y {
                    correct += 1;
                }
            }
            total_acc += correct as f64 / n as f64;
        }
        total_acc / (reps.max(1)) as f64
    }
}

/// Index of the largest logit (ties break low, like the digital eval).
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Integer weight codes of one conv layer as f32 conductance targets,
/// tap-major `(taps, c_out)` — the layout the kernels consume.
fn weight_codes(w: &WeightKind, taps: usize, c_out: usize) -> Vec<f32> {
    let mut codes = vec![0f32; taps * c_out];
    match w {
        WeightKind::Dense { b } => {
            debug_assert_eq!(b.len(), taps * c_out, "dense weight geometry");
            for (c, &v) in codes.iter_mut().zip(b.iter()) {
                *c = v as f32;
            }
        }
        WeightKind::Ternary(t) => {
            for ko in 0..c_out {
                let (plus, minus) = t.col(ko);
                for &p in plus {
                    codes[p as usize * c_out + ko] = 1.0;
                }
                for &m in minus {
                    codes[m as usize * c_out + ko] = -1.0;
                }
            }
        }
    }
    codes
}

fn widen(codes: &[i8], out: &mut Vec<f64>) {
    out.clear();
    out.extend(codes.iter().map(|&c| c as f64));
}

/// Perturb f64 activation codes with DAC noise (σ in % of one code
/// step). σ = 0 copies exactly and draws nothing, so the silent walk
/// stays bit-exact and cheap.
fn perturb_acts(codes: &[f64], sigma_pct: f32, rng: &mut Rng, out: &mut Vec<f64>) {
    out.clear();
    if sigma_pct == 0.0 {
        out.extend_from_slice(codes);
    } else {
        let s = sigma_pct as f64 / 100.0;
        out.extend(codes.iter().map(|&c| c + rng.gaussian() * s));
    }
}

/// Perturb programmed weight codes with memory-cell noise, drawn fresh
/// per inference (σ in % of one code step).
fn perturb_weights(codes: &[f32], sigma_pct: f32, rng: &mut Rng, out: &mut Vec<f64>) {
    out.clear();
    if sigma_pct == 0.0 {
        out.extend(codes.iter().map(|&c| c as f64));
    } else {
        let s = sigma_pct as f64 / 100.0;
        out.extend(codes.iter().map(|&c| c as f64 + rng.gaussian() * s));
    }
}

/// ADC binning of one analog accumulator through the *same* f32
/// prefactor the digital requant LUT was built from
/// ([`crate::quant::RequantLut::f`]). At σ_MAC = 0 this is exactly the
/// LUT's reference: fused layers compute
/// `next.int_code(mid.quantize(acc as f32 * f))`, unfused
/// `mid.int_code(acc as f32 * f)` — identical rounding on both sides,
/// so the σ = 0 walk is bit-identical for every in-range accumulator.
/// (Recomputing the prefactor in f64 here would differ from the LUT by
/// ULPs and break rounding ties.)
fn adc_bin(
    f: f32,
    mid: &QParams,
    next: Option<&QParams>,
    acc: f64,
    sigma_mac_pct: f32,
    mac_lsb: f64,
    rng: &mut Rng,
) -> i32 {
    let mut y = (acc as f32) * f;
    if sigma_mac_pct != 0.0 {
        // ADC input-referred noise, in % of the output quantizer's LSB
        y += (rng.gaussian() * (sigma_mac_pct as f64 / 100.0) * mac_lsb) as f32;
    }
    match next {
        Some(nq) => nq.int_code(mid.quantize(y)),
        None => mid.int_code(y),
    }
}

/// One noisy 1-D analog conv layer: f64 activation codes `(c_in,
/// t_cur)` → post-ADC integer codes `(c_out, t_out)`.
#[allow(clippy::too_many_arguments)]
fn analog_conv1d(
    l: &QuantConv1d,
    wc: &[f32],
    input: &[f64],
    t_cur: usize,
    noise: NoiseConfig,
    rng: &mut Rng,
    acts: &mut Vec<f64>,
    wn: &mut Vec<f64>,
    out: &mut Vec<f64>,
) {
    let t_out = l.t_out(t_cur);
    perturb_acts(input, noise.sigma_a, rng, acts);
    perturb_weights(wc, noise.sigma_w, rng, wn);
    let mac_lsb = l.mid.lsb() as f64;
    out.clear();
    out.resize(l.c_out * t_out, 0.0);
    for t in 0..t_out {
        for ko in 0..l.c_out {
            // Kirchhoff accumulation: full analog precision
            let mut acc = 0f64;
            for ci in 0..l.c_in {
                for f in 0..l.ksize {
                    acc += acts[ci * t_cur + t + f * l.dilation]
                        * wn[(ci * l.ksize + f) * l.c_out + ko];
                }
            }
            let code =
                adc_bin(l.lut.f, &l.mid, l.next.as_ref(), acc, noise.sigma_mac, mac_lsb, rng);
            out[ko * t_out + t] = code as f64;
        }
    }
}

/// One noisy 2-D analog conv layer: f64 activation codes `(c_in, h,
/// w)` → post-ADC integer codes `(c_out, h_out, w_out)`. Zero padding
/// contributes no current and carries no DAC noise — an undriven line
/// is exactly zero, so out-of-bounds taps are skipped.
#[allow(clippy::too_many_arguments)]
fn analog_conv2d(
    l: &QuantConv2d,
    wc: &[f32],
    input: &[f64],
    h_in: usize,
    w_in: usize,
    noise: NoiseConfig,
    rng: &mut Rng,
    acts: &mut Vec<f64>,
    wn: &mut Vec<f64>,
    out: &mut Vec<f64>,
) {
    let (h_out, w_out) = l.out_hw(h_in, w_in);
    perturb_acts(input, noise.sigma_a, rng, acts);
    perturb_weights(wc, noise.sigma_w, rng, wn);
    let mac_lsb = l.mid.lsb() as f64;
    let k = l.ksize;
    out.clear();
    out.resize(l.c_out * h_out * w_out, 0.0);
    for oh in 0..h_out {
        for ow in 0..w_out {
            for ko in 0..l.c_out {
                let mut acc = 0f64;
                for ci in 0..l.c_in {
                    for fh in 0..k {
                        let ih = (oh * l.stride + fh) as isize - l.pad as isize;
                        if ih < 0 || ih >= h_in as isize {
                            continue;
                        }
                        for fw in 0..k {
                            let iw = (ow * l.stride + fw) as isize - l.pad as isize;
                            if iw < 0 || iw >= w_in as isize {
                                continue;
                            }
                            acc += acts[(ci * h_in + ih as usize) * w_in + iw as usize]
                                * wn[((ci * k + fh) * k + fw) * l.c_out + ko];
                        }
                    }
                }
                let code =
                    adc_bin(l.lut.f, &l.mid, l.next.as_ref(), acc, noise.sigma_mac, mac_lsb, rng);
                out[(ko * h_out + oh) * w_out + ow] = code as f64;
            }
        }
    }
}

/// Order-exact max pool over post-ADC codes (codes are integers; every
/// quantizer grid is monotone, so the code max is the value max —
/// mirrors [`crate::infer::graph::MaxPool2d::forward_into`]).
fn analog_max_pool(
    p: &crate::infer::graph::MaxPool2d,
    x: &[f64],
    channels: usize,
    h_in: usize,
    w_in: usize,
    out: &mut Vec<f64>,
) {
    let (h_out, w_out) = p.out_hw(h_in, w_in);
    out.clear();
    out.resize(channels * h_out * w_out, 0.0);
    for c in 0..channels {
        let plane = &x[c * h_in * w_in..(c + 1) * h_in * w_in];
        let oplane = &mut out[c * h_out * w_out..(c + 1) * h_out * w_out];
        for oh in 0..h_out {
            for ow in 0..w_out {
                let (h0, w0) = (oh * p.stride, ow * p.stride);
                let mut m = f64::NEG_INFINITY;
                for ih in h0..h0 + p.ksize {
                    for &v in &plane[ih * w_in + w0..ih * w_in + w0 + p.ksize] {
                        m = m.max(v);
                    }
                }
                oplane[oh * w_out + ow] = m;
            }
        }
    }
}

/// Analog GAP, mirroring the digital
/// [`crate::infer::graph::global_avg_pool_into`]: post-ADC codes are
/// exact integers, so the sum runs in i64 and dequantizes through
/// [`QParams::dequantize_i64`]. The `sum.round() as i32` cast this
/// replaces saturated once `t * 127` overflowed i32 — the same
/// truncation PR 1 fixed on the digital path.
fn analog_gap(codes: &[f64], channels: usize, t: usize, dq: &QParams, pooled: &mut [f32]) {
    debug_assert_eq!(codes.len(), channels * t, "pooled code geometry");
    debug_assert_eq!(pooled.len(), channels);
    for (k, p) in pooled.iter_mut().enumerate() {
        let mut sum = 0i64;
        for &c in &codes[k * t..(k + 1) * t] {
            sum += c as i64;
        }
        *p = dq.dequantize_i64(sum) / t as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table7_points_match_paper() {
        let pts = NoiseConfig::table7_points();
        assert_eq!(pts.len(), 5);
        assert_eq!(pts[0], NoiseConfig { sigma_w: 1.0, sigma_a: 1.0, sigma_mac: 5.0 });
        assert_eq!(pts[4], NoiseConfig { sigma_w: 30.0, sigma_a: 30.0, sigma_mac: 150.0 });
        // MAC sigma = 5x the w/a sigma at every point
        for p in &pts {
            assert!((p.sigma_mac - 5.0 * p.sigma_w).abs() < 1e-6);
        }
    }

    #[test]
    fn silent_detection() {
        assert!(NoiseConfig::default().silent());
        assert!(!NoiseConfig { sigma_w: 1.0, ..Default::default() }.silent());
    }

    #[test]
    fn analog_gap_survives_huge_time_axis() {
        // the analog twin of the digital regression in
        // rust/tests/parallel.rs: t large enough that a sum of
        // max-magnitude codes overflows i32 (127 * 20e6 ≈ 2.54e9 >
        // 2^31) — the old `sum.round() as i32` saturated here
        let t = 20_000_000usize;
        let codes = vec![127f64; t];
        let dq = QParams::new(1.0, 7.0, 0.0);
        let mut pooled = [0f32; 1];
        analog_gap(&codes, 1, t, &dq, &mut pooled);
        let want = (127.0f64 / 7.0) as f32; // mean code 127 exactly
        assert!((pooled[0] - want).abs() < 1e-4, "wide sum truncated: got {}", pooled[0]);
        assert!(pooled[0] > 0.0, "i32 saturation would pin the mean at the clamp");
        // small in-range sums agree with the plain i32 dequantize
        let codes = [3.0f64, -2.0, 7.0, 0.0];
        let mut pooled = [0f32; 2];
        analog_gap(&codes, 2, 2, &dq, &mut pooled);
        assert_eq!(pooled[0], dq.dequantize(1) / 2.0);
        assert_eq!(pooled[1], dq.dequantize(7) / 2.0);
    }

    #[test]
    fn weight_code_extraction_matches_layouts() {
        // dense: codes pass through in tap-major layout
        let dense = WeightKind::Dense { b: vec![1i8, -2, 3, 0, 5, -6] };
        let codes = weight_codes(&dense, 3, 2);
        assert_eq!(codes, vec![1.0, -2.0, 3.0, 0.0, 5.0, -6.0]);
    }
}
