//! In-tree concurrency model checking and the `check::sync` facade.
//!
//! The engine's fork-join pool and the serving registry are condvar/lock
//! protocols whose correctness depends on *which* interleavings the OS
//! happens to produce under test. This module closes that gap without
//! vendoring loom/shuttle (no new dependencies in this image):
//!
//! - `check::sync` is a drop-in facade over `std::sync` primitives
//!   (`Mutex`, `Condvar`, `RwLock`, the atomics the engine uses, and
//!   named thread spawning). In a normal build it re-exports `std::sync`
//!   types verbatim — zero cost, zero behavior change. Under
//!   `--features model-check` the same names resolve to wrappers that
//!   route every operation through a controlled scheduler.
//! - `check::sched` (model-check builds only) serializes the "threads"
//!   of a model run onto one runnable-at-a-time schedule and explores
//!   the tree of scheduling decisions: depth-first over yield points
//!   with a bounded-preemption budget, falling back to seeded random
//!   schedules for state spaces larger than the DFS cap. It detects
//!   deadlock (which is also how a lost notify manifests), panics /
//!   assertion failures inside the model, and reports a replayable
//!   schedule trace for any failure.
//!
//! Rules for engine code (enforced by `cargo xtask lint`):
//!
//! - Concurrency-bearing modules (`exec`, `serve`, `infer::graph`) must
//!   import `Mutex`/`Condvar`/`RwLock` from `crate::check::sync`, never
//!   from `std::sync` directly. `Arc`, `OnceLock`, `mpsc` and
//!   `atomic::Ordering` stay in `std::sync` — the facade does not wrap
//!   them.
//! - Threads are spawned through `check::sync::spawn_named` so model
//!   runs can capture them.
//!
//! Model tests live in `rust/tests/model_check.rs` and run with
//! `cargo test -p fqconv --features model-check --test model_check`.
//! See CONCURRENCY.md at the repo root for the protocol invariants the
//! model tests pin.

#[cfg(feature = "model-check")]
pub mod sched;
pub mod sync;

#[cfg(feature = "model-check")]
pub use sched::{check, check_with, replay, Config, Failure, FailureKind, Report};
