//! Controlled scheduler behind the `check::sync` facade (model-check
//! builds only).
//!
//! A model run executes the checked closure on real OS threads, but
//! serialized: exactly one model thread is runnable at a time, and it
//! only advances to the next *yield point* (a facade operation — lock
//! acquire, condvar wait/notify, atomic access, spawn) before the
//! scheduler decides who runs next. Every decision picks an index into
//! a deterministic candidate list (the current thread first if still
//! runnable, then the other runnable threads in id order), so a run is
//! fully described by the sequence of chosen indices — the *schedule*.
//!
//! Exploration is a stateless depth-first search over that decision
//! tree in the CHESS style: re-run the closure from scratch with a
//! schedule prefix, record the branching factor at each decision, and
//! backtrack on the deepest incrementable choice. Switching away from a
//! thread that could have kept running costs one unit of the
//! *preemption budget* (`Config::preemptions`); once spent, only forced
//! switches (current thread blocked or finished) branch. Bounded
//! preemption keeps the tree finite and small while still covering the
//! schedules that break real condvar protocols. Past `max_execs` the
//! search falls back to `random_execs` seeded random schedules.
//!
//! Detected failures:
//! - **deadlock / lost notify** — no thread is runnable but some are
//!   unfinished (a dropped or misordered `notify` strands waiters here);
//! - **panic** — any assertion or panic inside the model closure.
//!
//! Every failure carries the choice sequence that produced it;
//! `replay` re-runs a closure under a recorded schedule.

use std::cell::Cell;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar as StdCondvar, Mutex as StdMutex, MutexGuard as StdMutexGuard, OnceLock};
use std::thread;

// ---------------------------------------------------------------------------
// public API types
// ---------------------------------------------------------------------------

/// Exploration limits for [`check_with`].
#[derive(Clone, Debug)]
pub struct Config {
    /// Budget of voluntary context switches (switching away from a
    /// still-runnable thread) per execution. Forced switches are free.
    pub preemptions: usize,
    /// Cap on DFS executions before falling back to random schedules.
    pub max_execs: usize,
    /// Number of seeded random executions if the DFS cap is hit.
    pub random_execs: usize,
    /// Seed for the random fallback (and for nothing else — DFS is
    /// deterministic).
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { preemptions: 2, max_execs: 20_000, random_execs: 2_000, seed: 0x5eed_cafe }
    }
}

/// What kind of failure the checker found.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// No runnable thread but unfinished threads remain (includes lost
    /// wakeups: the stranded waiter shows up in the message).
    Deadlock,
    /// A model thread panicked (assertion failure, index error, ...).
    Panic,
}

/// A failing schedule with enough context to diagnose and replay it.
#[derive(Clone, Debug)]
pub struct Failure {
    pub kind: FailureKind,
    /// Human-readable description (panic message or blocked-thread set).
    pub message: String,
    /// The choice-index sequence that reproduces this failure via
    /// [`replay`].
    pub schedule: Vec<usize>,
    /// Per-yield-point log of the failing execution: `tN name: op`.
    pub trace: Vec<String>,
}

/// Outcome of a [`check_with`] exploration.
#[derive(Clone, Debug)]
pub struct Report {
    /// Number of executions run (DFS + random).
    pub execs: usize,
    /// True iff the DFS exhausted the whole bounded-preemption tree.
    pub complete: bool,
    /// First failure found, if any (exploration stops at the first).
    pub failure: Option<Failure>,
}

// ---------------------------------------------------------------------------
// scheduler state
// ---------------------------------------------------------------------------

/// Panic payload used to unwind model threads when an execution aborts
/// (failure found or exploration tearing down). Never escapes `check`.
struct Abort;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BlockOn {
    /// Waiting to acquire a mutex/rwlock (resource id).
    Resource(usize),
    /// Waiting on a condvar (condvar id, FIFO arrival order).
    Condvar(usize, u64),
    /// Waiting for a model thread to finish (thread id).
    Join(usize),
}

#[derive(Clone, Debug, PartialEq, Eq)]
enum ThreadState {
    Runnable,
    Blocked(BlockOn),
    Finished,
}

struct ModelThread {
    name: String,
    state: ThreadState,
}

#[derive(Clone, Copy)]
struct Choice {
    /// Chosen index into the candidate list at this decision point.
    idx: usize,
    /// Number of candidates that were available.
    n: usize,
}

struct SchedState {
    /// True while an execution is in flight. Facade ops from threads
    /// without a model id (plain test threads) never consult this.
    active: bool,
    threads: Vec<ModelThread>,
    /// Id of the thread currently granted the CPU.
    current: usize,
    /// Unfinished model threads.
    live: usize,
    /// Prescribed choice-index prefix for this execution.
    schedule: Vec<usize>,
    /// Next decision index.
    depth: usize,
    /// Choices actually taken this execution (idx + branching factor).
    choices: Vec<Choice>,
    preemptions: usize,
    budget: usize,
    /// Some(rng-state): past the schedule prefix, choose randomly
    /// instead of defaulting to index 0.
    rng: Option<u64>,
    /// FIFO ticket counter for condvar waiters.
    wait_seq: u64,
    aborted: bool,
    failure: Option<Failure>,
    /// (thread id, op label) per yield point; rendered only on failure.
    trace: Vec<(usize, &'static str)>,
    /// OS handles of threads spawned inside the model, joined by the
    /// controller after each execution.
    os_handles: Vec<thread::JoinHandle<()>>,
}

impl SchedState {
    fn idle() -> Self {
        SchedState {
            active: false,
            threads: Vec::new(),
            current: 0,
            live: 0,
            schedule: Vec::new(),
            depth: 0,
            choices: Vec::new(),
            preemptions: 0,
            budget: 0,
            rng: None,
            wait_seq: 0,
            aborted: false,
            failure: None,
            trace: Vec::new(),
            os_handles: Vec::new(),
        }
    }

    fn rendered_trace(&self) -> Vec<String> {
        self.trace
            .iter()
            .map(|&(tid, op)| {
                let name = self.threads.get(tid).map(|t| t.name.as_str()).unwrap_or("?");
                format!("t{tid} {name}: {op}")
            })
            .collect()
    }

    fn taken_schedule(&self) -> Vec<usize> {
        self.choices.iter().map(|c| c.idx).collect()
    }
}

struct Global {
    lock: StdMutex<SchedState>,
    cv: StdCondvar,
}

fn global() -> &'static Global {
    static G: OnceLock<Global> = OnceLock::new();
    G.get_or_init(|| Global { lock: StdMutex::new(SchedState::idle()), cv: StdCondvar::new() })
}

/// Serializes whole model runs: `cargo test` runs tests on parallel
/// threads, and the scheduler is a process-global singleton.
fn run_lock() -> &'static StdMutex<()> {
    static L: OnceLock<StdMutex<()>> = OnceLock::new();
    L.get_or_init(|| StdMutex::new(()))
}

thread_local! {
    /// Model-thread id of the current OS thread, if it is one.
    static TL_ID: Cell<Option<usize>> = const { Cell::new(None) };
}

fn tl_id() -> Option<usize> {
    TL_ID.with(|c| c.get())
}

/// True iff the calling OS thread is a thread of an in-flight model
/// execution. The facade uses this as its model/std dispatch switch.
pub(crate) fn on_model_thread() -> bool {
    tl_id().is_some()
}

/// Fresh id for a facade mutex/rwlock/condvar (used as the blocked-set
/// key; allocation order is irrelevant to exploration).
pub(crate) fn new_resource_id() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

fn lock_state() -> StdMutexGuard<'static, SchedState> {
    global().lock.lock().unwrap_or_else(|e| e.into_inner())
}

fn lcg(state: &mut u64) -> u64 {
    *state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
    *state >> 33
}

// ---------------------------------------------------------------------------
// core scheduling
// ---------------------------------------------------------------------------

/// Record a failure and abort the execution. The caller must
/// `cv.notify_all()` afterwards so blocked threads unwind.
fn fail(st: &mut SchedState, kind: FailureKind, message: String) {
    if st.failure.is_none() {
        st.failure = Some(Failure {
            kind,
            message,
            schedule: st.taken_schedule(),
            trace: st.rendered_trace(),
        });
    }
    st.aborted = true;
}

/// Make a scheduling decision: pick the next thread to run and set
/// `current`. `me` is the deciding thread (it may be blocked or
/// finished — then the switch is forced and free). Detects deadlock.
fn pick_next(st: &mut SchedState, me: usize) {
    if st.aborted || st.live == 0 {
        return;
    }
    let me_runnable = matches!(st.threads[me].state, ThreadState::Runnable);
    // Candidates: current-thread-first (continuing is the free default),
    // then the other runnable threads in id order.
    let mut cands: Vec<usize> = Vec::new();
    if me_runnable {
        cands.push(me);
    }
    for (tid, t) in st.threads.iter().enumerate() {
        if tid != me && matches!(t.state, ThreadState::Runnable) {
            cands.push(tid);
        }
    }
    if cands.is_empty() {
        let blocked: Vec<String> = st
            .threads
            .iter()
            .enumerate()
            .filter_map(|(tid, t)| match &t.state {
                ThreadState::Blocked(on) => {
                    Some(format!("t{tid} {} blocked on {:?}", t.name, on))
                }
                _ => None,
            })
            .collect();
        fail(
            st,
            FailureKind::Deadlock,
            format!("deadlock: no runnable thread; {}", blocked.join("; ")),
        );
        return;
    }
    // Out of preemption budget: the current thread must keep running.
    if me_runnable && cands.len() > 1 && st.preemptions >= st.budget {
        cands.truncate(1);
    }
    let idx = if st.depth < st.schedule.len() {
        st.schedule[st.depth].min(cands.len() - 1)
    } else if let Some(rng) = st.rng.as_mut() {
        (lcg(rng) as usize) % cands.len()
    } else {
        0
    };
    st.choices.push(Choice { idx, n: cands.len() });
    st.depth += 1;
    let next = cands[idx];
    if me_runnable && next != me {
        st.preemptions += 1;
    }
    st.current = next;
}

/// Park the calling OS thread until the scheduler grants it the CPU
/// (or the execution aborts, in which case unwind via `Abort`).
fn wait_granted(mut st: StdMutexGuard<'_, SchedState>, me: usize) {
    loop {
        if st.aborted {
            drop(st);
            panic::panic_any(Abort);
        }
        if st.current == me && matches!(st.threads[me].state, ThreadState::Runnable) {
            return;
        }
        st = global().cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
}

/// Yield point: give the scheduler a chance to switch threads before
/// the caller's next facade operation. No-op off model threads.
pub(crate) fn op_yield(op: &'static str) {
    let Some(me) = tl_id() else { return };
    let mut st = lock_state();
    if !st.active {
        return;
    }
    st.trace.push((me, op));
    pick_next(&mut st, me);
    global().cv.notify_all();
    wait_granted(st, me);
}

/// Block the calling model thread on a mutex/rwlock until `release`
/// wakes it. The caller retries its `try_lock` after this returns.
pub(crate) fn block_resource(id: usize, op: &'static str) {
    let Some(me) = tl_id() else { return };
    let mut st = lock_state();
    if !st.active {
        return;
    }
    st.trace.push((me, op));
    st.threads[me].state = ThreadState::Blocked(BlockOn::Resource(id));
    pick_next(&mut st, me);
    global().cv.notify_all();
    wait_granted(st, me);
}

/// A mutex/rwlock was released: every thread blocked on it becomes
/// runnable again (they re-contend at their next grant). Not itself a
/// yield point — the releasing thread keeps the CPU.
pub(crate) fn release(id: usize) {
    if tl_id().is_none() {
        return;
    }
    let mut st = lock_state();
    if !st.active || st.aborted {
        return;
    }
    for t in st.threads.iter_mut() {
        if t.state == ThreadState::Blocked(BlockOn::Resource(id)) {
            t.state = ThreadState::Runnable;
        }
    }
    global().cv.notify_all();
}

/// Enqueue the calling thread as a condvar waiter. Must be called
/// *before* the associated mutex guard is dropped so the
/// wait-atomicity contract holds (no yield point in between: the
/// caller keeps the CPU until `cv_block`).
pub(crate) fn cv_enqueue(id: usize) {
    let Some(me) = tl_id() else { return };
    let mut st = lock_state();
    if !st.active {
        return;
    }
    let seq = st.wait_seq;
    st.wait_seq += 1;
    st.trace.push((me, "cv-wait"));
    st.threads[me].state = ThreadState::Blocked(BlockOn::Condvar(id, seq));
}

/// Park until a notify wakes this thread (enqueued via `cv_enqueue`).
pub(crate) fn cv_block() {
    let Some(me) = tl_id() else { return };
    let mut st = lock_state();
    if !st.active {
        return;
    }
    pick_next(&mut st, me);
    global().cv.notify_all();
    wait_granted(st, me);
}

/// Wake one (FIFO) or all waiters of a condvar. The caller should pass
/// through an `op_yield` first so the notify placement is explored.
pub(crate) fn cv_wake(id: usize, all: bool) {
    if tl_id().is_none() {
        return;
    }
    let mut st = lock_state();
    if !st.active || st.aborted {
        return;
    }
    let mut waiters: Vec<(u64, usize)> = st
        .threads
        .iter()
        .enumerate()
        .filter_map(|(tid, t)| match t.state {
            ThreadState::Blocked(BlockOn::Condvar(cid, seq)) if cid == id => Some((seq, tid)),
            _ => None,
        })
        .collect();
    waiters.sort_unstable();
    if !all {
        waiters.truncate(1);
    }
    for &(_, tid) in &waiters {
        st.threads[tid].state = ThreadState::Runnable;
    }
    global().cv.notify_all();
}

/// Block until model thread `target` finishes.
pub(crate) fn join_wait(target: usize) {
    let Some(me) = tl_id() else { return };
    loop {
        let mut st = lock_state();
        if !st.active {
            return;
        }
        if st.aborted {
            drop(st);
            panic::panic_any(Abort);
        }
        if matches!(st.threads[target].state, ThreadState::Finished) {
            return;
        }
        st.trace.push((me, "join"));
        st.threads[me].state = ThreadState::Blocked(BlockOn::Join(target));
        pick_next(&mut st, me);
        global().cv.notify_all();
        wait_granted(st, me);
    }
}

/// True iff model thread `target` has finished.
pub(crate) fn is_finished(target: usize) -> bool {
    let st = lock_state();
    st.active && matches!(st.threads.get(target).map(|t| &t.state), Some(ThreadState::Finished))
}

fn payload_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

/// Final bookkeeping for a model thread. `panic_msg` is `Some` for a
/// real (non-`Abort`) panic, which becomes the execution's failure.
fn finish_thread(me: usize, panic_msg: Option<String>) {
    let mut st = lock_state();
    if !st.active {
        return;
    }
    if let Some(msg) = panic_msg {
        if !st.aborted {
            fail(&mut st, FailureKind::Panic, format!("model thread t{me} panicked: {msg}"));
        }
    }
    st.threads[me].state = ThreadState::Finished;
    st.live -= 1;
    for t in st.threads.iter_mut() {
        if t.state == ThreadState::Blocked(BlockOn::Join(me)) {
            t.state = ThreadState::Runnable;
        }
    }
    if st.live > 0 && !st.aborted {
        pick_next(&mut st, me);
    }
    global().cv.notify_all();
}

// ---------------------------------------------------------------------------
// model thread spawning
// ---------------------------------------------------------------------------

type ResultSlot<T> = std::sync::Arc<StdMutex<Option<thread::Result<T>>>>;

/// Handle to a thread spawned inside a model execution.
pub(crate) struct ModelHandle<T> {
    tid: usize,
    result: ResultSlot<T>,
}

impl<T> ModelHandle<T> {
    pub(crate) fn join(self) -> thread::Result<T> {
        join_wait(self.tid);
        let out = self.result.lock().unwrap_or_else(|e| e.into_inner()).take();
        out.expect("model thread finished without storing a result")
    }

    pub(crate) fn is_finished(&self) -> bool {
        is_finished(self.tid)
    }
}

/// Body shared by the model main thread and model-spawned threads:
/// adopt the id, wait for the first grant, run, record, finish.
fn model_thread_body<T, F>(tid: usize, f: F, result: &ResultSlot<T>)
where
    F: FnOnce() -> T,
{
    TL_ID.with(|c| c.set(Some(tid)));
    let out = panic::catch_unwind(AssertUnwindSafe(|| {
        wait_granted(lock_state(), tid);
        f()
    }));
    match out {
        Ok(v) => {
            *result.lock().unwrap_or_else(|e| e.into_inner()) = Some(Ok(v));
            finish_thread(tid, None);
        }
        Err(p) => {
            if p.downcast_ref::<Abort>().is_some() {
                finish_thread(tid, None);
            } else {
                let msg = payload_message(p.as_ref());
                *result.lock().unwrap_or_else(|e| e.into_inner()) = Some(Err(p));
                finish_thread(tid, Some(msg));
            }
        }
    }
}

/// Spawn a thread inside the current model execution. Registers it as
/// runnable and passes through a yield point so the scheduler can run
/// the child before the parent's next step.
pub(crate) fn spawn_model<T, F>(name: &str, f: F) -> ModelHandle<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    assert!(on_model_thread(), "spawn_model called off a model thread");
    let result: ResultSlot<T> = std::sync::Arc::new(StdMutex::new(None));
    let tid;
    {
        let mut st = lock_state();
        assert!(st.active, "spawn_model outside an execution");
        tid = st.threads.len();
        st.threads.push(ModelThread { name: name.to_string(), state: ThreadState::Runnable });
        st.live += 1;
        let slot = result.clone();
        let os = thread::Builder::new()
            .name(format!("model-{name}"))
            .spawn(move || model_thread_body(tid, f, &slot))
            .expect("spawn model OS thread");
        st.os_handles.push(os);
    }
    op_yield("spawn");
    ModelHandle { tid, result }
}

// ---------------------------------------------------------------------------
// controller
// ---------------------------------------------------------------------------

/// Suppress panic output from model threads: their panics are captured
/// as `Failure`s (and `Abort` unwinds are pure control flow). Installed
/// once; delegates to the previous hook for ordinary threads.
fn install_quiet_panic_hook() {
    static HOOK: OnceLock<()> = OnceLock::new();
    HOOK.get_or_init(|| {
        let prev = panic::take_hook();
        panic::set_hook(Box::new(move |info| {
            if !on_model_thread() {
                prev(info);
            }
        }));
    });
}

/// Run the closure once under `schedule` (defaulting to choice 0 — or
/// random, if `rng` — past its end). Returns the recorded choices and
/// any failure.
fn run_one(
    f: &std::sync::Arc<dyn Fn() + Send + Sync>,
    schedule: &[usize],
    budget: usize,
    rng: Option<u64>,
) -> (Vec<Choice>, Option<Failure>) {
    let g = global();
    {
        let mut st = lock_state();
        *st = SchedState::idle();
        st.active = true;
        st.schedule = schedule.to_vec();
        st.budget = budget;
        st.rng = rng;
        st.threads.push(ModelThread { name: "main".to_string(), state: ThreadState::Runnable });
        st.live = 1;
        st.current = 0;
        let f = f.clone();
        let result: ResultSlot<()> = std::sync::Arc::new(StdMutex::new(None));
        let os = thread::Builder::new()
            .name("model-main".to_string())
            .spawn(move || model_thread_body(0, move || f(), &result))
            .expect("spawn model main thread");
        st.os_handles.push(os);
    }
    g.cv.notify_all();
    let mut st = g.lock.lock().unwrap_or_else(|e| e.into_inner());
    while st.live > 0 {
        st = g.cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
    st.active = false;
    let choices = std::mem::take(&mut st.choices);
    let failure = st.failure.take();
    let handles = std::mem::take(&mut st.os_handles);
    drop(st);
    for h in handles {
        let _ = h.join();
    }
    (choices, failure)
}

/// Deepest-incrementable-choice backtracking: the next DFS schedule
/// after an execution that took `choices`, or `None` when the bounded
/// tree is exhausted.
fn next_schedule(choices: &[Choice]) -> Option<Vec<usize>> {
    for (i, c) in choices.iter().enumerate().rev() {
        if c.idx + 1 < c.n {
            let mut s: Vec<usize> = choices[..i].iter().map(|x| x.idx).collect();
            s.push(c.idx + 1);
            return Some(s);
        }
    }
    None
}

/// Explore `f` under the default [`Config`].
pub fn check<F>(f: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    check_with(Config::default(), f)
}

/// Explore every schedule of `f` within the bounded-preemption DFS
/// tree (up to `cfg.max_execs`), then `cfg.random_execs` seeded random
/// schedules if the tree was not exhausted. Stops at the first failure.
///
/// `f` runs many times and must be self-contained: build all state
/// inside the closure, spawn via `check::sync::spawn_named`, and keep
/// it deterministic apart from scheduling.
pub fn check_with<F>(cfg: Config, f: F) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    let _serial = run_lock().lock().unwrap_or_else(|e| e.into_inner());
    install_quiet_panic_hook();
    let f: std::sync::Arc<dyn Fn() + Send + Sync> = std::sync::Arc::new(f);
    let mut schedule: Vec<usize> = Vec::new();
    let mut execs = 0usize;
    let mut complete = false;
    while execs < cfg.max_execs {
        execs += 1;
        let (choices, failure) = run_one(&f, &schedule, cfg.preemptions, None);
        if failure.is_some() {
            return Report { execs, complete: false, failure };
        }
        match next_schedule(&choices) {
            Some(next) => schedule = next,
            None => {
                complete = true;
                break;
            }
        }
    }
    if !complete {
        let mut seed = cfg.seed | 1;
        for _ in 0..cfg.random_execs {
            execs += 1;
            let rng = lcg(&mut seed).wrapping_mul(2) | 1;
            let (_, failure) = run_one(&f, &[], cfg.preemptions, Some(rng));
            if failure.is_some() {
                return Report { execs, complete: false, failure };
            }
        }
    }
    Report { execs, complete, failure: None }
}

/// Re-run `f` once under a recorded failing schedule (from
/// `Failure::schedule`). Choices past the end of the schedule default
/// to index 0, mirroring the DFS. Returns that single execution's
/// outcome.
pub fn replay<F>(f: F, schedule: &[usize]) -> Report
where
    F: Fn() + Send + Sync + 'static,
{
    let _serial = run_lock().lock().unwrap_or_else(|e| e.into_inner());
    install_quiet_panic_hook();
    let f: std::sync::Arc<dyn Fn() + Send + Sync> = std::sync::Arc::new(f);
    // Replays use an effectively unlimited preemption budget: the
    // recorded schedule already encodes every switch it needs, and a
    // tighter budget could only truncate its candidate lists.
    let (_, failure) = run_one(&f, schedule, usize::MAX, None);
    Report { execs: 1, complete: false, failure }
}
