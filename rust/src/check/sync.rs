//! Drop-in `std::sync` facade for the engine's concurrency-bearing
//! modules.
//!
//! Normal builds: pure re-exports of the `std::sync` types plus a
//! `spawn_named` helper — identical codegen, identical behavior, pinned
//! by the tier-1 suite. `--features model-check` builds: wrappers with
//! the same names and signatures that route every operation through
//! `check::sched` *when called from a model thread* and fall back to
//! plain `std` blocking behavior everywhere else, so ordinary tests
//! keep working in a model-check build.
//!
//! Facade rules (enforced by `cargo xtask lint`):
//! - `exec`, `serve`, and `infer::graph` import `Mutex`/`Condvar`/
//!   `RwLock` (and the atomics below) from here, never `std::sync`.
//! - Threads are spawned via `spawn_named`, never `std::thread`
//!   directly, so model runs capture them.
//! - `Arc`, `OnceLock`, `mpsc`, and `atomic::Ordering` are not wrapped;
//!   keep importing them from `std::sync`.
//!
//! Model-mode semantics (see `check::sched` for the scheduler):
//! - `lock`/`read`/`write` spin on `try_*` with a scheduler yield
//!   before each attempt and scheduler-blocked bookkeeping on
//!   contention; poisoning is absorbed (a poisoned model run has
//!   already recorded the panic that caused it).
//! - `Condvar::wait` enqueues FIFO, releases the mutex, parks on the
//!   scheduler, and re-acquires on wake. There are no spurious wakeups
//!   in the model, so a protocol relying on them is caught, not masked.
//! - Atomics are sequentially consistent at yield-point granularity:
//!   each access is a scheduling point and the requested `Ordering` is
//!   accepted but executed as `SeqCst`. The model checker therefore
//!   explores interleavings of atomic accesses, not weak-memory
//!   reorderings — `Ordering` correctness is covered by the per-site
//!   justification comments (see CONCURRENCY.md), not the checker.

#[cfg(not(feature = "model-check"))]
mod imp {
    pub use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize};
    pub use std::sync::{
        Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard,
    };
    pub use std::thread::JoinHandle;

    /// Spawn a named thread (std build: a thin `std::thread::Builder`
    /// wrapper). Panics if the OS refuses to spawn, like the previous
    /// in-tree call sites did.
    pub fn spawn_named<T, F>(name: &str, f: F) -> JoinHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .expect("spawn thread")
    }
}

#[cfg(feature = "model-check")]
mod imp {
    use crate::check::sched;
    use std::ops::{Deref, DerefMut};
    use std::sync::atomic::Ordering;
    use std::sync::{LockResult, TryLockError};

    // -- Mutex --------------------------------------------------------

    pub struct Mutex<T: ?Sized> {
        id: usize,
        inner: std::sync::Mutex<T>,
    }

    pub struct MutexGuard<'a, T: ?Sized> {
        lock: &'a Mutex<T>,
        inner: Option<std::sync::MutexGuard<'a, T>>,
        /// True iff acquired through the model scheduler (so drop must
        /// release the scheduler's blocked-set entry).
        model: bool,
    }

    impl<T> Mutex<T> {
        pub fn new(t: T) -> Self {
            Mutex { id: sched::new_resource_id(), inner: std::sync::Mutex::new(t) }
        }
    }

    impl<T: ?Sized> Mutex<T> {
        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            if !sched::on_model_thread() {
                let g = self.inner.lock().unwrap_or_else(|e| e.into_inner());
                return Ok(MutexGuard { lock: self, inner: Some(g), model: false });
            }
            loop {
                sched::op_yield("mutex-lock");
                match self.inner.try_lock() {
                    Ok(g) => {
                        return Ok(MutexGuard { lock: self, inner: Some(g), model: true });
                    }
                    Err(TryLockError::Poisoned(e)) => {
                        return Ok(MutexGuard {
                            lock: self,
                            inner: Some(e.into_inner()),
                            model: true,
                        });
                    }
                    Err(TryLockError::WouldBlock) => {
                        sched::block_resource(self.id, "mutex-blocked");
                    }
                }
            }
        }
    }

    impl<T: ?Sized> Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("mutex guard")
        }
    }

    impl<T: ?Sized> DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("mutex guard")
        }
    }

    impl<T: ?Sized> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            let model = self.model;
            // Release the real lock before telling the scheduler, so a
            // woken thread's try_lock can succeed at its next grant.
            drop(self.inner.take());
            if model {
                sched::release(self.lock.id);
            }
        }
    }

    // -- Condvar ------------------------------------------------------

    pub struct Condvar {
        id: usize,
        std: std::sync::Condvar,
    }

    impl Condvar {
        #[allow(clippy::new_without_default)]
        pub fn new() -> Self {
            Condvar { id: sched::new_resource_id(), std: std::sync::Condvar::new() }
        }

        pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            if !guard.model {
                let inner = guard.inner.take().expect("mutex guard");
                let inner = self.std.wait(inner).unwrap_or_else(|e| e.into_inner());
                guard.inner = Some(inner);
                return Ok(guard);
            }
            let lock = guard.lock;
            // Enqueue as a waiter *before* releasing the mutex; no
            // scheduling point runs in between, so wait is atomic with
            // the release exactly like std's contract.
            sched::cv_enqueue(self.id);
            drop(guard);
            sched::cv_block();
            lock.lock()
        }

        pub fn notify_one(&self) {
            if !sched::on_model_thread() {
                self.std.notify_one();
                return;
            }
            // Yield first so schedules where the notify is delayed
            // relative to other threads are explored too.
            sched::op_yield("notify-one");
            sched::cv_wake(self.id, false);
        }

        pub fn notify_all(&self) {
            if !sched::on_model_thread() {
                self.std.notify_all();
                return;
            }
            sched::op_yield("notify-all");
            sched::cv_wake(self.id, true);
        }
    }

    // -- RwLock -------------------------------------------------------

    pub struct RwLock<T: ?Sized> {
        id: usize,
        inner: std::sync::RwLock<T>,
    }

    pub struct RwLockReadGuard<'a, T: ?Sized> {
        lock: &'a RwLock<T>,
        inner: Option<std::sync::RwLockReadGuard<'a, T>>,
        model: bool,
    }

    pub struct RwLockWriteGuard<'a, T: ?Sized> {
        lock: &'a RwLock<T>,
        inner: Option<std::sync::RwLockWriteGuard<'a, T>>,
        model: bool,
    }

    impl<T> RwLock<T> {
        pub fn new(t: T) -> Self {
            RwLock { id: sched::new_resource_id(), inner: std::sync::RwLock::new(t) }
        }
    }

    impl<T: ?Sized> RwLock<T> {
        pub fn read(&self) -> LockResult<RwLockReadGuard<'_, T>> {
            if !sched::on_model_thread() {
                let g = self.inner.read().unwrap_or_else(|e| e.into_inner());
                return Ok(RwLockReadGuard { lock: self, inner: Some(g), model: false });
            }
            loop {
                sched::op_yield("rwlock-read");
                match self.inner.try_read() {
                    Ok(g) => {
                        return Ok(RwLockReadGuard { lock: self, inner: Some(g), model: true });
                    }
                    Err(TryLockError::Poisoned(e)) => {
                        return Ok(RwLockReadGuard {
                            lock: self,
                            inner: Some(e.into_inner()),
                            model: true,
                        });
                    }
                    Err(TryLockError::WouldBlock) => {
                        sched::block_resource(self.id, "rwlock-read-blocked");
                    }
                }
            }
        }

        pub fn write(&self) -> LockResult<RwLockWriteGuard<'_, T>> {
            if !sched::on_model_thread() {
                let g = self.inner.write().unwrap_or_else(|e| e.into_inner());
                return Ok(RwLockWriteGuard { lock: self, inner: Some(g), model: false });
            }
            loop {
                sched::op_yield("rwlock-write");
                match self.inner.try_write() {
                    Ok(g) => {
                        return Ok(RwLockWriteGuard { lock: self, inner: Some(g), model: true });
                    }
                    Err(TryLockError::Poisoned(e)) => {
                        return Ok(RwLockWriteGuard {
                            lock: self,
                            inner: Some(e.into_inner()),
                            model: true,
                        });
                    }
                    Err(TryLockError::WouldBlock) => {
                        sched::block_resource(self.id, "rwlock-write-blocked");
                    }
                }
            }
        }
    }

    impl<T: ?Sized> Deref for RwLockReadGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("rwlock read guard")
        }
    }

    impl<T: ?Sized> Drop for RwLockReadGuard<'_, T> {
        fn drop(&mut self) {
            let model = self.model;
            drop(self.inner.take());
            if model {
                sched::release(self.lock.id);
            }
        }
    }

    impl<T: ?Sized> Deref for RwLockWriteGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("rwlock write guard")
        }
    }

    impl<T: ?Sized> DerefMut for RwLockWriteGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("rwlock write guard")
        }
    }

    impl<T: ?Sized> Drop for RwLockWriteGuard<'_, T> {
        fn drop(&mut self) {
            let model = self.model;
            drop(self.inner.take());
            if model {
                sched::release(self.lock.id);
            }
        }
    }

    // -- atomics ------------------------------------------------------
    //
    // Each access is a scheduling point; the requested ordering is
    // accepted for signature parity but executed as SeqCst (the model
    // explores interleavings, not weak-memory reorderings).

    macro_rules! model_atomic {
        ($name:ident, $std:ty, $prim:ty) => {
            #[derive(Debug, Default)]
            pub struct $name {
                inner: $std,
            }

            impl $name {
                pub const fn new(v: $prim) -> Self {
                    Self { inner: <$std>::new(v) }
                }

                pub fn load(&self, _order: Ordering) -> $prim {
                    sched::op_yield("atomic-load");
                    self.inner.load(Ordering::SeqCst)
                }

                pub fn store(&self, v: $prim, _order: Ordering) {
                    sched::op_yield("atomic-store");
                    self.inner.store(v, Ordering::SeqCst)
                }
            }
        };
    }

    model_atomic!(AtomicBool, std::sync::atomic::AtomicBool, bool);
    model_atomic!(AtomicU64, std::sync::atomic::AtomicU64, u64);
    model_atomic!(AtomicUsize, std::sync::atomic::AtomicUsize, usize);

    impl AtomicU64 {
        pub fn fetch_add(&self, v: u64, _order: Ordering) -> u64 {
            sched::op_yield("atomic-rmw");
            self.inner.fetch_add(v, Ordering::SeqCst)
        }

        pub fn fetch_sub(&self, v: u64, _order: Ordering) -> u64 {
            sched::op_yield("atomic-rmw");
            self.inner.fetch_sub(v, Ordering::SeqCst)
        }
    }

    impl AtomicUsize {
        pub fn fetch_add(&self, v: usize, _order: Ordering) -> usize {
            sched::op_yield("atomic-rmw");
            self.inner.fetch_add(v, Ordering::SeqCst)
        }

        pub fn fetch_sub(&self, v: usize, _order: Ordering) -> usize {
            sched::op_yield("atomic-rmw");
            self.inner.fetch_sub(v, Ordering::SeqCst)
        }
    }

    impl AtomicBool {
        pub fn swap(&self, v: bool, _order: Ordering) -> bool {
            sched::op_yield("atomic-rmw");
            self.inner.swap(v, Ordering::SeqCst)
        }
    }

    // -- threads ------------------------------------------------------

    enum HandleInner<T> {
        Std(std::thread::JoinHandle<T>),
        Model(sched::ModelHandle<T>),
    }

    /// Join handle matching the subset of `std::thread::JoinHandle`
    /// the engine uses (`join`, `is_finished`).
    pub struct JoinHandle<T>(HandleInner<T>);

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            match self.0 {
                HandleInner::Std(h) => h.join(),
                HandleInner::Model(h) => h.join(),
            }
        }

        pub fn is_finished(&self) -> bool {
            match &self.0 {
                HandleInner::Std(h) => h.is_finished(),
                HandleInner::Model(h) => h.is_finished(),
            }
        }
    }

    /// Spawn a named thread: a model thread when called from inside a
    /// model execution, a real OS thread otherwise.
    pub fn spawn_named<T, F>(name: &str, f: F) -> JoinHandle<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        if sched::on_model_thread() {
            JoinHandle(HandleInner::Model(sched::spawn_model(name, f)))
        } else {
            JoinHandle(HandleInner::Std(
                std::thread::Builder::new()
                    .name(name.to_string())
                    .spawn(f)
                    .expect("spawn thread"),
            ))
        }
    }
}

pub use imp::*;
