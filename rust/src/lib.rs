//! # fqconv — FQ-Conv: Fully Quantized Convolution, reproduced
//!
//! Rust Layer-3 coordinator for the FQ-Conv system (Verhoef et al., 2019):
//! a quantization-aware-training orchestrator (gradual quantization +
//! distillation + checkpointing) driving AOT-compiled JAX/XLA train steps
//! through PJRT, plus a from-scratch integer inference engine, an analog
//! crossbar-array simulator, synthetic data substrates (keyword-spotting
//! audio with a full MFCC front end, CIFAR-like images), and a serving
//! layer (request router + dynamic batcher).
//!
//! Python/JAX runs only at build time (`make artifacts`); everything in
//! this crate is runtime-self-contained given `artifacts/`.
//!
//! Module map (see DESIGN.md for the full system inventory):
//!
//! * [`util`]        — PRNGs, JSON, timers, property testing
//! * [`check`]       — `std::sync` facade + in-tree concurrency model
//!                     checker (deterministic-schedule exploration under
//!                     `--features model-check`; see CONCURRENCY.md)
//! * [`exec`]        — scoped-thread data-parallel substrate (deterministic
//!                     fork-join used by the engine and the serving layer)
//! * [`tensor`]      — minimal strided ndarray (f32 / i32 / i8)
//! * [`quant`]       — the paper's quantizer (Eqs. 1-2) + integer LUT re-binning
//! * [`config`]      — TOML-subset experiment configuration
//! * [`runtime`]     — PJRT client wrapper: load + execute `artifacts/*.hlo.txt`
//! * [`data`]        — synthetic KWS audio + DSP front end, image generators
//! * [`models`]      — architecture descriptors, accounting, Fig. 2/4 printers
//! * [`coordinator`] — gradual-quantization scheduler, trainer, checkpoints,
//!                     BN-folding FQ transform (§3.4)
//! * [`infer`]       — integer FQ-Conv engine (i8 GEMM, ternary fast path)
//! * [`analog`]      — crossbar simulator with w/a/MAC noise (Table 7)
//! * [`serve`]       — router + dynamic batcher over the deployment artifact
//! * [`stream`]      — streaming stateful inference: per-session ring-buffer
//!                     conv state + overlap-save MFCC front end, bit-identical
//!                     to the offline whole-window forward
//! * [`obs`]         — observability: sharded metrics registry, request
//!                     tracing rings, shared integer latency histogram,
//!                     Prometheus/JSON exposition
//! * [`metrics`]     — accuracy, confusion, latency histograms
//! * [`bench`]       — micro-benchmark harness used by `cargo bench` targets

// Unsafe code policy: every `unsafe` operation inside an `unsafe fn`
// must still be wrapped in an explicit `unsafe {}` block with its own
// `// SAFETY:` comment (enforced by clippy::undocumented_unsafe_blocks
// in CI and by `cargo xtask lint`).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod analog;
pub mod bench;
pub mod check;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod exp;
pub mod infer;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod stream;
pub mod tensor;
pub mod util;

/// Repository-relative default artifact directory.
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Resolve the artifacts directory: `$FQCONV_ARTIFACTS` or ./artifacts,
/// walking up from the current directory (tests run from target dirs).
pub fn artifacts_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("FQCONV_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return ARTIFACTS_DIR.into();
        }
    }
}
