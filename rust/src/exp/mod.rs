//! Experiment drivers: one function per paper table/figure.
//!
//! Shared by the `cargo bench` table regenerators (quick budget) and the
//! `fqconv exp <table>` CLI (full budget). Each driver prints the paper's
//! rows and returns a machine-readable record that callers may persist.
//! DESIGN.md §6 maps every driver to its paper artifact.

use anyhow::{Context, Result};

use crate::analog::{CrossbarSim, NoiseConfig};
use crate::config::Budget;
use crate::coordinator::{checkpoint, fq_transform, ParamSet, Pipeline, Schedule, Stage, TeacherPolicy, Trainer, Variant};
use crate::data::{self, Dataset};
use crate::models;
use crate::runtime::{hp, Engine, Manifest};
use crate::util::json::{self, Json};

pub struct Ctx<'a> {
    pub engine: &'a Engine,
    pub manifest: &'a Manifest,
    pub budget: Budget,
    pub verbose: bool,
    pub seed: u64,
}

impl<'a> Ctx<'a> {
    pub fn new(engine: &'a Engine, manifest: &'a Manifest, budget: Budget) -> Self {
        Ctx { engine, manifest, budget, verbose: false, seed: 17 }
    }

    fn dataset_for(&self, model: &str) -> Result<Box<dyn Dataset>> {
        let info = self.manifest.model(model)?;
        Ok(data::for_model(&info.kind, &info.input_shape, info.num_classes))
    }

    fn pipeline<'b>(&'b self, ds: &'b dyn Dataset) -> Pipeline<'b> {
        let mut p = Pipeline::new(self.engine, self.manifest, ds);
        p.eval_batches = self.budget.eval_batches;
        p.seed = self.seed;
        p.verbose = self.verbose;
        p
    }
}

/// Append a result record to artifacts/results/<name>.jsonl.
pub fn persist(manifest: &Manifest, name: &str, record: &Json) {
    let dir = manifest.dir.join("results");
    std::fs::create_dir_all(&dir).ok();
    let path = dir.join(format!("{name}.jsonl"));
    let mut line = record.to_string();
    line.push('\n');
    use std::io::Write;
    if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        let _ = f.write_all(line.as_bytes());
    }
}

// ---------------------------------------------------------------------------
// Table 1 — GQ ladder + no-GQ ablation (ResNet / CIFAR-10-like)
// ---------------------------------------------------------------------------

pub struct Table1Row {
    pub stage: String,
    pub wbits: u32,
    pub abits: u32,
    pub acc_gq: f64,
    pub acc_no_gq: Option<f64>,
}

pub fn table1(ctx: &Ctx, model: &str) -> Result<Vec<Table1Row>> {
    let ds = ctx.dataset_for(model)?;
    let pipe = ctx.pipeline(ds.as_ref());
    let steps = ctx.budget.steps_per_stage;
    // ternary stages benefit from a longer, gentler schedule (paper trains
    // 200 epochs; we scale steps at the low end of the ladder)
    let sched = {
        let mut s = Schedule::table1(model, steps, 0.02);
        for st in s.stages.iter_mut() {
            if st.wbits != 0 && st.wbits <= 3 {
                st.steps = steps * 2;
                st.lr = 0.01;
            }
        }
        s
    };
    let report = pipe.run(&sched)?;

    // no-GQ ablation: FP0 -> Qkk directly, for the low-precision stages
    let mut rows = Vec::new();
    for st in &sched.stages {
        let no_gq = if st.wbits != 0 && st.wbits <= 4 {
            let s2 = Schedule::table1_no_gq(model, st.wbits, st.abits, st.steps, st.lr);
            let r2 = pipe.run(&s2)?;
            r2.stages.last().map(|s| s.val_acc)
        } else {
            None
        };
        rows.push(Table1Row {
            stage: st.name.clone(),
            wbits: st.wbits,
            abits: st.abits,
            acc_gq: report.stage(&st.name).map(|s| s.val_acc).unwrap_or(0.0),
            acc_no_gq: no_gq,
        });
    }

    println!("\nTable 1 — Gradual Quantization of {model} (synthetic CIFAR-10-like)");
    println!(
        "{:<7} {:>7} {:>7} {:>10} {:>14} {:>8}",
        "Network", "#bits/w", "#bits/a", "acc (GQ)", "acc (no GQ)", "diff"
    );
    for r in &rows {
        let b = |v: u32| if v == 0 { "fp".into() } else { v.to_string() };
        let (no, diff) = match r.acc_no_gq {
            Some(a) => (format!("{:.2}%", a * 100.0), format!("{:+.2}", (r.acc_gq - a) * 100.0)),
            None => ("-".into(), "-".into()),
        };
        println!(
            "{:<7} {:>7} {:>7} {:>9.2}% {:>14} {:>8}",
            r.stage,
            b(r.wbits),
            b(r.abits),
            r.acc_gq * 100.0,
            no,
            diff
        );
        persist(
            ctx.manifest,
            "table1",
            &json::obj(vec![
                ("model", json::s(model)),
                ("stage", json::s(&r.stage)),
                ("acc_gq", json::num(r.acc_gq)),
                ("acc_no_gq", r.acc_no_gq.map(json::num).unwrap_or(Json::Null)),
            ]),
        );
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Table 2 — quantizer comparison at W2/A2 and W3/A3
// ---------------------------------------------------------------------------

pub struct Table2Row {
    pub name: String,
    pub baseline: f64,
    pub quantized: f64,
}

pub fn table2(ctx: &Ctx, model: &str) -> Result<Vec<Table2Row>> {
    let ds = ctx.dataset_for(model)?;
    let steps = ctx.budget.steps_per_stage;
    let mut rows = Vec::new();
    for (flavor, label) in [("", "GQ (ours)"), ("dorefa", "DoReFa"), ("pact", "PACT")] {
        for (w, a) in [(2u32, 2u32), (3, 3)] {
            let mut pipe = ctx.pipeline(ds.as_ref());
            pipe.flavor = match flavor {
                "" => "",
                "dorefa" => "dorefa",
                _ => "pact",
            };
            // ours rides the full GQ ladder; baselines do FP -> Q directly
            // with the same total budget (their papers train direct)
            let (sched, stage_name) = if flavor.is_empty() {
                let mut s = Schedule::table1(model, steps, 0.02);
                for st in s.stages.iter_mut() {
                    if st.wbits != 0 && st.wbits <= 3 {
                        st.steps = steps * 2;
                        st.lr = 0.01;
                    }
                }
                // truncate ladder at the target bitwidth
                let keep: Vec<Stage> = s
                    .stages
                    .iter()
                    .take_while(|st| st.wbits == 0 || st.wbits >= w)
                    .cloned()
                    .collect();
                let name = keep.last().unwrap().name.clone();
                (Schedule::new(model, keep, TeacherPolicy::Declared)?, name)
            } else {
                let name = format!("Q{w}{a}");
                let mut s = Schedule::table1_no_gq(model, w, a, steps * 2, 0.01);
                s.stages[0].steps = steps; // FP baseline stage
                (s.clone(), name)
            };
            let report = pipe.run(&sched)?;
            let baseline = report.stages.first().map(|s| s.val_acc).unwrap_or(0.0);
            let quantized = report.stage(&stage_name).map(|s| s.val_acc).unwrap_or(0.0);
            rows.push(Table2Row { name: format!("{label} (W{w}/A{a})"), baseline, quantized });
        }
    }
    println!("\nTable 2 — quantizer comparison on {model} (identical harness)");
    println!("{:<20} {:>10} {:>11} {:>7}", "Name", "Baseline", "Quantized", "Diff");
    for r in &rows {
        println!(
            "{:<20} {:>9.2}% {:>10.2}% {:>6.2}",
            r.name,
            r.baseline * 100.0,
            r.quantized * 100.0,
            (r.baseline - r.quantized) * 100.0
        );
        persist(
            ctx.manifest,
            "table2",
            &json::obj(vec![
                ("name", json::s(&r.name)),
                ("baseline", json::num(r.baseline)),
                ("quantized", json::num(r.quantized)),
            ]),
        );
    }
    println!("(LQ-Net rows are quoted from the paper; see DESIGN.md §4)");
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Table 3 — DarkNet ladder (ImageNet-64-like)
// ---------------------------------------------------------------------------

pub fn table3(ctx: &Ctx) -> Result<Vec<(String, f64, f64)>> {
    let ds = ctx.dataset_for("darknet_tiny")?;
    let pipe = ctx.pipeline(ds.as_ref());
    let sched = Schedule::table3_darknet(ctx.budget.steps_per_stage, 0.02);
    let report = pipe.run(&sched)?;
    println!("\nTable 3 — Quantized DarkNet-tiny (synthetic ImageNet-64-like)");
    println!("{:<7} {:>9} {:>9} {:>10} {:>10}", "Network", "#bits/w", "#bits/a", "Top-1", "Top-5");
    let mut rows = Vec::new();
    for s in &report.stages {
        println!(
            "{:<7} {:>9} {:>9} {:>9.2}% {:>9.2}%",
            s.name,
            if s.wbits == 0 { "fp".into() } else { s.wbits.to_string() },
            if s.abits == 0 { "fp".into() } else { s.abits.to_string() },
            s.val_acc * 100.0,
            s.val_topk * 100.0
        );
        rows.push((s.name.clone(), s.val_acc, s.val_topk));
        persist(
            ctx.manifest,
            "table3",
            &json::obj(vec![
                ("stage", json::s(&s.name)),
                ("top1", json::num(s.val_acc)),
                ("top5", json::num(s.val_topk)),
            ]),
        );
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Table 4 — KWS gradual-quantization sequence (incl. FQ24)
// ---------------------------------------------------------------------------

pub fn table4(ctx: &Ctx) -> Result<crate::coordinator::PipelineReport> {
    let ds = ctx.dataset_for("kws")?;
    let mut pipe = ctx.pipeline(ds.as_ref());
    pipe.ckpt_dir = Some(ctx.manifest.dir.join("ckpts"));
    let steps = ctx.budget.steps_per_stage;
    let mut sched = Schedule::table4_kws(steps, 0.01);
    for st in sched.stages.iter_mut() {
        if st.wbits == 2 {
            st.steps = steps * 2; // ternary stages get a longer budget
        }
    }
    let report = pipe.run(&sched)?;
    println!("\nTable 4 — Quantized KWS training sequence (synthetic speech commands)");
    println!("{}", report.render_table());
    for s in &report.stages {
        persist(
            ctx.manifest,
            "table4",
            &json::obj(vec![
                ("stage", json::s(&s.name)),
                ("acc", json::num(s.val_acc)),
                ("fq", Json::Bool(s.fq)),
            ]),
        );
    }
    Ok(report)
}

// ---------------------------------------------------------------------------
// Table 5 — model comparison (params / size / mults / accuracy)
// ---------------------------------------------------------------------------

pub fn table5(ctx: &Ctx, acc_q35: f64, acc_fq24: f64) -> Result<String> {
    let info = ctx.manifest.model("kws")?;
    let mut rows = models::table5_literature_rows();
    rows.extend(models::table5_our_rows(info, acc_q35, acc_fq24));
    let table = models::render_table5(&rows);
    println!("\nTable 5 — KWS model comparison (literature rows quoted from the paper)");
    println!("{table}");
    Ok(table)
}

// ---------------------------------------------------------------------------
// Table 6 — ResNet / CIFAR-100-like ladder incl. FQ fine-tune
// ---------------------------------------------------------------------------

pub fn table6(ctx: &Ctx, model: &str) -> Result<crate::coordinator::PipelineReport> {
    let ds = ctx.dataset_for(model)?;
    let mut pipe = ctx.pipeline(ds.as_ref());
    pipe.ckpt_dir = Some(ctx.manifest.dir.join("ckpts"));
    let steps = ctx.budget.steps_per_stage;
    let mut sched = Schedule::table6(model, steps, 0.002);
    for st in sched.stages.iter_mut() {
        if st.wbits != 0 && st.wbits <= 3 {
            st.steps = steps * 2;
        }
    }
    let report = pipe.run(&sched)?;
    println!("\nTable 6 — Gradual Quantization of {model} (synthetic CIFAR-100-like)");
    println!("{}", report.render_table());
    for s in &report.stages {
        persist(
            ctx.manifest,
            "table6",
            &json::obj(vec![
                ("model", json::s(model)),
                ("stage", json::s(&s.name)),
                ("top1", json::num(s.val_acc)),
                ("top5", json::num(s.val_topk)),
            ]),
        );
    }
    Ok(report)
}

// ---------------------------------------------------------------------------
// Table 7 — noise resilience (analog crossbar sim + noise-aware training)
// ---------------------------------------------------------------------------

pub struct Table7Row {
    pub noise: NoiseConfig,
    pub acc_clean_trained: f64,
    pub acc_noise_trained: f64,
}

/// Runs the KWS column of Table 7. Requires table4 checkpoints on disk
/// (run [`table4`] first, or pass `train_first = true`).
pub fn table7_kws(ctx: &Ctx, train_first: bool) -> Result<Vec<Table7Row>> {
    let ds = ctx.dataset_for("kws")?;
    let ckpt_dir = ctx.manifest.dir.join("ckpts");
    let fq_ckpt = ckpt_dir.join("kws_FQ24.ckpt");
    if train_first || !fq_ckpt.exists() {
        table4(ctx)?;
    }
    let info = ctx.manifest.model("kws")?;
    let fq_graph = info.fq.clone().context("kws fq graph")?;
    let ck = checkpoint::read(&fq_ckpt)?;
    let params = ParamSet::from_checkpoint(&fq_graph, &ck)?;
    let frames = info.input_shape[1];
    let (nw, na) = (1.0, 7.0); // FQ24: ternary weights, 4-bit acts

    // --- clean-trained network under noise -------------------------------
    let mut xbar = CrossbarSim::from_kws_params(&params, nw, na, frames)?;
    // --- noise-aware fine-tune (σ injected via hp during fq_train) -------
    let mut trainer = Trainer::new(ctx.engine, ctx.manifest, "kws", Variant::Fq)?;
    trainer.set_params(params.clone());
    let mut rng = crate::util::Rng::new(ctx.seed ^ 0x70);
    let mut hpv = hp::defaults();
    hpv[hp::LR] = 2e-4;
    hpv[hp::NW] = nw;
    hpv[hp::NA] = na;
    hpv[hp::SIGMA_W] = 20.0;
    hpv[hp::SIGMA_A] = 20.0;
    hpv[hp::SIGMA_MAC] = 100.0;
    let nt_steps = ctx.budget.steps_per_stage;
    for step in 0..nt_steps {
        let batch = ds.train_batch(trainer.info.batch, &mut rng);
        hpv[hp::SEED] = (step as u32).wrapping_mul(2654435761) as f32;
        trainer.step(&batch, None, &hpv)?;
    }
    let mut xbar_nt = CrossbarSim::from_kws_params(&trainer.params, nw, na, frames)?;

    let mut rows = Vec::new();
    println!("\nTable 7 (KWS column) — ternary network under analog noise");
    println!(
        "{:<28} {:>18} {:>18}",
        "Noise (% LSB)", "not noise-trained", "noise-trained"
    );
    // baseline (no noise) first
    let base_clean = xbar.evaluate_noisy(
        ds.as_ref(),
        ctx.budget.noise_samples,
        NoiseConfig::default(),
        1,
        ctx.seed,
    );
    println!("{:<28} {:>17.2}% {:>18}", "baseline (no noise)", base_clean * 100.0, "-");
    for noise in NoiseConfig::table7_points() {
        let a = xbar.evaluate_noisy(
            ds.as_ref(),
            ctx.budget.noise_samples,
            noise,
            ctx.budget.noise_reps,
            ctx.seed,
        );
        let b = xbar_nt.evaluate_noisy(
            ds.as_ref(),
            ctx.budget.noise_samples,
            noise,
            ctx.budget.noise_reps,
            ctx.seed,
        );
        println!("{:<28} {:>17.2}% {:>17.2}%", noise.label(), a * 100.0, b * 100.0);
        persist(
            ctx.manifest,
            "table7",
            &json::obj(vec![
                ("dataset", json::s("kws")),
                ("sigma_w", json::num(noise.sigma_w as f64)),
                ("not_trained", json::num(a)),
                ("trained", json::num(b)),
            ]),
        );
        rows.push(Table7Row { noise, acc_clean_trained: a, acc_noise_trained: b });
    }
    Ok(rows)
}

/// CIFAR column of Table 7: the FQ ResNet evaluated through its noisy
/// fq_fwd artifact (σ enters via hp; per-rep seeds vary the noise draw).
pub fn table7_cifar(ctx: &Ctx, model: &str, train_first: bool) -> Result<Vec<Table7Row>> {
    let ckpt_dir = ctx.manifest.dir.join("ckpts");
    let fq_ckpt = ckpt_dir.join(format!("{model}_FQ25.ckpt"));
    if train_first || !fq_ckpt.exists() {
        table6(ctx, model)?;
    }
    let info = ctx.manifest.model(model)?;
    let fq_graph = info.fq.clone().context("fq graph")?;
    let ck = checkpoint::read(&fq_ckpt)?;
    let params = ParamSet::from_checkpoint(&fq_graph, &ck)?;
    let ds = ctx.dataset_for(model)?;
    let (nw, na) = (1.0, 15.0); // FQ25: ternary weights, 5-bit acts

    let eval_noisy = |trainer: &Trainer, noise: &NoiseConfig| -> Result<f64> {
        let mut acc = 0.0;
        for rep in 0..ctx.budget.noise_reps {
            let mut hpv = hp::defaults();
            hpv[hp::NW] = nw;
            hpv[hp::NA] = na;
            hpv[hp::SIGMA_W] = noise.sigma_w;
            hpv[hp::SIGMA_A] = noise.sigma_a;
            hpv[hp::SIGMA_MAC] = noise.sigma_mac;
            hpv[hp::SEED] = (ctx.seed as u32 ^ (rep as u32 * 7919)) as f32;
            acc += trainer.evaluate(ds.as_ref(), &hpv, ctx.budget.eval_batches)?;
        }
        Ok(acc / ctx.budget.noise_reps as f64)
    };

    let mut clean = Trainer::new(ctx.engine, ctx.manifest, model, Variant::Fq)?;
    clean.set_params(params.clone());
    // noise-aware fine-tune
    let mut noisy = Trainer::new(ctx.engine, ctx.manifest, model, Variant::Fq)?;
    noisy.set_params(params);
    let mut rng = crate::util::Rng::new(ctx.seed ^ 0x71);
    let mut hpv = hp::defaults();
    hpv[hp::LR] = 2e-4;
    hpv[hp::NW] = nw;
    hpv[hp::NA] = na;
    hpv[hp::SIGMA_W] = 20.0;
    hpv[hp::SIGMA_A] = 20.0;
    hpv[hp::SIGMA_MAC] = 100.0;
    for step in 0..ctx.budget.steps_per_stage {
        let batch = ds.train_batch(noisy.info.batch, &mut rng);
        hpv[hp::SEED] = (step as u32).wrapping_mul(2654435761) as f32;
        noisy.step(&batch, None, &hpv)?;
    }

    let mut rows = Vec::new();
    println!("\nTable 7 (CIFAR-100-like column) — {model} FQ25 under noise");
    println!(
        "{:<28} {:>18} {:>18}",
        "Noise (% LSB)", "not noise-trained", "noise-trained"
    );
    for noise in NoiseConfig::table7_points() {
        let a = eval_noisy(&clean, &noise)?;
        let b = eval_noisy(&noisy, &noise)?;
        println!("{:<28} {:>17.2}% {:>17.2}%", noise.label(), a * 100.0, b * 100.0);
        persist(
            ctx.manifest,
            "table7",
            &json::obj(vec![
                ("dataset", json::s(model)),
                ("sigma_w", json::num(noise.sigma_w as f64)),
                ("not_trained", json::num(a)),
                ("trained", json::num(b)),
            ]),
        );
        rows.push(Table7Row { noise, acc_clean_trained: a, acc_noise_trained: b });
    }
    Ok(rows)
}

// ---------------------------------------------------------------------------
// Figures
// ---------------------------------------------------------------------------

/// Fig. 1: render the GQ procedure for a model.
pub fn fig1_plan(model: &str, steps: usize) -> String {
    let sched = match model {
        "kws" => Schedule::table4_kws(steps, 0.01),
        "darknet_tiny" => Schedule::table3_darknet(steps, 0.02),
        m if m.starts_with("resnet32") || m.starts_with("resnet14") => {
            Schedule::table6(m, steps, 0.002)
        }
        m => Schedule::table1(m, steps, 0.02),
    };
    sched.render()
}

/// Fig. 3 companion: numeric check that BN folding is exact when the
/// shift term vanishes (see rust/tests/fq_transform.rs for the full test).
pub fn fig3_note() -> &'static str {
    "Fig. 3: BN+ReLU -> quantized ReLU. The QAT->FQ transform folds\n\
     inference-mode BN scale into the conv weights per channel and wires\n\
     the quantizer grids (coordinator::fq_transform); the dropped shift\n\
     is recovered by fine-tuning (§3.4). See `fqconv exp table4`."
}

/// Ensure fq_transform is linked into table7 path (silence unused warns).
#[allow(unused)]
fn _touch(_: fn(&crate::runtime::ModelInfo, &crate::runtime::GraphSpec, &ParamSet) -> Result<ParamSet>) {}
#[allow(unused)]
const _: fn(&crate::runtime::ModelInfo, &crate::runtime::GraphSpec, &ParamSet) -> Result<ParamSet> =
    fq_transform::qat_to_fq;
