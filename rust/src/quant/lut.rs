//! Integer re-binning LUT: the paper's "hardware-supported quantization".
//!
//! Eq. (4) leaves the conv output as an integer accumulator `acc` with an
//! implicit scale f = (s^a s^w)/(n^a n^w). The next layer wants integer
//! codes on its own input grid. The float path computes
//!
//! ```text
//! code = round(clip(acc * f / s^o, b, 1) * n^o)
//! ```
//!
//! The paper observes this scale "is not needed for active computation as
//! long as the hardware-supported quantization ... puts the integer-valued
//! sum into the correct integer-valued quantized bin". We implement that
//! hardware bin mapper as a threshold table: since `code(acc)` is
//! monotone non-decreasing in `acc`, the mapping is fully described by at
//! most (range of codes) threshold integers. Thresholds are found by
//! binary search against the *f32 reference formula*, so the LUT agrees
//! with the XLA artifact bit-for-bit for every in-range accumulator —
//! including ties-to-even edge cases (verified by property test).

use super::QParams;

/// Threshold-table requantizer: integer accumulator -> integer output code.
#[derive(Clone, Debug)]
pub struct RequantLut {
    /// thresholds[k] = smallest acc whose code is codes_min + k + 1
    thresholds: Vec<i64>,
    code_min: i32,
    code_max: i32,
    pub acc_min: i64,
    pub acc_max: i64,
    /// the float path it reproduces (kept for tests / fallback)
    pub f: f32,
    pub out: QParams,
}

impl RequantLut {
    /// Reference (float-path) code for an accumulator value.
    #[inline]
    pub fn reference_code(acc: i64, f: f32, out: &QParams) -> i32 {
        out.int_code(acc as f32 * f)
    }

    /// Build for accumulators in [acc_min, acc_max].
    ///
    /// `f` is the Eq. (4) prefactor (s^a s^w)/(n^a n^w) and `out` the next
    /// layer's input quantizer. Requires f > 0 (scales are e^s > 0).
    pub fn build(f: f32, out: QParams, acc_min: i64, acc_max: i64) -> Self {
        Self::build_eval(|acc| Self::reference_code(acc, f, &out), f, out, acc_min, acc_max)
    }

    /// Reference code for the *composed* two-step re-binning the deployed
    /// kernel performs: acc -> Q_mid (this layer's output quantizer) ->
    /// integer code on the *next* layer's input grid. Double rounding is
    /// intentional — it is what the XLA artifact computes.
    #[inline]
    pub fn reference_code_composed(acc: i64, f: f32, mid: &QParams, next: &QParams) -> i32 {
        let y = mid.quantize(acc as f32 * f);
        next.int_code(y)
    }

    /// Build the composed LUT (see [`Self::reference_code_composed`]).
    pub fn build_composed(
        f: f32,
        mid: QParams,
        next: QParams,
        acc_min: i64,
        acc_max: i64,
    ) -> Self {
        Self::build_eval(
            |acc| Self::reference_code_composed(acc, f, &mid, &next),
            f,
            next,
            acc_min,
            acc_max,
        )
    }

    fn build_eval(
        eval: impl Fn(i64) -> i32,
        f: f32,
        out: QParams,
        acc_min: i64,
        acc_max: i64,
    ) -> Self {
        assert!(f > 0.0);
        assert!(acc_min <= acc_max);
        let (code_min, code_max) = out.code_range();
        let mut thresholds = Vec::with_capacity((code_max - code_min) as usize);
        for target in code_min + 1..=code_max {
            // smallest acc in [acc_min, acc_max+1] with code(acc) >= target
            let (mut lo, mut hi) = (acc_min, acc_max + 1);
            while lo < hi {
                let mid = lo + (hi - lo) / 2;
                if eval(mid) >= target {
                    hi = mid;
                } else {
                    lo = mid + 1;
                }
            }
            thresholds.push(lo);
        }
        RequantLut { thresholds, code_min, code_max, acc_min, acc_max, f, out }
    }

    /// Map an accumulator to its output code. O(log levels).
    #[inline]
    pub fn apply(&self, acc: i64) -> i32 {
        debug_assert!(acc >= self.acc_min && acc <= self.acc_max, "acc {acc} out of LUT range");
        // partition_point: number of thresholds <= acc
        let k = self.thresholds.partition_point(|&t| t <= acc);
        self.code_min + k as i32
    }

    pub fn code_range(&self) -> (i32, i32) {
        (self.code_min, self.code_max)
    }

    pub fn num_thresholds(&self) -> usize {
        self.thresholds.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_exact(f: f32, out: QParams, lo: i64, hi: i64) {
        let lut = RequantLut::build(f, out, lo, hi);
        for acc in lo..=hi {
            assert_eq!(
                lut.apply(acc),
                RequantLut::reference_code(acc, f, &out),
                "acc={acc} f={f} out={out:?}"
            );
        }
    }

    #[test]
    fn exact_over_small_range() {
        check_exact(0.01, QParams::new(1.0, 7.0, 0.0), -500, 500);
    }

    #[test]
    fn exact_signed_output() {
        check_exact(0.003, QParams::new(0.7, 15.0, -1.0), -2000, 2000);
    }

    #[test]
    fn exact_ternary_input_grid() {
        // ternary weights, 4-bit acts: f = (sa*sw)/(na*nw) with nw=1
        let f = (0.9 * 0.4) / (7.0 * 1.0);
        check_exact(f, QParams::new(1.2, 7.0, 0.0), -300, 300);
    }

    #[test]
    fn saturates_at_bounds() {
        let out = QParams::new(1.0, 3.0, 0.0);
        let lut = RequantLut::build(0.1, out, -100, 100);
        assert_eq!(lut.apply(-100), 0);
        assert_eq!(lut.apply(100), 3);
    }

    #[test]
    fn threshold_count_bounded_by_levels() {
        let out = QParams::new(1.0, 7.0, -1.0);
        let lut = RequantLut::build(0.05, out, -1000, 1000);
        assert_eq!(lut.num_thresholds(), 14); // codes -7..=7 -> 14 boundaries
    }
}
