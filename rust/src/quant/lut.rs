//! Integer re-binning LUT: the paper's "hardware-supported quantization".
//!
//! Eq. (4) leaves the conv output as an integer accumulator `acc` with an
//! implicit scale f = (s^a s^w)/(n^a n^w). The next layer wants integer
//! codes on its own input grid. The float path computes
//!
//! ```text
//! code = round(clip(acc * f / s^o, b, 1) * n^o)
//! ```
//!
//! The paper observes this scale "is not needed for active computation as
//! long as the hardware-supported quantization ... puts the integer-valued
//! sum into the correct integer-valued quantized bin". We implement that
//! hardware bin mapper two ways:
//!
//! * a **dense direct-index table**: one i16 code per in-range
//!   accumulator value, built whenever the accumulator span fits
//!   [`DENSE_TABLE_MAX`] entries. `apply` is then a single branchless
//!   bounded load — no search at all. For the conv layers the span is
//!   `kdim * amax * nw` (a few thousand for the KWS shapes), so this is
//!   the path the inference engine always takes.
//! * a **threshold table** fallback: since `code(acc)` is monotone
//!   non-decreasing in `acc`, the mapping is fully described by at most
//!   (range of codes) threshold integers, found by binary search against
//!   the f32 reference formula, and applied by `partition_point`.
//!
//! Both agree with the XLA artifact bit-for-bit for every in-range
//! accumulator — including ties-to-even edge cases (verified by the
//! property tests in rust/tests/properties.rs, which sweep the dense
//! table against [`RequantLut::reference_code`] exactly).

use super::QParams;

/// Largest accumulator span (`acc_max - acc_min + 1`) for which the
/// dense direct-index table is built: 2^17 entries = 256 KiB of i16 —
/// comfortably cache-resident per layer, and far above every KWS shape
/// (`kdim * amax * nw` ~ 1e3..1e4).
pub const DENSE_TABLE_MAX: i64 = 1 << 17;

/// Threshold-table requantizer: integer accumulator -> integer output code.
#[derive(Clone, Debug)]
pub struct RequantLut {
    /// thresholds[k] = smallest acc whose code is codes_min + k + 1
    thresholds: Vec<i64>,
    /// dense direct-index table: `table[acc - acc_min]` = output code
    /// (present iff the span fits [`DENSE_TABLE_MAX`])
    table: Vec<i16>,
    code_min: i32,
    code_max: i32,
    pub acc_min: i64,
    pub acc_max: i64,
    /// the float path it reproduces (kept for tests / fallback)
    pub f: f32,
    pub out: QParams,
}

impl RequantLut {
    /// Reference (float-path) code for an accumulator value.
    #[inline]
    pub fn reference_code(acc: i64, f: f32, out: &QParams) -> i32 {
        out.int_code(acc as f32 * f)
    }

    /// Build for accumulators in [acc_min, acc_max].
    ///
    /// `f` is the Eq. (4) prefactor (s^a s^w)/(n^a n^w) and `out` the next
    /// layer's input quantizer. Requires f > 0 (scales are e^s > 0).
    pub fn build(f: f32, out: QParams, acc_min: i64, acc_max: i64) -> Self {
        Self::build_eval(|acc| Self::reference_code(acc, f, &out), f, out, acc_min, acc_max)
    }

    /// Reference code for the *composed* two-step re-binning the deployed
    /// kernel performs: acc -> Q_mid (this layer's output quantizer) ->
    /// integer code on the *next* layer's input grid. Double rounding is
    /// intentional — it is what the XLA artifact computes.
    #[inline]
    pub fn reference_code_composed(acc: i64, f: f32, mid: &QParams, next: &QParams) -> i32 {
        let y = mid.quantize(acc as f32 * f);
        next.int_code(y)
    }

    /// Build the composed LUT (see [`Self::reference_code_composed`]).
    pub fn build_composed(
        f: f32,
        mid: QParams,
        next: QParams,
        acc_min: i64,
        acc_max: i64,
    ) -> Self {
        Self::build_eval(
            |acc| Self::reference_code_composed(acc, f, &mid, &next),
            f,
            next,
            acc_min,
            acc_max,
        )
    }

    fn build_eval(
        eval: impl Fn(i64) -> i32,
        f: f32,
        out: QParams,
        acc_min: i64,
        acc_max: i64,
    ) -> Self {
        assert!(f > 0.0);
        assert!(acc_min <= acc_max);
        let (code_min, code_max) = out.code_range();
        // threshold table (kept even when the dense table exists: it is
        // the fallback for out-of-cap ranges and the oracle the tests
        // cross-check the dense table against)
        let mut thresholds = Vec::with_capacity((code_max - code_min) as usize);
        for target in code_min + 1..=code_max {
            // smallest acc in [acc_min, acc_max+1] with code(acc) >= target
            let (mut lo, mut hi) = (acc_min, acc_max + 1);
            while lo < hi {
                let mid = lo + (hi - lo) / 2;
                if eval(mid) >= target {
                    hi = mid;
                } else {
                    lo = mid + 1;
                }
            }
            thresholds.push(lo);
        }
        // dense direct-index table when the span is small enough
        let span = acc_max - acc_min + 1;
        let dense_ok =
            span <= DENSE_TABLE_MAX && code_min >= i16::MIN as i32 && code_max <= i16::MAX as i32;
        let table = if dense_ok {
            (acc_min..=acc_max).map(|acc| eval(acc) as i16).collect()
        } else {
            Vec::new()
        };
        RequantLut { thresholds, table, code_min, code_max, acc_min, acc_max, f, out }
    }

    /// True when the branchless dense table is active.
    #[inline]
    pub fn is_dense(&self) -> bool {
        !self.table.is_empty()
    }

    /// The dense table and its base accumulator, for callers that want
    /// to hoist the lookup into their own fused loop:
    /// `code = table[(acc.clamp(acc_min, acc_max) - base) as usize]`.
    #[inline]
    pub fn dense_table(&self) -> Option<(&[i16], i64)> {
        if self.table.is_empty() {
            None
        } else {
            Some((&self.table, self.acc_min))
        }
    }

    /// Map an accumulator to its output code: a single bounded load on
    /// the dense path, O(log levels) on the threshold fallback.
    #[inline]
    pub fn apply(&self, acc: i64) -> i32 {
        debug_assert!(acc >= self.acc_min && acc <= self.acc_max, "acc {acc} out of LUT range");
        if !self.table.is_empty() {
            let idx = (acc.clamp(self.acc_min, self.acc_max) - self.acc_min) as usize;
            return self.table[idx] as i32;
        }
        self.apply_search(acc)
    }

    /// The threshold-table path, regardless of whether the dense table
    /// exists (exposed so tests can cross-check the two).
    #[inline]
    pub fn apply_search(&self, acc: i64) -> i32 {
        // partition_point: number of thresholds <= acc
        let k = self.thresholds.partition_point(|&t| t <= acc);
        self.code_min + k as i32
    }

    pub fn code_range(&self) -> (i32, i32) {
        (self.code_min, self.code_max)
    }

    pub fn num_thresholds(&self) -> usize {
        self.thresholds.len()
    }
}

// ---------------------------------------------------------------------------
// Integer residual add
// ---------------------------------------------------------------------------

/// Integer skip-add requantizer for residual blocks.
///
/// A residual join adds two tensors that live on *different* quantizer
/// grids: the block body's output codes (scale `es_a / n_a`) and the
/// shortcut's codes (`es_b / n_b`). The float path rescales both to a
/// common scale, adds, and re-quantizes onto the consumer's input grid —
/// the fused-requant recipe from the integer-inference surveys
/// (Krishnamoorthi 2018 §2.4.2; Nagel et al. 2021). Because both inputs
/// are small integer codes, the whole composition is a finite function
/// of the code *pair*; [`AddLut`] tabulates it exactly, so the hot path
/// is one branchless 2-D table load per element and **no float scale
/// ever materializes** — same philosophy as [`RequantLut`], extended to
/// a binary op.
///
/// Table size is `|codes_a| x |codes_b|` i8 entries: 64 bytes for the
/// 3-bit activations of the paper's CIFAR nets, 64 KiB even for two full
/// 8-bit grids — always cache-resident.
#[derive(Clone, Debug)]
pub struct AddLut {
    /// `table[(ca - a_min) * b_span + (cb - b_min)]` = output code
    table: Vec<i8>,
    a_min: i32,
    b_min: i32,
    b_span: usize,
    /// the body-branch grid the `a` codes live on
    pub a: QParams,
    /// the shortcut grid the `b` codes live on
    pub b: QParams,
    /// the consumer grid output codes are emitted on
    pub out: QParams,
}

impl AddLut {
    /// Reference (float-path) code: dequantize both addends, add, and
    /// quantize onto the output grid.
    #[inline]
    pub fn reference_code(ca: i32, cb: i32, a: &QParams, b: &QParams, out: &QParams) -> i32 {
        out.int_code(a.dequantize(ca) + b.dequantize(cb))
    }

    /// Tabulate the add for every representable `(a, b)` code pair.
    pub fn build(a: QParams, b: QParams, out: QParams) -> Self {
        let (a_min, a_max) = a.code_range();
        let (b_min, b_max) = b.code_range();
        let (o_min, o_max) = out.code_range();
        assert!(
            o_min >= i8::MIN as i32 && o_max <= i8::MAX as i32,
            "output codes must fit i8 (got {o_min}..={o_max})"
        );
        let b_span = (b_max - b_min + 1) as usize;
        let a_span = (a_max - a_min + 1) as usize;
        let mut table = Vec::with_capacity(a_span * b_span);
        for ca in a_min..=a_max {
            for cb in b_min..=b_max {
                table.push(Self::reference_code(ca, cb, &a, &b, &out) as i8);
            }
        }
        AddLut { table, a_min, b_min, b_span, a, b, out }
    }

    /// Map one code pair to its output code (single bounded load). Both
    /// codes must be in their grids' ranges — true by construction for
    /// codes the quantized kernels emit.
    #[inline]
    pub fn apply(&self, ca: i8, cb: i8) -> i8 {
        let ia = (ca as i32 - self.a_min) as usize;
        let ib = (cb as i32 - self.b_min) as usize;
        debug_assert!(ia * self.b_span + ib < self.table.len(), "code pair ({ca},{cb}) off-grid");
        self.table[ia * self.b_span + ib]
    }

    /// Number of tabulated pairs (observability / tests).
    pub fn len(&self) -> usize {
        self.table.len()
    }

    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_exact(f: f32, out: QParams, lo: i64, hi: i64) {
        let lut = RequantLut::build(f, out, lo, hi);
        for acc in lo..=hi {
            assert_eq!(
                lut.apply(acc),
                RequantLut::reference_code(acc, f, &out),
                "acc={acc} f={f} out={out:?}"
            );
            assert_eq!(
                lut.apply_search(acc),
                lut.apply(acc),
                "dense/threshold disagree at acc={acc}"
            );
        }
    }

    #[test]
    fn exact_over_small_range() {
        check_exact(0.01, QParams::new(1.0, 7.0, 0.0), -500, 500);
    }

    #[test]
    fn exact_signed_output() {
        check_exact(0.003, QParams::new(0.7, 15.0, -1.0), -2000, 2000);
    }

    #[test]
    fn exact_ternary_input_grid() {
        // ternary weights, 4-bit acts: f = (sa*sw)/(na*nw) with nw=1
        let f = (0.9 * 0.4) / (7.0 * 1.0);
        check_exact(f, QParams::new(1.2, 7.0, 0.0), -300, 300);
    }

    #[test]
    fn saturates_at_bounds() {
        let out = QParams::new(1.0, 3.0, 0.0);
        let lut = RequantLut::build(0.1, out, -100, 100);
        assert_eq!(lut.apply(-100), 0);
        assert_eq!(lut.apply(100), 3);
    }

    #[test]
    fn threshold_count_bounded_by_levels() {
        let out = QParams::new(1.0, 7.0, -1.0);
        let lut = RequantLut::build(0.05, out, -1000, 1000);
        assert_eq!(lut.num_thresholds(), 14); // codes -7..=7 -> 14 boundaries
    }

    #[test]
    fn small_ranges_take_the_dense_path() {
        let out = QParams::new(1.0, 7.0, 0.0);
        let lut = RequantLut::build(0.01, out, -5000, 5000);
        assert!(lut.is_dense());
        let (tbl, base) = lut.dense_table().unwrap();
        assert_eq!(tbl.len() as i64, 10001);
        assert_eq!(base, -5000);
    }

    #[test]
    fn huge_ranges_fall_back_to_thresholds() {
        let out = QParams::new(1.0, 7.0, 0.0);
        let span = DENSE_TABLE_MAX + 10;
        let lut = RequantLut::build(1e-6, out, -span / 2, span / 2);
        assert!(!lut.is_dense());
        assert!(lut.dense_table().is_none());
        // the threshold path still answers correctly at the edges
        for acc in [-span / 2, -1, 0, 1, span / 2] {
            assert_eq!(lut.apply(acc), RequantLut::reference_code(acc, 1e-6, &out));
        }
    }

    #[test]
    fn add_lut_matches_float_reference_exactly() {
        // body on a ReLU grid, skip on a signed grid, output on a third
        let a = QParams::new(0.9, 7.0, 0.0);
        let b = QParams::new(1.3, 7.0, -1.0);
        let out = QParams::new(1.1, 7.0, 0.0);
        let lut = AddLut::build(a, b, out);
        assert_eq!(lut.len(), 8 * 15);
        for ca in 0..=7i32 {
            for cb in -7..=7i32 {
                assert_eq!(
                    lut.apply(ca as i8, cb as i8) as i32,
                    AddLut::reference_code(ca, cb, &a, &b, &out),
                    "pair ({ca},{cb})"
                );
            }
        }
    }

    #[test]
    fn add_lut_is_monotone_in_each_argument() {
        let a = QParams::new(0.7, 15.0, 0.0);
        let b = QParams::new(1.9, 7.0, 0.0);
        let out = QParams::new(1.2, 15.0, 0.0);
        let lut = AddLut::build(a, b, out);
        for ca in 0..=15i8 {
            for cb in 1..=7i8 {
                assert!(lut.apply(ca, cb) >= lut.apply(ca, cb - 1), "b-monotone at ({ca},{cb})");
            }
        }
        for cb in 0..=7i8 {
            for ca in 1..=15i8 {
                assert!(lut.apply(ca, cb) >= lut.apply(ca - 1, cb), "a-monotone at ({ca},{cb})");
            }
        }
    }

    #[test]
    fn composed_dense_matches_composed_reference() {
        let mid = QParams::new(0.8, 7.0, 0.0);
        let next = QParams::new(1.1, 7.0, 0.0);
        let f = 0.004f32;
        let lut = RequantLut::build_composed(f, mid, next, -700, 700);
        assert!(lut.is_dense());
        for acc in -700..=700 {
            assert_eq!(
                lut.apply(acc),
                RequantLut::reference_code_composed(acc, f, &mid, &next),
                "acc={acc}"
            );
        }
    }
}
