//! The paper's quantizer (Eqs. 1-2) on the Rust side, plus the integer
//! re-binning LUT used by the inference engine.
//!
//! Numerics MUST match the JAX side bit-for-bit on the forward path:
//! `jnp.round` rounds half-to-even, so we use `f32::round_ties_even`.
//! Property tests in rust/tests/properties.rs and the artifact-agreement
//! test in rust/tests/engine_vs_artifact.rs pin this down.

pub mod lut;

pub use lut::{AddLut, RequantLut};

/// Positive level count for an `nbits` code: n = 2^(nb-1) - 1.
pub fn n_levels(nbits: u32) -> i32 {
    (1i32 << (nbits - 1)) - 1
}

/// Eq. (1): round(clip(x, b, 1) * n) / n.
#[inline]
pub fn quantize_unit(x: f32, b: f32, n: f32) -> f32 {
    (x.clamp(b, 1.0) * n).round_ties_even() / n
}

/// Eq. (2): Q(x) = es * quantize(x / es) with es = e^s pre-exponentiated.
#[inline]
pub fn learned_quantize(x: f32, es: f32, n: f32, b: f32) -> f32 {
    es * quantize_unit(x / es, b, n)
}

/// Integer code: round(clip(x/es, b, 1) * n) in [b*n, n].
#[inline]
pub fn quantize_int(x: f32, es: f32, n: f32, b: f32) -> i32 {
    ((x / es).clamp(b, 1.0) * n).round_ties_even() as i32
}

/// Quantize a slice to integer codes (i8 is enough for nb <= 8: |code| <= 127).
pub fn quantize_int8_slice(xs: &[f32], es: f32, n: f32, b: f32) -> Vec<i8> {
    xs.iter().map(|&x| quantize_int(x, es, n, b) as i8).collect()
}

/// Dequantize an integer code back to the real line: x = es * code / n.
#[inline]
pub fn dequantize(code: i32, es: f32, n: f32) -> f32 {
    es * code as f32 / n
}

/// Per-tensor quantization parameters for one role (weights/acts/output).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QParams {
    /// e^s, the learned scale (always positive).
    pub es: f32,
    /// positive level count n = 2^(nb-1)-1.
    pub n: f32,
    /// clip lower bound: -1.0 (signed / hard-tanh-like) or 0.0 (ReLU-like).
    pub b: f32,
}

impl QParams {
    pub fn new(es: f32, n: f32, b: f32) -> Self {
        assert!(es > 0.0, "scale must be positive (es = e^s)");
        assert!(n >= 1.0);
        QParams { es, n, b }
    }

    pub fn from_log_scale(s: f32, nbits: u32, b: f32) -> Self {
        QParams::new(s.exp(), n_levels(nbits) as f32, b)
    }

    /// One least-significant-bit step in real units (the Table-7 noise unit).
    pub fn lsb(&self) -> f32 {
        self.es / self.n
    }

    pub fn quantize(&self, x: f32) -> f32 {
        learned_quantize(x, self.es, self.n, self.b)
    }

    pub fn int_code(&self, x: f32) -> i32 {
        quantize_int(x, self.es, self.n, self.b)
    }

    pub fn dequantize(&self, code: i32) -> f32 {
        dequantize(code, self.es, self.n)
    }

    /// Dequantize a *wide* integer (e.g. a pooled sum of codes): for
    /// values that fit an i32 this is bit-identical to [`Self::dequantize`];
    /// beyond that it widens to f64 instead of silently truncating (the
    /// old `sum as i32` bug in global average pooling).
    pub fn dequantize_i64(&self, code: i64) -> f32 {
        if let Ok(c) = i32::try_from(code) {
            self.dequantize(c)
        } else {
            debug_assert!(
                code.unsigned_abs() < (1u64 << 53),
                "pooled sum {code} exceeds exact f64 integer range"
            );
            (self.es as f64 * code as f64 / self.n as f64) as f32
        }
    }

    /// Smallest / largest representable integer code.
    pub fn code_range(&self) -> (i32, i32) {
        ((self.b * self.n).round_ties_even() as i32, self.n as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn n_levels_match_paper() {
        assert_eq!(n_levels(2), 1); // ternary
        assert_eq!(n_levels(3), 3);
        assert_eq!(n_levels(4), 7);
        assert_eq!(n_levels(5), 15);
        assert_eq!(n_levels(8), 127);
    }

    #[test]
    fn round_half_to_even_matches_jnp() {
        // jnp.round(0.5) == 0, jnp.round(1.5) == 2
        assert_eq!(quantize_unit(0.5 / 1.0, -1.0, 1.0), 0.0);
        assert_eq!(quantize_unit(1.5, -1.0, 1.0), 1.0); // clipped then rounded
        assert_eq!((0.5f32).round_ties_even(), 0.0);
        assert_eq!((1.5f32).round_ties_even(), 2.0);
        assert_eq!((2.5f32).round_ties_even(), 2.0);
    }

    #[test]
    fn ternary_codes() {
        let q = QParams::new(1.0, 1.0, -1.0);
        assert_eq!(q.int_code(0.7), 1);
        assert_eq!(q.int_code(0.2), 0);
        assert_eq!(q.int_code(-0.9), -1);
        assert_eq!(q.code_range(), (-1, 1));
    }

    #[test]
    fn relu_bound_codes() {
        let q = QParams::new(2.0, 7.0, 0.0);
        assert_eq!(q.int_code(-5.0), 0);
        assert_eq!(q.int_code(5.0), 7);
        assert_eq!(q.code_range(), (0, 7));
    }

    #[test]
    fn quantize_dequantize_roundtrip() {
        let q = QParams::new(0.8, 15.0, -1.0);
        for i in -15..=15 {
            let x = q.dequantize(i);
            assert_eq!(q.int_code(x), i, "code {i}");
            assert!((q.quantize(x) - x).abs() < 1e-6);
        }
    }

    #[test]
    fn max_error_half_lsb_inside_range() {
        let q = QParams::new(1.3, 7.0, -1.0);
        let mut x = -1.3f32;
        while x < 1.3 {
            let err = (q.quantize(x) - x).abs();
            assert!(err <= q.lsb() / 2.0 + 1e-6, "x={x} err={err}");
            x += 0.013;
        }
    }

    #[test]
    fn dequantize_i64_widens_instead_of_truncating() {
        let q = QParams::new(1.0, 7.0, 0.0);
        // in-range: bit-identical to the i32 path
        for c in [-123456i64, -1, 0, 1, 987654] {
            assert_eq!(q.dequantize_i64(c), q.dequantize(c as i32));
        }
        // beyond i32: the old `as i32` cast would have wrapped
        let big = i32::MAX as i64 + 12_345;
        let got = q.dequantize_i64(big);
        let want = (big as f64 / 7.0) as f32;
        assert_eq!(got, want);
        assert!(got > 0.0, "wrapped to negative: {got}");
        let neg = -(i32::MAX as i64) - 99_999;
        assert!(q.dequantize_i64(neg) < 0.0);
    }

    #[test]
    fn int8_slice() {
        let v = quantize_int8_slice(&[0.9, -0.9, 0.1], 1.0, 1.0, -1.0);
        assert_eq!(v, vec![1, -1, 0]);
    }
}
