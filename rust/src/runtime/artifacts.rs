//! Artifact registry: parses `artifacts/manifest.json` (written by
//! python/compile/aot.py) into typed model/graph descriptors.
//!
//! The manifest is the contract between build-time Python and the runtime
//! coordinator: tensor order here IS the positional argument order of the
//! lowered computations.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered graph family's parameter signature.
#[derive(Clone, Debug, Default)]
pub struct GraphSpec {
    pub trainable: Vec<TensorSpec>,
    pub state: Vec<TensorSpec>,
    /// optimizer slot shapes (SGD: momentum per trainable; Adam: m+v+step)
    pub opt: Vec<Vec<usize>>,
    pub param_count: usize,
}

impl GraphSpec {
    pub fn n_inputs_train(&self) -> usize {
        self.trainable.len() + self.state.len() + self.opt.len() + 4 // x, y, teacher, hp
    }

    pub fn all_specs(&self) -> impl Iterator<Item = &TensorSpec> {
        self.trainable.iter().chain(self.state.iter())
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.all_specs().position(|s| s.name == name)
    }
}

/// QAT -> FQ parameter transform rule (§3.4; see coordinator::fq_transform).
#[derive(Clone, Debug)]
pub struct FqRule {
    pub fq: String,
    pub qat: String,
    pub pred_scale: String,
    pub bn: bool,
}

#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub kind: String,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub opt_kind: String,
    pub macs_per_sample: u64,
    pub qat: GraphSpec,
    pub fq: Option<GraphSpec>,
    pub fq_map: Vec<FqRule>,
    pub artifacts: BTreeMap<String, String>,
    pub init_ckpt: String,
}

impl ModelInfo {
    pub fn artifact_path(&self, dir: &Path, key: &str) -> Result<PathBuf> {
        match self.artifacts.get(key) {
            Some(f) => Ok(dir.join(f)),
            None => bail!("model {} has no artifact {key:?}", self.name),
        }
    }

    /// Per-sample input element count.
    pub fn input_numel(&self) -> usize {
        self.input_shape.iter().product()
    }
}

pub struct Manifest {
    pub dir: PathBuf,
    pub hp_len: usize,
    pub models: BTreeMap<String, ModelInfo>,
}

fn parse_specs(j: &Json) -> Vec<TensorSpec> {
    j.as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|t| TensorSpec {
            name: t.req("name").as_str().unwrap_or_default().to_string(),
            shape: t.req("shape").usizes(),
        })
        .collect()
}

fn parse_graph(j: &Json) -> GraphSpec {
    GraphSpec {
        trainable: parse_specs(j.req("trainable")),
        state: parse_specs(j.req("state")),
        opt: j.req("opt").as_arr().unwrap_or(&[]).iter().map(|s| s.usizes()).collect(),
        param_count: j.req("param_count").as_usize().unwrap_or(0),
    }
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        Self::verify_hp(&j)?;
        let mut models = BTreeMap::new();
        for (name, m) in j.req("models").as_obj().context("manifest.models")? {
            let fq = m.get("fq").map(parse_graph);
            let fq_map = m
                .get("fq_map")
                .and_then(|v| v.as_arr())
                .unwrap_or(&[])
                .iter()
                .map(|r| FqRule {
                    fq: r.req("fq").as_str().unwrap_or_default().to_string(),
                    qat: r.req("qat").as_str().unwrap_or_default().to_string(),
                    pred_scale: r.req("pred_scale").as_str().unwrap_or_default().to_string(),
                    bn: r.req("bn").as_bool().unwrap_or(false),
                })
                .collect();
            let artifacts = m
                .req("artifacts")
                .as_obj()
                .context("artifacts")?
                .iter()
                .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                .collect();
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    kind: m.req("kind").as_str().unwrap_or_default().to_string(),
                    batch: m.req("batch").as_usize().context("batch")?,
                    input_shape: m.req("input_shape").usizes(),
                    num_classes: m.req("num_classes").as_usize().context("num_classes")?,
                    opt_kind: m.req("opt_kind").as_str().unwrap_or_default().to_string(),
                    macs_per_sample: m.req("macs_per_sample").as_f64().unwrap_or(0.0) as u64,
                    qat: parse_graph(m.req("qat")),
                    fq,
                    fq_map,
                    artifacts,
                    init_ckpt: m.req("init_ckpt").as_str().unwrap_or_default().to_string(),
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            hp_len: j.req("hp_len").as_usize().context("hp_len")?,
            models,
        })
    }

    /// The Rust hp constants must agree with the python layout.
    fn verify_hp(j: &Json) -> Result<()> {
        use super::hp;
        let layout = j.req("hp_layout");
        let expect = [
            ("lr", hp::LR),
            ("weight_decay", hp::WEIGHT_DECAY),
            ("momentum", hp::MOMENTUM),
            ("distill_weight", hp::DISTILL_WEIGHT),
            ("distill_temp", hp::DISTILL_TEMP),
            ("nw", hp::NW),
            ("na", hp::NA),
            ("sigma_w", hp::SIGMA_W),
            ("sigma_a", hp::SIGMA_A),
            ("sigma_mac", hp::SIGMA_MAC),
            ("seed", hp::SEED),
            ("bn_momentum", hp::BN_MOMENTUM),
        ];
        for (key, idx) in expect {
            let got = layout.req(key).as_usize();
            if got != Some(idx) {
                bail!("hp layout mismatch for {key}: manifest={got:?} rust={idx}");
            }
        }
        if j.req("hp_len").as_usize() != Some(hp::LEN) {
            bail!("hp_len mismatch");
        }
        Ok(())
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models.get(name).with_context(|| format!("unknown model {name:?}"))
    }
}
