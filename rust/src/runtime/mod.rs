//! PJRT runtime: load AOT artifacts (`*.hlo.txt`) and execute them.
//!
//! Thin, deliberate wrapper over the `xla` crate following the pattern
//! validated in /opt/xla-example: HLO *text* -> `HloModuleProto` ->
//! `XlaComputation` -> `PjRtClient::compile` -> `execute`. All lowered
//! computations return a tuple (`return_tuple=True` at lowering), which
//! [`Executable::run`] decomposes back into per-output literals.
//!
//! The coordinator keeps parameters as [`xla::Literal`] values between
//! steps — on the CPU PJRT client host<->device transfers are memcpys,
//! and the perf pass (EXPERIMENTS.md §Perf) measures the copy overhead
//! explicitly via `benches/perf_runtime.rs`.

pub mod artifacts;

use std::path::Path;

use anyhow::{Context, Result};

pub use artifacts::{FqRule, GraphSpec, Manifest, ModelInfo, TensorSpec};

/// Hyper-parameter vector layout — MUST mirror python/compile/layers.py HP.
/// Checked against the manifest at load time (`Manifest::verify_hp`).
pub mod hp {
    pub const LEN: usize = 16;
    pub const LR: usize = 0;
    pub const WEIGHT_DECAY: usize = 1;
    pub const MOMENTUM: usize = 2;
    pub const DISTILL_WEIGHT: usize = 3;
    pub const DISTILL_TEMP: usize = 4;
    pub const NW: usize = 5;
    pub const NA: usize = 6;
    pub const SIGMA_W: usize = 7;
    pub const SIGMA_A: usize = 8;
    pub const SIGMA_MAC: usize = 9;
    pub const SEED: usize = 10;
    pub const BN_MOMENTUM: usize = 11;

    /// Default vector matching layers.hp_vec(): momentum 0.9, bn 0.1, T 4.
    pub fn defaults() -> [f32; LEN] {
        let mut v = [0.0f32; LEN];
        v[MOMENTUM] = 0.9;
        v[BN_MOMENTUM] = 0.1;
        v[DISTILL_TEMP] = 4.0;
        v
    }
}

/// PJRT engine: one client, many compiled executables.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path.file_name().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default(),
        })
    }
}

/// A compiled computation ready to execute.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Executable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with literal inputs; decompose the result tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let out = bufs[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        out.to_tuple().map_err(|e| anyhow::anyhow!("decomposing {} result: {e}", self.name))
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// f32 literal with the given logical shape.
pub fn lit_f32(shape: &[usize], data: &[f32]) -> xla::Literal {
    let n: usize = shape.iter().product();
    assert_eq!(n, data.len(), "lit_f32 shape/data mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).expect("reshape literal")
}

/// i32 literal with the given logical shape.
pub fn lit_i32(shape: &[usize], data: &[i32]) -> xla::Literal {
    let n: usize = shape.iter().product();
    assert_eq!(n, data.len(), "lit_i32 shape/data mismatch");
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).expect("reshape literal")
}

/// Scalar (rank-0) f32 literal.
pub fn lit_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("literal to f32 vec: {e}"))
}

pub fn lit_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    let v = lit_to_vec_f32(lit)?;
    anyhow::ensure!(v.len() == 1, "expected scalar, got {} elems", v.len());
    Ok(v[0])
}
