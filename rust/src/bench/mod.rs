//! Micro-benchmark harness (no criterion in the offline image).
//!
//! `cargo bench` targets are `harness = false` binaries that call into
//! this module: warmup, timed iterations, robust statistics (median /
//! mean / min / p95), and throughput helpers. Output format is stable so
//! EXPERIMENTS.md §Perf can quote it directly.

use crate::util::Timer;

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub p95_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<40} iters={:<5} mean={:>10} median={:>10} min={:>10} p95={:>10}",
            self.name,
            self.iters,
            fmt_t(self.mean_s),
            fmt_t(self.median_s),
            fmt_t(self.min_s),
            fmt_t(self.p95_s),
        )
    }

    /// Items-per-second at the median (e.g. MACs, samples, requests).
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.median_s
    }
}

fn fmt_t(s: f64) -> String {
    crate::util::timer::fmt_duration(s)
}

/// Time `f` for `iters` iterations after `warmup` calls.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Timer::start();
        f();
        times.push(t.elapsed_s());
    }
    times.sort_by(|a, b| a.total_cmp(b));
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchStats {
        name: name.to_string(),
        iters,
        mean_s: mean,
        median_s: times[times.len() / 2],
        min_s: times[0],
        p95_s: times[((times.len() as f64 * 0.95) as usize).min(times.len() - 1)],
    }
}

/// Adaptive variant: runs until `min_time_s` of measurement or `max_iters`.
pub fn bench_for(name: &str, min_time_s: f64, max_iters: usize, mut f: impl FnMut()) -> BenchStats {
    // warmup once
    f();
    let mut times = Vec::new();
    let total = Timer::start();
    while total.elapsed_s() < min_time_s && times.len() < max_iters {
        let t = Timer::start();
        f();
        times.push(t.elapsed_s());
    }
    times.sort_by(|a, b| a.total_cmp(b));
    let n = times.len().max(1);
    let mean = times.iter().sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: times.len(),
        mean_s: mean,
        median_s: times.get(times.len() / 2).copied().unwrap_or(0.0),
        min_s: times.first().copied().unwrap_or(0.0),
        p95_s: times.get((times.len() as f64 * 0.95) as usize).copied().unwrap_or(0.0),
    }
}

/// Standard table/bench header so all bench outputs look alike.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let mut n = 0;
        let s = bench("noop", 2, 10, || n += 1);
        assert_eq!(s.iters, 10);
        assert_eq!(n, 12);
        assert!(s.min_s <= s.median_s && s.median_s <= s.p95_s);
    }

    #[test]
    fn throughput_positive() {
        let s = bench("spin", 0, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.throughput(1000.0) > 0.0);
    }
}
