//! Model descriptors: architecture rendering (Figs. 2-4), parameter/MAC
//! accounting and the Table-5 model-comparison rows.
//!
//! The source of truth for shapes is `artifacts/manifest.json`; this
//! module derives presentation and accounting views from it.

use crate::runtime::ModelInfo;

/// KWS dilation schedule (mirror of compile/models/kws.py).
pub const KWS_DILATIONS: [usize; 7] = [1, 1, 2, 4, 8, 8, 8];

/// Bytes needed to store a model's weights at `wbits` weight bits
/// (the paper's "Size (Byte)" column: params * bits / 8).
pub fn model_size_bytes(param_count: usize, wbits: u32) -> f64 {
    param_count as f64 * wbits as f64 / 8.0
}

/// One row of Table 5.
#[derive(Clone, Debug)]
pub struct ModelRow {
    pub name: String,
    pub acc_pct: f64,
    pub params: f64,
    pub size_bytes: f64,
    pub mults: f64,
    pub ours: bool,
}

/// Literature keyword-spotting models quoted by Table 5
/// (Sainath & Parada 2015; Tang & Lin 2018).
pub fn table5_literature_rows() -> Vec<ModelRow> {
    let r = |name: &str, acc: f64, params: f64, size: f64, mults: f64| ModelRow {
        name: name.into(),
        acc_pct: acc,
        params,
        size_bytes: size,
        mults,
        ours: false,
    };
    vec![
        r("trad-fpool13", 90.5, 1.37e6, 5.48e6, 125e6),
        r("tpool2", 91.7, 1.09e6, 4.36e6, 103e6),
        r("one-stride1", 77.9, 954e3, 3.82e6, 5.76e6),
        r("res15", 95.8, 238e3, 952e3, 894e6),
        r("res15-narrow", 94.0, 42.6e3, 170e3, 160e6),
    ]
}

/// Our Table-5 rows, from the manifest + a measured accuracy.
pub fn table5_our_rows(info: &ModelInfo, acc_q35: f64, acc_fq24: f64) -> Vec<ModelRow> {
    let params = info.qat.param_count as f64;
    let macs = info.macs_per_sample as f64;
    vec![
        ModelRow {
            name: "Q35 (ours)".into(),
            acc_pct: acc_q35 * 100.0,
            params,
            size_bytes: model_size_bytes(info.qat.param_count, 3),
            mults: macs,
            ours: true,
        },
        ModelRow {
            name: "FQ24 (ours)".into(),
            acc_pct: acc_fq24 * 100.0,
            params,
            size_bytes: model_size_bytes(info.fq.as_ref().map(|g| g.param_count).unwrap_or(info.qat.param_count), 2),
            mults: macs,
            ours: true,
        },
    ]
}

fn human(v: f64) -> String {
    if v >= 1e6 {
        format!("{:.2}M", v / 1e6)
    } else if v >= 1e3 {
        format!("{:.1}K", v / 1e3)
    } else {
        format!("{v:.0}")
    }
}

pub fn render_table5(rows: &[ModelRow]) -> String {
    let mut out = format!(
        "{:<16} {:>10} {:>10} {:>12} {:>10}\n",
        "Model", "Test acc.", "# params", "Size (Byte)", "Mult."
    );
    for r in rows {
        out.push_str(&format!(
            "{:<16} {:>9.2}% {:>10} {:>12} {:>10}{}\n",
            r.name,
            r.acc_pct,
            human(r.params),
            human(r.size_bytes),
            human(r.mults),
            if r.ours { "   <- this work" } else { "" },
        ));
    }
    out
}

// ---------------------------------------------------------------------------
// Architecture printers (Figs. 2-4)
// ---------------------------------------------------------------------------

/// Fig. 2 (KWS) / Fig. 4 (ResNet) style architecture summary.
/// `fq = true` renders the §3.4 fully-quantized variant (Fig. 3/4B).
pub fn render_architecture(info: &ModelInfo, fq: bool) -> String {
    match info.kind.as_str() {
        "kws" => render_kws(info, fq),
        "resnet" => render_resnet(info, fq),
        "darknet" => render_darknet(info),
        other => format!("(no architecture printer for kind {other})"),
    }
}

fn block_line(out: &mut String, depth: usize, text: &str) {
    out.push_str(&"  ".repeat(depth));
    out.push_str(text);
    out.push('\n');
}

fn render_kws(info: &ModelInfo, fq: bool) -> String {
    let mut out = String::new();
    let t0 = info.input_shape[1];
    out.push_str(&format!(
        "KWS network ({}) — input MFCC ({} coeffs x {} frames)\n",
        if fq { "fully quantized, Fig. 4B style" } else { "QAT, Fig. 4A style" },
        info.input_shape[0],
        t0
    ));
    block_line(&mut out, 1, "FC embed 39 -> 100 (full precision)  + BN + Q_in(b=-1)");
    let mut t = t0;
    let mut rf = 1usize;
    for (i, d) in KWS_DILATIONS.iter().enumerate() {
        t -= 2 * d;
        rf += 2 * d;
        let tail = if fq {
            "-> integer MAC -> Q_ReLU(b=0)   [no BN, no float ReLU]"
        } else {
            "-> BN -> ReLU -> Q_act"
        };
        block_line(
            &mut out,
            1,
            &format!("FQ-Conv1d#{i} 45f k=3 d={d:<2} T:{t:<3} RF:{rf:<3} {tail}"),
        );
    }
    block_line(&mut out, 1, "GlobalAvgPool (higher precision) -> FC -> softmax(12)");
    out.push_str(&format!(
        "params: {} ({:.1}K)   MACs/sample: {:.2}M\n",
        info.qat.param_count,
        info.qat.param_count as f64 / 1e3,
        info.macs_per_sample as f64 / 1e6
    ));
    out
}

fn render_resnet(info: &ModelInfo, fq: bool) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{} ({}) — input {}x{}x{} ({} classes)\n",
        info.name,
        if fq {
            "fully quantized, Fig. 4B: Q_in -> FQ-Conv blocks, no BN"
        } else {
            "QAT, Fig. 4A: conv(Q(w)) -> BN -> ReLU -> Q_act"
        },
        info.input_shape[0],
        info.input_shape[1],
        info.input_shape[2],
        info.num_classes
    ));
    // reconstruct stage structure from the spec names
    let mut blocks: Vec<String> = Vec::new();
    for spec in &info.qat.trainable {
        if let Some(stripped) = spec.name.strip_suffix(".c1.w") {
            blocks.push(stripped.to_string());
        }
    }
    block_line(&mut out, 1, "conv1 3x3 + BN + ReLU + Q_act");
    for b in &blocks {
        let down = info.qat.trainable.iter().any(|s| s.name == format!("{b}.down.w"));
        let tail = if fq { "FQ residual block" } else { "residual block" };
        block_line(
            &mut out,
            1,
            &format!("{b}: {tail}{}", if down { " (1x1 downsample, quantized)" } else { "" }),
        );
    }
    block_line(&mut out, 1, "GlobalAvgPool -> FC -> softmax (full precision)");
    out.push_str(&format!(
        "params: {:.2}K   MACs/sample: {:.2}M\n",
        info.qat.param_count as f64 / 1e3,
        info.macs_per_sample as f64 / 1e6
    ));
    out
}

fn render_darknet(info: &ModelInfo) -> String {
    let mut out = format!(
        "{} — DarkNet-19 block pattern (3x3 + maxpool + 1x1 squeeze), {} classes\n",
        info.name, info.num_classes
    );
    for spec in &info.qat.trainable {
        if let Some(name) = spec.name.strip_suffix(".w") {
            if spec.shape.len() == 4 {
                block_line(
                    &mut out,
                    1,
                    &format!(
                        "{name}: conv {}x{} {} -> {}",
                        spec.shape[2], spec.shape[3], spec.shape[1], spec.shape[0]
                    ),
                );
            }
        }
    }
    out.push_str(&format!(
        "params: {:.2}K   MACs/sample: {:.2}M\n",
        info.qat.param_count as f64 / 1e3,
        info.macs_per_sample as f64 / 1e6
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_bytes_matches_paper() {
        // Table 5: 50K params -> Q35 (3 bit) 18.75KB, FQ24 (2 bit) 12.5KB
        assert!((model_size_bytes(50_000, 3) - 18_750.0).abs() < 1.0);
        assert!((model_size_bytes(50_000, 2) - 12_500.0).abs() < 1.0);
    }

    #[test]
    fn literature_rows_present() {
        let rows = table5_literature_rows();
        assert_eq!(rows.len(), 5);
        assert!(rows.iter().any(|r| r.name == "res15-narrow"));
    }

    #[test]
    fn human_formatting() {
        assert_eq!(human(1_370_000.0), "1.37M");
        assert_eq!(human(42_600.0), "42.6K");
        assert_eq!(human(12.0), "12");
    }
}
