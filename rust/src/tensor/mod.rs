//! Minimal dense tensor substrate: contiguous row-major storage over f32 /
//! i32 / i8 element types — exactly what the integer inference engine, the
//! data pipelines and the PJRT host buffers need, nothing more.

use std::fmt;

/// Dense row-major tensor over a copyable element type.
#[derive(Clone, PartialEq)]
pub struct Tensor<T = f32> {
    shape: Vec<usize>,
    data: Vec<T>,
}

pub type TensorF = Tensor<f32>;
pub type TensorI32 = Tensor<i32>;
pub type TensorI8 = Tensor<i8>;

impl<T: Copy + Default> Tensor<T> {
    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![T::default(); n] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn full(shape: &[usize], v: T) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    pub fn scalar(v: T) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[T] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    /// Reshape without copying (sizes must match).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len(), "reshape size mismatch");
        self.shape = shape.to_vec();
        self
    }

    /// Row-major flat offset of a multi-index.
    #[inline]
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (i, (&ix, &dim)) in idx.iter().zip(&self.shape).enumerate() {
            debug_assert!(ix < dim, "index {ix} out of bounds for dim {i} ({dim})");
            off = off * dim + ix;
        }
        off
    }

    #[inline]
    pub fn at(&self, idx: &[usize]) -> T {
        self.data[self.offset(idx)]
    }

    #[inline]
    pub fn set(&mut self, idx: &[usize], v: T) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    /// Views a 2-D tensor's row as a slice.
    pub fn row(&self, r: usize) -> &[T] {
        assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        &self.data[r * cols..(r + 1) * cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [T] {
        assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        &mut self.data[r * cols..(r + 1) * cols]
    }
}

impl Tensor<f32> {
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Self {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&v| f(v)).collect() }
    }

    /// Elementwise a += b.
    pub fn add_assign(&mut self, other: &Tensor<f32>) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// argmax over the last axis of a 2-D tensor, one result per row.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2);
        (0..self.shape[0])
            .map(|r| {
                let row = self.row(r);
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    }
}

impl<T: fmt::Debug + Copy + Default> fmt::Debug for Tensor<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{:?}, {:?}, ... ({} elems)]", self.data[0], self.data[1], self.data.len())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_row_major() {
        let t = Tensor::from_vec(&[2, 3], (0..6).map(|i| i as f32).collect());
        assert_eq!(t.at(&[0, 0]), 0.0);
        assert_eq!(t.at(&[0, 2]), 2.0);
        assert_eq!(t.at(&[1, 0]), 3.0);
        assert_eq!(t.at(&[1, 2]), 5.0);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(&[6], (0..6).map(|i| i as f32).collect());
        let t = t.reshape(&[3, 2]);
        assert_eq!(t.at(&[2, 1]), 5.0);
    }

    #[test]
    #[should_panic]
    fn reshape_size_mismatch_panics() {
        let t: TensorF = Tensor::zeros(&[4]);
        let _ = t.reshape(&[5]);
    }

    #[test]
    fn argmax_rows() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.2, 3.0, -1.0, 2.0]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn rows() {
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(t.row(1), &[3.0, 4.0]);
    }

    #[test]
    fn i8_tensor() {
        let t: TensorI8 = Tensor::full(&[3], -3);
        assert_eq!(t.data(), &[-3, -3, -3]);
    }

    #[test]
    fn scalar_shape() {
        let t = Tensor::scalar(7.0f32);
        assert_eq!(t.ndim(), 0);
        assert_eq!(t.len(), 1);
    }
}
