//! Trainer: drives one model's AOT train/forward artifacts through PJRT.
//!
//! The trainer owns host-side parameters ([`ParamSet`]) and optimizer
//! slots, converts them to literals per call, and replays the artifact's
//! positional calling convention (trainable, state, opt, x, y, teacher,
//! hp — see python/compile/train.py). Bitwidths/noise/lr all travel in
//! the `hp` vector, so a single [`Trainer`] serves every stage of the
//! gradual-quantization ladder.

use anyhow::{bail, Context, Result};

use crate::data::Batch;
use crate::metrics;
use crate::runtime::{hp, lit_f32, lit_i32, lit_scalar_f32, lit_to_vec_f32, Engine, Executable, GraphSpec, Manifest, ModelInfo};
use crate::tensor::TensorF;

use super::params::ParamSet;

/// Which lowered graph family a trainer drives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// QAT graphs (Fig. 4A) with a quantizer flavor: "" (ours), "dorefa", "pact".
    Qat(&'static str),
    /// Fully quantized graphs (Fig. 4B, §3.4).
    Fq,
}

impl Variant {
    pub fn train_key(&self) -> String {
        match self {
            Variant::Qat("") => "train".into(),
            Variant::Qat(f) => format!("train_{f}"),
            Variant::Fq => "fq_train".into(),
        }
    }

    pub fn fwd_key(&self) -> String {
        match self {
            Variant::Qat("") => "fwd".into(),
            Variant::Qat(f) => format!("fwd_{f}"),
            Variant::Fq => "fq_fwd".into(),
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub loss: f32,
    pub acc: f32,
}

pub struct Trainer {
    pub info: ModelInfo,
    pub graph: GraphSpec,
    pub variant: Variant,
    pub params: ParamSet,
    opt: Vec<TensorF>,
    exe_train: Executable,
    exe_fwd: Executable,
    /// cumulative steps taken (diagnostics)
    pub steps: usize,
}

impl Trainer {
    pub fn new(
        engine: &Engine,
        manifest: &Manifest,
        model: &str,
        variant: Variant,
    ) -> Result<Self> {
        let info = manifest.model(model)?.clone();
        let graph = match variant {
            Variant::Qat(_) => info.qat.clone(),
            Variant::Fq => match &info.fq {
                Some(g) => g.clone(),
                None => bail!("model {model} has no FQ graphs"),
            },
        };
        let exe_train = engine
            .load(&info.artifact_path(&manifest.dir, &variant.train_key())?)
            .context("loading train artifact")?;
        let exe_fwd = engine
            .load(&info.artifact_path(&manifest.dir, &variant.fwd_key())?)
            .context("loading fwd artifact")?;
        let params = ParamSet::zeros(&graph);
        let opt = graph.opt.iter().map(|s| TensorF::zeros(s)).collect();
        Ok(Trainer { info, graph, variant, params, opt, exe_train, exe_fwd, steps: 0 })
    }

    /// Load parameters (trainable+state) from a checkpoint; resets optimizer.
    pub fn load_params(&mut self, ck: &super::checkpoint::Checkpoint) -> Result<()> {
        self.params = ParamSet::from_checkpoint(&self.graph, ck)?;
        self.reset_opt();
        Ok(())
    }

    pub fn set_params(&mut self, ps: ParamSet) {
        assert_eq!(ps.specs.len(), self.params.specs.len());
        self.params = ps;
        self.reset_opt();
    }

    pub fn reset_opt(&mut self) {
        self.opt = self.graph.opt.iter().map(|s| TensorF::zeros(s)).collect();
    }

    fn param_literals(&self) -> Vec<xla::Literal> {
        self.params
            .specs
            .iter()
            .zip(&self.params.values)
            .map(|(s, v)| lit_f32(&s.shape, v.data()))
            .collect()
    }

    fn batch_literals(&self, batch: &Batch) -> (xla::Literal, xla::Literal) {
        (lit_f32(batch.x.shape(), batch.x.data()), lit_i32(&[batch.y.len()], &batch.y))
    }

    /// One optimization step. `teacher` logits (B, C) or None (=> zeros;
    /// pair with hp[DISTILL_WEIGHT]=0).
    pub fn step(&mut self, batch: &Batch, teacher: Option<&TensorF>, hpv: &[f32]) -> Result<StepStats> {
        anyhow::ensure!(hpv.len() == hp::LEN, "hp length");
        anyhow::ensure!(batch.y.len() == self.info.batch, "batch size mismatch");
        let mut inputs = self.param_literals();
        for (shape, t) in self.graph.opt.iter().zip(&self.opt) {
            inputs.push(lit_f32(shape, t.data()));
        }
        let (xl, yl) = self.batch_literals(batch);
        inputs.push(xl);
        inputs.push(yl);
        let tshape = [self.info.batch, self.info.num_classes];
        match teacher {
            Some(t) => {
                anyhow::ensure!(t.shape() == tshape, "teacher logits shape");
                inputs.push(lit_f32(&tshape, t.data()));
            }
            None => inputs.push(lit_f32(&tshape, &vec![0.0; tshape[0] * tshape[1]])),
        }
        inputs.push(lit_f32(&[hp::LEN], hpv));

        let outs = self.exe_train.run(&inputs)?;
        let t_n = self.params.specs.len();
        let o_n = self.opt.len();
        anyhow::ensure!(outs.len() == t_n + o_n + 2, "unexpected output arity {}", outs.len());
        for (i, spec) in self.params.specs.iter().enumerate() {
            self.params.values[i] =
                TensorF::from_vec(&spec.shape, lit_to_vec_f32(&outs[i])?);
        }
        for (i, shape) in self.graph.opt.iter().enumerate() {
            self.opt[i] = TensorF::from_vec(shape, lit_to_vec_f32(&outs[t_n + i])?);
        }
        self.steps += 1;
        Ok(StepStats {
            loss: lit_scalar_f32(&outs[t_n + o_n])?,
            acc: lit_scalar_f32(&outs[t_n + o_n + 1])?,
        })
    }

    /// Eval-mode forward logits for a batch (B must equal artifact batch).
    pub fn forward(&self, x: &TensorF, hpv: &[f32]) -> Result<TensorF> {
        let mut inputs = self.param_literals();
        inputs.push(lit_f32(x.shape(), x.data()));
        inputs.push(lit_f32(&[hp::LEN], hpv));
        let outs = self.exe_fwd.run(&inputs)?;
        let logits = lit_to_vec_f32(&outs[0])?;
        Ok(TensorF::from_vec(&[self.info.batch, self.info.num_classes], logits))
    }

    /// Top-1 accuracy over `batches` deterministic validation batches.
    pub fn evaluate(&self, ds: &dyn crate::data::Dataset, hpv: &[f32], batches: usize) -> Result<f64> {
        let mut correct = 0usize;
        let mut total = 0usize;
        for bi in 0..batches {
            let batch = ds.val_batch((bi * self.info.batch) as u64, self.info.batch);
            let logits = self.forward(&batch.x, hpv)?;
            correct += (metrics::accuracy(&logits, &batch.y) * batch.y.len() as f64).round() as usize;
            total += batch.y.len();
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Top-1 and top-k accuracy over validation batches.
    pub fn evaluate_topk(
        &self,
        ds: &dyn crate::data::Dataset,
        hpv: &[f32],
        batches: usize,
        k: usize,
    ) -> Result<(f64, f64)> {
        let (mut top1, mut topk) = (0.0, 0.0);
        for bi in 0..batches {
            let batch = ds.val_batch((bi * self.info.batch) as u64, self.info.batch);
            let logits = self.forward(&batch.x, hpv)?;
            top1 += metrics::accuracy(&logits, &batch.y);
            topk += metrics::topk_accuracy(&logits, &batch.y, k);
        }
        Ok((top1 / batches.max(1) as f64, topk / batches.max(1) as f64))
    }
}
