//! Pipeline: runs a gradual-quantization [`Schedule`] end to end.
//!
//! For each stage the pipeline (a) initializes from the named earlier
//! stage's parameters (or the shipped init checkpoint), (b) resolves the
//! distillation teacher per the schedule's [`TeacherPolicy`] and computes
//! teacher logits batch-by-batch through the teacher's forward artifact,
//! (c) drives the stage's train artifact, (d) evaluates on the held-out
//! ids and records the stage result, and (e) optionally persists an FQCK
//! checkpoint per stage.
//!
//! This file IS the paper's §3.2+§3.3 as a system: bitwidth laddering,
//! teacher promotion ("each time we obtained a more accurate network ...
//! it became the teacher"), and the §3.4 QAT->FQ hand-off.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::data::Dataset;
use crate::runtime::{hp, Engine, Manifest};
use crate::util::{Rng, Timer};

use super::checkpoint;
use super::fq_transform;
use super::params::ParamSet;
use super::schedule::{Schedule, Stage, TeacherPolicy};
use super::trainer::{Trainer, Variant};

#[derive(Clone, Debug)]
pub struct StageResult {
    pub name: String,
    pub wbits: u32,
    pub abits: u32,
    pub fq: bool,
    pub val_acc: f64,
    pub val_topk: f64,
    pub final_loss: f32,
    pub steps: usize,
    pub seconds: f64,
    pub teacher: Option<String>,
}

#[derive(Clone, Debug, Default)]
pub struct PipelineReport {
    pub model: String,
    pub stages: Vec<StageResult>,
}

impl PipelineReport {
    pub fn stage(&self, name: &str) -> Option<&StageResult> {
        self.stages.iter().find(|s| s.name == name)
    }

    pub fn render_table(&self) -> String {
        let mut out = format!(
            "{:<7} {:>6} {:>6} {:>4} {:>9} {:>9} {:>8} {:>8}\n",
            "stage", "w-bits", "a-bits", "fq", "val-top1", "val-topk", "loss", "teacher"
        );
        for s in &self.stages {
            out.push_str(&format!(
                "{:<7} {:>6} {:>6} {:>4} {:>8.2}% {:>8.2}% {:>8.4} {:>8}\n",
                s.name,
                if s.wbits == 0 { "fp".into() } else { s.wbits.to_string() },
                if s.abits == 0 { "fp".into() } else { s.abits.to_string() },
                if s.fq { "yes" } else { "no" },
                s.val_acc * 100.0,
                s.val_topk * 100.0,
                s.final_loss,
                s.teacher.as_deref().unwrap_or("-"),
            ));
        }
        out
    }
}

/// Stored per completed stage: parameters + the hp fields needed to run
/// its forward pass as a teacher.
struct StageArtifact {
    params: ParamSet,
    variant: Variant,
    nw: f32,
    na: f32,
    acc: f64,
}

/// Snap every `<layer>.sw` to a robust data-driven scale for the given
/// positive level count `n` (see call site above):
///
///   es = min( max|w| , 1.4 * n * mean|w| )
///
/// For ternary (n=1) this is the classic TWN threshold (the quantizer's
/// decision boundary lands at ~0.7 mean|w|); for wider codes it converges
/// to max|w|. The min() guards against per-channel dispersion: after BN
/// folding, max|w| can sit 100x above the typical weight (tiny running
/// variances in early layers), and a max-based ternary scale would round
/// almost every weight to zero — a dead network that the b=0 quantized
/// ReLU cannot recover by gradient (both x and s gradients vanish below
/// the clip). Diagnosed on the Table-4 FQ24 stage; see EXPERIMENTS.md.
pub fn calibrate_weight_scales(params: &mut ParamSet, n: f32) {
    let n = n.max(1.0);
    let names: Vec<String> = params
        .specs
        .iter()
        .filter_map(|s| s.name.strip_suffix(".sw").map(|p| p.to_string()))
        .collect();
    for prefix in names {
        if let Some(w) = params.get(&format!("{prefix}.w")) {
            let max = w.max_abs();
            let mean_abs = w.data().iter().map(|v| v.abs()).sum::<f32>() / w.len().max(1) as f32;
            let es = max.min(1.4 * n * mean_abs).max(1e-4);
            let _ = params.set_scalar(&format!("{prefix}.sw"), es.ln());
        }
    }
}

pub struct Pipeline<'a> {
    pub engine: &'a Engine,
    pub manifest: &'a Manifest,
    pub dataset: &'a dyn Dataset,
    /// flavor for QAT stages ("" = our learned quantizer; "dorefa"/"pact")
    pub flavor: &'static str,
    pub seed: u64,
    /// validation batches per evaluation
    pub eval_batches: usize,
    pub topk: usize,
    /// distillation weight when a teacher is present
    pub distill_weight: f32,
    pub weight_decay: f32,
    /// write per-stage checkpoints here if set
    pub ckpt_dir: Option<PathBuf>,
    /// per-step log callback (stage, step, loss, acc)
    pub verbose: bool,
}

impl<'a> Pipeline<'a> {
    pub fn new(engine: &'a Engine, manifest: &'a Manifest, dataset: &'a dyn Dataset) -> Self {
        Pipeline {
            engine,
            manifest,
            dataset,
            flavor: "",
            seed: 17,
            eval_batches: 8,
            topk: 5,
            distill_weight: 0.6,
            weight_decay: 5e-4,
            ckpt_dir: None,
            verbose: false,
        }
    }

    fn base_hp(&self, stage: &Stage) -> [f32; hp::LEN] {
        let mut v = hp::defaults();
        v[hp::LR] = stage.lr;
        v[hp::WEIGHT_DECAY] = self.weight_decay;
        v[hp::NW] = stage.n_levels_w();
        v[hp::NA] = stage.n_levels_a();
        v
    }

    /// Run the whole schedule. Returns the report; final stage parameters
    /// are persisted to ckpt_dir (if set) as `<model>_<stage>.ckpt`.
    pub fn run(&self, schedule: &Schedule) -> Result<PipelineReport> {
        schedule.validate()?;
        let mut rng = Rng::new(self.seed);
        let mut report = PipelineReport { model: schedule.model.clone(), ..Default::default() };
        let mut store: BTreeMap<String, StageArtifact> = BTreeMap::new();
        // one QAT trainer reused across stages; FQ trainer created lazily
        let mut qat = Trainer::new(self.engine, self.manifest, &schedule.model, Variant::Qat(self.flavor))?;
        let mut fq: Option<Trainer> = None;
        // teacher forward runs through a dedicated QAT trainer so the
        // student's parameters are untouched
        let mut teacher_rt =
            Trainer::new(self.engine, self.manifest, &schedule.model, Variant::Qat(self.flavor))?;
        let init_ck = checkpoint::read(&self.manifest.dir.join(&qat.info.init_ckpt))?;

        for stage in &schedule.stages {
            let timer = Timer::start();
            let variant = if stage.fq { Variant::Fq } else { Variant::Qat(self.flavor) };
            // --- (a) initialize --------------------------------------------------
            if stage.fq {
                if fq.is_none() {
                    fq = Some(Trainer::new(self.engine, self.manifest, &schedule.model, Variant::Fq)?);
                }
                let t = fq.as_mut().unwrap();
                let src = &store
                    .get(stage.init_from.as_ref().unwrap())
                    .context("fq init stage missing")?
                    .params;
                let fq_params =
                    fq_transform::qat_to_fq(&t.info, &t.graph, src).context("qat->fq transform")?;
                t.set_params(fq_params);
            } else {
                match &stage.init_from {
                    Some(src) => {
                        let a = store.get(src).context("init stage missing")?;
                        qat.set_params(a.params.clone());
                    }
                    None => qat.load_params(&init_ck)?,
                }
            }

            // weight-scale calibration: on entering a quantized stage, snap
            // each layer's weight log-scale to ln(max|w|) so the clip range
            // matches the trained weight distribution. Without this, e^s=1
            // vs |w|~0.1 rounds every ternary code to zero — the "too wide
            // initial quantization range collapses all values onto a single
            // quantized value" failure mode the paper calls out in §3.2.
            if stage.wbits > 0 {
                let t: &mut Trainer = if stage.fq { fq.as_mut().unwrap() } else { &mut qat };
                calibrate_weight_scales(&mut t.params, stage.n_levels_w());
            }

            // --- (b) resolve teacher ---------------------------------------------
            let teacher_name = match (schedule.policy, &stage.teacher) {
                (TeacherPolicy::PromoteBest, Some(_)) | (TeacherPolicy::PromoteBest, None) => {
                    // most accurate completed stage so far (if any)
                    store
                        .iter()
                        .filter(|(_, a)| matches!(a.variant, Variant::Qat(_)))
                        .max_by(|a, b| a.1.acc.total_cmp(&b.1.acc))
                        .map(|(n, _)| n.clone())
                        .or_else(|| stage.teacher.clone())
                }
                (TeacherPolicy::Declared, t) => t.clone(),
            };
            let teacher = teacher_name.as_ref().and_then(|n| store.get(n));
            let mut teacher_hp = hp::defaults();
            if let Some(t) = teacher {
                teacher_rt.set_params(t.params.clone());
                teacher_hp[hp::NW] = t.nw;
                teacher_hp[hp::NA] = t.na;
            }

            // --- (c) train ---------------------------------------------------------
            let mut hpv = self.base_hp(stage);
            hpv[hp::DISTILL_WEIGHT] = if teacher.is_some() { self.distill_weight } else { 0.0 };
            let t: &mut Trainer = if stage.fq { fq.as_mut().unwrap() } else { &mut qat };
            let mut last_loss = f32::NAN;
            for step in 0..stage.steps {
                let batch = self.dataset.train_batch(t.info.batch, &mut rng);
                let tlogits = match teacher {
                    Some(_) => Some(teacher_rt.forward(&batch.x, &teacher_hp)?),
                    None => None,
                };
                hpv[hp::SEED] = (self.seed as u32 ^ (step as u32 * 2654435761)) as f32;
                let stats = t.step(&batch, tlogits.as_ref(), &hpv)?;
                last_loss = stats.loss;
                if self.verbose && (step % 20 == 0 || step + 1 == stage.steps) {
                    eprintln!(
                        "[{}] {} step {:>4}/{} loss={:.4} acc={:.3}",
                        schedule.model, stage.name, step, stage.steps, stats.loss, stats.acc
                    );
                }
            }

            // --- (d) evaluate --------------------------------------------------------
            let mut eval_hp = self.base_hp(stage);
            eval_hp[hp::DISTILL_WEIGHT] = 0.0;
            let (top1, topk) =
                t.evaluate_topk(self.dataset, &eval_hp, self.eval_batches, self.topk)?;
            let result = StageResult {
                name: stage.name.clone(),
                wbits: stage.wbits,
                abits: stage.abits,
                fq: stage.fq,
                val_acc: top1,
                val_topk: topk,
                final_loss: last_loss,
                steps: stage.steps,
                seconds: timer.elapsed_s(),
                teacher: teacher_name.clone(),
            };
            if self.verbose {
                eprintln!(
                    "[{}] {} done: top1={:.2}% topk={:.2}% ({:.1}s)",
                    schedule.model,
                    stage.name,
                    top1 * 100.0,
                    topk * 100.0,
                    result.seconds
                );
            }

            // --- (e) store + persist ---------------------------------------------------
            if let Some(dir) = &self.ckpt_dir {
                let path = dir.join(format!("{}_{}.ckpt", schedule.model, stage.name));
                checkpoint::write(&path, &t.params.to_checkpoint())?;
            }
            store.insert(
                stage.name.clone(),
                StageArtifact {
                    params: t.params.clone(),
                    variant,
                    nw: stage.n_levels_w(),
                    na: stage.n_levels_a(),
                    acc: top1,
                },
            );
            report.stages.push(result);
        }
        Ok(report)
    }

    /// Final parameters of a stage re-run (convenience for examples/benches:
    /// run the schedule and return the last stage's parameters too).
    pub fn run_returning_params(
        &self,
        schedule: &Schedule,
    ) -> Result<(PipelineReport, ParamSet)> {
        // re-run with checkpointing into a temp dir if none configured
        let report = self.run(schedule)?;
        let last = schedule.stages.last().context("empty schedule")?;
        let dir = self.ckpt_dir.clone().context("run_returning_params needs ckpt_dir")?;
        let ck = checkpoint::read(&dir.join(format!("{}_{}.ckpt", schedule.model, last.name)))?;
        let info = self.manifest.model(&schedule.model)?;
        let graph = if last.fq { info.fq.clone().context("fq graph")? } else { info.qat.clone() };
        Ok((report, ParamSet::from_checkpoint(&graph, &ck)?))
    }
}
