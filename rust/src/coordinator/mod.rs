//! Layer-3 coordination: the paper's training pipeline as a system.
//!
//! * [`checkpoint`]   — FQCK1 checkpoint store (shared format with aot.py)
//! * [`params`]       — named parameter sets bound to manifest specs
//! * [`trainer`]      — drives one model's AOT train/forward artifacts
//! * [`schedule`]     — gradual-quantization stage ladders (Tables 1/4/6)
//! * [`pipeline`]     — runs a schedule end-to-end: stage init chaining,
//!                      teacher promotion, distillation orchestration
//! * [`fq_transform`] — §3.4 BN-folding QAT->FQ parameter transform

pub mod checkpoint;
pub mod fq_transform;
pub mod params;
pub mod pipeline;
pub mod schedule;
pub mod trainer;

pub use params::ParamSet;
pub use pipeline::{Pipeline, PipelineReport, StageResult};
pub use schedule::{Schedule, Stage, TeacherPolicy};
pub use trainer::{StepStats, Trainer, Variant};
