//! §3.4: transform a trained QAT network (conv + BN + ReLU + quantizers)
//! into its fully-quantized twin (FQ-Conv, no BN, quantizer-as-ReLU).
//!
//! Per fq_map rule (emitted by the python model definitions):
//!   * fold inference-mode BN into the conv weights per output channel,
//!     `w'[k,..] = w[k,..] * gamma[k] / sqrt(var[k] + eps)`. The shift beta' is dropped — the paper finds it "doesn't contribute
//!     much to overall accuracy if we train the network to adapt", which
//!     is exactly what the FQ fine-tune stage does.
//!   * output quantizer scale `so` <- the QAT activation scale `sa`
//!     (the quantizer that used to sit after BN+ReLU);
//!   * input scale `sa` <- the predecessor's activation scale (the grid
//!     the incoming activations already live on);
//!   * weight scale `sw` <- QAT `sw`, shifted by the log-ratio of
//!     max-|w| after/before folding so the folded weights still span the
//!     quantizer range (the per-layer part of "absorb the BN scale into
//!     the quantization scale"; the per-channel remainder is what the
//!     fine-tune absorbs).
//!
//! Every parameter whose name exists identically in both graphs (embed
//! layer, heads, `.sadd` scales, `input.s`) is copied verbatim first.

use anyhow::{Context, Result};

use crate::runtime::{GraphSpec, ModelInfo};

use super::params::ParamSet;

pub const BN_EPS: f32 = 1e-5;

/// Build FQ parameters from trained QAT parameters.
pub fn qat_to_fq(info: &ModelInfo, fq_graph: &GraphSpec, qat: &ParamSet) -> Result<ParamSet> {
    let mut fq = ParamSet::zeros(fq_graph);

    // 1. verbatim copies for shared names
    for i in 0..fq.specs.len() {
        let name = fq.specs[i].name.clone();
        if let Some(src) = qat.get(&name) {
            if src.shape() == fq.specs[i].shape.as_slice() {
                fq.values[i] = src.clone();
            }
        }
    }

    // 2. per-rule BN folding + scale wiring
    for rule in &info.fq_map {
        let wname_q = format!("{}.w", rule.qat);
        let w = qat.get(&wname_q).with_context(|| format!("qat missing {wname_q}"))?;
        let mut wv = w.clone();
        if rule.bn {
            let gamma = qat
                .get(&format!("{}.bn.gamma", rule.qat))
                .with_context(|| format!("qat missing {}.bn.gamma", rule.qat))?;
            let var = qat
                .get(&format!("{}.bn.var", rule.qat))
                .with_context(|| format!("qat missing {}.bn.var", rule.qat))?;
            let cout = wv.shape()[0];
            let per = wv.len() / cout;
            let data = wv.data_mut();
            for k in 0..cout {
                let g = gamma.data()[k] / (var.data()[k] + BN_EPS).sqrt();
                for v in &mut data[k * per..(k + 1) * per] {
                    *v *= g;
                }
            }
        }
        // weight scale shift: keep folded weights spanning the clip range
        let sw_q = qat.scalar(&format!("{}.sw", rule.qat))?;
        let before = w.max_abs().max(1e-8);
        let after = wv.max_abs().max(1e-8);
        let sw_fq = sw_q + (after / before).ln();

        let wname_f = format!("{}.w", rule.fq);
        *fq.get_mut(&wname_f).with_context(|| format!("fq missing {wname_f}"))? = wv;
        fq.set_scalar(&format!("{}.sw", rule.fq), sw_fq)?;
        // output grid = the QAT block's activation quantizer
        let sa_q = qat.scalar(&format!("{}.sa", rule.qat))?;
        fq.set_scalar(&format!("{}.so", rule.fq), sa_q)?;
        // input grid = predecessor's output quantizer
        let pred = qat.scalar(&rule.pred_scale)?;
        fq.set_scalar(&format!("{}.sa", rule.fq), pred)?;
    }
    Ok(fq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{FqRule, TensorSpec};
    use crate::tensor::TensorF;

    fn toy() -> (ModelInfo, GraphSpec, ParamSet) {
        let qat_graph = GraphSpec {
            trainable: vec![
                TensorSpec { name: "input.s".into(), shape: vec![] },
                TensorSpec { name: "c.w".into(), shape: vec![2, 1, 1, 1] },
                TensorSpec { name: "c.bn.gamma".into(), shape: vec![2] },
                TensorSpec { name: "c.bn.beta".into(), shape: vec![2] },
                TensorSpec { name: "c.sw".into(), shape: vec![] },
                TensorSpec { name: "c.sa".into(), shape: vec![] },
            ],
            state: vec![
                TensorSpec { name: "c.bn.mean".into(), shape: vec![2] },
                TensorSpec { name: "c.bn.var".into(), shape: vec![2] },
            ],
            opt: vec![],
            param_count: 2,
        };
        let fq_graph = GraphSpec {
            trainable: vec![
                TensorSpec { name: "input.s".into(), shape: vec![] },
                TensorSpec { name: "c.w".into(), shape: vec![2, 1, 1, 1] },
                TensorSpec { name: "c.sw".into(), shape: vec![] },
                TensorSpec { name: "c.sa".into(), shape: vec![] },
                TensorSpec { name: "c.so".into(), shape: vec![] },
            ],
            state: vec![],
            opt: vec![],
            param_count: 2,
        };
        let mut qat = ParamSet::zeros(&qat_graph);
        *qat.get_mut("c.w").unwrap() = TensorF::from_vec(&[2, 1, 1, 1], vec![1.0, -2.0]);
        *qat.get_mut("c.bn.gamma").unwrap() = TensorF::from_vec(&[2], vec![2.0, 0.5]);
        *qat.get_mut("c.bn.var").unwrap() = TensorF::from_vec(&[2], vec![1.0, 1.0]);
        qat.set_scalar("input.s", -0.3).unwrap();
        qat.set_scalar("c.sw", 0.1).unwrap();
        qat.set_scalar("c.sa", 0.7).unwrap();
        let info = ModelInfo {
            name: "toy".into(),
            kind: "resnet".into(),
            batch: 1,
            input_shape: vec![1, 1, 1],
            num_classes: 2,
            opt_kind: "sgd".into(),
            macs_per_sample: 0,
            qat: qat_graph,
            fq: Some(fq_graph.clone()),
            fq_map: vec![FqRule {
                fq: "c".into(),
                qat: "c".into(),
                pred_scale: "input.s".into(),
                bn: true,
            }],
            artifacts: Default::default(),
            init_ckpt: String::new(),
        };
        (info, fq_graph, qat)
    }

    #[test]
    fn folds_bn_per_channel() {
        let (info, fq_graph, qat) = toy();
        let fq = qat_to_fq(&info, &fq_graph, &qat).unwrap();
        let w = fq.get("c.w").unwrap().data();
        // gamma/sqrt(var+eps) = [2.0, 0.5] (var=1, eps tiny)
        assert!((w[0] - 2.0).abs() < 1e-3, "w0={}", w[0]);
        assert!((w[1] + 1.0).abs() < 1e-3, "w1={}", w[1]);
    }

    #[test]
    fn wires_scales() {
        let (info, fq_graph, qat) = toy();
        let fq = qat_to_fq(&info, &fq_graph, &qat).unwrap();
        assert_eq!(fq.scalar("c.so").unwrap(), 0.7); // <- qat c.sa
        assert_eq!(fq.scalar("c.sa").unwrap(), -0.3); // <- input.s
        assert_eq!(fq.scalar("input.s").unwrap(), -0.3); // verbatim copy
        // sw shifted by ln(maxabs_after / maxabs_before) = ln(2/2)=0 => ~0.1
        // before fold max|w|=2, after fold max|w'|=2 => unchanged
        assert!((fq.scalar("c.sw").unwrap() - 0.1).abs() < 1e-4);
    }

    #[test]
    fn missing_rule_tensor_errors() {
        let (mut info, fq_graph, qat) = toy();
        info.fq_map[0].qat = "nope".into();
        assert!(qat_to_fq(&info, &fq_graph, &qat).is_err());
    }
}
