//! FQCK1 checkpoint format — mirror of python/compile/ckpt.py.
//!
//! Layout (little-endian):
//!   magic "FQCK1\n" | u32 count | per tensor:
//!   u16 name_len | name | u8 ndim | u32*ndim dims | f32*numel data

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::TensorF;

pub const MAGIC: &[u8; 6] = b"FQCK1\n";

/// An ordered set of named tensors (order matters: it is spec order).
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub tensors: Vec<(String, TensorF)>,
    index: BTreeMap<String, usize>,
}

impl Checkpoint {
    pub fn new(tensors: Vec<(String, TensorF)>) -> Self {
        let index = tensors.iter().enumerate().map(|(i, (n, _))| (n.clone(), i)).collect();
        Checkpoint { tensors, index }
    }

    pub fn get(&self, name: &str) -> Option<&TensorF> {
        self.index.get(name).map(|&i| &self.tensors[i].1)
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }
}

pub fn read(path: &Path) -> Result<Checkpoint> {
    let mut f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    parse(&buf).with_context(|| format!("parsing checkpoint {}", path.display()))
}

pub fn parse(buf: &[u8]) -> Result<Checkpoint> {
    if buf.len() < 10 || &buf[..6] != MAGIC {
        bail!("bad FQCK magic");
    }
    let mut off = 6;
    let count = u32::from_le_bytes(buf[off..off + 4].try_into()?) as usize;
    off += 4;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        if off + 2 > buf.len() {
            bail!("truncated checkpoint (name len)");
        }
        let nlen = u16::from_le_bytes(buf[off..off + 2].try_into()?) as usize;
        off += 2;
        let name = std::str::from_utf8(&buf[off..off + nlen])?.to_string();
        off += nlen;
        let ndim = buf[off] as usize;
        off += 1;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            dims.push(u32::from_le_bytes(buf[off..off + 4].try_into()?) as usize);
            off += 4;
        }
        let numel: usize = dims.iter().product();
        let need = numel * 4;
        if off + need > buf.len() {
            bail!("truncated checkpoint (tensor {name} data)");
        }
        let mut data = vec![0f32; numel];
        for (i, chunk) in buf[off..off + need].chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes(chunk.try_into()?);
        }
        off += need;
        tensors.push((name, TensorF::from_vec(&dims, data)));
    }
    Ok(Checkpoint::new(tensors))
}

pub fn write(path: &Path, ck: &Checkpoint) -> Result<()> {
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(MAGIC);
    out.extend_from_slice(&(ck.tensors.len() as u32).to_le_bytes());
    for (name, t) in &ck.tensors {
        let nb = name.as_bytes();
        out.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        out.extend_from_slice(nb);
        out.push(t.ndim() as u8);
        for &d in t.shape() {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        for &v in t.data() {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).ok();
    }
    let mut f =
        std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    f.write_all(&out)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint::new(vec![
            ("a.w".into(), TensorF::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.])),
            ("a.s".into(), TensorF::scalar(-0.5)),
        ]);
        let dir = std::env::temp_dir().join("fqck_test");
        let path = dir.join("t.ckpt");
        write(&path, &ck).unwrap();
        let ck2 = read(&path).unwrap();
        assert_eq!(ck2.len(), 2);
        assert_eq!(ck2.get("a.w").unwrap().data(), ck.get("a.w").unwrap().data());
        assert_eq!(ck2.get("a.s").unwrap().shape(), &[] as &[usize]);
        assert_eq!(ck2.tensors[0].0, "a.w"); // order preserved
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(parse(b"NOTCK1\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn rejects_truncation() {
        let ck = Checkpoint::new(vec![("x".into(), TensorF::from_vec(&[4], vec![0.; 4]))]);
        let dir = std::env::temp_dir().join("fqck_test2");
        let path = dir.join("t.ckpt");
        write(&path, &ck).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(parse(&bytes[..bytes.len() - 3]).is_err());
    }
}
