//! Named parameter sets bound to manifest tensor specs.
//!
//! A [`ParamSet`] is the coordinator's host-side view of one graph
//! family's `trainable + state` tensors, in exactly the positional order
//! the lowered artifact expects.

use anyhow::{bail, Result};

use crate::runtime::{GraphSpec, TensorSpec};
use crate::tensor::TensorF;
use crate::util::Rng;

use super::checkpoint::Checkpoint;

#[derive(Clone, Debug)]
pub struct ParamSet {
    pub specs: Vec<TensorSpec>,
    pub values: Vec<TensorF>,
    /// number of leading trainable tensors (rest is state)
    pub n_trainable: usize,
}

impl ParamSet {
    /// Allocate zeros matching a graph spec (trainable then state).
    pub fn zeros(graph: &GraphSpec) -> Self {
        let specs: Vec<TensorSpec> = graph.all_specs().cloned().collect();
        let values = specs.iter().map(|s| TensorF::zeros(&s.shape)).collect();
        ParamSet { specs, values, n_trainable: graph.trainable.len() }
    }

    /// Load from a checkpoint; every spec must be present with the right shape.
    pub fn from_checkpoint(graph: &GraphSpec, ck: &Checkpoint) -> Result<Self> {
        let mut ps = Self::zeros(graph);
        for (i, spec) in ps.specs.iter().enumerate() {
            match ck.get(&spec.name) {
                Some(t) if t.shape() == spec.shape.as_slice() => ps.values[i] = t.clone(),
                Some(t) => bail!(
                    "checkpoint tensor {} shape {:?} != spec {:?}",
                    spec.name,
                    t.shape(),
                    spec.shape
                ),
                None => bail!("checkpoint missing tensor {}", spec.name),
            }
        }
        Ok(ps)
    }

    pub fn to_checkpoint(&self) -> Checkpoint {
        Checkpoint::new(
            self.specs.iter().zip(&self.values).map(|(s, v)| (s.name.clone(), v.clone())).collect(),
        )
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.specs.iter().position(|s| s.name == name)
    }

    pub fn get(&self, name: &str) -> Option<&TensorF> {
        self.index_of(name).map(|i| &self.values[i])
    }

    pub fn get_mut(&mut self, name: &str) -> Option<&mut TensorF> {
        self.index_of(name).map(move |i| &mut self.values[i])
    }

    /// Scalar parameter value (quantizer log-scales etc.).
    pub fn scalar(&self, name: &str) -> Result<f32> {
        match self.get(name) {
            Some(t) if t.len() == 1 => Ok(t.data()[0]),
            Some(t) => bail!("{name} is not scalar ({:?})", t.shape()),
            None => bail!("no parameter {name}"),
        }
    }

    pub fn set_scalar(&mut self, name: &str, v: f32) -> Result<()> {
        match self.get_mut(name) {
            Some(t) if t.len() == 1 => {
                t.data_mut()[0] = v;
                Ok(())
            }
            Some(_) => bail!("{name} is not scalar"),
            None => bail!("no parameter {name}"),
        }
    }

    /// Total element count (all tensors).
    pub fn numel(&self) -> usize {
        self.values.iter().map(|t| t.len()).sum()
    }

    /// Random He-style re-initialization (used by tests and ablations).
    pub fn randomize(&mut self, rng: &mut Rng) {
        for (spec, t) in self.specs.iter().zip(self.values.iter_mut()) {
            if spec.name.ends_with(".w") {
                let fan_in: usize = spec.shape.iter().skip(1).product::<usize>().max(1);
                let std = (2.0 / fan_in as f32).sqrt();
                rng.fill_gaussian(t.data_mut(), std);
            } else if spec.name.contains(".bn.var") {
                t.data_mut().fill(1.0);
            } else if spec.name.contains(".bn.gamma") {
                t.data_mut().fill(1.0);
            } else {
                t.data_mut().fill(0.0);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::GraphSpec;

    fn toy_graph() -> GraphSpec {
        GraphSpec {
            trainable: vec![
                TensorSpec { name: "a.w".into(), shape: vec![4, 3] },
                TensorSpec { name: "a.s".into(), shape: vec![] },
            ],
            state: vec![TensorSpec { name: "a.bn.mean".into(), shape: vec![4] }],
            opt: vec![vec![4, 3], vec![]],
            param_count: 12,
        }
    }

    #[test]
    fn zeros_layout() {
        let ps = ParamSet::zeros(&toy_graph());
        assert_eq!(ps.specs.len(), 3);
        assert_eq!(ps.n_trainable, 2);
        assert_eq!(ps.numel(), 12 + 1 + 4);
    }

    #[test]
    fn scalar_access() {
        let mut ps = ParamSet::zeros(&toy_graph());
        ps.set_scalar("a.s", -0.7).unwrap();
        assert_eq!(ps.scalar("a.s").unwrap(), -0.7);
        assert!(ps.scalar("a.w").is_err());
        assert!(ps.scalar("nope").is_err());
    }

    #[test]
    fn checkpoint_roundtrip() {
        let mut ps = ParamSet::zeros(&toy_graph());
        ps.get_mut("a.w").unwrap().data_mut()[5] = 3.5;
        let ck = ps.to_checkpoint();
        let ps2 = ParamSet::from_checkpoint(&toy_graph(), &ck).unwrap();
        assert_eq!(ps2.get("a.w").unwrap().data()[5], 3.5);
    }

    #[test]
    fn from_checkpoint_rejects_shape_mismatch() {
        let ck = Checkpoint::new(vec![
            ("a.w".into(), TensorF::zeros(&[4, 2])),
            ("a.s".into(), TensorF::scalar(0.0)),
            ("a.bn.mean".into(), TensorF::zeros(&[4])),
        ]);
        assert!(ParamSet::from_checkpoint(&toy_graph(), &ck).is_err());
    }
}
