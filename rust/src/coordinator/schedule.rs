//! Gradual-quantization schedules (§3.2): the stage ladders of
//! Tables 1, 4 and 6 as data, plus validation and the Fig.-1 renderer.
//!
//! A stage names its initializing network and its teacher by *stage
//! name* — exactly how the paper's tables specify them ("Init. net",
//! "Trainer net"). `Schedule::validate` checks the reference DAG is
//! legal (references resolve to strictly earlier stages; bitwidths only
//! decrease along init chains; FQ stages initialize from a same-bitwidth
//! QAT stage).

use anyhow::{bail, Result};

/// One training stage of the ladder.
#[derive(Clone, Debug)]
pub struct Stage {
    pub name: String,
    /// weight bits; 0 = full precision
    pub wbits: u32,
    /// activation bits; 0 = full precision
    pub abits: u32,
    /// stage whose final parameters initialize this one (None = random init)
    pub init_from: Option<String>,
    /// distillation teacher stage (None = no distillation)
    pub teacher: Option<String>,
    /// fully-quantized fine-tune stage (BN removed, §3.4)
    pub fq: bool,
    pub steps: usize,
    pub lr: f32,
}

impl Stage {
    pub fn new(name: &str, wbits: u32, abits: u32) -> Self {
        Stage {
            name: name.into(),
            wbits,
            abits,
            init_from: None,
            teacher: None,
            fq: false,
            steps: 200,
            lr: 0.01,
        }
    }

    pub fn from(mut self, init: &str) -> Self {
        self.init_from = Some(init.into());
        self
    }

    pub fn taught_by(mut self, teacher: &str) -> Self {
        self.teacher = Some(teacher.into());
        self
    }

    pub fn fq(mut self) -> Self {
        self.fq = true;
        self
    }

    pub fn steps(mut self, n: usize) -> Self {
        self.steps = n;
        self
    }

    pub fn lr(mut self, lr: f32) -> Self {
        self.lr = lr;
        self
    }

    /// Positive level count for the hp vector (0 disables quantization).
    pub fn n_levels_w(&self) -> f32 {
        if self.wbits == 0 { 0.0 } else { ((1u32 << (self.wbits - 1)) - 1) as f32 }
    }

    pub fn n_levels_a(&self) -> f32 {
        if self.abits == 0 { 0.0 } else { ((1u32 << (self.abits - 1)) - 1) as f32 }
    }

    fn bits_label(&self) -> String {
        let b = |v: u32| if v == 0 { "fp".to_string() } else { v.to_string() };
        format!("W{}/A{}", b(self.wbits), b(self.abits))
    }
}

/// How the pipeline picks teachers when a stage doesn't name one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TeacherPolicy {
    /// use exactly what each stage declares
    Declared,
    /// paper §4.2: "each time we obtained a more accurate network ...
    /// the more accurate network became the teacher"
    PromoteBest,
}

#[derive(Clone, Debug)]
pub struct Schedule {
    pub model: String,
    pub stages: Vec<Stage>,
    pub policy: TeacherPolicy,
}

impl Schedule {
    pub fn new(model: &str, stages: Vec<Stage>, policy: TeacherPolicy) -> Result<Self> {
        let s = Schedule { model: model.into(), stages, policy };
        s.validate()?;
        Ok(s)
    }

    pub fn stage(&self, name: &str) -> Option<&Stage> {
        self.stages.iter().find(|s| s.name == name)
    }

    /// DAG legality + monotone-bitwidth checks.
    pub fn validate(&self) -> Result<()> {
        for (i, st) in self.stages.iter().enumerate() {
            if self.stages.iter().take(i).any(|p| p.name == st.name) {
                bail!("duplicate stage name {}", st.name);
            }
            for (what, r) in [("init_from", &st.init_from), ("teacher", &st.teacher)] {
                if let Some(name) = r {
                    let pos = self.stages.iter().position(|p| &p.name == name);
                    match pos {
                        None => bail!("stage {}: {what} references unknown stage {name}", st.name),
                        Some(p) if p >= i => {
                            bail!("stage {}: {what} must reference an earlier stage", st.name)
                        }
                        _ => {}
                    }
                }
            }
            if let Some(init) = &st.init_from {
                let p = self.stage(init).unwrap();
                // bitwidth must not increase along the init chain
                // (fp = 0 means "unconstrained"; fp can follow quantized, Table 1 FP1)
                let dec = |prev: u32, cur: u32| cur == 0 || prev == 0 || cur <= prev;
                if !dec(p.wbits, st.wbits) || !dec(p.abits, st.abits) {
                    bail!(
                        "stage {}: bitwidth increases from init {} ({} -> {})",
                        st.name,
                        init,
                        p.bits_label(),
                        st.bits_label()
                    );
                }
                if st.fq && !(p.wbits == st.wbits && p.abits == st.abits) {
                    bail!("FQ stage {} must init from same-bitwidth QAT stage", st.name);
                }
            } else if st.fq {
                bail!("FQ stage {} needs an init_from (trained QAT parameters)", st.name);
            }
        }
        Ok(())
    }

    /// ASCII rendering of the ladder — the Fig.-1 regenerator.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "Gradual quantization schedule — model {} ({:?})\n",
            self.model, self.policy
        ));
        for st in &self.stages {
            let init = st.init_from.as_deref().unwrap_or("random");
            let teach = st.teacher.as_deref().unwrap_or("-");
            out.push_str(&format!(
                "  {:<6} [{}{}]  init<-{:<6} teacher<-{:<6} steps={} lr={}\n",
                st.name,
                st.bits_label(),
                if st.fq { ", FQ" } else { "" },
                init,
                teach,
                st.steps,
                st.lr,
            ));
        }
        // chain arrows
        out.push_str("  chain: ");
        for (i, st) in self.stages.iter().enumerate() {
            if i > 0 {
                out.push_str(" -> ");
            }
            out.push_str(&st.name);
        }
        out.push('\n');
        out
    }

    // -----------------------------------------------------------------------
    // Paper ladders (steps/lr scaled per workload by the callers)
    // -----------------------------------------------------------------------

    /// Table 1: ResNet-20 on CIFAR-10. FP0 -> Q88 -> FP1 -> Q66..Q22,
    /// each quantized stage initialized from the previous, taught by FP1.
    pub fn table1(model: &str, steps: usize, lr: f32) -> Schedule {
        let s = |n: &str, w, a| Stage::new(n, w, a).steps(steps).lr(lr);
        Schedule::new(
            model,
            vec![
                s("FP0", 0, 0),
                s("Q88", 8, 8).from("FP0").taught_by("FP0"),
                s("FP1", 0, 0).from("Q88").taught_by("Q88"),
                s("Q66", 6, 6).from("Q88").taught_by("FP1"),
                s("Q55", 5, 5).from("Q66").taught_by("FP1"),
                s("Q44", 4, 4).from("Q55").taught_by("FP1"),
                s("Q33", 3, 3).from("Q44").taught_by("FP1"),
                s("Q22", 2, 2).from("Q33").taught_by("FP1"),
            ],
            TeacherPolicy::Declared,
        )
        .expect("table1 schedule valid")
    }

    /// The no-GQ ablation of Table 1: FP0 -> Qkk directly (teacher FP0).
    pub fn table1_no_gq(model: &str, wbits: u32, abits: u32, steps: usize, lr: f32) -> Schedule {
        let name = format!("Q{wbits}{abits}");
        Schedule::new(
            model,
            vec![
                Stage::new("FP0", 0, 0).steps(steps).lr(lr),
                Stage::new(&name, wbits, abits).from("FP0").taught_by("FP0").steps(steps).lr(lr),
            ],
            TeacherPolicy::Declared,
        )
        .expect("no-gq schedule valid")
    }

    /// Table 4: the KWS ladder FP -> Q66 -> Q45 -> Q35 -> Q24 -> FQ24.
    pub fn table4_kws(steps: usize, lr: f32) -> Schedule {
        let s = |n: &str, w, a| Stage::new(n, w, a).steps(steps).lr(lr);
        // FQ fine-tune: removing BN drops the per-channel shift, which the
        // retrain has to absorb (§3.4) — it gets a longer, slightly hotter
        // schedule than the paper's epoch-rich setting would need.
        Schedule::new(
            "kws",
            vec![
                s("FP", 0, 0),
                s("Q66", 6, 6).from("FP").taught_by("FP"),
                s("Q45", 4, 5).from("Q66").taught_by("Q66"),
                s("Q35", 3, 5).from("Q45").taught_by("Q45"),
                s("Q24", 2, 4).from("Q35").taught_by("Q45"),
                s("FQ24", 2, 4).from("Q24").taught_by("Q45").fq().lr(lr * 0.2).steps(steps * 2),
            ],
            TeacherPolicy::PromoteBest,
        )
        .expect("table4 schedule valid")
    }

    /// Table 6: ResNet-32 on CIFAR-100 ladder incl. the FQ25 fine-tune.
    pub fn table6(model: &str, steps: usize, lr: f32) -> Schedule {
        let s = |n: &str, w, a| Stage::new(n, w, a).steps(steps).lr(lr);
        Schedule::new(
            model,
            vec![
                s("FP0", 0, 0).lr(lr * 10.0),
                s("Q88", 8, 8).from("FP0").taught_by("FP0"),
                s("FP1", 0, 0).from("Q88").taught_by("Q88"),
                s("Q66", 6, 6).from("Q88").taught_by("FP1"),
                s("Q55", 5, 5).from("Q66").taught_by("FP1"),
                s("Q45", 4, 5).from("Q55").taught_by("FP1"),
                s("Q35", 3, 5).from("Q45").taught_by("FP1"),
                s("Q25", 2, 5).from("Q35").taught_by("FP1"),
                s("FQ25", 2, 5).from("Q25").taught_by("FP1").fq(),
            ],
            TeacherPolicy::Declared,
        )
        .expect("table6 schedule valid")
    }

    /// Table 3: the DarkNet ladder Q88 -> ... -> Q25 (teacher = FP stage;
    /// the paper used a ResNet-50 teacher + label refinery, see DESIGN.md §4).
    pub fn table3_darknet(steps: usize, lr: f32) -> Schedule {
        let s = |n: &str, w, a| Stage::new(n, w, a).steps(steps).lr(lr);
        Schedule::new(
            "darknet_tiny",
            vec![
                s("FP0", 0, 0),
                s("Q88", 8, 8).from("FP0").taught_by("FP0"),
                s("Q77", 7, 7).from("Q88").taught_by("FP0"),
                s("Q66", 6, 6).from("Q77").taught_by("FP0"),
                s("Q55", 5, 5).from("Q66").taught_by("FP0"),
                s("Q45", 4, 5).from("Q55").taught_by("FP0"),
                s("Q35", 3, 5).from("Q45").taught_by("FP0"),
                s("Q25", 2, 5).from("Q35").taught_by("FP0"),
            ],
            TeacherPolicy::Declared,
        )
        .expect("table3 schedule valid")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ladders_validate() {
        Schedule::table1("resnet20", 10, 0.01);
        Schedule::table4_kws(10, 0.01);
        Schedule::table6("resnet32", 10, 0.001);
        Schedule::table3_darknet(10, 0.01);
    }

    #[test]
    fn rejects_forward_reference() {
        let r = Schedule::new(
            "m",
            vec![Stage::new("A", 0, 0).from("B"), Stage::new("B", 8, 8)],
            TeacherPolicy::Declared,
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_bitwidth_increase() {
        let r = Schedule::new(
            "m",
            vec![Stage::new("Q22", 2, 2), Stage::new("Q88", 8, 8).from("Q22")],
            TeacherPolicy::Declared,
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_duplicate_names() {
        let r = Schedule::new(
            "m",
            vec![Stage::new("A", 0, 0), Stage::new("A", 8, 8)],
            TeacherPolicy::Declared,
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_fq_without_init() {
        let r = Schedule::new("m", vec![Stage::new("FQ", 2, 4).fq()], TeacherPolicy::Declared);
        assert!(r.is_err());
    }

    #[test]
    fn rejects_fq_bitwidth_change() {
        let r = Schedule::new(
            "m",
            vec![Stage::new("Q24", 2, 4), Stage::new("FQ22", 2, 2).from("Q24").fq()],
            TeacherPolicy::Declared,
        );
        assert!(r.is_err());
    }

    #[test]
    fn levels() {
        let s = Stage::new("Q24", 2, 4);
        assert_eq!(s.n_levels_w(), 1.0);
        assert_eq!(s.n_levels_a(), 7.0);
        assert_eq!(Stage::new("FP", 0, 0).n_levels_w(), 0.0);
    }

    #[test]
    fn render_mentions_all_stages() {
        let s = Schedule::table4_kws(10, 0.01);
        let r = s.render();
        for st in &s.stages {
            assert!(r.contains(&st.name));
        }
    }
}
