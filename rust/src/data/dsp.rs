//! DSP substrate for the KWS front end — from scratch, no crates.
//!
//! Implements the paper's preprocessing: "39-dimensional Mel-Frequency
//! Cepstrum Coefficients (13 MFCCs and their first- and second-order
//! deltas) constructed using 20ms sliding window, shifted by 10ms".
//!
//! Pipeline per frame: Hann window -> radix-2 FFT -> power spectrum ->
//! mel filterbank -> log -> DCT-II (13 coeffs); then Δ and ΔΔ over frames
//! with the standard 2-tap regression kernel.

use std::f32::consts::PI;

/// In-place iterative radix-2 Cooley-Tukey FFT. `re`/`im` length must be a
/// power of two.
pub fn fft(re: &mut [f32], im: &mut [f32]) {
    let n = re.len();
    assert_eq!(n, im.len());
    assert!(n.is_power_of_two(), "fft length {n} not a power of two");
    // bit-reversal permutation
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = (i as u32).reverse_bits() >> (32 - bits);
        let j = j as usize;
        if j > i {
            re.swap(i, j);
            im.swap(i, j);
        }
    }
    let mut len = 2;
    while len <= n {
        let ang = -2.0 * PI / len as f32;
        let (wr, wi) = (ang.cos(), ang.sin());
        for start in (0..n).step_by(len) {
            let (mut cr, mut ci) = (1.0f32, 0.0f32);
            for k in 0..len / 2 {
                let a = start + k;
                let b = a + len / 2;
                let tr = re[b] * cr - im[b] * ci;
                let ti = re[b] * ci + im[b] * cr;
                re[b] = re[a] - tr;
                im[b] = im[a] - ti;
                re[a] += tr;
                im[a] += ti;
                let ncr = cr * wr - ci * wi;
                ci = cr * wi + ci * wr;
                cr = ncr;
            }
        }
        len <<= 1;
    }
}

/// Hann window of length n.
pub fn hann(n: usize) -> Vec<f32> {
    (0..n).map(|i| 0.5 - 0.5 * (2.0 * PI * i as f32 / n as f32).cos()).collect()
}

fn hz_to_mel(hz: f32) -> f32 {
    2595.0 * (1.0 + hz / 700.0).log10()
}

fn mel_to_hz(mel: f32) -> f32 {
    700.0 * (10f32.powf(mel / 2595.0) - 1.0)
}

/// Triangular mel filterbank: `n_filters` x (nfft/2+1) weights.
pub fn mel_filterbank(n_filters: usize, nfft: usize, sample_rate: f32) -> Vec<Vec<f32>> {
    let nyquist = sample_rate / 2.0;
    let mel_max = hz_to_mel(nyquist);
    let mel_pts: Vec<f32> =
        (0..n_filters + 2).map(|i| mel_to_hz(mel_max * i as f32 / (n_filters + 1) as f32)).collect();
    let bin_of = |hz: f32| (hz / nyquist * (nfft as f32 / 2.0)).floor() as usize;
    let bins: Vec<usize> = mel_pts.iter().map(|&hz| bin_of(hz).min(nfft / 2)).collect();
    let mut bank = vec![vec![0.0f32; nfft / 2 + 1]; n_filters];
    for f in 0..n_filters {
        let (lo, mid, hi) = (bins[f], bins[f + 1], bins[f + 2]);
        for b in lo..mid.max(lo + 1) {
            bank[f][b] = (b - lo) as f32 / (mid - lo).max(1) as f32;
        }
        for b in mid..hi.max(mid + 1) {
            if b <= nfft / 2 {
                bank[f][b] = 1.0 - (b - mid) as f32 / (hi - mid).max(1) as f32;
            }
        }
    }
    bank
}

/// DCT-II matrix (orthonormal), `n_out` x `n_in`.
pub fn dct_matrix(n_out: usize, n_in: usize) -> Vec<Vec<f32>> {
    let mut m = vec![vec![0.0f32; n_in]; n_out];
    for (k, row) in m.iter_mut().enumerate() {
        let norm = if k == 0 { (1.0 / n_in as f32).sqrt() } else { (2.0 / n_in as f32).sqrt() };
        for (i, v) in row.iter_mut().enumerate() {
            *v = norm * (PI / n_in as f32 * (i as f32 + 0.5) * k as f32).cos();
        }
    }
    m
}

/// MFCC extractor configuration.
#[derive(Clone, Debug)]
pub struct MfccConfig {
    pub sample_rate: f32,
    pub win: usize,
    pub hop: usize,
    pub nfft: usize,
    pub n_mels: usize,
    pub n_mfcc: usize,
}

impl Default for MfccConfig {
    /// Paper settings at 4 kHz: 20 ms window (80 samples), 10 ms hop (40).
    fn default() -> Self {
        MfccConfig { sample_rate: 4000.0, win: 80, hop: 40, nfft: 128, n_mels: 20, n_mfcc: 13 }
    }
}

/// Reusable per-frame scratch (FFT buffers, power spectrum, log-mel
/// energies) for the allocation-free MFCC paths. Obtain one sized to an
/// extractor via [`Mfcc::scratch`].
pub struct MfccScratch {
    re: Vec<f32>,
    im: Vec<f32>,
    power: Vec<f32>,
    mels: Vec<f32>,
}

/// Precomputed MFCC pipeline.
pub struct Mfcc {
    pub cfg: MfccConfig,
    window: Vec<f32>,
    bank: Vec<Vec<f32>>,
    dct: Vec<Vec<f32>>,
}

impl Mfcc {
    pub fn new(cfg: MfccConfig) -> Self {
        assert!(cfg.nfft >= cfg.win);
        Mfcc {
            window: hann(cfg.win),
            bank: mel_filterbank(cfg.n_mels, cfg.nfft, cfg.sample_rate),
            dct: dct_matrix(cfg.n_mfcc, cfg.n_mels),
            cfg,
        }
    }

    /// Number of frames for a signal of `n` samples.
    pub fn frames_for(&self, n: usize) -> usize {
        if n < self.cfg.win {
            0
        } else {
            (n - self.cfg.win) / self.cfg.hop + 1
        }
    }

    /// Samples required to produce exactly `frames` frames.
    pub fn samples_for_frames(&self, frames: usize) -> usize {
        (frames - 1) * self.cfg.hop + self.cfg.win
    }

    /// Pre-sized per-frame scratch for the allocation-free paths.
    pub fn scratch(&self) -> MfccScratch {
        MfccScratch {
            re: vec![0.0; self.cfg.nfft],
            im: vec![0.0; self.cfg.nfft],
            power: vec![0.0; self.cfg.nfft / 2 + 1],
            mels: vec![0.0; self.cfg.n_mels],
        }
    }

    /// One analysis frame up to the log-mel energies: window, FFT,
    /// power spectrum, filterbank, log — leaves the result in
    /// `scr.mels`. Shared by [`Mfcc::compute_into`] and
    /// [`Mfcc::frame_into`] so the per-frame op sequence (and thus the
    /// f32 result) cannot diverge between the offline and streaming
    /// paths.
    fn mel_frame(&self, window: &[f32], scr: &mut MfccScratch) {
        debug_assert_eq!(window.len(), self.cfg.win);
        let half = self.cfg.nfft / 2 + 1;
        scr.re[..self.cfg.win]
            .iter_mut()
            .zip(window)
            .zip(&self.window)
            .for_each(|((r, &s), &w)| *r = s * w);
        scr.re[self.cfg.win..].fill(0.0);
        scr.im.fill(0.0);
        fft(&mut scr.re, &mut scr.im);
        for b in 0..half {
            scr.power[b] = scr.re[b] * scr.re[b] + scr.im[b] * scr.im[b];
        }
        for (f, filt) in self.bank.iter().enumerate() {
            let e: f32 = filt.iter().zip(&scr.power).map(|(&w, &p)| w * p).sum();
            scr.mels[f] = (e + 1e-10).ln();
        }
    }

    /// One frame for the streaming front end: exactly `win` samples →
    /// `n_mfcc` contiguous coefficients. Each coefficient is the same
    /// f32 expression [`Mfcc::compute`] writes (strided) into its
    /// output column, so streamed frames are bit-identical to offline
    /// columns.
    pub fn frame_into(&self, window: &[f32], scr: &mut MfccScratch, coeffs: &mut [f32]) {
        assert_eq!(window.len(), self.cfg.win, "window size");
        assert_eq!(coeffs.len(), self.cfg.n_mfcc, "coefficient buffer size");
        self.mel_frame(window, scr);
        for (k, row) in self.dct.iter().enumerate() {
            coeffs[k] = row.iter().zip(&scr.mels).map(|(&d, &m)| d * m).sum();
        }
    }

    /// Allocation-free [`Mfcc::compute`]: the row-major (n_mfcc,
    /// frames) matrix into a caller-owned buffer with caller-owned
    /// scratch — per-frame streaming and batch front ends reuse the
    /// same buffers instead of churning the allocator per call.
    pub fn compute_into(&self, signal: &[f32], scr: &mut MfccScratch, out: &mut [f32]) {
        let frames = self.frames_for(signal.len());
        assert_eq!(out.len(), self.cfg.n_mfcc * frames, "output buffer size");
        for t in 0..frames {
            let start = t * self.cfg.hop;
            self.mel_frame(&signal[start..start + self.cfg.win], scr);
            for (k, row) in self.dct.iter().enumerate() {
                out[k * frames + t] = row.iter().zip(&scr.mels).map(|(&d, &m)| d * m).sum();
            }
        }
    }

    /// MFCC matrix, row-major (n_mfcc, frames) — allocating wrapper
    /// over [`Mfcc::compute_into`].
    pub fn compute(&self, signal: &[f32]) -> Vec<f32> {
        let frames = self.frames_for(signal.len());
        let mut out = vec![0.0f32; self.cfg.n_mfcc * frames];
        let mut scr = self.scratch();
        self.compute_into(signal, &mut scr, &mut out);
        out
    }

    /// Full 39-dim features: MFCC + Δ + ΔΔ, shape (3*n_mfcc, frames).
    pub fn compute_with_deltas(&self, signal: &[f32]) -> Vec<f32> {
        let frames = self.frames_for(signal.len());
        let c = self.cfg.n_mfcc;
        let base = self.compute(signal);
        let d1 = deltas(&base, c, frames);
        let d2 = deltas(&d1, c, frames);
        let mut out = Vec::with_capacity(3 * c * frames);
        out.extend_from_slice(&base);
        out.extend_from_slice(&d1);
        out.extend_from_slice(&d2);
        out
    }
}

/// Standard delta features: d[t] = Σ_{k=1..2} k (x[t+k]-x[t-k]) / (2 Σ k²),
/// with edge clamping. Input/output row-major (coeffs, frames).
pub fn deltas(x: &[f32], coeffs: usize, frames: usize) -> Vec<f32> {
    let denom = 2.0 * (1.0 + 4.0); // 2 * sum(k^2)
    let mut out = vec![0.0f32; coeffs * frames];
    let get = |c: usize, t: i64| {
        let t = t.clamp(0, frames as i64 - 1) as usize;
        x[c * frames + t]
    };
    for c in 0..coeffs {
        for t in 0..frames {
            let ti = t as i64;
            let mut acc = 0.0;
            for k in 1..=2i64 {
                acc += k as f32 * (get(c, ti + k) - get(c, ti - k));
            }
            out[c * frames + t] = acc / denom;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fft_impulse_is_flat() {
        let mut re = vec![0.0; 16];
        let mut im = vec![0.0; 16];
        re[0] = 1.0;
        fft(&mut re, &mut im);
        for i in 0..16 {
            assert!((re[i] - 1.0).abs() < 1e-5 && im[i].abs() < 1e-5);
        }
    }

    #[test]
    fn fft_single_tone_peaks_at_bin() {
        let n = 128;
        let k = 10;
        let mut re: Vec<f32> =
            (0..n).map(|i| (2.0 * PI * k as f32 * i as f32 / n as f32).cos()).collect();
        let mut im = vec![0.0; n];
        fft(&mut re, &mut im);
        let mags: Vec<f32> =
            re.iter().zip(&im).map(|(&r, &i)| (r * r + i * i).sqrt()).collect();
        let peak = mags.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
        assert!(peak == k || peak == n - k, "peak at {peak}");
        assert!((mags[k] - n as f32 / 2.0).abs() < 1e-2);
    }

    #[test]
    fn fft_parseval() {
        let n = 64;
        let sig: Vec<f32> = (0..n).map(|i| ((i * 37 % 11) as f32 - 5.0) / 5.0).collect();
        let mut re = sig.clone();
        let mut im = vec![0.0; n];
        fft(&mut re, &mut im);
        let time_e: f32 = sig.iter().map(|&v| v * v).sum();
        let freq_e: f32 = re.iter().zip(&im).map(|(&r, &i)| r * r + i * i).sum::<f32>() / n as f32;
        assert!((time_e - freq_e).abs() / time_e < 1e-4);
    }

    #[test]
    fn mel_bank_covers_spectrum() {
        let bank = mel_filterbank(20, 128, 4000.0);
        assert_eq!(bank.len(), 20);
        // every filter has some mass; interior bins covered by some filter
        for (i, f) in bank.iter().enumerate() {
            assert!(f.iter().sum::<f32>() > 0.0, "filter {i} empty");
        }
    }

    #[test]
    fn dct_orthonormal_rows() {
        let m = dct_matrix(13, 20);
        for a in 0..13 {
            for b in 0..13 {
                let dot: f32 = m[a].iter().zip(&m[b]).map(|(&x, &y)| x * y).sum();
                let want = if a == b { 1.0 } else { 0.0 };
                assert!((dot - want).abs() < 1e-5, "rows {a},{b} dot={dot}");
            }
        }
    }

    #[test]
    fn mfcc_shapes() {
        let m = Mfcc::new(MfccConfig::default());
        let n = m.samples_for_frames(80);
        let sig = vec![0.01f32; n];
        assert_eq!(m.frames_for(n), 80);
        let out = m.compute_with_deltas(&sig);
        assert_eq!(out.len(), 39 * 80);
    }

    #[test]
    fn mfcc_distinguishes_tones() {
        let m = Mfcc::new(MfccConfig::default());
        let n = m.samples_for_frames(40);
        let tone = |f: f32| -> Vec<f32> {
            (0..n).map(|i| (2.0 * PI * f * i as f32 / 4000.0).sin()).collect()
        };
        let a = m.compute(&tone(300.0));
        let b = m.compute(&tone(1200.0));
        let dist: f32 = a.iter().zip(&b).map(|(&x, &y)| (x - y).powi(2)).sum::<f32>().sqrt();
        assert!(dist > 1.0, "tones not separated: {dist}");
    }

    #[test]
    fn compute_into_matches_compute() {
        let m = Mfcc::new(MfccConfig::default());
        let n = m.samples_for_frames(20);
        let sig: Vec<f32> = (0..n).map(|i| (2.0 * PI * 700.0 * i as f32 / 4000.0).sin()).collect();
        let want = m.compute(&sig);
        let mut scr = m.scratch();
        let mut got = vec![0.0f32; want.len()];
        m.compute_into(&sig, &mut scr, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn frame_into_matches_compute_columns() {
        let m = Mfcc::new(MfccConfig::default());
        let frames = 7;
        let n = m.samples_for_frames(frames);
        let sig: Vec<f32> = (0..n).map(|i| ((i * 73 % 19) as f32 - 9.0) / 9.0).collect();
        let whole = m.compute(&sig);
        let mut scr = m.scratch();
        let mut coeffs = vec![0.0f32; m.cfg.n_mfcc];
        for t in 0..frames {
            let start = t * m.cfg.hop;
            m.frame_into(&sig[start..start + m.cfg.win], &mut scr, &mut coeffs);
            for k in 0..m.cfg.n_mfcc {
                assert_eq!(coeffs[k], whole[k * frames + t], "k={k} t={t}");
            }
        }
    }

    #[test]
    fn frames_samples_round_trip_property() {
        use crate::util::proptest::check;
        check(
            "mfcc-frames-roundtrip",
            150,
            |g, s| {
                let win = 1 + g.sized_usize(s, 127);
                let hop = 1 + g.sized_usize(s, 160);
                let frames = g.sized_usize(s, 50);
                (win, hop, frames)
            },
            |&(win, hop, frames)| {
                let m = Mfcc::new(MfccConfig {
                    sample_rate: 4000.0,
                    win,
                    hop,
                    nfft: 128,
                    n_mels: 4,
                    n_mfcc: 3,
                });
                let samples = m.samples_for_frames(frames);
                if m.frames_for(samples) != frames {
                    return Err(format!(
                        "frames_for(samples_for_frames({frames})) = {} (win={win} hop={hop})",
                        m.frames_for(samples)
                    ));
                }
                // samples_for_frames is minimal: one sample less loses a frame
                if m.frames_for(samples - 1) != frames - 1 {
                    return Err(format!(
                        "samples_for_frames({frames}) = {samples} not minimal (win={win} hop={hop})"
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn deltas_of_constant_are_zero() {
        let x = vec![3.0f32; 13 * 10];
        let d = deltas(&x, 13, 10);
        assert!(d.iter().all(|&v| v.abs() < 1e-6));
    }

    #[test]
    fn deltas_of_ramp_are_constant_slope() {
        let frames = 12;
        let x: Vec<f32> = (0..frames).map(|t| 2.0 * t as f32).collect();
        let d = deltas(&x, 1, frames);
        // interior frames: slope 2
        for t in 2..frames - 2 {
            assert!((d[t] - 2.0).abs() < 1e-5, "t={t} d={}", d[t]);
        }
    }
}
