//! Procedural class-conditional image generator — the CIFAR-10/100 and
//! ImageNet-64 stand-in (DESIGN.md §4).
//!
//! Each class has a deterministic visual signature combining:
//!   * a shape family (disc, ring, box, cross, stripes, checker, blob,
//!     triangle) — for 100-class mode the family is chosen by the
//!     *superclass* (c / 5), preserving CIFAR-100's 20-superclass
//!     structure that the distillation experiments lean on;
//!   * a base hue (per class) and texture frequency/phase (per subclass).
//!
//! Per-sample variation: position/scale jitter, rotation-ish phase
//! shifts, background gradient, pixel noise. Images are CHW float,
//! normalized to zero mean / unit-ish std like the paper's preprocessing.

use super::augment;
use super::Dataset;
use crate::util::Rng;

pub struct ImageDataset {
    num_classes: usize,
    hw: usize,
}

#[derive(Clone, Copy, Debug)]
struct ClassSig {
    family: usize,
    hue: (f32, f32, f32),
    tex_freq: f32,
    tex_angle: f32,
    scale: f32,
}

const FAMILIES: usize = 8;

fn class_signature(c: usize, num_classes: usize) -> ClassSig {
    // 100-class mode: family from superclass (5 classes per superclass,
    // 20 superclasses à la CIFAR-100); otherwise family cycles directly.
    let (family_key, sub_key) =
        if num_classes >= 100 { (c / 5, c) } else { (c, c) };
    let mut r = Rng::new(0x1A4E ^ (sub_key as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let family = family_key % FAMILIES;
    // hue: well-spread via golden-ratio walk on the superclass, plus a
    // per-subclass shift so the 5 subclasses of a family stay separable
    // at small training budgets (they remain far closer to each other
    // than to other families — the property distillation leans on)
    let h = (family_key as f32 * 0.381_966 + (sub_key % 5) as f32 * 0.06) % 1.0;
    let hue = hsv_ish(h, 0.7, 0.9);
    ClassSig {
        family,
        hue,
        tex_freq: 1.0 + (sub_key % 5) as f32 * 2.1 + r.range(-0.2, 0.2),
        tex_angle: (sub_key % 5) as f32 * 0.55 + r.range(-0.1, 0.1),
        scale: 0.5 + (sub_key % 5) as f32 * 0.1,
    }
}

fn hsv_ish(h: f32, s: f32, v: f32) -> (f32, f32, f32) {
    let f = |shift: f32| {
        let x = ((h + shift) % 1.0) * 6.0;
        let c = (1.0 - (x % 2.0 - 1.0).abs()).clamp(0.0, 1.0);
        v * (1.0 - s * (1.0 - c))
    };
    (f(0.0), f(1.0 / 3.0), f(2.0 / 3.0))
}

impl ImageDataset {
    pub fn new(num_classes: usize, hw: usize) -> Self {
        ImageDataset { num_classes, hw }
    }

    /// Render the clean image for (class, instance-rng).
    fn render(&self, class: usize, r: &mut Rng) -> Vec<f32> {
        let hw = self.hw;
        let sig = class_signature(class, self.num_classes);
        let cx = 0.5 + r.range(-0.15, 0.15);
        let cy = 0.5 + r.range(-0.15, 0.15);
        let scale = sig.scale * r.range(0.85, 1.15);
        let phase = r.range(0.0, std::f32::consts::PI);
        let bg = r.range(-0.3, 0.3);
        let bgx = r.range(-0.3, 0.3);
        let mut img = vec![0.0f32; 3 * hw * hw];
        for y in 0..hw {
            for x in 0..hw {
                let u = x as f32 / hw as f32 - cx;
                let v = y as f32 / hw as f32 - cy;
                let rr = (u * u + v * v).sqrt() / (0.5 * scale);
                let ang = v.atan2(u);
                let mask: f32 = match sig.family {
                    0 => (1.0 - rr).clamp(0.0, 1.0),                         // disc
                    1 => (1.0 - (rr - 0.7).abs() * 4.0).clamp(0.0, 1.0),     // ring
                    2 => {
                        // box
                        let m = u.abs().max(v.abs()) / (0.5 * scale);
                        if m < 1.0 { 1.0 } else { 0.0 }
                    }
                    3 => {
                        // cross
                        let t = 0.22 * scale;
                        if u.abs() < t || v.abs() < t { 1.0 } else { 0.0 }
                    }
                    4 => {
                        // stripes
                        let s = (u * sig.tex_angle.cos() + v * sig.tex_angle.sin())
                            * sig.tex_freq
                            * 6.0;
                        (s + phase).sin().max(0.0)
                    }
                    5 => {
                        // checker
                        let s = (u * sig.tex_freq * 5.0).sin() * (v * sig.tex_freq * 5.0).sin();
                        if s > 0.0 { 1.0 } else { 0.0 }
                    }
                    6 => {
                        // blob: radial + angular lobes
                        let lobes = 2.0 + (class % 4) as f32;
                        (1.0 - rr + 0.3 * (lobes * ang + phase).sin()).clamp(0.0, 1.0)
                    }
                    _ => {
                        // triangle-ish half-plane composite
                        let a = v - 0.8 * u;
                        let b = v + 0.8 * u;
                        if a < 0.15 * scale && b < 0.15 * scale && v > -0.5 * scale {
                            1.0
                        } else {
                            0.0
                        }
                    }
                };
                // texture modulation + background gradient
                let tex = 0.75
                    + 0.25
                        * ((u * sig.tex_freq * 8.0 + phase).sin()
                            * (v * sig.tex_freq * 8.0).cos());
                let base = bg + bgx * (u + v);
                let (cr, cg, cb) = sig.hue;
                let idx = y * hw + x;
                img[idx] = base + mask * tex * cr;
                img[hw * hw + idx] = base + mask * tex * cg;
                img[2 * hw * hw + idx] = base + mask * tex * cb;
            }
        }
        // pixel noise + rough normalization (zero mean, ~unit std)
        let mean: f32 = img.iter().sum::<f32>() / img.len() as f32;
        let var: f32 =
            img.iter().map(|&p| (p - mean) * (p - mean)).sum::<f32>() / img.len() as f32;
        let std = var.sqrt().max(1e-3);
        for p in img.iter_mut() {
            *p = (*p - mean) / std + r.gaussian_f32(0.0, 0.05);
        }
        img
    }

    /// CIFAR-100-style superclass of a label (valid in 100-class mode).
    pub fn superclass(&self, label: usize) -> usize {
        label / 5
    }
}

impl Dataset for ImageDataset {
    fn input_shape(&self) -> Vec<usize> {
        vec![3, self.hw, self.hw]
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn sample(&self, id: u64, aug: Option<&mut Rng>) -> (Vec<f32>, i32) {
        let class = (id % self.num_classes as u64) as usize;
        let mut r = Rng::new(id.wrapping_mul(0xA076_1D64_78BD_642F).wrapping_add(3));
        let img = self.render(class, &mut r);
        let img = if let Some(rng) = aug {
            augment::crop_flip_chw(&img, 3, self.hw, self.hw, 2, rng)
        } else {
            img
        };
        (img, class as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let ds = ImageDataset::new(10, 16);
        let (a, ya) = ds.sample(3, None);
        let (b, yb) = ds.sample(3, None);
        assert_eq!(a.len(), 3 * 16 * 16);
        assert_eq!(a, b);
        assert_eq!(ya, yb);
    }

    #[test]
    fn roughly_normalized() {
        let ds = ImageDataset::new(10, 32);
        let (img, _) = ds.sample(100, None);
        let mean: f32 = img.iter().sum::<f32>() / img.len() as f32;
        assert!(mean.abs() < 0.3, "mean {mean}");
        assert!(img.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn classes_differ() {
        let ds = ImageDataset::new(10, 16);
        let (a, _) = ds.sample(0, None); // class 0
        let (b, _) = ds.sample(1, None); // class 1
        let d: f32 = a.iter().zip(&b).map(|(&x, &y)| (x - y).abs()).sum();
        assert!(d > 10.0, "classes too similar: {d}");
    }

    #[test]
    fn superclass_structure_in_100() {
        let ds = ImageDataset::new(100, 16);
        assert_eq!(ds.superclass(0), ds.superclass(4));
        assert_ne!(ds.superclass(0), ds.superclass(5));
        // same superclass => same shape family: compare binary masks loosely
        let (a, _) = ds.sample(0, None);
        let (b, _) = ds.sample(1, None); // class 1, same superclass as 0
        let (c, _) = ds.sample(50, None); // different superclass
        let d = |x: &[f32], y: &[f32]| -> f32 {
            x.iter().zip(y).map(|(&p, &q)| (p - q).abs()).sum()
        };
        assert!(d(&a, &b) < d(&a, &c) * 1.6);
    }

    #[test]
    fn augmentation_changes_pixels() {
        let ds = ImageDataset::new(10, 16);
        let mut rng = Rng::new(5);
        let (a, _) = ds.sample(7, None);
        let (b, _) = ds.sample(7, Some(&mut rng));
        assert_ne!(a, b);
    }
}
