//! Synthetic data substrates + batch plumbing.
//!
//! The paper evaluates on Google Speech Commands, CIFAR-10/100 and
//! ImageNet; none are available offline, so we build generators that
//! preserve the *structure* each experiment needs (DESIGN.md §4):
//!
//! * [`kws`]    — per-class formant-signature audio + background noise +
//!   time shifts, through a real MFCC front end ([`dsp`]).
//! * [`images`] — procedural class-conditional images (CIFAR-10-like,
//!   CIFAR-100-like with 20 superclasses, ImageNet-64-like).
//! * [`dsp`]    — FFT, mel filterbank, DCT-II, deltas — from scratch.
//! * [`augment`]— crops, flips, audio mixing.
//!
//! Sample identity: every sample is addressed by a `u64` id; ids
//! `0..VAL_SIZE` are the held-out validation set, training draws ids
//! above [`VAL_SIZE`]. Generation is deterministic in (id), augmentation
//! is driven by an explicit RNG — so runs are reproducible end-to-end.

pub mod augment;
pub mod dsp;
pub mod images;
pub mod kws;

use crate::tensor::TensorF;
use crate::util::Rng;

/// Held-out validation ids per dataset.
pub const VAL_SIZE: u64 = 512;

/// One training/eval batch, channels-first layout matching the artifacts.
#[derive(Clone, Debug)]
pub struct Batch {
    /// (B, ...input_shape)
    pub x: TensorF,
    pub y: Vec<i32>,
}

/// A deterministic synthetic dataset.
pub trait Dataset: Send + Sync {
    /// Per-sample shape, channels-first (no batch dim).
    fn input_shape(&self) -> Vec<usize>;
    fn num_classes(&self) -> usize;
    /// Generate sample `id`. `aug` enables training-time augmentation.
    fn sample(&self, id: u64, aug: Option<&mut Rng>) -> (Vec<f32>, i32);

    /// Random training batch (ids >= VAL_SIZE, augmented).
    fn train_batch(&self, batch: usize, rng: &mut Rng) -> Batch {
        let ids: Vec<u64> =
            (0..batch).map(|_| VAL_SIZE + (rng.next_u64() % 1_000_000)).collect();
        self.batch_for_ids(&ids, Some(rng))
    }

    /// Deterministic validation batch starting at `start` (no augmentation).
    fn val_batch(&self, start: u64, batch: usize) -> Batch {
        let ids: Vec<u64> = (0..batch as u64).map(|i| (start + i) % VAL_SIZE).collect();
        self.batch_for_ids(&ids, None)
    }

    fn batch_for_ids(&self, ids: &[u64], mut rng: Option<&mut Rng>) -> Batch {
        let shape = self.input_shape();
        let numel: usize = shape.iter().product();
        let mut x = Vec::with_capacity(ids.len() * numel);
        let mut y = Vec::with_capacity(ids.len());
        for &id in ids {
            let (v, label) = self.sample(id, rng.as_deref_mut());
            debug_assert_eq!(v.len(), numel);
            x.extend_from_slice(&v);
            y.push(label);
        }
        let mut full = vec![ids.len()];
        full.extend(&shape);
        Batch { x: TensorF::from_vec(&full, x), y }
    }
}

/// Dataset registry by model kind (used by the CLI and benches).
pub fn for_model(kind: &str, input_shape: &[usize], num_classes: usize) -> Box<dyn Dataset> {
    match kind {
        "kws" => Box::new(kws::KwsDataset::new(kws::KwsConfig::default())),
        "resnet" | "darknet" => Box::new(images::ImageDataset::new(
            num_classes,
            *input_shape.last().unwrap_or(&32),
        )),
        other => panic!("no dataset for model kind {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Toy;
    impl Dataset for Toy {
        fn input_shape(&self) -> Vec<usize> {
            vec![2, 3]
        }
        fn num_classes(&self) -> usize {
            4
        }
        fn sample(&self, id: u64, _aug: Option<&mut Rng>) -> (Vec<f32>, i32) {
            (vec![id as f32; 6], (id % 4) as i32)
        }
    }

    #[test]
    fn batch_shapes() {
        let mut rng = Rng::new(0);
        let b = Toy.train_batch(5, &mut rng);
        assert_eq!(b.x.shape(), &[5, 2, 3]);
        assert_eq!(b.y.len(), 5);
    }

    #[test]
    fn val_batches_deterministic() {
        let a = Toy.val_batch(0, 8);
        let b = Toy.val_batch(0, 8);
        assert_eq!(a.x.data(), b.x.data());
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn train_ids_outside_val() {
        let mut rng = Rng::new(1);
        let b = Toy.train_batch(64, &mut rng);
        // Toy encodes id in features: all >= VAL_SIZE
        assert!(b.x.data().iter().all(|&v| v >= VAL_SIZE as f32));
    }
}
