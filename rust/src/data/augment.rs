//! Training-time augmentation primitives (paper recipes).

use crate::util::Rng;

/// Colored-ish background noise: white noise through a one-pole lowpass
/// whose coefficient varies per draw (models the dataset's mixed noise
/// types: white / pink-ish / hum-ish).
pub fn background_noise(n: usize, rng: &mut Rng, level: f32) -> Vec<f32> {
    let alpha = rng.range(0.0, 0.9);
    let mut out = vec![0.0f32; n];
    let mut prev = 0.0f32;
    for v in out.iter_mut() {
        let white = rng.gaussian_f32(0.0, 1.0);
        prev = alpha * prev + (1.0 - alpha) * white;
        *v = level * prev;
    }
    // occasionally add mains-hum style tone
    if rng.chance(0.3) {
        let f = rng.range(40.0, 80.0);
        for (i, v) in out.iter_mut().enumerate() {
            *v += 0.3 * level * (2.0 * std::f32::consts::PI * f * i as f32 / 4000.0).sin();
        }
    }
    out
}

/// Shift a waveform by `shift` samples (positive = delay), zero-filled.
pub fn time_shift(wave: &mut [f32], shift: i64) {
    let n = wave.len() as i64;
    if shift == 0 || shift.abs() >= n {
        if shift.abs() >= n {
            wave.fill(0.0);
        }
        return;
    }
    if shift > 0 {
        wave.copy_within(0..(n - shift) as usize, shift as usize);
        wave[..shift as usize].fill(0.0);
    } else {
        let s = (-shift) as usize;
        wave.copy_within(s.., 0);
        let start = wave.len() - s;
        wave[start..].fill(0.0);
    }
}

/// Random crop of a CHW image zero-padded by `pad` on each side
/// (the CIFAR recipe), plus optional horizontal flip.
pub fn crop_flip_chw(
    img: &[f32],
    c: usize,
    h: usize,
    w: usize,
    pad: usize,
    rng: &mut Rng,
) -> Vec<f32> {
    let dy = rng.below(2 * pad + 1) as i64 - pad as i64;
    let dx = rng.below(2 * pad + 1) as i64 - pad as i64;
    let flip = rng.chance(0.5);
    let mut out = vec![0.0f32; c * h * w];
    for ch in 0..c {
        for y in 0..h {
            let sy = y as i64 + dy;
            if sy < 0 || sy >= h as i64 {
                continue;
            }
            for x in 0..w {
                let sx0 = if flip { w - 1 - x } else { x } as i64 + dx;
                if sx0 < 0 || sx0 >= w as i64 {
                    continue;
                }
                out[ch * h * w + y * w + x] = img[ch * h * w + sy as usize * w + sx0 as usize];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shift_positive_delays() {
        let mut w = vec![1.0, 2.0, 3.0, 4.0];
        time_shift(&mut w, 2);
        assert_eq!(w, vec![0.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    fn shift_negative_advances() {
        let mut w = vec![1.0, 2.0, 3.0, 4.0];
        time_shift(&mut w, -1);
        assert_eq!(w, vec![2.0, 3.0, 4.0, 0.0]);
    }

    #[test]
    fn shift_too_far_zeroes() {
        let mut w = vec![1.0, 2.0];
        time_shift(&mut w, 5);
        assert_eq!(w, vec![0.0, 0.0]);
    }

    #[test]
    fn crop_identity_when_no_jitter() {
        // pad=0 + no flip path can only shift by 0
        let img: Vec<f32> = (0..2 * 3 * 3).map(|i| i as f32).collect();
        let mut rng = Rng::new(0);
        // run until we hit the no-flip draw
        for _ in 0..10 {
            let out = crop_flip_chw(&img, 2, 3, 3, 0, &mut rng);
            let flipped: Vec<f32> = (0..2 * 3 * 3)
                .map(|i| {
                    let (ch, y, x) = (i / 9, (i % 9) / 3, i % 3);
                    img[ch * 9 + y * 3 + (2 - x)]
                })
                .collect();
            assert!(out == img || out == flipped);
        }
    }

    #[test]
    fn noise_level_scales_rms() {
        let mut rng = Rng::new(9);
        let quiet = background_noise(4000, &mut rng, 0.01);
        let loud = background_noise(4000, &mut rng, 0.1);
        let rms = |v: &[f32]| (v.iter().map(|&x| x * x).sum::<f32>() / v.len() as f32).sqrt();
        assert!(rms(&loud) > 3.0 * rms(&quiet));
    }
}
