//! Synthetic keyword-spotting dataset (Google Speech Commands stand-in).
//!
//! Each keyword class has a deterministic acoustic signature: a sequence
//! of 2-3 "syllables", each a sum of a fundamental + two formant-like
//! harmonics with class-specific frequencies and a chirp slope, under an
//! attack/decay envelope. Per-sample (id-keyed) variation models speaker
//! diversity: pitch shift, tempo, amplitude. The 12 labels follow the
//! paper's task: 10 keywords + `silence` (background noise only) +
//! `unknown` (one of 20 extra keyword signatures).
//!
//! Training augmentation matches the paper's recipe: background noise
//! mixed in with probability 0.8 and random time shifts ~U(-100ms, 100ms).
//!
//! Samples are emitted as 39x80 MFCC(+Δ,ΔΔ) features via [`dsp::Mfcc`].

use super::augment;
use super::dsp::{Mfcc, MfccConfig};
use super::Dataset;
use crate::util::Rng;

pub const NUM_KEYWORDS: usize = 10;
pub const LABEL_SILENCE: i32 = 10;
pub const LABEL_UNKNOWN: i32 = 11;
pub const NUM_CLASSES: usize = 12;
/// extra keyword signatures pooled into `unknown` (paper: remaining 20)
pub const NUM_UNKNOWN_WORDS: usize = 20;

#[derive(Clone, Debug)]
pub struct KwsConfig {
    pub frames: usize,
    pub mfcc: MfccConfig,
    /// probability of mixing background noise into a training sample
    pub noise_prob: f64,
    /// max |time shift| in samples (100 ms at 4 kHz)
    pub max_shift: usize,
}

impl Default for KwsConfig {
    fn default() -> Self {
        KwsConfig { frames: 80, mfcc: MfccConfig::default(), noise_prob: 0.8, max_shift: 400 }
    }
}

pub struct KwsDataset {
    cfg: KwsConfig,
    mfcc: Mfcc,
    samples: usize,
}

/// Class-specific acoustic signature.
#[derive(Clone, Debug)]
struct Signature {
    /// per-syllable (fundamental Hz, formant Hz, chirp Hz/s)
    syllables: Vec<(f32, f32, f32)>,
}

fn signature(word: usize) -> Signature {
    // Deterministic per-word: spread fundamentals over 150..550 Hz and
    // formants over 600..1900 Hz so words are acoustically distinct but
    // overlap enough to be non-trivial.
    let mut r = Rng::new(SIG_SEED ^ (word as u64).wrapping_mul(0x9E37_79B9));
    let n_syl = 2 + (word % 2);
    let syllables = (0..n_syl)
        .map(|s| {
            let f0 = 150.0 + 40.0 * ((word * 7 + s * 3) % 11) as f32 + r.range(-10.0, 10.0);
            let f1 = 600.0 + 130.0 * ((word * 5 + s * 7) % 10) as f32 + r.range(-30.0, 30.0);
            let chirp = r.range(-400.0, 400.0);
            (f0, f1, chirp)
        })
        .collect();
    Signature { syllables }
}

impl KwsDataset {
    pub fn new(cfg: KwsConfig) -> Self {
        let mfcc = Mfcc::new(cfg.mfcc.clone());
        let samples = mfcc.samples_for_frames(cfg.frames);
        KwsDataset { cfg, mfcc, samples }
    }

    pub fn config(&self) -> &KwsConfig {
        &self.cfg
    }

    /// Raw waveform for sample id (before augmentation). Returns (wave, label).
    pub fn waveform(&self, id: u64) -> (Vec<f32>, i32) {
        let mut r = Rng::new(id.wrapping_mul(0xD1B5_4A32_D192_ED03).wrapping_add(7));
        let class = (id % NUM_CLASSES as u64) as i32;
        let n = self.samples;
        match class {
            LABEL_SILENCE => (augment::background_noise(n, &mut r, 0.02), LABEL_SILENCE),
            LABEL_UNKNOWN => {
                let word = NUM_KEYWORDS + r.below(NUM_UNKNOWN_WORDS);
                (self.render_word(word, n, &mut r), LABEL_UNKNOWN)
            }
            k => (self.render_word(k as usize, n, &mut r), k),
        }
    }

    /// Render one keyword utterance with speaker variation from `r`.
    fn render_word(&self, word: usize, n: usize, r: &mut Rng) -> Vec<f32> {
        let sig = signature(word);
        let sr = self.cfg.mfcc.sample_rate;
        // speaker variation: pitch ±12%, tempo ±15%, loudness 0.6..1.0
        let pitch = r.range(0.88, 1.12);
        let tempo = r.range(0.85, 1.15);
        let amp = r.range(0.6, 1.0);
        let n_syl = sig.syllables.len();
        let total = (n as f32 * 0.85 * tempo).min(n as f32) as usize;
        let syl_len = total / n_syl;
        let gap = syl_len / 5;
        let mut wave = vec![0.0f32; n];
        let start0 = (n - total) / 2;
        for (si, &(f0, f1, chirp)) in sig.syllables.iter().enumerate() {
            let start = start0 + si * syl_len;
            let len = syl_len.saturating_sub(gap).max(8);
            let jitter0 = r.range(0.97, 1.03);
            for i in 0..len {
                let t = i as f32 / sr;
                let rel = i as f32 / len as f32;
                // attack/decay envelope
                let env = (rel * 6.0).min(1.0) * (1.0 - rel).max(0.0).powf(0.5);
                let inst0 = (f0 * pitch * jitter0 + chirp * t) * t;
                let inst1 = (f1 * pitch + 1.7 * chirp * t) * t;
                let v = 0.8 * (2.0 * std::f32::consts::PI * inst0).sin()
                    + 0.45 * (2.0 * std::f32::consts::PI * inst1).sin()
                    + 0.18 * (2.0 * std::f32::consts::PI * 2.1 * inst0).sin();
                if start + i < n {
                    wave[start + i] += amp * env * v;
                }
            }
        }
        wave
    }
}

impl Dataset for KwsDataset {
    fn input_shape(&self) -> Vec<usize> {
        vec![3 * self.cfg.mfcc.n_mfcc, self.cfg.frames]
    }

    fn num_classes(&self) -> usize {
        NUM_CLASSES
    }

    fn sample(&self, id: u64, aug: Option<&mut Rng>) -> (Vec<f32>, i32) {
        let (mut wave, label) = self.waveform(id);
        if let Some(r) = aug {
            // paper recipe: random shift U(-100ms, 100ms), noise w.p. 0.8
            let shift =
                r.below(2 * self.cfg.max_shift + 1) as i64 - self.cfg.max_shift as i64;
            augment::time_shift(&mut wave, shift);
            if r.chance(self.cfg.noise_prob) {
                let level = r.range(0.01, 0.1);
                let noise = augment::background_noise(wave.len(), r, level);
                for (w, nz) in wave.iter_mut().zip(noise) {
                    *w += nz;
                }
            }
        }
        let feats = self.mfcc.compute_with_deltas(&wave);
        // normalize to roughly unit scale for the FP embedding layer
        let feats = feats.iter().map(|&v| v * 0.1).collect();
        (feats, label)
    }
}

/// Rng seed tag for class signatures ("KW" as bytes).
const SIG_SEED: u64 = 0x4B57;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_labels() {
        let ds = KwsDataset::new(KwsConfig::default());
        assert_eq!(ds.input_shape(), vec![39, 80]);
        for id in 0..24 {
            let (x, y) = ds.sample(id, None);
            assert_eq!(x.len(), 39 * 80);
            assert!((0..12).contains(&y));
            assert!(x.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn deterministic_without_aug() {
        let ds = KwsDataset::new(KwsConfig::default());
        let (a, _) = ds.sample(5, None);
        let (b, _) = ds.sample(5, None);
        assert_eq!(a, b);
    }

    #[test]
    fn classes_are_separable_in_feature_space() {
        // nearest-centroid sanity: same-word samples closer than cross-word
        let ds = KwsDataset::new(KwsConfig::default());
        let feat = |id: u64| ds.sample(id, None).0;
        let d = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(&x, &y)| (x - y).powi(2)).sum::<f32>().sqrt()
        };
        // ids congruent mod 12 share a class
        let a0 = feat(0);
        let a1 = feat(12);
        let b0 = feat(1);
        assert!(d(&a0, &a1) < d(&a0, &b0) * 1.5, "within-class distance should be small");
    }

    #[test]
    fn silence_is_quiet() {
        let ds = KwsDataset::new(KwsConfig::default());
        let (w, y) = ds.waveform(LABEL_SILENCE as u64);
        assert_eq!(y, LABEL_SILENCE);
        let rms = (w.iter().map(|&v| v * v).sum::<f32>() / w.len() as f32).sqrt();
        assert!(rms < 0.05, "silence rms {rms}");
    }
}
