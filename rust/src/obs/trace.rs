//! Request tracing: fixed-size events in lock-free per-worker ring
//! buffers behind a pluggable monotonic clock.
//!
//! A `TraceId` is minted per accepted submit / session feed; every hop
//! of the request's path through the serving stack (admit/shed →
//! enqueue → dispatch → terminal reply) appends one [`TraceEvent`] to
//! the recording thread's shard. The record path is three `Relaxed`
//! stores plus one `fetch_add` — no lock, no allocation, no float
//! (pinned by the `cargo xtask lint` hot-path-float rule).
//!
//! Reliability contract (documented, and weaker than the metrics
//! counters'): each shard is a ring of `capacity` slots addressed by a
//! monotone reservation counter, so concurrent writers on one shard
//! never contend for a slot until the ring wraps; after a wrap, a slow
//! writer can tear a slot a fast writer lapped. Snapshots taken while
//! traffic is live are therefore best-effort; snapshots taken after the
//! writing threads are joined (shutdown, or a drained test) are exact,
//! because the join imposes the happens-before that `Relaxed` omits.
//! The accounting tests in rust/tests/obs.rs only assert on
//! post-quiescence snapshots.

use crate::check::sync::AtomicU64;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------------

/// Pluggable monotonic time source for trace timestamps, injectable so
/// deterministic tests (fake clock) and model-check runs can assert on
/// recorded traces.
pub trait Clock: Send + Sync {
    /// Nanoseconds since an arbitrary fixed epoch; monotone.
    fn now_ns(&self) -> u64;
}

/// Wall monotonic clock: nanoseconds since construction.
pub struct MonotonicClock {
    epoch: Instant,
}

impl Default for MonotonicClock {
    fn default() -> Self {
        Self::new()
    }
}

impl MonotonicClock {
    pub fn new() -> Self {
        MonotonicClock { epoch: Instant::now() }
    }
}

impl Clock for MonotonicClock {
    fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }
}

/// Settable clock for deterministic tests.
#[derive(Default)]
pub struct FakeClock {
    t: AtomicU64,
}

impl FakeClock {
    pub fn new(start_ns: u64) -> Self {
        FakeClock { t: AtomicU64::new(start_ns) }
    }

    pub fn set(&self, t_ns: u64) {
        // Relaxed: test-clock cell; readers only need *a* recent value
        self.t.store(t_ns, Ordering::Relaxed);
    }

    pub fn advance(&self, d_ns: u64) {
        // Relaxed: monotone test-clock bump (exact under RMW atomicity)
        self.t.fetch_add(d_ns, Ordering::Relaxed);
    }
}

impl Clock for FakeClock {
    fn now_ns(&self) -> u64 {
        // Relaxed: see `set`
        self.t.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Events
// ---------------------------------------------------------------------------

/// One hop of a request's path through the serving stack. Discriminants
/// are stable (they are packed into ring slots and exposed in JSON);
/// the derived `Ord` follows a request's forward progression.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(u8)]
pub enum EventKind {
    /// request accepted by `submit` (trace minted); `a` = priority lane
    Submit = 1,
    /// request refused with a typed error; `a` = shed reason code
    Shed = 2,
    /// batch formed and pushed onto the shared queue; `a` = lane
    Enqueue = 3,
    /// session feed parked on a busy session's backlog
    Backlog = 4,
    /// a worker popped the request and is about to run the backend;
    /// `a` = worker index
    Dispatch = 5,
    /// batch re-queued after a worker error / bounce; `a` = worker index
    Requeue = 6,
    /// terminal: reply sent with logits; `a` = worker index
    Served = 7,
    /// terminal: reply sent as DeadlineExceeded
    Expired = 8,
    /// terminal: reply sent as BackendFailed; `a` = delivery attempts
    Failed = 9,
    /// streaming session opened; `a` = session slot index
    SessionOpen = 10,
    /// streaming session closed; `a` = session slot index
    SessionClose = 11,
    /// a replica was quarantined (not tied to one trace; trace = 0);
    /// `a` = worker index
    Quarantine = 12,
}

impl EventKind {
    /// Decode a packed discriminant (see [`TraceBuf`] slot layout).
    pub fn from_u8(v: u8) -> Option<EventKind> {
        use EventKind::*;
        Some(match v {
            1 => Submit,
            2 => Shed,
            3 => Enqueue,
            4 => Backlog,
            5 => Dispatch,
            6 => Requeue,
            7 => Served,
            8 => Expired,
            9 => Failed,
            10 => SessionOpen,
            11 => SessionClose,
            12 => Quarantine,
            _ => return None,
        })
    }

    /// Stable lowercase name (exposition + logs).
    pub fn as_str(self) -> &'static str {
        match self {
            EventKind::Submit => "submit",
            EventKind::Shed => "shed",
            EventKind::Enqueue => "enqueue",
            EventKind::Backlog => "backlog",
            EventKind::Dispatch => "dispatch",
            EventKind::Requeue => "requeue",
            EventKind::Served => "served",
            EventKind::Expired => "expired",
            EventKind::Failed => "failed",
            EventKind::SessionOpen => "session_open",
            EventKind::SessionClose => "session_close",
            EventKind::Quarantine => "quarantine",
        }
    }

    /// True for the kinds that end a request's path (exactly one per
    /// accepted request — the protocol invariant the tracer witnesses).
    pub fn is_terminal(self) -> bool {
        matches!(self, EventKind::Served | EventKind::Expired | EventKind::Failed)
    }
}

/// One decoded trace event.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// request trace id (0 for events not tied to one request)
    pub trace: u64,
    /// clock timestamp, ns
    pub t_ns: u64,
    pub kind: EventKind,
    /// kind-specific detail (worker index, lane, shed reason, …)
    pub a: u32,
    /// kind-specific detail, 24 bits retained (batch size, slot, …)
    pub b: u32,
}

// ---------------------------------------------------------------------------
// Ring buffer
// ---------------------------------------------------------------------------

/// One ring slot: trace id, timestamp, and the packed kind/detail word
/// (`kind` in bits 0..8, `a` in 8..40, `b` in 40..64).
struct TraceSlot {
    id: AtomicU64,
    t: AtomicU64,
    kw: AtomicU64,
}

/// One writer shard: a reservation counter plus `capacity` slots.
struct TraceShard {
    head: AtomicU64,
    slots: Vec<TraceSlot>,
}

/// Per-worker ring buffers of fixed-size trace events. Shard 0 is the
/// serving stack's control plane (submit/shed/enqueue, written under
/// the registry's locks or from client threads); shard `wi + 1` belongs
/// to worker `wi`. See the module doc for the reliability contract.
pub struct TraceBuf {
    shards: Vec<TraceShard>,
    clock: Arc<dyn Clock>,
}

impl TraceBuf {
    /// `shards` writer shards of `capacity` events each.
    pub fn new(shards: usize, capacity: usize, clock: Arc<dyn Clock>) -> Self {
        let cap = capacity.max(1);
        let mk = |_: usize| TraceShard {
            head: AtomicU64::new(0),
            slots: (0..cap)
                .map(|_| TraceSlot {
                    id: AtomicU64::new(0),
                    t: AtomicU64::new(0),
                    kw: AtomicU64::new(0),
                })
                .collect(),
        };
        TraceBuf { shards: (0..shards.max(1)).map(mk).collect(), clock }
    }

    /// The clock events are stamped with.
    pub fn clock(&self) -> &Arc<dyn Clock> {
        &self.clock
    }

    /// Record one event on `shard` (wrapped into range). Lock-free:
    /// reserve a slot with one `fetch_add`, then three plain stores.
    pub fn record(&self, shard: usize, trace: u64, kind: EventKind, a: u32, b: u32) {
        let len = self.shards.len();
        let sh = &self.shards[shard % len];
        // Relaxed: the reservation index only needs RMW atomicity (each
        // writer gets a unique slot); readers order via thread join
        let seq = sh.head.fetch_add(1, Ordering::Relaxed);
        let slot = &sh.slots[(seq % sh.slots.len() as u64) as usize];
        let kw = kind as u64 | ((a as u64) << 8) | (((b as u64) & 0xff_ffff) << 40);
        // Relaxed stores: slots are racy-by-contract for live snapshots
        // and made visible to exact snapshots by thread join (module doc)
        slot.id.store(trace, Ordering::Relaxed);
        slot.t.store(self.clock.now_ns(), Ordering::Relaxed);
        slot.kw.store(kw, Ordering::Relaxed);
    }

    /// Total events recorded across shards (including overwritten ones).
    pub fn events_total(&self) -> u64 {
        // Relaxed: monitoring sum
        self.shards.iter().map(|s| s.head.load(Ordering::Relaxed)).sum()
    }

    /// Events lost to ring wrap-around across shards.
    pub fn dropped(&self) -> u64 {
        self.shards
            .iter()
            // Relaxed: monitoring sum
            .map(|s| s.head.load(Ordering::Relaxed).saturating_sub(s.slots.len() as u64))
            .sum()
    }

    /// Decode every retained event, sorted by `(t_ns, trace)`. Exact
    /// once the writers are quiescent (module doc).
    pub fn snapshot(&self) -> Vec<TraceEvent> {
        let mut out = Vec::new();
        for sh in &self.shards {
            // Relaxed: snapshot loads; see the reliability contract
            let n = sh.head.load(Ordering::Relaxed).min(sh.slots.len() as u64) as usize;
            for slot in &sh.slots[..n] {
                let kw = slot.kw.load(Ordering::Relaxed);
                let Some(kind) = EventKind::from_u8((kw & 0xff) as u8) else { continue };
                out.push(TraceEvent {
                    trace: slot.id.load(Ordering::Relaxed),
                    t_ns: slot.t.load(Ordering::Relaxed),
                    kind,
                    a: ((kw >> 8) & 0xffff_ffff) as u32,
                    b: ((kw >> 40) & 0xff_ffff) as u32,
                });
            }
        }
        out.sort_by_key(|e| (e.t_ns, e.trace, e.kind));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_roundtrip() {
        let clock = Arc::new(FakeClock::new(5));
        let buf = TraceBuf::new(2, 8, clock.clone());
        buf.record(0, 42, EventKind::Submit, 1, 0);
        clock.advance(10);
        buf.record(1, 42, EventKind::Dispatch, 3, 999_999);
        let ev = buf.snapshot();
        assert_eq!(ev.len(), 2);
        assert_eq!((ev[0].trace, ev[0].kind, ev[0].a, ev[0].t_ns), (42, EventKind::Submit, 1, 5));
        let d = &ev[1];
        assert_eq!((d.kind, d.a, d.b, d.t_ns), (EventKind::Dispatch, 3, 999_999, 15));
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let buf = TraceBuf::new(1, 4, Arc::new(FakeClock::new(0)));
        for i in 0..10u64 {
            buf.record(0, i, EventKind::Served, 0, 0);
        }
        assert_eq!(buf.events_total(), 10);
        assert_eq!(buf.dropped(), 6);
        let ev = buf.snapshot();
        assert_eq!(ev.len(), 4, "ring retains capacity events");
        // retained ids are the survivors of the wrap (8, 9 lapped 4, 5 …)
        for e in &ev {
            assert!(e.trace >= 6, "stale event survived the wrap: {e:?}");
        }
    }

    #[test]
    fn kind_discriminants_are_stable() {
        for v in 0..=20u8 {
            if let Some(k) = EventKind::from_u8(v) {
                assert_eq!(k as u8, v);
            }
        }
        assert!(EventKind::Served.is_terminal());
        assert!(!EventKind::Dispatch.is_terminal());
    }
}
