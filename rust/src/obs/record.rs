//! Lock-free record-path primitives for the metrics registry: sharded
//! counters, gauges, sharded histograms, and the rate-limited-log
//! gate. Everything here is integer-only and allocation-free after
//! construction (pinned by the `cargo xtask lint` hot-path-float rule)
//! and uses only `load`/`store`/`fetch_add`/`fetch_sub` from the
//! `check::sync` atomic facade.
//!
//! Soundness of the Relaxed orderings (see CONCURRENCY.md §obs): every
//! atomic here is a *monitoring* cell — written on hot paths, read only
//! by merge-on-read snapshots that make no cross-cell consistency
//! claim. `fetch_add(Relaxed)` makes each individual counter exact
//! (RMW atomicity does not depend on ordering); a snapshot may observe
//! one counter slightly ahead of another, which exposition tolerates by
//! construction. Exact accounting identities (served + shed + expired +
//! failed == accepted) are asserted only after thread joins, which
//! impose the needed happens-before.

use crate::check::sync::AtomicU64;
use std::sync::atomic::Ordering;
use std::sync::Arc;

use super::hist::{bucket_index, Histogram, N_BUCKETS};

/// Build a shard vector of zeroed atomics (facade atomics are not
/// `Clone`, so `vec![..; n]` cannot).
fn zeroed(n: usize) -> Vec<AtomicU64> {
    (0..n.max(1)).map(|_| AtomicU64::new(0)).collect()
}

// ---------------------------------------------------------------------------
// Counter
// ---------------------------------------------------------------------------

/// Monotone counter with per-worker shards: `add(shard, n)` touches one
/// cache line per worker, `total()` merges on read.
#[derive(Clone)]
pub struct Counter {
    shards: Arc<Vec<AtomicU64>>,
}

impl Counter {
    pub fn new(shards: usize) -> Self {
        Counter { shards: Arc::new(zeroed(shards)) }
    }

    /// Add `n` on the caller's shard (a worker index; wrapped into
    /// range so any caller-supplied index is safe).
    pub fn add(&self, shard: usize, n: u64) {
        let len = self.shards.len();
        // Relaxed: monitoring increment, merged on read (module doc)
        self.shards[shard % len].fetch_add(n, Ordering::Relaxed);
    }

    /// `add(shard, 1)`.
    pub fn inc(&self, shard: usize) {
        self.add(shard, 1);
    }

    /// Merge-on-read total across shards.
    pub fn total(&self) -> u64 {
        // Relaxed: each shard is exact; the sum is a monitoring snapshot
        self.shards.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }
}

// ---------------------------------------------------------------------------
// Gauge
// ---------------------------------------------------------------------------

/// Last-writer-wins gauge (queue depth, session count, budgets).
#[derive(Clone)]
pub struct Gauge {
    cell: Arc<AtomicU64>,
}

impl Default for Gauge {
    fn default() -> Self {
        Self::new()
    }
}

impl Gauge {
    pub fn new() -> Self {
        Gauge { cell: Arc::new(AtomicU64::new(0)) }
    }

    pub fn set(&self, v: u64) {
        // Relaxed: monitoring store, no release obligation (module doc)
        self.cell.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        // Relaxed: monitoring load (module doc)
        self.cell.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Sharded histogram
// ---------------------------------------------------------------------------

/// One worker's histogram shard: per-bucket counters plus an exact
/// running sum. No atomic min/max (the facade has no `fetch_max`);
/// snapshots reconstruct min/max from the outermost non-empty buckets.
struct HistShard {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
}

/// Fixed-bucket histogram with lock-free per-worker shards, merged into
/// a plain [`Histogram`] on read.
#[derive(Clone)]
pub struct ShardedHist {
    shards: Arc<Vec<HistShard>>,
}

impl ShardedHist {
    pub fn new(shards: usize) -> Self {
        let shards = (0..shards.max(1))
            .map(|_| HistShard { buckets: zeroed(N_BUCKETS), sum: AtomicU64::new(0) })
            .collect();
        ShardedHist { shards: Arc::new(shards) }
    }

    /// Record one microsecond sample on the caller's shard: two
    /// `fetch_add`s, no lock, no allocation, no float.
    pub fn record_us(&self, shard: usize, us: u64) {
        let len = self.shards.len();
        let sh = &self.shards[shard % len];
        // Relaxed: per-bucket monitoring increments (module doc)
        sh.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        sh.sum.fetch_add(us, Ordering::Relaxed);
    }

    /// Merge every shard into one plain histogram.
    pub fn snapshot(&self) -> Histogram {
        let mut out = Histogram::new();
        let mut counts = vec![0u64; N_BUCKETS];
        for sh in self.shards.iter() {
            for (c, b) in counts.iter_mut().zip(sh.buckets.iter()) {
                // Relaxed: snapshot load of monitoring cells (module doc)
                *c = b.load(Ordering::Relaxed);
            }
            out.merge_bucket_counts(&counts, sh.sum.load(Ordering::Relaxed));
        }
        out
    }
}

// ---------------------------------------------------------------------------
// LogLimiter
// ---------------------------------------------------------------------------

/// Once-per-interval gate for repeated `log::error!` sites: the first
/// caller in each interval logs (and learns how many identical events
/// were suppressed since the last emission); everyone else bumps the
/// suppression counter. Under a concurrent stampede two callers can
/// both observe a stale `last` and both log — an acceptable, bounded
/// duplication for a rate *limiter* (the point is flood control, not
/// exactly-once).
pub struct LogLimiter {
    interval_ns: u64,
    /// ns timestamp of the last allowed log; `u64::MAX` = never logged
    /// (so the very first event always passes, even at clock time 0)
    last: AtomicU64,
    suppressed: AtomicU64,
}

impl LogLimiter {
    pub fn new(interval_ns: u64) -> Self {
        LogLimiter {
            interval_ns,
            last: AtomicU64::new(u64::MAX),
            suppressed: AtomicU64::new(0),
        }
    }

    /// Events suppressed since the last allowed log (not yet drained).
    pub fn suppressed(&self) -> u64 {
        // Relaxed: monitoring load (module doc)
        self.suppressed.load(Ordering::Relaxed)
    }

    /// Returns `Some(drained)` — the number of events suppressed since
    /// the previous emission — when this event may log; `None` when it
    /// is inside the quiet interval.
    pub fn allow(&self, now_ns: u64) -> Option<u64> {
        // Relaxed: the gate is heuristic; a stale read only causes a
        // duplicate log line, never a lost suppression count (doc above)
        let last = self.last.load(Ordering::Relaxed);
        if last == u64::MAX || now_ns.saturating_sub(last) >= self.interval_ns {
            self.last.store(now_ns, Ordering::Relaxed);
            let drained = self.suppressed.load(Ordering::Relaxed);
            if drained > 0 {
                // fetch_sub (not store 0) so increments racing this
                // drain are carried into the next interval, not lost
                self.suppressed.fetch_sub(drained, Ordering::Relaxed);
            }
            Some(drained)
        } else {
            self.suppressed.fetch_add(1, Ordering::Relaxed);
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_merges_shards() {
        let c = Counter::new(4);
        c.add(0, 5);
        c.add(1, 7);
        c.add(9, 1); // out-of-range shard wraps, never panics
        c.inc(3);
        assert_eq!(c.total(), 14);
    }

    #[test]
    fn gauge_last_writer_wins() {
        let g = Gauge::new();
        g.set(42);
        g.set(7);
        assert_eq!(g.get(), 7);
    }

    #[test]
    fn sharded_hist_matches_plain() {
        let sh = ShardedHist::new(3);
        let mut plain = Histogram::new();
        for i in 0..300u64 {
            let us = (i * 31) % 5000;
            sh.record_us((i % 3) as usize, us);
            plain.record_us(us);
        }
        let merged = sh.snapshot();
        assert_eq!(merged.count(), plain.count());
        assert_eq!(merged.sum_us(), plain.sum_us());
        // sharded min/max are bucket midpoints, so compare at bucket
        // tolerance rather than exactly
        let (m, p) = (merged.percentile(50.0), plain.percentile(50.0));
        assert!((m - p).abs() <= p * 0.25 + 1.0, "merged p50 {m} vs plain {p}");
    }

    #[test]
    fn limiter_gates_by_interval() {
        let l = LogLimiter::new(1_000);
        assert_eq!(l.allow(0), Some(0), "first event always logs");
        assert_eq!(l.allow(10), None);
        assert_eq!(l.allow(20), None);
        assert_eq!(l.suppressed(), 2);
        assert_eq!(l.allow(1_000), Some(2), "interval elapsed, drains suppressed");
        assert_eq!(l.suppressed(), 0);
        assert_eq!(l.allow(1_500), None);
        assert_eq!(l.allow(2_100), Some(1));
    }
}
