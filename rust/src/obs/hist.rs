//! Fixed-bucket integer latency histogram — the one histogram the whole
//! tree shares (`metrics::LatencyHist` and the serving layer's per-model
//! latency stats are this type; the registry's sharded histograms merge
//! into it on read).
//!
//! The record path is integer-only and allocation-free (pinned by the
//! `cargo xtask lint` hot-path-float rule): values below 32us get an
//! exact unit bucket; above that, buckets are log-spaced with 4
//! sub-buckets per octave, so a bucket's upper edge is at most 25% above
//! its lower edge and the midpoint estimate is within ~12.5% of any
//! sample in it. `count`/`sum`/`min`/`max` are tracked exactly, so
//! `mean()` has no bucketing error at all.

/// Unit-bucket region: values below this are their own bucket.
const UNIT: usize = 32;
/// Sub-buckets per octave in the log region.
const SUBS: usize = 4;
/// Total buckets: 32 unit + 4 per octave for msb 5..=63.
pub const N_BUCKETS: usize = UNIT + (64 - 6) * SUBS + SUBS;

/// Bucket index for a microsecond value. Exact below [`UNIT`];
/// log-spaced (4 sub-buckets per power of two) above.
pub fn bucket_index(us: u64) -> usize {
    if us < UNIT as u64 {
        return us as usize;
    }
    let msb = 63 - us.leading_zeros() as usize;
    let sub = ((us >> (msb - 2)) & 3) as usize;
    UNIT + (msb - 5) * SUBS + sub
}

/// Representative (midpoint) microsecond value of a bucket.
pub fn bucket_value(idx: usize) -> u64 {
    if idx < UNIT {
        return idx as u64;
    }
    let b = idx - UNIT;
    let msb = 5 + b / SUBS;
    let sub = (b % SUBS) as u64;
    let lo = (4 + sub) << (msb - 2);
    // midpoint = lo + half the bucket width; computed additively so the
    // top octave's upper edge (2^64) never materializes
    lo + (1u64 << (msb - 3))
}

/// Fixed-bucket integer histogram of microsecond samples.
#[derive(Clone, Debug)]
pub struct Histogram {
    buckets: Box<[u64; N_BUCKETS]>,
    count: u64,
    sum: u64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: Box::new([0; N_BUCKETS]),
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Record one microsecond sample. Integer-only: no allocation, no
    /// float math (hot-path lint applies to this file).
    pub fn record_us(&mut self, us: u64) {
        self.buckets[bucket_index(us)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(us);
        self.min = self.min.min(us);
        self.max = self.max.max(us);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all recorded microseconds.
    pub fn sum_us(&self) -> u64 {
        self.sum
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max_us(&self) -> u64 {
        self.max
    }

    /// Exact minimum recorded value (0 when empty).
    pub fn min_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Fold another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (b, &o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += o;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Fold raw per-bucket counts (a lock-free shard snapshot) into this
    /// histogram. `sum` is the shard's exact running sum; min/max are
    /// reconstructed from the outermost non-empty buckets (the sharded
    /// record path has no atomic min/max — see `obs::record`).
    pub fn merge_bucket_counts(&mut self, counts: &[u64], sum: u64) {
        debug_assert_eq!(counts.len(), N_BUCKETS);
        for (i, (b, &n)) in self.buckets.iter_mut().zip(counts.iter()).enumerate() {
            if n > 0 {
                *b += n;
                self.count += n;
                self.min = self.min.min(bucket_value(i));
                self.max = self.max.max(bucket_value(i));
            }
        }
        self.sum = self.sum.saturating_add(sum);
    }

    /// Value at percentile `p` (0..=100), estimated as the midpoint of
    /// the bucket holding that rank and clamped to the exact observed
    /// `[min, max]`. An empty histogram returns a defined 0.0 — never
    /// NaN or a bucket-edge artifact.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if n > 0 && seen > rank {
                return (bucket_value(i).clamp(self.min, self.max)) as f64;
            }
        }
        self.max as f64
    }

    /// Exact mean (the sum is tracked outside the buckets); 0.0 when
    /// empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    /// One-line human summary (the serving CLI's latency line).
    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.0}us p50={:.0}us p95={:.0}us p99={:.0}us max={:.0}us",
            self.count,
            self.mean(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.max as f64,
        )
    }

    /// Raw bucket counts (exposition walks these for the Prometheus
    /// rendering).
    pub fn buckets(&self) -> &[u64] {
        &self.buckets[..]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_defined_zero() {
        let h = Histogram::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min_us(), 0);
        assert_eq!(h.max_us(), 0);
        let s = h.summary();
        assert!(s.starts_with("n=0"), "summary of empty hist: {s}");
        assert!(!s.contains("NaN"), "summary must never render NaN: {s}");
    }

    #[test]
    fn buckets_are_monotone_and_tight() {
        let mut last = usize::MAX;
        for us in [0u64, 1, 31, 32, 33, 63, 64, 100, 1000, 123_456, u64::MAX] {
            let idx = bucket_index(us);
            assert!(idx < N_BUCKETS, "index {idx} for {us}");
            if last != usize::MAX {
                assert!(idx >= last, "bucket index regressed at {us}");
            }
            last = idx;
            let rep = bucket_value(idx);
            let err = rep.abs_diff(us) as f64 / us.max(1) as f64;
            assert!(us >= UNIT as u64 || rep == us, "unit region must be exact for {us}");
            if us < u64::MAX / 2 {
                assert!(err <= 0.125 + 1e-9, "rep {rep} for {us}: rel err {err}");
            }
        }
    }

    #[test]
    fn percentile_within_bucket_tolerance() {
        let mut h = Histogram::new();
        for i in 1..=100u64 {
            h.record_us(i);
        }
        assert!((h.percentile(50.0) - 50.0).abs() <= 50.0 * 0.15);
        assert!((h.percentile(99.0) - 99.0).abs() <= 99.0 * 0.15);
        assert!((h.mean() - 50.5).abs() < 1e-9, "mean is exact");
        assert_eq!(h.max_us(), 100);
        assert_eq!(h.min_us(), 1);
    }

    #[test]
    fn single_sample_is_exact() {
        let mut h = Histogram::new();
        h.record_us(777);
        // midpoint clamps to the exact [min, max] window
        assert_eq!(h.percentile(0.0), 777.0);
        assert_eq!(h.percentile(50.0), 777.0);
        assert_eq!(h.percentile(100.0), 777.0);
    }

    #[test]
    fn merge_matches_sequential() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for i in 0..500u64 {
            let us = i * 17 % 9001;
            if i % 2 == 0 {
                a.record_us(us);
            } else {
                b.record_us(us);
            }
            whole.record_us(us);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.sum_us(), whole.sum_us());
        assert_eq!(a.max_us(), whole.max_us());
        assert_eq!(a.percentile(99.0), whole.percentile(99.0));
    }
}
