//! End-to-end observability layer: an exportable metrics registry,
//! request tracing, and the exposition formats the serving stack
//! reports through.
//!
//! Three sub-layers, hot-to-cold:
//!
//! * [`record`] — the lock-free record-path primitives: per-worker
//!   sharded [`Counter`]s, [`Gauge`]s, sharded histograms and the
//!   [`LogLimiter`] gate. Integer-only, allocation-free, atomics via
//!   the `check::sync` facade (pinned by `cargo xtask lint`).
//! * [`trace`] — fixed-size request-path events in per-worker ring
//!   buffers ([`TraceBuf`]) behind a pluggable [`Clock`], so a seeded
//!   chaos run is fully reconstructable from its traces
//!   (rust/tests/obs.rs).
//! * [`hist`] — the shared fixed-bucket integer [`Histogram`] every
//!   latency stat in the tree now uses (`metrics::LatencyHist` is a
//!   re-export).
//!
//! [`MetricsRegistry`] names the metrics: handles are pre-allocated at
//! registration (one lock per *registration*, zero locks per *record*),
//! and [`MetricsRegistry::snapshot`] merges the shards on read. The
//! snapshot renders as Prometheus text ([`prometheus_text`]) or JSON
//! ([`samples_json`]) — the `fqconv stats` subcommand and
//! `serve::Server::metrics_text` are thin wrappers over these.

pub mod hist;
pub mod record;
pub mod trace;

pub use hist::Histogram;
pub use record::{Counter, Gauge, LogLimiter, ShardedHist};
pub use trace::{Clock, EventKind, FakeClock, MonotonicClock, TraceBuf, TraceEvent};

use crate::check::sync::Mutex;
use std::sync::Arc;

use crate::util::json::{num, obj, s, Json};

// ---------------------------------------------------------------------------
// Config
// ---------------------------------------------------------------------------

/// Observability configuration for a serving registry.
#[derive(Clone)]
pub struct ObsConfig {
    /// Master switch: when false, trace/metric record calls are no-ops
    /// (the bench's `obs_overhead` section measures the difference).
    pub enabled: bool,
    /// Trace ring capacity per writer shard (events retained).
    pub trace_capacity: usize,
    /// Timestamp source for trace events — inject a [`FakeClock`] for
    /// deterministic tests.
    pub clock: Arc<dyn Clock>,
}

impl Default for ObsConfig {
    fn default() -> Self {
        ObsConfig {
            enabled: true,
            trace_capacity: 4096,
            clock: Arc::new(MonotonicClock::new()),
        }
    }
}

impl ObsConfig {
    /// Everything off — the metrics-off baseline configuration.
    pub fn disabled() -> Self {
        ObsConfig { enabled: false, ..Default::default() }
    }

    /// Replace the trace clock (deterministic tests).
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.clock = clock;
        self
    }

    /// Replace the per-shard trace ring capacity.
    pub fn with_trace_capacity(mut self, capacity: usize) -> Self {
        self.trace_capacity = capacity;
        self
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Hist(ShardedHist),
}

struct Entry {
    name: &'static str,
    labels: String,
    metric: Metric,
}

/// Named metrics, registered once and recorded lock-free.
///
/// Registration (`counter`/`gauge`/`histogram`) takes the registry lock
/// and pre-allocates the shard storage; the returned handle records
/// with atomics only, so the hot path never touches the lock, never
/// allocates, and never sees a float. Registering the same
/// `(name, labels)` twice returns a handle to the same storage, so
/// independent components can share a metric by name.
pub struct MetricsRegistry {
    shards: usize,
    entries: Mutex<Vec<Entry>>,
}

impl MetricsRegistry {
    /// A registry whose sharded metrics split across `shards` writers
    /// (one per serve worker, typically).
    pub fn new(shards: usize) -> Self {
        MetricsRegistry { shards: shards.max(1), entries: Mutex::new(Vec::new()) }
    }

    fn register<T: Clone>(
        &self,
        name: &'static str,
        labels: &str,
        pick: impl Fn(&Metric) -> Option<T>,
        make: impl FnOnce(usize) -> (Metric, T),
    ) -> T {
        let mut entries = self.entries.lock().unwrap();
        for e in entries.iter() {
            if e.name == name && e.labels == labels {
                if let Some(h) = pick(&e.metric) {
                    return h;
                }
                panic!("metric {name}{{{labels}}} re-registered as a different type");
            }
        }
        let (metric, handle) = make(self.shards);
        entries.push(Entry { name, labels: labels.to_string(), metric });
        handle
    }

    /// Register (or look up) a sharded counter.
    pub fn counter(&self, name: &'static str, labels: &str) -> Counter {
        self.register(
            name,
            labels,
            |m| match m {
                Metric::Counter(c) => Some(c.clone()),
                _ => None,
            },
            |shards| {
                let c = Counter::new(shards);
                (Metric::Counter(c.clone()), c)
            },
        )
    }

    /// Register (or look up) a gauge.
    pub fn gauge(&self, name: &'static str, labels: &str) -> Gauge {
        self.register(
            name,
            labels,
            |m| match m {
                Metric::Gauge(g) => Some(g.clone()),
                _ => None,
            },
            |_| {
                let g = Gauge::new();
                (Metric::Gauge(g.clone()), g)
            },
        )
    }

    /// Register (or look up) a sharded fixed-bucket histogram.
    pub fn histogram(&self, name: &'static str, labels: &str) -> ShardedHist {
        self.register(
            name,
            labels,
            |m| match m {
                Metric::Hist(h) => Some(h.clone()),
                _ => None,
            },
            |shards| {
                let h = ShardedHist::new(shards);
                (Metric::Hist(h.clone()), h)
            },
        )
    }

    /// Merge-on-read snapshot of every registered metric, sorted by
    /// `(name, labels)` so exposition is deterministic.
    pub fn snapshot(&self) -> Vec<MetricSample> {
        let entries = self.entries.lock().unwrap();
        let mut out: Vec<MetricSample> = entries
            .iter()
            .map(|e| MetricSample {
                name: e.name,
                labels: e.labels.clone(),
                value: match &e.metric {
                    Metric::Counter(c) => SampleValue::Counter(c.total()),
                    Metric::Gauge(g) => SampleValue::Gauge(g.get()),
                    Metric::Hist(h) => SampleValue::Hist(h.snapshot()),
                },
            })
            .collect();
        drop(entries);
        out.sort_by(|a, b| (a.name, &a.labels).cmp(&(b.name, &b.labels)));
        out
    }
}

/// One metric's merged value at snapshot time.
pub enum SampleValue {
    Counter(u64),
    Gauge(u64),
    Hist(Histogram),
}

/// One `(name, labels)` entry of a registry snapshot.
pub struct MetricSample {
    pub name: &'static str,
    /// Pre-rendered Prometheus label pairs, e.g. `model="kws",lane="0"`
    /// (empty for unlabelled metrics).
    pub labels: String,
    pub value: SampleValue,
}

// ---------------------------------------------------------------------------
// Exposition
// ---------------------------------------------------------------------------

fn promline(out: &mut String, name: &str, suffix: &str, labels: &str, value: f64) {
    out.push_str(name);
    out.push_str(suffix);
    if !labels.is_empty() {
        out.push('{');
        out.push_str(labels);
        out.push('}');
    }
    if value.fract() == 0.0 && value.abs() < 9e15 {
        out.push_str(&format!(" {}\n", value as i64));
    } else {
        out.push_str(&format!(" {value}\n"));
    }
}

/// Render a snapshot in the Prometheus text exposition format.
/// Histograms are summarized as `_count` / `_sum_us` / `_p50_us` /
/// `_p99_us` / `_max_us` series (quantiles merged from the shards).
pub fn prometheus_text(samples: &[MetricSample]) -> String {
    let mut out = String::new();
    let mut last_name = "";
    for smp in samples {
        if smp.name != last_name {
            let ty = match smp.value {
                SampleValue::Counter(_) => "counter",
                SampleValue::Gauge(_) => "gauge",
                SampleValue::Hist(_) => "summary",
            };
            out.push_str(&format!("# TYPE {} {ty}\n", smp.name));
            last_name = smp.name;
        }
        match &smp.value {
            SampleValue::Counter(v) | SampleValue::Gauge(v) => {
                promline(&mut out, smp.name, "", &smp.labels, *v as f64);
            }
            SampleValue::Hist(h) => {
                promline(&mut out, smp.name, "_count", &smp.labels, h.count() as f64);
                promline(&mut out, smp.name, "_sum_us", &smp.labels, h.sum_us() as f64);
                promline(&mut out, smp.name, "_p50_us", &smp.labels, h.percentile(50.0));
                promline(&mut out, smp.name, "_p99_us", &smp.labels, h.percentile(99.0));
                promline(&mut out, smp.name, "_max_us", &smp.labels, h.max_us() as f64);
            }
        }
    }
    out
}

/// Render a snapshot as a JSON array of `{name, labels, ...}` records.
pub fn samples_json(samples: &[MetricSample]) -> Json {
    let rows = samples
        .iter()
        .map(|smp| match &smp.value {
            SampleValue::Counter(v) => obj(vec![
                ("name", s(smp.name)),
                ("labels", s(&smp.labels)),
                ("type", s("counter")),
                ("value", num(*v as f64)),
            ]),
            SampleValue::Gauge(v) => obj(vec![
                ("name", s(smp.name)),
                ("labels", s(&smp.labels)),
                ("type", s("gauge")),
                ("value", num(*v as f64)),
            ]),
            SampleValue::Hist(h) => obj(vec![
                ("name", s(smp.name)),
                ("labels", s(&smp.labels)),
                ("type", s("histogram")),
                ("count", num(h.count() as f64)),
                ("sum_us", num(h.sum_us() as f64)),
                ("p50_us", num(h.percentile(50.0))),
                ("p99_us", num(h.percentile(99.0))),
                ("max_us", num(h.max_us() as f64)),
            ]),
        })
        .collect();
    Json::Arr(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_roundtrip() {
        let reg = MetricsRegistry::new(2);
        let c = reg.counter("fqconv_test_total", "model=\"kws\"");
        c.add(0, 3);
        c.add(1, 4);
        // same (name, labels) → same storage
        reg.counter("fqconv_test_total", "model=\"kws\"").inc(0);
        let g = reg.gauge("fqconv_test_depth", "");
        g.set(9);
        let h = reg.histogram("fqconv_test_latency", "");
        h.record_us(0, 100);
        h.record_us(1, 200);

        let snap = reg.snapshot();
        assert_eq!(snap.len(), 3);
        let total = snap
            .iter()
            .find_map(|smp| match (&smp.value, smp.name) {
                (SampleValue::Counter(v), "fqconv_test_total") => Some(*v),
                _ => None,
            })
            .unwrap();
        assert_eq!(total, 8);

        let text = prometheus_text(&snap);
        assert!(text.contains("# TYPE fqconv_test_total counter"), "{text}");
        assert!(text.contains("fqconv_test_total{model=\"kws\"} 8"), "{text}");
        assert!(text.contains("fqconv_test_depth 9"), "{text}");
        assert!(text.contains("fqconv_test_latency_count 2"), "{text}");
        assert!(text.contains("fqconv_test_latency_sum_us 300"), "{text}");

        let j = samples_json(&snap).to_string();
        assert!(j.contains("\"fqconv_test_depth\""), "{j}");
    }

    #[test]
    #[should_panic(expected = "different type")]
    fn type_confusion_is_loud() {
        let reg = MetricsRegistry::new(1);
        let _c = reg.counter("fqconv_conflict", "");
        let _g = reg.gauge("fqconv_conflict", "");
    }

    #[test]
    fn disabled_config_flags_off() {
        assert!(ObsConfig::default().enabled);
        assert!(!ObsConfig::disabled().enabled);
    }
}
