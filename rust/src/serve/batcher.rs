//! Dynamic-batching policy (pure logic, no threads — unit-testable).
//!
//! The policy mirrors the classic serving trade-off: a batch closes when
//! it reaches `max_batch` (throughput bound) or when the oldest queued
//! request has waited `max_wait_us` (latency bound). Requests carry a
//! [`Priority`] class — the batcher keeps one forming batch *per
//! priority* and the shared work queue serves Interactive batches before
//! Batch ones — and an optional absolute deadline: the batcher wakes at
//! the earliest pending deadline, so a doomed request is answered with
//! a typed error promptly at its deadline (early expiry) — and whatever
//! slips through is still expired at dispatch or at worker pop.
//!
//! [`simulate`] / [`simulate_prio`] / [`simulate_prio_bounded`] are
//! discrete-time models of the threaded loop (`serve`), used by the
//! property tests in rust/tests/properties.rs: no admissible arrival
//! sequence may starve a request beyond `max_wait_us` + backlog, an
//! Interactive batch never waits behind a Batch-priority batch it was
//! ready before, and a deadlined request is either dispatched by its
//! deadline or expired — never silently lost. The bounded variant adds
//! the registry's admission control: a lane at its pending bound
//! refuses new arrivals with [`SimOutcome::Shed`] *at submit* — a shed
//! is never deferred to a deadline.
//!
//! Because this module is pure (no locks, no threads), it needs nothing
//! from the `crate::check::sync` facade; the *threaded* batcher loop in
//! `serve` that drives this policy is swept onto the facade and its
//! queue/registry protocols are model-checked under
//! `--features model-check` (see CONCURRENCY.md for the invariants).

/// Request priority class. Interactive batches are pulled from the
/// shared work queue before Batch-priority ones; within a class,
/// batches stay FIFO.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Priority {
    #[default]
    Interactive,
    Batch,
}

impl Priority {
    /// Both classes, in queue-pop order.
    pub const ALL: [Priority; 2] = [Priority::Interactive, Priority::Batch];

    /// Dense index for per-priority tables (pop order).
    pub fn index(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    /// close the batch at this size
    pub max_batch: usize,
    /// close the batch when the oldest request has waited this long
    pub max_wait_us: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait_us: 2_000 }
    }
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_wait_us: u64) -> Self {
        assert!(max_batch >= 1);
        BatchPolicy { max_batch, max_wait_us }
    }
}

/// Streaming-session idle-sweep cadence: how often a streaming model's
/// batcher scans its session table for idle-timeout evictions (and the
/// cap on that batcher's recv timeout, so the sweep keeps ticking on a
/// quiet ingress). One linear scan of the slab per tick — 10k slots per
/// 10 ms is noise next to a single feed's conv work.
pub const SESSION_SWEEP_TICK: std::time::Duration = std::time::Duration::from_millis(10);

/// One simulated request for [`simulate_prio`]. Times are absolute
/// microseconds; `deadline_us` is the instant after which the request
/// must not start inference.
#[derive(Clone, Copy, Debug)]
pub struct SimRequest {
    pub arrival_us: u64,
    pub priority: Priority,
    pub deadline_us: Option<u64>,
}

impl SimRequest {
    pub fn at(arrival_us: u64, priority: Priority) -> Self {
        SimRequest { arrival_us, priority, deadline_us: None }
    }
}

/// Per-request outcome of [`simulate_prio`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimOutcome {
    /// rode a batch: closed at `closed_us`, inference started at
    /// `start_us`, `batch` survivors ran together
    Dispatched { closed_us: u64, start_us: u64, batch: usize },
    /// deadline elapsed before the batch could start; answered with
    /// `ServeError::DeadlineExceeded` at `at_us`
    Expired { at_us: u64 },
    /// refused admission: the lane already held its bound of pending
    /// requests at the arrival instant, so the request was answered
    /// with `ServeError::Overloaded` **at submit** (`at_us` is always
    /// the arrival time — shedding never waits for a deadline)
    Shed { at_us: u64 },
}

impl SimOutcome {
    pub fn start_us(&self) -> Option<u64> {
        match self {
            SimOutcome::Dispatched { start_us, .. } => Some(*start_us),
            SimOutcome::Expired { .. } | SimOutcome::Shed { .. } => None,
        }
    }
}

/// A closed batch travelling through the simulated queue.
struct SimBatch {
    priority: Priority,
    closed_us: u64,
    members: Vec<usize>,
}

/// Discrete-time simulation of the priority batcher + single worker
/// over the two-lane shared queue (used by tests and the
/// batching-policy ablation bench).
///
/// Mirrors `serve`'s threaded loop: per-priority forming batches close
/// on size or on the oldest member's `max_wait_us` timer (an arrival
/// landing exactly at the timer instant starts the next batch); closed
/// batches queue per lane; the worker always pops the Interactive lane
/// first. Deadlines expire in two places, mirroring the threaded loop:
/// a member whose deadline passes while its batch is still *forming*
/// is expired **early** at the deadline wake (`max(deadline + 1,
/// arrival)` — the batcher checks strictly after the deadline, and
/// cannot act before the request exists); at pop time, members whose
/// deadline lies strictly before the inference start are expired out
/// of the batch. One idealization: an early-expired member still
/// occupies its forming-batch slot for the close-time computation
/// (the threaded loop frees the slot at the expiry wake, so a later
/// arrival may close marginally differently); the tested invariants —
/// expiry strictly after the deadline, dispatch never past it — hold
/// under both accountings.
pub fn simulate_prio(
    policy: BatchPolicy,
    reqs: &[SimRequest],
    service_us: u64,
) -> Vec<SimOutcome> {
    simulate_prio_bounded(policy, None, reqs, service_us)
}

/// [`simulate_prio`] with per-lane admission control: with
/// `bound = Some(B)`, a request arriving while its priority lane
/// already holds `B` pending admitted requests is refused at submit
/// with [`SimOutcome::Shed`] at its own arrival instant. "Pending"
/// mirrors the threaded registry's reservation counter: a request
/// holds its slot from arrival until its *terminal reply* — the end of
/// its service (`start_us + service_us`) or its expiry — not merely
/// until dispatch. Shed requests occupy no slot, join no batch, and
/// never expire. `bound = None` is exactly [`simulate_prio`].
///
/// Computed as a fixpoint: shedding the first over-bound arrival
/// changes every later batch composition, so the simulation re-runs on
/// the surviving set until no arrival finds its lane full. Each round
/// sheds exactly one request, so it terminates.
pub fn simulate_prio_bounded(
    policy: BatchPolicy,
    bound: Option<usize>,
    reqs: &[SimRequest],
    service_us: u64,
) -> Vec<SimOutcome> {
    let mut admitted = vec![true; reqs.len()];
    loop {
        let out = simulate_admitted(policy, reqs, service_us, &admitted);
        let Some(b) = bound else { return out };
        // departure instant of each admitted request: when its terminal
        // reply releases the lane slot (service end, or typed expiry)
        let depart: Vec<u64> = out
            .iter()
            .map(|o| match *o {
                SimOutcome::Dispatched { start_us, .. } => start_us + service_us,
                SimOutcome::Expired { at_us } | SimOutcome::Shed { at_us } => at_us,
            })
            .collect();
        // first arrival that found its lane full (ties broken by
        // submission order = index order)
        let victim = (0..reqs.len()).find(|&i| {
            if !admitted[i] {
                return false;
            }
            let lane = reqs[i].priority.index();
            let t = reqs[i].arrival_us;
            let held = (0..i)
                .filter(|&j| {
                    admitted[j] && reqs[j].priority.index() == lane && depart[j] > t
                })
                .count();
            held >= b
        });
        match victim {
            Some(i) => admitted[i] = false,
            None => return out,
        }
    }
}

/// One simulation pass over the admitted subset; non-admitted requests
/// are reported [`SimOutcome::Shed`] at their arrival and are invisible
/// to batching, queueing, and the worker.
fn simulate_admitted(
    policy: BatchPolicy,
    reqs: &[SimRequest],
    service_us: u64,
    admitted: &[bool],
) -> Vec<SimOutcome> {
    debug_assert!(reqs.windows(2).all(|w| w[0].arrival_us <= w[1].arrival_us));
    let mut out: Vec<SimOutcome> = reqs
        .iter()
        .enumerate()
        .map(|(i, r)| {
            if admitted[i] {
                SimOutcome::Expired { at_us: 0 }
            } else {
                SimOutcome::Shed { at_us: r.arrival_us }
            }
        })
        .collect();

    // --- phase 1: close batches per priority (independent of the queue
    // and worker state, exactly as in the threaded batcher) ------------
    let mut batches: Vec<SimBatch> = Vec::new();
    for prio in Priority::ALL {
        let idx: Vec<usize> =
            (0..reqs.len()).filter(|&i| admitted[i] && reqs[i].priority == prio).collect();
        let mut i = 0;
        while i < idx.len() {
            let open = reqs[idx[i]].arrival_us;
            let deadline = open + policy.max_wait_us;
            // collect while size and timer admit; strictly *before* the
            // timer instant (the threaded recv_timeout has already fired
            // at `deadline`, so a boundary arrival starts the next batch)
            let mut j = i + 1;
            while j < idx.len() && j - i < policy.max_batch && reqs[idx[j]].arrival_us < deadline {
                j += 1;
            }
            let closed_us = if j - i == policy.max_batch {
                reqs[idx[j - 1]].arrival_us // filled up
            } else {
                deadline // timer fired
            };
            // early expiry: a deadline that passes before the batch
            // closes is answered at its own wake, not at dispatch
            let mut members = Vec::with_capacity(j - i);
            for &r in &idx[i..j] {
                match reqs[r].deadline_us {
                    Some(d) if d < closed_us => {
                        out[r] =
                            SimOutcome::Expired { at_us: (d + 1).max(reqs[r].arrival_us) };
                    }
                    _ => members.push(r),
                }
            }
            batches.push(SimBatch { priority: prio, closed_us, members });
            i = j;
        }
    }

    // --- phase 2: one worker drains the two-lane queue ----------------
    // Lanes are FIFO; close times are non-decreasing within a lane.
    let mut lane_pos = [0usize; 2]; // next unserved batch per lane
    let mut lanes: [Vec<&SimBatch>; 2] = [Vec::new(), Vec::new()];
    for b in &batches {
        lanes[b.priority.index()].push(b);
    }
    lanes.iter_mut().for_each(|l| l.sort_by_key(|b| b.closed_us));
    let mut worker_free_at = 0u64;
    loop {
        // among unserved batches, those closed by `worker_free_at` are
        // "in the queue"; the Interactive lane pops first. If none is
        // ready, the worker sleeps until the earliest close.
        let ready_lane = Priority::ALL
            .into_iter()
            .map(Priority::index)
            .find(|&li| {
                lane_pos[li] < lanes[li].len()
                    && lanes[li][lane_pos[li]].closed_us <= worker_free_at
            });
        let li = match ready_lane {
            Some(li) => li,
            None => {
                // nothing queued yet: jump to the earliest next close
                // (Interactive wins a tie — same pop-order rule)
                let next = Priority::ALL
                    .into_iter()
                    .map(Priority::index)
                    .filter(|&li| lane_pos[li] < lanes[li].len())
                    .min_by_key(|&li| (lanes[li][lane_pos[li]].closed_us, li));
                match next {
                    Some(li) => {
                        worker_free_at = worker_free_at.max(lanes[li][lane_pos[li]].closed_us);
                        li
                    }
                    None => break, // every batch served
                }
            }
        };
        let b = lanes[li][lane_pos[li]];
        lane_pos[li] += 1;
        let start = b.closed_us.max(worker_free_at);
        // expire members whose deadline lies strictly before the start
        let survivors: Vec<usize> = b
            .members
            .iter()
            .copied()
            .filter(|&r| match reqs[r].deadline_us {
                Some(d) => {
                    if d < start {
                        out[r] = SimOutcome::Expired { at_us: start };
                        false
                    } else {
                        true
                    }
                }
                None => true,
            })
            .collect();
        if survivors.is_empty() {
            continue; // nothing to run; the worker stays free
        }
        for &r in &survivors {
            out[r] = SimOutcome::Dispatched {
                closed_us: b.closed_us,
                start_us: start,
                batch: survivors.len(),
            };
        }
        worker_free_at = start + service_us;
    }
    out
}

/// Single-priority, no-deadline view of [`simulate_prio`]: given arrival
/// times (us), returns per-request (dispatch_time, batch_size). Kept as
/// the stable interface of the original batcher model.
pub fn simulate(policy: BatchPolicy, arrivals_us: &[u64], service_us: u64) -> Vec<(u64, usize)> {
    let reqs: Vec<SimRequest> =
        arrivals_us.iter().map(|&t| SimRequest::at(t, Priority::Interactive)).collect();
    simulate_prio(policy, &reqs, service_us)
        .into_iter()
        .map(|o| match o {
            SimOutcome::Dispatched { start_us, batch, .. } => (start_us, batch),
            SimOutcome::Expired { .. } => unreachable!("no deadlines in simulate()"),
            SimOutcome::Shed { .. } => unreachable!("no admission bound in simulate()"),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_to_max_batch() {
        let p = BatchPolicy::new(4, 1_000_000);
        let arr: Vec<u64> = (0..8).map(|i| i * 10).collect();
        let d = simulate(p, &arr, 100);
        assert_eq!(d[0].1, 4);
        assert_eq!(d[4].1, 4);
    }

    #[test]
    fn timer_closes_partial_batch() {
        let p = BatchPolicy::new(16, 500);
        let arr = vec![0, 100, 10_000];
        let d = simulate(p, &arr, 50);
        assert_eq!(d[0].1, 2); // first two ride together
        assert_eq!(d[0].0, 500); // dispatched at deadline
        assert_eq!(d[2].1, 1);
    }

    #[test]
    fn no_request_waits_beyond_deadline_plus_service() {
        // arrivals outpaceable by the worker: each batch spans more time
        // than one service, so the backlog never grows and the tight
        // bound max_wait + one service time must hold for every request
        let p = BatchPolicy::new(8, 1_000);
        let arr: Vec<u64> = (0..50).map(|i| i * 137).collect();
        let service = 200;
        for (k, &(start, _)) in simulate(p, &arr, service).iter().enumerate() {
            assert!(
                start.saturating_sub(arr[k]) <= p.max_wait_us + service,
                "request {k} starved: waited {}",
                start - arr[k]
            );
        }
    }

    #[test]
    fn arrival_exactly_at_deadline_starts_next_batch() {
        // the threaded batcher times out *at* the deadline, so an arrival
        // landing exactly then must ride the following batch
        let p = BatchPolicy::new(16, 500);
        let arr = vec![0, 500];
        let d = simulate(p, &arr, 10);
        assert_eq!(d[0], (500, 1), "first batch closes at its own deadline, alone");
        assert_eq!(d[1], (1_000, 1), "boundary arrival opens a fresh batch");
    }

    #[test]
    fn batch_one_behaves_like_no_batching() {
        let p = BatchPolicy::new(1, 1_000_000);
        let arr = vec![0, 5, 10];
        let d = simulate(p, &arr, 100);
        assert!(d.iter().all(|&(_, s)| s == 1));
        // sequential service
        assert_eq!(d[0].0, 0);
        assert_eq!(d[1].0, 100);
        assert_eq!(d[2].0, 200);
    }

    #[test]
    fn interactive_lane_pops_before_batch_lane() {
        // both lanes close a batch at t=100 while the worker is busy
        // until t=10_000: the Interactive batch must start first
        let p = BatchPolicy::new(1, 100);
        let reqs = vec![
            SimRequest::at(0, Priority::Batch), // served first (worker idle)
            SimRequest::at(50, Priority::Batch),
            SimRequest::at(60, Priority::Interactive),
        ];
        let d = simulate_prio(p, &reqs, 10_000);
        let s1 = d[1].start_us().unwrap();
        let s2 = d[2].start_us().unwrap();
        assert!(s2 < s1, "interactive ({s2}) must preempt queued batch lane ({s1})");
    }

    #[test]
    fn expired_member_leaves_the_batch() {
        // request 1's deadline (5) already lies before the batch start
        // (10): it is expired out and request 0 runs alone — the expired
        // member must not count toward the reported batch size
        let p = BatchPolicy::new(2, 100);
        let reqs = vec![
            SimRequest::at(0, Priority::Interactive),
            SimRequest { arrival_us: 10, priority: Priority::Interactive, deadline_us: Some(5) },
        ];
        let d = simulate_prio(p, &reqs, 50);
        assert_eq!(d[0], SimOutcome::Dispatched { closed_us: 10, start_us: 10, batch: 1 });
        assert_eq!(d[1], SimOutcome::Expired { at_us: 10 });
    }

    #[test]
    fn queued_request_expires_behind_a_slow_service() {
        // worker busy until t=5_000; request 1's deadline (1_000) passes
        // while its batch waits in the queue -> typed expiry, and the
        // later request still runs
        let p = BatchPolicy::new(1, 100);
        let queued = SimRequest {
            arrival_us: 10,
            priority: Priority::Interactive,
            deadline_us: Some(1_000),
        };
        let reqs = vec![
            SimRequest::at(0, Priority::Interactive),
            queued,
            SimRequest::at(20, Priority::Interactive),
        ];
        let d = simulate_prio(p, &reqs, 5_000);
        assert_eq!(d[1], SimOutcome::Expired { at_us: 5_000 });
        assert_eq!(d[2], SimOutcome::Dispatched { closed_us: 20, start_us: 5_000, batch: 1 });
    }

    #[test]
    fn doomed_request_expires_at_its_deadline_not_at_dispatch() {
        // the forming batch stays open until t=10_000 (big max_batch,
        // long timer); the deadlined member must be answered at its own
        // deadline wake (101), not held hostage until dispatch
        let p = BatchPolicy::new(16, 10_000);
        let reqs = vec![
            SimRequest::at(0, Priority::Interactive),
            SimRequest {
                arrival_us: 0,
                priority: Priority::Interactive,
                deadline_us: Some(100),
            },
        ];
        let d = simulate_prio(p, &reqs, 50);
        assert_eq!(d[1], SimOutcome::Expired { at_us: 101 }, "early expiry at the deadline");
        assert_eq!(
            d[0],
            SimOutcome::Dispatched { closed_us: 10_000, start_us: 10_000, batch: 1 },
            "the survivor still rides the timer-closed batch alone"
        );
    }

    #[test]
    fn already_overdue_arrival_expires_at_arrival() {
        // a request that arrives with its deadline already past cannot
        // be answered before it exists: expiry clamps to the arrival
        let p = BatchPolicy::new(16, 500);
        let reqs = vec![SimRequest {
            arrival_us: 40,
            priority: Priority::Batch,
            deadline_us: Some(5),
        }];
        let d = simulate_prio(p, &reqs, 10);
        assert_eq!(d[0], SimOutcome::Expired { at_us: 40 });
    }

    #[test]
    fn bound_one_sheds_the_overlapping_arrival_at_submit() {
        // batch-of-one, slow worker: request 0 holds its lane slot until
        // its reply at t=5_000, so request 1 (same lane, arrives at
        // t=10) finds the lane full and is shed at its own arrival —
        // request 2 arrives after the reply and rides normally
        let p = BatchPolicy::new(1, 100);
        let reqs = vec![
            SimRequest::at(0, Priority::Interactive),
            SimRequest::at(10, Priority::Interactive),
            SimRequest::at(6_000, Priority::Interactive),
        ];
        let d = simulate_prio_bounded(p, Some(1), &reqs, 5_000);
        assert_eq!(d[0], SimOutcome::Dispatched { closed_us: 0, start_us: 0, batch: 1 });
        assert_eq!(d[1], SimOutcome::Shed { at_us: 10 }, "shed at submit, not later");
        assert_eq!(d[2], SimOutcome::Dispatched { closed_us: 6_000, start_us: 6_000, batch: 1 });
    }

    #[test]
    fn lanes_have_independent_bounds() {
        // the Interactive lane being full must not shed a Batch arrival
        let p = BatchPolicy::new(1, 100);
        let reqs = vec![
            SimRequest::at(0, Priority::Interactive),
            SimRequest::at(10, Priority::Batch),
        ];
        let d = simulate_prio_bounded(p, Some(1), &reqs, 5_000);
        assert!(matches!(d[0], SimOutcome::Dispatched { .. }));
        assert!(matches!(d[1], SimOutcome::Dispatched { .. }));
    }

    #[test]
    fn unbounded_delegation_is_identical() {
        let p = BatchPolicy::new(4, 700);
        let reqs: Vec<SimRequest> = (0..30)
            .map(|i| {
                let prio = if i % 3 == 0 { Priority::Batch } else { Priority::Interactive };
                SimRequest { arrival_us: i * 61, priority: prio, deadline_us: Some(i * 61 + 900) }
            })
            .collect();
        assert_eq!(
            simulate_prio(p, &reqs, 350),
            simulate_prio_bounded(p, None, &reqs, 350)
        );
    }

    #[test]
    fn shed_request_frees_no_slot_and_joins_no_batch() {
        // bound 1, three simultaneous-ish arrivals: only the first is
        // admitted while it is pending; the shed ones must not inflate
        // any batch size
        let p = BatchPolicy::new(8, 100);
        let reqs = vec![
            SimRequest::at(0, Priority::Interactive),
            SimRequest::at(1, Priority::Interactive),
            SimRequest::at(2, Priority::Interactive),
        ];
        let d = simulate_prio_bounded(p, Some(1), &reqs, 50);
        assert_eq!(d[0], SimOutcome::Dispatched { closed_us: 100, start_us: 100, batch: 1 });
        assert_eq!(d[1], SimOutcome::Shed { at_us: 1 });
        assert_eq!(d[2], SimOutcome::Shed { at_us: 2 });
    }

    #[test]
    fn deadline_at_start_instant_still_rides() {
        // expiry is strict (deadline < start): a deadline exactly at the
        // dispatch instant is honored
        let p = BatchPolicy::new(1, 50);
        let reqs = vec![SimRequest {
            arrival_us: 0,
            priority: Priority::Interactive,
            deadline_us: Some(0),
        }];
        let d = simulate_prio(p, &reqs, 10);
        assert_eq!(d[0], SimOutcome::Dispatched { closed_us: 0, start_us: 0, batch: 1 });
    }
}
