//! Dynamic-batching policy (pure logic, no threads — unit-testable).
//!
//! The policy mirrors the classic serving trade-off: a batch closes when
//! it reaches `max_batch` (throughput bound) or when the oldest queued
//! request has waited `max_wait_us` (latency bound). The property tests
//! in rust/tests/properties.rs check that no admissible sequence of
//! arrivals can starve a request beyond `max_wait_us` + one service time.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BatchPolicy {
    /// close the batch at this size
    pub max_batch: usize,
    /// close the batch when the oldest request has waited this long
    pub max_wait_us: u64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 16, max_wait_us: 2_000 }
    }
}

impl BatchPolicy {
    pub fn new(max_batch: usize, max_wait_us: u64) -> Self {
        assert!(max_batch >= 1);
        BatchPolicy { max_batch, max_wait_us }
    }
}

/// Discrete-time simulation of the batcher (used by tests and the
/// batching-policy ablation bench): given arrival times (us), returns
/// per-request (dispatch_time, batch_size).
pub fn simulate(policy: BatchPolicy, arrivals_us: &[u64], service_us: u64) -> Vec<(u64, usize)> {
    let mut out = vec![(0u64, 0usize); arrivals_us.len()];
    let mut i = 0;
    let mut worker_free_at = 0u64;
    while i < arrivals_us.len() {
        let open = arrivals_us[i];
        let deadline = open + policy.max_wait_us;
        // collect while size and deadline admit. Strictly *before* the
        // deadline: the threaded batcher's recv_timeout has already fired
        // at `deadline`, so an arrival landing exactly then starts the
        // next batch (keeps simulate() aligned with serve::batcher_loop)
        let mut j = i + 1;
        while j < arrivals_us.len()
            && j - i < policy.max_batch
            && arrivals_us[j] < deadline
        {
            j += 1;
        }
        let size = j - i;
        let close = if size == policy.max_batch {
            arrivals_us[j - 1] // filled up
        } else {
            deadline // timer fired
        };
        let start = close.max(worker_free_at);
        worker_free_at = start + service_us;
        for r in i..j {
            out[r] = (start, size);
        }
        i = j;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fills_to_max_batch() {
        let p = BatchPolicy::new(4, 1_000_000);
        let arr: Vec<u64> = (0..8).map(|i| i * 10).collect();
        let d = simulate(p, &arr, 100);
        assert_eq!(d[0].1, 4);
        assert_eq!(d[4].1, 4);
    }

    #[test]
    fn timer_closes_partial_batch() {
        let p = BatchPolicy::new(16, 500);
        let arr = vec![0, 100, 10_000];
        let d = simulate(p, &arr, 50);
        assert_eq!(d[0].1, 2); // first two ride together
        assert_eq!(d[0].0, 500); // dispatched at deadline
        assert_eq!(d[2].1, 1);
    }

    #[test]
    fn no_request_waits_beyond_deadline_plus_service() {
        // arrivals outpaceable by the worker: each batch spans more time
        // than one service, so the backlog never grows and the tight
        // bound max_wait + one service time must hold for every request
        let p = BatchPolicy::new(8, 1_000);
        let arr: Vec<u64> = (0..50).map(|i| i * 137).collect();
        let service = 200;
        for (k, &(start, _)) in simulate(p, &arr, service).iter().enumerate() {
            assert!(
                start.saturating_sub(arr[k]) <= p.max_wait_us + service,
                "request {k} starved: waited {}",
                start - arr[k]
            );
        }
    }

    #[test]
    fn arrival_exactly_at_deadline_starts_next_batch() {
        // the threaded batcher times out *at* the deadline, so an arrival
        // landing exactly then must ride the following batch
        let p = BatchPolicy::new(16, 500);
        let arr = vec![0, 500];
        let d = simulate(p, &arr, 10);
        assert_eq!(d[0], (500, 1), "first batch closes at its own deadline, alone");
        assert_eq!(d[1], (1_000, 1), "boundary arrival opens a fresh batch");
    }

    #[test]
    fn batch_one_behaves_like_no_batching() {
        let p = BatchPolicy::new(1, 1_000_000);
        let arr = vec![0, 5, 10];
        let d = simulate(p, &arr, 100);
        assert!(d.iter().all(|&(_, s)| s == 1));
        // sequential service
        assert_eq!(d[0].0, 0);
        assert_eq!(d[1].0, 100);
        assert_eq!(d[2].0, 200);
    }
}
