//! Serving layer: request router + dynamic batcher over the deployed
//! FQ network — the edge-inference story the paper motivates.
//!
//! Architecture (vLLM-router-like, scaled to the edge):
//!
//! ```text
//!  clients --> [ingress queue] --> batcher thread --(batches)--> worker pool
//!                                   (max_batch / max_wait_us)       |
//!  clients <---------------- per-request response channels <--------+
//! ```
//!
//! * [`batcher`] — pure batch-assembly policy (unit-testable, no threads)
//! * [`Server`]  — threads + channels glue; workers own backend replicas
//!
//! Backends: the native integer engine ([`NativeBackend`], per-sample,
//! batch-size-free) or the XLA deployment artifact ([`XlaBackend`],
//! fixed-batch with padding). Both are measured in `benches/perf_serve.rs`.

pub mod batcher;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use std::path::PathBuf;

use crate::infer::pipeline::{FqKwsNet, Scratch};
use crate::metrics::LatencyHist;
use crate::runtime::{hp, lit_f32, lit_to_vec_f32, Engine, Executable};
use crate::tensor::TensorF;

pub use batcher::BatchPolicy;

/// A classification request: one feature tensor (flattened sample).
pub struct Request {
    pub id: u64,
    pub features: Vec<f32>,
    submitted: Instant,
    reply: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub class: usize,
    pub latency_us: f64,
    /// size of the batch this request rode in (observability)
    pub batch_size: usize,
}

/// Inference backend executed by a worker.
pub trait Backend {
    /// (B, sample_numel) -> (B, classes)
    fn infer(&mut self, x: &TensorF) -> Result<TensorF>;
    fn sample_shape(&self) -> Vec<usize>;
}

/// Native integer engine backend (batch-size agnostic).
pub struct NativeBackend {
    pub net: Arc<FqKwsNet>,
    scratch: Scratch,
    shape: Vec<usize>,
}

impl NativeBackend {
    pub fn new(net: Arc<FqKwsNet>, shape: Vec<usize>) -> Self {
        NativeBackend { net, scratch: Scratch::default(), shape }
    }
}

impl Backend for NativeBackend {
    fn infer(&mut self, x: &TensorF) -> Result<TensorF> {
        let b = x.shape()[0];
        let per: usize = self.shape.iter().product();
        let mut out = Vec::with_capacity(b * self.net.classes);
        for i in 0..b {
            out.extend(self.net.forward(&x.data()[i * per..(i + 1) * per], &mut self.scratch));
        }
        Ok(TensorF::from_vec(&[b, self.net.classes], out))
    }

    fn sample_shape(&self) -> Vec<usize> {
        self.shape.clone()
    }
}

/// XLA deployment-artifact backend (fixed batch; pads partial batches).
///
/// NOTE: the `xla` crate's PJRT handles are not `Send` (Rc-based), so an
/// `XlaBackend` must be constructed *inside* its worker thread — use
/// [`XlaBackend::factory`] with [`Server::start`], which builds one
/// engine + compiled executable per worker.
pub struct XlaBackend {
    _engine: Engine,
    exe: Executable,
    params: Vec<(Vec<usize>, Vec<f32>)>,
    pub hp: [f32; hp::LEN],
    pub batch: usize,
    pub classes: usize,
    shape: Vec<usize>,
}

impl XlaBackend {
    /// Build in-thread from an artifact path + host-side parameters.
    pub fn load(
        artifact: &PathBuf,
        params: Vec<(Vec<usize>, Vec<f32>)>,
        hpv: [f32; hp::LEN],
        batch: usize,
        classes: usize,
        shape: Vec<usize>,
    ) -> Result<Self> {
        let engine = Engine::cpu()?;
        let exe = engine.load(artifact)?;
        Ok(XlaBackend { _engine: engine, exe, params, hp: hpv, batch, classes, shape })
    }

    /// A `Send` factory for [`Server::start`].
    pub fn factory(
        artifact: PathBuf,
        params: Vec<(Vec<usize>, Vec<f32>)>,
        hpv: [f32; hp::LEN],
        batch: usize,
        classes: usize,
        shape: Vec<usize>,
    ) -> BackendFactory {
        Box::new(move || {
            Box::new(
                XlaBackend::load(&artifact, params, hpv, batch, classes, shape)
                    .expect("building XLA backend"),
            ) as Box<dyn Backend>
        })
    }
}

impl Backend for XlaBackend {
    fn infer(&mut self, x: &TensorF) -> Result<TensorF> {
        let b = x.shape()[0];
        let per: usize = self.shape.iter().product();
        anyhow::ensure!(b <= self.batch, "batch {b} exceeds artifact batch {}", self.batch);
        let mut padded = x.data().to_vec();
        padded.resize(self.batch * per, 0.0);
        let mut shape = vec![self.batch];
        shape.extend(&self.shape);
        let mut inputs: Vec<xla::Literal> =
            self.params.iter().map(|(s, d)| lit_f32(s, d)).collect();
        inputs.push(lit_f32(&shape, &padded));
        inputs.push(lit_f32(&[hp::LEN], &self.hp));
        let outs = self.exe.run(&inputs)?;
        let logits = lit_to_vec_f32(&outs[0])?;
        Ok(TensorF::from_vec(&[b, self.classes], logits[..b * self.classes].to_vec()))
    }

    fn sample_shape(&self) -> Vec<usize> {
        self.shape.clone()
    }
}

/// Backend constructor executed inside the worker thread (required for
/// non-Send backends like [`XlaBackend`]).
pub type BackendFactory = Box<dyn FnOnce() -> Box<dyn Backend> + Send>;

/// Wrap an already-Send backend in a factory.
pub fn ready<B: Backend + Send + 'static>(b: B) -> BackendFactory {
    Box::new(move || Box::new(b) as Box<dyn Backend>)
}

/// Server statistics snapshot.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub served: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub latency_summary: String,
    pub p50_us: f64,
    pub p99_us: f64,
}

pub struct Server {
    ingress: Sender<Request>,
    next_id: AtomicU64,
    served: Arc<AtomicUsize>,
    batches: Arc<AtomicUsize>,
    hist: Arc<Mutex<LatencyHist>>,
    sample_numel: usize,
    workers: Vec<thread::JoinHandle<()>>,
    batcher: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// Start a server over backend factories (one worker thread per
    /// factory; each factory runs inside its thread so non-Send backends
    /// like XLA executables work).
    pub fn start_with(
        factories: Vec<BackendFactory>,
        sample_numel: usize,
        policy: BatchPolicy,
    ) -> Self {
        assert!(!factories.is_empty());
        let (ingress_tx, ingress_rx) = mpsc::channel::<Request>();
        let served = Arc::new(AtomicUsize::new(0));
        let batches = Arc::new(AtomicUsize::new(0));
        let hist = Arc::new(Mutex::new(LatencyHist::new()));

        // worker pool: each worker builds + owns a backend replica
        let mut worker_txs = Vec::new();
        let mut workers = Vec::new();
        for (wi, factory) in factories.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<Vec<Request>>();
            worker_txs.push(tx);
            let served = Arc::clone(&served);
            let batches = Arc::clone(&batches);
            let hist = Arc::clone(&hist);
            workers.push(
                thread::Builder::new()
                    .name(format!("fqconv-worker-{wi}"))
                    .spawn(move || {
                        let mut backend = factory();
                        while let Ok(reqs) = rx.recv() {
                            let b = reqs.len();
                            let mut flat = Vec::with_capacity(b * sample_numel);
                            for r in &reqs {
                                flat.extend_from_slice(&r.features);
                            }
                            let x = TensorF::from_vec(&[b, sample_numel], flat);
                            match backend.infer(&x) {
                                Ok(logits) => {
                                    // count the batch BEFORE replying: stats()
                                    // may be read the instant the last response
                                    // lands
                                    batches.fetch_add(1, Ordering::Relaxed);
                                    let preds = logits.argmax_rows();
                                    let classes = logits.shape()[1];
                                    for (i, r) in reqs.into_iter().enumerate() {
                                        let lat = r.submitted.elapsed().as_secs_f64() * 1e6;
                                        hist.lock().unwrap().record_us(lat);
                                        served.fetch_add(1, Ordering::Relaxed);
                                        let _ = r.reply.send(Response {
                                            id: r.id,
                                            logits: logits.data()
                                                [i * classes..(i + 1) * classes]
                                                .to_vec(),
                                            class: preds[i],
                                            latency_us: lat,
                                            batch_size: b,
                                        });
                                    }
                                }
                                Err(e) => {
                                    log::error!("backend error: {e:#}");
                                }
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
        }

        // batcher thread: assemble batches per policy, round-robin dispatch
        let batcher = {
            let policy = policy;
            thread::Builder::new()
                .name("fqconv-batcher".into())
                .spawn(move || batcher_loop(ingress_rx, worker_txs, policy))
                .expect("spawn batcher")
        };

        Server {
            ingress: ingress_tx,
            next_id: AtomicU64::new(0),
            served,
            batches,
            hist,
            sample_numel,
            workers,
            batcher: Some(batcher),
        }
    }

    /// Submit a request; returns the response channel.
    pub fn submit(&self, features: Vec<f32>) -> Receiver<Response> {
        assert_eq!(features.len(), self.sample_numel, "bad feature length");
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            features,
            submitted: Instant::now(),
            reply: tx,
        };
        self.ingress.send(req).expect("server closed");
        rx
    }

    /// Blocking convenience call.
    pub fn infer(&self, features: Vec<f32>) -> Response {
        self.submit(features).recv().expect("worker dropped")
    }

    pub fn stats(&self) -> ServerStats {
        let hist = self.hist.lock().unwrap();
        let served = self.served.load(Ordering::Relaxed) as u64;
        let batches = self.batches.load(Ordering::Relaxed) as u64;
        ServerStats {
            served,
            batches,
            mean_batch: if batches > 0 { served as f64 / batches as f64 } else { 0.0 },
            latency_summary: hist.summary(),
            p50_us: hist.percentile(50.0),
            p99_us: hist.percentile(99.0),
        }
    }

    /// Graceful shutdown: drain, then join threads.
    pub fn shutdown(mut self) {
        drop(std::mem::replace(&mut self.ingress, mpsc::channel().0));
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn batcher_loop(rx: Receiver<Request>, workers: Vec<Sender<Vec<Request>>>, policy: BatchPolicy) {
    let mut next_worker = 0usize;
    let mut pending: Vec<Request> = Vec::new();
    let mut deadline: Option<Instant> = None;
    loop {
        let timeout = match deadline {
            Some(d) => d.saturating_duration_since(Instant::now()),
            None => Duration::from_secs(3600),
        };
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if pending.is_empty() {
                    deadline = Some(Instant::now() + Duration::from_micros(policy.max_wait_us));
                }
                pending.push(req);
                if pending.len() >= policy.max_batch {
                    dispatch(&mut pending, &workers, &mut next_worker);
                    deadline = None;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if !pending.is_empty() {
                    dispatch(&mut pending, &workers, &mut next_worker);
                }
                deadline = None;
            }
            Err(RecvTimeoutError::Disconnected) => {
                if !pending.is_empty() {
                    dispatch(&mut pending, &workers, &mut next_worker);
                }
                return;
            }
        }
    }
}

fn dispatch(pending: &mut Vec<Request>, workers: &[Sender<Vec<Request>>], next: &mut usize) {
    let mut batch = std::mem::take(pending);
    if batch.is_empty() {
        return;
    }
    // round-robin; SendError hands the batch back so we can try the next
    // worker if one has died
    for _ in 0..workers.len() {
        let w = *next % workers.len();
        *next += 1;
        match workers[w].send(batch) {
            Ok(()) => return,
            Err(e) => batch = e.0,
        }
    }
}
