//! Serving layer: request router + dynamic batcher over the deployed
//! FQ network — the edge-inference story the paper motivates.
//!
//! Architecture (vLLM-router-like, scaled to the edge):
//!
//! ```text
//!  clients --> [ingress queue] --> batcher thread --(batches)--> shared
//!                                   (max_batch / max_wait_us)    work queue
//!                                                                   |
//!                                              idle workers PULL ---+
//!  clients <---------------- per-request response channels <--------+
//! ```
//!
//! * [`batcher`] — pure batch-assembly policy (unit-testable, no threads)
//! * [`Server`]  — threads + channels glue; workers own backend replicas
//!
//! Scheduling is **pull-based**: the batcher pushes closed batches onto
//! one shared queue and idle workers take from it. Unlike the previous
//! push-based round-robin, a slow worker never head-of-line-blocks
//! batches that another worker could serve, and a dead worker simply
//! stops pulling. Error policy distinguishes poisoned *batches* from
//! poisoned *backends*: a failed batch is re-queued at the back (other
//! traffic proceeds first) with bounded attempts before it is dropped,
//! and a worker retires only after [`MAX_WORKER_ERRORS`] *consecutive*
//! failures (success resets the budget) — so one unservable batch
//! cannot cascade-retire the whole pool. Per-worker counters surface in [`ServerStats::workers`]. When
//! the *last* worker retires the queue is closed and drained (and
//! further pushes are dropped) so waiting clients observe a disconnect
//! instead of hanging — guaranteed even for panicking backends via a
//! drop guard.
//!
//! Backends: the native integer engine ([`NativeBackend`], per-sample,
//! batch-size-free) or the XLA deployment artifact ([`XlaBackend`],
//! fixed-batch with padding). Both are measured in `benches/perf_serve.rs`.
//!
//! Hot-path allocation discipline: each worker stages batch features in
//! one recycled buffer and the native backend routes logits through its
//! reusable [`Scratch`], so steady-state serving performs no per-sample
//! heap allocation; batch-level data parallelism inside the engine runs
//! on the persistent [`crate::exec::Pool`] (no thread spawn per batch).

pub mod batcher;

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use std::path::PathBuf;

use crate::infer::pipeline::{FqKwsNet, Scratch};
use crate::metrics::LatencyHist;
use crate::runtime::{hp, lit_f32, lit_to_vec_f32, Engine, Executable};
use crate::tensor::TensorF;

pub use batcher::BatchPolicy;

/// A classification request: one feature tensor (flattened sample).
pub struct Request {
    pub id: u64,
    pub features: Vec<f32>,
    submitted: Instant,
    reply: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub class: usize,
    pub latency_us: f64,
    /// size of the batch this request rode in (observability)
    pub batch_size: usize,
}

/// Inference backend executed by a worker.
pub trait Backend {
    /// (B, sample_numel) -> (B, classes)
    fn infer(&mut self, x: &TensorF) -> Result<TensorF>;
    fn sample_shape(&self) -> Vec<usize>;
}

/// Native integer engine backend (batch-size agnostic).
pub struct NativeBackend {
    pub net: Arc<FqKwsNet>,
    scratch: Scratch,
    shape: Vec<usize>,
}

impl NativeBackend {
    pub fn new(net: Arc<FqKwsNet>, shape: Vec<usize>) -> Self {
        NativeBackend { net, scratch: Scratch::default(), shape }
    }
}

impl Backend for NativeBackend {
    fn infer(&mut self, x: &TensorF) -> Result<TensorF> {
        let b = x.shape()[0];
        let mut out = vec![0f32; b * self.net.classes];
        // shared batch loop with FqKwsNet::forward_batch; worker-level
        // parallelism comes from the pool, so each backend stays
        // single-threaded over its own reusable scratch
        self.net.forward_rows(x.data(), &mut self.scratch, &mut out);
        Ok(TensorF::from_vec(&[b, self.net.classes], out))
    }

    fn sample_shape(&self) -> Vec<usize> {
        self.shape.clone()
    }
}

/// XLA deployment-artifact backend (fixed batch; pads partial batches).
///
/// NOTE: the `xla` crate's PJRT handles are not `Send` (Rc-based), so an
/// `XlaBackend` must be constructed *inside* its worker thread — use
/// [`XlaBackend::factory`] with [`Server::start_with`], which builds one
/// engine + compiled executable per worker.
pub struct XlaBackend {
    _engine: Engine,
    exe: Executable,
    params: Vec<(Vec<usize>, Vec<f32>)>,
    pub hp: [f32; hp::LEN],
    pub batch: usize,
    pub classes: usize,
    shape: Vec<usize>,
}

impl XlaBackend {
    /// Build in-thread from an artifact path + host-side parameters.
    pub fn load(
        artifact: &PathBuf,
        params: Vec<(Vec<usize>, Vec<f32>)>,
        hpv: [f32; hp::LEN],
        batch: usize,
        classes: usize,
        shape: Vec<usize>,
    ) -> Result<Self> {
        let engine = Engine::cpu()?;
        let exe = engine.load(artifact)?;
        Ok(XlaBackend { _engine: engine, exe, params, hp: hpv, batch, classes, shape })
    }

    /// A `Send` factory for [`Server::start_with`].
    pub fn factory(
        artifact: PathBuf,
        params: Vec<(Vec<usize>, Vec<f32>)>,
        hpv: [f32; hp::LEN],
        batch: usize,
        classes: usize,
        shape: Vec<usize>,
    ) -> BackendFactory {
        Box::new(move || {
            Box::new(
                XlaBackend::load(&artifact, params, hpv, batch, classes, shape)
                    .expect("building XLA backend"),
            ) as Box<dyn Backend>
        })
    }
}

impl Backend for XlaBackend {
    fn infer(&mut self, x: &TensorF) -> Result<TensorF> {
        let b = x.shape()[0];
        let per: usize = self.shape.iter().product();
        anyhow::ensure!(b <= self.batch, "batch {b} exceeds artifact batch {}", self.batch);
        let mut padded = x.data().to_vec();
        padded.resize(self.batch * per, 0.0);
        let mut shape = vec![self.batch];
        shape.extend(&self.shape);
        let mut inputs: Vec<xla::Literal> =
            self.params.iter().map(|(s, d)| lit_f32(s, d)).collect();
        inputs.push(lit_f32(&shape, &padded));
        inputs.push(lit_f32(&[hp::LEN], &self.hp));
        let outs = self.exe.run(&inputs)?;
        let logits = lit_to_vec_f32(&outs[0])?;
        Ok(TensorF::from_vec(&[b, self.classes], logits[..b * self.classes].to_vec()))
    }

    fn sample_shape(&self) -> Vec<usize> {
        self.shape.clone()
    }
}

/// Backend constructor executed inside the worker thread (required for
/// non-Send backends like [`XlaBackend`]).
pub type BackendFactory = Box<dyn FnOnce() -> Box<dyn Backend> + Send>;

/// Wrap an already-Send backend in a factory.
pub fn ready<B: Backend + Send + 'static>(b: B) -> BackendFactory {
    Box::new(move || Box::new(b) as Box<dyn Backend>)
}

// ---------------------------------------------------------------------------
// Shared work queue
// ---------------------------------------------------------------------------

/// One closed batch travelling from the batcher to a worker.
struct QueuedBatch {
    reqs: Vec<Request>,
    /// delivery attempts so far (bounds error-path re-queues)
    attempts: usize,
}

struct QueueState {
    q: VecDeque<QueuedBatch>,
    closed: bool,
}

/// MPMC batch queue: the batcher pushes, idle workers pull.
struct SharedQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl SharedQueue {
    fn new() -> Arc<Self> {
        Arc::new(SharedQueue {
            state: Mutex::new(QueueState { q: VecDeque::new(), closed: false }),
            cv: Condvar::new(),
        })
    }

    /// Push to the back. On a closed queue (all workers retired) the
    /// batch is dropped instead — dropping its reply senders signals a
    /// disconnect to waiting clients rather than queueing them forever.
    fn push_back(&self, b: QueuedBatch) {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            drop(st);
            drop(b);
            return;
        }
        st.q.push_back(b);
        drop(st);
        self.cv.notify_one();
    }

    /// Blocking pop; `None` once the queue is closed *and* drained.
    fn pop(&self) -> Option<QueuedBatch> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(b) = st.q.pop_front() {
                return Some(b);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Close and return whatever was still queued (dropping the returned
    /// batches drops their reply senders, unblocking waiting clients).
    fn close_and_drain(&self) -> Vec<QueuedBatch> {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        let drained = st.q.drain(..).collect();
        drop(st);
        self.cv.notify_all();
        drained
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// Per-worker counters (lock-free; read by [`Server::stats`]).
#[derive(Debug, Default)]
struct WorkerSlot {
    batches: AtomicU64,
    served: AtomicU64,
    errors: AtomicU64,
    retired: AtomicBool,
}

/// Snapshot of one worker's counters.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub worker: usize,
    pub batches: u64,
    pub served: u64,
    pub errors: u64,
    /// false once the worker retired (backend error) or shut down
    pub alive: bool,
}

/// Server statistics snapshot.
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub served: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub latency_summary: String,
    pub p50_us: f64,
    pub p99_us: f64,
    /// per-worker counters, indexed by worker id
    pub workers: Vec<WorkerStats>,
}

pub struct Server {
    ingress: Sender<Request>,
    next_id: AtomicU64,
    served: Arc<AtomicUsize>,
    batches: Arc<AtomicUsize>,
    hist: Arc<Mutex<LatencyHist>>,
    slots: Arc<Vec<WorkerSlot>>,
    sample_numel: usize,
    workers: Vec<thread::JoinHandle<()>>,
    batcher: Option<thread::JoinHandle<()>>,
}

impl Server {
    /// Start a server over backend factories (one worker thread per
    /// factory; each factory runs inside its thread so non-Send backends
    /// like XLA executables work).
    pub fn start_with(
        factories: Vec<BackendFactory>,
        sample_numel: usize,
        policy: BatchPolicy,
    ) -> Self {
        assert!(!factories.is_empty());
        let n_workers = factories.len();
        let (ingress_tx, ingress_rx) = mpsc::channel::<Request>();
        let served = Arc::new(AtomicUsize::new(0));
        let batches = Arc::new(AtomicUsize::new(0));
        let hist = Arc::new(Mutex::new(LatencyHist::new()));
        let queue = SharedQueue::new();
        let slots: Arc<Vec<WorkerSlot>> =
            Arc::new((0..n_workers).map(|_| WorkerSlot::default()).collect());
        let alive = Arc::new(AtomicUsize::new(n_workers));
        // a batch that keeps failing is eventually dropped (clients see
        // a disconnect, not a hang); the +1 guarantees a batch failed
        // only by one soon-to-retire worker still reaches a healthy one
        let max_attempts = n_workers + 1;

        let mut workers = Vec::new();
        for (wi, factory) in factories.into_iter().enumerate() {
            let queue = Arc::clone(&queue);
            let served = Arc::clone(&served);
            let batches = Arc::clone(&batches);
            let hist = Arc::clone(&hist);
            let slots = Arc::clone(&slots);
            let alive = Arc::clone(&alive);
            workers.push(
                thread::Builder::new()
                    .name(format!("fqconv-worker-{wi}"))
                    .spawn(move || {
                        worker_loop(
                            wi,
                            factory,
                            sample_numel,
                            &queue,
                            &served,
                            &batches,
                            &hist,
                            &slots[wi],
                            &alive,
                            max_attempts,
                        );
                    })
                    .expect("spawn worker"),
            );
        }

        // batcher thread: assemble batches per policy, push to the queue
        let batcher = {
            let queue = Arc::clone(&queue);
            thread::Builder::new()
                .name("fqconv-batcher".into())
                .spawn(move || batcher_loop(ingress_rx, &queue, policy))
                .expect("spawn batcher")
        };

        Server {
            ingress: ingress_tx,
            next_id: AtomicU64::new(0),
            served,
            batches,
            hist,
            slots,
            sample_numel,
            workers,
            batcher: Some(batcher),
        }
    }

    /// Submit a request; returns the response channel.
    pub fn submit(&self, features: Vec<f32>) -> Receiver<Response> {
        assert_eq!(features.len(), self.sample_numel, "bad feature length");
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            features,
            submitted: Instant::now(),
            reply: tx,
        };
        self.ingress.send(req).expect("server closed");
        rx
    }

    /// Blocking convenience call.
    pub fn infer(&self, features: Vec<f32>) -> Response {
        self.submit(features).recv().expect("worker dropped")
    }

    pub fn stats(&self) -> ServerStats {
        let hist = self.hist.lock().unwrap();
        let served = self.served.load(Ordering::Relaxed) as u64;
        let batches = self.batches.load(Ordering::Relaxed) as u64;
        let workers = self
            .slots
            .iter()
            .enumerate()
            .map(|(i, s)| WorkerStats {
                worker: i,
                batches: s.batches.load(Ordering::Relaxed),
                served: s.served.load(Ordering::Relaxed),
                errors: s.errors.load(Ordering::Relaxed),
                alive: !s.retired.load(Ordering::Relaxed),
            })
            .collect();
        ServerStats {
            served,
            batches,
            mean_batch: if batches > 0 { served as f64 / batches as f64 } else { 0.0 },
            latency_summary: hist.summary(),
            p50_us: hist.percentile(50.0),
            p99_us: hist.percentile(99.0),
            workers,
        }
    }

    /// Graceful shutdown: drain, then join threads.
    pub fn shutdown(mut self) {
        drop(std::mem::replace(&mut self.ingress, mpsc::channel().0));
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A worker retires after this many **consecutive** backend errors —
/// one error can be batch-attributed (bad payload), an unbroken run of
/// them means the backend replica itself is poisoned. Any successful
/// batch resets the count.
pub const MAX_WORKER_ERRORS: u64 = 2;

/// Runs the worker's retirement bookkeeping on *every* exit path —
/// including a panicking backend — so the last worker out always
/// closes the queue and unblocks waiting clients.
struct RetireGuard<'a> {
    slot: &'a WorkerSlot,
    alive: &'a AtomicUsize,
    queue: &'a SharedQueue,
}

impl Drop for RetireGuard<'_> {
    fn drop(&mut self) {
        self.slot.retired.store(true, Ordering::Relaxed);
        if self.alive.fetch_sub(1, Ordering::AcqRel) == 1 {
            // last worker out: nothing can serve queued batches any more
            drop(self.queue.close_and_drain());
        }
    }
}

/// One worker: pull batches from the shared queue until it closes.
/// A backend error re-queues the batch at the back (bounded attempts,
/// then dropped); the worker itself retires after [`MAX_WORKER_ERRORS`]
/// consecutive failures and the shared queue lets the remaining workers
/// absorb the load.
#[allow(clippy::too_many_arguments)]
fn worker_loop(
    wi: usize,
    factory: BackendFactory,
    sample_numel: usize,
    queue: &SharedQueue,
    served: &AtomicUsize,
    batches: &AtomicUsize,
    hist: &Mutex<LatencyHist>,
    slot: &WorkerSlot,
    alive: &AtomicUsize,
    max_attempts: usize,
) {
    let _guard = RetireGuard { slot, alive, queue };
    let mut backend = factory();
    let mut my_errors = 0u64;
    // batch feature staging buffer, recycled across batches (the tensor
    // hands the allocation back via into_vec after each infer call)
    let mut flat: Vec<f32> = Vec::new();
    while let Some(mut qb) = queue.pop() {
        let b = qb.reqs.len();
        flat.clear();
        flat.reserve(b * sample_numel);
        for r in &qb.reqs {
            flat.extend_from_slice(&r.features);
        }
        let x = TensorF::from_vec(&[b, sample_numel], std::mem::take(&mut flat));
        let result = backend.infer(&x);
        flat = x.into_vec();
        match result {
            Ok(logits) => {
                my_errors = 0; // the error budget is for *consecutive* failures
                // count the batch BEFORE replying: stats() may be read
                // the instant the last response lands
                batches.fetch_add(1, Ordering::Relaxed);
                slot.batches.fetch_add(1, Ordering::Relaxed);
                let preds = logits.argmax_rows();
                let classes = logits.shape()[1];
                for (i, r) in qb.reqs.into_iter().enumerate() {
                    let lat = r.submitted.elapsed().as_secs_f64() * 1e6;
                    hist.lock().unwrap().record_us(lat);
                    served.fetch_add(1, Ordering::Relaxed);
                    slot.served.fetch_add(1, Ordering::Relaxed);
                    let _ = r.reply.send(Response {
                        id: r.id,
                        logits: logits.data()[i * classes..(i + 1) * classes].to_vec(),
                        class: preds[i],
                        latency_us: lat,
                        batch_size: b,
                    });
                }
            }
            Err(e) => {
                slot.errors.fetch_add(1, Ordering::Relaxed);
                my_errors += 1;
                qb.attempts += 1;
                if qb.attempts < max_attempts {
                    log::error!(
                        "worker {wi} backend error (attempt {} of {max_attempts}): {e:#}",
                        qb.attempts
                    );
                    queue.push_back(qb);
                } else {
                    // drop the batch — reply senders close and the
                    // waiting clients observe a disconnect, not a hang
                    log::error!(
                        "worker {wi} backend error, dropping batch of {b} after \
                         {max_attempts} attempts: {e:#}"
                    );
                }
                if my_errors >= MAX_WORKER_ERRORS {
                    log::error!("worker {wi} retiring after {my_errors} consecutive errors");
                    break;
                }
            }
        }
    }
    // RetireGuard's Drop marks the slot retired and closes the queue
    // when this was the last worker — on panic unwinds too.
}

fn batcher_loop(rx: Receiver<Request>, queue: &SharedQueue, policy: BatchPolicy) {
    let mut pending: Vec<Request> = Vec::new();
    let mut deadline: Option<Instant> = None;
    loop {
        let timeout = match deadline {
            Some(d) => d.saturating_duration_since(Instant::now()),
            None => Duration::from_secs(3600),
        };
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                if pending.is_empty() {
                    deadline = Some(Instant::now() + Duration::from_micros(policy.max_wait_us));
                }
                pending.push(req);
                if pending.len() >= policy.max_batch {
                    dispatch(&mut pending, queue);
                    deadline = None;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                if !pending.is_empty() {
                    dispatch(&mut pending, queue);
                }
                deadline = None;
            }
            Err(RecvTimeoutError::Disconnected) => {
                if !pending.is_empty() {
                    dispatch(&mut pending, queue);
                }
                queue.close();
                return;
            }
        }
    }
}

fn dispatch(pending: &mut Vec<Request>, queue: &SharedQueue) {
    let batch = std::mem::take(pending);
    if batch.is_empty() {
        return;
    }
    queue.push_back(QueuedBatch { reqs: batch, attempts: 0 });
}
