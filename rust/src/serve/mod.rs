//! Serving layer: a multi-model registry over one shared worker pool —
//! the edge-inference story the paper motivates, scaled out to many
//! deployed networks.
//!
//! Architecture (vLLM-router-like, scaled to the edge):
//!
//! ```text
//!              [ModelRegistry]  register / evict by ModelId
//!                     |
//!        +------------+------------+
//!        v                         v
//!  model "kws"               model "resnet"
//!  ingress queue             ingress queue          (one per model)
//!        |                         |
//!  batcher thread            batcher thread         (one per model;
//!   per-priority forming      per-priority forming   deadline-expired
//!   batches, max_batch /      batches                requests answered
//!   max_wait_us)                   |                 with a typed error)
//!        |                         |
//!        +---------> shared two-lane work queue <----+
//!          [Interactive lane | Batch lane], each lane
//!           holding per-model sub-queues scheduled by
//!           deficit-weighted fair queueing over the
//!           models' per-sample cost (MACs)
//!                           |
//!          idle workers PULL (Interactive first) ----+
//!          each worker lazily builds + caches one
//!          backend replica per model (factory runs
//!          in-thread: non-Send backends work),
//!          placement shaped by per-model replica budgets
//!                           |
//!  clients <----- per-request reply channels:
//!                 Ok(Response {model, priority, logits, ...})
//!                 | Err(ServeError::{DeadlineExceeded, BackendFailed,
//!                                    Overloaded})
//! ```
//!
//! * [`batcher`] — pure batch-assembly policy + priority/deadline
//!   simulation (unit-testable, no threads)
//! * [`ModelRegistry`] — threads + channels glue; the shared worker
//!   pool serves every registered model
//! * [`Server`] — single-model convenience facade over a registry
//!
//! Scheduling is **pull-based, priority-aware, and cost-aware**: each
//! model's batcher pushes closed batches onto the shared two-lane queue
//! and idle workers pull — Interactive lane strictly before Batch lane,
//! so latency-sensitive traffic never queues behind bulk scoring. A slow
//! worker never head-of-line-blocks batches another worker could serve,
//! and a dead worker simply stops pulling. Within a lane, batches are
//! *not* FIFO across models: each model has its own FIFO sub-queue and
//! the lane runs **deficit-weighted fair queueing** — every model
//! carries a virtual-cost tag, a pop takes the smallest tag and charges
//! the model `samples x cost_per_sample` ([`ModelSpec::with_cost`],
//! typically [`QuantGraph::cost_per_sample`] MACs), so a cheap
//! interactive model interleaves fairly with an expensive batch model
//! instead of starving behind its backlog. Models without a declared
//! cost are charged 1 per sample (request-count fair), which for a
//! single registered model degenerates to exactly the old FIFO order.
//!
//! **Admission control and load shedding** ([`AdmissionPolicy`]): a
//! model may bound its per-lane count of admitted-but-unanswered
//! requests. The bound is enforced at submit by an atomic reservation —
//! over the bound, [`ModelRegistry::submit_with`] returns
//! [`ServeError::Overloaded`] *immediately* instead of queueing a
//! request that will miss its deadline anyway (shedding beats
//! deadline-missing at saturation). With
//! [`AdmissionPolicy::shed_infeasible`], a deadlined request is also
//! shed when the cost-based ETA (pending depth x the model's observed
//! per-sample service-time EWMA / pool size) already exceeds its
//! budget. The reservation is released at the request's **terminal
//! reply** — served, expired, failed, or shed — and the protocol
//! invariant *every admitted request reaches exactly one terminal
//! reply* is model-checked (see CONCURRENCY.md).
//!
//! **Replica pressure response**: each model has a *replica budget* —
//! how many workers (lowest indices first) may pull its batches. With
//! [`AdmissionPolicy::autoscale`] the model's batcher scales the budget
//! up under queue pressure (depth or deadline expiries) and down after
//! a sustained idle period, with hysteresis on both edges;
//! [`ModelRegistry::set_replica_budget`] sets it directly. Budgets are
//! advisory placement, never a liveness hazard: bounced/retried batches
//! and batches whose in-budget workers have all retired are exempt, and
//! every budget change wakes the queue so waiting workers re-evaluate.
//!
//! **Chaos testing**: [`chaos::ChaosBackend`] wraps any backend with
//! deterministic, seeded fault injection (transient errors, stalls,
//! worker panics) so the degradation story above is *tested*, not
//! asserted — see `rust/tests/serving.rs`.
//!
//! **Noisy Monte-Carlo ensembles** ([`ModelSpec::with_noise`]): a model
//! may declare a [`NoiseSpec`] — the analog §4.4 noise point to
//! simulate ([`crate::analog::CrossbarSim`]) and an ensemble size N.
//! Its backend is then wrapped in [`NoisyBackend`]: every sample runs N
//! independent noisy replicas (each with a deterministically derived
//! seed from the spec seed, the sample's feature bits, and the replica
//! index — so results are independent of batch composition and worker
//! assignment) and the replies are combined by mean logit or majority
//! vote ([`Vote`]). The ensemble size is surfaced in
//! [`ModelStats::ensemble`] and the N× compute cost feeds the DWFQ
//! scheduling weight, so a noisy model is charged fairly against its
//! digital neighbors. Each replica draw owns its own freshly seeded
//! [`Rng`] — no shared RNG, nothing to contend on (see CONCURRENCY.md).
//!
//! **Streaming sessions** ([`ModelRegistry::open_session`] /
//! [`ModelRegistry::feed`] / [`ModelRegistry::close_session`]): a model
//! registered with [`ModelSpec::with_streaming`] additionally serves
//! stateful per-user streams over [`crate::stream`]. Sessions live in a
//! slab-indexed, generation-tagged [`SessionId`] table — a stale handle
//! (closed or idle-evicted session, recycled slot) gets the typed
//! [`ServeError::UnknownSession`], never another session's data. Feeds
//! multiplex over the *same* worker pool as batch traffic: a feed
//! enqueues a single-request batch tagged with its session, and the
//! popping worker checks the session's `Send` state out of the table,
//! applies the frame with its per-worker [`StreamScratch`], replies with
//! running logits, then drains any feeds that queued behind it (the
//! checkout serializes a session's frames in feed order) before putting
//! the state back. Sessions are bounded per model (`max_sessions`,
//! typed [`ServeError::Overloaded`] on open) and idle-evicted from the
//! owning model's batcher tick; eviction and feed linearize on the
//! table mutex, so a close/evict racing an in-flight feed yields
//! exactly one terminal outcome per feed (model-checked, see
//! rust/tests/model_check.rs).
//!
//! **Deadlines.** A request may carry a deadline; the batcher wakes at
//! the earliest pending deadline and expires overdue forming-batch
//! members *right away* (early expiry), and both the batcher (at
//! dispatch) and the worker (at pop) expire whatever slipped through,
//! answering with [`ServeError::DeadlineExceeded`] instead of letting
//! doomed requests ride — an answer that can no longer be used by its
//! caller is not worth a backend's cycles, and the caller learns
//! promptly at the deadline, not at dispatch.
//!
//! **Error policy** distinguishes poisoned *batches* from poisoned
//! *replicas*: a failed batch is re-queued at the back of its lane
//! (bounded attempts, then every member is answered with
//! [`ServeError::BackendFailed`]), and after [`MAX_WORKER_ERRORS`]
//! *consecutive* failures on one model a worker quarantines its replica
//! **for that model only** — it stays alive, keeps serving every other
//! model, and hands the quarantined model's batches back to the queue
//! (with a back-off and a bounce budget) for healthier replicas. One
//! broken model can therefore never take the shared pool down. Per-worker
//! counters surface in [`RegistryStats::workers`]; if the *last* worker
//! dies (panicking backend) the queue is closed and drained with typed
//! errors so waiting clients observe a failure instead of hanging —
//! guaranteed via a drop guard.
//!
//! Backends implement the allocation-free [`Backend::infer_into`]
//! contract: flattened features in, logits out, no per-batch tensor or
//! shape allocation ([`Backend::sample_shape`] returns a borrowed
//! slice). The native integer engine ([`NativeBackend`]) routes a batch
//! of one through the single-sample `forward_into` with the full
//! intra-layer thread budget (the batch-of-one fast path; use
//! [`NativeBackend::factory_sharded`] to split that budget across a
//! many-worker pool), [`GraphBackend`] serves any bare [`QuantGraph`]
//! (the 2-D ResNet-32 / DarkNet-19 stage lists) next to the KWS models
//! — batches of images run *sample-parallel* across the intra budget
//! via [`QuantGraph::forward_batch_into`] — and the XLA deployment
//! artifact ([`XlaBackend`]) pads to its fixed batch. All are measured
//! in `benches/perf_serve.rs`.
//!
//! Hot-path allocation discipline: each worker stages batch features
//! and logits in recycled buffers and the native backend routes
//! intermediates through its reusable [`Scratch`], so steady-state
//! serving performs no per-sample heap allocation; batch-level data
//! parallelism inside the engine runs on the persistent
//! [`crate::exec::Pool`] (no thread spawn per batch).

pub mod batcher;
pub mod chaos;

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::path::PathBuf;
use crate::check::sync::{
    spawn_named, AtomicBool, AtomicU64, AtomicUsize, Condvar, JoinHandle, Mutex, RwLock,
};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::analog::{CrossbarSim, NoiseConfig};
use crate::exec;
use crate::infer::graph::ScratchPool;
use crate::infer::pipeline::{FqKwsNet, Scratch};
use crate::infer::QuantGraph;
use crate::metrics::LatencyHist;
use crate::obs::{
    prometheus_text, samples_json, Clock, Counter, EventKind, LogLimiter, MetricSample,
    MetricsRegistry, ObsConfig, SampleValue, TraceBuf, TraceEvent,
};
use crate::runtime::{hp, lit_f32, lit_to_vec_f32, Engine, Executable};
use crate::stream::{StreamScratch, StreamState, Streamer};
use crate::util::Rng;

pub use batcher::{BatchPolicy, Priority};

// ---------------------------------------------------------------------------
// Identifiers, requests, responses, typed errors
// ---------------------------------------------------------------------------

/// Cheap, clonable model identifier (an interned name).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId(Arc<str>);

impl ModelId {
    pub fn new(name: &str) -> Self {
        ModelId(Arc::from(name))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl From<&str> for ModelId {
    fn from(s: &str) -> Self {
        ModelId::new(s)
    }
}

/// Typed serving failure, delivered on the reply channel (clients never
/// observe a bare disconnect for a policy decision).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// the request's deadline passed before a worker could start it; it
    /// was expired out of its batch instead of riding
    DeadlineExceeded { model: ModelId, waited_us: u64 },
    /// the batch failed on every delivery attempt (backend errors)
    BackendFailed { model: ModelId, attempts: usize },
    /// shed at submit by admission control: the model's per-lane
    /// pending bound was hit, or the cost-based ETA already exceeded
    /// the request's deadline budget (shedding beats deadline-missing
    /// at saturation). `pending` is the admitted-but-unanswered depth
    /// observed at the shed.
    Overloaded { model: ModelId, pending: usize },
    /// no model with this id is registered
    UnknownModel(ModelId),
    /// the streaming [`SessionId`] is stale: the session was closed or
    /// idle-evicted (or the handle belongs to a recycled slot of an
    /// earlier generation)
    UnknownSession { model: ModelId },
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::DeadlineExceeded { model, waited_us } => {
                write!(f, "deadline exceeded after {waited_us}us on model {model}")
            }
            ServeError::BackendFailed { model, attempts } => {
                write!(f, "backend for model {model} failed after {attempts} attempts")
            }
            ServeError::Overloaded { model, pending } => {
                write!(f, "model {model} overloaded ({pending} pending), request shed")
            }
            ServeError::UnknownModel(m) => write!(f, "unknown model {m}"),
            ServeError::UnknownSession { model } => {
                write!(f, "unknown or expired streaming session on model {model}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

/// What a reply channel carries.
pub type ServeResult = std::result::Result<Response, ServeError>;

/// A classification request: one feature tensor (flattened sample),
/// plus its scheduling class and optional absolute deadline.
pub struct Request {
    pub id: u64,
    pub features: Vec<f32>,
    pub priority: Priority,
    /// a request not started by this instant is answered with
    /// [`ServeError::DeadlineExceeded`] instead of riding a batch
    pub deadline: Option<Instant>,
    submitted: Instant,
    reply: Sender<ServeResult>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// the model that served this request
    pub model: ModelId,
    pub priority: Priority,
    pub logits: Vec<f32>,
    pub class: usize,
    pub latency_us: f64,
    /// size of the batch this request rode in (observability)
    pub batch_size: usize,
}

// ---------------------------------------------------------------------------
// Backends
// ---------------------------------------------------------------------------

/// Inference backend executed by a worker. The contract is
/// allocation-free: the worker owns the staging buffers, the backend
/// owns its scratch, and per-batch metadata is borrowed, not cloned.
pub trait Backend {
    /// Flattened `(batch, sample_numel)` features → logits into `out`
    /// (`batch * out_dim()`, row-major).
    fn infer_into(&mut self, x: &[f32], batch: usize, out: &mut [f32]) -> Result<()>;

    /// Per-sample feature shape — borrowed: this is called on the hot
    /// path (per batch), a clone per call was pure allocator traffic.
    fn sample_shape(&self) -> &[usize];

    /// Logits per sample (sizes the worker's output window).
    fn out_dim(&self) -> usize;
}

/// Batch-of-one intra-layer thread budget for one of `serve_workers`
/// concurrently-forking replicas: the machine budget split across the
/// pool (min 1), shared by every `factory_sharded` so the backend
/// families cannot drift apart.
fn sharded_budget(serve_workers: usize) -> usize {
    (exec::default_threads() / serve_workers.max(1)).max(1)
}

/// Native integer engine backend (batch-size agnostic).
pub struct NativeBackend {
    pub net: Arc<FqKwsNet>,
    scratch: Scratch,
    shape: Vec<usize>,
    /// intra-layer thread budget for the batch-of-one fast path
    intra_threads: usize,
}

impl NativeBackend {
    /// Backend with the batch-of-one fast path sized to the machine
    /// ([`exec::default_threads`]). NOTE: the global [`exec::Pool`]
    /// serializes concurrent forks, so on a many-worker pool serving
    /// max_batch=1 traffic, replicas built with
    /// [`NativeBackend::with_intra_threads`]`(.., 1)` can outperform
    /// the default (worker-level parallelism instead of contended
    /// intra-layer forks); outputs are bit-identical either way.
    pub fn new(net: Arc<FqKwsNet>, shape: Vec<usize>) -> Self {
        let threads = exec::default_threads();
        NativeBackend::with_intra_threads(net, shape, threads)
    }

    /// Backend with an explicit intra-layer budget for batches of one
    /// (`1` disables the fast path; outputs are bit-identical either way).
    pub fn with_intra_threads(net: Arc<FqKwsNet>, shape: Vec<usize>, intra_threads: usize) -> Self {
        let scratch = Scratch::for_graph(net.graph());
        NativeBackend { net, scratch, shape, intra_threads: intra_threads.max(1) }
    }

    /// A shareable factory for [`ModelRegistry::register`] /
    /// [`Server::start`]: every call builds a fresh replica over the
    /// shared network.
    pub fn factory(net: &Arc<FqKwsNet>, shape: &[usize]) -> BackendFactory {
        let (net, shape) = (Arc::clone(net), shape.to_vec());
        Arc::new(move |_wi| {
            Box::new(NativeBackend::new(Arc::clone(&net), shape.clone())) as Box<dyn Backend>
        })
    }

    /// [`NativeBackend::factory`] for a pool of `serve_workers` workers
    /// serving batch-of-one traffic: replicas get an intra-layer thread
    /// budget of `pool_workers / serve_workers` (min 1) instead of the
    /// full machine, so concurrent replicas stop contending on the
    /// global [`exec::Pool`]'s fork lock (which serializes forks — with
    /// many workers each forking the full budget, the pool becomes the
    /// bottleneck; see the [`NativeBackend::new`] note). Outputs are
    /// bit-identical at every budget.
    pub fn factory_sharded(
        net: &Arc<FqKwsNet>,
        shape: &[usize],
        serve_workers: usize,
    ) -> BackendFactory {
        let budget = sharded_budget(serve_workers);
        let (net, shape) = (Arc::clone(net), shape.to_vec());
        Arc::new(move |_wi| {
            let b = NativeBackend::with_intra_threads(Arc::clone(&net), shape.clone(), budget);
            Box::new(b) as Box<dyn Backend>
        })
    }
}

impl Backend for NativeBackend {
    fn infer_into(&mut self, x: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        anyhow::ensure!(out.len() == batch * self.net.classes, "logit buffer size");
        if batch == 1 {
            // batch-of-one fast path (max_batch == 1 policies route every
            // request here): spend the whole thread budget *inside* the
            // layer kernels instead of across a one-sample batch loop
            self.net.forward_into(x, &mut self.scratch, out, self.intra_threads);
        } else {
            // shared batch loop with FqKwsNet::forward_batch; worker-level
            // parallelism comes from the pool, so each backend stays
            // single-threaded over its own reusable scratch
            self.net.forward_rows(x, &mut self.scratch, out);
        }
        Ok(())
    }

    fn sample_shape(&self) -> &[usize] {
        &self.shape
    }

    fn out_dim(&self) -> usize {
        self.net.classes
    }
}

/// Backend over a bare [`QuantGraph`] — serves any architecture the
/// graph engine can express (the 2-D ResNet-32 / DarkNet-19 stage
/// lists, a custom stack, ...) without a named facade. Batch-size
/// agnostic: a batch of one spends the intra-layer thread budget inside
/// the kernels (same fast path as [`NativeBackend`]); larger batches
/// run **sample-parallel** over the same budget through
/// [`QuantGraph::forward_batch_pooled`], with per-worker scratches
/// recycled through the backend's [`ScratchPool`] (after the first
/// batch the batched path allocates nothing) — image samples carry
/// tens of millions of MACs each, so splitting the batch beats walking
/// it sequentially. With a budget of one (e.g.
/// [`GraphBackend::factory_sharded`] on a many-worker pool) batches
/// walk sequentially over the backend's own reusable [`Scratch`],
/// allocation-free. Bit-identical at every budget.
pub struct GraphBackend {
    pub graph: Arc<QuantGraph>,
    scratch: Scratch,
    /// recycled per-worker scratches for the sample-parallel batch path
    /// (fills up to `intra_threads` scratches on the first batch, then
    /// the serve loop allocates nothing)
    scratch_pool: ScratchPool,
    /// intra-layer thread budget for the batch-of-one fast path
    intra_threads: usize,
}

impl GraphBackend {
    /// Backend with the batch-of-one fast path sized to the machine
    /// ([`exec::default_threads`]); use
    /// [`GraphBackend::with_intra_threads`] or
    /// [`GraphBackend::factory_sharded`] on many-worker pools.
    pub fn new(graph: Arc<QuantGraph>) -> Self {
        let threads = exec::default_threads();
        GraphBackend::with_intra_threads(graph, threads)
    }

    /// Backend with an explicit intra-layer budget for batches of one
    /// (`1` disables the fast path; outputs are bit-identical either way).
    pub fn with_intra_threads(graph: Arc<QuantGraph>, intra_threads: usize) -> Self {
        let scratch = Scratch::for_graph(&graph);
        GraphBackend {
            graph,
            scratch,
            scratch_pool: ScratchPool::new(),
            intra_threads: intra_threads.max(1),
        }
    }

    /// A shareable factory for [`ModelRegistry::register`]: every call
    /// builds a fresh replica (own scratch) over the shared graph.
    pub fn factory(graph: &Arc<QuantGraph>) -> BackendFactory {
        let graph = Arc::clone(graph);
        Arc::new(move |_wi| Box::new(GraphBackend::new(Arc::clone(&graph))) as Box<dyn Backend>)
    }

    /// [`GraphBackend::factory`] with the batch-of-one intra-layer
    /// budget split across `serve_workers` — same fork-lock relief as
    /// [`NativeBackend::factory_sharded`].
    pub fn factory_sharded(graph: &Arc<QuantGraph>, serve_workers: usize) -> BackendFactory {
        let budget = sharded_budget(serve_workers);
        let graph = Arc::clone(graph);
        Arc::new(move |_wi| {
            let b = GraphBackend::with_intra_threads(Arc::clone(&graph), budget);
            Box::new(b) as Box<dyn Backend>
        })
    }
}

impl Backend for GraphBackend {
    fn infer_into(&mut self, x: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        let per = self.graph.in_numel();
        let classes = self.graph.classes();
        anyhow::ensure!(x.len() == batch * per, "feature geometry");
        anyhow::ensure!(out.len() == batch * classes, "logit buffer size");
        if batch == 1 {
            // batch-of-one fast path: the whole thread budget goes
            // inside the layer kernels (bit-identical at every budget)
            self.graph.forward_into(x, &mut self.scratch, out, self.intra_threads);
        } else if self.intra_threads <= 1 {
            // sharded budget: sequential walk over the backend's own
            // scratch (worker-level parallelism comes from the pool)
            self.graph.forward_rows(x, &mut self.scratch, out);
        } else {
            // sample-parallel batch over the intra budget — batch > 1
            // no longer drops to a single thread per sample; per-worker
            // scratches recycle through the backend's pool
            self.graph.forward_batch_pooled(x, batch, out, self.intra_threads, &self.scratch_pool);
        }
        Ok(())
    }

    fn sample_shape(&self) -> &[usize] {
        self.graph.in_shape()
    }

    fn out_dim(&self) -> usize {
        self.graph.classes()
    }
}

/// How a [`NoisyBackend`] ensemble combines its N replica outputs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Vote {
    /// average the N logit vectors (soft ensemble; output logits are
    /// the mean, so downstream argmax is the ensemble-mean class)
    MeanLogit,
    /// each replica casts one argmax vote; the output "logits" are the
    /// per-class vote counts, so downstream argmax is the plurality
    /// class
    Majority,
}

/// Declaration of a Monte-Carlo noisy ensemble for one model
/// ([`ModelSpec::with_noise`]): which graph to simulate on the analog
/// crossbar, at which §4.4 noise point, with how many independent
/// replicas per request, and how to combine them.
#[derive(Clone)]
pub struct NoiseSpec {
    /// the served graph, walked in f64 code-space by
    /// [`crate::analog::CrossbarSim`]
    pub graph: Arc<QuantGraph>,
    /// the Table-7 operating point; a silent config disables the
    /// ensemble (the wrapped backend serves directly)
    pub noise: NoiseConfig,
    /// ensemble size N (requests cost N× in DWFQ weight)
    pub replicas: usize,
    pub vote: Vote,
    /// base seed; per-sample, per-replica streams are derived from it
    /// deterministically (same features + same spec → same reply,
    /// independent of batching or worker placement)
    pub seed: u64,
}

/// FNV-1a over the raw feature bits: the per-sample component of the
/// replica seed derivation, so a sample's noise draws do not depend on
/// where in a batch (or on which worker) it lands.
fn hash_f32_bits(xs: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in xs {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01B3);
        }
    }
    h
}

/// Monte-Carlo noisy-ensemble backend ([`ModelSpec::with_noise`]):
/// wraps any inner backend and, per sample, runs N independent
/// [`CrossbarSim`] walks at the declared noise point, combining the
/// replies per [`Vote`]. With one replica or a silent noise config it
/// delegates to the wrapped backend unchanged (so a chaos wrapper
/// around the *outer* factory still exercises faults). Each replica's
/// RNG is freshly seeded from (spec seed, feature-bit hash, replica
/// index) and owned by the draw — no shared RNG state, nothing for
/// concurrent workers to contend on.
pub struct NoisyBackend {
    inner: Box<dyn Backend>,
    sim: CrossbarSim,
    spec: NoiseSpec,
    scratch: Scratch,
    /// one replica's logits (reused)
    rep_logits: Vec<f32>,
    /// the per-sample ensemble accumulator (reused)
    acc: Vec<f32>,
}

impl NoisyBackend {
    pub fn new(inner: Box<dyn Backend>, spec: NoiseSpec) -> Self {
        let sim = CrossbarSim::new(Arc::clone(&spec.graph));
        let scratch = Scratch::for_graph(&spec.graph);
        let classes = spec.graph.classes();
        NoisyBackend {
            inner,
            sim,
            spec,
            scratch,
            rep_logits: vec![0.0; classes],
            acc: vec![0.0; classes],
        }
    }

    /// Wrap a factory so every worker replica carries its own simulator
    /// and scratch (used by [`ModelSpec::with_noise`]).
    pub fn factory(inner: BackendFactory, spec: NoiseSpec) -> BackendFactory {
        Arc::new(move |wi| {
            Box::new(NoisyBackend::new(inner(wi), spec.clone())) as Box<dyn Backend>
        })
    }

    /// One sample's N-replica ensemble into `out`.
    fn ensemble_one(&mut self, xs: &[f32], out: &mut [f32]) {
        let n = self.spec.replicas;
        let base = self.spec.seed ^ hash_f32_bits(xs);
        self.acc.clear();
        self.acc.resize(out.len(), 0.0);
        for rep in 0..n {
            let mut rng =
                Rng::new(base ^ (rep as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            self.sim.forward_noisy_into(
                xs,
                self.spec.noise,
                &mut rng,
                &mut self.scratch,
                &mut self.rep_logits,
            );
            match self.spec.vote {
                Vote::MeanLogit => {
                    for (a, &l) in self.acc.iter_mut().zip(self.rep_logits.iter()) {
                        *a += l / n as f32;
                    }
                }
                Vote::Majority => {
                    self.acc[crate::analog::argmax(&self.rep_logits)] += 1.0;
                }
            }
        }
        out.copy_from_slice(&self.acc);
    }
}

impl Backend for NoisyBackend {
    fn infer_into(&mut self, x: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        if self.spec.replicas <= 1 || self.spec.noise.silent() {
            // degenerate ensemble: the wrapped backend serves directly
            // (and a chaos/fault wrapper outside this factory still
            // applies either way)
            return self.inner.infer_into(x, batch, out);
        }
        let per = self.sim.graph().in_numel();
        let classes = self.sim.graph().classes();
        anyhow::ensure!(x.len() == batch * per, "feature geometry");
        anyhow::ensure!(out.len() == batch * classes, "logit buffer size");
        for i in 0..batch {
            let (xs, o) = (&x[i * per..(i + 1) * per], &mut out[i * classes..(i + 1) * classes]);
            self.ensemble_one(xs, o);
        }
        Ok(())
    }

    fn sample_shape(&self) -> &[usize] {
        self.sim.graph().in_shape()
    }

    fn out_dim(&self) -> usize {
        self.sim.graph().classes()
    }
}

/// XLA deployment-artifact backend (fixed batch; pads partial batches).
///
/// NOTE: the `xla` crate's PJRT handles are not `Send` (Rc-based), so an
/// `XlaBackend` must be constructed *inside* its worker thread — use
/// [`XlaBackend::factory`], which builds one engine + compiled
/// executable per worker, lazily on the worker's first batch for the
/// model.
pub struct XlaBackend {
    _engine: Engine,
    exe: Executable,
    params: Vec<(Vec<usize>, Vec<f32>)>,
    pub hp: [f32; hp::LEN],
    pub batch: usize,
    pub classes: usize,
    shape: Vec<usize>,
}

impl XlaBackend {
    /// Build in-thread from an artifact path + host-side parameters.
    pub fn load(
        artifact: &PathBuf,
        params: Vec<(Vec<usize>, Vec<f32>)>,
        hpv: [f32; hp::LEN],
        batch: usize,
        classes: usize,
        shape: Vec<usize>,
    ) -> Result<Self> {
        let engine = Engine::cpu()?;
        let exe = engine.load(artifact)?;
        Ok(XlaBackend { _engine: engine, exe, params, hp: hpv, batch, classes, shape })
    }

    /// A shareable factory for [`ModelRegistry::register`] /
    /// [`Server::start`]: every call builds a fresh in-thread replica.
    pub fn factory(
        artifact: PathBuf,
        params: Vec<(Vec<usize>, Vec<f32>)>,
        hpv: [f32; hp::LEN],
        batch: usize,
        classes: usize,
        shape: Vec<usize>,
    ) -> BackendFactory {
        Arc::new(move |_wi| {
            Box::new(
                XlaBackend::load(&artifact, params.clone(), hpv, batch, classes, shape.clone())
                    .expect("building XLA backend"),
            ) as Box<dyn Backend>
        })
    }
}

impl Backend for XlaBackend {
    fn infer_into(&mut self, x: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        let per: usize = self.shape.iter().product();
        anyhow::ensure!(x.len() == batch * per, "feature geometry");
        anyhow::ensure!(batch <= self.batch, "batch {batch} exceeds artifact batch {}", self.batch);
        anyhow::ensure!(out.len() == batch * self.classes, "logit buffer size");
        let mut padded = x.to_vec();
        padded.resize(self.batch * per, 0.0);
        let mut shape = vec![self.batch];
        shape.extend(&self.shape);
        let mut inputs: Vec<xla::Literal> =
            self.params.iter().map(|(s, d)| lit_f32(s, d)).collect();
        inputs.push(lit_f32(&shape, &padded));
        inputs.push(lit_f32(&[hp::LEN], &self.hp));
        let outs = self.exe.run(&inputs)?;
        let logits = lit_to_vec_f32(&outs[0])?;
        out.copy_from_slice(&logits[..batch * self.classes]);
        Ok(())
    }

    fn sample_shape(&self) -> &[usize] {
        &self.shape
    }

    fn out_dim(&self) -> usize {
        self.classes
    }
}

/// Shareable backend constructor: every worker calls it (with its
/// worker index) *inside its own thread* the first time it pulls a
/// batch for the model — which is how non-Send backends like
/// [`XlaBackend`] get one replica per worker.
pub type BackendFactory = Arc<dyn Fn(usize) -> Box<dyn Backend> + Send + Sync>;

/// Wrap a per-replica constructor into a [`BackendFactory`] (the worker
/// index is ignored; each call builds a fresh backend).
pub fn ready<B, F>(make: F) -> BackendFactory
where
    B: Backend + 'static,
    F: Fn() -> B + Send + Sync + 'static,
{
    Arc::new(move |_wi| Box::new(make()) as Box<dyn Backend>)
}

/// A [`BackendFactory`] that sees the worker index — lets tests and
/// heterogeneous deployments give specific workers specific replicas.
pub fn ready_indexed<F>(make: F) -> BackendFactory
where
    F: Fn(usize) -> Box<dyn Backend> + Send + Sync + 'static,
{
    Arc::new(make)
}

// ---------------------------------------------------------------------------
// Observability plumbing
// ---------------------------------------------------------------------------

/// Shed reason codes carried in [`EventKind::Shed`]'s `a` detail and
/// indexing [`ServeObs::shed`] / [`SHED_REASONS`].
const SHED_OVERLOAD: u32 = 0;
const SHED_INFEASIBLE: u32 = 1;
const SHED_BACKLOG: u32 = 2;
const SHED_SESSION_CAP: u32 = 3;
const SHED_STALE_SESSION: u32 = 4;
const SHED_EVICTED: u32 = 5;
/// Stable reason labels, indexed by the `SHED_*` codes.
pub const SHED_REASONS: [&str; 6] =
    ["overload", "infeasible", "backlog", "session_cap", "stale_session", "evicted"];

/// Minimum interval between repeats of one error-log site; suppressed
/// repeats are counted (`fqconv_log_suppressed_total`) and summarized
/// when the gate re-opens, so a wedged replica cannot flood the log.
const ERROR_LOG_INTERVAL: Duration = Duration::from_secs(1);

/// Observability plumbing shared by one registry: pre-registered metric
/// handles (so the record paths never touch the metrics-registry lock),
/// the per-worker trace rings, and the rate-limited error-log gates for
/// the repeated worker-loop error sites. Trace shard 0 is the control
/// plane (submit/shed/enqueue/session paths); shard `wi + 1` belongs to
/// worker `wi`.
struct ServeObs {
    enabled: bool,
    metrics: MetricsRegistry,
    trace: TraceBuf,
    /// one counter per shed reason, indexed by the `SHED_*` codes
    shed: Vec<Counter>,
    worker_errors: Counter,
    quarantines: Counter,
    log_suppressed: Counter,
    err_backend: LogLimiter,
    err_bounce: LogLimiter,
    err_quarantine: LogLimiter,
}

impl ServeObs {
    fn new(n_workers: usize, cfg: ObsConfig) -> Self {
        let metrics = MetricsRegistry::new(n_workers.max(1));
        let trace = TraceBuf::new(n_workers + 1, cfg.trace_capacity, Arc::clone(&cfg.clock));
        let shed = SHED_REASONS
            .iter()
            .map(|r| metrics.counter("fqconv_shed_total", &format!("reason=\"{r}\"")))
            .collect();
        let interval_ns = ERROR_LOG_INTERVAL.as_nanos() as u64;
        ServeObs {
            enabled: cfg.enabled,
            worker_errors: metrics.counter("fqconv_worker_errors_total", ""),
            quarantines: metrics.counter("fqconv_quarantines_total", ""),
            log_suppressed: metrics.counter("fqconv_log_suppressed_total", ""),
            err_backend: LogLimiter::new(interval_ns),
            err_bounce: LogLimiter::new(interval_ns),
            err_quarantine: LogLimiter::new(interval_ns),
            shed,
            metrics,
            trace,
        }
    }

    /// Append one trace event (no-op when observability is disabled).
    #[inline]
    fn event(&self, shard: usize, trace: u64, kind: EventKind, a: u32, b: u32) {
        if self.enabled {
            self.trace.record(shard, trace, kind, a, b);
        }
    }

    /// Count + trace one shed decision, reason-coded.
    fn shed_event(&self, shard: usize, trace: u64, reason: u32) {
        if self.enabled {
            self.shed[reason as usize].inc(shard);
            self.trace.record(shard, trace, EventKind::Shed, reason, 0);
        }
    }

    /// Route one error line through a per-site rate gate: at most one
    /// line per [`ERROR_LOG_INTERVAL`], with the suppressed-repeat
    /// count appended when the gate re-opens. With observability
    /// disabled every line logs (the pre-obs behavior).
    fn limited_error(&self, gate: &LogLimiter, shard: usize, msg: impl FnOnce() -> String) {
        if !self.enabled {
            log::error!("{}", msg());
            return;
        }
        match gate.allow(self.trace.clock().now_ns()) {
            Some(0) => log::error!("{}", msg()),
            Some(n) => log::error!("{} [{n} similar suppressed]", msg()),
            None => self.log_suppressed.inc(shard),
        }
    }
}

// ---------------------------------------------------------------------------
// Shared two-lane work queue
// ---------------------------------------------------------------------------

/// One closed batch travelling from a model's batcher to a worker.
struct QueuedBatch {
    model: Arc<ModelEntry>,
    priority: Priority,
    reqs: Vec<Request>,
    /// delivery attempts that actually ran a backend and failed
    /// (bounds error-path re-queues)
    attempts: usize,
    /// hand-backs by workers whose replica for the model is quarantined
    /// (bounds the ping-pong when every worker has quarantined it)
    bounces: usize,
    /// `Some` marks a streaming-session feed: the popping worker checks
    /// the session's state out of the model's table instead of running
    /// a batch backend (`reqs` then holds exactly one frame request)
    session: Option<SessionId>,
}

/// DWFQ charge for one popped batch of `samples` requests. Prefers the
/// *measured* per-sample wall cost from an attached observed graph's
/// stage timers ([`QuantGraph::measured_us_per_sample`], µs); falls
/// back to the registered static estimate in kMAC units, min 1 so
/// cost-unknown models (`cost == 0`) schedule request-count fair. The
/// two units are commensurable — the integer engine sustains on the
/// order of one GMAC/s, so kMAC/1000 ≈ µs — which keeps a lane fair
/// when only some of its models carry an observed graph.
fn cost_weight(e: &ModelEntry) -> u64 {
    if let Some(us) = e.observed_graph.as_ref().and_then(|g| g.measured_us_per_sample()) {
        return us;
    }
    (e.cost_per_sample / 1_000).max(1)
}

/// One priority lane of the shared queue: per-model FIFO sub-queues
/// scheduled by deficit-weighted fair queueing. Each model carries a
/// virtual-cost tag; a pop takes the smallest tag (id breaks ties) and
/// charges the model `samples x cost_weight`, so cheap models
/// interleave with expensive ones instead of queueing behind their
/// backlog. With one model per lane this is exactly FIFO.
struct Lane {
    /// per-model FIFO of closed batches (an entry is removed when its
    /// sub-queue drains)
    queues: HashMap<ModelId, VecDeque<QueuedBatch>>,
    /// virtual finish tags: cumulative weighted cost charged per model
    vcost: HashMap<ModelId, u64>,
    /// lane virtual clock: the tag of the most recently popped model; a
    /// model entering an empty sub-queue is clamped up to it, so idle
    /// periods accumulate no credit (start-time fair queueing)
    vclock: u64,
}

impl Lane {
    fn new() -> Self {
        Lane { queues: HashMap::new(), vcost: HashMap::new(), vclock: 0 }
    }

    fn push(&mut self, b: QueuedBatch) {
        let id = b.model.id.clone();
        if !self.queues.contains_key(&id) {
            // a model entering with no queued work is clamped up to the
            // lane clock: idle periods accumulate no scheduling credit
            let tag = self.vcost.entry(id.clone()).or_insert(0);
            *tag = (*tag).max(self.vclock);
        }
        self.queues.entry(id).or_default().push_back(b);
    }

    /// Pop the front batch of the smallest-tag model whose front batch
    /// `admit` accepts, and charge the model its weighted cost.
    fn pop_admitted(
        &mut self,
        admit: &mut impl FnMut(&QueuedBatch) -> bool,
    ) -> Option<QueuedBatch> {
        let mut best: Option<(u64, ModelId)> = None;
        for (id, q) in &self.queues {
            let front = q.front().expect("drained sub-queues are removed");
            if !admit(front) {
                continue;
            }
            let tag = self.vcost.get(id).copied().unwrap_or(self.vclock);
            let better = match &best {
                None => true,
                Some((bt, bid)) => (tag, id) < (*bt, bid),
            };
            if better {
                best = Some((tag, id.clone()));
            }
        }
        let (tag, id) = best?;
        let q = self.queues.get_mut(&id).expect("selected sub-queue exists");
        let b = q.pop_front().expect("selected sub-queue is non-empty");
        if q.is_empty() {
            self.queues.remove(&id);
        }
        self.vclock = tag;
        let charge = (b.reqs.len() as u64).saturating_mul(cost_weight(&b.model));
        self.vcost.insert(id, tag.saturating_add(charge));
        // GC tags that can no longer matter: no queued work and already
        // at/behind the clock (a future push would clamp them up anyway)
        let (vclock, queues) = (self.vclock, &self.queues);
        self.vcost.retain(|mid, t| queues.contains_key(mid) || *t > vclock);
        Some(b)
    }

    fn drain(&mut self) -> impl Iterator<Item = QueuedBatch> + '_ {
        self.vcost.clear();
        self.queues.drain().flat_map(|(_, q)| q)
    }
}

struct QueueState {
    /// one DWFQ lane per [`Priority`], indexed by [`Priority::index`]
    lanes: [Lane; 2],
    closed: bool,
}

/// MPMC batch queue: model batchers push into their lane, idle workers
/// pull — Interactive lane strictly first, weighted-fair across models
/// within a lane, placement shaped by per-model replica budgets.
struct SharedQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

/// May worker `wi` take this batch? Replica budgets place a model's
/// batches on the lowest-indexed workers. Never a liveness hazard:
/// retried/bounced batches are exempt (a quarantined in-budget replica
/// must be able to hand work to out-of-budget peers), and the budget is
/// ignored once every in-budget worker has retired.
fn budget_admits(qb: &QueuedBatch, wi: usize, slots: &[WorkerSlot]) -> bool {
    if qb.bounces > 0 || qb.attempts > 0 {
        return true;
    }
    // Relaxed loads under the queue mutex: writers publish through
    // SharedQueue::wake_all, whose lock round-trip provides the edge; a
    // stale value only delays placement by one wakeup, never wedges it.
    let budget = qb.model.replica_budget.load(Ordering::Relaxed).clamp(1, slots.len());
    if wi < budget {
        return true;
    }
    slots[..budget].iter().all(|s| s.retired.load(Ordering::Relaxed))
}

impl SharedQueue {
    fn new() -> Self {
        SharedQueue {
            state: Mutex::new(QueueState { lanes: [Lane::new(), Lane::new()], closed: false }),
            cv: Condvar::new(),
        }
    }

    /// Push to the batch's lane. On a closed queue (all workers
    /// retired) every member is answered with a typed
    /// [`ServeError::BackendFailed`] instead of queueing forever.
    fn push(&self, b: QueuedBatch) {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            drop(st);
            fail_batch(b, 0);
            return;
        }
        st.lanes[b.priority.index()].push(b);
        drop(st);
        // notify_all, not notify_one: pops are selective (replica
        // budgets), so the one woken worker might not admit this batch
        self.cv.notify_all();
    }

    /// Blocking pop for worker `wi`, Interactive lane first, DWFQ
    /// within a lane, replica budgets respected while the queue is
    /// open; `None` once the queue is closed *and* drained.
    fn pop(&self, wi: usize, slots: &[WorkerSlot]) -> Option<QueuedBatch> {
        let mut st = self.state.lock().unwrap();
        loop {
            let closed = st.closed;
            // lanes are in Priority::index order: Interactive first. A
            // closed queue admits everything: draining beats placement.
            for lane in st.lanes.iter_mut() {
                let mut admit = |qb: &QueuedBatch| closed || budget_admits(qb, wi, slots);
                if let Some(b) = lane.pop_admitted(&mut admit) {
                    return Some(b);
                }
            }
            if closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Close and return whatever was still queued (the caller answers
    /// each drained batch with a typed error).
    fn close_and_drain(&self) -> Vec<QueuedBatch> {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        let drained = st.lanes.iter_mut().flat_map(|l| l.drain()).collect();
        drop(st);
        self.cv.notify_all();
        drained
    }

    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        drop(st);
        self.cv.notify_all();
    }

    /// Depth snapshot per (lane, model): queued batches, queued
    /// requests, and the model's DWFQ virtual-cost tag (its deficit
    /// position). Exposition only — takes the queue mutex once.
    fn depth_snapshot(&self) -> Vec<(usize, ModelId, u64, u64, u64)> {
        let st = self.state.lock().unwrap();
        let mut out = Vec::new();
        for (li, lane) in st.lanes.iter().enumerate() {
            for (id, q) in &lane.queues {
                let reqs: usize = q.iter().map(|b| b.reqs.len()).sum();
                let tag = lane.vcost.get(id).copied().unwrap_or(lane.vclock);
                out.push((li, id.clone(), q.len() as u64, reqs as u64, tag));
            }
        }
        drop(st);
        out.sort_by(|a, b| (a.0, &a.1).cmp(&(b.0, &b.1)));
        out
    }

    /// Wake every waiting worker without touching queue contents — used
    /// after replica-budget or worker-liveness changes so the admission
    /// predicate in [`SharedQueue::pop`] is re-evaluated. The lock
    /// round-trip (even over an unchanged queue) orders the caller's
    /// preceding Relaxed stores before any waiter's next predicate
    /// evaluation: the waiter re-reads under the same mutex.
    fn wake_all(&self) {
        drop(self.state.lock().unwrap());
        self.cv.notify_all();
    }
}

/// Answer every member of a batch with [`ServeError::BackendFailed`].
/// A terminal reply: releases each member's admission reservation and
/// traces one [`EventKind::Failed`] per member on `shard`. A
/// session-feed batch additionally returns its session to idle and
/// fails whatever backlog queued behind the doomed feed — no client may
/// hang on a frame that can never run.
fn fail_batch(b: QueuedBatch, shard: usize) {
    let QueuedBatch { model, mut reqs, attempts, session, .. } = b;
    if let Some(sid) = session {
        if let Some(sm) = model.stream.as_ref() {
            let mut tab = sm.sessions.lock().unwrap();
            let mut close = false;
            if let Some(slot) = tab.get_live(sid) {
                slot.busy = false;
                reqs.extend(slot.backlog.drain(..));
                close = slot.pending_close;
            }
            if close {
                tab.release(sid.slot);
            }
        }
    }
    model.counters.dropped.fetch_add(reqs.len() as u64, Ordering::Relaxed);
    for r in reqs {
        model.counters.pending[r.priority.index()].fetch_sub(1, Ordering::Relaxed);
        model.obs.event(shard, r.id, EventKind::Failed, attempts as u32, 0);
        let _ = r
            .reply
            .send(Err(ServeError::BackendFailed { model: model.id.clone(), attempts }));
    }
}

/// Answer one request with [`ServeError::DeadlineExceeded`].
/// A terminal reply: releases the request's admission reservation and
/// traces [`EventKind::Expired`] on `shard`.
fn expire(r: Request, entry: &ModelEntry, shard: usize) {
    entry.counters.expired.fetch_add(1, Ordering::Relaxed);
    entry.counters.pending[r.priority.index()].fetch_sub(1, Ordering::Relaxed);
    let waited = (r.submitted.elapsed().as_secs_f64() * 1e6) as u64;
    entry.obs.event(shard, r.id, EventKind::Expired, 0, 0);
    let _ = r
        .reply
        .send(Err(ServeError::DeadlineExceeded { model: entry.id.clone(), waited_us: waited }));
}

// ---------------------------------------------------------------------------
// Streaming sessions
// ---------------------------------------------------------------------------

/// Handle to one open streaming session: slab slot index plus a
/// generation tag. `Copy` — clients pass it by value to every
/// [`ModelRegistry::feed`]. A handle outliving its session (closed,
/// idle-evicted, or the slot recycled to a newer session) is answered
/// with the typed [`ServeError::UnknownSession`] — never with another
/// session's data, because the generation tag can only match the
/// session it was minted for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct SessionId {
    slot: usize,
    generation: u64,
}

/// Streaming-session configuration for one model
/// ([`ModelSpec::with_streaming`]): the 1-D sequence graph to stream
/// and the session-admission knobs.
#[derive(Clone)]
pub struct StreamSpec {
    /// the graph streamed per session; must be a 1-D sequence graph
    /// ([`crate::stream::StatePlan::for_graph`] validates at register)
    pub graph: Arc<QuantGraph>,
    /// bound on concurrently open sessions: [`ModelRegistry::open_session`]
    /// past the bound returns [`ServeError::Overloaded`] (admission
    /// control for state residency, like `max_pending` for requests)
    pub max_sessions: usize,
    /// a session with no feed for this long is evicted by the model's
    /// batcher tick; its next feed gets [`ServeError::UnknownSession`]
    pub idle_timeout: Duration,
}

/// Feeds a session may hold queued behind its in-flight feed before new
/// ones are shed with [`ServeError::Overloaded`] — a per-session bound,
/// so one runaway stream cannot hoard the feed path.
const MAX_SESSION_BACKLOG: usize = 32;

/// One slab slot of a [`SessionTable`].
struct SessionSlot {
    /// tag of the session currently (or last) resident here; a
    /// [`SessionId`] is live iff `occupied` and the tags match
    generation: u64,
    occupied: bool,
    /// a worker holds the state checked out (exactly one in-flight feed
    /// batch exists): new feeds append to `backlog`, the idle sweep
    /// skips the slot, close marks `pending_close` instead of freeing
    busy: bool,
    /// close/evict arrived while busy — the worker frees the slot when
    /// it would otherwise put the state back
    pending_close: bool,
    /// feeds queued behind the in-flight one, drained in arrival order
    /// by the worker holding the checkout (so one session's frames are
    /// never applied out of order); bounded by [`MAX_SESSION_BACKLOG`]
    backlog: VecDeque<Request>,
    /// `None` while the state is checked out by a worker
    state: Option<StreamState>,
    last_fed: Instant,
}

impl SessionSlot {
    fn vacant() -> Self {
        SessionSlot {
            generation: 0,
            occupied: false,
            busy: false,
            pending_close: false,
            backlog: VecDeque::new(),
            state: None,
            last_fed: Instant::now(),
        }
    }
}

/// Slab of one model's streaming sessions: slot indices recycle through
/// a free list; monotone generation tags make recycled handles stale.
/// Every transition (open, feed, checkout, put-back, close, idle sweep)
/// happens under the table mutex, so feed and eviction linearize —
/// exactly one terminal outcome per feed (see the module docs).
struct SessionTable {
    slots: Vec<SessionSlot>,
    free: Vec<usize>,
    /// open sessions (occupied slots)
    live: usize,
    next_generation: u64,
}

impl SessionTable {
    fn new() -> Self {
        SessionTable { slots: Vec::new(), free: Vec::new(), live: 0, next_generation: 0 }
    }

    /// The slot behind a handle, iff the handle is still live.
    fn get_live(&mut self, sid: SessionId) -> Option<&mut SessionSlot> {
        let s = self.slots.get_mut(sid.slot)?;
        (s.occupied && s.generation == sid.generation).then_some(s)
    }

    /// Install a fresh session state, recycling a free slot if any.
    fn open(&mut self, state: StreamState) -> SessionId {
        let generation = self.next_generation;
        self.next_generation += 1;
        let slot = match self.free.pop() {
            Some(i) => i,
            None => {
                self.slots.push(SessionSlot::vacant());
                self.slots.len() - 1
            }
        };
        let s = &mut self.slots[slot];
        s.generation = generation;
        s.occupied = true;
        s.busy = false;
        s.pending_close = false;
        s.state = Some(state);
        s.last_fed = Instant::now();
        self.live += 1;
        SessionId { slot, generation }
    }

    /// Free a slot (drops its state, returns the index to the free
    /// list). The caller must have drained the backlog first.
    fn release(&mut self, slot: usize) {
        let s = &mut self.slots[slot];
        debug_assert!(s.occupied, "releasing a vacant session slot");
        debug_assert!(s.backlog.is_empty(), "releasing a slot with queued feeds");
        s.occupied = false;
        s.busy = false;
        s.pending_close = false;
        s.state = None;
        self.free.push(slot);
        self.live -= 1;
    }
}

/// The streaming half of a registered model: the shared immutable
/// [`Streamer`] plus the session slab.
struct StreamModel {
    streamer: Streamer,
    sessions: Mutex<SessionTable>,
    max_sessions: usize,
    idle_timeout: Duration,
}

/// Streaming snapshot for one model ([`ModelRegistry::stream_info`]).
#[derive(Clone, Copy, Debug)]
pub struct StreamInfo {
    pub open_sessions: usize,
    pub max_sessions: usize,
    /// exact bytes one session's state reserves
    /// ([`crate::stream::StatePlan::bytes_per_session`])
    pub bytes_per_session: usize,
    /// frames before a fresh session emits its first logits
    pub warmup_frames: usize,
    /// feature width of one feed frame
    pub frame_dim: usize,
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

/// Per-model admission-control policy (see the module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AdmissionPolicy {
    /// cap on admitted-but-unanswered requests per (model, lane);
    /// `usize::MAX` = unbounded. Over the cap, submit returns
    /// [`ServeError::Overloaded`] immediately.
    pub max_pending: usize,
    /// also shed a deadlined request whose cost-based ETA (pending
    /// depth x observed per-sample service EWMA / pool size) already
    /// exceeds its deadline budget
    pub shed_infeasible: bool,
    /// let the registry scale this model's replica budget up/down from
    /// observed queue pressure (starts at 1 and grows; off = the full
    /// pool serves the model, the pre-admission status quo)
    pub autoscale: bool,
}

impl Default for AdmissionPolicy {
    /// Unbounded, no feasibility shedding, no autoscaling — exactly
    /// the registry's behavior before admission control existed.
    fn default() -> Self {
        AdmissionPolicy { max_pending: usize::MAX, shed_infeasible: false, autoscale: false }
    }
}

impl AdmissionPolicy {
    /// Admit everything (the default).
    pub fn unbounded() -> Self {
        AdmissionPolicy::default()
    }

    /// Bound each lane's pending depth and shed infeasible deadlines —
    /// the saturation-safe configuration.
    pub fn bounded(max_pending: usize) -> Self {
        AdmissionPolicy {
            max_pending: max_pending.max(1),
            shed_infeasible: true,
            autoscale: false,
        }
    }

    /// Enable replica-budget autoscaling (see the module docs).
    pub fn with_autoscale(mut self) -> Self {
        self.autoscale = true;
        self
    }
}

/// Everything the registry needs to serve one model. Build with
/// [`ModelSpec::new`] + the `with_*` builders.
pub struct ModelSpec {
    pub factory: BackendFactory,
    /// flattened feature count per sample (checked at submit)
    pub sample_numel: usize,
    pub policy: BatchPolicy,
    /// estimated cost per sample in MACs (the DWFQ scheduling weight;
    /// typically [`QuantGraph::cost_per_sample`]). 0 = unknown, which
    /// schedules as cost 1 — request-count fair.
    pub cost_per_sample: u64,
    pub admission: AdmissionPolicy,
    /// streaming-session configuration; `None` = batch-only model
    pub streaming: Option<StreamSpec>,
    /// the graph the factory's replicas execute, attached for per-stage
    /// timing exposition and measured-cost DWFQ feedback
    /// ([`ModelSpec::with_observed_graph`]); `None` = static cost only
    pub observed_graph: Option<Arc<QuantGraph>>,
    /// Monte-Carlo ensemble size ([`ModelSpec::with_noise`]); 1 = plain
    /// single-shot serving. Surfaced in [`ModelStats::ensemble`].
    pub ensemble: usize,
}

impl ModelSpec {
    /// Spec with no declared cost and the default (unbounded, non-
    /// autoscaling) admission policy.
    pub fn new(factory: BackendFactory, sample_numel: usize, policy: BatchPolicy) -> Self {
        ModelSpec {
            factory,
            sample_numel,
            policy,
            cost_per_sample: 0,
            admission: AdmissionPolicy::default(),
            streaming: None,
            observed_graph: None,
            ensemble: 1,
        }
    }

    /// Declare the model's per-sample cost (MACs) for cost-aware
    /// weighted-fair scheduling and ETA-based shedding.
    pub fn with_cost(mut self, macs_per_sample: u64) -> Self {
        self.cost_per_sample = macs_per_sample;
        self
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> Self {
        self.admission = admission;
        self
    }

    /// Enable streaming sessions over a 1-D sequence graph: the model
    /// additionally answers [`ModelRegistry::open_session`] /
    /// [`ModelRegistry::feed`] / [`ModelRegistry::close_session`]. The
    /// graph is validated (and its state plan built) at register time.
    pub fn with_streaming(mut self, spec: StreamSpec) -> Self {
        self.streaming = Some(spec);
        self
    }

    /// Serve this model as an N-replica Monte-Carlo noisy ensemble: the
    /// current factory is wrapped in [`NoisyBackend::factory`] and the
    /// declared per-sample cost is multiplied by the ensemble size (N
    /// crossbar walks per request is N× the compute, and DWFQ should
    /// charge it) — so call this *after* [`ModelSpec::with_cost`].
    pub fn with_noise(mut self, spec: NoiseSpec) -> Self {
        let n = spec.replicas.max(1) as u64;
        self.ensemble = spec.replicas.max(1);
        self.cost_per_sample = self.cost_per_sample.max(1) * n;
        self.factory = NoisyBackend::factory(self.factory, spec);
        self
    }

    /// Attach the served [`QuantGraph`] (the same `Arc` the factory's
    /// replicas execute) so its cumulative per-stage timers show up in
    /// the metrics exposition (`fqconv_stage_us_total{model,stage}`)
    /// and its measured per-sample cost replaces the static MAC
    /// estimate in the DWFQ weight once the first samples land
    /// ([`QuantGraph::measured_us_per_sample`]).
    pub fn with_observed_graph(mut self, graph: &Arc<QuantGraph>) -> Self {
        self.observed_graph = Some(Arc::clone(graph));
        self
    }
}

/// Per-model lock-free counters + latency histograms.
///
/// Ordering policy (audited against the model-checker protocols, see
/// CONCURRENCY.md): every counter here is monitoring-only — bumped on
/// one thread, read by `stats()` snapshots that tolerate being a few
/// operations stale. `Relaxed` is sufficient because no control-flow
/// decision is derived from a counter value; the request/reply payloads
/// themselves travel through mpsc channels and the queue mutex, whose
/// release/acquire edges order the data.
struct ModelCounters {
    served: AtomicU64,
    batches: AtomicU64,
    expired: AtomicU64,
    dropped: AtomicU64,
    /// requests answered with [`ServeError::Overloaded`] at submit
    shed: AtomicU64,
    /// admitted-but-unanswered requests per lane: the admission
    /// reservation counter — incremented at submit (reserve), and
    /// decremented exactly once per request at its terminal reply
    /// (served / expired / failed). Relaxed: the *bound* needs only
    /// fetch_add/fetch_sub atomicity, not ordering — an over-the-cap
    /// reservation is rolled back before any payload exists, and the
    /// admitted payload is ordered by the ingress channel. Also read
    /// (Relaxed) as the queue-depth signal by the autoscaler and stats.
    pending: [AtomicUsize; 2],
    /// EWMA of observed per-sample service time in us (0 = no sample
    /// yet). Relaxed + racy load/store read-modify-write: a
    /// monitoring-quality estimate for ETA shedding; a lost update
    /// under a race only delays convergence by one batch.
    est_sample_us: AtomicU64,
    hist: Mutex<LatencyHist>,
    prio_hist: [Mutex<LatencyHist>; 2],
    served_by_prio: [AtomicU64; 2],
}

impl ModelCounters {
    fn new() -> Self {
        ModelCounters {
            served: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            expired: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            pending: [AtomicUsize::new(0), AtomicUsize::new(0)],
            est_sample_us: AtomicU64::new(0),
            hist: Mutex::new(LatencyHist::new()),
            prio_hist: [Mutex::new(LatencyHist::new()), Mutex::new(LatencyHist::new())],
            served_by_prio: [AtomicU64::new(0), AtomicU64::new(0)],
        }
    }
}

/// One registered model: identity, backend recipe, batching policy,
/// its ingress (taken on evict to stop the batcher) and its counters.
struct ModelEntry {
    id: ModelId,
    /// bumped per (re-)registration — a worker's cached replica for a
    /// re-registered id is stale when generations differ
    generation: u64,
    factory: BackendFactory,
    sample_numel: usize,
    policy: BatchPolicy,
    /// estimated MACs per sample (0 = unknown): the DWFQ weight
    cost_per_sample: u64,
    admission: AdmissionPolicy,
    /// how many workers (lowest indices first) may pull this model's
    /// batches; clamped to [1, n_workers] at use. Relaxed stores
    /// followed by `SharedQueue::wake_all` (the lock round-trip is the
    /// publication edge); consumed in `pop` under the queue mutex.
    replica_budget: AtomicUsize,
    ingress: Mutex<Option<Sender<Request>>>,
    counters: ModelCounters,
    /// streaming half ([`ModelSpec::with_streaming`]); `None` for
    /// batch-only models
    stream: Option<StreamModel>,
    /// the served graph's timers ([`ModelSpec::with_observed_graph`])
    observed_graph: Option<Arc<QuantGraph>>,
    /// Monte-Carlo ensemble size ([`ModelSpec::with_noise`]); 1 = plain
    ensemble: usize,
    /// the owning registry's observability plumbing, held per entry so
    /// the terminal-reply helpers ([`fail_batch`], [`expire`]) can
    /// trace from any call site
    obs: Arc<ServeObs>,
}

/// Per-worker counters (lock-free; read by [`ModelRegistry::stats`]).
/// Same `Relaxed` policy as [`ModelCounters`]: monitoring-only values,
/// except `retired`+`alive` whose shutdown edge is ordered by the
/// `AcqRel` fetch_sub in [`RetireGuard`]'s drop.
#[derive(Debug, Default)]
struct WorkerSlot {
    batches: AtomicU64,
    served: AtomicU64,
    errors: AtomicU64,
    retired: AtomicBool,
}

/// Snapshot of one worker's counters.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub worker: usize,
    pub batches: u64,
    pub served: u64,
    pub errors: u64,
    /// false once the worker died (panicking backend) or shut down —
    /// backend *errors* never retire a worker, they quarantine replicas
    pub alive: bool,
}

/// Per-priority latency snapshot.
#[derive(Clone, Debug, Default)]
pub struct PriorityStats {
    pub served: u64,
    pub p50_us: f64,
    pub p99_us: f64,
}

/// Snapshot of one model's counters.
#[derive(Clone, Debug)]
pub struct ModelStats {
    pub id: ModelId,
    pub served: u64,
    pub batches: u64,
    pub mean_batch: f64,
    /// requests answered with [`ServeError::DeadlineExceeded`]
    pub expired: u64,
    /// requests answered with [`ServeError::BackendFailed`]
    pub dropped: u64,
    /// requests shed with [`ServeError::Overloaded`] at submit
    pub shed: u64,
    /// admitted-but-unanswered requests at snapshot time (both lanes)
    pub pending: u64,
    /// current replica budget (workers allowed to pull this model)
    pub replica_budget: usize,
    /// open streaming sessions (0 for batch-only models)
    pub sessions: u64,
    /// Monte-Carlo ensemble size ([`ModelSpec::with_noise`]); 1 = plain
    pub ensemble: usize,
    pub latency_summary: String,
    pub p50_us: f64,
    pub p99_us: f64,
    /// indexed by [`Priority::index`]
    pub priorities: [PriorityStats; 2],
}

/// Registry-wide statistics snapshot.
#[derive(Clone, Debug)]
pub struct RegistryStats {
    pub served: u64,
    pub batches: u64,
    /// per registered model, sorted by id
    pub models: Vec<ModelStats>,
    /// per-worker counters, indexed by worker id
    pub workers: Vec<WorkerStats>,
}

struct RegistryInner {
    queue: SharedQueue,
    /// `RwLock`, not `Mutex`: submits to *different* models only take a
    /// read lock here, so concurrent client traffic never serializes on
    /// one registry-wide lock — writers are rare (register / evict)
    models: RwLock<HashMap<ModelId, Arc<ModelEntry>>>,
    /// Relaxed everywhere: only uniqueness of the handed-out ids is
    /// needed, which fetch_add's atomicity alone guarantees. Starts at
    /// 1: the ids double as trace ids and 0 is the tracer's
    /// not-request-tied sentinel.
    next_req_id: AtomicU64,
    /// Relaxed everywhere: ditto — generation values are *compared*
    /// under the `models` RwLock, never used as a publication fence.
    next_generation: AtomicU64,
    /// bumped per evict — workers compare against it to prune cached
    /// replicas of models that are no longer registered. Relaxed: a
    /// stale read only delays pruning by one loop iteration; the prune
    /// itself re-reads `models` under its RwLock, which provides the
    /// happens-before edge for the map contents.
    evictions: AtomicU64,
    served: AtomicU64,
    batches: AtomicU64,
    slots: Vec<WorkerSlot>,
    alive: AtomicUsize,
    /// a batch that keeps failing is answered with a typed error after
    /// this many deliveries; the +1 guarantees a batch failed only by
    /// one soon-to-quarantine replica still reaches a healthy one
    max_attempts: usize,
    /// quarantine hand-backs before a batch is failed (each bounce
    /// re-queues first and then backs off 1 ms, so a healthy worker has
    /// ample opportunity to take the batch in between)
    max_bounces: usize,
    /// metrics registry + trace rings + rate-limited log gates
    obs: Arc<ServeObs>,
}

/// Multi-model serving: register/evict named models at runtime; every
/// model gets its own ingress + batcher, all models share one worker
/// pool via the two-lane priority queue. See the module docs for the
/// full architecture diagram.
pub struct ModelRegistry {
    inner: Arc<RegistryInner>,
    workers: Vec<JoinHandle<()>>,
    batchers: Mutex<Vec<JoinHandle<()>>>,
}

impl ModelRegistry {
    /// Start a registry with `n_workers` pull-based worker threads and
    /// no models; [`ModelRegistry::register`] adds models at runtime.
    /// Observability is on with defaults — use
    /// [`ModelRegistry::start_with_obs`] to disable it or to inject a
    /// deterministic trace clock.
    pub fn start(n_workers: usize) -> Self {
        ModelRegistry::start_with_obs(n_workers, ObsConfig::default())
    }

    /// [`ModelRegistry::start`] with explicit observability
    /// configuration (master switch, trace-ring capacity, clock).
    pub fn start_with_obs(n_workers: usize, obs: ObsConfig) -> Self {
        assert!(n_workers >= 1, "registry needs at least one worker");
        let inner = Arc::new(RegistryInner {
            queue: SharedQueue::new(),
            models: RwLock::new(HashMap::new()),
            next_req_id: AtomicU64::new(1),
            next_generation: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            served: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            slots: (0..n_workers).map(|_| WorkerSlot::default()).collect(),
            alive: AtomicUsize::new(n_workers),
            max_attempts: n_workers + 1,
            max_bounces: 8 * n_workers,
            obs: Arc::new(ServeObs::new(n_workers, obs)),
        });
        let workers = (0..n_workers)
            .map(|wi| {
                let inner = Arc::clone(&inner);
                spawn_named(&format!("fqconv-worker-{wi}"), move || worker_loop(wi, &inner))
            })
            .collect();
        ModelRegistry { inner, workers, batchers: Mutex::new(Vec::new()) }
    }

    /// Register a model under `id`: spawns its ingress + batcher thread
    /// and makes it submittable. Errors if the id is already registered
    /// (evict first to replace).
    pub fn register(&self, id: impl Into<ModelId>, spec: ModelSpec) -> Result<()> {
        let id = id.into();
        let mut models = self.inner.models.write().unwrap();
        anyhow::ensure!(!models.contains_key(&id), "model {id} already registered");
        // validate the streaming graph (and build its state plan)
        // before the model becomes visible, so a 2-D graph fails the
        // register call instead of every later open_session
        let stream = match spec.streaming {
            Some(s) => {
                anyhow::ensure!(s.max_sessions >= 1, "max_sessions must be at least 1");
                Some(StreamModel {
                    streamer: Streamer::new(s.graph)?,
                    sessions: Mutex::new(SessionTable::new()),
                    max_sessions: s.max_sessions,
                    idle_timeout: s.idle_timeout,
                })
            }
            None => None,
        };
        let (tx, rx) = mpsc::channel::<Request>();
        // autoscaling models start with one replica and grow under
        // pressure; otherwise the whole pool serves the model (the
        // pre-admission status quo)
        let budget = if spec.admission.autoscale { 1 } else { self.inner.slots.len() };
        let entry = Arc::new(ModelEntry {
            id: id.clone(),
            generation: self.inner.next_generation.fetch_add(1, Ordering::Relaxed),
            factory: spec.factory,
            sample_numel: spec.sample_numel,
            policy: spec.policy,
            cost_per_sample: spec.cost_per_sample,
            admission: spec.admission,
            replica_budget: AtomicUsize::new(budget),
            ingress: Mutex::new(Some(tx)),
            counters: ModelCounters::new(),
            stream,
            observed_graph: spec.observed_graph,
            ensemble: spec.ensemble.max(1),
            obs: Arc::clone(&self.inner.obs),
        });
        models.insert(id.clone(), Arc::clone(&entry));
        drop(models);
        let inner = Arc::clone(&self.inner);
        let handle = spawn_named(&format!("fqconv-batcher-{id}"), move || {
            batcher_loop(rx, &inner, &entry)
        });
        let mut batchers = self.batchers.lock().unwrap();
        // reap batchers of evicted models (their threads already exited)
        // so register/evict cycles don't grow the handle list forever
        let mut i = 0;
        while i < batchers.len() {
            if batchers[i].is_finished() {
                let _ = batchers.swap_remove(i).join();
            } else {
                i += 1;
            }
        }
        batchers.push(handle);
        Ok(())
    }

    /// Evict a model: unregisters the id and stops its batcher (after
    /// it dispatched everything already ingressed). Batches already on
    /// the shared queue still get served. Returns false if the id was
    /// not registered.
    pub fn evict(&self, id: &ModelId) -> bool {
        let entry = self.inner.models.write().unwrap().remove(id);
        match entry {
            Some(e) => {
                // dropping the sender disconnects the batcher's ingress;
                // it dispatches its forming batches and exits
                e.ingress.lock().unwrap().take();
                // tell workers to prune their cached replica of this model
                self.inner.evictions.fetch_add(1, Ordering::Relaxed);
                true
            }
            None => false,
        }
    }

    /// Registered model ids, sorted.
    pub fn model_ids(&self) -> Vec<ModelId> {
        let mut ids: Vec<ModelId> = self.inner.models.read().unwrap().keys().cloned().collect();
        ids.sort();
        ids
    }

    /// Submit an Interactive request with no deadline.
    pub fn submit(
        &self,
        id: &ModelId,
        features: Vec<f32>,
    ) -> std::result::Result<Receiver<ServeResult>, ServeError> {
        self.submit_with(id, features, Priority::Interactive, None)
    }

    /// Submit with an explicit priority class and optional deadline
    /// budget (relative to now); returns the reply channel.
    pub fn submit_with(
        &self,
        id: &ModelId,
        features: Vec<f32>,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> std::result::Result<Receiver<ServeResult>, ServeError> {
        let entry = match self.inner.models.read().unwrap().get(id) {
            Some(e) => Arc::clone(e),
            None => return Err(ServeError::UnknownModel(id.clone())),
        };
        assert_eq!(features.len(), entry.sample_numel, "bad feature length for model {id}");
        // the request id doubles as its trace id: minted before the
        // admission decision so a shed leaves a complete trace too
        let rid = self.inner.next_req_id.fetch_add(1, Ordering::Relaxed);
        let lane = priority.index();
        entry.obs.event(0, rid, EventKind::Submit, lane as u32, 0);
        // admission control: reserve a pending slot before anything
        // else exists for this request. The fetch_add *is* the
        // reservation — its atomicity alone enforces the bound under
        // any interleaving; an over-the-cap reservation is rolled back
        // and the caller gets the typed shed reply right here, at
        // submit, not at its deadline.
        let held = entry.counters.pending[lane].fetch_add(1, Ordering::Relaxed);
        if held >= entry.admission.max_pending {
            entry.counters.pending[lane].fetch_sub(1, Ordering::Relaxed);
            entry.counters.shed.fetch_add(1, Ordering::Relaxed);
            entry.obs.shed_event(0, rid, SHED_OVERLOAD);
            return Err(ServeError::Overloaded { model: id.clone(), pending: held });
        }
        // cost-based deadline feasibility: if the admitted backlog
        // already implies an ETA past this request's deadline, shed now
        // instead of admitting a request that can only expire
        if entry.admission.shed_infeasible {
            if let Some(budget) = deadline {
                let est = entry.counters.est_sample_us.load(Ordering::Relaxed);
                if est > 0 {
                    let backlog = (entry.counters.pending[0].load(Ordering::Relaxed)
                        + entry.counters.pending[1].load(Ordering::Relaxed))
                        as u64;
                    let eta_us = backlog * est / self.inner.slots.len().max(1) as u64;
                    if Duration::from_micros(eta_us) > budget {
                        entry.counters.pending[lane].fetch_sub(1, Ordering::Relaxed);
                        entry.counters.shed.fetch_add(1, Ordering::Relaxed);
                        entry.obs.shed_event(0, rid, SHED_INFEASIBLE);
                        return Err(ServeError::Overloaded {
                            model: id.clone(),
                            pending: backlog as usize,
                        });
                    }
                }
            }
        }
        let now = Instant::now();
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: rid,
            features,
            priority,
            deadline: deadline.map(|d| now + d),
            submitted: now,
            reply: tx,
        };
        let ingress = entry.ingress.lock().unwrap();
        match ingress.as_ref().map(|tx| tx.send(req)) {
            Some(Ok(())) => Ok(rx),
            // racing an evict: the model is gone as far as clients
            // care; the request never entered, release its reservation
            _ => {
                drop(ingress);
                entry.counters.pending[lane].fetch_sub(1, Ordering::Relaxed);
                entry.obs.shed_event(0, rid, SHED_EVICTED);
                Err(ServeError::UnknownModel(id.clone()))
            }
        }
    }

    /// Set a model's replica budget directly (clamped to
    /// `[1, n_workers]`); returns false for an unknown id. The
    /// autoscaler (if enabled for the model) keeps adjusting from here.
    pub fn set_replica_budget(&self, id: &ModelId, budget: usize) -> bool {
        let entry = match self.inner.models.read().unwrap().get(id) {
            Some(e) => Arc::clone(e),
            None => return false,
        };
        let clamped = budget.clamp(1, self.inner.slots.len());
        // Relaxed + wake_all: see the field's ordering note
        entry.replica_budget.store(clamped, Ordering::Relaxed);
        self.inner.queue.wake_all();
        true
    }

    /// Open a streaming session on a model registered with
    /// [`ModelSpec::with_streaming`]. Bounded by the spec's
    /// `max_sessions`: over the bound, returns the typed
    /// [`ServeError::Overloaded`] immediately (state-residency
    /// admission control, consistent with request shedding).
    ///
    /// # Panics
    /// On a model registered without streaming — a programmer error,
    /// like a bad feature length at submit.
    pub fn open_session(&self, id: &ModelId) -> std::result::Result<SessionId, ServeError> {
        let entry = match self.inner.models.read().unwrap().get(id) {
            Some(e) => Arc::clone(e),
            None => return Err(ServeError::UnknownModel(id.clone())),
        };
        let sm = stream_model(&entry);
        let mut tab = sm.sessions.lock().unwrap();
        if tab.live >= sm.max_sessions {
            entry.counters.shed.fetch_add(1, Ordering::Relaxed);
            entry.obs.shed_event(0, 0, SHED_SESSION_CAP);
            return Err(ServeError::Overloaded { model: id.clone(), pending: tab.live });
        }
        let sid = tab.open(sm.streamer.open());
        entry.obs.event(0, 0, EventKind::SessionOpen, sid.slot as u32, 0);
        Ok(sid)
    }

    /// Feed one frame (`stream_info().frame_dim` features) to an open
    /// session. Replies on the returned channel with the session's
    /// running logits — empty `logits` (and class 0) while the session
    /// is still inside its warm-up receptive field. A stale handle gets
    /// the typed [`ServeError::UnknownSession`]; feeds racing an
    /// in-flight feed of the same session queue behind it (bounded,
    /// then [`ServeError::Overloaded`]) and are applied in feed order.
    ///
    /// # Panics
    /// On a wrong frame length or a model without streaming — both
    /// programmer errors, like a bad feature length at submit.
    pub fn feed(
        &self,
        id: &ModelId,
        sid: SessionId,
        frame: Vec<f32>,
    ) -> std::result::Result<Receiver<ServeResult>, ServeError> {
        let entry = match self.inner.models.read().unwrap().get(id) {
            Some(e) => Arc::clone(e),
            None => return Err(ServeError::UnknownModel(id.clone())),
        };
        let sm = stream_model(&entry);
        assert_eq!(frame.len(), sm.streamer.frame_dim(), "bad frame length for model {id}");
        let now = Instant::now();
        let rid = self.inner.next_req_id.fetch_add(1, Ordering::Relaxed);
        entry.obs.event(0, rid, EventKind::Submit, Priority::Interactive.index() as u32, 0);
        let (tx, rx) = mpsc::channel();
        let req = Request {
            id: rid,
            features: frame,
            priority: Priority::Interactive,
            deadline: None,
            submitted: now,
            reply: tx,
        };
        let lane = Priority::Interactive.index();
        let mut tab = sm.sessions.lock().unwrap();
        let slot = match tab.get_live(sid) {
            Some(s) if !s.pending_close => s,
            _ => {
                entry.obs.shed_event(0, rid, SHED_STALE_SESSION);
                return Err(ServeError::UnknownSession { model: id.clone() });
            }
        };
        slot.last_fed = now;
        if slot.busy {
            // a worker holds the checkout: queue behind the in-flight
            // feed; the holder drains the backlog in feed order before
            // putting the state back
            if slot.backlog.len() >= MAX_SESSION_BACKLOG {
                entry.counters.shed.fetch_add(1, Ordering::Relaxed);
                entry.obs.shed_event(0, rid, SHED_BACKLOG);
                return Err(ServeError::Overloaded {
                    model: id.clone(),
                    pending: slot.backlog.len(),
                });
            }
            // admission reservation, released at the terminal reply
            entry.counters.pending[lane].fetch_add(1, Ordering::Relaxed);
            entry.obs.event(0, rid, EventKind::Backlog, sid.slot as u32, 0);
            slot.backlog.push_back(req);
            return Ok(rx);
        }
        slot.busy = true;
        entry.counters.pending[lane].fetch_add(1, Ordering::Relaxed);
        entry.obs.event(0, rid, EventKind::Enqueue, lane as u32, 1);
        drop(tab);
        // bypass the forming batcher: a feed is already a complete unit
        // of work, and frame latency is the product metric
        self.inner.queue.push(QueuedBatch {
            model: Arc::clone(&entry),
            priority: Priority::Interactive,
            reqs: vec![req],
            attempts: 0,
            bounces: 0,
            session: Some(sid),
        });
        Ok(rx)
    }

    /// Close a session. If a feed is in flight, the slot is freed by
    /// the worker when it finishes (the feed still gets its served
    /// reply); either way the handle is immediately stale — subsequent
    /// feeds get [`ServeError::UnknownSession`].
    pub fn close_session(
        &self,
        id: &ModelId,
        sid: SessionId,
    ) -> std::result::Result<(), ServeError> {
        let entry = match self.inner.models.read().unwrap().get(id) {
            Some(e) => Arc::clone(e),
            None => return Err(ServeError::UnknownModel(id.clone())),
        };
        let sm = stream_model(&entry);
        let mut tab = sm.sessions.lock().unwrap();
        let busy = match tab.get_live(sid) {
            Some(s) if !s.pending_close => s.busy,
            _ => return Err(ServeError::UnknownSession { model: id.clone() }),
        };
        if busy {
            // the worker holding the checkout frees the slot at put-back
            tab.get_live(sid).expect("validated above").pending_close = true;
        } else {
            tab.release(sid.slot);
        }
        entry.obs.event(0, 0, EventKind::SessionClose, sid.slot as u32, 0);
        Ok(())
    }

    /// Streaming snapshot for a model: open-session count and the state
    /// plan's per-session geometry. `None` for unknown or batch-only
    /// models.
    pub fn stream_info(&self, id: &ModelId) -> Option<StreamInfo> {
        let entry = Arc::clone(self.inner.models.read().unwrap().get(id)?);
        let sm = entry.stream.as_ref()?;
        let plan = sm.streamer.plan();
        Some(StreamInfo {
            open_sessions: sm.sessions.lock().unwrap().live,
            max_sessions: sm.max_sessions,
            bytes_per_session: plan.bytes_per_session(),
            warmup_frames: plan.warmup_frames(),
            frame_dim: sm.streamer.frame_dim(),
        })
    }

    /// Blocking convenience call (Interactive, no deadline).
    pub fn infer(&self, id: &ModelId, features: Vec<f32>) -> ServeResult {
        match self.submit(id, features) {
            Ok(rx) => rx.recv().unwrap_or_else(|_| {
                Err(ServeError::BackendFailed { model: id.clone(), attempts: 0 })
            }),
            Err(e) => Err(e),
        }
    }

    pub fn stats(&self) -> RegistryStats {
        let mut entries: Vec<Arc<ModelEntry>> =
            self.inner.models.read().unwrap().values().cloned().collect();
        entries.sort_by(|a, b| a.id.cmp(&b.id));
        let models = entries.iter().map(|e| model_stats(e)).collect();
        let workers = self
            .inner
            .slots
            .iter()
            .enumerate()
            .map(|(i, s)| WorkerStats {
                worker: i,
                batches: s.batches.load(Ordering::Relaxed),
                served: s.served.load(Ordering::Relaxed),
                errors: s.errors.load(Ordering::Relaxed),
                alive: !s.retired.load(Ordering::Relaxed),
            })
            .collect();
        RegistryStats {
            served: self.inner.served.load(Ordering::Relaxed),
            batches: self.inner.batches.load(Ordering::Relaxed),
            models,
            workers,
        }
    }

    /// Merge-on-read snapshot of every metric the registry exposes:
    /// the pre-registered obs counters (sheds by reason, worker errors,
    /// quarantines, suppressed log lines), per-model serving counters +
    /// latency histograms, queue depth/deficit and replica-budget
    /// gauges, session counts, per-stage timing of observed graphs, and
    /// the trace-ring totals. Sorted by `(name, labels)`.
    pub fn metrics_samples(&self) -> Vec<MetricSample> {
        fn push(out: &mut Vec<MetricSample>, name: &'static str, labels: String, v: SampleValue) {
            out.push(MetricSample { name, labels, value: v });
        }
        let mut out = self.inner.obs.metrics.snapshot();
        let mut entries: Vec<Arc<ModelEntry>> =
            self.inner.models.read().unwrap().values().cloned().collect();
        entries.sort_by(|a, b| a.id.cmp(&b.id));
        for e in &entries {
            let l = format!("model=\"{}\"", e.id);
            let c = &e.counters;
            let served = c.served.load(Ordering::Relaxed);
            let batches = c.batches.load(Ordering::Relaxed);
            push(&mut out, "fqconv_served_total", l.clone(), SampleValue::Counter(served));
            push(&mut out, "fqconv_batches_total", l.clone(), SampleValue::Counter(batches));
            let expired = c.expired.load(Ordering::Relaxed);
            push(&mut out, "fqconv_expired_total", l.clone(), SampleValue::Counter(expired));
            let dropped = c.dropped.load(Ordering::Relaxed);
            push(&mut out, "fqconv_failed_total", l.clone(), SampleValue::Counter(dropped));
            let shed = c.shed.load(Ordering::Relaxed);
            push(&mut out, "fqconv_model_shed_total", l.clone(), SampleValue::Counter(shed));
            for p in Priority::ALL {
                let pl = format!("model=\"{}\",lane=\"{}\"", e.id, p.index());
                let pending = c.pending[p.index()].load(Ordering::Relaxed) as u64;
                push(&mut out, "fqconv_pending", pl, SampleValue::Gauge(pending));
            }
            let budget = e.replica_budget.load(Ordering::Relaxed) as u64;
            push(&mut out, "fqconv_replica_budget", l.clone(), SampleValue::Gauge(budget));
            if let Some(sm) = e.stream.as_ref() {
                let live = sm.sessions.lock().unwrap().live as u64;
                push(&mut out, "fqconv_open_sessions", l.clone(), SampleValue::Gauge(live));
            }
            let hist = c.hist.lock().unwrap().clone();
            push(&mut out, "fqconv_latency", l.clone(), SampleValue::Hist(hist));
            if let Some(g) = e.observed_graph.as_ref() {
                for st in g.stage_times() {
                    let sl = format!(
                        "model=\"{}\",index=\"{}\",stage=\"{}\"",
                        e.id, st.index, st.kind
                    );
                    let us = st.total_ns / 1_000;
                    push(&mut out, "fqconv_stage_us_total", sl.clone(), SampleValue::Counter(us));
                    let calls = SampleValue::Counter(st.calls);
                    push(&mut out, "fqconv_stage_calls_total", sl, calls);
                }
                if let Some(us) = g.measured_us_per_sample() {
                    let v = SampleValue::Gauge(us);
                    push(&mut out, "fqconv_measured_us_per_sample", l.clone(), v);
                }
            }
        }
        for (lane, id, batches, reqs, deficit) in self.inner.queue.depth_snapshot() {
            let ql = format!("model=\"{id}\",lane=\"{lane}\"");
            push(&mut out, "fqconv_queue_batches", ql.clone(), SampleValue::Gauge(batches));
            push(&mut out, "fqconv_queue_requests", ql.clone(), SampleValue::Gauge(reqs));
            push(&mut out, "fqconv_queue_deficit", ql, SampleValue::Gauge(deficit));
        }
        let alive = self.inner.alive.load(Ordering::Relaxed) as u64;
        push(&mut out, "fqconv_workers_alive", String::new(), SampleValue::Gauge(alive));
        let ev = self.inner.obs.trace.events_total();
        push(&mut out, "fqconv_trace_events_total", String::new(), SampleValue::Counter(ev));
        let dr = self.inner.obs.trace.dropped();
        push(&mut out, "fqconv_trace_dropped_total", String::new(), SampleValue::Counter(dr));
        out.sort_by(|a, b| (a.name, &a.labels).cmp(&(b.name, &b.labels)));
        out
    }

    /// Prometheus text exposition of [`ModelRegistry::metrics_samples`].
    pub fn metrics_text(&self) -> String {
        prometheus_text(&self.metrics_samples())
    }

    /// JSON exposition of [`ModelRegistry::metrics_samples`].
    pub fn metrics_json(&self) -> String {
        samples_json(&self.metrics_samples()).to_string()
    }

    /// Best-effort live decode of the trace rings (see the reliability
    /// contract in [`crate::obs::trace`]); use
    /// [`ModelRegistry::shutdown_with_traces`] for an exact snapshot.
    pub fn trace_snapshot(&self) -> Vec<TraceEvent> {
        self.inner.obs.trace.snapshot()
    }

    /// `(events_recorded, events_lost_to_wraparound)` across the trace
    /// rings — when the second number is 0, every recorded event is
    /// still retained and a trace reconstruction is complete.
    pub fn trace_counts(&self) -> (u64, u64) {
        (self.inner.obs.trace.events_total(), self.inner.obs.trace.dropped())
    }

    /// Graceful shutdown: stop every batcher, let workers drain the
    /// queue, then join all threads. Dropping the registry performs the
    /// same teardown, so an early return or panic cannot leak the pool.
    pub fn shutdown(mut self) {
        self.teardown();
    }

    /// [`ModelRegistry::shutdown`], returning the final trace snapshot.
    /// Exact: every writer thread has been joined, so the join's
    /// happens-before makes all `Relaxed` ring writes visible.
    pub fn shutdown_with_traces(mut self) -> Vec<TraceEvent> {
        self.teardown();
        self.inner.obs.trace.snapshot()
    }

    /// Idempotent shutdown body, shared by [`ModelRegistry::shutdown`]
    /// and `Drop`.
    fn teardown(&mut self) {
        {
            let models = self.inner.models.read().unwrap();
            for e in models.values() {
                e.ingress.lock().unwrap().take();
            }
        }
        for b in self.batchers.lock().unwrap().drain(..) {
            let _ = b.join();
        }
        // everything ingressed is now on the queue; close it so workers
        // exit after draining
        self.inner.queue.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for ModelRegistry {
    fn drop(&mut self) {
        self.teardown();
    }
}

/// The streaming half of a model entry; panics (programmer error) on a
/// batch-only model, mirroring the submit-time feature-length assert.
fn stream_model(entry: &ModelEntry) -> &StreamModel {
    entry.stream.as_ref().unwrap_or_else(|| {
        panic!("model {} was registered without streaming (ModelSpec::with_streaming)", entry.id)
    })
}

fn model_stats(e: &ModelEntry) -> ModelStats {
    let served = e.counters.served.load(Ordering::Relaxed);
    let batches = e.counters.batches.load(Ordering::Relaxed);
    let hist = e.counters.hist.lock().unwrap();
    let mut priorities: [PriorityStats; 2] = Default::default();
    for p in Priority::ALL {
        let i = p.index();
        let ph = e.counters.prio_hist[i].lock().unwrap();
        priorities[i] = PriorityStats {
            served: e.counters.served_by_prio[i].load(Ordering::Relaxed),
            p50_us: ph.percentile(50.0),
            p99_us: ph.percentile(99.0),
        };
    }
    ModelStats {
        id: e.id.clone(),
        served,
        batches,
        mean_batch: if batches > 0 { served as f64 / batches as f64 } else { 0.0 },
        expired: e.counters.expired.load(Ordering::Relaxed),
        dropped: e.counters.dropped.load(Ordering::Relaxed),
        shed: e.counters.shed.load(Ordering::Relaxed),
        pending: (e.counters.pending[0].load(Ordering::Relaxed)
            + e.counters.pending[1].load(Ordering::Relaxed)) as u64,
        replica_budget: e.replica_budget.load(Ordering::Relaxed),
        sessions: e.stream.as_ref().map_or(0, |sm| sm.sessions.lock().unwrap().live as u64),
        ensemble: e.ensemble,
        latency_summary: hist.summary(),
        p50_us: hist.percentile(50.0),
        p99_us: hist.percentile(99.0),
        priorities,
    }
}

// ---------------------------------------------------------------------------
// Single-model facade
// ---------------------------------------------------------------------------

/// Server statistics snapshot (single-model facade view).
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    pub served: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub expired: u64,
    pub dropped: u64,
    /// requests shed with [`ServeError::Overloaded`] at submit
    pub shed: u64,
    pub latency_summary: String,
    pub p50_us: f64,
    pub p99_us: f64,
    /// indexed by [`Priority::index`]
    pub priorities: [PriorityStats; 2],
    /// per-worker counters, indexed by worker id
    pub workers: Vec<WorkerStats>,
}

/// Single-model convenience facade over a [`ModelRegistry`]: one
/// registered model named `"default"`, same workers/batcher/queue
/// machinery underneath.
pub struct Server {
    registry: ModelRegistry,
    model: ModelId,
}

impl Server {
    /// Start a registry with `workers` worker threads and register one
    /// model over `factory` (default cost/admission; use
    /// [`Server::start_spec`] for admission control).
    pub fn start(
        factory: BackendFactory,
        workers: usize,
        sample_numel: usize,
        policy: BatchPolicy,
    ) -> Self {
        Server::start_spec(ModelSpec::new(factory, sample_numel, policy), workers)
    }

    /// [`Server::start`] with a full [`ModelSpec`] — cost estimate and
    /// admission policy included.
    pub fn start_spec(spec: ModelSpec, workers: usize) -> Self {
        Server::start_spec_obs(spec, workers, ObsConfig::default())
    }

    /// [`Server::start_spec`] with explicit observability configuration
    /// ([`ModelRegistry::start_with_obs`]).
    pub fn start_spec_obs(spec: ModelSpec, workers: usize, obs: ObsConfig) -> Self {
        let registry = ModelRegistry::start_with_obs(workers, obs);
        let model = ModelId::new("default");
        registry.register(model.clone(), spec).expect("fresh registry cannot have the id");
        Server { registry, model }
    }

    /// The underlying registry (register more models, evict, etc.).
    pub fn registry(&self) -> &ModelRegistry {
        &self.registry
    }

    pub fn model_id(&self) -> &ModelId {
        &self.model
    }

    /// Submit an Interactive request; returns the reply channel.
    pub fn submit(&self, features: Vec<f32>) -> Receiver<ServeResult> {
        self.submit_with(features, Priority::Interactive, None)
    }

    /// Submit with a priority class and optional deadline budget. If the
    /// facade's model was evicted through [`Server::registry`], the
    /// reply channel carries the typed [`ServeError::UnknownModel`]
    /// (never a panic or bare disconnect).
    pub fn submit_with(
        &self,
        features: Vec<f32>,
        priority: Priority,
        deadline: Option<Duration>,
    ) -> Receiver<ServeResult> {
        match self.registry.submit_with(&self.model, features, priority, deadline) {
            Ok(rx) => rx,
            Err(e) => {
                let (tx, rx) = mpsc::channel();
                let _ = tx.send(Err(e));
                rx
            }
        }
    }

    /// Blocking convenience call; panics on a serving error (use
    /// [`Server::submit`] for typed error handling).
    pub fn infer(&self, features: Vec<f32>) -> Response {
        self.submit(features).recv().expect("worker dropped").expect("serving failed")
    }

    /// Open a streaming session on the facade model (see
    /// [`ModelRegistry::open_session`]).
    pub fn open_session(&self) -> std::result::Result<SessionId, ServeError> {
        self.registry.open_session(&self.model)
    }

    /// Feed one frame to a session (see [`ModelRegistry::feed`]).
    pub fn feed(
        &self,
        sid: SessionId,
        frame: Vec<f32>,
    ) -> std::result::Result<Receiver<ServeResult>, ServeError> {
        self.registry.feed(&self.model, sid, frame)
    }

    /// Close a session (see [`ModelRegistry::close_session`]).
    pub fn close_session(&self, sid: SessionId) -> std::result::Result<(), ServeError> {
        self.registry.close_session(&self.model, sid)
    }

    pub fn stats(&self) -> ServerStats {
        let rs = self.registry.stats();
        let m = rs.models.into_iter().find(|m| m.id == self.model);
        let mut out = ServerStats { workers: rs.workers, ..Default::default() };
        if let Some(m) = m {
            out.served = m.served;
            out.batches = m.batches;
            out.mean_batch = m.mean_batch;
            out.expired = m.expired;
            out.dropped = m.dropped;
            out.shed = m.shed;
            out.latency_summary = m.latency_summary;
            out.p50_us = m.p50_us;
            out.p99_us = m.p99_us;
            out.priorities = m.priorities;
        }
        out
    }

    /// Prometheus text exposition of the full metrics snapshot
    /// ([`ModelRegistry::metrics_text`]).
    pub fn metrics_text(&self) -> String {
        self.registry.metrics_text()
    }

    /// JSON exposition of the full metrics snapshot
    /// ([`ModelRegistry::metrics_json`]).
    pub fn metrics_json(&self) -> String {
        self.registry.metrics_json()
    }

    /// Graceful shutdown: drain, then join threads.
    pub fn shutdown(self) {
        self.registry.shutdown();
    }

    /// Shut down and return the exact final trace snapshot
    /// ([`ModelRegistry::shutdown_with_traces`]).
    pub fn shutdown_with_traces(self) -> Vec<TraceEvent> {
        self.registry.shutdown_with_traces()
    }
}

// ---------------------------------------------------------------------------
// Worker + batcher loops
// ---------------------------------------------------------------------------

/// A worker quarantines its replica for a model after this many
/// **consecutive** backend errors on that model — one error can be
/// batch-attributed (bad payload), an unbroken run of them means the
/// replica itself is poisoned. Any successful batch resets the budget.
/// Quarantine is per `(worker, model)`: the worker stays alive and
/// keeps serving every other model, and re-queues the quarantined
/// model's batches (bounded attempts) so healthy replicas on other
/// workers can absorb them — one broken model cannot take down the
/// shared pool.
pub const MAX_WORKER_ERRORS: u64 = 2;

/// Runs the worker's retirement bookkeeping on *every* exit path —
/// including a panicking backend — so the last worker out always
/// closes the queue and answers waiting clients with typed errors.
struct RetireGuard<'a> {
    slot: &'a WorkerSlot,
    inner: &'a RegistryInner,
}

impl Drop for RetireGuard<'_> {
    fn drop(&mut self) {
        // Relaxed: stats-only flag; no reader derives control flow from it.
        self.slot.retired.store(true, Ordering::Relaxed);
        // AcqRel (required, not just documentation): the last worker out
        // must observe every predecessor's retirement before deciding it
        // is last — Release publishes this worker's retirement, Acquire
        // orders it after the others', so exactly one worker sees the
        // count hit 1 and closes/drains the queue exactly once.
        if self.inner.alive.fetch_sub(1, Ordering::AcqRel) == 1 {
            // last worker out: nothing can serve queued batches any more
            for qb in self.inner.queue.close_and_drain() {
                fail_batch(qb, 0);
            }
        } else {
            // a worker died mid-run: wake the survivors so batches that
            // were budget-gated onto *this* worker get re-evaluated
            // against the retired-fallback in `budget_admits` instead of
            // waiting on a notify that will never come
            self.inner.queue.wake_all();
        }
    }
}

/// `max_by(partial_cmp)` over a logits row — last maximum wins on ties,
/// matching `TensorF::argmax_rows` so the registry rework changed no
/// predicted class.
fn argmax(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// One worker: pull batches from the shared queue until it closes,
/// lazily building one backend replica per model (cached across
/// batches, invalidated by re-registration, pruned on eviction via the
/// registry's eviction epoch). A backend error re-queues the batch at
/// the back of its lane (bounded attempts, then a typed error); after
/// [`MAX_WORKER_ERRORS`] consecutive failures *on one model* the worker
/// quarantines that model's replica — it keeps serving every other
/// model and hands the quarantined model's batches back to the queue
/// for healthier replicas. The worker itself only exits on queue close
/// or a panicking backend (RetireGuard).
fn worker_loop(wi: usize, inner: &RegistryInner) {
    let slot = &inner.slots[wi];
    let _guard = RetireGuard { slot, inner };
    let mut backends: HashMap<ModelId, (u64, Box<dyn Backend>)> = HashMap::new();
    // per model: (generation, consecutive error count) / quarantined
    // generation — generation-scoped so a re-registered model never
    // inherits its predecessor's error budget
    let mut errs: HashMap<ModelId, (u64, u64)> = HashMap::new();
    let mut quarantined: HashMap<ModelId, u64> = HashMap::new();
    let mut seen_evictions = 0u64;
    // staging buffers, recycled across batches and models
    let mut flat: Vec<f32> = Vec::new();
    let mut out: Vec<f32> = Vec::new();
    let mut live: Vec<Request> = Vec::new();
    // per-model streaming scratch (generation-scoped like replicas) and
    // the recycled logits row for session feeds
    let mut stream_scratch: HashMap<ModelId, (u64, StreamScratch)> = HashMap::new();
    let mut feed_logits: Vec<f32> = Vec::new();
    while let Some(mut qb) = inner.queue.pop(wi, &inner.slots) {
        let entry = Arc::clone(&qb.model);
        // an evict happened since we last looked: drop replicas (and
        // quarantine marks) whose registration is gone, so e.g. an
        // evicted XLA replica does not sit in memory until shutdown
        let evictions = inner.evictions.load(Ordering::Relaxed);
        if evictions != seen_evictions {
            seen_evictions = evictions;
            let models = inner.models.read().unwrap();
            backends.retain(|mid, (gen, _)| {
                models.get(mid).is_some_and(|e| e.generation == *gen)
            });
            quarantined.retain(|mid, gen| {
                models.get(mid).is_some_and(|e| e.generation == *gen)
            });
            errs.retain(|mid, (gen, _)| {
                models.get(mid).is_some_and(|e| e.generation == *gen)
            });
            stream_scratch.retain(|mid, (gen, _)| {
                models.get(mid).is_some_and(|e| e.generation == *gen)
            });
        }
        // streaming-session feed: no backend replica involved — check
        // the session state out of the table and run the stream path
        if let Some(sid) = qb.session {
            serve_stream_feed(inner, wi, slot, qb, sid, &mut stream_scratch, &mut feed_logits);
            continue;
        }
        // expire members whose deadline passed while queued
        let now = Instant::now();
        live.clear();
        for r in qb.reqs.drain(..) {
            if r.deadline.is_some_and(|d| now > d) {
                expire(r, &entry, wi + 1);
            } else {
                live.push(r);
            }
        }
        if live.is_empty() {
            continue;
        }
        std::mem::swap(&mut qb.reqs, &mut live);
        let b = qb.reqs.len();

        // this worker's replica is quarantined: hand the batch back for
        // another worker. Re-queue FIRST so the batch is visible to
        // healthier workers during this worker's back-off; the bounce
        // budget keeps this terminating (with a typed failure) even
        // when every worker has quarantined the model.
        if quarantined.get(&entry.id) == Some(&entry.generation) {
            qb.bounces += 1;
            if qb.bounces >= inner.max_bounces {
                inner.obs.limited_error(&inner.obs.err_bounce, wi, || {
                    format!(
                        "model {}: every worker has quarantined its replica; failing a \
                         batch of {b} after {} hand-backs",
                        entry.id, qb.bounces
                    )
                });
                fail_batch(qb, wi + 1);
            } else {
                for r in &qb.reqs {
                    inner.obs.event(wi + 1, r.id, EventKind::Requeue, wi as u32, b as u32);
                }
                inner.queue.push(qb);
                thread::sleep(Duration::from_millis(1));
            }
            continue;
        }

        // resolve this worker's replica for the model (lazy + cached)
        let fresh = backends.get(&entry.id).is_some_and(|(gen, _)| *gen == entry.generation);
        let mut oneshot: Option<Box<dyn Backend>> = None;
        if !fresh {
            let live_generation =
                inner.models.read().unwrap().get(&entry.id).map(|e| e.generation);
            let replica = (entry.factory)(wi);
            // a misregistered model (factory shape != sample_numel) must
            // fail typed, not panic inside the backend in release builds
            // — a panicking worker is the one cascade quarantine cannot
            // contain
            let numel: usize = replica.sample_shape().iter().product();
            if numel != entry.sample_numel {
                log::error!(
                    "model {}: backend sample shape {:?} (numel {numel}) disagrees with \
                     registered sample_numel {}; quarantining and failing the batch",
                    entry.id,
                    replica.sample_shape(),
                    entry.sample_numel
                );
                quarantined.insert(entry.id.clone(), entry.generation);
                inner.obs.quarantines.inc(wi);
                inner.obs.event(wi + 1, 0, EventKind::Quarantine, wi as u32, 0);
                fail_batch(qb, wi + 1);
                continue;
            }
            if live_generation == Some(entry.generation) {
                backends.insert(entry.id.clone(), (entry.generation, replica));
            } else {
                // the batch belongs to an evicted / replaced registration:
                // serve it with a one-shot replica instead of evicting the
                // cache entry for the model's *current* generation
                oneshot = Some(replica);
            }
        }
        let backend = match oneshot.as_mut() {
            Some(b) => b,
            None => &mut backends.get_mut(&entry.id).unwrap().1,
        };

        flat.clear();
        flat.reserve(b * entry.sample_numel);
        for r in &qb.reqs {
            inner.obs.event(wi + 1, r.id, EventKind::Dispatch, wi as u32, b as u32);
            flat.extend_from_slice(&r.features);
        }
        let classes = backend.out_dim();
        out.clear();
        out.resize(b * classes, 0.0);
        // Contain a panicking backend (e.g. chaos-injected): answer the
        // batch with typed failures FIRST — releasing every member's
        // admission reservation — then let the unwind continue so the
        // worker still dies per the RetireGuard contract. Without this,
        // the panicking batch's clients would hang until queue close.
        let started = Instant::now();
        let infer = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            backend.infer_into(&flat, b, &mut out)
        }));
        let infer = match infer {
            Ok(r) => r,
            Err(payload) => {
                fail_batch(qb, wi + 1);
                std::panic::resume_unwind(payload);
            }
        };
        match infer {
            Ok(()) => {
                // feed the per-sample service-time estimator that the
                // deadline-feasibility shed in `submit_with` reads
                let per_sample_us =
                    ((started.elapsed().as_secs_f64() * 1e6) as u64 / b as u64).max(1);
                let old = entry.counters.est_sample_us.load(Ordering::Relaxed);
                let est =
                    if old == 0 { per_sample_us } else { (old * 7 + per_sample_us) / 8 };
                entry.counters.est_sample_us.store(est, Ordering::Relaxed);
                // the budget is for *consecutive* failures of this
                // registration — a stale one-shot success must not clear
                // the current replica's count
                if errs.get(&entry.id).is_some_and(|(gen, _)| *gen == entry.generation) {
                    errs.remove(&entry.id);
                }
                // count the batch BEFORE replying: stats() may be read
                // the instant the last response lands
                inner.batches.fetch_add(1, Ordering::Relaxed);
                entry.counters.batches.fetch_add(1, Ordering::Relaxed);
                slot.batches.fetch_add(1, Ordering::Relaxed);
                for (i, r) in qb.reqs.drain(..).enumerate() {
                    let row = &out[i * classes..(i + 1) * classes];
                    let waited = r.submitted.elapsed();
                    let lat = waited.as_secs_f64() * 1e6;
                    let pi = r.priority.index();
                    entry.counters.hist.lock().unwrap().record_us(waited.as_micros() as u64);
                    let ph = &entry.counters.prio_hist[pi];
                    ph.lock().unwrap().record_us(waited.as_micros() as u64);
                    entry.counters.served_by_prio[pi].fetch_add(1, Ordering::Relaxed);
                    entry.counters.served.fetch_add(1, Ordering::Relaxed);
                    // terminal reply: release the admission reservation
                    entry.counters.pending[pi].fetch_sub(1, Ordering::Relaxed);
                    inner.served.fetch_add(1, Ordering::Relaxed);
                    slot.served.fetch_add(1, Ordering::Relaxed);
                    inner.obs.event(wi + 1, r.id, EventKind::Served, wi as u32, b as u32);
                    let _ = r.reply.send(Ok(Response {
                        id: r.id,
                        model: entry.id.clone(),
                        priority: r.priority,
                        logits: row.to_vec(),
                        class: argmax(row),
                        latency_us: lat,
                        batch_size: b,
                    }));
                }
            }
            Err(e) => {
                slot.errors.fetch_add(1, Ordering::Relaxed);
                inner.obs.worker_errors.inc(wi);
                let slot_errs =
                    errs.entry(entry.id.clone()).or_insert((entry.generation, 0));
                if slot_errs.0 != entry.generation {
                    *slot_errs = (entry.generation, 0);
                }
                slot_errs.1 += 1;
                let model_errors = slot_errs.1;
                qb.attempts += 1;
                if qb.attempts < inner.max_attempts {
                    inner.obs.limited_error(&inner.obs.err_backend, wi, || {
                        format!(
                            "worker {wi} backend error on model {} (attempt {} of {}): {e:#}",
                            entry.id, qb.attempts, inner.max_attempts
                        )
                    });
                    for r in &qb.reqs {
                        let kind = EventKind::Requeue;
                        inner.obs.event(wi + 1, r.id, kind, wi as u32, b as u32);
                    }
                    inner.queue.push(qb);
                } else {
                    inner.obs.limited_error(&inner.obs.err_backend, wi, || {
                        format!(
                            "worker {wi} backend error on model {}, failing batch of {b} \
                             after {} attempts: {e:#}",
                            entry.id, inner.max_attempts
                        )
                    });
                    fail_batch(qb, wi + 1);
                }
                if model_errors >= MAX_WORKER_ERRORS {
                    inner.obs.limited_error(&inner.obs.err_quarantine, wi, || {
                        format!(
                            "worker {wi} quarantining its replica for model {} after \
                             {model_errors} consecutive errors",
                            entry.id
                        )
                    });
                    inner.obs.quarantines.inc(wi);
                    inner.obs.event(wi + 1, 0, EventKind::Quarantine, wi as u32, 0);
                    quarantined.insert(entry.id.clone(), entry.generation);
                    // drop the cached replica only if it is the one that
                    // failed (a stale one-shot error must not evict the
                    // current generation's healthy cache entry)
                    if backends.get(&entry.id).is_some_and(|(g, _)| *g == entry.generation) {
                        backends.remove(&entry.id);
                    }
                    errs.remove(&entry.id);
                }
            }
        }
    }
    // RetireGuard's Drop marks the slot retired and closes the queue
    // when this was the last worker — on panic unwinds too.
}

/// Answer feed requests whose session vanished with the typed
/// [`ServeError::UnknownSession`]. A terminal reply: releases each
/// admission reservation and traces [`EventKind::Failed`] on `shard`.
fn reply_unknown_session(
    entry: &ModelEntry,
    shard: usize,
    reqs: impl IntoIterator<Item = Request>,
) {
    for r in reqs {
        entry.counters.pending[r.priority.index()].fetch_sub(1, Ordering::Relaxed);
        entry.obs.event(shard, r.id, EventKind::Failed, 0, 0);
        let _ = r.reply.send(Err(ServeError::UnknownSession { model: entry.id.clone() }));
    }
}

/// One popped session-feed batch: check the session's state out of its
/// model's table, apply the frame through the shared [`Streamer`] with
/// this worker's [`StreamScratch`], reply with the running logits
/// (empty during warm-up), then keep the checkout while draining any
/// feeds that queued behind it — the checkout is what serializes one
/// session's frames in feed order across the whole pool — and finally
/// put the state back (or free the slot if a close raced the feed).
fn serve_stream_feed(
    inner: &RegistryInner,
    wi: usize,
    wslot: &WorkerSlot,
    mut qb: QueuedBatch,
    sid: SessionId,
    scratches: &mut HashMap<ModelId, (u64, StreamScratch)>,
    logits: &mut Vec<f32>,
) {
    let entry = Arc::clone(&qb.model);
    if entry.stream.is_none() {
        // unreachable by construction (feeds only exist for streaming
        // models); degrade to a typed failure rather than a panic
        fail_batch(qb, wi + 1);
        return;
    }
    let sm = stream_model(&entry);
    let cached = scratches
        .entry(entry.id.clone())
        .or_insert_with(|| (entry.generation, sm.streamer.scratch()));
    if cached.0 != entry.generation {
        *cached = (entry.generation, sm.streamer.scratch());
    }
    let scr = &mut cached.1;
    // checkout: the feed path set `busy` before enqueueing, so the
    // state must be resident; defensively degrade to a typed error
    let mut state = {
        let mut tab = sm.sessions.lock().unwrap();
        match tab.get_live(sid).and_then(|s| s.state.take()) {
            Some(st) => st,
            None => {
                drop(tab);
                reply_unknown_session(&entry, wi + 1, qb.reqs.drain(..));
                return;
            }
        }
    };
    let classes = sm.streamer.classes();
    let mut reqs: VecDeque<Request> = qb.reqs.drain(..).collect();
    inner.batches.fetch_add(1, Ordering::Relaxed);
    entry.counters.batches.fetch_add(1, Ordering::Relaxed);
    wslot.batches.fetch_add(1, Ordering::Relaxed);
    loop {
        for r in reqs.drain(..) {
            inner.obs.event(wi + 1, r.id, EventKind::Dispatch, wi as u32, 1);
            sm.streamer.feed(&mut state, &r.features, scr);
            logits.clear();
            logits.resize(classes, 0.0);
            let ready = sm.streamer.logits_into(&state, scr, logits);
            let waited = r.submitted.elapsed();
            let lat = waited.as_secs_f64() * 1e6;
            let pi = r.priority.index();
            entry.counters.hist.lock().unwrap().record_us(waited.as_micros() as u64);
            let ph = &entry.counters.prio_hist[pi];
            ph.lock().unwrap().record_us(waited.as_micros() as u64);
            entry.counters.served_by_prio[pi].fetch_add(1, Ordering::Relaxed);
            entry.counters.served.fetch_add(1, Ordering::Relaxed);
            // terminal reply: release the admission reservation
            entry.counters.pending[pi].fetch_sub(1, Ordering::Relaxed);
            inner.served.fetch_add(1, Ordering::Relaxed);
            wslot.served.fetch_add(1, Ordering::Relaxed);
            inner.obs.event(wi + 1, r.id, EventKind::Served, wi as u32, 1);
            let _ = r.reply.send(Ok(Response {
                id: r.id,
                model: entry.id.clone(),
                priority: r.priority,
                logits: if ready { logits.clone() } else { Vec::new() },
                class: if ready { argmax(logits) } else { 0 },
                latency_us: lat,
                batch_size: 1,
            }));
        }
        let mut tab = sm.sessions.lock().unwrap();
        let Some(slot) = tab.get_live(sid) else {
            // the slot vanished while checked out — unreachable while
            // the protocol holds `busy`; drop the state and move on
            return;
        };
        if !slot.backlog.is_empty() {
            // feeds arrived while we processed: drain them too under
            // the same checkout so they apply in feed order
            std::mem::swap(&mut reqs, &mut slot.backlog);
            continue;
        }
        if slot.pending_close {
            // a close raced the in-flight feed; the feed above already
            // got its served reply — free the slot now (exactly one
            // terminal outcome per feed)
            tab.release(sid.slot);
        } else {
            slot.state = Some(state);
            slot.busy = false;
            slot.last_fed = Instant::now();
        }
        return;
    }
}

/// Autoscaler cadence: how often an autoscaling model's batcher
/// re-evaluates queue pressure (caps the batcher's recv timeout).
const AUTOSCALE_TICK: Duration = Duration::from_millis(10);
/// Hysteresis: minimum gap between consecutive scale-*up* steps, so one
/// burst does not instantly claim the whole pool.
const SCALE_UP_COOLDOWN: Duration = Duration::from_millis(20);
/// Hysteresis: how long the model must sit at zero admitted depth
/// before the batcher returns a replica to the pool.
const SCALE_DOWN_IDLE: Duration = Duration::from_millis(250);

/// One model's batcher: assemble per-priority batches per the model's
/// policy and push them onto the shared queue. Exits when the model's
/// ingress disconnects (evict / shutdown), dispatching what it holds.
///
/// **Early expiry:** the loop wakes at the earliest pending request
/// deadline (not only at the forming-batch timers), so a doomed request
/// gets its typed [`ServeError::DeadlineExceeded`] reply promptly at
/// its deadline instead of waiting for its batch to dispatch.
///
/// **Replica pressure response:** when the model's
/// [`AdmissionPolicy::autoscale`] flag is set, the batcher doubles as
/// the model's autoscaler — every [`AUTOSCALE_TICK`] it reads the
/// admitted-but-unanswered depth and the expired counter, grows the
/// replica budget by one under pressure (depth above `2 * max_batch`,
/// or fresh deadline expiries) with [`SCALE_UP_COOLDOWN`] hysteresis,
/// and shrinks it after [`SCALE_DOWN_IDLE`] of sustained zero depth.
///
/// **Streaming idle sweep:** a streaming model's batcher also ticks
/// every [`batcher::SESSION_SWEEP_TICK`], evicting sessions idle past
/// the spec's `idle_timeout`. Busy slots (a feed in flight) are
/// skipped — activity by definition — and the feed path updates
/// `last_fed` under the same table mutex, so eviction and feed
/// linearize: an evicted session's next feed gets the typed
/// [`ServeError::UnknownSession`], never a hang or a double reply.
fn batcher_loop(rx: Receiver<Request>, inner: &RegistryInner, entry: &Arc<ModelEntry>) {
    let policy = entry.policy;
    let mut pending: [Vec<Request>; 2] = [Vec::new(), Vec::new()];
    let mut deadline: [Option<Instant>; 2] = [None, None];
    let n_workers = inner.slots.len();
    let mut scale_tick = Instant::now();
    let mut last_up: Option<Instant> = None;
    let mut idle_since: Option<Instant> = None;
    let mut last_expired = 0u64;
    let mut sweep_tick = Instant::now();
    loop {
        let now = Instant::now();
        if let Some(sm) = entry.stream.as_ref() {
            if now.saturating_duration_since(sweep_tick) >= batcher::SESSION_SWEEP_TICK {
                sweep_tick = now;
                sweep_idle_sessions(sm, now);
            }
        }
        if entry.admission.autoscale && now.saturating_duration_since(scale_tick) >= AUTOSCALE_TICK
        {
            scale_tick = now;
            let depth = entry.counters.pending[0].load(Ordering::Relaxed)
                + entry.counters.pending[1].load(Ordering::Relaxed);
            let expired = entry.counters.expired.load(Ordering::Relaxed);
            let budget = entry.replica_budget.load(Ordering::Relaxed);
            let pressured = depth > 2 * policy.max_batch || expired > last_expired;
            last_expired = expired;
            if pressured {
                idle_since = None;
                let cooled = match last_up {
                    None => true,
                    Some(t) => now.saturating_duration_since(t) >= SCALE_UP_COOLDOWN,
                };
                if budget < n_workers && cooled {
                    // Relaxed store; wake_all's lock round-trip is the
                    // publication edge to `pop` (see `replica_budget`)
                    entry.replica_budget.store(budget + 1, Ordering::Relaxed);
                    inner.queue.wake_all();
                    last_up = Some(now);
                }
            } else if depth == 0 {
                match idle_since {
                    None => idle_since = Some(now),
                    Some(t) if now.saturating_duration_since(t) >= SCALE_DOWN_IDLE => {
                        if budget > 1 {
                            entry.replica_budget.store(budget - 1, Ordering::Relaxed);
                            inner.queue.wake_all();
                        }
                        idle_since = Some(now);
                    }
                    Some(_) => {}
                }
            } else {
                idle_since = None;
            }
        }
        // early expiry: answer overdue forming-batch members right away
        for lane in pending.iter_mut() {
            let mut i = 0;
            while i < lane.len() {
                if lane[i].deadline.is_some_and(|d| now > d) {
                    expire(lane.remove(i), entry, 0);
                } else {
                    i += 1;
                }
            }
        }
        // fire any lane whose forming-batch timer elapsed
        for p in Priority::ALL {
            let pi = p.index();
            if deadline[pi].is_some_and(|d| now >= d) {
                dispatch(&mut pending[pi], p, inner, entry);
                deadline[pi] = None;
            }
        }
        // wake at the earlier of: a lane's forming-batch timer, or the
        // earliest pending request deadline (early expiry)
        let next_expiry = pending.iter().flatten().filter_map(|r| r.deadline).min();
        let mut timeout = deadline
            .iter()
            .flatten()
            .copied()
            .chain(next_expiry)
            .map(|d| d.saturating_duration_since(now))
            .min()
            .unwrap_or(Duration::from_secs(3600));
        if entry.admission.autoscale {
            // autoscaling models must keep ticking even when idle
            timeout = timeout.min(AUTOSCALE_TICK);
        }
        if entry.stream.is_some() {
            // streaming models must keep sweeping idle sessions
            timeout = timeout.min(batcher::SESSION_SWEEP_TICK);
        }
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                let p = req.priority;
                let pi = p.index();
                if pending[pi].is_empty() {
                    let wait = Duration::from_micros(policy.max_wait_us);
                    deadline[pi] = Some(Instant::now() + wait);
                }
                pending[pi].push(req);
                if pending[pi].len() >= policy.max_batch {
                    dispatch(&mut pending[pi], p, inner, entry);
                    deadline[pi] = None;
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // lane timers are handled at the top of the loop
            }
            Err(RecvTimeoutError::Disconnected) => {
                for p in Priority::ALL {
                    dispatch(&mut pending[p.index()], p, inner, entry);
                }
                return;
            }
        }
    }
}

/// Evict sessions idle past the model's `idle_timeout` (run from the
/// owning batcher's tick). Skips busy slots: an in-flight feed is
/// activity, and its worker refreshes `last_fed` at put-back under the
/// same mutex this sweep holds, so the two linearize.
fn sweep_idle_sessions(sm: &StreamModel, now: Instant) {
    let mut tab = sm.sessions.lock().unwrap();
    for i in 0..tab.slots.len() {
        let s = &tab.slots[i];
        if s.occupied
            && !s.busy
            && !s.pending_close
            && now.saturating_duration_since(s.last_fed) >= sm.idle_timeout
        {
            tab.release(i);
        }
    }
}

/// Close a forming batch: expire overdue members with a typed reply,
/// push the rest onto the shared queue's lane for `prio`.
fn dispatch(
    pending: &mut Vec<Request>,
    prio: Priority,
    inner: &RegistryInner,
    entry: &Arc<ModelEntry>,
) {
    if pending.is_empty() {
        return;
    }
    let now = Instant::now();
    let mut live = Vec::with_capacity(pending.len());
    for r in pending.drain(..) {
        if r.deadline.is_some_and(|d| now > d) {
            expire(r, entry, 0);
        } else {
            live.push(r);
        }
    }
    if live.is_empty() {
        return;
    }
    for r in &live {
        entry.obs.event(0, r.id, EventKind::Enqueue, prio.index() as u32, live.len() as u32);
    }
    inner.queue.push(QueuedBatch {
        model: Arc::clone(entry),
        priority: prio,
        reqs: live,
        attempts: 0,
        bounces: 0,
        session: None,
    });
}
