//! Deterministic fault injection for serving tests and drills.
//!
//! [`ChaosBackend`] decorates any [`Backend`] and injects three fault
//! classes into `infer_into`, all driven by one seeded [`Rng`] so a
//! failing run is replayable from its seed alone:
//!
//! - **transient errors** — the call returns an `Err`, which the worker
//!   loop turns into a bounded re-queue and, past the attempt budget, a
//!   typed [`ServeError::BackendFailed`](crate::serve::ServeError)
//!   reply;
//! - **stalls** — the call sleeps for a configured duration before
//!   delegating, modelling a slow or wedged replica (this is what
//!   drives deadline expiry and feasibility shedding under test);
//! - **worker panics** — at most one worker (by index) panics on its
//!   *first* chaos call, exercising the worker-death containment path:
//!   the in-flight batch still gets typed failure replies (admission
//!   reservations released), the `RetireGuard` retires the slot, and
//!   the survivors are woken to absorb its budgeted work.
//!
//! Determinism: each replica derives its stream from
//! `seed ^ worker-index`, so a given `(seed, worker)` pair always draws
//! the same fault sequence regardless of scheduling. The decorator
//! holds no shared state — per the repo's raw-sync lint (which covers
//! this file), it names no `std::sync` lock or condvar.
//!
//! ```no_run
//! use std::time::Duration;
//! use fqconv::serve::chaos::{chaos_factory, ChaosConfig};
//! # let inner: fqconv::serve::BackendFactory = todo!();
//! let cfg = ChaosConfig::new(7)
//!     .with_failures(50)                               // 5% transient errors
//!     .with_stalls(100, Duration::from_millis(2))      // 10% slow calls
//!     .with_panic_on(1);                               // worker 1 dies
//! let factory = chaos_factory(inner, cfg);
//! ```

use std::time::Duration;

use crate::serve::{Backend, BackendFactory};
use crate::util::Rng;
use anyhow::Result;
use std::sync::Arc;

/// Fault mix for a [`ChaosBackend`]; see the module doc.
#[derive(Clone, Copy, Debug)]
pub struct ChaosConfig {
    /// base seed; each replica draws from `seed ^ worker-index`
    pub seed: u64,
    /// per-mille of calls that return a transient error
    pub fail_per_mille: u32,
    /// per-mille of calls that stall for [`ChaosConfig::stall`]
    pub stall_per_mille: u32,
    /// injected delay for a stalled call
    pub stall: Duration,
    /// this worker's replica panics on its first chaos call
    pub panic_on_worker: Option<usize>,
}

impl ChaosConfig {
    /// No faults; compose with the `with_*` builders.
    pub fn new(seed: u64) -> Self {
        ChaosConfig {
            seed,
            fail_per_mille: 0,
            stall_per_mille: 0,
            stall: Duration::ZERO,
            panic_on_worker: None,
        }
    }

    /// Inject transient `Err` returns on `per_mille`/1000 of calls.
    pub fn with_failures(mut self, per_mille: u32) -> Self {
        self.fail_per_mille = per_mille.min(1000);
        self
    }

    /// Stall `per_mille`/1000 of calls for `stall` before delegating.
    pub fn with_stalls(mut self, per_mille: u32, stall: Duration) -> Self {
        self.stall_per_mille = per_mille.min(1000);
        self.stall = stall;
        self
    }

    /// Panic worker `worker`'s replica on its first chaos call — at
    /// most one worker dies, deterministically.
    pub fn with_panic_on(mut self, worker: usize) -> Self {
        self.panic_on_worker = Some(worker);
        self
    }
}

/// A [`Backend`] decorator injecting seeded faults; see the module doc.
pub struct ChaosBackend {
    inner: Box<dyn Backend>,
    rng: Rng,
    cfg: ChaosConfig,
    worker: usize,
    calls: u64,
}

impl ChaosBackend {
    /// Decorate `inner` as worker `worker`'s replica under `cfg`.
    pub fn new(inner: Box<dyn Backend>, worker: usize, cfg: ChaosConfig) -> Self {
        ChaosBackend { inner, rng: Rng::new(cfg.seed ^ worker as u64), cfg, worker, calls: 0 }
    }
}

impl Backend for ChaosBackend {
    fn infer_into(&mut self, x: &[f32], batch: usize, out: &mut [f32]) -> Result<()> {
        self.calls += 1;
        if self.cfg.panic_on_worker == Some(self.worker) && self.calls == 1 {
            panic!("chaos: injected worker panic (worker {})", self.worker);
        }
        let draw = self.rng.below(1000) as u32;
        if draw < self.cfg.fail_per_mille {
            anyhow::bail!("chaos: injected transient backend failure");
        }
        if draw < self.cfg.fail_per_mille + self.cfg.stall_per_mille {
            std::thread::sleep(self.cfg.stall);
        }
        self.inner.infer_into(x, batch, out)
    }

    fn sample_shape(&self) -> &[usize] {
        self.inner.sample_shape()
    }

    fn out_dim(&self) -> usize {
        self.inner.out_dim()
    }
}

/// Wrap a [`BackendFactory`] so every replica it builds is decorated
/// with a [`ChaosBackend`] seeded from `cfg.seed` and the worker index.
pub fn chaos_factory(inner: BackendFactory, cfg: ChaosConfig) -> BackendFactory {
    Arc::new(move |wi| Box::new(ChaosBackend::new(inner(wi), wi, cfg)) as Box<dyn Backend>)
}
