//! Metrics: classification accuracy/confusion + latency histograms.

use crate::tensor::TensorF;

/// Top-1 accuracy of logits (B, C) against labels.
pub fn accuracy(logits: &TensorF, labels: &[i32]) -> f64 {
    let preds = logits.argmax_rows();
    let correct =
        preds.iter().zip(labels).filter(|(&p, &y)| p as i32 == y).count();
    correct as f64 / labels.len().max(1) as f64
}

/// Top-k accuracy (paper reports top-5 for the 100-class experiments).
pub fn topk_accuracy(logits: &TensorF, labels: &[i32], k: usize) -> f64 {
    let b = logits.shape()[0];
    let mut correct = 0usize;
    for r in 0..b {
        let row = logits.row(r);
        let mut idx: Vec<usize> = (0..row.len()).collect();
        idx.sort_by(|&a, &bb| row[bb].total_cmp(&row[a]));
        if idx.iter().take(k).any(|&i| i as i32 == labels[r]) {
            correct += 1;
        }
    }
    correct as f64 / b.max(1) as f64
}

/// Running confusion matrix.
#[derive(Clone, Debug)]
pub struct Confusion {
    pub n: usize,
    counts: Vec<u64>,
}

impl Confusion {
    pub fn new(n: usize) -> Self {
        Confusion { n, counts: vec![0; n * n] }
    }

    pub fn add(&mut self, truth: i32, pred: usize) {
        if (truth as usize) < self.n && pred < self.n {
            self.counts[truth as usize * self.n + pred] += 1;
        }
    }

    pub fn add_batch(&mut self, logits: &TensorF, labels: &[i32]) {
        for (p, &y) in logits.argmax_rows().into_iter().zip(labels) {
            self.add(y, p);
        }
    }

    pub fn count(&self, truth: usize, pred: usize) -> u64 {
        self.counts[truth * self.n + pred]
    }

    pub fn accuracy(&self) -> f64 {
        let total: u64 = self.counts.iter().sum();
        let diag: u64 = (0..self.n).map(|i| self.count(i, i)).sum();
        diag as f64 / total.max(1) as f64
    }

    /// Per-class recall.
    pub fn recall(&self, class: usize) -> f64 {
        let row: u64 = (0..self.n).map(|p| self.count(class, p)).sum();
        self.count(class, class) as f64 / row.max(1) as f64
    }
}

/// The shared fixed-bucket integer latency histogram — one
/// implementation for the whole tree, owned by [`crate::obs::hist`]
/// (the serving layer's per-model stats and the metrics registry's
/// sharded histograms both merge into it).
pub use crate::obs::hist::Histogram;

/// Back-compat alias: the name this module exported before the
/// histogram implementations were unified in `obs`.
pub type LatencyHist = Histogram;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts() {
        let logits = TensorF::from_vec(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 1.0, 0.0]);
        assert!((accuracy(&logits, &[0, 1, 1]) - 2.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn topk_contains_label() {
        let logits = TensorF::from_vec(&[1, 4], vec![0.1, 0.5, 0.4, 0.0]);
        assert_eq!(topk_accuracy(&logits, &[2], 1), 0.0);
        assert_eq!(topk_accuracy(&logits, &[2], 2), 1.0);
    }

    #[test]
    fn confusion_diag() {
        let mut c = Confusion::new(3);
        c.add(0, 0);
        c.add(1, 2);
        c.add(1, 1);
        assert_eq!(c.count(1, 2), 1);
        assert!((c.accuracy() - 2.0 / 3.0).abs() < 1e-9);
        assert!((c.recall(1) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn latency_percentiles() {
        let mut h = LatencyHist::new();
        for i in 1..=100u64 {
            h.record_us(i);
        }
        // percentiles carry the shared histogram's bucket tolerance
        // (~12.5% relative); the mean is exact (sum tracked outside
        // the buckets)
        assert!((h.percentile(50.0) - 50.0).abs() <= 50.0 * 0.15);
        assert!((h.percentile(99.0) - 99.0).abs() <= 99.0 * 0.15);
        assert!((h.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_latency_hist_is_defined() {
        let h = LatencyHist::new();
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert!(h.summary().starts_with("n=0"));
    }
}
