//! Tiny property-based testing helper (no proptest crate offline).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` random inputs
//! from `gen`; on failure it performs a bounded re-sampling "shrink-lite"
//! pass (retry with fresh, smaller inputs from the generator's low end)
//! and panics with the seed so the case is replayable.

use super::rng::Rng;

pub struct Gen<'a> {
    pub rng: &'a mut Rng,
}

impl<'a> Gen<'a> {
    /// Size hint in [0,1]: early cases are small, later cases larger.
    pub fn sized_usize(&mut self, size: f64, max: usize) -> usize {
        let cap = ((max as f64) * size).ceil().max(1.0) as usize;
        1 + self.rng.below(cap)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo, hi)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.range(lo, hi)).collect()
    }

    pub fn vec_gaussian(&mut self, len: usize, std: f32) -> Vec<f32> {
        let mut v = vec![0.0; len];
        self.rng.fill_gaussian(&mut v, std);
        v
    }

    pub fn choice<'b, T>(&mut self, opts: &'b [T]) -> &'b T {
        &opts[self.rng.below(opts.len())]
    }
}

/// Run a property over `cases` random inputs. `make` builds an input from
/// (Gen, size); `prop` returns Err(description) on violation.
pub fn check<T: std::fmt::Debug, M, P>(name: &str, cases: usize, mut make: M, mut prop: P)
where
    M: FnMut(&mut Gen, f64) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let seed = 0xF0CC_u64 ^ name.bytes().fold(0u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64));
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let size = (case + 1) as f64 / cases as f64;
        let input = make(&mut Gen { rng: &mut rng }, size);
        if let Err(why) = prop(&input) {
            // shrink-lite: retry small inputs to find a minimal-ish witness
            let mut witness = format!("{input:?}");
            let mut why_min = why.clone();
            let mut shrink_rng = Rng::new(seed ^ 0xDEAD);
            for _ in 0..50 {
                let small = make(&mut Gen { rng: &mut shrink_rng }, 0.05);
                if let Err(w2) = prop(&small) {
                    witness = format!("{small:?}");
                    why_min = w2;
                    break;
                }
            }
            panic!(
                "property {name:?} failed (seed={seed:#x}, case {case}): {why_min}\n  witness: {witness}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 50, |g, s| {
            let a = g.f32_in(-10.0, 10.0);
            let b = g.f32_in(-10.0, 10.0);
            let _ = s;
            (a, b)
        }, |&(a, b)| {
            if (a + b - (b + a)).abs() < 1e-6 {
                Ok(())
            } else {
                Err("not commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_panics() {
        check("always-fails", 5, |g, _| g.f32_in(0.0, 1.0), |_| Err("nope".into()));
    }
}
