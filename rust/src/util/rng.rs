//! Deterministic PRNG substrate: SplitMix64 core + Gaussian sampling.
//!
//! Every stochastic component in the crate (data generators, augmentation,
//! analog noise, property tests) takes an explicit [`Rng`] so experiments
//! are reproducible from a single seed recorded in EXPERIMENTS.md.

/// SplitMix64 — tiny, fast, passes BigCrush when used as a stream.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second Box-Muller sample
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Derive an independent stream (used per-layer / per-worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    pub fn chance(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn gaussian_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian() as f32
    }

    /// Fill a slice with N(0, std).
    pub fn fill_gaussian(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.gaussian_f32(0.0, std);
        }
    }

    /// Fisher-Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            idx.swap(i, self.below(i + 1));
        }
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(3);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let g = r.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(1);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(5);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
