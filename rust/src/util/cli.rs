//! Tiny CLI argument parser substrate (no clap offline).
//!
//! Supports `command [positional...] --flag value --switch` with typed
//! accessors and an auto-generated usage line on errors.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from std::env::args() (skipping argv[0]).
    pub fn parse() -> Self {
        Self::from_iter(std::env::args().skip(1))
    }

    pub fn from_iter<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        if let Some(cmd) = it.peek() {
            if !cmd.starts_with('-') {
                args.command = it.next().unwrap();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                // --flag=value or --flag value or --switch
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.flags.insert(name.to_string(), v);
                } else {
                    args.switches.push(name.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::from_iter(s.split_whitespace().map(|w| w.to_string()))
    }

    #[test]
    fn command_and_positional() {
        let a = parse("exp table4 --budget quick --verbose");
        assert_eq!(a.command, "exp");
        assert_eq!(a.positional, vec!["table4"]);
        assert_eq!(a.str_or("budget", "full"), "quick");
        assert!(a.has("verbose"));
    }

    #[test]
    fn eq_flags_and_numbers() {
        let a = parse("train --steps=250 --lr 0.01");
        assert_eq!(a.usize_or("steps", 0), 250);
        assert!((a.f64_or("lr", 0.0) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn missing_defaults() {
        let a = parse("serve");
        assert_eq!(a.usize_or("requests", 64), 64);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn switch_before_flag_value_disambiguation() {
        let a = parse("x --verbose --model kws");
        assert!(a.has("verbose") || a.flag("verbose").is_some());
        assert_eq!(a.str_or("model", "?"), "kws");
    }
}
