//! Fixed-size thread pool substrate (no rayon/tokio in the offline image).
//!
//! Used by the serving worker pool and the data-parallel parts of the
//! integer inference engine. Jobs are `FnOnce` closures; `scope_chunks`
//! provides the fork-join pattern the GEMM tiler needs.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("fqconv-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.as_ref().unwrap().send(Box::new(f)).expect("pool closed");
    }

    /// Run `f(chunk_index)` for each of `n` chunks and wait for all.
    pub fn scope_chunks<F>(&self, n: usize, f: F)
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        if n == 0 {
            return;
        }
        let f = Arc::new(f);
        let remaining = Arc::new((Mutex::new(n), std::sync::Condvar::new()));
        for i in 0..n {
            let f = Arc::clone(&f);
            let remaining = Arc::clone(&remaining);
            self.execute(move || {
                f(i);
                let (lock, cv) = &*remaining;
                let mut left = lock.lock().unwrap();
                *left -= 1;
                if *left == 0 {
                    cv.notify_all();
                }
            });
        }
        let (lock, cv) = &*remaining;
        let mut left = lock.lock().unwrap();
        while *left > 0 {
            left = cv.wait(left).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Default worker count: physical parallelism minus one coordinator thread.
pub fn default_workers() -> usize {
    thread::available_parallelism().map(|n| n.get().saturating_sub(1).max(1)).unwrap_or(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_chunks_waits() {
        let pool = ThreadPool::new(3);
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        pool.scope_chunks(17, move |_| {
            h.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 17);
    }

    #[test]
    fn zero_chunks_ok() {
        let pool = ThreadPool::new(2);
        pool.scope_chunks(0, |_| panic!("should not run"));
    }
}
