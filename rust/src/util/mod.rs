//! Infrastructure substrates built from scratch (the offline image ships
//! no rand/serde/rayon/criterion — see DESIGN.md §2).

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;
