//! Minimal JSON parser/writer substrate (no serde in the offline image).
//!
//! Parses the artifact manifest and serving requests; writes experiment
//! result records. Supports the full JSON grammar except `\u` surrogate
//! pairs beyond the BMP (not needed for our ASCII manifests).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object member or panic with a readable message (manifest is trusted).
    pub fn req(&self, key: &str) -> &Json {
        self.get(key).unwrap_or_else(|| panic!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usizes(&self) -> Vec<usize> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default()
    }

    // -- writer ---------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for result records.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("short unicode escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad unicode escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad unicode escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy one utf-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(j.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.req("a").as_arr().unwrap()[2].req("b").as_str(), Some("x"));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"model":"kws","shape":[3,39,80],"acc":0.943,"ok":true,"note":null}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
