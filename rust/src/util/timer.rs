//! Wall-clock timing helpers shared by the trainer, benches and serving
//! metrics.

use std::time::Instant;

pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e6
    }

    pub fn restart(&mut self) -> f64 {
        let e = self.elapsed_s();
        self.start = Instant::now();
        e
    }
}

/// Human-readable duration for logs: "1.23s", "45.6ms", "789us".
pub fn fmt_duration(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.2}s")
    } else if seconds >= 1e-3 {
        format!("{:.1}ms", seconds * 1e3)
    } else {
        format!("{:.0}us", seconds * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(2.5), "2.50s");
        assert_eq!(fmt_duration(0.0456), "45.6ms");
        assert_eq!(fmt_duration(0.000789), "789us");
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
