//! Data-parallel execution substrate for the integer inference engine
//! and the serving layer (no rayon in the offline image).
//!
//! Fork-join now runs over a **persistent worker pool** ([`Pool`]): a set
//! of parked threads woken by a condvar per fork, instead of spawning
//! scoped threads per call. At small batch sizes (the serving hot path)
//! the per-call spawn cost dominated the actual kernel work; the pool
//! amortizes it to a notify/park round-trip. The previous scoped-thread
//! implementation is kept as [`par_rows_mut_scoped`] so benches can
//! measure the pool against it.
//!
//! **Determinism contract:** every helper in this module partitions work
//! into contiguous, disjoint ranges and each output element is computed
//! by exactly one worker with exactly the same instruction sequence the
//! sequential path uses. Results are therefore bit-identical for every
//! thread count, including 1 — pinned by rust/tests/parallel.rs.
//!
//! Thread-count policy: callers pass an explicit `threads` budget;
//! [`default_threads`] resolves the process-wide default
//! (`FQCONV_THREADS` env var, else `available_parallelism`), and
//! [`clamp_threads`] shrinks a budget so small problems never pay
//! fork-join overhead. The global pool is sized once from
//! [`default_threads`] on first use; budgets above its width are clamped
//! to it (outputs are bit-identical either way).
//!
//! Re-entrancy: a fork issued from inside a pool worker (or from the
//! thread currently driving a fork) degrades to the sequential path on
//! the calling thread — nested parallelism would deadlock a single
//! shared pool, and the determinism contract makes the sequential
//! fallback indistinguishable in output.

use crate::check::sync::{spawn_named, Condvar, JoinHandle, Mutex};
use std::cell::Cell;
use std::ops::Range;
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, OnceLock};

/// Process-wide default worker count: `FQCONV_THREADS` if set (>= 1),
/// else the machine's available parallelism.
pub fn default_threads() -> usize {
    match std::env::var("FQCONV_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Split `0..n` into at most `parts` contiguous, balanced, disjoint
/// ranges (earlier ranges get the remainder). Deterministic in (n, parts).
pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.max(1).min(n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Shrink a thread budget so each worker keeps at least
/// `min_rows_per_thread` rows — below that, fork-join overhead dominates.
pub fn clamp_threads(threads: usize, rows: usize, min_rows_per_thread: usize) -> usize {
    threads.max(1).min((rows / min_rows_per_thread.max(1)).max(1))
}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

/// Type-erased fork body: `f(part_index)` runs one contiguous part.
type JobFn = dyn Fn(usize) + Sync;

/// One published fork. The raw pointer is only dereferenced by workers
/// whose part index participates in the fork, strictly between job
/// publication and their `remaining` decrement — and [`Pool::run`] does
/// not return (or unwind) until every participant has decremented, so
/// the pointee outlives every dereference.
struct Job {
    f: *const JobFn,
    parts: usize,
    epoch: u64,
}

// SAFETY: the pointer is only shared under the lifetime discipline
// documented on [`Job`]; the pointee is required to be `Sync`.
unsafe impl Send for Job {}

struct PoolState {
    /// bumped once per fork; workers track the last epoch they observed
    epoch: u64,
    job: Option<Job>,
    /// worker parts (parts - 1; the caller runs part 0) not yet finished
    remaining: usize,
    /// a worker's part panicked during the current fork
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// workers park here waiting for a new epoch (or shutdown)
    work_cv: Condvar,
    /// the forking thread parks here waiting for `remaining == 0`
    done_cv: Condvar,
}

thread_local! {
    /// True on pool worker threads and on a thread currently driving a
    /// fork — a nested fork from either must degrade to sequential.
    static IN_POOL_FORK: Cell<bool> = const { Cell::new(false) };
}

/// Persistent fork-join worker pool: `workers` parked threads plus the
/// calling thread, woken per [`Pool::run`] and parked again after.
pub struct Pool {
    shared: Arc<PoolShared>,
    /// serializes concurrent forks from independent threads — the pool
    /// has a single job slot by design (forks are short; queueing them
    /// would only reorder identical work)
    fork_lock: Mutex<()>,
    workers: usize,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn a pool with `workers` parked worker threads. Total fork
    /// concurrency is `workers + 1`: the forking thread runs part 0.
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                epoch: 0,
                job: None,
                remaining: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let handles = (0..workers)
            .map(|wi| {
                let shared = Arc::clone(&shared);
                spawn_named(&format!("fqconv-pool-{wi}"), move || worker_loop(wi, &shared))
            })
            .collect();
        Pool { shared, fork_lock: Mutex::new(()), workers, handles }
    }

    /// The process-wide pool, sized once from [`default_threads`] on
    /// first use (workers = default_threads - 1; the caller is the +1).
    pub fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(|| Pool::new(default_threads().saturating_sub(1)))
    }

    /// Maximum concurrency of a fork (workers + the calling thread).
    pub fn width(&self) -> usize {
        self.workers + 1
    }

    /// Fork-join: run `f(0)..f(parts - 1)` concurrently (part 0 on the
    /// calling thread) and return once all parts finished. `parts` must
    /// not exceed [`Pool::width`]. Panics in any part propagate to the
    /// caller after every part has completed — the pool itself survives.
    pub fn run(&self, parts: usize, f: &JobFn) {
        assert!(parts <= self.width(), "fork of {parts} parts on a width-{} pool", self.width());
        if parts <= 1 {
            f(0);
            return;
        }
        if IN_POOL_FORK.with(|g| g.get()) {
            // nested fork: run sequentially (bit-identical by contract)
            for i in 0..parts {
                f(i);
            }
            return;
        }
        let _fork = self.fork_lock.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            // SAFETY: widen the borrow to the 'static trait-object type
            // stored in Job; `run` joins all participants before
            // returning or unwinding, so no use outlives `f`.
            let f_ptr: *const JobFn = unsafe {
                std::mem::transmute::<&JobFn, *const JobFn>(f)
            };
            st.job = Some(Job { f: f_ptr, parts, epoch: st.epoch });
            st.remaining = parts - 1;
            st.panicked = false;
        }
        self.shared.work_cv.notify_all();

        // Join-on-drop guard: even if the caller's own part panics, we
        // must not unwind past the workers still reading our stack.
        struct Join<'a>(&'a PoolShared);
        impl Drop for Join<'_> {
            fn drop(&mut self) {
                let mut st = self.0.state.lock().unwrap();
                while st.remaining > 0 {
                    st = self.0.done_cv.wait(st).unwrap();
                }
                st.job = None;
            }
        }
        let join = Join(&self.shared);
        IN_POOL_FORK.with(|g| g.set(true));
        let caller_result = panic::catch_unwind(AssertUnwindSafe(|| f(0)));
        IN_POOL_FORK.with(|g| g.set(false));
        drop(join); // waits for all worker parts
        let worker_panicked = self.shared.state.lock().unwrap().panicked;
        if let Err(payload) = caller_result {
            panic::resume_unwind(payload);
        }
        if worker_panicked {
            panic!("pool worker panicked during fork");
        }
    }

    /// Fork-join over the rows of a row-major `(rows, row_len)` output
    /// buffer — pool-backed equivalent of [`par_rows_mut_scoped`].
    pub fn par_rows_mut<T, F>(
        &self,
        out: &mut [T],
        rows: usize,
        row_len: usize,
        threads: usize,
        f: F,
    ) where
        T: Send,
        F: Fn(Range<usize>, &mut [T]) + Sync,
    {
        assert_eq!(out.len(), rows * row_len, "output buffer / row geometry mismatch");
        let parts = partition(rows, threads.min(self.width()));
        if parts.len() <= 1 {
            f(0..rows, out);
            return;
        }
        let windows = split_windows(out, &parts, row_len);
        let windows = &windows;
        let f = &f;
        let task = move |i: usize| {
            let (range, w) = &windows[i];
            // SAFETY: split_windows produced disjoint sub-slices of `out`
            // and each part index is run exactly once per fork.
            let slice = unsafe { std::slice::from_raw_parts_mut(w.0, w.1) };
            f(range.clone(), slice);
        };
        self.run(parts.len(), &task);
    }

    /// Fork-join over two parallel row-major buffers sharing one row
    /// partition: `f(range, a_window, b_window)` sees the same rows of
    /// both. Lets a kernel fuse a second per-row pass (e.g. requantize
    /// accumulators into output codes) without a second fork.
    #[allow(clippy::too_many_arguments)]
    pub fn par_rows_pair_mut<A, B, F>(
        &self,
        a: &mut [A],
        b: &mut [B],
        rows: usize,
        a_row_len: usize,
        b_row_len: usize,
        threads: usize,
        f: F,
    ) where
        A: Send,
        B: Send,
        F: Fn(Range<usize>, &mut [A], &mut [B]) + Sync,
    {
        assert_eq!(a.len(), rows * a_row_len, "buffer A / row geometry mismatch");
        assert_eq!(b.len(), rows * b_row_len, "buffer B / row geometry mismatch");
        let parts = partition(rows, threads.min(self.width()));
        if parts.len() <= 1 {
            f(0..rows, a, b);
            return;
        }
        let wa = split_windows(a, &parts, a_row_len);
        let wb = split_windows(b, &parts, b_row_len);
        let (wa, wb) = (&wa, &wb);
        let f = &f;
        let task = move |i: usize| {
            let (range, pa) = &wa[i];
            let (_, pb) = &wb[i];
            // SAFETY: split_windows produced disjoint windows of `a` and
            // each part index is run exactly once per fork.
            let sa = unsafe { std::slice::from_raw_parts_mut(pa.0, pa.1) };
            // SAFETY: same as above, for the disjoint windows of `b`.
            let sb = unsafe { std::slice::from_raw_parts_mut(pb.0, pb.1) };
            f(range.clone(), sa, sb);
        };
        self.run(parts.len(), &task);
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw (ptr, len) for a disjoint `&mut` window handed across the fork.
struct WindowPtr<T>(*mut T, usize);
// SAFETY: each window is a disjoint sub-slice of one `&mut` buffer and
// is accessed by exactly one part of the fork.
unsafe impl<T: Send> Send for WindowPtr<T> {}
// SAFETY: a fork only hands each window to the single part that owns
// it, so shared references to the wrapper never alias a mutation.
unsafe impl<T: Send> Sync for WindowPtr<T> {}

/// Split a row-major buffer into per-part windows matching `parts`.
fn split_windows<T>(
    buf: &mut [T],
    parts: &[Range<usize>],
    row_len: usize,
) -> Vec<(Range<usize>, WindowPtr<T>)> {
    let mut out = Vec::with_capacity(parts.len());
    let mut rest = buf;
    for r in parts {
        let take = (r.end - r.start) * row_len;
        let (w, tail) = std::mem::take(&mut rest).split_at_mut(take);
        rest = tail;
        out.push((r.clone(), WindowPtr(w.as_mut_ptr(), w.len())));
    }
    out
}

fn worker_loop(wi: usize, shared: &PoolShared) {
    IN_POOL_FORK.with(|g| g.set(true));
    let mut seen_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                let fresh = match &st.job {
                    Some(j) if j.epoch != seen_epoch => {
                        Some(Job { f: j.f, parts: j.parts, epoch: j.epoch })
                    }
                    _ => None,
                };
                if let Some(j) = fresh {
                    seen_epoch = j.epoch;
                    break j;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        let part = wi + 1;
        if part >= job.parts {
            // not a participant of this fork: never dereference the job
            continue;
        }
        // SAFETY: participants dereference only between publication and
        // their decrement below; Pool::run joins on that decrement.
        let f = unsafe { &*job.f };
        let ok = panic::catch_unwind(AssertUnwindSafe(|| f(part))).is_ok();
        let mut st = shared.state.lock().unwrap();
        if !ok {
            st.panicked = true;
        }
        st.remaining -= 1;
        if st.remaining == 0 {
            shared.done_cv.notify_all();
        }
    }
}

// ---------------------------------------------------------------------------
// Module-level fork-join entry points (global pool)
// ---------------------------------------------------------------------------

/// Fork-join over the rows of a row-major `(rows, row_len)` output
/// buffer: `out` is split into contiguous per-worker windows and
/// `f(range, window)` runs once per worker with `window` covering exactly
/// `range`'s rows. With one part (or one row) this degrades to a plain
/// call on the current thread. Backed by the persistent [`Pool::global`]
/// — no thread spawn per call.
pub fn par_rows_mut<T, F>(out: &mut [T], rows: usize, row_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    Pool::global().par_rows_mut(out, rows, row_len, threads, f);
}

/// [`par_rows_mut`] over two parallel buffers sharing one row partition
/// (see [`Pool::par_rows_pair_mut`]).
#[allow(clippy::too_many_arguments)]
pub fn par_rows_pair_mut<A, B, F>(
    a: &mut [A],
    b: &mut [B],
    rows: usize,
    a_row_len: usize,
    b_row_len: usize,
    threads: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(Range<usize>, &mut [A], &mut [B]) + Sync,
{
    Pool::global().par_rows_pair_mut(a, b, rows, a_row_len, b_row_len, threads, f);
}

/// The pre-pool scoped-thread implementation of [`par_rows_mut`], kept
/// as the baseline the persistent pool is benchmarked against
/// (rust/benches/perf_infer.rs) — it pays a thread spawn per window per
/// call. Output is bit-identical to the pool path.
pub fn par_rows_mut_scoped<T, F>(out: &mut [T], rows: usize, row_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    assert_eq!(out.len(), rows * row_len, "output buffer / row geometry mismatch");
    let parts = partition(rows, threads);
    if parts.len() <= 1 {
        f(0..rows, out);
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = out;
        let n_parts = parts.len();
        let mut iter = parts.into_iter();
        for r in iter.by_ref().take(n_parts - 1) {
            let tail = std::mem::take(&mut rest);
            let (window, tail) = tail.split_at_mut((r.end - r.start) * row_len);
            rest = tail;
            s.spawn(move || f(r, window));
        }
        // the calling thread takes the final window instead of idling
        // at the scope barrier: one fewer spawn per fork-join
        let last = iter.next().expect("partition returned >= 2 parts");
        f(last, rest);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_disjointly() {
        for (n, parts) in [(0usize, 3usize), (1, 4), (7, 3), (64, 8), (10, 1), (5, 9)] {
            let ranges = partition(n, parts);
            assert!(ranges.len() <= parts.max(1));
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, n);
            // balanced: lengths differ by at most 1
            let lens: Vec<usize> = ranges.iter().map(|r| r.end - r.start).collect();
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced partition {lens:?}");
        }
    }

    #[test]
    fn clamp_keeps_rows_per_thread() {
        assert_eq!(clamp_threads(8, 78, 16), 4);
        assert_eq!(clamp_threads(8, 10, 16), 1);
        assert_eq!(clamp_threads(0, 100, 16), 1);
        assert_eq!(clamp_threads(2, 1000, 16), 2);
    }

    fn fill_rows(out: &mut [u32], rows: usize, row_len: usize, threads: usize, scoped: bool) {
        let f = |range: Range<usize>, window: &mut [u32]| {
            for (i, row) in range.clone().zip(window.chunks_mut(row_len)) {
                for (j, v) in row.iter_mut().enumerate() {
                    *v += (i * row_len + j) as u32 + 1;
                }
            }
        };
        if scoped {
            par_rows_mut_scoped(out, rows, row_len, threads, f);
        } else {
            par_rows_mut(out, rows, row_len, threads, f);
        }
    }

    #[test]
    fn par_rows_writes_every_row_once() {
        let (rows, row_len) = (37, 5);
        let want: Vec<u32> = (0..rows * row_len).map(|i| i as u32 + 1).collect();
        for threads in [1usize, 2, 3, 8, 64] {
            for scoped in [false, true] {
                let mut out = vec![0u32; rows * row_len];
                fill_rows(&mut out, rows, row_len, threads, scoped);
                assert_eq!(out, want, "threads={threads} scoped={scoped}");
            }
        }
    }

    #[test]
    fn zero_rows_is_a_noop() {
        let mut out: Vec<u8> = Vec::new();
        par_rows_mut(&mut out, 0, 4, 4, |_, _| {});
        par_rows_mut_scoped(&mut out, 0, 4, 4, |_, _| {});
    }

    #[test]
    fn pool_reused_across_many_forks() {
        let pool = Pool::new(3);
        for round in 0..50 {
            let (rows, row_len) = (13usize, 3usize);
            let mut out = vec![0u64; rows * row_len];
            pool.par_rows_mut(&mut out, rows, row_len, 4, |range, window| {
                for (i, row) in range.clone().zip(window.chunks_mut(row_len)) {
                    for v in row.iter_mut() {
                        *v = (i as u64 + 1) * (round + 1);
                    }
                }
            });
            for i in 0..rows {
                assert!(out[i * row_len..(i + 1) * row_len]
                    .iter()
                    .all(|&v| v == (i as u64 + 1) * (round + 1)));
            }
        }
    }

    #[test]
    fn pair_windows_share_row_partition() {
        let (rows, la, lb) = (9usize, 4usize, 2usize);
        let mut a = vec![0i32; rows * la];
        let mut b = vec![0i8; rows * lb];
        par_rows_pair_mut(&mut a, &mut b, rows, la, lb, 3, |range, wa, wb| {
            for (i, row) in range.clone().zip(wa.chunks_mut(la)) {
                row.fill(i as i32);
            }
            for (i, row) in range.clone().zip(wb.chunks_mut(lb)) {
                row.fill(i as i8);
            }
        });
        for i in 0..rows {
            assert!(a[i * la..(i + 1) * la].iter().all(|&v| v == i as i32));
            assert!(b[i * lb..(i + 1) * lb].iter().all(|&v| v == i as i8));
        }
    }

    #[test]
    fn nested_fork_degrades_to_sequential() {
        // a fork issued from inside a fork must not deadlock the pool
        let (rows, row_len) = (8usize, 4usize);
        let mut out = vec![0u32; rows * row_len];
        par_rows_mut(&mut out, rows, row_len, 4, |range, window| {
            let inner_rows = range.end - range.start;
            par_rows_mut(window, inner_rows, row_len, 4, |inner, w| {
                for (k, row) in inner.clone().zip(w.chunks_mut(row_len)) {
                    row.fill((range.start + k) as u32 + 1);
                }
            });
        });
        let want: Vec<u32> =
            (0..rows).flat_map(|i| std::iter::repeat(i as u32 + 1).take(row_len)).collect();
        assert_eq!(out, want);
    }

    #[test]
    fn concurrent_forks_from_independent_threads_serialize() {
        // several OS threads forking on the global pool at once: the
        // fork lock serializes them and every result stays correct
        std::thread::scope(|s| {
            for t in 0..4u32 {
                s.spawn(move || {
                    for _ in 0..20 {
                        let (rows, row_len) = (11usize, 3usize);
                        let mut out = vec![0u32; rows * row_len];
                        par_rows_mut(&mut out, rows, row_len, 3, |range, window| {
                            for (i, row) in range.clone().zip(window.chunks_mut(row_len)) {
                                row.fill(i as u32 * 10 + t);
                            }
                        });
                        for i in 0..rows {
                            assert!(out[i * row_len..(i + 1) * row_len]
                                .iter()
                                .all(|&v| v == i as u32 * 10 + t));
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn pool_survives_a_panicking_part() {
        let pool = Pool::new(2);
        let boom = panic::catch_unwind(AssertUnwindSafe(|| {
            let mut out = vec![0u32; 30];
            pool.par_rows_mut(&mut out, 30, 1, 3, |range, _| {
                if range.start == 0 {
                    panic!("injected");
                }
            });
        }));
        assert!(boom.is_err(), "panic must propagate to the forking caller");
        // the pool still works after the failed fork
        let mut out = vec![0u32; 30];
        pool.par_rows_mut(&mut out, 30, 1, 3, |range, w| {
            for (i, v) in range.clone().zip(w.iter_mut()) {
                *v = i as u32;
            }
        });
        let want: Vec<u32> = (0..30).collect();
        assert_eq!(out, want);
    }
}
