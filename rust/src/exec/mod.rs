//! Data-parallel execution substrate for the integer inference engine
//! and the serving layer (no rayon in the offline image).
//!
//! Everything here is built on **scoped threads** (`std::thread::scope`),
//! so workers may borrow non-`'static` data — the engine hands each
//! worker a disjoint `&mut` window of the output buffer plus a shared
//! `&` view of the inputs, and each worker owns its own scratch space
//! for the duration of the call (per-thread scratch reuse across the
//! items in its range).
//!
//! **Determinism contract:** every helper in this module partitions work
//! into contiguous, disjoint ranges and each output element is computed
//! by exactly one worker with exactly the same instruction sequence the
//! sequential path uses. Results are therefore bit-identical for every
//! thread count, including 1 — pinned by rust/tests/parallel.rs.
//!
//! Thread-count policy: callers pass an explicit `threads` budget;
//! [`default_threads`] resolves the process-wide default
//! (`FQCONV_THREADS` env var, else `available_parallelism`), and
//! [`clamp_threads`] shrinks a budget so small problems never pay
//! fork-join overhead.

use std::ops::Range;

/// Process-wide default worker count: `FQCONV_THREADS` if set (>= 1),
/// else the machine's available parallelism.
pub fn default_threads() -> usize {
    match std::env::var("FQCONV_THREADS").ok().and_then(|v| v.parse::<usize>().ok()) {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Split `0..n` into at most `parts` contiguous, balanced, disjoint
/// ranges (earlier ranges get the remainder). Deterministic in (n, parts).
pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.max(1).min(n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Shrink a thread budget so each worker keeps at least
/// `min_rows_per_thread` rows — below that, fork-join overhead dominates.
pub fn clamp_threads(threads: usize, rows: usize, min_rows_per_thread: usize) -> usize {
    threads.max(1).min((rows / min_rows_per_thread.max(1)).max(1))
}

/// Fork-join over the rows of a row-major `(rows, row_len)` output
/// buffer: `out` is split into contiguous per-worker windows and
/// `f(range, window)` runs once per worker with `window` covering exactly
/// `range`'s rows. With one part (or one row) this degrades to a plain
/// call on the current thread — no spawn.
pub fn par_rows_mut<T, F>(out: &mut [T], rows: usize, row_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(Range<usize>, &mut [T]) + Sync,
{
    assert_eq!(out.len(), rows * row_len, "output buffer / row geometry mismatch");
    let parts = partition(rows, threads);
    if parts.len() <= 1 {
        f(0..rows, out);
        return;
    }
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = out;
        let n_parts = parts.len();
        let mut iter = parts.into_iter();
        for r in iter.by_ref().take(n_parts - 1) {
            let tail = std::mem::take(&mut rest);
            let (window, tail) = tail.split_at_mut((r.end - r.start) * row_len);
            rest = tail;
            s.spawn(move || f(r, window));
        }
        // the calling thread takes the final window instead of idling
        // at the scope barrier: one fewer spawn per fork-join
        let last = iter.next().expect("partition returned >= 2 parts");
        f(last, rest);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_disjointly() {
        for (n, parts) in [(0usize, 3usize), (1, 4), (7, 3), (64, 8), (10, 1), (5, 9)] {
            let ranges = partition(n, parts);
            assert!(ranges.len() <= parts.max(1));
            let mut next = 0;
            for r in &ranges {
                assert_eq!(r.start, next);
                next = r.end;
            }
            assert_eq!(next, n);
            // balanced: lengths differ by at most 1
            let lens: Vec<usize> = ranges.iter().map(|r| r.end - r.start).collect();
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(hi - lo <= 1, "unbalanced partition {lens:?}");
        }
    }

    #[test]
    fn clamp_keeps_rows_per_thread() {
        assert_eq!(clamp_threads(8, 78, 16), 4);
        assert_eq!(clamp_threads(8, 10, 16), 1);
        assert_eq!(clamp_threads(0, 100, 16), 1);
        assert_eq!(clamp_threads(2, 1000, 16), 2);
    }

    #[test]
    fn par_rows_writes_every_row_once() {
        let (rows, row_len) = (37, 5);
        for threads in [1usize, 2, 3, 8, 64] {
            let mut out = vec![0u32; rows * row_len];
            par_rows_mut(&mut out, rows, row_len, threads, |range, window| {
                for (i, row) in range.clone().zip(window.chunks_mut(row_len)) {
                    for (j, v) in row.iter_mut().enumerate() {
                        *v += (i * row_len + j) as u32 + 1;
                    }
                }
            });
            let want: Vec<u32> = (0..rows * row_len).map(|i| i as u32 + 1).collect();
            assert_eq!(out, want, "threads={threads}");
        }
    }

    #[test]
    fn zero_rows_is_a_noop() {
        let mut out: Vec<u8> = Vec::new();
        par_rows_mut(&mut out, 0, 4, 4, |_, _| {});
    }
}
