//! Streaming stateful inference — per-stream session state over the
//! integer engine.
//!
//! The paper's headline workload is always-on keyword spotting: per-user
//! audio *streams*, not batch-of-N clips. Offline, the engine consumes a
//! whole `(n_in, frames)` window; in production each user produces one
//! new MFCC frame every hop, and recomputing the full window per frame
//! is `frames`× wasted work. The dilated conv stack makes incremental
//! reuse *exact*: layer output column `t` depends only on the `span =
//! dilation * (ksize - 1) + 1` most recent input columns, so a per-layer
//! ring of that many columns is the entire state a stream needs:
//!
//! ```text
//!   frame (n_in f32) ──FpEmbed──► col (dim i8)
//!        │                          │ push
//!        ▼                          ▼
//!   layer 0 ring  [· · · · ·]  span_0 = d0*(k0-1)+1 cols of c_in codes
//!        │ warm? emit one col       │
//!        ▼                          ▼
//!   layer 1 ring  [· · · · · · · ·] ...            (cascade: layer l+1
//!        │                                          only receives a col
//!        ▼                                          when layer l emits)
//!   last layer col ──► gap_sum[ch] += col[ch] (i64), gap_cols += 1
//!                       │
//!                       ▼  logits_into(): dequantize_i64 / gap_cols,
//!                          DenseHead — emittable after any frame
//! ```
//!
//! **Bit-identity contract:** after feeding `n` frames, `logits_into`
//! equals the offline [`QuantGraph::forward_into`] on the first `n`
//! frames of the same signal, bit for bit (pinned across every KWS
//! dilation schedule and the edge shapes by rust/tests/stream.rs):
//!
//! * the per-frame [`FpEmbed`](crate::infer::graph::FpEmbed) chain
//!   accumulates over input channels in the same f32 order as the
//!   offline per-row axpy, so each embedded column is identical;
//! * the conv cascade is exact integer arithmetic through the same
//!   fused `RequantLut` tables ([`state::feed_col`] — integer-only by
//!   construction, pinned by `cargo xtask lint`);
//! * the running i64 GAP sum equals the offline whole-window i64 sum
//!   (integer addition is associative), finished with the identical
//!   `dequantize_i64 / t` expression.
//!
//! [`Streamer`] is the shared, immutable per-model part (graph +
//! [`StatePlan`]); [`StreamState`] is the per-session part (rings + GAP
//! accumulator — `Send`, checked out by whichever serve worker pops the
//! feed); [`StreamScratch`] is the per-worker part (reused column /
//! accumulator buffers, allocation-free after warm-up). The serving
//! session layer (`ModelRegistry::{open_session, feed, close_session}`)
//! lives in [`crate::serve`]; [`StreamingMfcc`] is the overlap-save
//! front end that turns raw samples into frames, bit-identical to
//! [`Mfcc::compute`] framing.

pub mod state;

use std::sync::Arc;

use anyhow::{bail, ensure, Result};

use crate::data::dsp::{Mfcc, MfccScratch};
use crate::infer::graph::{QuantGraph, QuantStage};
use crate::quant::{learned_quantize, QParams};

use state::ConvRing;

// ---------------------------------------------------------------------------
// StatePlan
// ---------------------------------------------------------------------------

/// Ring geometry for one conv layer of the plan.
#[derive(Clone, Copy, Debug)]
pub struct RingSpec {
    pub c_in: usize,
    /// columns of history retained: `dilation * (ksize - 1) + 1`
    pub span: usize,
}

/// Per-model streaming plan derived from a validated 1-D [`QuantGraph`]:
/// ring geometry per conv layer, warm-up length, and the exact bytes a
/// session's state reserves (the serving layer's RSS proxy).
#[derive(Clone, Debug)]
pub struct StatePlan {
    rings: Vec<RingSpec>,
    n_in: usize,
    /// GAP width = last conv layer's c_out
    channels: usize,
    classes: usize,
    /// the final conv grid the GAP dequantizes on
    dq: QParams,
    /// frames before the first logits: the stack's receptive field
    warmup: usize,
    /// widest column the cascade ping-pongs (embed dim / any c_out)
    max_cols: usize,
}

impl StatePlan {
    /// Build the plan by walking the graph's stage list. Fails on 2-D
    /// (image) graphs — streaming is a sequence-model workload.
    pub fn for_graph(g: &QuantGraph) -> Result<StatePlan> {
        for st in g.stages() {
            match st {
                QuantStage::FpEmbed(_)
                | QuantStage::FqConvStack(_)
                | QuantStage::GlobalAvgPool(_)
                | QuantStage::DenseHead(_) => {}
                _ => bail!("streaming supports 1-D sequence graphs only"),
            }
        }
        let e = g.embed();
        let mut rings = Vec::new();
        let mut warmup = 1usize;
        let mut max_cols = e.dim;
        let mut channels = e.dim;
        for l in g.conv_layers() {
            let span = l.dilation * (l.ksize - 1) + 1;
            ensure!(l.c_in == channels, "conv stack channel chain broken");
            rings.push(RingSpec { c_in: l.c_in, span });
            warmup += span - 1;
            max_cols = max_cols.max(l.c_out);
            channels = l.c_out;
        }
        ensure!(!rings.is_empty(), "no conv layers to stream");
        let dq = match g.stages().iter().find_map(|s| match s {
            QuantStage::GlobalAvgPool(gap) => Some(gap.dq),
            _ => None,
        }) {
            Some(dq) => dq,
            None => bail!("graph has no GlobalAvgPool stage"),
        };
        Ok(StatePlan {
            rings,
            n_in: g.n_in(),
            channels,
            classes: g.classes(),
            dq,
            warmup,
            max_cols,
        })
    }

    pub fn rings(&self) -> &[RingSpec] {
        &self.rings
    }

    /// Frames a fresh session must absorb before the first logits (the
    /// conv stack's receptive field: `1 + Σ (span_l - 1)`).
    pub fn warmup_frames(&self) -> usize {
        self.warmup
    }

    /// Exact bytes one session's [`StreamState`] reserves: ring storage
    /// plus the i64 GAP accumulator plus the struct itself. The
    /// no-growth tests pin `StreamState::resident_bytes` to this.
    pub fn bytes_per_session(&self) -> usize {
        let ring_bytes: usize = self.rings.iter().map(|r| r.c_in * r.span).sum();
        ring_bytes
            + self.channels * std::mem::size_of::<i64>()
            + std::mem::size_of::<StreamState>()
    }
}

// ---------------------------------------------------------------------------
// StreamState + StreamScratch
// ---------------------------------------------------------------------------

/// Per-session streaming state: one [`ConvRing`] per conv layer plus
/// the running i64 GAP accumulator. Plain owned data — `Send` — so the
/// serving layer can check a session out to whichever worker pops its
/// feed; all model parameters stay in the shared [`Streamer`].
pub struct StreamState {
    rings: Vec<ConvRing>,
    gap_sum: Vec<i64>,
    /// output columns the last layer has emitted (the GAP divisor)
    gap_cols: usize,
    frames_in: usize,
}

impl StreamState {
    fn new(plan: &StatePlan) -> Self {
        StreamState {
            rings: plan.rings.iter().map(|r| ConvRing::new(r.c_in, r.span)).collect(),
            gap_sum: vec![0; plan.channels],
            gap_cols: 0,
            frames_in: 0,
        }
    }

    /// Frames fed into this session so far.
    pub fn frames_in(&self) -> usize {
        self.frames_in
    }

    /// True once logits are emittable (the warm-up receptive field has
    /// been absorbed).
    pub fn ready(&self) -> bool {
        self.gap_cols > 0
    }

    /// Bytes resident in this session's state (capacities, not lengths
    /// — pinned equal to [`StatePlan::bytes_per_session`] and constant
    /// across feeds by rust/tests/stream.rs).
    pub fn resident_bytes(&self) -> usize {
        self.rings.iter().map(|r| r.resident_bytes()).sum::<usize>()
            + self.gap_sum.capacity() * std::mem::size_of::<i64>()
            + std::mem::size_of::<StreamState>()
    }
}

/// Per-worker scratch for the feed path: ping-pong column buffers, the
/// i32 accumulator column, and the pooled-feature row. Reused across
/// sessions and feeds — allocation-free after the first warm feed
/// ([`StreamScratch::capacities`] is pinned stable by tests).
#[derive(Default)]
pub struct StreamScratch {
    acc: Vec<i32>,
    col_a: Vec<i8>,
    col_b: Vec<i8>,
    pooled: Vec<f32>,
}

impl StreamScratch {
    /// Scratch with every buffer pre-reserved to the plan, so even the
    /// first feed allocates nothing.
    pub fn for_plan(plan: &StatePlan) -> Self {
        StreamScratch {
            acc: Vec::with_capacity(plan.max_cols),
            col_a: Vec::with_capacity(plan.max_cols),
            col_b: Vec::with_capacity(plan.max_cols),
            pooled: Vec::with_capacity(plan.channels),
        }
    }

    /// Current capacities `(acc, col_a, col_b, pooled)` — lets tests pin
    /// that steady-state feeds never reallocate.
    pub fn capacities(&self) -> (usize, usize, usize, usize) {
        (self.acc.capacity(), self.col_a.capacity(), self.col_b.capacity(), self.pooled.capacity())
    }
}

// ---------------------------------------------------------------------------
// Streamer
// ---------------------------------------------------------------------------

/// The shared per-model half of the streaming subsystem: an immutable
/// [`QuantGraph`] plus its [`StatePlan`]. One `Streamer` serves any
/// number of concurrent [`StreamState`] sessions from any thread.
pub struct Streamer {
    graph: Arc<QuantGraph>,
    plan: StatePlan,
}

impl Streamer {
    pub fn new(graph: Arc<QuantGraph>) -> Result<Self> {
        let plan = StatePlan::for_graph(&graph)?;
        Ok(Streamer { graph, plan })
    }

    pub fn plan(&self) -> &StatePlan {
        &self.plan
    }

    pub fn graph(&self) -> &QuantGraph {
        &self.graph
    }

    pub fn classes(&self) -> usize {
        self.plan.classes
    }

    /// Feature width of one frame (the graph's `n_in`).
    pub fn frame_dim(&self) -> usize {
        self.plan.n_in
    }

    /// Open a fresh session state sized to the plan.
    pub fn open(&self) -> StreamState {
        StreamState::new(&self.plan)
    }

    /// A pre-sized per-worker scratch.
    pub fn scratch(&self) -> StreamScratch {
        StreamScratch::for_plan(&self.plan)
    }

    /// Feed one frame of `n_in` features: embed → cascade the conv
    /// rings → fold the last layer's column (if any) into the GAP
    /// accumulator. See the module doc for the bit-identity argument.
    pub fn feed(&self, st: &mut StreamState, frame: &[f32], scr: &mut StreamScratch) {
        assert_eq!(frame.len(), self.plan.n_in, "frame width");
        let e = self.graph.embed();
        let StreamScratch { acc, col_a, col_b, .. } = scr;
        // FpEmbed on a single column: identical f32 accumulation order
        // (over input channels, in sequence) to the offline per-row axpy.
        col_a.clear();
        col_a.resize(e.dim, 0);
        for (k, o) in col_a.iter_mut().enumerate() {
            let wrow = &e.w[k * e.n_in..(k + 1) * e.n_in];
            let mut av = 0.0f32;
            for (&wc, &xv) in wrow.iter().zip(frame) {
                av += wc * xv;
            }
            let bn = av * e.scale[k] + e.shift[k];
            let q = learned_quantize(bn, e.es, e.na, -1.0);
            *o = e.out_q.int_code(q) as i8;
        }
        st.frames_in += 1;
        // cascade: layer l+1 only receives a column when layer l emits
        let (mut cur, mut nxt) = (col_a, col_b);
        let mut emitted = true;
        for (l, ring) in self.graph.conv_layers().zip(st.rings.iter_mut()) {
            if !state::feed_col(l, ring, cur, acc, nxt) {
                emitted = false;
                break;
            }
            std::mem::swap(&mut cur, &mut nxt);
        }
        if emitted {
            st.gap_cols += 1;
            for (s, &c) in st.gap_sum.iter_mut().zip(cur.iter()) {
                *s += c as i64;
            }
        }
    }

    /// Logits over everything fed so far, bit-identical to the offline
    /// whole-window forward on the same frames. Returns `false` (and
    /// leaves `logits` untouched) while the session is still inside the
    /// warm-up receptive field.
    pub fn logits_into(&self, st: &StreamState, scr: &mut StreamScratch, logits: &mut [f32]) -> bool {
        assert_eq!(logits.len(), self.plan.classes, "logit buffer size");
        if st.gap_cols == 0 {
            return false;
        }
        scr.pooled.clear();
        scr.pooled.resize(self.plan.channels, 0.0);
        for (p, &s) in scr.pooled.iter_mut().zip(st.gap_sum.iter()) {
            *p = self.plan.dq.dequantize_i64(s) / st.gap_cols as f32;
        }
        self.graph.head().forward_into(&scr.pooled, logits);
        true
    }
}

// ---------------------------------------------------------------------------
// StreamingMfcc
// ---------------------------------------------------------------------------

/// Overlap-save streaming front end over [`Mfcc`]: a per-session ring of
/// the last `win` raw samples; every `hop` new samples it linearizes the
/// window and emits one MFCC frame via [`Mfcc::frame_into`] — the same
/// per-frame op sequence as [`Mfcc::compute`], so each emitted frame is
/// bit-identical to the corresponding column of the offline matrix.
pub struct StreamingMfcc {
    ring: Vec<f32>,
    head: usize,
    /// samples still needed before the next frame completes
    until_emit: usize,
    hop: usize,
    /// linearized window + contiguous frame scratch
    window: Vec<f32>,
    frame: Vec<f32>,
    frames_emitted: usize,
}

impl StreamingMfcc {
    pub fn new(mfcc: &Mfcc) -> Self {
        StreamingMfcc {
            ring: vec![0.0; mfcc.cfg.win],
            head: 0,
            until_emit: mfcc.cfg.win,
            hop: mfcc.cfg.hop,
            window: vec![0.0; mfcc.cfg.win],
            frame: vec![0.0; mfcc.cfg.n_mfcc],
            frames_emitted: 0,
        }
    }

    pub fn frames_emitted(&self) -> usize {
        self.frames_emitted
    }

    /// Feed raw samples; `on_frame` is called with each completed
    /// `n_mfcc`-coefficient frame, in order. `mfcc` and `scr` must be
    /// the extractor/scratch pair this session was opened against.
    pub fn push(
        &mut self,
        mfcc: &Mfcc,
        scr: &mut MfccScratch,
        samples: &[f32],
        mut on_frame: impl FnMut(&[f32]),
    ) {
        let win = self.ring.len();
        for &s in samples {
            self.ring[self.head] = s;
            self.head = (self.head + 1) % win;
            self.until_emit -= 1;
            if self.until_emit == 0 {
                // linearize: after the advance, the oldest retained
                // sample sits at `head`
                for (i, w) in self.window.iter_mut().enumerate() {
                    *w = self.ring[(self.head + i) % win];
                }
                mfcc.frame_into(&self.window, scr, &mut self.frame);
                on_frame(&self.frame);
                self.frames_emitted += 1;
                self.until_emit = self.hop;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::graph::{synthetic_graph, SeqArch, SynthArch};

    fn tiny() -> Arc<QuantGraph> {
        let arch = SeqArch {
            name: "tiny-stream",
            n_in: 5,
            frames: 30,
            embed_dim: 6,
            classes: 4,
            convs: vec![(6, 3, 1), (7, 3, 2)],
        };
        Arc::new(synthetic_graph(&SynthArch::Seq(arch), 1.0, 7.0, 3).unwrap())
    }

    #[test]
    fn plan_geometry() {
        let g = tiny();
        let s = Streamer::new(g).unwrap();
        let p = s.plan();
        assert_eq!(p.rings().len(), 2);
        assert_eq!(p.rings()[0].span, 3);
        assert_eq!(p.rings()[1].span, 5);
        // receptive field: 1 + 2 + 4
        assert_eq!(p.warmup_frames(), 7);
        // ring storage (6*3 + 6*5 code bytes) + the i64 GAP row (7*8)
        assert!(p.bytes_per_session() >= 6 * 3 + 6 * 5 + 7 * 8);
    }

    #[test]
    fn rejects_2d_graphs() {
        let g = synthetic_graph(&SynthArch::resnet32(), 1.0, 7.0, 3).unwrap();
        assert!(StatePlan::for_graph(&g).is_err());
    }

    #[test]
    fn not_ready_before_warmup() {
        let g = tiny();
        let s = Streamer::new(g).unwrap();
        let mut st = s.open();
        let mut scr = s.scratch();
        let mut logits = vec![0.0; s.classes()];
        let frame = vec![0.25f32; s.frame_dim()];
        for t in 0..s.plan().warmup_frames() - 1 {
            s.feed(&mut st, &frame, &mut scr);
            assert!(!s.logits_into(&st, &mut scr, &mut logits), "t={t}");
        }
        s.feed(&mut st, &frame, &mut scr);
        assert!(s.logits_into(&st, &mut scr, &mut logits));
        assert!(st.ready());
    }
}
