//! Integer-only incremental conv1d state — the streaming hot path.
//!
//! One [`ConvRing`] per conv layer holds exactly the receptive-field
//! history that layer needs: `span = dilation * (ksize - 1) + 1` input
//! columns of `c_in` i8 codes. Feeding one new column ([`feed_col`])
//! pushes it into the ring and, once the ring is warm, produces one
//! output column by running the layer's taps against the retained
//! history — the same i32 accumulation and the same fused
//! [`crate::quant::RequantLut`] re-binning as the offline
//! [`crate::infer::QuantConv1d::forward`], so the emitted codes are
//! bit-identical to the whole-window forward (integer arithmetic is
//! exact, so tap order cannot change the accumulator).
//!
//! This file is deliberately free of any float type or literal: it is
//! pinned by the `cargo xtask lint` hot-path-float rule, like the conv
//! kernels it reuses. Everything float-bearing in the streaming path
//! (FpEmbed, GAP dequantize, the dense head) lives in the parent
//! [`crate::stream`] module.

use crate::infer::conv::{requant_rows, QuantConv1d, WeightKind};

/// Ring buffer of the last `span` input columns a dilated conv layer
/// can still see. Storage is slot-major — `ring[slot * c_in + ci]` —
/// so one pushed column is a single contiguous copy.
///
/// Protocol: `head` is the next write position, which (once warm) is
/// also the *oldest* retained column; logical offset `j` from the
/// oldest therefore lives at physical slot `(head + j) % span`.
pub struct ConvRing {
    ring: Vec<i8>,
    head: usize,
    /// columns received so far, saturating at `span`
    filled: usize,
    c_in: usize,
    span: usize,
}

impl ConvRing {
    pub fn new(c_in: usize, span: usize) -> Self {
        assert!(c_in > 0 && span > 0, "degenerate ring geometry");
        ConvRing { ring: vec![0; c_in * span], head: 0, filled: 0, c_in, span }
    }

    /// Columns of history this ring retains (`dilation * (ksize-1) + 1`).
    pub fn span(&self) -> usize {
        self.span
    }

    /// True once the ring holds a full receptive field — every push
    /// from now on emits one output column.
    pub fn is_warm(&self) -> bool {
        self.filled == self.span
    }

    /// Bytes resident in the ring storage (capacity, not length — the
    /// memory-bound tests pin that this never grows across feeds).
    pub fn resident_bytes(&self) -> usize {
        self.ring.capacity()
    }

    fn push(&mut self, col: &[i8]) {
        debug_assert_eq!(col.len(), self.c_in, "column width");
        self.ring[self.head * self.c_in..(self.head + 1) * self.c_in].copy_from_slice(col);
        self.head = (self.head + 1) % self.span;
        if self.filled < self.span {
            self.filled += 1;
        }
    }

    /// Code of input channel `ci` at logical column offset `off`
    /// (0 = oldest retained column).
    #[inline]
    fn at(&self, off: usize, ci: usize) -> i8 {
        debug_assert!(off < self.span && ci < self.c_in);
        let slot = (self.head + off) % self.span;
        self.ring[slot * self.c_in + ci]
    }
}

/// Push one input column into `ring` and, once the layer's receptive
/// field is resident, emit one output column of `layer.c_out` codes on
/// the layer's fused output grid into `out`. Returns `true` when `out`
/// was written (the ring is warm), `false` during warm-up.
///
/// Tap `(ci, f)` of the layer reads the retained column at logical
/// offset `f * dilation` — exactly the element `x[ci, t + f*dilation]`
/// the offline conv reads for output step `t` — and the accumulator is
/// requantized through the layer's own LUT via the shared
/// [`requant_rows`] pass, so the result is bit-identical to
/// [`QuantConv1d::forward`] on the whole window.
pub fn feed_col(
    layer: &QuantConv1d,
    ring: &mut ConvRing,
    col: &[i8],
    acc: &mut Vec<i32>,
    out: &mut Vec<i8>,
) -> bool {
    debug_assert_eq!(ring.c_in, layer.c_in, "ring/layer channel mismatch");
    ring.push(col);
    if !ring.is_warm() {
        return false;
    }
    acc.clear();
    acc.resize(layer.c_out, 0);
    match &layer.weights {
        WeightKind::Ternary(tern) => {
            for (ko, a) in acc.iter_mut().enumerate() {
                let (plus, minus) = tern.col(ko);
                let mut v = 0i32;
                for &p in plus {
                    let (ci, f) = (p as usize / layer.ksize, p as usize % layer.ksize);
                    v += ring.at(f * layer.dilation, ci) as i32;
                }
                for &p in minus {
                    let (ci, f) = (p as usize / layer.ksize, p as usize % layer.ksize);
                    v -= ring.at(f * layer.dilation, ci) as i32;
                }
                *a = v;
            }
        }
        WeightKind::Dense { b } => {
            for ci in 0..layer.c_in {
                for f in 0..layer.ksize {
                    let xv = ring.at(f * layer.dilation, ci) as i32;
                    if xv == 0 {
                        continue; // zero inputs contribute exactly nothing
                    }
                    let w = &b[(ci * layer.ksize + f) * layer.c_out..][..layer.c_out];
                    for (a, &wv) in acc.iter_mut().zip(w) {
                        *a += wv as i32 * xv;
                    }
                }
            }
        }
    }
    out.clear();
    out.resize(layer.c_out, 0);
    requant_rows(&layer.lut, acc, out);
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QParams;
    use crate::util::Rng;

    fn random_layer(rng: &mut Rng, c_in: usize, c_out: usize, ksize: usize, dil: usize, nw: f32) -> QuantConv1d {
        let w: Vec<f32> = (0..c_out * c_in * ksize).map(|_| rng.gaussian_f32(0.0, 0.5)).collect();
        let qa = QParams::new(0.9, 7.0, 0.0);
        let qw = QParams::new(0.5, nw, -1.0);
        let mid = QParams::new(1.1, 7.0, 0.0);
        let next = Some(QParams::new(1.05, 7.0, 0.0));
        QuantConv1d::new(&w, c_out, c_in, ksize, dil, qa, qw, mid, next)
    }

    #[test]
    fn warmup_then_one_column_per_push() {
        let mut rng = Rng::new(23);
        let layer = random_layer(&mut rng, 3, 4, 3, 2, 1.0);
        let span = layer.dilation * (layer.ksize - 1) + 1;
        let mut ring = ConvRing::new(layer.c_in, span);
        let (mut acc, mut out) = (Vec::new(), Vec::new());
        for t in 0..span - 1 {
            let col: Vec<i8> = (0..layer.c_in).map(|_| rng.below(8) as i8).collect();
            assert!(!feed_col(&layer, &mut ring, &col, &mut acc, &mut out), "t={t}");
        }
        let col: Vec<i8> = (0..layer.c_in).map(|_| rng.below(8) as i8).collect();
        assert!(feed_col(&layer, &mut ring, &col, &mut acc, &mut out));
        assert_eq!(out.len(), layer.c_out);
    }

    #[test]
    fn streamed_columns_match_whole_window_forward() {
        // both weight kinds, dilations incl. the KWS extremes
        let mut rng = Rng::new(29);
        for &(ksize, dil) in &[(3usize, 1usize), (3, 2), (3, 8), (1, 1), (5, 2)] {
            for nw in [1.0f32, 7.0] {
                let (c_in, c_out, t_in) = (5usize, 6usize, 40usize);
                let layer = random_layer(&mut rng, c_in, c_out, ksize, dil, nw);
                let x: Vec<i8> = (0..c_in * t_in).map(|_| rng.below(8) as i8).collect();
                let (mut acc, mut want) = (Vec::new(), Vec::new());
                layer.forward(&x, t_in, &mut acc, &mut want);
                let t_out = layer.t_out(t_in);

                let span = dil * (ksize - 1) + 1;
                let mut ring = ConvRing::new(c_in, span);
                let (mut sacc, mut col_out) = (Vec::new(), Vec::new());
                let mut col = vec![0i8; c_in];
                let mut emitted = 0usize;
                for t in 0..t_in {
                    for (ci, c) in col.iter_mut().enumerate() {
                        *c = x[ci * t_in + t];
                    }
                    if feed_col(&layer, &mut ring, &col, &mut sacc, &mut col_out) {
                        for ko in 0..c_out {
                            assert_eq!(
                                col_out[ko],
                                want[ko * t_out + emitted],
                                "ksize={ksize} dil={dil} nw={nw} t={t} ko={ko}"
                            );
                        }
                        emitted += 1;
                    }
                }
                assert_eq!(emitted, t_out, "ksize={ksize} dil={dil} nw={nw}");
            }
        }
    }

    #[test]
    fn ring_memory_is_static() {
        let mut rng = Rng::new(31);
        let layer = random_layer(&mut rng, 4, 4, 3, 4, 1.0);
        let span = layer.dilation * (layer.ksize - 1) + 1;
        let mut ring = ConvRing::new(layer.c_in, span);
        let bytes = ring.resident_bytes();
        assert_eq!(bytes, layer.c_in * span);
        let (mut acc, mut out) = (Vec::new(), Vec::new());
        let col = vec![1i8; layer.c_in];
        for _ in 0..10 * span {
            feed_col(&layer, &mut ring, &col, &mut acc, &mut out);
        }
        assert_eq!(ring.resident_bytes(), bytes, "ring grew across feeds");
    }
}
