//! Experiment configuration: a TOML-subset parser (sections, scalar
//! keys, inline comments) + typed experiment config with defaults and
//! file/CLI overrides. No serde/toml crates in the offline image.
//!
//! Grammar supported:
//! ```toml
//! # comment
//! [section]
//! key = "string"      # strings, numbers, booleans
//! steps = 200
//! lr = 0.01
//! verbose = true
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    Bool(bool),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed config: section -> key -> value ("" = top-level section).
#[derive(Clone, Debug, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Self> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: unterminated section header", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some(eq) = line.find('=') else {
                bail!("line {}: expected key = value", lineno + 1);
            };
            let key = line[..eq].trim().to_string();
            let val = parse_value(line[eq + 1..].trim())
                .with_context(|| format!("line {}: bad value", lineno + 1))?;
            cfg.sections.entry(section.clone()).or_default().insert(key, val);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn usize_or(&self, section: &str, key: &str, default: usize) -> usize {
        self.get(section, key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Apply a `section.key=value` override string (CLI `--set`).
    pub fn set_override(&mut self, spec: &str) -> Result<()> {
        let (path, val) = spec.split_once('=').context("override must be sec.key=value")?;
        let (section, key) = match path.trim().split_once('.') {
            Some((s, k)) => (s.to_string(), k.to_string()),
            None => (String::new(), path.trim().to_string()),
        };
        let val = parse_value(val.trim())?;
        self.sections.entry(section).or_default().insert(key, val);
        Ok(())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            bail!("unterminated string {s:?}");
        }
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(n) = s.parse::<f64>() {
        return Ok(Value::Num(n));
    }
    // bare word = string (convenient for model names)
    if !s.is_empty() && s.chars().all(|c| c.is_alphanumeric() || c == '_' || c == '-') {
        return Ok(Value::Str(s.to_string()));
    }
    bail!("cannot parse value {s:?}")
}

/// Experiment budget presets: benches use `quick`, the CLI defaults to `full`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Budget {
    pub steps_per_stage: usize,
    pub eval_batches: usize,
    pub noise_reps: usize,
    pub noise_samples: usize,
}

impl Budget {
    pub fn quick() -> Self {
        Budget { steps_per_stage: 120, eval_batches: 8, noise_reps: 3, noise_samples: 96 }
    }

    pub fn full() -> Self {
        Budget { steps_per_stage: 600, eval_batches: 16, noise_reps: 10, noise_samples: 256 }
    }

    pub fn smoke() -> Self {
        Budget { steps_per_stage: 8, eval_batches: 2, noise_reps: 1, noise_samples: 16 }
    }

    pub fn from_config(cfg: &Config, section: &str, base: Budget) -> Self {
        Budget {
            steps_per_stage: cfg.usize_or(section, "steps_per_stage", base.steps_per_stage),
            eval_batches: cfg.usize_or(section, "eval_batches", base.eval_batches),
            noise_reps: cfg.usize_or(section, "noise_reps", base.noise_reps),
            noise_samples: cfg.usize_or(section, "noise_samples", base.noise_samples),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let cfg = Config::parse(
            "top = 1\n[exp]\nmodel = \"kws\"  # the model\nsteps = 200\nlr = 0.01\nverbose = true\nname = resnet8s\n",
        )
        .unwrap();
        assert_eq!(cfg.f64_or("", "top", 0.0), 1.0);
        assert_eq!(cfg.str_or("exp", "model", "?"), "kws");
        assert_eq!(cfg.usize_or("exp", "steps", 0), 200);
        assert!((cfg.f64_or("exp", "lr", 0.0) - 0.01).abs() < 1e-12);
        assert!(cfg.bool_or("exp", "verbose", false));
        assert_eq!(cfg.str_or("exp", "name", "?"), "resnet8s");
    }

    #[test]
    fn overrides() {
        let mut cfg = Config::parse("[exp]\nsteps = 10\n").unwrap();
        cfg.set_override("exp.steps=99").unwrap();
        assert_eq!(cfg.usize_or("exp", "steps", 0), 99);
        cfg.set_override("toplevel=5").unwrap();
        assert_eq!(cfg.usize_or("", "toplevel", 0), 5);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Config::parse("[unterminated\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
        assert!(Config::parse("x = \"open\n").is_err());
    }

    #[test]
    fn comments_respect_strings() {
        let cfg = Config::parse("x = \"a#b\" # real comment\n").unwrap();
        assert_eq!(cfg.str_or("", "x", "?"), "a#b");
    }

    #[test]
    fn budgets() {
        assert!(Budget::quick().steps_per_stage < Budget::full().steps_per_stage);
        let cfg = Config::parse("[budget]\nsteps_per_stage = 42\n").unwrap();
        let b = Budget::from_config(&cfg, "budget", Budget::quick());
        assert_eq!(b.steps_per_stage, 42);
        assert_eq!(b.eval_batches, Budget::quick().eval_batches);
    }
}
