//! The full KWS network as a native integer pipeline.
//!
//! Mirrors `compile.models.kws.fq_apply_pallas` exactly: full-precision
//! 1x1 embedding + inference-mode BN + learned input quantizer, seven
//! integer FQ-Conv layers with LUT re-binning, higher-precision global
//! average pooling, dense head. Built straight from a trained FQ
//! [`ParamSet`] + the manifest — no XLA on this path.

use anyhow::{Context, Result};

use crate::coordinator::ParamSet;
use crate::exec;
use crate::quant::{learned_quantize, QParams};
use crate::runtime::{GraphSpec, TensorSpec};
use crate::tensor::TensorF;
use crate::util::Rng;

use super::conv::QuantConv1d;

/// KWS dilation schedule — must match compile/models/kws.py DILATIONS.
pub const DILATIONS: [usize; 7] = [1, 1, 2, 4, 8, 8, 8];

pub const BN_EPS: f32 = 1e-5;

struct Embed {
    w: Vec<f32>, // (embed, n_mfcc)
    scale: Vec<f32>,
    shift: Vec<f32>,
    /// e^{embed.sa}: the learned input quantizer of the QCNN
    es: f32,
    n_mfcc: usize,
    dim: usize,
}

pub struct FqKwsNet {
    embed: Embed,
    pub layers: Vec<QuantConv1d>,
    head_w: Vec<f32>, // (filters, classes)
    head_b: Vec<f32>,
    pub na: f32,
    pub filters: usize,
    pub classes: usize,
    pub frames: usize,
}

/// Reusable per-thread scratch buffers (hot path is allocation-free).
/// Each worker of a data-parallel batch owns one of these.
#[derive(Default)]
pub struct Scratch {
    acc: Vec<i32>,
    a: Vec<i8>,
    b: Vec<i8>,
    /// float accumulator row for the embedding's streaming dot products
    fa: Vec<f32>,
    /// pooled features, reused so the GAP + head path never allocates
    pooled: Vec<f32>,
}

/// Higher-precision global average pooling over final-grid codes
/// (filters, t_cur): the sum runs in i64 so an arbitrarily long time
/// axis cannot silently truncate (an i8-code sum overflows i32 once
/// t_cur exceeds ~2^24 — see [`QParams::dequantize_i64`]).
pub fn global_avg_pool_into(
    codes: &[i8],
    filters: usize,
    t_cur: usize,
    dq: &QParams,
    pooled: &mut [f32],
) {
    debug_assert_eq!(codes.len(), filters * t_cur);
    debug_assert_eq!(pooled.len(), filters);
    for (k, p) in pooled.iter_mut().enumerate() {
        let mut sum = 0i64;
        for t in 0..t_cur {
            sum += codes[k * t_cur + t] as i64;
        }
        *p = dq.dequantize_i64(sum) / t_cur as f32;
    }
}

/// Allocating convenience wrapper over [`global_avg_pool_into`].
pub fn global_avg_pool(codes: &[i8], filters: usize, t_cur: usize, dq: &QParams) -> Vec<f32> {
    let mut pooled = vec![0f32; filters];
    global_avg_pool_into(codes, filters, t_cur, dq, &mut pooled);
    pooled
}

impl FqKwsNet {
    /// Build from trained FQ parameters (nw/na are the stage's level counts).
    pub fn from_params(params: &ParamSet, nw: f32, na: f32, frames: usize) -> Result<Self> {
        let get = |n: &str| params.get(n).with_context(|| format!("missing param {n}"));
        let ew = get("embed.w")?;
        let (dim, n_mfcc) = (ew.shape()[0], ew.shape()[1]);
        let gamma = get("embed.bn.gamma")?.data();
        let beta = get("embed.bn.beta")?.data();
        let mean = get("embed.bn.mean")?.data();
        let var = get("embed.bn.var")?.data();
        // fold eval-mode BN into per-channel scale+shift
        let scale: Vec<f32> =
            (0..dim).map(|k| gamma[k] / (var[k] + BN_EPS).sqrt()).collect();
        let shift: Vec<f32> = (0..dim).map(|k| beta[k] - scale[k] * mean[k]).collect();
        let embed = Embed {
            w: ew.data().to_vec(),
            scale,
            shift,
            es: params.scalar("embed.sa")?.exp(),
            n_mfcc,
            dim,
        };

        let n_layers = DILATIONS.len();
        // per-layer quantizers; layer 0 sees the signed embedding grid
        let mut layers = Vec::with_capacity(n_layers);
        for i in 0..n_layers {
            let w = get(&format!("conv{i}.w"))?;
            let (c_out, c_in, ksize) = (w.shape()[0], w.shape()[1], w.shape()[2]);
            let ba = if i == 0 { -1.0 } else { 0.0 };
            let qa = QParams::new(params.scalar(&format!("conv{i}.sa"))?.exp(), na, ba);
            let qw = QParams::new(params.scalar(&format!("conv{i}.sw"))?.exp(), nw, -1.0);
            let mid = QParams::new(params.scalar(&format!("conv{i}.so"))?.exp(), na, 0.0);
            let next = if i + 1 < n_layers {
                Some(QParams::new(params.scalar(&format!("conv{}.sa", i + 1))?.exp(), na, 0.0))
            } else {
                None
            };
            layers.push(QuantConv1d::new(
                w.data(),
                c_out,
                c_in,
                ksize,
                DILATIONS[i],
                qa,
                qw,
                mid,
                next,
            ));
        }
        let head_w = get("head.w")?.data().to_vec();
        let head_b = get("head.b")?.data().to_vec();
        let filters = layers.last().unwrap().c_out;
        let classes = head_b.len();
        Ok(FqKwsNet { embed, layers, head_w, head_b, na, filters, classes, frames })
    }

    /// Deterministic synthetic network + parameters — no artifacts or
    /// XLA needed. Shapes match the KWS dataset (39 MFCC features x 80
    /// frames, 12 classes) so `data::kws::KwsDataset` samples feed it
    /// directly; used by offline tests and the perf benches.
    pub fn synthetic(nw: f32, na: f32, seed: u64) -> Result<Self> {
        let (n_mfcc, frames, dim, filters, classes) = (39usize, 80usize, 32usize, 32usize, 12usize);
        let mut specs: Vec<TensorSpec> = Vec::new();
        let mut spec = |name: &str, shape: Vec<usize>| {
            specs.push(TensorSpec { name: name.to_string(), shape });
        };
        spec("embed.w", vec![dim, n_mfcc]);
        for field in ["gamma", "beta", "mean", "var"] {
            spec(&format!("embed.bn.{field}"), vec![dim]);
        }
        spec("embed.sa", vec![]);
        for i in 0..DILATIONS.len() {
            let c_in = if i == 0 { dim } else { filters };
            spec(&format!("conv{i}.w"), vec![filters, c_in, 3]);
            for role in ["sa", "sw", "so"] {
                spec(&format!("conv{i}.{role}"), vec![]);
            }
        }
        spec("head.w", vec![filters, classes]);
        spec("head.b", vec![classes]);
        let graph = GraphSpec {
            trainable: specs,
            state: Vec::new(),
            opt: Vec::new(),
            param_count: 0,
        };
        let mut params = ParamSet::zeros(&graph);
        let mut rng = Rng::new(seed ^ 0x5EED_F0CC);
        for (spec, v) in graph.trainable.iter().zip(params.values.iter_mut()) {
            if spec.name.ends_with(".w") {
                rng.fill_gaussian(v.data_mut(), 0.5);
            } else if spec.name.ends_with(".bn.gamma") || spec.name.ends_with(".bn.var") {
                v.data_mut().fill(1.0);
            }
            // bn.beta / bn.mean / head.b / log-scales stay 0 (=> es = 1)
        }
        FqKwsNet::from_params(&params, nw, na, frames)
    }

    pub fn out_frames(&self) -> usize {
        let mut t = self.frames;
        for l in &self.layers {
            t = l.t_out(t);
        }
        t
    }

    /// Forward one sample: MFCC features (n_mfcc, frames) -> logits.
    pub fn forward(&self, x: &[f32], s: &mut Scratch) -> Vec<f32> {
        self.forward_with(x, s, 1)
    }

    /// [`FqKwsNet::forward`] with an intra-layer thread budget for the
    /// per-layer kernels (useful when serving single samples on an
    /// otherwise idle machine). Bit-identical at every `threads`.
    pub fn forward_with(&self, x: &[f32], s: &mut Scratch, threads: usize) -> Vec<f32> {
        let mut logits = vec![0f32; self.classes];
        self.forward_into(x, s, &mut logits, threads);
        logits
    }

    /// Allocation-free forward: logits land in the caller's slice and
    /// every intermediate lives in `s` — the steady-state serving path
    /// performs zero heap allocations per sample.
    pub fn forward_into(&self, x: &[f32], s: &mut Scratch, logits: &mut [f32], threads: usize) {
        let t_in = self.frames;
        let e = &self.embed;
        debug_assert_eq!(x.len(), e.n_mfcc * t_in);
        assert_eq!(logits.len(), self.classes, "logit buffer size");
        // --- FP embedding + BN + learned input quantization -> codes ----
        // Streamed as per-channel axpy rows: for each output channel the
        // t-axis accumulator row is contiguous and every input row is
        // contiguous, so the inner loops vectorize; the per-(k,t) f32
        // addition order over c is unchanged from the naive triple loop,
        // keeping the embedding bit-identical to the float reference.
        let qa0 = &self.layers[0].qa;
        s.a.clear();
        s.a.resize(e.dim * t_in, 0);
        s.fa.clear();
        s.fa.resize(t_in, 0.0);
        for k in 0..e.dim {
            let wrow = &e.w[k * e.n_mfcc..(k + 1) * e.n_mfcc];
            let fa = &mut s.fa[..t_in];
            fa.fill(0.0);
            for (c, &wc) in wrow.iter().enumerate() {
                let xrow = &x[c * t_in..(c + 1) * t_in];
                for (av, &xv) in fa.iter_mut().zip(xrow) {
                    *av += wc * xv;
                }
            }
            let (sc, sh) = (e.scale[k], e.shift[k]);
            let arow = &mut s.a[k * t_in..(k + 1) * t_in];
            for (o, &av) in arow.iter_mut().zip(fa.iter()) {
                let bn = av * sc + sh;
                // two-step: Q_{embed.sa}(b=-1) then conv0's input bin
                let q = learned_quantize(bn, e.es, self.na, -1.0);
                *o = qa0.int_code(q) as i8;
            }
        }
        // --- integer QCNN ------------------------------------------------
        let mut t_cur = t_in;
        let mut cur_in_a = true;
        for l in &self.layers {
            {
                let (input, output) =
                    if cur_in_a { (&s.a, &mut s.b) } else { (&s.b, &mut s.a) };
                l.forward_mt(input, t_cur, &mut s.acc, output, threads);
            }
            t_cur = l.t_out(t_cur);
            cur_in_a = !cur_in_a;
        }
        let codes = if cur_in_a { &s.a } else { &s.b };
        // --- higher-precision GAP + head ---------------------------------
        let last = self.layers.last().unwrap();
        let dq = last.lut.out; // final grid
        s.pooled.clear();
        s.pooled.resize(self.filters, 0.0);
        global_avg_pool_into(codes, self.filters, t_cur, &dq, &mut s.pooled);
        self.head_logits_into(&s.pooled, logits);
    }

    /// Forward a run of flattened samples into a pre-sized logits window
    /// — the single shared batch loop behind [`FqKwsNet::forward_batch`]
    /// and the serving backend (`serve::NativeBackend`). Allocation-free
    /// in steady state (all intermediates live in `s`).
    pub fn forward_rows(&self, xs: &[f32], s: &mut Scratch, out: &mut [f32]) {
        let per = self.embed.n_mfcc * self.frames;
        assert_eq!(xs.len() % per.max(1), 0, "feature buffer not a whole number of samples");
        assert_eq!(out.len(), xs.len() / per * self.classes, "logit buffer size");
        for (xi, oi) in xs.chunks_exact(per).zip(out.chunks_exact_mut(self.classes)) {
            self.forward_into(xi, s, oi, 1);
        }
    }

    /// Forward a batch (B, n_mfcc, frames) -> logits tensor (B, classes),
    /// data-parallel across samples over [`exec::default_threads`].
    pub fn forward_batch(&self, x: &TensorF) -> TensorF {
        self.forward_batch_with(x, exec::default_threads())
    }

    /// [`FqKwsNet::forward_batch`] with an explicit pool size. Samples
    /// are split into contiguous blocks over the persistent worker pool
    /// ([`exec::par_rows_mut`] — no thread spawn per batch), one block
    /// per worker, each with its own [`Scratch`] reused across its
    /// samples; a batch of one instead spends the budget inside the
    /// layer kernels. Output is bit-identical for every `threads`
    /// (rust/tests/parallel.rs).
    pub fn forward_batch_with(&self, x: &TensorF, threads: usize) -> TensorF {
        let b = x.shape()[0];
        let per = self.embed.n_mfcc * self.frames;
        let mut out = vec![0f32; b * self.classes];
        let threads = threads.max(1);
        if b == 1 {
            let mut s = Scratch::default();
            self.forward_into(x.data(), &mut s, &mut out, threads);
        } else if threads == 1 {
            let mut s = Scratch::default();
            self.forward_rows(x.data(), &mut s, &mut out);
        } else {
            exec::par_rows_mut(&mut out, b, self.classes, threads, |rows, window| {
                let mut s = Scratch::default();
                self.forward_rows(&x.data()[rows.start * per..rows.end * per], &mut s, window);
            });
        }
        TensorF::from_vec(&[b, self.classes], out)
    }

    /// Embedding internals for the analog simulator:
    /// (dim, n_mfcc, w, bn_scale, bn_shift, e^{embed.sa}).
    pub fn embed_view(&self) -> (usize, usize, &[f32], &[f32], &[f32], f32) {
        let e = &self.embed;
        (e.dim, e.n_mfcc, &e.w, &e.scale, &e.shift, e.es)
    }

    /// (mid, next) quantizer grids of layer `li`.
    pub fn layer_grids(&self, li: usize) -> (crate::quant::QParams, Option<crate::quant::QParams>) {
        let l = &self.layers[li];
        (l.mid, l.next)
    }

    /// Dense head on pooled features, into a caller-owned buffer (the
    /// hot path routes this through [`Scratch`] so no per-sample `Vec`
    /// is allocated — including no clone of the bias row).
    pub fn head_logits_into(&self, pooled: &[f32], logits: &mut [f32]) {
        debug_assert_eq!(pooled.len(), self.filters);
        logits.copy_from_slice(&self.head_b);
        for (k, &p) in pooled.iter().enumerate() {
            let w = &self.head_w[k * self.classes..(k + 1) * self.classes];
            for (l, &wj) in logits.iter_mut().zip(w) {
                *l += p * wj;
            }
        }
    }

    /// Allocating convenience wrapper over [`FqKwsNet::head_logits_into`].
    pub fn head_logits(&self, pooled: &[f32]) -> Vec<f32> {
        let mut logits = vec![0f32; self.classes];
        self.head_logits_into(pooled, &mut logits);
        logits
    }

    /// Total integer MACs per sample (for the perf accounting).
    pub fn macs_per_sample(&self) -> u64 {
        let mut t = self.frames;
        let mut total = 0u64;
        for l in &self.layers {
            t = l.t_out(t);
            total += (l.c_out * l.c_in * l.ksize * t) as u64;
        }
        total
    }
}
