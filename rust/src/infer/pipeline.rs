//! The KWS network as a thin constructor facade over the composable
//! [`QuantGraph`] engine.
//!
//! Mirrors `compile.models.kws.fq_apply_pallas` exactly: full-precision
//! 1x1 embedding + inference-mode BN + learned input quantizer, seven
//! integer FQ-Conv layers with LUT re-binning, higher-precision global
//! average pooling, dense head. [`FqKwsNet::from_params`] only *builds
//! the stage list* ([`kws_stages`]) from a trained FQ [`ParamSet`] + the
//! manifest — sequencing, buffer planning and the allocation-free
//! forward all live in [`QuantGraph`], shared with every other
//! architecture on the graph API (rust/tests/graph.rs pins the facade
//! bit-identical to a hand-assembled graph at every pool size).

use anyhow::{Context, Result};

use crate::coordinator::ParamSet;
use crate::exec;
use crate::quant::QParams;
use crate::runtime::{GraphSpec, TensorSpec};
use crate::tensor::TensorF;
use crate::util::Rng;

use super::conv::QuantConv1d;
use super::graph::{DenseHead, FpEmbed, FqConvStack, GlobalAvgPool, QuantGraph, QuantStage};

// Re-exported from the graph engine so existing imports keep working.
pub use super::graph::{global_avg_pool, global_avg_pool_into, Scratch};

/// KWS dilation schedule — must match compile/models/kws.py DILATIONS.
pub const DILATIONS: [usize; 7] = [1, 1, 2, 4, 8, 8, 8];

pub const BN_EPS: f32 = 1e-5;

/// Assemble the KWS stage list (FP embed → 7-layer FQ-Conv stack → GAP
/// → dense head) from trained FQ parameters. This is the *only* place
/// the KWS architecture is spelled out; [`QuantGraph::new`] validates
/// and seals it.
pub fn kws_stages(params: &ParamSet, nw: f32, na: f32) -> Result<Vec<QuantStage>> {
    let get = |n: &str| params.get(n).with_context(|| format!("missing param {n}"));
    let ew = get("embed.w")?;
    let (dim, n_mfcc) = (ew.shape()[0], ew.shape()[1]);
    let gamma = get("embed.bn.gamma")?.data();
    let beta = get("embed.bn.beta")?.data();
    let mean = get("embed.bn.mean")?.data();
    let var = get("embed.bn.var")?.data();
    // fold eval-mode BN into per-channel scale+shift
    let scale: Vec<f32> = (0..dim).map(|k| gamma[k] / (var[k] + BN_EPS).sqrt()).collect();
    let shift: Vec<f32> = (0..dim).map(|k| beta[k] - scale[k] * mean[k]).collect();
    // layer 0 sees the signed embedding grid
    let qa0 = QParams::new(params.scalar("conv0.sa")?.exp(), na, -1.0);
    let embed = FpEmbed {
        w: ew.data().to_vec(),
        scale,
        shift,
        es: params.scalar("embed.sa")?.exp(),
        na,
        out_q: qa0,
        n_in: n_mfcc,
        dim,
    };

    let n_layers = DILATIONS.len();
    let mut layers = Vec::with_capacity(n_layers);
    for i in 0..n_layers {
        let w = get(&format!("conv{i}.w"))?;
        let (c_out, c_in, ksize) = (w.shape()[0], w.shape()[1], w.shape()[2]);
        let ba = if i == 0 { -1.0 } else { 0.0 };
        let qa = QParams::new(params.scalar(&format!("conv{i}.sa"))?.exp(), na, ba);
        let qw = QParams::new(params.scalar(&format!("conv{i}.sw"))?.exp(), nw, -1.0);
        let mid = QParams::new(params.scalar(&format!("conv{i}.so"))?.exp(), na, 0.0);
        let next = if i + 1 < n_layers {
            Some(QParams::new(params.scalar(&format!("conv{}.sa", i + 1))?.exp(), na, 0.0))
        } else {
            None
        };
        layers.push(QuantConv1d::new(
            w.data(),
            c_out,
            c_in,
            ksize,
            DILATIONS[i],
            qa,
            qw,
            mid,
            next,
        ));
    }
    let last = layers.last().unwrap();
    let gap = GlobalAvgPool { channels: last.c_out, dq: last.out_grid() };

    let head_w = get("head.w")?.data().to_vec();
    let head_b = get("head.b")?.data().to_vec();
    let (d_in, d_out) = (get("head.w")?.shape()[0], head_b.len());
    let head = DenseHead { w: head_w, b: head_b, d_in, d_out };

    Ok(vec![
        QuantStage::FpEmbed(embed),
        QuantStage::FqConvStack(FqConvStack { layers }),
        QuantStage::GlobalAvgPool(gap),
        QuantStage::DenseHead(head),
    ])
}

/// Deterministic synthetic KWS parameters — no artifacts or XLA needed.
/// Shapes match the KWS dataset (39 MFCC features x 80 frames, 12
/// classes) so `data::kws::KwsDataset` samples feed the resulting net
/// directly; used by offline tests and the perf benches (and by
/// rust/tests/graph.rs to build the facade and a hand-assembled graph
/// from the *same* parameters).
pub fn synthetic_params(seed: u64) -> Result<ParamSet> {
    let (n_mfcc, dim, filters, classes) = (39usize, 32usize, 32usize, 12usize);
    let mut specs: Vec<TensorSpec> = Vec::new();
    let mut spec = |name: &str, shape: Vec<usize>| {
        specs.push(TensorSpec { name: name.to_string(), shape });
    };
    spec("embed.w", vec![dim, n_mfcc]);
    for field in ["gamma", "beta", "mean", "var"] {
        spec(&format!("embed.bn.{field}"), vec![dim]);
    }
    spec("embed.sa", vec![]);
    for i in 0..DILATIONS.len() {
        let c_in = if i == 0 { dim } else { filters };
        spec(&format!("conv{i}.w"), vec![filters, c_in, 3]);
        for role in ["sa", "sw", "so"] {
            spec(&format!("conv{i}.{role}"), vec![]);
        }
    }
    spec("head.w", vec![filters, classes]);
    spec("head.b", vec![classes]);
    let graph = GraphSpec { trainable: specs, state: Vec::new(), opt: Vec::new(), param_count: 0 };
    let mut params = ParamSet::zeros(&graph);
    let mut rng = Rng::new(seed ^ 0x5EED_F0CC);
    for (spec, v) in graph.trainable.iter().zip(params.values.iter_mut()) {
        if spec.name.ends_with(".w") {
            rng.fill_gaussian(v.data_mut(), 0.5);
        } else if spec.name.ends_with(".bn.gamma") || spec.name.ends_with(".bn.var") {
            v.data_mut().fill(1.0);
        }
        // bn.beta / bn.mean / head.b / log-scales stay 0 (=> es = 1)
    }
    Ok(params)
}

/// The KWS deployment network: a named facade over [`QuantGraph`].
pub struct FqKwsNet {
    graph: QuantGraph,
    pub na: f32,
    pub filters: usize,
    pub classes: usize,
    pub frames: usize,
}

impl FqKwsNet {
    /// Build from trained FQ parameters (nw/na are the stage's level counts).
    pub fn from_params(params: &ParamSet, nw: f32, na: f32, frames: usize) -> Result<Self> {
        let graph = QuantGraph::new(kws_stages(params, nw, na)?, frames)?;
        let filters = graph.head().d_in;
        let classes = graph.classes();
        Ok(FqKwsNet { graph, na, filters, classes, frames })
    }

    /// Deterministic synthetic network — [`synthetic_params`] +
    /// [`FqKwsNet::from_params`] at the KWS input geometry.
    pub fn synthetic(nw: f32, na: f32, seed: u64) -> Result<Self> {
        FqKwsNet::from_params(&synthetic_params(seed)?, nw, na, 80)
    }

    /// The underlying stage graph.
    pub fn graph(&self) -> &QuantGraph {
        &self.graph
    }

    /// The integer conv layers, in execution order.
    pub fn layers(&self) -> &[QuantConv1d] {
        self.graph.first_stack()
    }

    pub fn out_frames(&self) -> usize {
        self.graph.out_frames()
    }

    /// Forward one sample: MFCC features (n_mfcc, frames) -> logits.
    pub fn forward(&self, x: &[f32], s: &mut Scratch) -> Vec<f32> {
        self.forward_with(x, s, 1)
    }

    /// [`FqKwsNet::forward`] with an intra-layer thread budget for the
    /// per-layer kernels (useful when serving single samples on an
    /// otherwise idle machine). Bit-identical at every `threads`.
    pub fn forward_with(&self, x: &[f32], s: &mut Scratch, threads: usize) -> Vec<f32> {
        let mut logits = vec![0f32; self.classes];
        self.forward_into(x, s, &mut logits, threads);
        logits
    }

    /// Allocation-free forward: logits land in the caller's slice and
    /// every intermediate lives in `s` — the steady-state serving path
    /// performs zero heap allocations per sample.
    pub fn forward_into(&self, x: &[f32], s: &mut Scratch, logits: &mut [f32], threads: usize) {
        self.graph.forward_into(x, s, logits, threads);
    }

    /// Forward a run of flattened samples into a pre-sized logits window
    /// — the single shared batch loop behind [`FqKwsNet::forward_batch`]
    /// and the serving backend (`serve::NativeBackend`), now delegated
    /// to [`QuantGraph::forward_rows`] so the facade and the bare-graph
    /// walk cannot diverge. Allocation-free in steady state (all
    /// intermediates live in `s`).
    pub fn forward_rows(&self, xs: &[f32], s: &mut Scratch, out: &mut [f32]) {
        self.graph.forward_rows(xs, s, out);
    }

    /// Forward a batch (B, n_mfcc, frames) -> logits tensor (B, classes),
    /// data-parallel across samples over [`exec::default_threads`].
    pub fn forward_batch(&self, x: &TensorF) -> TensorF {
        self.forward_batch_with(x, exec::default_threads())
    }

    /// [`FqKwsNet::forward_batch`] with an explicit pool size — now a
    /// thin wrapper over the graph engine's
    /// [`QuantGraph::forward_batch_into`]: samples are split into
    /// contiguous blocks over the persistent worker pool (no thread
    /// spawn per batch), one block per worker, each with its own
    /// [`Scratch`] reused across its samples; a batch of one instead
    /// spends the budget inside the layer kernels. Output is
    /// bit-identical for every `threads` (rust/tests/parallel.rs).
    pub fn forward_batch_with(&self, x: &TensorF, threads: usize) -> TensorF {
        let b = x.shape()[0];
        let mut out = vec![0f32; b * self.classes];
        self.graph.forward_batch_into(x.data(), b, &mut out, threads);
        TensorF::from_vec(&[b, self.classes], out)
    }

    /// Embedding internals for the analog simulator:
    /// (dim, n_mfcc, w, bn_scale, bn_shift, e^{embed.sa}).
    pub fn embed_view(&self) -> (usize, usize, &[f32], &[f32], &[f32], f32) {
        let e = self.graph.embed();
        (e.dim, e.n_in, &e.w, &e.scale, &e.shift, e.es)
    }

    /// (mid, next) quantizer grids of layer `li`.
    pub fn layer_grids(&self, li: usize) -> (crate::quant::QParams, Option<crate::quant::QParams>) {
        let l = &self.layers()[li];
        (l.mid, l.next)
    }

    /// Dense head on pooled features, into a caller-owned buffer (the
    /// hot path routes this through [`Scratch`] so no per-sample `Vec`
    /// is allocated — including no clone of the bias row).
    pub fn head_logits_into(&self, pooled: &[f32], logits: &mut [f32]) {
        self.graph.head().forward_into(pooled, logits);
    }

    /// Allocating convenience wrapper over [`FqKwsNet::head_logits_into`].
    pub fn head_logits(&self, pooled: &[f32]) -> Vec<f32> {
        let mut logits = vec![0f32; self.classes];
        self.head_logits_into(pooled, &mut logits);
        logits
    }

    /// Total integer MACs per sample (for the perf accounting).
    pub fn macs_per_sample(&self) -> u64 {
        self.graph.macs_per_sample()
    }

    /// Per-sample serving cost (conv MACs + head multiplies) — the
    /// registry's DWFQ weight; see [`QuantGraph::cost_per_sample`].
    pub fn cost_per_sample(&self) -> u64 {
        self.graph.cost_per_sample()
    }
}
