//! Composable quantized model graph — the engine's architecture seam.
//!
//! The paper's deployment pipeline (full-precision embedding → integer
//! FQ-Conv stack → higher-precision global average pooling → dense head)
//! used to be hardwired into one monolithic network struct. Survey work
//! on integer inference (Krishnamoorthi 2018; Nagel et al. 2021) frames
//! a quantized model instead as a *graph of requantizing ops with
//! per-tensor scale metadata*; this module is that abstraction:
//!
//! * [`QuantStage`] — the typed stages a fully-quantized network is
//!   composed of: [`FpEmbed`] (f32 features → input codes),
//!   [`FqConvStack`] (integer codes → integer codes, ping-pong),
//!   [`GlobalAvgPool`] (codes → f32 features, i64 higher-precision sum)
//!   and [`DenseHead`] (f32 features → logits).
//! * [`QuantGraph`] — owns stage sequencing, shape/grid validation,
//!   ping-pong code-buffer planning and scratch sizing, and exposes an
//!   allocation-free [`QuantGraph::forward_into`]. Every architecture
//!   the paper evaluates (the KWS TCN, ResNet-32, DarkNet-19) is a
//!   different stage list over the same bit-exact kernels.
//!
//! [`crate::infer::FqKwsNet`] is now a thin constructor facade over a
//! `QuantGraph`; [`synthetic_graph`] instantiates arbitrary
//! [`SynthArch`] descriptions (including a deeper/wider second
//! architecture, [`SynthArch::deep_wide`]) on the same API, which is how
//! rust/tests/graph.rs proves the graph generalizes beyond KWS.
//!
//! **Determinism contract:** stage bodies are the exact loops the
//! monolithic pipeline ran — same float accumulation order, same integer
//! instruction sequence — so a graph-built network is bit-identical to
//! the pre-refactor pipeline at every thread count (rust/tests/graph.rs,
//! rust/tests/parallel.rs).

use anyhow::{bail, ensure, Result};

use crate::quant::{learned_quantize, QParams};
use crate::util::Rng;

use super::conv::QuantConv1d;

// ---------------------------------------------------------------------------
// Scratch
// ---------------------------------------------------------------------------

/// Reusable per-thread scratch buffers (the hot path is allocation-free
/// in steady state). Each worker of a data-parallel batch owns one.
/// [`Scratch::for_graph`] pre-sizes every buffer from the graph's plan
/// so even the *first* forward allocates nothing.
#[derive(Default)]
pub struct Scratch {
    /// i32 conv accumulators, (c_out, t_out) of the current layer
    pub(crate) acc: Vec<i32>,
    /// ping-pong i8 code buffers
    pub(crate) a: Vec<i8>,
    pub(crate) b: Vec<i8>,
    /// float accumulator row for the embedding's streaming dot products
    pub(crate) fa: Vec<f32>,
    /// pooled features, reused so the GAP + head path never allocates
    pub(crate) pooled: Vec<f32>,
}

impl Scratch {
    /// Scratch with every buffer pre-reserved to the graph's plan.
    pub fn for_graph(g: &QuantGraph) -> Self {
        let p = &g.plan;
        Scratch {
            acc: Vec::with_capacity(p.acc),
            a: Vec::with_capacity(p.codes),
            b: Vec::with_capacity(p.codes),
            fa: Vec::with_capacity(p.fa),
            pooled: Vec::with_capacity(p.pooled),
        }
    }

    /// Current buffer capacities `(acc, a, b, fa, pooled)` — lets tests
    /// pin that a pre-planned scratch never reallocates on the hot path.
    pub fn capacities(&self) -> (usize, usize, usize, usize, usize) {
        (
            self.acc.capacity(),
            self.a.capacity(),
            self.b.capacity(),
            self.fa.capacity(),
            self.pooled.capacity(),
        )
    }
}

// ---------------------------------------------------------------------------
// Stages
// ---------------------------------------------------------------------------

/// Full-precision 1x1 embedding + inference-mode (folded) BN + learned
/// input quantizer: f32 features `(n_in, T)` → i8 codes `(dim, T)` on
/// the first conv layer's input grid (`out_q`).
pub struct FpEmbed {
    /// (dim, n_in) projection weights
    pub w: Vec<f32>,
    /// folded eval-mode BN: y = x * scale + shift, per output channel
    pub scale: Vec<f32>,
    pub shift: Vec<f32>,
    /// e^{sa}: the learned input quantizer scale of the quantized stack
    pub es: f32,
    /// activation level count of the quantized stack
    pub na: f32,
    /// the first conv layer's input grid (codes are emitted on it)
    pub out_q: QParams,
    pub n_in: usize,
    pub dim: usize,
}

impl FpEmbed {
    /// Embed one sample into `codes` (resized to `dim * t_in`), using
    /// `fa` as the reusable float accumulator row.
    ///
    /// Streamed as per-channel axpy rows: for each output channel the
    /// t-axis accumulator row is contiguous and every input row is
    /// contiguous, so the inner loops vectorize; the per-(k,t) f32
    /// addition order over c is unchanged from the naive triple loop,
    /// keeping the embedding bit-identical to the float reference.
    pub fn forward_into(&self, x: &[f32], t_in: usize, codes: &mut Vec<i8>, fa: &mut Vec<f32>) {
        debug_assert_eq!(x.len(), self.n_in * t_in);
        codes.clear();
        codes.resize(self.dim * t_in, 0);
        fa.clear();
        fa.resize(t_in, 0.0);
        for k in 0..self.dim {
            let wrow = &self.w[k * self.n_in..(k + 1) * self.n_in];
            let facc = &mut fa[..t_in];
            facc.fill(0.0);
            for (c, &wc) in wrow.iter().enumerate() {
                let xrow = &x[c * t_in..(c + 1) * t_in];
                for (av, &xv) in facc.iter_mut().zip(xrow) {
                    *av += wc * xv;
                }
            }
            let (sc, sh) = (self.scale[k], self.shift[k]);
            let crow = &mut codes[k * t_in..(k + 1) * t_in];
            for (o, &av) in crow.iter_mut().zip(facc.iter()) {
                let bn = av * sc + sh;
                // two-step: Q_{sa}(b=-1) then the first conv's input bin
                let q = learned_quantize(bn, self.es, self.na, -1.0);
                *o = self.out_q.int_code(q) as i8;
            }
        }
    }
}

/// A run of integer FQ-Conv layers. Codes ping-pong between the two
/// scratch buffers; each layer re-bins into the next layer's input grid
/// through its fused requant LUT.
pub struct FqConvStack {
    pub layers: Vec<QuantConv1d>,
}

/// Higher-precision global average pooling: i8 codes `(channels, t)` →
/// f32 features `(channels,)`, summing in i64 so an arbitrarily long
/// time axis cannot silently truncate (see [`QParams::dequantize_i64`]).
pub struct GlobalAvgPool {
    pub channels: usize,
    /// the final conv grid the codes live on
    pub dq: QParams,
}

/// Full-precision dense classifier head on pooled features.
pub struct DenseHead {
    /// (d_in, d_out) weights
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub d_in: usize,
    pub d_out: usize,
}

impl DenseHead {
    /// Pooled features → logits, into a caller-owned buffer (the hot
    /// path routes this through [`Scratch`] so no per-sample `Vec` is
    /// allocated — including no clone of the bias row).
    pub fn forward_into(&self, pooled: &[f32], logits: &mut [f32]) {
        debug_assert_eq!(pooled.len(), self.d_in);
        debug_assert_eq!(logits.len(), self.d_out);
        logits.copy_from_slice(&self.b);
        for (k, &p) in pooled.iter().enumerate() {
            let w = &self.w[k * self.d_out..(k + 1) * self.d_out];
            for (l, &wj) in logits.iter_mut().zip(w) {
                *l += p * wj;
            }
        }
    }
}

/// One typed stage of a fully-quantized inference graph.
pub enum QuantStage {
    FpEmbed(FpEmbed),
    FqConvStack(FqConvStack),
    GlobalAvgPool(GlobalAvgPool),
    DenseHead(DenseHead),
}

impl QuantStage {
    fn kind(&self) -> &'static str {
        match self {
            QuantStage::FpEmbed(_) => "FpEmbed",
            QuantStage::FqConvStack(_) => "FqConvStack",
            QuantStage::GlobalAvgPool(_) => "GlobalAvgPool",
            QuantStage::DenseHead(_) => "DenseHead",
        }
    }
}

// ---------------------------------------------------------------------------
// Higher-precision GAP kernel (stage body, shared with the facade)
// ---------------------------------------------------------------------------

/// Higher-precision global average pooling over final-grid codes
/// (channels, t_cur): the sum runs in i64 so an arbitrarily long time
/// axis cannot silently truncate (an i8-code sum overflows i32 once
/// t_cur exceeds ~2^24 — see [`QParams::dequantize_i64`]).
pub fn global_avg_pool_into(
    codes: &[i8],
    channels: usize,
    t_cur: usize,
    dq: &QParams,
    pooled: &mut [f32],
) {
    debug_assert_eq!(codes.len(), channels * t_cur);
    debug_assert_eq!(pooled.len(), channels);
    for (k, p) in pooled.iter_mut().enumerate() {
        let mut sum = 0i64;
        for t in 0..t_cur {
            sum += codes[k * t_cur + t] as i64;
        }
        *p = dq.dequantize_i64(sum) / t_cur as f32;
    }
}

/// Allocating convenience wrapper over [`global_avg_pool_into`].
pub fn global_avg_pool(codes: &[i8], channels: usize, t_cur: usize, dq: &QParams) -> Vec<f32> {
    let mut pooled = vec![0f32; channels];
    global_avg_pool_into(codes, channels, t_cur, dq, &mut pooled);
    pooled
}

// ---------------------------------------------------------------------------
// Graph
// ---------------------------------------------------------------------------

/// Peak buffer sizes of one forward pass, computed once at build time so
/// [`Scratch::for_graph`] can pre-reserve everything.
#[derive(Clone, Copy, Debug, Default)]
struct Plan {
    /// max i8 code-buffer numel at any stage boundary (ping-pong size)
    codes: usize,
    /// max i32 accumulator numel across conv layers
    acc: usize,
    /// float accumulator row length (embedding)
    fa: usize,
    /// pooled feature length
    pooled: usize,
}

/// A validated, executable sequence of [`QuantStage`]s.
///
/// The accepted stage grammar is `FpEmbed FqConvStack+ GlobalAvgPool
/// DenseHead` — exactly the paper's fully-quantized deployment shape,
/// with the conv stack free to be any depth/width/dilation schedule.
/// Construction validates channel chaining, quantizer-grid consistency
/// at the pooling boundary, and that the time axis survives every
/// dilated layer; `forward_into` then runs without any per-call checks
/// beyond debug asserts.
pub struct QuantGraph {
    stages: Vec<QuantStage>,
    frames: usize,
    n_in: usize,
    classes: usize,
    out_frames: usize,
    plan: Plan,
}

impl QuantGraph {
    /// Validate and seal a stage sequence for inputs of `frames` time
    /// steps. Errors name the offending stage so mis-assembled
    /// architectures fail loudly at build time, not silently at inference.
    pub fn new(stages: Vec<QuantStage>, frames: usize) -> Result<Self> {
        ensure!(frames >= 1, "graph needs at least one input frame");
        ensure!(!stages.is_empty(), "empty stage list");

        // --- grammar + shape chaining -----------------------------------
        let mut it = stages.iter().enumerate().peekable();
        let (n_in, mut channels) = match it.next() {
            Some((_, QuantStage::FpEmbed(e))) => {
                ensure!(e.dim >= 1 && e.n_in >= 1, "degenerate embedding shape");
                ensure!(e.w.len() == e.dim * e.n_in, "embedding weight numel");
                ensure!(
                    e.scale.len() == e.dim && e.shift.len() == e.dim,
                    "embedding BN fold length"
                );
                (e.n_in, e.dim)
            }
            Some((_, s)) => bail!("graph must start with FpEmbed, found {}", s.kind()),
            None => unreachable!(),
        };

        let mut t = frames;
        let mut plan = Plan { codes: channels * t, acc: 0, fa: frames, pooled: 0 };
        let mut n_stacks = 0usize;
        let mut last_grid: Option<QParams> = None;
        while let Some((si, QuantStage::FqConvStack(stack))) =
            it.next_if(|(_, s)| matches!(s, QuantStage::FqConvStack(_)))
        {
            ensure!(!stack.layers.is_empty(), "stage {si}: empty FqConvStack");
            n_stacks += 1;
            for (li, l) in stack.layers.iter().enumerate() {
                ensure!(
                    l.c_in == channels,
                    "stage {si} layer {li}: c_in {} but incoming channels {channels}",
                    l.c_in
                );
                let span = l.dilation * (l.ksize - 1);
                ensure!(
                    t > span,
                    "stage {si} layer {li}: receptive span {span} consumes all {t} \
                     remaining frames"
                );
                t = l.t_out(t);
                channels = l.c_out;
                plan.codes = plan.codes.max(channels * t);
                plan.acc = plan.acc.max(channels * t);
                last_grid = Some(l.out_grid());
            }
        }
        ensure!(n_stacks >= 1, "graph needs at least one FqConvStack");

        match it.next() {
            Some((si, QuantStage::GlobalAvgPool(g))) => {
                ensure!(
                    g.channels == channels,
                    "stage {si}: GlobalAvgPool over {} channels but conv stack \
                     emits {channels}",
                    g.channels
                );
                if let Some(grid) = last_grid {
                    ensure!(
                        g.dq == grid,
                        "stage {si}: GlobalAvgPool dequant grid does not match the \
                         final conv layer's output grid"
                    );
                }
                plan.pooled = g.channels;
            }
            Some((_, s)) => {
                bail!("expected GlobalAvgPool after the conv stack, found {}", s.kind())
            }
            None => bail!("graph ends without GlobalAvgPool + DenseHead"),
        }

        let classes = match it.next() {
            Some((si, QuantStage::DenseHead(h))) => {
                ensure!(
                    h.d_in == channels,
                    "stage {si}: DenseHead d_in {} but pooled features have {channels}",
                    h.d_in
                );
                ensure!(h.w.len() == h.d_in * h.d_out, "head weight numel");
                ensure!(h.b.len() == h.d_out, "head bias length");
                h.d_out
            }
            Some((_, s)) => bail!("expected DenseHead after GlobalAvgPool, found {}", s.kind()),
            None => bail!("graph ends without a DenseHead"),
        };
        if let Some((_, s)) = it.next() {
            bail!("trailing stage after DenseHead: {}", s.kind());
        }

        Ok(QuantGraph { stages, frames, n_in, classes, out_frames: t, plan })
    }

    pub fn stages(&self) -> &[QuantStage] {
        &self.stages
    }

    /// Input time steps per sample.
    pub fn frames(&self) -> usize {
        self.frames
    }

    /// Flattened feature count per sample: `n_in * frames`.
    pub fn in_numel(&self) -> usize {
        self.n_in * self.frames
    }

    /// Input channel count (e.g. MFCC features).
    pub fn n_in(&self) -> usize {
        self.n_in
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Time steps surviving the full conv stack (the GAP width).
    pub fn out_frames(&self) -> usize {
        self.out_frames
    }

    /// The embedding stage (always present in a validated graph).
    pub fn embed(&self) -> &FpEmbed {
        match &self.stages[0] {
            QuantStage::FpEmbed(e) => e,
            _ => unreachable!("validated graph starts with FpEmbed"),
        }
    }

    /// The classifier head (always last in a validated graph).
    pub fn head(&self) -> &DenseHead {
        match self.stages.last() {
            Some(QuantStage::DenseHead(h)) => h,
            _ => unreachable!("validated graph ends with DenseHead"),
        }
    }

    /// All conv layers, in execution order, across every stack stage.
    pub fn conv_layers(&self) -> impl Iterator<Item = &QuantConv1d> {
        self.stages.iter().flat_map(|s| match s {
            QuantStage::FqConvStack(st) => st.layers.as_slice(),
            _ => &[],
        })
    }

    /// The layers of the first conv stack (the whole stack for
    /// single-stack graphs like the KWS facade).
    pub fn first_stack(&self) -> &[QuantConv1d] {
        for s in &self.stages {
            if let QuantStage::FqConvStack(st) = s {
                return &st.layers;
            }
        }
        &[]
    }

    /// Total integer MACs per sample (for the perf accounting).
    pub fn macs_per_sample(&self) -> u64 {
        let mut t = self.frames;
        let mut total = 0u64;
        for l in self.conv_layers() {
            t = l.t_out(t);
            total += (l.c_out * l.c_in * l.ksize * t) as u64;
        }
        total
    }

    /// Allocation-free forward of one sample: f32 features
    /// `(n_in, frames)` → logits in the caller's slice. Every
    /// intermediate lives in `s`; `threads` is the intra-layer budget
    /// handed to the conv kernels (bit-identical at every value).
    pub fn forward_into(&self, x: &[f32], s: &mut Scratch, logits: &mut [f32], threads: usize) {
        debug_assert_eq!(x.len(), self.in_numel(), "feature buffer size");
        assert_eq!(logits.len(), self.classes, "logit buffer size");
        let mut t_cur = self.frames;
        // which ping-pong buffer currently holds the live codes
        let mut cur_in_a = true;
        for stage in &self.stages {
            match stage {
                QuantStage::FpEmbed(e) => {
                    e.forward_into(x, t_cur, &mut s.a, &mut s.fa);
                    cur_in_a = true;
                }
                QuantStage::FqConvStack(stack) => {
                    for l in &stack.layers {
                        let (input, output) =
                            if cur_in_a { (&s.a, &mut s.b) } else { (&s.b, &mut s.a) };
                        l.forward_mt(input, t_cur, &mut s.acc, output, threads);
                        t_cur = l.t_out(t_cur);
                        cur_in_a = !cur_in_a;
                    }
                }
                QuantStage::GlobalAvgPool(g) => {
                    let codes = if cur_in_a { &s.a } else { &s.b };
                    s.pooled.clear();
                    s.pooled.resize(g.channels, 0.0);
                    global_avg_pool_into(codes, g.channels, t_cur, &g.dq, &mut s.pooled);
                }
                QuantStage::DenseHead(h) => h.forward_into(&s.pooled, logits),
            }
        }
    }

    /// Allocating convenience wrapper over [`QuantGraph::forward_into`].
    pub fn forward(&self, x: &[f32], s: &mut Scratch) -> Vec<f32> {
        let mut logits = vec![0f32; self.classes];
        self.forward_into(x, s, &mut logits, 1);
        logits
    }
}

// ---------------------------------------------------------------------------
// Synthetic architectures (offline tests / benches)
// ---------------------------------------------------------------------------

/// A synthetic architecture description: enough to instantiate a full
/// [`QuantGraph`] with deterministic random parameters and no artifacts.
pub struct SynthArch {
    pub name: &'static str,
    pub n_in: usize,
    pub frames: usize,
    pub embed_dim: usize,
    pub classes: usize,
    /// per conv layer: (c_out, ksize, dilation)
    pub convs: Vec<(usize, usize, usize)>,
}

impl SynthArch {
    /// The paper's KWS temporal-conv net: 39 MFCC x 80 frames, 32-wide,
    /// seven ksize-3 layers with the [1, 1, 2, 4, 8, 8, 8] schedule.
    pub fn kws() -> Self {
        SynthArch {
            name: "kws",
            n_in: 39,
            frames: 80,
            embed_dim: 32,
            classes: 12,
            convs: [1usize, 1, 2, 4, 8, 8, 8].iter().map(|&d| (32, 3, d)).collect(),
        }
    }

    /// A deeper/wider second architecture with a different dilation
    /// schedule (two stacked pyramids reaching dilation 16) — exists to
    /// prove the graph API generalizes beyond the KWS monolith.
    pub fn deep_wide() -> Self {
        SynthArch {
            name: "deep-wide",
            n_in: 39,
            frames: 160,
            embed_dim: 48,
            classes: 12,
            convs: [1usize, 2, 4, 8, 16, 1, 2, 4, 8, 16].iter().map(|&d| (48, 3, d)).collect(),
        }
    }
}

/// Build a [`QuantGraph`] for `arch` with deterministic Gaussian
/// parameters (seeded) — no artifacts or XLA needed. `nw`/`na` are the
/// weight/activation level counts (nw = 1 takes the ternary path).
pub fn synthetic_graph(arch: &SynthArch, nw: f32, na: f32, seed: u64) -> Result<QuantGraph> {
    ensure!(!arch.convs.is_empty(), "architecture has no conv layers");
    let mut rng = Rng::new(seed ^ 0x9A_D06_C0DE);
    let dim = arch.embed_dim;

    let mut ew = vec![0f32; dim * arch.n_in];
    rng.fill_gaussian(&mut ew, 0.5);
    // unit BN fold (gamma = var = 1, beta = mean = 0), unit quant scales
    // — mirrors FqKwsNet::synthetic's parameterization
    let qa0 = QParams::new(1.0, na, -1.0);
    let embed = FpEmbed {
        w: ew,
        scale: vec![1.0; dim],
        shift: vec![0.0; dim],
        es: 1.0,
        na,
        out_q: qa0,
        n_in: arch.n_in,
        dim,
    };

    let mut layers = Vec::with_capacity(arch.convs.len());
    let mut c_in = dim;
    for (i, &(c_out, ksize, dilation)) in arch.convs.iter().enumerate() {
        let mut w = vec![0f32; c_out * c_in * ksize];
        rng.fill_gaussian(&mut w, 0.5);
        let ba = if i == 0 { -1.0 } else { 0.0 };
        let qa = QParams::new(1.0, na, ba);
        let qw = QParams::new(1.0, nw, -1.0);
        let mid = QParams::new(1.0, na, 0.0);
        let next = if i + 1 < arch.convs.len() { Some(QParams::new(1.0, na, 0.0)) } else { None };
        layers.push(QuantConv1d::new(&w, c_out, c_in, ksize, dilation, qa, qw, mid, next));
        c_in = c_out;
    }
    let filters = c_in;
    let gap = GlobalAvgPool { channels: filters, dq: layers.last().unwrap().out_grid() };

    let mut hw = vec![0f32; filters * arch.classes];
    rng.fill_gaussian(&mut hw, 0.5);
    let head =
        DenseHead { w: hw, b: vec![0.0; arch.classes], d_in: filters, d_out: arch.classes };

    QuantGraph::new(
        vec![
            QuantStage::FpEmbed(embed),
            QuantStage::FqConvStack(FqConvStack { layers }),
            QuantStage::GlobalAvgPool(gap),
            QuantStage::DenseHead(head),
        ],
        arch.frames,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_arch() -> SynthArch {
        SynthArch {
            name: "tiny",
            n_in: 3,
            frames: 12,
            embed_dim: 4,
            classes: 2,
            convs: vec![(4, 3, 1), (5, 3, 2)],
        }
    }

    #[test]
    fn builds_and_plans_a_tiny_graph() {
        let g = synthetic_graph(&tiny_arch(), 1.0, 7.0, 3).expect("tiny graph");
        assert_eq!(g.frames(), 12);
        assert_eq!(g.in_numel(), 36);
        assert_eq!(g.classes(), 2);
        // t: 12 -> 10 -> 6
        assert_eq!(g.out_frames(), 6);
        assert_eq!(g.first_stack().len(), 2);
        assert!(g.macs_per_sample() > 0);
        let mut s = Scratch::for_graph(&g);
        let x = vec![0.25f32; g.in_numel()];
        let logits = g.forward(&x, &mut s);
        assert_eq!(logits.len(), 2);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rejects_missing_conv_stack() {
        let good = synthetic_graph(&tiny_arch(), 1.0, 7.0, 3).unwrap();
        let mut stages = good.stages;
        // drop the conv stack entirely: the grammar check must fire
        stages.remove(1);
        let err = QuantGraph::new(stages, 12).unwrap_err().to_string();
        assert!(err.contains("FqConvStack"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_time_axis_collapse() {
        let mut arch = tiny_arch();
        arch.frames = 5; // 5 - 2 = 3, then 3 - 4: receptive span too wide
        let err = synthetic_graph(&arch, 1.0, 7.0, 3).unwrap_err().to_string();
        assert!(err.contains("receptive span"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_misordered_stages() {
        let good = synthetic_graph(&tiny_arch(), 1.0, 7.0, 3).unwrap();
        let mut stages = good.stages;
        stages.swap(2, 3); // head before GAP
        let err = QuantGraph::new(stages, 12).unwrap_err().to_string();
        assert!(err.contains("GlobalAvgPool"), "unexpected error: {err}");
    }

    #[test]
    fn forward_bit_identical_across_thread_budgets() {
        let g = synthetic_graph(&SynthArch::deep_wide(), 1.0, 7.0, 11).expect("deep-wide");
        let mut rng = Rng::new(5);
        let mut x = vec![0f32; g.in_numel()];
        rng.fill_gaussian(&mut x, 1.0);
        let mut s = Scratch::for_graph(&g);
        let want = g.forward(&x, &mut s);
        for threads in [2usize, 4, 8] {
            let mut logits = vec![0f32; g.classes()];
            g.forward_into(&x, &mut s, &mut logits, threads);
            assert_eq!(logits, want, "threads={threads}");
        }
    }
}
