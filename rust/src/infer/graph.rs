//! Composable quantized model graph — the engine's architecture seam.
//!
//! The paper's deployment pipeline (full-precision embedding → integer
//! FQ-Conv stack → higher-precision global average pooling → dense head)
//! used to be hardwired into one monolithic network struct. Survey work
//! on integer inference (Krishnamoorthi 2018; Nagel et al. 2021) frames
//! a quantized model instead as a *graph of requantizing ops with
//! per-tensor scale metadata*; this module is that abstraction:
//!
//! * [`QuantStage`] — the typed stages a fully-quantized network is
//!   composed of. Sequence (1-D) nets use [`FpEmbed`] (f32 features →
//!   input codes), [`FqConvStack`] (integer codes → integer codes,
//!   ping-pong); image (2-D, NCHW) nets use [`QuantStem2d`] (f32 pixels
//!   → input codes on the first conv's grid), [`FqConv2dStack`] and
//!   [`Residual`] (integer skip-add through an exact
//!   [`crate::quant::AddLut`], optional strided 1x1 projection on the
//!   shortcut). Both families share [`GlobalAvgPool`] (codes → f32
//!   features, i64 higher-precision sum over time steps *or* spatial
//!   positions) and [`DenseHead`] (f32 features → logits).
//! * [`QuantGraph`] — owns stage sequencing, shape/grid validation,
//!   ping-pong code-buffer planning and scratch sizing, and exposes an
//!   allocation-free [`QuantGraph::forward_into`]. Every architecture
//!   the paper evaluates (the KWS TCN, ResNet-32, DarkNet-19) is a
//!   different stage list over the same bit-exact kernels.
//!
//! Accepted stage grammars (validated at build time, by constructor):
//!
//! ```text
//! QuantGraph::new    (1-D):  FpEmbed     FqConvStack+                GlobalAvgPool DenseHead
//! QuantGraph::new_2d (2-D):  QuantStem2d (FqConv2dStack | Residual)+ GlobalAvgPool DenseHead
//! ```
//!
//! A 2-D [`Residual`] block is the integer form of the classic ResNet
//! basic block (see [`super::resnet`] for ResNet-32 assembled on this
//! grammar):
//!
//! ```text
//!        codes (c_in, h, w) on grid G_in
//!          |------------------------------.
//!   FQ-Conv2d (3x3, maybe strided)        |  identity           (c_in == c_out)
//!   FQ-Conv2d (3x3)                       |  or FQ-Conv2d 1x1   (strided / widening
//!          |                              |                      projection)
//!        body codes on grid G_a     shortcut codes on grid G_b
//!          `-----------> AddLut <---------'
//!              out[i] = Q_out(deq_a(body[i]) + deq_b(skip[i]))
//!                 (one exact 2-D table load per element)
//! ```
//!
//! [`crate::infer::FqKwsNet`] is now a thin constructor facade over a
//! `QuantGraph`; [`synthetic_graph`] instantiates arbitrary
//! [`SynthArch`] descriptions — the KWS TCN, the deeper/wider
//! [`SynthArch::deep_wide`], and the 2-D residual
//! [`SynthArch::resnet32`] — on the same API, which is how
//! rust/tests/graph.rs proves the graph generalizes beyond KWS.
//!
//! **Determinism contract:** stage bodies are the exact loops the
//! monolithic pipeline ran — same float accumulation order, same integer
//! instruction sequence — so a graph-built network is bit-identical to
//! the pre-refactor pipeline at every thread count (rust/tests/graph.rs,
//! rust/tests/parallel.rs); the 2-D stages inherit the contract from
//! the contiguous-disjoint-row partitioning of [`crate::exec`].

use anyhow::{bail, ensure, Result};

use crate::quant::{learned_quantize, AddLut, QParams};
use crate::util::Rng;

use super::conv::QuantConv1d;
use super::conv2d::QuantConv2d;

// ---------------------------------------------------------------------------
// Scratch
// ---------------------------------------------------------------------------

/// Reusable per-thread scratch buffers (the hot path is allocation-free
/// in steady state). Each worker of a data-parallel batch owns one.
/// [`Scratch::for_graph`] pre-sizes every buffer from the graph's plan
/// so even the *first* forward allocates nothing.
#[derive(Default)]
pub struct Scratch {
    /// i32 conv accumulators, (c_out, t_out) of the current layer
    pub(crate) acc: Vec<i32>,
    /// ping-pong i8 code buffers
    pub(crate) a: Vec<i8>,
    pub(crate) b: Vec<i8>,
    /// residual shortcut codes, held while the block body ping-pongs
    pub(crate) skip: Vec<i8>,
    /// float accumulator row for the embedding's streaming dot products
    pub(crate) fa: Vec<f32>,
    /// pooled features, reused so the GAP + head path never allocates
    pub(crate) pooled: Vec<f32>,
}

impl Scratch {
    /// Scratch with every buffer pre-reserved to the graph's plan.
    pub fn for_graph(g: &QuantGraph) -> Self {
        let p = &g.plan;
        Scratch {
            acc: Vec::with_capacity(p.acc),
            a: Vec::with_capacity(p.codes),
            b: Vec::with_capacity(p.codes),
            skip: Vec::with_capacity(p.skip),
            fa: Vec::with_capacity(p.fa),
            pooled: Vec::with_capacity(p.pooled),
        }
    }

    /// Current buffer capacities `(acc, a, b, skip, fa, pooled)` — lets
    /// tests pin that a pre-planned scratch never reallocates on the
    /// hot path.
    pub fn capacities(&self) -> (usize, usize, usize, usize, usize, usize) {
        (
            self.acc.capacity(),
            self.a.capacity(),
            self.b.capacity(),
            self.skip.capacity(),
            self.fa.capacity(),
            self.pooled.capacity(),
        )
    }

    /// One 2-D conv layer step of the graph walk: ping-pong buffer
    /// select, conv + fused requant, spatial bookkeeping. Shared by the
    /// plain-stack and residual-body loops so their bookkeeping cannot
    /// diverge.
    fn conv2d_step(
        &mut self,
        l: &QuantConv2d,
        h_cur: &mut usize,
        w_cur: &mut usize,
        cur_in_a: &mut bool,
        threads: usize,
    ) {
        let (input, output) =
            if *cur_in_a { (&self.a, &mut self.b) } else { (&self.b, &mut self.a) };
        l.forward_mt(input, *h_cur, *w_cur, &mut self.acc, output, threads);
        let (h2, w2) = l.out_hw(*h_cur, *w_cur);
        *h_cur = h2;
        *w_cur = w2;
        *cur_in_a = !*cur_in_a;
    }
}

// ---------------------------------------------------------------------------
// Stages
// ---------------------------------------------------------------------------

/// Full-precision 1x1 embedding + inference-mode (folded) BN + learned
/// input quantizer: f32 features `(n_in, T)` → i8 codes `(dim, T)` on
/// the first conv layer's input grid (`out_q`).
pub struct FpEmbed {
    /// (dim, n_in) projection weights
    pub w: Vec<f32>,
    /// folded eval-mode BN: y = x * scale + shift, per output channel
    pub scale: Vec<f32>,
    pub shift: Vec<f32>,
    /// e^{sa}: the learned input quantizer scale of the quantized stack
    pub es: f32,
    /// activation level count of the quantized stack
    pub na: f32,
    /// the first conv layer's input grid (codes are emitted on it)
    pub out_q: QParams,
    pub n_in: usize,
    pub dim: usize,
}

impl FpEmbed {
    /// Embed one sample into `codes` (resized to `dim * t_in`), using
    /// `fa` as the reusable float accumulator row.
    ///
    /// Streamed as per-channel axpy rows: for each output channel the
    /// t-axis accumulator row is contiguous and every input row is
    /// contiguous, so the inner loops vectorize; the per-(k,t) f32
    /// addition order over c is unchanged from the naive triple loop,
    /// keeping the embedding bit-identical to the float reference.
    pub fn forward_into(&self, x: &[f32], t_in: usize, codes: &mut Vec<i8>, fa: &mut Vec<f32>) {
        debug_assert_eq!(x.len(), self.n_in * t_in);
        codes.clear();
        codes.resize(self.dim * t_in, 0);
        fa.clear();
        fa.resize(t_in, 0.0);
        for k in 0..self.dim {
            let wrow = &self.w[k * self.n_in..(k + 1) * self.n_in];
            let facc = &mut fa[..t_in];
            facc.fill(0.0);
            for (c, &wc) in wrow.iter().enumerate() {
                let xrow = &x[c * t_in..(c + 1) * t_in];
                for (av, &xv) in facc.iter_mut().zip(xrow) {
                    *av += wc * xv;
                }
            }
            let (sc, sh) = (self.scale[k], self.shift[k]);
            let crow = &mut codes[k * t_in..(k + 1) * t_in];
            for (o, &av) in crow.iter_mut().zip(facc.iter()) {
                let bn = av * sc + sh;
                // two-step: Q_{sa}(b=-1) then the first conv's input bin
                let q = learned_quantize(bn, self.es, self.na, -1.0);
                *o = self.out_q.int_code(q) as i8;
            }
        }
    }
}

/// A run of integer FQ-Conv layers. Codes ping-pong between the two
/// scratch buffers; each layer re-bins into the next layer's input grid
/// through its fused requant LUT.
pub struct FqConvStack {
    pub layers: Vec<QuantConv1d>,
}

/// Higher-precision global average pooling: i8 codes `(channels, t)` →
/// f32 features `(channels,)`, summing in i64 so an arbitrarily long
/// time axis cannot silently truncate (see [`QParams::dequantize_i64`]).
pub struct GlobalAvgPool {
    pub channels: usize,
    /// the final conv grid the codes live on
    pub dq: QParams,
}

/// Full-precision dense classifier head on pooled features.
pub struct DenseHead {
    /// (d_in, d_out) weights
    pub w: Vec<f32>,
    pub b: Vec<f32>,
    pub d_in: usize,
    pub d_out: usize,
}

impl DenseHead {
    /// Pooled features → logits, into a caller-owned buffer (the hot
    /// path routes this through [`Scratch`] so no per-sample `Vec` is
    /// allocated — including no clone of the bias row).
    pub fn forward_into(&self, pooled: &[f32], logits: &mut [f32]) {
        debug_assert_eq!(pooled.len(), self.d_in);
        debug_assert_eq!(logits.len(), self.d_out);
        logits.copy_from_slice(&self.b);
        for (k, &p) in pooled.iter().enumerate() {
            let w = &self.w[k * self.d_out..(k + 1) * self.d_out];
            for (l, &wj) in logits.iter_mut().zip(w) {
                *l += p * wj;
            }
        }
    }
}

/// Learned input quantizer for image (NCHW) networks: f32 pixels
/// `(c_in, h, w)` → i8 codes on the first conv layer's input grid —
/// the 2-D analogue of [`FpEmbed`]'s trailing quantization step (ResNet
/// and DarkNet have no full-precision embedding; their first conv is
/// itself quantized).
pub struct QuantStem2d {
    /// input channels (e.g. 3 RGB planes)
    pub c_in: usize,
    /// the first conv layer's input grid (codes are emitted on it)
    pub out_q: QParams,
}

impl QuantStem2d {
    /// Quantize one sample into `codes` (resized to `x.len()`).
    pub fn forward_into(&self, x: &[f32], codes: &mut Vec<i8>) {
        codes.clear();
        codes.reserve(x.len());
        for &v in x {
            codes.push(self.out_q.int_code(v) as i8);
        }
    }
}

/// A run of integer 2-D FQ-Conv layers. Codes ping-pong between the
/// two scratch buffers, exactly like the 1-D stack.
pub struct FqConv2dStack {
    pub layers: Vec<QuantConv2d>,
}

/// Integer residual block: a conv body, an optional shortcut
/// projection, and an exact tabulated skip-add (see the module doc for
/// the block diagram). The join is `out[i] = add.apply(body[i],
/// skip[i])` — one branchless 2-D table load per element, no float
/// scale on the hot path.
pub struct Residual {
    /// the block body (e.g. two 3x3 convs; the first may be strided)
    pub body: Vec<QuantConv2d>,
    /// optional shortcut projection (1x1, possibly strided) for blocks
    /// that change channel count or spatial extent; None = identity
    pub down: Option<QuantConv2d>,
    /// the integer skip-add: `a` must be the body's output grid, `b`
    /// the shortcut's grid; `out` is the consumer's input grid
    pub add: AddLut,
}

/// One typed stage of a fully-quantized inference graph.
pub enum QuantStage {
    FpEmbed(FpEmbed),
    FqConvStack(FqConvStack),
    QuantStem2d(QuantStem2d),
    FqConv2dStack(FqConv2dStack),
    Residual(Residual),
    GlobalAvgPool(GlobalAvgPool),
    DenseHead(DenseHead),
}

impl QuantStage {
    fn kind(&self) -> &'static str {
        match self {
            QuantStage::FpEmbed(_) => "FpEmbed",
            QuantStage::FqConvStack(_) => "FqConvStack",
            QuantStage::QuantStem2d(_) => "QuantStem2d",
            QuantStage::FqConv2dStack(_) => "FqConv2dStack",
            QuantStage::Residual(_) => "Residual",
            QuantStage::GlobalAvgPool(_) => "GlobalAvgPool",
            QuantStage::DenseHead(_) => "DenseHead",
        }
    }
}

// ---------------------------------------------------------------------------
// Higher-precision GAP kernel (stage body, shared with the facade)
// ---------------------------------------------------------------------------

/// Higher-precision global average pooling over final-grid codes
/// (channels, t_cur): the sum runs in i64 so an arbitrarily long time
/// axis cannot silently truncate (an i8-code sum overflows i32 once
/// t_cur exceeds ~2^24 — see [`QParams::dequantize_i64`]).
pub fn global_avg_pool_into(
    codes: &[i8],
    channels: usize,
    t_cur: usize,
    dq: &QParams,
    pooled: &mut [f32],
) {
    debug_assert_eq!(codes.len(), channels * t_cur);
    debug_assert_eq!(pooled.len(), channels);
    for (k, p) in pooled.iter_mut().enumerate() {
        let mut sum = 0i64;
        for t in 0..t_cur {
            sum += codes[k * t_cur + t] as i64;
        }
        *p = dq.dequantize_i64(sum) / t_cur as f32;
    }
}

/// Allocating convenience wrapper over [`global_avg_pool_into`].
pub fn global_avg_pool(codes: &[i8], channels: usize, t_cur: usize, dq: &QParams) -> Vec<f32> {
    let mut pooled = vec![0f32; channels];
    global_avg_pool_into(codes, channels, t_cur, dq, &mut pooled);
    pooled
}

// ---------------------------------------------------------------------------
// Graph
// ---------------------------------------------------------------------------

/// Peak buffer sizes of one forward pass, computed once at build time so
/// [`Scratch::for_graph`] can pre-reserve everything.
#[derive(Clone, Copy, Debug, Default)]
struct Plan {
    /// max i8 code-buffer numel at any stage boundary (ping-pong size)
    codes: usize,
    /// max i32 accumulator numel across conv layers
    acc: usize,
    /// max residual shortcut numel (0 for graphs without residuals)
    skip: usize,
    /// float accumulator row length (embedding)
    fa: usize,
    /// pooled feature length
    pooled: usize,
}

/// A validated, executable sequence of [`QuantStage`]s.
///
/// Two grammars are accepted, one per constructor (see the module doc):
/// [`QuantGraph::new`] seals the 1-D sequence shape `FpEmbed
/// FqConvStack+ GlobalAvgPool DenseHead`; [`QuantGraph::new_2d`] seals
/// the image shape `QuantStem2d (FqConv2dStack | Residual)+
/// GlobalAvgPool DenseHead`. Construction validates channel/spatial
/// chaining, quantizer-grid consistency at the residual joins and the
/// pooling boundary, and that the time axis survives every dilated
/// layer; `forward_into` then runs without any per-call checks beyond
/// debug asserts.
pub struct QuantGraph {
    stages: Vec<QuantStage>,
    /// per-sample input shape: `[n_in, frames]` for sequence graphs,
    /// `[c, h, w]` for image graphs
    in_shape: Vec<usize>,
    classes: usize,
    /// positions the GAP stage averages over (surviving time steps for
    /// sequences, `h*w` for images)
    out_frames: usize,
    plan: Plan,
}

/// True for the stage kinds the 2-D validator's conv loop accepts.
fn is_2d_conv_stage(s: &QuantStage) -> bool {
    matches!(s, QuantStage::FqConv2dStack(_) | QuantStage::Residual(_))
}

/// Shared tail validation for both grammars: a [`GlobalAvgPool`]
/// matching the conv stages' channels and output grid, then a
/// [`DenseHead`], then end of list. Returns the class count.
fn validate_tail<'a, I>(
    it: &mut I,
    channels: usize,
    last_grid: Option<QParams>,
    plan: &mut Plan,
) -> Result<usize>
where
    I: Iterator<Item = (usize, &'a QuantStage)>,
{
    match it.next() {
        Some((si, QuantStage::GlobalAvgPool(g))) => {
            ensure!(
                g.channels == channels,
                "stage {si}: GlobalAvgPool over {} channels but the conv stages \
                 emit {channels}",
                g.channels
            );
            if let Some(grid) = last_grid {
                ensure!(
                    g.dq == grid,
                    "stage {si}: GlobalAvgPool dequant grid does not match the final \
                     conv stage's output grid"
                );
            }
            plan.pooled = g.channels;
        }
        Some((_, s)) => bail!("expected GlobalAvgPool after the conv stages, found {}", s.kind()),
        None => bail!("graph ends without GlobalAvgPool + DenseHead"),
    }
    let classes = match it.next() {
        Some((si, QuantStage::DenseHead(h))) => {
            ensure!(
                h.d_in == channels,
                "stage {si}: DenseHead d_in {} but pooled features have {channels}",
                h.d_in
            );
            ensure!(h.w.len() == h.d_in * h.d_out, "head weight numel");
            ensure!(h.b.len() == h.d_out, "head bias length");
            h.d_out
        }
        Some((_, s)) => bail!("expected DenseHead after GlobalAvgPool, found {}", s.kind()),
        None => bail!("graph ends without a DenseHead"),
    };
    if let Some((_, s)) = it.next() {
        bail!("trailing stage after DenseHead: {}", s.kind());
    }
    Ok(classes)
}

/// Shared per-conv bookkeeping for the 2-D validator: channel/spatial
/// chaining plus buffer planning; returns the layer's output grid.
fn chain_conv2d(
    l: &QuantConv2d,
    si: usize,
    li: &str,
    channels: &mut usize,
    hc: &mut usize,
    wc: &mut usize,
    plan: &mut Plan,
) -> Result<QParams> {
    ensure!(
        l.c_in == *channels,
        "stage {si} layer {li}: c_in {} but incoming channels {channels}",
        l.c_in
    );
    ensure!(
        *hc + 2 * l.pad >= l.ksize && *wc + 2 * l.pad >= l.ksize,
        "stage {si} layer {li}: {}x{} kernel (pad {}) consumes the whole {hc}x{wc} extent",
        l.ksize,
        l.ksize,
        l.pad
    );
    let (h2, w2) = l.out_hw(*hc, *wc);
    ensure!(h2 >= 1 && w2 >= 1, "stage {si} layer {li}: empty output extent");
    *hc = h2;
    *wc = w2;
    *channels = l.c_out;
    plan.codes = plan.codes.max(l.c_out * h2 * w2);
    plan.acc = plan.acc.max(l.c_out * h2 * w2);
    Ok(l.out_grid())
}

impl QuantGraph {
    /// Validate and seal a stage sequence for inputs of `frames` time
    /// steps. Errors name the offending stage so mis-assembled
    /// architectures fail loudly at build time, not silently at inference.
    pub fn new(stages: Vec<QuantStage>, frames: usize) -> Result<Self> {
        ensure!(frames >= 1, "graph needs at least one input frame");
        ensure!(!stages.is_empty(), "empty stage list");

        // --- grammar + shape chaining -----------------------------------
        let mut it = stages.iter().enumerate().peekable();
        let (n_in, mut channels) = match it.next() {
            Some((_, QuantStage::FpEmbed(e))) => {
                ensure!(e.dim >= 1 && e.n_in >= 1, "degenerate embedding shape");
                ensure!(e.w.len() == e.dim * e.n_in, "embedding weight numel");
                ensure!(
                    e.scale.len() == e.dim && e.shift.len() == e.dim,
                    "embedding BN fold length"
                );
                (e.n_in, e.dim)
            }
            Some((_, s)) => bail!("graph must start with FpEmbed, found {}", s.kind()),
            None => unreachable!(),
        };

        let mut t = frames;
        let mut plan = Plan { codes: channels * t, acc: 0, skip: 0, fa: frames, pooled: 0 };
        let mut n_stacks = 0usize;
        let mut last_grid: Option<QParams> = None;
        while let Some((si, QuantStage::FqConvStack(stack))) =
            it.next_if(|(_, s)| matches!(s, QuantStage::FqConvStack(_)))
        {
            ensure!(!stack.layers.is_empty(), "stage {si}: empty FqConvStack");
            n_stacks += 1;
            for (li, l) in stack.layers.iter().enumerate() {
                ensure!(
                    l.c_in == channels,
                    "stage {si} layer {li}: c_in {} but incoming channels {channels}",
                    l.c_in
                );
                let span = l.dilation * (l.ksize - 1);
                ensure!(
                    t > span,
                    "stage {si} layer {li}: receptive span {span} consumes all {t} \
                     remaining frames"
                );
                t = l.t_out(t);
                channels = l.c_out;
                plan.codes = plan.codes.max(channels * t);
                plan.acc = plan.acc.max(channels * t);
                last_grid = Some(l.out_grid());
            }
        }
        ensure!(n_stacks >= 1, "graph needs at least one FqConvStack");
        let classes = validate_tail(&mut it, channels, last_grid, &mut plan)?;

        Ok(QuantGraph { stages, in_shape: vec![n_in, frames], classes, out_frames: t, plan })
    }

    /// Validate and seal a 2-D (NCHW image) stage sequence for inputs
    /// of `h x w` pixels. Grammar: `QuantStem2d (FqConv2dStack |
    /// Residual)+ GlobalAvgPool DenseHead`. Errors name the offending
    /// stage so mis-assembled architectures fail loudly at build time.
    pub fn new_2d(stages: Vec<QuantStage>, h: usize, w: usize) -> Result<Self> {
        ensure!(h >= 1 && w >= 1, "graph needs a non-empty input image");
        ensure!(!stages.is_empty(), "empty stage list");

        let mut it = stages.iter().enumerate().peekable();
        let (c_in, mut grid) = match it.next() {
            Some((_, QuantStage::QuantStem2d(s))) => {
                ensure!(s.c_in >= 1, "degenerate stem channel count");
                (s.c_in, s.out_q)
            }
            Some((_, s)) => bail!("2-D graph must start with QuantStem2d, found {}", s.kind()),
            None => unreachable!(),
        };

        let (mut channels, mut hc, mut wc) = (c_in, h, w);
        let mut plan = Plan { codes: channels * hc * wc, acc: 0, skip: 0, fa: 0, pooled: 0 };
        let mut n_stacks = 0usize;

        while let Some((si, stage)) = it.next_if(|(_, s)| is_2d_conv_stage(s)) {
            n_stacks += 1;
            match stage {
                QuantStage::FqConv2dStack(stack) => {
                    ensure!(!stack.layers.is_empty(), "stage {si}: empty FqConv2dStack");
                    for (li, l) in stack.layers.iter().enumerate() {
                        grid = chain_conv2d(
                            l,
                            si,
                            &li.to_string(),
                            &mut channels,
                            &mut hc,
                            &mut wc,
                            &mut plan,
                        )?;
                    }
                }
                QuantStage::Residual(r) => {
                    ensure!(!r.body.is_empty(), "stage {si}: residual block without a body");
                    let (in_ch, in_h, in_w, in_grid) = (channels, hc, wc, grid);
                    for (li, l) in r.body.iter().enumerate() {
                        grid = chain_conv2d(
                            l,
                            si,
                            &format!("body.{li}"),
                            &mut channels,
                            &mut hc,
                            &mut wc,
                            &mut plan,
                        )?;
                    }
                    let skip_grid = match &r.down {
                        Some(d) => {
                            let (mut dc, mut dh, mut dw) = (in_ch, in_h, in_w);
                            let g =
                                chain_conv2d(d, si, "down", &mut dc, &mut dh, &mut dw, &mut plan)?;
                            ensure!(
                                dc == channels && dh == hc && dw == wc,
                                "stage {si}: shortcut projection emits {dc}x{dh}x{dw} but \
                                 the body emits {channels}x{hc}x{wc}"
                            );
                            g
                        }
                        None => {
                            ensure!(
                                in_ch == channels && in_h == hc && in_w == wc,
                                "stage {si}: identity shortcut needs matching shapes \
                                 ({in_ch}x{in_h}x{in_w} in, {channels}x{hc}x{wc} out) — \
                                 add a projection"
                            );
                            in_grid
                        }
                    };
                    ensure!(
                        r.add.a == grid,
                        "stage {si}: AddLut body grid does not match the body's output grid"
                    );
                    ensure!(
                        r.add.b == skip_grid,
                        "stage {si}: AddLut shortcut grid does not match the shortcut's grid"
                    );
                    plan.skip = plan.skip.max(in_ch * in_h * in_w).max(channels * hc * wc);
                    grid = r.add.out;
                }
                _ => unreachable!("next_if matched conv2d stage kinds"),
            }
        }
        ensure!(n_stacks >= 1, "2-D graph needs at least one FqConv2dStack or Residual");
        let classes = validate_tail(&mut it, channels, Some(grid), &mut plan)?;

        Ok(QuantGraph { stages, in_shape: vec![c_in, h, w], classes, out_frames: hc * wc, plan })
    }

    pub fn stages(&self) -> &[QuantStage] {
        &self.stages
    }

    /// Per-sample input shape: `[n_in, frames]` for sequence graphs,
    /// `[c, h, w]` for image graphs (what a serving backend reports as
    /// its sample shape).
    pub fn in_shape(&self) -> &[usize] {
        &self.in_shape
    }

    /// Input time steps per sample (sequence graphs) / spatial
    /// positions per sample (image graphs).
    pub fn frames(&self) -> usize {
        self.in_shape[1..].iter().product()
    }

    /// Flattened feature count per sample.
    pub fn in_numel(&self) -> usize {
        self.in_shape.iter().product()
    }

    /// Input channel count (MFCC features / image planes).
    pub fn n_in(&self) -> usize {
        self.in_shape[0]
    }

    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Time steps surviving the full conv stack (the GAP width).
    pub fn out_frames(&self) -> usize {
        self.out_frames
    }

    /// The embedding stage (always present in a validated graph).
    pub fn embed(&self) -> &FpEmbed {
        match &self.stages[0] {
            QuantStage::FpEmbed(e) => e,
            _ => unreachable!("validated graph starts with FpEmbed"),
        }
    }

    /// The classifier head (always last in a validated graph).
    pub fn head(&self) -> &DenseHead {
        match self.stages.last() {
            Some(QuantStage::DenseHead(h)) => h,
            _ => unreachable!("validated graph ends with DenseHead"),
        }
    }

    /// All conv layers, in execution order, across every stack stage.
    pub fn conv_layers(&self) -> impl Iterator<Item = &QuantConv1d> {
        self.stages.iter().flat_map(|s| match s {
            QuantStage::FqConvStack(st) => st.layers.as_slice(),
            _ => &[],
        })
    }

    /// The layers of the first conv stack (the whole stack for
    /// single-stack graphs like the KWS facade).
    pub fn first_stack(&self) -> &[QuantConv1d] {
        for s in &self.stages {
            if let QuantStage::FqConvStack(st) = s {
                return &st.layers;
            }
        }
        &[]
    }

    /// Total integer MACs per sample (for the perf accounting).
    pub fn macs_per_sample(&self) -> u64 {
        if self.in_shape.len() == 3 {
            return self.macs_2d();
        }
        let mut t = self.frames();
        let mut total = 0u64;
        for l in self.conv_layers() {
            t = l.t_out(t);
            total += (l.c_out * l.c_in * l.ksize * t) as u64;
        }
        total
    }

    /// MAC accounting for image graphs: walk the spatial extent through
    /// every conv stage (residual bodies + shortcut projections).
    fn macs_2d(&self) -> u64 {
        let (mut h, mut w) = (self.in_shape[1], self.in_shape[2]);
        let mut total = 0u64;
        for stage in &self.stages {
            match stage {
                QuantStage::FqConv2dStack(st) => {
                    for l in &st.layers {
                        let (h2, w2) = l.out_hw(h, w);
                        total += l.macs(h2, w2);
                        h = h2;
                        w = w2;
                    }
                }
                QuantStage::Residual(r) => {
                    let (ih, iw) = (h, w);
                    for l in &r.body {
                        let (h2, w2) = l.out_hw(h, w);
                        total += l.macs(h2, w2);
                        h = h2;
                        w = w2;
                    }
                    if let Some(d) = &r.down {
                        let (dh, dw) = d.out_hw(ih, iw);
                        total += d.macs(dh, dw);
                    }
                }
                _ => {}
            }
        }
        total
    }

    /// All 2-D conv layers, in execution order — a block's shortcut
    /// projection runs (and is yielded) before its body, matching the
    /// forward walk, which stashes the shortcut first. Empty for
    /// sequence graphs.
    pub fn conv2d_layers(&self) -> impl Iterator<Item = &QuantConv2d> {
        self.stages.iter().flat_map(|s| {
            let (down, body) = match s {
                QuantStage::FqConv2dStack(st) => (None, st.layers.as_slice()),
                QuantStage::Residual(r) => (r.down.as_ref(), r.body.as_slice()),
                _ => (None, &[][..]),
            };
            down.into_iter().chain(body)
        })
    }

    /// Allocation-free forward of one sample: f32 features
    /// `(n_in, frames)` → logits in the caller's slice. Every
    /// intermediate lives in `s`; `threads` is the intra-layer budget
    /// handed to the conv kernels (bit-identical at every value).
    pub fn forward_into(&self, x: &[f32], s: &mut Scratch, logits: &mut [f32], threads: usize) {
        debug_assert_eq!(x.len(), self.in_numel(), "feature buffer size");
        assert_eq!(logits.len(), self.classes, "logit buffer size");
        // current extent: time steps for sequence stages; (h, w) for
        // image stages (GAP derives its pooled width from whichever
        // family the graph belongs to)
        let mut t_cur = self.frames();
        let (mut h_cur, mut w_cur) = match self.in_shape.len() {
            3 => (self.in_shape[1], self.in_shape[2]),
            _ => (0, 0),
        };
        // which ping-pong buffer currently holds the live codes
        let mut cur_in_a = true;
        for stage in &self.stages {
            match stage {
                QuantStage::FpEmbed(e) => {
                    e.forward_into(x, t_cur, &mut s.a, &mut s.fa);
                    cur_in_a = true;
                }
                QuantStage::FqConvStack(stack) => {
                    for l in &stack.layers {
                        let (input, output) =
                            if cur_in_a { (&s.a, &mut s.b) } else { (&s.b, &mut s.a) };
                        l.forward_mt(input, t_cur, &mut s.acc, output, threads);
                        t_cur = l.t_out(t_cur);
                        cur_in_a = !cur_in_a;
                    }
                }
                QuantStage::QuantStem2d(st) => {
                    st.forward_into(x, &mut s.a);
                    cur_in_a = true;
                }
                QuantStage::FqConv2dStack(stack) => {
                    for l in &stack.layers {
                        s.conv2d_step(l, &mut h_cur, &mut w_cur, &mut cur_in_a, threads);
                    }
                }
                QuantStage::Residual(r) => {
                    // stash the shortcut (identity copy or projection)
                    {
                        let input: &Vec<i8> = if cur_in_a { &s.a } else { &s.b };
                        if let Some(d) = &r.down {
                            d.forward_mt(input, h_cur, w_cur, &mut s.acc, &mut s.skip, threads);
                        } else {
                            s.skip.clear();
                            s.skip.extend_from_slice(input);
                        }
                    }
                    // run the body through the ping-pong buffers
                    for l in &r.body {
                        s.conv2d_step(l, &mut h_cur, &mut w_cur, &mut cur_in_a, threads);
                    }
                    // exact integer skip-add, in place over the body output
                    let cur: &mut Vec<i8> = if cur_in_a { &mut s.a } else { &mut s.b };
                    debug_assert_eq!(cur.len(), s.skip.len(), "residual join geometry");
                    for (o, &sk) in cur.iter_mut().zip(s.skip.iter()) {
                        *o = r.add.apply(*o, sk);
                    }
                }
                QuantStage::GlobalAvgPool(g) => {
                    let codes = if cur_in_a { &s.a } else { &s.b };
                    let t = if self.in_shape.len() == 3 { h_cur * w_cur } else { t_cur };
                    s.pooled.clear();
                    s.pooled.resize(g.channels, 0.0);
                    global_avg_pool_into(codes, g.channels, t, &g.dq, &mut s.pooled);
                }
                QuantStage::DenseHead(h) => h.forward_into(&s.pooled, logits),
            }
        }
    }

    /// Allocating convenience wrapper over [`QuantGraph::forward_into`].
    pub fn forward(&self, x: &[f32], s: &mut Scratch) -> Vec<f32> {
        let mut logits = vec![0f32; self.classes];
        self.forward_into(x, s, &mut logits, 1);
        logits
    }
}

// ---------------------------------------------------------------------------
// Synthetic architectures (offline tests / benches)
// ---------------------------------------------------------------------------

/// A synthetic sequence (1-D) architecture description.
pub struct SeqArch {
    pub name: &'static str,
    pub n_in: usize,
    pub frames: usize,
    pub embed_dim: usize,
    pub classes: usize,
    /// per conv layer: (c_out, ksize, dilation)
    pub convs: Vec<(usize, usize, usize)>,
}

/// A synthetic image (2-D residual) architecture description —
/// CIFAR-style ResNets: a 3x3 stem, `groups` of basic blocks (two 3x3
/// convs each; the first block of a group may stride and widen, taking
/// a 1x1 shortcut projection), GAP, dense head.
pub struct ImgArch {
    pub name: &'static str,
    /// input planes (3 for RGB)
    pub in_ch: usize,
    pub h: usize,
    pub w: usize,
    pub classes: usize,
    /// stem conv output channels
    pub stem_ch: usize,
    /// per group: (channels, residual blocks, stride of the first block)
    pub groups: Vec<(usize, usize, usize)>,
}

impl ImgArch {
    /// The paper's Table-6 CIFAR-10 network: ResNet-(6n+2) with n = 5 —
    /// 16/32/64-channel groups of five basic blocks on 32x32 inputs.
    pub fn resnet32() -> Self {
        ImgArch::resnet("resnet32", 5)
    }

    /// CIFAR ResNet-(6n+2) with `n` blocks per group.
    pub fn resnet(name: &'static str, n: usize) -> Self {
        assert!(n >= 1, "resnet needs at least one block per group");
        ImgArch {
            name,
            in_ch: 3,
            h: 32,
            w: 32,
            classes: 10,
            stem_ch: 16,
            groups: vec![(16, n, 1), (32, n, 2), (64, n, 2)],
        }
    }
}

/// A synthetic architecture description: enough to instantiate a full
/// [`QuantGraph`] with deterministic random parameters and no artifacts.
pub enum SynthArch {
    Seq(SeqArch),
    Img(ImgArch),
}

impl SynthArch {
    /// The paper's KWS temporal-conv net: 39 MFCC x 80 frames, 32-wide,
    /// seven ksize-3 layers with the [1, 1, 2, 4, 8, 8, 8] schedule.
    pub fn kws() -> Self {
        SynthArch::Seq(SeqArch {
            name: "kws",
            n_in: 39,
            frames: 80,
            embed_dim: 32,
            classes: 12,
            convs: [1usize, 1, 2, 4, 8, 8, 8].iter().map(|&d| (32, 3, d)).collect(),
        })
    }

    /// A deeper/wider second architecture with a different dilation
    /// schedule (two stacked pyramids reaching dilation 16) — exists to
    /// prove the graph API generalizes beyond the KWS monolith.
    pub fn deep_wide() -> Self {
        SynthArch::Seq(SeqArch {
            name: "deep-wide",
            n_in: 39,
            frames: 160,
            embed_dim: 48,
            classes: 12,
            convs: [1usize, 2, 4, 8, 16, 1, 2, 4, 8, 16].iter().map(|&d| (48, 3, d)).collect(),
        })
    }

    /// The paper's Table-6 ternary ResNet-32 on CIFAR-10-shaped inputs
    /// (see [`ImgArch::resnet32`]), expressed on the 2-D residual
    /// stage grammar.
    pub fn resnet32() -> Self {
        SynthArch::Img(ImgArch::resnet32())
    }

    /// A shallower CIFAR ResNet-(6n+2) — same stage grammar as
    /// [`SynthArch::resnet32`] at a fraction of the cost (tests).
    pub fn resnet(name: &'static str, n: usize) -> Self {
        SynthArch::Img(ImgArch::resnet(name, n))
    }

    pub fn name(&self) -> &'static str {
        match self {
            SynthArch::Seq(a) => a.name,
            SynthArch::Img(a) => a.name,
        }
    }
}

/// Build a [`QuantGraph`] for `arch` with deterministic Gaussian
/// parameters (seeded) — no artifacts or XLA needed. `nw`/`na` are the
/// weight/activation level counts (nw = 1 takes the ternary path).
pub fn synthetic_graph(arch: &SynthArch, nw: f32, na: f32, seed: u64) -> Result<QuantGraph> {
    match arch {
        SynthArch::Seq(a) => synthetic_seq_graph(a, nw, na, seed),
        SynthArch::Img(a) => super::resnet::synthetic_resnet_graph(a, nw, na, seed),
    }
}

fn synthetic_seq_graph(arch: &SeqArch, nw: f32, na: f32, seed: u64) -> Result<QuantGraph> {
    ensure!(!arch.convs.is_empty(), "architecture has no conv layers");
    let mut rng = Rng::new(seed ^ 0x9A_D06_C0DE);
    let dim = arch.embed_dim;

    let mut ew = vec![0f32; dim * arch.n_in];
    rng.fill_gaussian(&mut ew, 0.5);
    // unit BN fold (gamma = var = 1, beta = mean = 0), unit quant scales
    // — mirrors FqKwsNet::synthetic's parameterization
    let qa0 = QParams::new(1.0, na, -1.0);
    let embed = FpEmbed {
        w: ew,
        scale: vec![1.0; dim],
        shift: vec![0.0; dim],
        es: 1.0,
        na,
        out_q: qa0,
        n_in: arch.n_in,
        dim,
    };

    let mut layers = Vec::with_capacity(arch.convs.len());
    let mut c_in = dim;
    for (i, &(c_out, ksize, dilation)) in arch.convs.iter().enumerate() {
        let mut w = vec![0f32; c_out * c_in * ksize];
        rng.fill_gaussian(&mut w, 0.5);
        let ba = if i == 0 { -1.0 } else { 0.0 };
        let qa = QParams::new(1.0, na, ba);
        let qw = QParams::new(1.0, nw, -1.0);
        let mid = QParams::new(1.0, na, 0.0);
        let next = if i + 1 < arch.convs.len() { Some(QParams::new(1.0, na, 0.0)) } else { None };
        layers.push(QuantConv1d::new(&w, c_out, c_in, ksize, dilation, qa, qw, mid, next));
        c_in = c_out;
    }
    let filters = c_in;
    let gap = GlobalAvgPool { channels: filters, dq: layers.last().unwrap().out_grid() };

    let mut hw = vec![0f32; filters * arch.classes];
    rng.fill_gaussian(&mut hw, 0.5);
    let head =
        DenseHead { w: hw, b: vec![0.0; arch.classes], d_in: filters, d_out: arch.classes };

    QuantGraph::new(
        vec![
            QuantStage::FpEmbed(embed),
            QuantStage::FqConvStack(FqConvStack { layers }),
            QuantStage::GlobalAvgPool(gap),
            QuantStage::DenseHead(head),
        ],
        arch.frames,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_seq() -> SeqArch {
        SeqArch {
            name: "tiny",
            n_in: 3,
            frames: 12,
            embed_dim: 4,
            classes: 2,
            convs: vec![(4, 3, 1), (5, 3, 2)],
        }
    }

    fn tiny_arch() -> SynthArch {
        SynthArch::Seq(tiny_seq())
    }

    #[test]
    fn builds_and_plans_a_tiny_graph() {
        let g = synthetic_graph(&tiny_arch(), 1.0, 7.0, 3).expect("tiny graph");
        assert_eq!(g.frames(), 12);
        assert_eq!(g.in_numel(), 36);
        assert_eq!(g.classes(), 2);
        // t: 12 -> 10 -> 6
        assert_eq!(g.out_frames(), 6);
        assert_eq!(g.first_stack().len(), 2);
        assert!(g.macs_per_sample() > 0);
        let mut s = Scratch::for_graph(&g);
        let x = vec![0.25f32; g.in_numel()];
        let logits = g.forward(&x, &mut s);
        assert_eq!(logits.len(), 2);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn rejects_missing_conv_stack() {
        let good = synthetic_graph(&tiny_arch(), 1.0, 7.0, 3).unwrap();
        let mut stages = good.stages;
        // drop the conv stack entirely: the grammar check must fire
        stages.remove(1);
        let err = QuantGraph::new(stages, 12).unwrap_err().to_string();
        assert!(err.contains("FqConvStack"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_time_axis_collapse() {
        let mut arch = tiny_seq();
        arch.frames = 5; // 5 - 2 = 3, then 3 - 4: receptive span too wide
        let err = synthetic_graph(&SynthArch::Seq(arch), 1.0, 7.0, 3).unwrap_err().to_string();
        assert!(err.contains("receptive span"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_misordered_stages() {
        let good = synthetic_graph(&tiny_arch(), 1.0, 7.0, 3).unwrap();
        let mut stages = good.stages;
        stages.swap(2, 3); // head before GAP
        let err = QuantGraph::new(stages, 12).unwrap_err().to_string();
        assert!(err.contains("GlobalAvgPool"), "unexpected error: {err}");
    }

    #[test]
    fn builds_and_plans_a_small_2d_residual_graph() {
        let g = synthetic_graph(&SynthArch::resnet("r8", 1), 1.0, 7.0, 3).expect("resnet8");
        assert_eq!(g.in_shape(), &[3, 32, 32]);
        assert_eq!(g.in_numel(), 3 * 32 * 32);
        assert_eq!(g.classes(), 10);
        // 32x32 -> 16x16 -> 8x8 through the strided groups
        assert_eq!(g.out_frames(), 64);
        assert!(g.macs_per_sample() > 0);
        // plan must cover the widest boundary: 16ch @ 32x32 = 16384
        let s = Scratch::for_graph(&g);
        let (acc, a, b, skip, _fa, pooled) = s.capacities();
        assert!(a >= 16 * 32 * 32 && b >= 16 * 32 * 32, "code plan too small: {a}/{b}");
        assert!(acc >= 16 * 32 * 32, "acc plan too small: {acc}");
        assert!(skip >= 16 * 32 * 32, "skip plan too small: {skip}");
        assert!(pooled >= 64, "pooled plan too small: {pooled}");
    }

    #[test]
    fn rejects_2d_graph_without_a_stem() {
        let good = synthetic_graph(&SynthArch::resnet("r8", 1), 1.0, 7.0, 3).unwrap();
        let mut stages = good.stages;
        stages.remove(0); // drop the stem: the 2-D grammar check fires
        let err = QuantGraph::new_2d(stages, 32, 32).unwrap_err().to_string();
        assert!(err.contains("QuantStem2d"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_residual_with_a_missing_projection() {
        let good = synthetic_graph(&SynthArch::resnet("r8", 1), 1.0, 7.0, 3).unwrap();
        let mut stages = good.stages;
        // the first strided/widening block needs its 1x1 projection —
        // turning it into an identity shortcut must fail loudly
        for s in stages.iter_mut() {
            if let QuantStage::Residual(r) = s {
                if r.down.is_some() {
                    r.down = None;
                    break;
                }
            }
        }
        let err = QuantGraph::new_2d(stages, 32, 32).unwrap_err().to_string();
        assert!(err.contains("identity shortcut"), "unexpected error: {err}");
    }

    #[test]
    fn rejects_grammar_mixing() {
        // a 1-D stage list handed to the 2-D constructor (and vice
        // versa) is a build-time error, not a runtime surprise
        let seq = synthetic_graph(&tiny_arch(), 1.0, 7.0, 3).unwrap();
        let err = QuantGraph::new_2d(seq.stages, 12, 12).unwrap_err().to_string();
        assert!(err.contains("QuantStem2d"), "unexpected error: {err}");
        let img = synthetic_graph(&SynthArch::resnet("r8", 1), 1.0, 7.0, 3).unwrap();
        let err = QuantGraph::new(img.stages, 32).unwrap_err().to_string();
        assert!(err.contains("FpEmbed"), "unexpected error: {err}");
    }

    #[test]
    fn forward_bit_identical_across_thread_budgets() {
        let g = synthetic_graph(&SynthArch::deep_wide(), 1.0, 7.0, 11).expect("deep-wide");
        let mut rng = Rng::new(5);
        let mut x = vec![0f32; g.in_numel()];
        rng.fill_gaussian(&mut x, 1.0);
        let mut s = Scratch::for_graph(&g);
        let want = g.forward(&x, &mut s);
        for threads in [2usize, 4, 8] {
            let mut logits = vec![0f32; g.classes()];
            g.forward_into(&x, &mut s, &mut logits, threads);
            assert_eq!(logits, want, "threads={threads}");
        }
    }
}
